"""Batched-operand arena bench: stacked vs arena-filled compression steps.

Schema 8 adds *arena* cells (``kind: "arena"``) to the ``BENCH_TVC.json``
trajectory — one per (consumer, B) with B in {8, 64} — timing the SAME
logical compression step under both bucket assemblies:

* ``consumer: "grad"`` — a ``grad_compress.compress_and_sync`` step over B
  same-view gradient leaves inside a p = 1 shard_map, whole-step donated
  (``donate_argnums``): the stacked step pays the ``jnp.stack`` round trip
  per bucket per deflation pass, the arena step assembles through
  :func:`repro.core.arena.assemble_rows` (a ``dynamic_update_slice`` chain
  — no ``concatenate`` in the jaxpr, so donation writes the bucket rows in
  place).  The step threads its own state (donated inputs are consumed, so
  the timer feeds each step's outputs back in — exactly the training
  loop's dataflow).

* ``consumer: "serve"`` — one serving retirement-compression step
  (:meth:`repro.serve.engine.DecodeEngine._compress_retired` over a full
  slot batch): the stacked step eagerly slices every retired context out
  of the slot-stacked cache and ``jnp.stack``s the group, the arena step
  scatter-fills the persistent donated ``[B_g, *view]`` operand straight
  from the cache leaves (``_arena_fill_kv``) and reuses it warm across
  events.

Recorded per cell (beyond the core keys):

* ``fill_events`` — one ``[b, view, cold]`` entry per arena fill event over
  the timed steps, from which ``check_bench`` recomputes
  ``stack_copy_removed_bytes`` VERBATIM
  (``(bucket_stack_elems - arena_fill_elems) x itemsize`` per event — the
  removed-copy accounting can never drift from the closed forms), the
  modeled ``streamed_bytes``
  (``ranks x sweeps x b x hopm_streamed_elems_sweep(view) x itemsize`` per
  event) and ``launches``
  (``ranks x sweeps x dhopm_launches_per_sweep(d_view)`` per event);
* ``stack_us`` / ``us`` / ``arena_speedup`` — total stacked vs arena-filled
  wall time over the same step count, gated in aggregate (geomean
  ``arena_speedup`` > 1 over the B >= 16 cells);
* ``arena_plan`` — the planner's arena-vs-stack resolution for this bucket
  (``plan_compress(B, view).arena``), recomputed verbatim by the gate.

Arena cells carry ``engine: "arena-loop"`` — like serving cells, their
``us`` is a Python-driven step loop, so the tag keeps them out of the
timed-engine time-implied ratio map.

A run merges its arena cells into ``out_path`` whenever the file exists
(replacing prior arena cells, bumping the schema) — so the CI gate jobs
accumulate arena cells on top of the tvc_kernel / serving smoke payloads —
and writes a standalone payload otherwise.
"""
from __future__ import annotations

import json
import pathlib
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs import get_config
from repro.core import memory_model as mm
from repro.core.bucketing import tensor_view
from repro.core.dhopm import hopm3_batched, hopm_init_factors
from repro.models import registry
from repro.plan import aot as plan_aot
from repro.plan import calibration as plan_calibration
from repro.serve import DecodeEngine
from repro.serve.engine import _KV_MAX_ORDER, _KV_TIMELINE_KEYS, ServeStats
from repro.train import grad_compress as gc
from .bench_tvc_kernel import SMOKE_OUT_PATH, _compile_pair, _with_plan
from .common import emit, stream_triad_gbs

ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_PATH = ROOT / "BENCH_TVC.json"

SCHEMA = 8

BATCH_SIZES = (8, 64)
SMOKE_BATCH_SIZES = (8,)
STEPS = 12
SMOKE_STEPS = 4
WARMUP = 2

#: grad consumer: B same-view eligible leaves per bucket
GRAD_VIEW = (64, 48)
GRAD_RANK = 2
GRAD_SWEEPS = 2

#: serve consumer: the smoke serving model + retirement geometry
ARCH = "qwen2-1.5b"
MAX_SEQ = 64
SERVE_CTX_P = 32
COMP_SWEEPS = 2


def _geo_cell(view, *, B, consumer, ranks, sweeps, us, stack_us,
              fill_events, removed_bytes, peak, cold_us, warm_us):
    itemsize = 4
    streamed = sum(
        int(ranks * sweeps * b * mm.hopm_streamed_elems_sweep(tuple(v)))
        * itemsize
        for b, v, _cold in fill_events)
    launches = sum(
        ranks * sweeps * mm.dhopm_launches_per_sweep(len(v))
        for _b, v, _cold in fill_events)
    gbs = streamed / max(us, 1e-9) / 1e3   # bytes/us -> GB/s
    return _with_plan({
        "kind": "arena",
        "order": len(view),
        "mode": 0,
        "dtype": "f32",
        "layout": "aligned",
        "shape": list(view),
        "engine": "arena-loop",
        "batch": B,
        "consumer": consumer,
        "ranks": ranks,
        "sweeps": sweeps,
        "fill_events": fill_events,
        "stack_us": stack_us,
        "arena_speedup": stack_us / max(us, 1e-9),
        "stack_copy_removed_bytes": removed_bytes,
        "arena_plan": gc._use_arena(
            gc.CompressorCfg(rank=ranks, sweeps=sweeps),
            B, tuple(view), itemsize),
        "launches": launches,
        "blocks": [],
        "streamed_bytes": streamed,
        "us": us,
        "gbs": gbs,
        "pct_peak": gbs / peak * 100.0,
        "compile_cold_us": cold_us,
        "compile_warm_us": warm_us,
    })


# -- grad consumer ----------------------------------------------------------

def _grad_step_fn(cfg):
    mesh = Mesh(np.array(jax.devices("cpu")[:1]), ("dp",))

    def step(grads, state):
        ng, ns, _ = gc.compress_and_sync(grads, state, cfg, "dp")
        return ng, ns

    sm = shard_map(step, mesh=mesh, in_specs=(P(), P()),
                   out_specs=(P(), P()))
    # whole-step donation: the arena assembly's in-place write depends on
    # the gradient/state buffers being donated — the training loop's shape
    return jax.jit(sm, donate_argnums=(0, 1))


def _time_grad(cfg, B, steps):
    """Total us over ``steps`` donated compress_and_sync steps, threading
    each step's outputs back in (donated inputs are consumed)."""
    params = {f"w{i}": jnp.zeros(GRAD_VIEW, jnp.float32) for i in range(B)}
    key = jax.random.PRNGKey(0)
    grads = {k: jax.random.normal(jax.random.fold_in(key, i),
                                  GRAD_VIEW, jnp.float32)
             for i, k in enumerate(params)}
    state = gc.init_state(params, cfg)
    step = _grad_step_fn(cfg)
    for _ in range(WARMUP):
        grads, state = step(grads, state)
    jax.block_until_ready(state)
    t0 = time.perf_counter()
    for _ in range(steps):
        grads, state = step(grads, state)
    jax.block_until_ready(state)
    return (time.perf_counter() - t0) * 1e6


def _grad_cell(B, *, smoke, peak):
    steps = SMOKE_STEPS if smoke else STEPS
    mk = lambda arena: gc.CompressorCfg(          # noqa: E731
        rank=GRAD_RANK, sweeps=GRAD_SWEEPS, min_size=1024, prec="f32",
        bucket=True, arena=arena)
    stack_us = _time_grad(mk(False), B, steps)
    us = _time_grad(mk(True), B, steps)
    # one bucket of B leaves per step, assembled warm in-trace (the donated
    # step's scatter aliases the row materialization on every iteration)
    fill_events = [[B, list(GRAD_VIEW), 0]] * steps
    removed = sum(
        (mm.bucket_stack_elems(b, v, ranks=GRAD_RANK)
         - mm.arena_fill_elems(b, v, ranks=GRAD_RANK, cold=cold)) * 4
        for b, v, cold in fill_events)
    # cold/warm fresh-jit compile of the arena-assembled bucket chain (the
    # cell's launch unit: assemble_rows + one batched mulsum chain)
    rows = [jnp.zeros(GRAD_VIEW, jnp.float32) for _ in range(B)]
    xs0 = hopm_init_factors(jax.random.PRNGKey(0), GRAD_VIEW)[0]
    xs_b = [jnp.stack([x] * B) for x in xs0]

    def make_unit():
        from repro.core.arena import assemble_rows
        return lambda *rs: hopm3_batched(
            assemble_rows(rs[:B]), list(rs[B:]),
            sweeps=GRAD_SWEEPS, impl="mulsum")

    cold_us, warm_us = _compile_pair(make_unit, *rows, *xs_b)
    return _geo_cell(GRAD_VIEW, B=B, consumer="grad", ranks=GRAD_RANK,
                     sweeps=GRAD_SWEEPS, us=us, stack_us=stack_us,
                     fill_events=fill_events, removed_bytes=removed,
                     peak=peak, cold_us=cold_us, warm_us=warm_us)


# -- serve consumer ---------------------------------------------------------

def _serve_setup(B):
    cfg = get_config(ARCH, smoke=True)
    mod = registry.get(cfg.family)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    eng = DecodeEngine(cfg, params, batch_size=B, max_seq=MAX_SEQ, eos_id=7)
    caches = eng.new_slot_caches()
    items = [({"rid": i, "ctx": SERVE_CTX_P - 3}, i, SERVE_CTX_P)
             for i in range(B)]
    # the group view every retirement member compresses under
    leaf = next(caches[n] for n in _KV_TIMELINE_KEYS if n in caches)
    sliced, _stop = eng._kv_sliced_shape(leaf, SERVE_CTX_P)
    view = tensor_view(sliced, _KV_MAX_ORDER)
    return eng, caches, items, view


def _time_serve(eng, caches, items, arena, steps):
    def one():
        st = ServeStats()
        res = eng._compress_retired(items, caches=caches,
                                    sweeps=COMP_SWEEPS, impl="auto",
                                    arena=arena, stats=st)
        jax.block_until_ready([r[n].lam for r in res for n in r])
    for _ in range(WARMUP):
        one()
    t0 = time.perf_counter()
    for _ in range(steps):
        one()
    return (time.perf_counter() - t0) * 1e6


def _serve_cell(B, *, smoke, peak):
    steps = SMOKE_STEPS if smoke else STEPS
    eng, caches, items, view = _serve_setup(B)
    stack_us = _time_serve(eng, caches, items, False, steps)
    eng._arena.reset()
    us = _time_serve(eng, caches, items, True, steps)
    # keep only the timed steps' fill events (the timer's internal warmup
    # reps — including the one cold first-allocation fill — are dropped,
    # with their removed-bytes contribution subtracted to match)
    events = list(eng._arena.stats.fill_events)
    removed = eng._arena.stats.stack_copy_removed_bytes
    n_groups = len(events) // (steps + WARMUP)
    dropped, events = (events[:WARMUP * n_groups],
                       events[WARMUP * n_groups:])
    removed -= sum(
        (mm.bucket_stack_elems(b, v, ranks=1)
         - mm.arena_fill_elems(b, v, ranks=1, cold=cold)) * 4
        for b, v, cold in dropped)
    # cold/warm fresh-jit compile of the grouped chain at this view
    b_g = events[0][0] if events else B
    A_b = jnp.zeros((b_g,) + tuple(view), jnp.float32)
    xs0 = [hopm_init_factors(jax.random.PRNGKey(i), view)[0]
           for i in range(b_g)]
    xs_b = [jnp.stack([x[m] for x in xs0]) for m in range(len(view))]

    def make():
        return lambda A, *xs: hopm3_batched(
            A, list(xs), sweeps=COMP_SWEEPS, impl="mulsum")

    cold_us, warm_us = _compile_pair(make, A_b, *xs_b)
    return _geo_cell(view, B=B, consumer="serve", ranks=1,
                     sweeps=COMP_SWEEPS, us=us, stack_us=stack_us,
                     fill_events=events, removed_bytes=removed,
                     peak=peak, cold_us=cold_us, warm_us=warm_us)


def run(smoke: bool = False, out_path=None):
    if out_path:
        out_path = pathlib.Path(out_path)
    else:
        out_path = SMOKE_OUT_PATH if smoke else OUT_PATH
    cache_dir = tempfile.mkdtemp(prefix="bench_arena_xla_cache_")
    plan_aot.enable_persistent_cache(cache_dir)
    peak = stream_triad_gbs(2_000_000 if smoke else 30_000_000)
    lines = [emit("stream_triad", 0.0, f"{peak:.1f}GB/s")]

    cells = []
    for B in (SMOKE_BATCH_SIZES if smoke else BATCH_SIZES):
        for consumer, fn in (("grad", _grad_cell), ("serve", _serve_cell)):
            cell = fn(B, smoke=smoke, peak=peak)
            cells.append(cell)
            lines.append(emit(
                f"arena_{consumer}_B{B}", cell["us"],
                f"x{cell['arena_speedup']:.2f};"
                f"removed={cell['stack_copy_removed_bytes']}B"))

    if out_path.exists():
        # merge: replace prior arena cells, keep every other kind (gate
        # jobs accumulate arena cells on top of smoke payloads)
        payload = json.loads(out_path.read_text())
        payload["cells"] = [c for c in payload["cells"]
                            if c.get("kind") != "arena"] + cells
        payload["meta"]["schema"] = SCHEMA
        payload["meta"]["arena_timestamp"] = time.strftime(
            "%Y-%m-%dT%H:%M:%SZ", time.gmtime())
    else:
        payload = {
            "meta": {
                "schema": SCHEMA,
                "engine": "arena-loop",
                "backend": jax.default_backend(),
                "jax": jax.__version__,
                "smoke": smoke,
                "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                           time.gmtime()),
                "compile_cache": True,
                "calibration": plan_calibration.load().get("source"),
            },
            "stream_triad_gbs": peak,
            "cells": cells,
        }
    out_path.write_text(json.dumps(payload, indent=1) + "\n")
    print(f"# wrote {out_path} ({len(cells)} arena cells)", flush=True)
    return lines, payload


if __name__ == "__main__":
    run()
