"""Beyond-paper table: dHOPM_3 gradient-compression wire savings per assigned
architecture (analytic, from the compressor's own accounting)."""
from __future__ import annotations

import jax

from repro.configs import get_config
from repro.models import registry
from repro.train.grad_compress import CompressorCfg, wire_bytes_summary
from .common import emit


def run(archs=("qwen2-1.5b", "granite-8b", "rwkv6-3b")):
    lines = []
    ccfg = CompressorCfg(rank=4, sweeps=2, prec="bf16")
    for arch in archs:
        cfg = get_config(arch, smoke=True)  # structure matches; sizes smaller
        full = get_config(arch)
        mod = registry.get(cfg.family)
        params_abs = jax.eval_shape(
            lambda k: mod.init(full, k), jax.random.PRNGKey(0))
        stats = wire_bytes_summary(params_abs, ccfg, p_dp=16)
        lines.append(emit(
            f"compress_wire_{arch}", 0.0,
            f"dense{stats['dense_bytes']/1e9:.2f}GB_comp"
            f"{stats['compressed_bytes']/1e9:.3f}GB_{stats['ratio']:.0f}x"))
    return lines


if __name__ == "__main__":
    run()
