"""Paper Table 3 + Fig. 6 analogue: HOPM performance.

* classic (2-buffer) vs HOPM_3 (3-buffer) wall time + streamed memory — the
  paper's headline saving ((d-1)(d-2)/2 contractions).
* bandwidth normalized to the STREAM triad.
The paper's OmpSs/OpenMP task-overlap comparison maps to XLA's scheduler on
this backend; the buffer-schedule comparison is the paper-meaningful axis.
"""
from __future__ import annotations

import jax

from repro.core import tvc_bytes
from repro.core.dhopm import hopm3, hopm_classic
from repro.core.memory_model import simulate_sweep
from .common import TENSORS, emit, rand_tensor, stream_triad_gbs, time_fn


def streamed_bytes(d: int, n: int, algo: str) -> float:
    return simulate_sweep(n, d, 1, d - 1, algo) * 4


def run(orders=(3, 4, 6, 8, 10)):
    peak = stream_triad_gbs()
    lines = []
    for d in orders:
        shape = TENSORS[d]
        n = shape[0]
        A = rand_tensor(shape, seed=d)
        xs = [rand_tensor((m,), seed=50 + i) for i, m in enumerate(shape)]
        f3 = jax.jit(lambda A, *xs: hopm3(A, list(xs), sweeps=1)[1])
        fc = jax.jit(lambda A, *xs: hopm_classic(A, list(xs), sweeps=1)[1])
        ff = jax.jit(lambda A, *xs: hopm3(A, list(xs), sweeps=1,
                                          fuse_pairs=True)[1])
        t3 = time_fn(f3, A, *xs)
        tc = time_fn(fc, A, *xs)
        tf = time_fn(ff, A, *xs)
        b3 = streamed_bytes(d, n, "hopm3")
        bc = streamed_bytes(d, n, "classic")
        bw3 = b3 / t3 / 1e9
        bwc = bc / tc / 1e9
        lines.append(emit(f"hopm3_d{d}", t3 * 1e6,
                          f"{bw3:.1f}GB/s={bw3/peak*100:.0f}%peak"))
        lines.append(emit(f"hopm_classic_d{d}", tc * 1e6,
                          f"{bwc:.1f}GB/s={bwc/peak*100:.0f}%peak"))
        lines.append(emit(f"hopm3_speedup_d{d}", 0.0,
                          f"{tc/t3:.2f}x_time_{bc/b3:.2f}x_memory"))
        bf = streamed_bytes(d, n, "hopm3_fused")
        lines.append(emit(f"hopm3_fused_d{d}", tf * 1e6,
                          f"{t3/tf:.2f}x_time_{b3/bf:.2f}x_memory_vs_hopm3"))
    return lines


if __name__ == "__main__":
    run()
