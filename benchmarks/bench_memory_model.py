"""Paper Fig. 2: eta^-1 and H^-1 streamed-memory surfaces (analytical model
+ exact simulator cross-check), evaluated on the paper's own Table-1 sizes."""
from __future__ import annotations

import numpy as np

from repro.core import memory_model as mm
from .common import emit

PAPER_TABLE1 = {2: 30623, 3: 979, 4: 175, 5: 63, 6: 31, 7: 19, 8: 13, 9: 10, 10: 8}


def run():
    lines = []
    # Fig 2(a): eta^-1 at the paper's highlighted corners
    for d in (3, 10):
        n = PAPER_TABLE1[d]
        v00 = mm.eta_inv(n, d, n, 0)            # p_hat = 1, s_hat = 0
        v01 = mm.eta_inv(n, d, n, d - 1)        # p_hat = 1, s_hat = 1
        lines.append(emit(f"fig2a_eta_inv_d{d}_s0", 0.0, f"{v00:.3f}"))
        lines.append(emit(f"fig2a_eta_inv_d{d}_slast", 0.0, f"{v01:.3f}"))
    # Fig 2(b): H^-1 grid stats
    for d in (3, 10):
        n = PAPER_TABLE1[d]
        grid = [mm.H_inv(n, d, p, s)
                for p in (1, 2, 4, 8) for s in range(d)]
        lines.append(emit(
            f"fig2b_H_inv_d{d}", 0.0,
            f"mean={np.mean(grid):.2f}min={np.min(grid):.2f}max={np.max(grid):.2f}"))
    # simulator vs closed form (validation)
    errs = []
    for d, n in PAPER_TABLE1.items():
        for p in (2, 8):
            for s in range(d):
                sim = mm.simulate_sweep(n, d, p, s, "classic")
                cf = mm.M_par(n, d, p, s)
                errs.append(abs(sim - cf) / cf)
    lines.append(emit("fig2_sim_vs_eq6_maxrelerr", 0.0, f"{max(errs):.2e}"))
    return lines


if __name__ == "__main__":
    run()
