"""Paper Fig. 7: mixed-precision throughput of dTVC / dHOPM_3 — storage
formats f32 / bf16("brain") / f16("half"), compute in f32 (§5.5)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import tvc
from repro.core.dhopm import hopm3
from .common import TENSORS, emit, rand_tensor, time_fn

POLICIES = {"single": jnp.float32, "brain-single": jnp.bfloat16,
            "half-single": jnp.float16}


def run(orders=(3, 6, 10)):
    lines = []
    for d in orders:
        shape = TENSORS[d]
        base = {}
        for pol, dt in POLICIES.items():
            A = rand_tensor(shape, seed=d).astype(dt)
            xs = [rand_tensor((m,), seed=60 + i).astype(dt)
                  for i, m in enumerate(shape)]
            polname = {"single": "f32", "brain-single": "bf16",
                       "half-single": "f16"}[pol]
            fn = jax.jit(lambda A, *xs: hopm3(A, list(xs), sweeps=1,
                                              prec=polname)[1])
            t = time_fn(fn, A, *xs)
            base[pol] = t
            speed = base["single"] / t
            lines.append(emit(f"mp_hopm3_d{d}_{pol}", t * 1e6,
                              f"{speed:.2f}x_vs_single"))
        # dTVC single-mode comparison
        for pol, dt in POLICIES.items():
            A = rand_tensor(shape, seed=d).astype(dt)
            x = rand_tensor((shape[1],), seed=61).astype(dt)
            polname = {"single": "f32", "brain-single": "bf16",
                       "half-single": "f16"}[pol]
            fn = jax.jit(lambda A, x: tvc(A, x, 1, prec=polname))
            t = time_fn(fn, A, x)
            lines.append(emit(f"mp_tvc_d{d}_{pol}", t * 1e6, f"storage{dt.dtype.itemsize if hasattr(dt,'dtype') else jnp.dtype(dt).itemsize}B"))
    return lines


if __name__ == "__main__":
    run()
