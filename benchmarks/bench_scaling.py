"""Paper Figs. 3/4/6 analogue: distributed strong-scaling behaviour.

Runs in a SUBPROCESS with 8 virtual CPU devices (virtual devices share the
physical cores, so absolute speedup is not the point on this container — the
measurable axes are the paper's: (i) assembled vs distributed-output dTVC
(Fig. 3's CTF-style assembly penalty), (ii) k = s vs k != s (Eq. 2 vs Eq. 1),
(iii) dHOPM_3 delayed-reduction collective cost per splitting dim."""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys

_CHILD = r"""
import numpy as np, jax, jax.numpy as jnp, time
from repro.core import dtvc as dtvc_mod
from repro.core import dhopm as dh
from benchmarks.common import time_fn, emit, rand_tensor

mesh = jax.make_mesh((8,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))
shape = (64, 64, 64)
A = rand_tensor(shape, seed=3)

# Fig 3: distributed-output vs assembled dTVC (k != s)
for assemble in (False, True):
    f = lambda A, x: dtvc_mod.dtvc(A, x, 1, 2, mesh, "x", assemble=assemble)
    x = rand_tensor((shape[1],), seed=4)
    t = time_fn(f, A, x)
    emit(f"dtvc_d3_assemble_{assemble}", t*1e6, f"{1.0/t:.1f}it/s")

# Eq. 1 vs Eq. 2: k != s vs k == s
for (k, s, tag) in ((1, 2, "k_ne_s"), (2, 2, "k_eq_s")):
    x = rand_tensor((shape[k],), seed=5)
    f = lambda A, x, k=k, s=s: dtvc_mod.dtvc(A, x, k, s, mesh, "x", assemble=False if k != s else True)
    t = time_fn(f, A, x)
    emit(f"dtvc_d3_{tag}", t*1e6, f"{1.0/t:.1f}it/s")

# Fig 6: dHOPM_3 across splitting dims (delayed reduction)
xs = [rand_tensor((n,), seed=10+i) for i, n in enumerate(shape)]
for s in range(3):
    f = lambda A, *xs, s=s: dh.dhopm3(A, list(xs), mesh, "x", s=s, sweeps=1)[1]
    t = time_fn(f, A, *xs)
    emit(f"dhopm3_d3_s{s}", t*1e6, f"{1.0/t:.1f}it/s")

# sequential baseline for the same tensor (p = 1 reference)
f = lambda A, *xs: dh.hopm3(A, list(xs), sweeps=1)[1]
t = time_fn(f, A, *xs)
emit("hopm3_d3_p1", t*1e6, f"{1.0/t:.1f}it/s")
print("SCALING_DONE")
"""


def run():
    root = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = f"{root / 'src'}:{root}"
    proc = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                          capture_output=True, text=True, timeout=900)
    if "SCALING_DONE" not in proc.stdout:
        raise RuntimeError(f"scaling bench failed:\n{proc.stdout}\n{proc.stderr[-2000:]}")
    lines = [ln for ln in proc.stdout.splitlines() if "," in ln]
    for ln in lines:
        print(ln)
    return lines


if __name__ == "__main__":
    run()
