"""Serving-throughput bench: the continuous-batching decode engine under a
ragged request stream, with and without HOPM rank-1 KV compression.

Schema 7 adds *serving* cells (``kind: "serving"``) to the ``BENCH_TVC.json``
trajectory: each cell serves ``requests`` ragged prompts through the slot
batch (B in {8, 64}) on the smoke model — the bench times the *serving
substrate* (admission, vmapped slot stepping, per-request sampling, grouped
KV compression), not the model — and records

* ``req_per_s`` — completed requests over wall time, gated by the CI
  ``--serving-rps-min`` floor;
* ``p50_us`` / ``p99_us`` — per-engine-step latency percentiles, recorded
  against the fixed ``slo_p50_us`` / ``slo_p99_us`` budgets (informational:
  CI machines cannot hold a latency SLO without flaking, so the gate prices
  the *throughput* floor and the compression *accounting*, and the SLO
  fields document the budget the full-run numbers are read against);
* ``comp_events`` — one ``[group_size, view]`` entry per grouped
  ``hopm3_batched`` launch event, from which ``check_bench`` recomputes
  ``comp_launches`` exactly (``sweeps x dhopm_launches_per_sweep(d_view)``
  per event — *independent of the group size*, the launch-amortization
  guarantee) and the modeled ``streamed_bytes``
  (``B_g x sweeps x hopm_streamed_elems_sweep(view) x itemsize``);
* ``comp_dense_bytes`` / ``comp_factor_bytes`` — the dense KV context
  footprint vs its rank-1 factorization
  (:func:`repro.core.memory_model.rank1_factor_elems`); compression cells
  must price a real ratio (> 1).

Serving cells carry ``engine: "serve-loop"`` — their ``us`` is wall time of
a Python-driven loop full of model forwards, so the time-implied-traffic
check (which assumes ``us`` times ONE contraction) must not price them;
the tag keeps them out of the timed-engine ratio map.  The ``plan`` field
records the planner's resolution for the compression groups
(:func:`repro.plan.planner.plan_compress` — ``mulsum`` pinned, the bitwise
guarantee), recomputed verbatim by the schema-6 plan gate.

Smoke mode writes a standalone ``BENCH_TVC.smoke.json``; a full run merges
its serving cells into the committed ``BENCH_TVC.json`` (replacing prior
serving cells, leaving every other kind untouched) and bumps the schema.
"""
from __future__ import annotations

import json
import pathlib
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import memory_model as mm
from repro.core.bucketing import pad_extent, tensor_view
from repro.core.dhopm import hopm3_batched, hopm_init_factors
from repro.models import registry
from repro.plan import aot as plan_aot
from repro.plan import calibration as plan_calibration
from repro.plan import planner as plan_planner
from repro.serve import DecodeEngine, Request, RequestQueue
from .bench_tvc_kernel import SMOKE_OUT_PATH, _compile_pair, _with_plan
from .common import emit, stream_triad_gbs

ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_PATH = ROOT / "BENCH_TVC.json"

SCHEMA = 8

#: smoke model: the serving bench times the substrate, not the model
ARCH = "qwen2-1.5b"

BATCH_SIZES = (8, 64)
SMOKE_BATCH_SIZES = (8,)
#: requests per slot (guarantees mid-generation slot recycling)
REQS_PER_SLOT = 3
SMOKE_REQS_PER_SLOT = 2
MAX_NEW_TOKENS = 8
SMOKE_MAX_NEW_TOKENS = 4
PROMPT_LENS = (4, 9)            # ragged on purpose
MAX_SEQ = 64
COMP_SWEEPS = 2
CTX_QUANTUM = 16
EOS_ID = 7

#: fixed latency budgets the recorded percentiles are read against
#: (informational — see module docstring)
SLO_P50_US = 500_000.0
SLO_P99_US = 2_000_000.0


def _make_queue(B: int, n: int, max_new: int, vocab: int) -> RequestQueue:
    rng = np.random.default_rng(17)
    q = RequestQueue()
    for i in range(n):
        plen = PROMPT_LENS[i % len(PROMPT_LENS)]
        q.push(Request(
            rid=i,
            tokens=rng.integers(1, vocab, plen).astype(np.int32),
            max_new_tokens=max_new))
    return q


def _comp_view(cfg, mod) -> tuple:
    """The bucketing view a minimal retired context compresses under —
    recorded on every serving cell (compress=off included) so the plan
    field always prices the same group shape."""
    cache = jax.eval_shape(lambda: mod.init_cache(cfg, 1, MAX_SEQ))
    for name in ("k", "c"):
        if name in cache:
            a = cache[name]
            shape = a.shape[:1] + a.shape[2:]          # drop batch-1 dim
            shape = (shape[:-2]
                     + (min(pad_extent(1, CTX_QUANTUM), shape[-2]),)
                     + shape[-1:])
            return tensor_view(shape, 4)
    return ()


def _serve_cell(eng, cfg, *, B, compress, smoke, peak, view):
    n = B * (SMOKE_REQS_PER_SLOT if smoke else REQS_PER_SLOT)
    max_new = SMOKE_MAX_NEW_TOKENS if smoke else MAX_NEW_TOKENS
    queue = _make_queue(B, n, max_new, cfg.vocab_size)
    # warm the jitted entry points out of the timed region (per-prompt-len
    # prefills + the slot step): one tiny pre-queue
    pre = _make_queue(B, min(B, len(PROMPT_LENS) * 2), 1, cfg.vocab_size)
    eng.serve(pre, compress=compress, comp_sweeps=COMP_SWEEPS,
              ctx_quantum=CTX_QUANTUM)

    t0 = time.perf_counter()
    results, stats = eng.serve(queue, compress=compress,
                               comp_sweeps=COMP_SWEEPS,
                               ctx_quantum=CTX_QUANTUM)
    wall = time.perf_counter() - t0
    assert stats.completed == n, (stats.completed, n)

    step_us = sorted(stats.step_us) or [0.0]
    p50 = step_us[len(step_us) // 2]
    p99 = step_us[min(len(step_us) - 1, int(len(step_us) * 0.99))]
    itemsize = 4            # smoke-model caches are f32
    streamed = stats.comp_streamed_bytes
    us = wall * 1e6
    gbs = streamed / wall / 1e9

    # cold/warm fresh-jit compile of the serving path's launch unit: one
    # grouped rank-1 compression chain at this cell's view
    impl = plan_planner.plan_compress(B, view, itemsize=itemsize).impl
    A_b = jnp.zeros((B,) + tuple(view), jnp.float32)
    xs0 = [hopm_init_factors(jax.random.PRNGKey(i), view)[0]
           for i in range(B)]
    xs_b = [jnp.stack([x[m] for x in xs0]) for m in range(len(view))]

    def make(impl_=impl):
        return lambda A, *xs: hopm3_batched(
            A, list(xs), sweeps=COMP_SWEEPS, impl=impl_)

    cold_us, warm_us = _compile_pair(make, A_b, *xs_b)

    return _with_plan({
        "kind": "serving",
        "order": len(view),
        "mode": 0,
        "dtype": "f32",
        "layout": "aligned",
        "shape": list(view),
        "engine": "serve-loop",
        "batch": B,
        "compress": compress,
        "requests": n,
        "steps": stats.steps,
        "prefills": stats.prefills,
        "recycled": stats.recycled,
        "generated_tokens": stats.generated_tokens,
        "req_per_s": n / wall,
        "tok_per_s": stats.generated_tokens / wall,
        "p50_us": p50,
        "p99_us": p99,
        "slo_p50_us": SLO_P50_US,
        "slo_p99_us": SLO_P99_US,
        "sweeps": COMP_SWEEPS,
        "comp_events": stats.comp_events,
        "comp_launches": stats.comp_launches,
        "comp_dense_bytes": stats.comp_dense_bytes,
        "comp_factor_bytes": stats.comp_factor_bytes,
        "blocks": [],
        "streamed_bytes": streamed,
        "us": us,
        "gbs": gbs,
        "pct_peak": gbs / peak * 100.0,
        "compile_cold_us": cold_us,
        "compile_warm_us": warm_us,
    })


def run(smoke: bool = False, out_path=None):
    if out_path:
        out_path = pathlib.Path(out_path)
    else:
        out_path = SMOKE_OUT_PATH if smoke else OUT_PATH
    cache_dir = tempfile.mkdtemp(prefix="bench_serving_xla_cache_")
    plan_aot.enable_persistent_cache(cache_dir)
    peak = stream_triad_gbs(2_000_000 if smoke else 30_000_000)
    lines = [emit("stream_triad", 0.0, f"{peak:.1f}GB/s")]

    cfg = get_config(ARCH, smoke=True)
    mod = registry.get(cfg.family)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    view = _comp_view(cfg, mod)

    cells = []
    for B in (SMOKE_BATCH_SIZES if smoke else BATCH_SIZES):
        eng = DecodeEngine(cfg, params, batch_size=B, max_seq=MAX_SEQ,
                           eos_id=EOS_ID)
        for compress in (False, True):
            cell = _serve_cell(eng, cfg, B=B, compress=compress,
                               smoke=smoke, peak=peak, view=view)
            cells.append(cell)
            lines.append(emit(
                f"serveB{B}_{'comp' if compress else 'raw'}",
                cell["us"],
                f"{cell['req_per_s']:.2f}req/s;"
                f"{cell['comp_launches']}launches;"
                f"p50={cell['p50_us'] / 1e3:.0f}ms"))

    if not smoke and out_path.exists():
        # merge: replace prior serving cells, keep every other kind
        payload = json.loads(out_path.read_text())
        payload["cells"] = [c for c in payload["cells"]
                            if c.get("kind") != "serving"] + cells
        payload["meta"]["schema"] = SCHEMA
        payload["meta"]["serving_timestamp"] = time.strftime(
            "%Y-%m-%dT%H:%M:%SZ", time.gmtime())
    else:
        payload = {
            "meta": {
                "schema": SCHEMA,
                "engine": "serve-loop",
                "backend": jax.default_backend(),
                "jax": jax.__version__,
                "smoke": smoke,
                "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                           time.gmtime()),
                "compile_cache": True,
                "calibration": plan_calibration.load().get("source"),
            },
            "stream_triad_gbs": peak,
            "cells": cells,
        }
    out_path.write_text(json.dumps(payload, indent=1) + "\n")
    print(f"# wrote {out_path} ({len(cells)} serving cells)", flush=True)
    return lines, payload


if __name__ == "__main__":
    run()
