"""Paper Table 2: looped vs unfolded vs native TVC bandwidth, averaged over
all contraction modes, normalized to the measured STREAM triad."""
from __future__ import annotations

import numpy as np
import jax

from repro.core import tvc, tvc_bytes
from .common import TENSORS, emit, rand_tensor, stream_triad_gbs, time_fn


def run(orders=(2, 3, 4, 5, 6, 8, 10), impls=("looped", "unfolded", "native")):
    peak = stream_triad_gbs()
    lines = [emit("stream_triad", 0.0, f"{peak:.1f}GB/s")]
    rows = {}
    for d in orders:
        shape = TENSORS[d]
        A = rand_tensor(shape, seed=d)
        for impl in impls:
            bws = []
            t_total = 0.0
            for k in range(d):
                x = rand_tensor((shape[k],), seed=100 + k)
                fn = jax.jit(lambda A, x, k=k, impl=impl: tvc(A, x, k, impl=impl))
                t = time_fn(fn, A, x)
                t_total += t
                bws.append(tvc_bytes(shape, k, 4) / t / 1e9)
            mean = float(np.mean(bws))
            std = float(np.std(bws))
            rows[(d, impl)] = (mean / peak * 100, std / peak * 100)
            lines.append(emit(
                f"tvc_d{d}_{impl}", t_total / d * 1e6,
                f"{mean:.1f}GB/s={mean/peak*100:.0f}%peak±{std/peak*100:.0f}"))
    return lines, rows


if __name__ == "__main__":
    run()
