"""Achieved-bandwidth harness for the zero-copy TVC kernel path.

Measures GB/s per (order, mode, dtype, aligned|ragged) cell — streamed bytes
per :func:`repro.core.tvc.tvc_bytes` (the paper's §2/§5 bandwidth
denominator, which the no-copy kernels now move *exactly*) over median wall
time — normalized against a measured STREAM-triad soak, and writes the
trajectory file ``BENCH_TVC.json`` at the repo root so future PRs have a
fixed schema to regress against.

Engine selection: on TPU the cells time the compiled Pallas kernels
(``impl="pallas"``); elsewhere a full run times the XLA ``native`` einsum as
the bandwidth proxy (interpret-mode Pallas timings are meaningless), while
``--smoke`` runs tiny shapes through interpret-mode Pallas purely to exercise
the writer and schema on CPU CI.  The engine is recorded per run so
trajectory comparisons stay apples-to-apples.

Each single-mode cell also records ``pad_overhead`` — the streamed-traffic
ratio the old pad-and-copy wrapper would have paid for that shape (from
:func:`repro.core.memory_model.pad_overhead`); aligned cells sit at 1.0.

Schema 2 adds *fused-pair* cells (``kind: "tvc2"``): the leading and tail
adjacent-mode pairs of every shape through the single-launch pair kernels
(``mode`` records k1), with ``streamed_bytes`` from
:func:`repro.core.tvc.tvc2_bytes` and ``fused_saving`` — the predicted
two-launch / fused traffic ratio
(:func:`repro.core.memory_model.fused_pair_saving`) that the CI bandwidth
gate holds the accounting to.

Schema 3 adds *batched* cells (``kind: "tvc_batched"``): B in {8, 64}
stacked copies of a deliberately small tensor — the dispatch-dominated
regime PR 3's calibration measured at 18-43x over the memory model — where
each cell times BOTH the one-launch batched path (``us``) and the same B
contractions as B separate launches inside one jit (``sep_us``), recording
``batched_speedup = sep_us / us`` next to the
:func:`repro.core.memory_model.launch_amortized_speedup` prediction.
Batched cells always run a *timed* engine — compiled Pallas on TPU,
elsewhere the bitwise-batchable ``mulsum`` engine that
``train.grad_compress``'s buckets actually run (tagged ``native-xla``) —
even under ``--smoke``, and each carries its own ``engine`` tag.  The CI
gate requires the geometric mean of ``batched_speedup`` over the B >= 16
cells to exceed 1: one batched launch must measurably beat B separate
launches where the launch-amortization model says it must.

Schema 4 adds *whole-algorithm batched* cells (``kind: "dhopm3_batched"``):
B complete split dHOPM_3 power-iteration chains run in lockstep through the
split-aware batched walker — ``launches`` batched contraction launches per
sweep (:func:`repro.core.memory_model.dhopm_launches_per_sweep`,
independent of B and jaxpr-asserted in the tests) — timed against B
separate ``dhopm3`` runs inside one jit.  ``streamed_bytes`` comes from the
:func:`repro.core.memory_model.simulate_sweep` closed form (B x the
per-tensor sweep, ``split_alive=True`` — the split schedule is structural
even at p = 1), and the gate grants these cells ``launches`` dispatch
allowances instead of one (their unbatched equivalent would get
B x launches).

Schema 5 adds *sync-vs-pipelined* cells (``kind: "dhopm3_overlap"``): one
split dHOPM_3 chain timed through the synchronous walker (``sync_us``) and
through the pipelined walker (``us``, ``overlap=`` chunked tails + staged
reduction hops), recording ``overlap_speedup = sync_us / us`` and the
launch counts of both schedules
(:func:`repro.core.memory_model.dhopm_launches_per_sweep` with and without
``overlap_chunks`` — jaxpr-asserted in the tests).  ``streamed_bytes``
comes from the overlap-aware ``simulate_sweep(..., overlap_chunks)`` form
((C-1) extra vector re-reads per pipelined tail).  Each cell also carries
the :func:`repro.core.memory_model.dhopm_time_sweep` prediction for the
reference distributed configuration (``model_p`` processes, wire at
``model_wire_gbs``): ``predicted_wire_us`` / ``predicted_exposed_us`` /
``predicted_hidden_us``, which the gate recomputes exactly and requires to
predict real hiding (``predicted_hidden_us > 0``) — the p = 1 cells measure
the pipeline's launch-overhead cost (gated by a geomean
``overlap_speedup`` floor), the model regression-tests the wire-hiding
claim the 8-device bitwise checks can't time.

Schema 6 wires in the :mod:`repro.plan` planner and warm-start layer:

* the primary ``tvc``/``tvc2`` timings (and the dhopm walkers' engine)
  run ``impl="auto"`` on timed engines — the bench measures what the
  dispatcher actually ships, not a hand-picked flag;
* every cell records ``plan`` — the planner's resolved
  (engine, fused, overlap_chunks, algo) for its inputs, recomputed
  verbatim by ``check_bench`` against the committed calibration table;
* every cell records ``compile_cold_us`` / ``compile_warm_us`` — two
  fresh identically-named jit lower+compiles against a fresh persistent
  compilation cache enabled for the run (the second must deserialize,
  not recompile: the warm-start gate);
* dispatch-dominated ``tvc``/``tvc2`` cells (time-implied ratio >=
  ``planner.DISPATCH_DOMINATED_X``) additionally sweep every explicit
  engine flag (``flags``: engine -> us; ``mulsum`` is excluded from the
  single-mode sweep — its CPU behavior is bimodal and auto never picks
  it there) and record ``auto_us`` + ``auto_vs_best_flag`` /
  ``auto_vs_worst_flag``, with one higher-rep retry if timer noise puts
  auto above the gate's 1.1x-of-best ceiling on the first attempt.

Schema 7 adds *serving* cells (``kind: "serving"``) — written by
:mod:`benchmarks.bench_serving`, which merges them into this file's
trajectory: the continuous-batching decode engine under a ragged request
stream, with grouped HOPM rank-1 KV compression accounted per launch
event.  See that module for the cell contract and gates.
"""
from __future__ import annotations

import json
import math
import pathlib
import tempfile
import time

import jax

import jax.numpy as jnp

from repro.core import tvc, tvc2, tvc2_bytes, tvc_batched, tvc_bytes
from repro.core.dhopm import OVERLAP_CHUNKS_DEFAULT, dhopm3, dhopm3_batched
from repro.core.memory_model import (
    dhopm_launches_per_sweep,
    dhopm_time_sweep,
    fused_pair_saving,
    launch_amortized_speedup,
    pad_overhead,
    simulate_sweep,
)
from repro.core.mixed_precision import get_policy
from repro.core.tvc import mode_uv
from repro.kernels import autotune
from repro.plan import aot as plan_aot
from repro.plan import calibration as plan_calibration
from repro.plan import planner as plan_planner
from .common import emit, rand_tensor, stream_triad_gbs, time_fn

ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_PATH = ROOT / "BENCH_TVC.json"
# smoke runs must never clobber the committed full-run trajectory artifact
SMOKE_OUT_PATH = ROOT / "BENCH_TVC.smoke.json"

SHAPES = {
    "aligned": {3: (256, 256, 256), 4: (64, 64, 64, 64), 5: (24,) * 5},
    "ragged": {3: (251, 257, 263), 4: (61, 67, 71, 59),
               5: (23, 19, 29, 31, 17)},
}
SMOKE_SHAPES = {
    "aligned": {3: (8, 16, 128), 4: (4, 8, 8, 16)},
    "ragged": {3: (5, 7, 129), 4: (3, 5, 7, 9)},
}
DTYPES = ("f32", "bf16")

# batched cells: deliberately SMALL tensors (the dispatch-dominated regime
# batching exists for), stacked B deep; modes cover both batched kernel
# bodies (v > 1 and the matvec tail)
BATCH_SHAPES = {"aligned": (16, 16, 16), "ragged": (13, 17, 11)}
SMOKE_BATCH_SHAPES = {"aligned": (8, 8, 16), "ragged": (5, 7, 9)}
BATCH_SIZES = (8, 64)
BATCH_MODES = (1, 2)
SMOKE_BATCH_DTYPES = ("f32",)

# dhopm3_batched cells (schema 4): B whole split dHOPM_3 chains per mesh in
# ONE launch sequence (launch count per sweep independent of B) vs B
# separate dhopm3 runs inside one jit.  Hypersquare shapes so the
# simulate_sweep closed form prices the streamed bytes; deliberately small
# (the dispatch-dominated regime the batched walker exists for); split at
# the paper-recommended s = d-1; p = 1 mesh so the cells run on any host
# (the split schedule is structural — it gates fusion and takes the Eq. 2
# slice path even at p = 1, priced with split_alive=True).
DHOPM_SHAPE = (8, 8, 8, 8)
SMOKE_DHOPM_SHAPE = (4, 4, 4, 4)
DHOPM_BATCH_SIZES = (8, 64)
SMOKE_DHOPM_BATCH_SIZES = (8,)
DHOPM_SWEEPS = 1

# dhopm3_overlap cells (schema 5): sync vs pipelined walker on one split
# chain.  The p = 1 timing measures what the pipeline COSTS (chunked tails
# = more, smaller launches; zero wire to hide on one process), so the gate
# holds the geomean overlap_speedup to a calibrated floor rather than > 1;
# the wire-hiding claim itself is carried by the dhopm_time_sweep model at
# the reference distributed configuration below, which the gate recomputes
# and requires to predict real hiding.
OVERLAP_MODEL_P = 8          # reference processes for the time model
OVERLAP_MODEL_WIRE_FRAC = 1 / 8.0   # wire_gbs = this fraction of STREAM peak


#: per-cell auto-vs-best-flag ceiling (mirrors check_bench --auto-ratio);
#: one higher-rep retry below this keeps timer noise from failing CI
AUTO_RATIO = 1.1


def _engine(smoke: bool) -> str:
    if jax.default_backend() == "tpu":
        return "pallas"
    return "pallas-interpret" if smoke else "native-xla"


def _compile_pair(make_fn, *args):
    """(cold_us, warm_us): two *fresh* identically-named jits of the same
    computation, lower+compiled back to back against the run's persistent
    compilation cache — the first pays the real compile (and populates the
    cache), the second must deserialize."""
    out = []
    for _ in range(2):
        t0 = time.perf_counter()
        jax.jit(make_fn()).lower(*args).compile()
        out.append((time.perf_counter() - t0) * 1e6)
    return out[0], out[1]


def _flag_sweep(make_fn, impls, args, reps):
    """Time ``impl="auto"`` against every explicit engine flag.

    Returns (auto_us, {impl: us}).  Auto's resolved engine is always one
    of ``impls``, so a clean measurement can't lose by more than noise —
    but CPU timing noise is one-sided (contention only ever adds time),
    and on a crossover-tie cell auto-vs-best compares two timings of the
    SAME executable.  So every timing is the element-wise min over up to
    4 attempts at growing reps (min-of-reps estimation), stopping early
    once auto clears the check_bench ceiling (AUTO_RATIO x best flag)."""
    auto_us, flags = float("inf"), {}
    for attempt in (0, 1, 2, 3):
        r = reps + 2 * attempt
        for impl_ in impls:
            t = time_fn(jax.jit(make_fn(impl_)), *args, reps=r) * 1e6
            flags[impl_] = min(t, flags.get(impl_, float("inf")))
        auto_us = min(auto_us,
                      time_fn(jax.jit(make_fn("auto")), *args, reps=r) * 1e6)
        if auto_us <= AUTO_RATIO * min(flags.values()):
            break
    return auto_us, flags


def _with_plan(cell: dict) -> dict:
    """Attach the planner's resolved plan (the schema-6 divergence gate
    recomputes this verbatim from the committed calibration table)."""
    cell["plan"] = plan_planner.plan_for_cell(cell)
    return cell


def _cell_blocks(shape, k, prec):
    u, nk, v = mode_uv(shape, k)
    if v == 1:
        bu, bk = autotune.pick_tvc2_blocks(
            u, nk, storage=prec.storage, compute=prec.compute)
        return u, nk, v, (bu, bk, 1)
    return u, nk, v, autotune.pick_tvc3_blocks(
        u, nk, v, storage=prec.storage, compute=prec.compute)


def _pair_view(shape, k1):
    u = math.prod(shape[:k1])
    n1, n2 = shape[k1], shape[k1 + 1]
    v = math.prod(shape[k1 + 2:])
    return u, n1, n2, v


def _pair_blocks(shape, k1, prec):
    u, n1, n2, v = _pair_view(shape, k1)
    if v == 1:
        bu, b1, b2 = autotune.pick_tvc2_pair_blocks(
            u, n1, n2, storage=prec.storage, compute=prec.compute)
        return (bu, b1, b2, 1)
    return autotune.pick_tvc4_blocks(
        u, n1, n2, v, storage=prec.storage, compute=prec.compute)


def run(smoke: bool = False, out_path=None):
    if out_path:
        out_path = pathlib.Path(out_path)
    else:
        out_path = SMOKE_OUT_PATH if smoke else OUT_PATH
    shapes = SMOKE_SHAPES if smoke else SHAPES
    engine = _engine(smoke)
    # timed engines run what the dispatcher actually ships; smoke keeps
    # interpret-mode pallas (the point of smoke is exercising that path)
    impl = "pallas" if engine == "pallas-interpret" else "auto"
    on_tpu = jax.default_backend() == "tpu"
    flag_impls_tvc = (("pallas", "native") if on_tpu
                      else ("native", "looped", "unfolded"))
    flag_impls_tvc2 = (("pallas", "native", "mulsum") if on_tpu
                       else ("native", "mulsum"))
    # a FRESH persistent compilation cache per run: the per-cell cold
    # compile must be genuinely cold, the warm one a deserialize
    cache_dir = tempfile.mkdtemp(prefix="bench_tvc_xla_cache_")
    plan_aot.enable_persistent_cache(cache_dir)
    peak = stream_triad_gbs(2_000_000 if smoke else 30_000_000)
    lines = [emit("stream_triad", 0.0, f"{peak:.1f}GB/s")]

    cells = []
    for layout, by_order in shapes.items():
        for d, shape in sorted(by_order.items()):
            modes = (0, d - 1) if smoke else range(d)
            for polname in DTYPES:
                prec = get_policy(polname)
                A = rand_tensor(shape, dtype=prec.storage, seed=d)
                itemsize = prec.storage_bytes
                for k in modes:
                    x = rand_tensor((shape[k],), dtype=prec.storage,
                                    seed=100 + k)

                    def make(impl_=impl, k=k, prec=prec):
                        return lambda A, x: tvc(A, x, k, impl=impl_,
                                                prec=prec)

                    cold_us, warm_us = _compile_pair(make, A, x)
                    fn = jax.jit(make())
                    t = time_fn(fn, A, x, reps=3 if smoke else 5)
                    nbytes = tvc_bytes(shape, k, itemsize)
                    gbs = nbytes / t / 1e9
                    u, nk, v, blocks = _cell_blocks(shape, k, prec)
                    cell = _with_plan({
                        "kind": "tvc",
                        "order": d,
                        "mode": k,
                        "dtype": polname,
                        "layout": layout,
                        "shape": list(shape),
                        "blocks": list(blocks),
                        "streamed_bytes": nbytes,
                        "us": t * 1e6,
                        "gbs": gbs,
                        "pct_peak": gbs / peak * 100.0,
                        "pad_overhead": pad_overhead(u, nk, v, blocks),
                        "compile_cold_us": cold_us,
                        "compile_warm_us": warm_us,
                    })
                    if impl == "auto" and plan_planner.dispatch_dominated(
                            t * 1e6, nbytes, peak):
                        auto_us, flags = _flag_sweep(
                            make, flag_impls_tvc, (A, x),
                            3 if smoke else 5)
                        cell["flags"] = flags
                        cell["auto_us"] = auto_us
                        cell["auto_vs_best_flag"] = \
                            min(flags.values()) / auto_us
                        cell["auto_vs_worst_flag"] = \
                            max(flags.values()) / auto_us
                    cells.append(cell)
                    lines.append(emit(
                        f"tvck_d{d}m{k}_{polname}_{layout}", t * 1e6,
                        f"{gbs:.2f}GB/s={gbs/peak*100:.0f}%peak"))

                # fused pairs: the leading pair and the chain tail (one
                # launch each through the pair kernels; einsum proxy on CPU)
                pair_k1s = (d - 2,) if smoke else sorted({0, d - 2})
                for k1 in pair_k1s:
                    x1 = rand_tensor((shape[k1],), dtype=prec.storage,
                                     seed=200 + k1)
                    x2 = rand_tensor((shape[k1 + 1],), dtype=prec.storage,
                                     seed=201 + k1)

                    def make(impl_=impl, k1=k1, prec=prec):
                        return lambda A, x1, x2: tvc2(
                            A, x1, k1, x2, k1 + 1, impl=impl_, prec=prec)

                    cold_us, warm_us = _compile_pair(make, A, x1, x2)
                    fn = jax.jit(make())
                    t = time_fn(fn, A, x1, x2, reps=3 if smoke else 5)
                    nbytes = tvc2_bytes(shape, k1, k1 + 1, itemsize)
                    gbs = nbytes / t / 1e9
                    u, n1, n2, v = _pair_view(shape, k1)
                    cell = _with_plan({
                        "kind": "tvc2",
                        "order": d,
                        "mode": k1,
                        "dtype": polname,
                        "layout": layout,
                        "shape": list(shape),
                        "blocks": list(_pair_blocks(shape, k1, prec)),
                        "streamed_bytes": nbytes,
                        "us": t * 1e6,
                        "gbs": gbs,
                        "pct_peak": gbs / peak * 100.0,
                        "fused_saving": fused_pair_saving(u, n1, n2, v),
                        "compile_cold_us": cold_us,
                        "compile_warm_us": warm_us,
                    })
                    if impl == "auto" and plan_planner.dispatch_dominated(
                            t * 1e6, nbytes, peak):
                        auto_us, flags = _flag_sweep(
                            make, flag_impls_tvc2, (A, x1, x2),
                            3 if smoke else 5)
                        cell["flags"] = flags
                        cell["auto_us"] = auto_us
                        cell["auto_vs_best_flag"] = \
                            min(flags.values()) / auto_us
                        cell["auto_vs_worst_flag"] = \
                            max(flags.values()) / auto_us
                    cells.append(cell)
                    lines.append(emit(
                        f"tvck2_d{d}p{k1}_{polname}_{layout}", t * 1e6,
                        f"{gbs:.2f}GB/s={gbs/peak*100:.0f}%peak"))

    # batched cells: small tensors stacked B deep — ONE batched launch vs
    # the same B contractions as B separate launches inside one jit (the
    # per-leaf-loop schedule the batched kernels replace).  These cells
    # ALWAYS run a timed engine (compiled Pallas on TPU; elsewhere the
    # bitwise-batchable mulsum engine grad_compress's buckets actually run,
    # tagged native-xla), interpret mode included — the speedup is a
    # same-engine relative measure and interpreter grid-step overhead would
    # drown it.  Each cell carries its own ``engine`` tag.
    batch_dtypes = SMOKE_BATCH_DTYPES if smoke else DTYPES
    batch_shapes = SMOKE_BATCH_SHAPES if smoke else BATCH_SHAPES
    from .check_bench import DEFAULT_DISPATCH_US
    # the batched entry points dispatch via the planner; the B-separate
    # reference loop pins the SAME engine the plan resolves to (the
    # speedup is a same-engine relative measure)
    impl_b = "auto"
    engine_b = "pallas" if on_tpu else "native-xla"
    dispatch_us = DEFAULT_DISPATCH_US
    for layout, shape in batch_shapes.items():
        d = len(shape)
        for polname in batch_dtypes:
            prec = get_policy(polname)
            itemsize = prec.storage_bytes
            for B in BATCH_SIZES:
                Ab = rand_tensor((B,) + shape, dtype=prec.storage, seed=d)
                for k in BATCH_MODES:
                    xb = rand_tensor((B, shape[k]), dtype=prec.storage,
                                     seed=300 + k)
                    sep_impl = plan_planner.plan_batched(
                        B, shape, k, itemsize=itemsize).impl

                    def make_b(k=k, prec=prec):
                        return lambda A, x: tvc_batched(
                            A, x, k, impl=impl_b, prec=prec)

                    cold_us, warm_us = _compile_pair(make_b, Ab, xb)
                    fn_b = jax.jit(make_b())
                    fn_sep = jax.jit(lambda A, x, k=k, B=B: jnp.stack([
                        tvc(A[i], x[i], k, impl=sep_impl, prec=prec)
                        for i in range(B)]))
                    t = time_fn(fn_b, Ab, xb, reps=3 if smoke else 5)
                    t_sep = time_fn(fn_sep, Ab, xb, reps=3 if smoke else 5,
                                    warmup=1)
                    one = tvc_bytes(shape, k, itemsize)
                    nbytes = B * one
                    gbs = nbytes / t / 1e9
                    u, nk, v = mode_uv(shape, k)
                    if v == 1:
                        blocks = autotune.pick_tvc2_batched_blocks(
                            B, u, nk, storage=prec.storage,
                            compute=prec.compute) + (1,)
                    else:
                        blocks = autotune.pick_tvc3_batched_blocks(
                            B, u, nk, v, storage=prec.storage,
                            compute=prec.compute)
                    cells.append(_with_plan({
                        "kind": "tvc_batched",
                        "order": d,
                        "mode": k,
                        "dtype": polname,
                        "layout": layout,
                        "shape": list(shape),
                        "engine": engine_b,
                        "batch": B,
                        "blocks": list(blocks),
                        "streamed_bytes": nbytes,
                        "us": t * 1e6,
                        "sep_us": t_sep * 1e6,
                        "gbs": gbs,
                        "pct_peak": gbs / peak * 100.0,
                        "batched_speedup": t_sep / t,
                        "predicted_speedup": launch_amortized_speedup(
                            B, one, peak, dispatch_us),
                        "compile_cold_us": cold_us,
                        "compile_warm_us": warm_us,
                    }))
                    lines.append(emit(
                        f"tvckB{B}_d{d}m{k}_{polname}_{layout}", t * 1e6,
                        f"{gbs:.2f}GB/s;x{t_sep / t:.1f}vs{B}sep"))

    # dhopm3_batched cells: B whole split dHOPM_3 power-iteration chains in
    # lockstep — one (batched) contraction launch per chain position — vs B
    # separate dhopm3 runs in one jit (the per-tensor loop the batched
    # walker replaces).  Same engine policy as the batched TVC cells.
    mesh1 = jax.make_mesh((1,), ("x",))
    d_shape = SMOKE_DHOPM_SHAPE if smoke else DHOPM_SHAPE
    d_batches = SMOKE_DHOPM_BATCH_SIZES if smoke else DHOPM_BATCH_SIZES
    dd = len(d_shape)
    s_split = dd - 1
    prec_f32 = get_policy("f32")
    algo_of = {False: "hopm3", True: "hopm3_fused"}
    for B in d_batches:
        Ab = rand_tensor((B,) + d_shape, dtype=prec_f32.storage, seed=dd)
        xsb = [rand_tensor((B, n), dtype=prec_f32.storage, seed=400 + j)
               for j, n in enumerate(d_shape)]
        for fused in (False, True):
            def make_b(f=fused):
                return lambda A, *xs: dhopm3_batched(
                    A, list(xs), mesh1, "x", s=s_split, sweeps=DHOPM_SWEEPS,
                    impl=impl_b, fuse_pairs=f)[0]

            cold_us, warm_us = _compile_pair(make_b, Ab, *xsb)
            fn_b = jax.jit(make_b())

            def sep(A, *xs, f=fused, B=B):
                outs = []
                for i in range(B):
                    o, _ = dhopm3(A[i], [x[i] for x in xs], mesh1, "x",
                                  s=s_split, sweeps=DHOPM_SWEEPS,
                                  impl=impl_b, fuse_pairs=f)
                    outs.append(o)
                return outs

            fn_sep = jax.jit(sep)
            t = time_fn(fn_b, Ab, *xsb, reps=3 if smoke else 5)
            t_sep = time_fn(fn_sep, Ab, *xsb, reps=3 if smoke else 5,
                            warmup=1)
            launches = DHOPM_SWEEPS * dhopm_launches_per_sweep(
                dd, s_split, fused)
            one_chain = int(DHOPM_SWEEPS * simulate_sweep(
                d_shape[0], dd, 1, s_split, algo_of[fused],
                split_alive=True)) * prec_f32.storage_bytes
            nbytes = B * one_chain
            gbs = nbytes / t / 1e9
            cells.append(_with_plan({
                "kind": "dhopm3_batched",
                "order": dd,
                "mode": s_split,
                "dtype": "f32",
                "layout": "aligned",
                "shape": list(d_shape),
                "engine": engine_b,
                "batch": B,
                "sweeps": DHOPM_SWEEPS,
                "p": 1,
                "split": s_split,
                "fused": fused,
                "launches": launches,
                "blocks": [],
                "streamed_bytes": nbytes,
                "us": t * 1e6,
                "sep_us": t_sep * 1e6,
                "gbs": gbs,
                "pct_peak": gbs / peak * 100.0,
                "batched_speedup": t_sep / t,
                "predicted_speedup": launch_amortized_speedup(
                    B, one_chain, peak, launches * dispatch_us),
                "compile_cold_us": cold_us,
                "compile_warm_us": warm_us,
            }))
            lines.append(emit(
                f"dhopm3B{B}_d{dd}s{s_split}{'f' if fused else 'u'}",
                t * 1e6, f"{launches}launches;x{t_sep / t:.1f}vs{B}sep"))

    # dhopm3_overlap cells: ONE split chain, synchronous walker vs the
    # pipelined walker (overlap= chunked tails + staged reduction hops).
    # Same engine policy as the batched cells; p = 1 mesh (the bitwise
    # 8-device halves run in the dist suite — here we time the pipeline's
    # launch cost and pin the analytic wire-hiding prediction).
    C_ov = OVERLAP_CHUNKS_DEFAULT
    wire_gbs = peak * OVERLAP_MODEL_WIRE_FRAC
    A1 = rand_tensor(d_shape, dtype=prec_f32.storage, seed=dd + 1)
    xs1 = [rand_tensor((n,), dtype=prec_f32.storage, seed=500 + j)
           for j, n in enumerate(d_shape)]
    for fused in (False, True):
        fn_sync = jax.jit(lambda A, *xs, f=fused: dhopm3(
            A, list(xs), mesh1, "x", s=s_split, sweeps=DHOPM_SWEEPS,
            impl=impl_b, fuse_pairs=f)[0])

        def make_pipe(f=fused):
            return lambda A, *xs: dhopm3(
                A, list(xs), mesh1, "x", s=s_split, sweeps=DHOPM_SWEEPS,
                impl=impl_b, fuse_pairs=f, overlap=C_ov)[0]

        cold_us, warm_us = _compile_pair(make_pipe, A1, *xs1)
        fn_pipe = jax.jit(make_pipe())
        t_sync = time_fn(fn_sync, A1, *xs1, reps=3 if smoke else 5)
        t = time_fn(fn_pipe, A1, *xs1, reps=3 if smoke else 5)
        launches = DHOPM_SWEEPS * dhopm_launches_per_sweep(
            dd, s_split, fused, overlap_chunks=C_ov)
        sync_launches = DHOPM_SWEEPS * dhopm_launches_per_sweep(
            dd, s_split, fused)
        nbytes = int(DHOPM_SWEEPS * simulate_sweep(
            d_shape[0], dd, 1, s_split, algo_of[fused], split_alive=True,
            overlap_chunks=C_ov)) * prec_f32.storage_bytes
        gbs = nbytes / t / 1e9
        model = dhopm_time_sweep(
            d_shape, OVERLAP_MODEL_P, prec_f32.storage_bytes, split=s_split,
            overlap_chunks=C_ov, peak_gbs=peak, wire_gbs=wire_gbs,
            dispatch_us=0.0)
        cells.append(_with_plan({
            "kind": "dhopm3_overlap",
            "order": dd,
            "mode": s_split,
            "dtype": "f32",
            "layout": "aligned",
            "shape": list(d_shape),
            "engine": engine_b,
            "sweeps": DHOPM_SWEEPS,
            "p": 1,
            "split": s_split,
            "fused": fused,
            "overlap_chunks": C_ov,
            "launches": launches,
            "sync_launches": sync_launches,
            "blocks": [],
            "streamed_bytes": nbytes,
            "us": t * 1e6,
            "sync_us": t_sync * 1e6,
            "gbs": gbs,
            "pct_peak": gbs / peak * 100.0,
            "overlap_speedup": t_sync / t,
            "model_p": OVERLAP_MODEL_P,
            "model_wire_gbs": wire_gbs,
            "model_dispatch_us": 0.0,
            "predicted_wire_us": DHOPM_SWEEPS * model["wire_us"],
            "predicted_exposed_us": DHOPM_SWEEPS * model["exposed_wire_us"],
            "predicted_hidden_us": DHOPM_SWEEPS * model["hidden_wire_us"],
            "compile_cold_us": cold_us,
            "compile_warm_us": warm_us,
        }))
        lines.append(emit(
            f"dhopm3ov_d{dd}s{s_split}{'f' if fused else 'u'}C{C_ov}",
            t * 1e6,
            f"{launches}vs{sync_launches}launches;"
            f"x{t_sync / t:.2f}sync;"
            f"hide{model['hidden_wire_us'] / max(model['wire_us'], 1e-12) * 100:.0f}%"))

    payload = {
        "meta": {
            "schema": 8,
            "engine": engine,
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "smoke": smoke,
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "compile_cache": True,
            "calibration": plan_calibration.load().get("source"),
        },
        "stream_triad_gbs": peak,
        "cells": cells,
    }
    out_path.write_text(json.dumps(payload, indent=1) + "\n")
    print(f"# wrote {out_path} ({len(cells)} cells)", flush=True)
    return lines, payload


if __name__ == "__main__":
    run()
