"""Fit ``kernels/calibration.json`` from a committed BENCH_TVC trajectory.

    PYTHONPATH=src python -m benchmarks.calibrate BENCH_TVC.json \
        [--out src/repro/kernels/calibration.json] [--dry-run]

The planner (:mod:`repro.plan.planner`) and the CI gate
(:mod:`benchmarks.check_bench`) both price decisions with this table, so
fitting it from the same committed trajectory keeps the two in lock-step:

* ``dispatch_us`` — per-launch overhead, fitted as the median of
  ``(sep_us - us) / (B - 1)`` over the ``tvc_batched`` cells (B separate
  launches vs one batched launch differ by exactly B-1 dispatches of the
  same streamed work).
* per-engine ``gbs`` / ``gbs_lead`` / ``gbs_inner`` — achieved GB/s,
  geometric mean over the cells (and, on schema >= 6 files, over the
  per-cell explicit-flag sweeps in ``cell["flags"]``).  ``tvc2`` cells
  split by contraction class: *leading* pairs (``mode == 0``) vs
  *inner*/tail pairs — the classes where the einsum-vs-mulsum ordering
  flips.  Leading-pair bandwidth is additionally split at a fitted
  cache-residency crossover (``cache_bytes`` + per-engine
  ``gbs_lead_small``): the einsum holds ~1 GB/s while the operand is
  cache-resident and collapses ~5x streaming from DRAM, while mulsum is
  flat, so the winner flips with tensor size on identical shapes/classes.
  Engines with no samples keep their conservative fallbacks
  (einsum variants ``looped``/``unfolded`` mirror the ``native`` fit:
  all three lower to the same XLA einsum and time within noise).
* ``ceilings`` — the time-implied-traffic gate allowances
  (``ratio_native``, ``lowprec_factor``, ``ratio_pallas``), derived as
  the worst needed ratio on the fitted trajectory x2 headroom, replacing
  the previous hand-tuned 32x/3x/2x constants.

Run it after regenerating BENCH_TVC.json, then re-run the bench once if
``check_bench``'s plan-recompute gate reports divergence (the fit moved a
planner decision — one fixed-point iteration converges in practice, the
measured engine margins are 3-6x against a ~5% fit jitter).
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import statistics
import sys

from repro.plan import calibration

from .check_bench import predicted_bytes

#: clamp for the per-launch dispatch fit (a negative or wild sample is
#: timer noise on a tiny cell, not physics)
DISPATCH_CLAMP_US = (1.0, 500.0)

#: headroom multiplier on the worst needed time-implied ratio — the
#: ceilings are catastrophic-regression bounds, not tight envelopes
CEILING_HEADROOM = 2.0


def _geomean(xs):
    xs = [x for x in xs if x > 0]
    if not xs:
        return None
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def _cls(cell) -> str:
    if cell["kind"] == "tvc2":
        return "lead" if cell["mode"] == 0 else "inner"
    return "single"


def _primary_engine(cell, run_engine: str) -> str | None:
    """Planner-namespace engine of a cell's primary timing."""
    plan = cell.get("plan")
    if isinstance(plan, dict) and plan.get("engine"):
        return plan["engine"]
    if run_engine == "native-xla":
        return "native"
    if run_engine == "pallas":
        return "pallas"
    return None  # pallas-interpret etc: wall times mean nothing


def fit_dispatch(cells) -> float | None:
    samples = []
    for c in cells:
        if c.get("kind") != "tvc_batched" or c.get("batch", 0) < 2:
            continue
        fit = (c["sep_us"] - c["us"]) / (c["batch"] - 1)
        if fit > 0:
            samples.append(fit)
    if not samples:
        return None
    lo, hi = DISPATCH_CLAMP_US
    return min(max(statistics.median(samples), lo), hi)


def fit_engines(cells, run_engine: str) -> dict:
    """(engine, class) -> list of (streamed_bytes, achieved GB/s) samples."""
    samples: dict[tuple[str, str], list[tuple[float, float]]] = {}

    def add(engine, cls, nbytes, gbs):
        if engine and gbs and gbs > 0:
            samples.setdefault((engine, cls), []).append((nbytes, gbs))

    for c in cells:
        if c["kind"] not in ("tvc", "tvc2"):
            continue
        cls = _cls(c)
        nbytes = c["streamed_bytes"]
        add(_primary_engine(c, c.get("engine", run_engine)), cls, nbytes,
            c["gbs"])
        # schema >= 6: per-cell explicit-flag sweeps (us per engine)
        for engine, us in (c.get("flags") or {}).items():
            if us and us > 0:
                add(engine, cls, nbytes, nbytes / (us * 1e3))
    return samples


#: bimodality threshold: a lead-pair bandwidth spread beyond this ratio on
#: one engine is two cache regimes, not noise
LEAD_BIMODAL_MIN_SPREAD = 2.5


def fit_cache_crossover(lead_samples) -> float:
    """Cache-residency crossover (bytes) from one engine's leading-pair
    (bytes, gbs) samples.

    The einsum's lead bandwidth is bimodal on the measured trajectory
    (~1 GB/s cache-resident, ~0.2 GB/s streaming).  Sort the samples by
    size and take the split point maximizing the bandwidth contrast
    geomean(small) / geomean(large) — robust to a single noisy sample,
    unlike clustering on a bandwidth threshold.  The crossover is the
    geometric mid of the boundary sizes.  Returns 0.0 (no split) when
    the best contrast stays under :data:`LEAD_BIMODAL_MIN_SPREAD` (the
    samples are unimodal within noise)."""
    if len(lead_samples) < 4:
        return 0.0
    pts = sorted(lead_samples)
    best_contrast, best_cross = 0.0, 0.0
    for i in range(1, len(pts)):
        if pts[i - 1][0] >= pts[i][0]:  # size tie: not a valid split
            continue
        small = _geomean([g for _, g in pts[:i]])
        large = _geomean([g for _, g in pts[i:]])
        if not small or not large:
            continue
        contrast = small / large
        if contrast > best_contrast:
            best_contrast = contrast
            best_cross = math.sqrt(pts[i - 1][0] * pts[i][0])
    if best_contrast < LEAD_BIMODAL_MIN_SPREAD:
        return 0.0
    return best_cross


def fit_ceilings(cells, run_engine: str, peak: float,
                 dispatch_us: float) -> dict:
    """Worst needed implied/predicted ratio per (engine-tag, dtype-class),
    with headroom, in the exact arithmetic ``check_bench`` gates with."""
    worst: dict[tuple[str, bool], float] = {}
    for c in cells:
        tag = c.get("engine", run_engine)
        if tag not in ("native-xla", "pallas"):
            continue
        pred = predicted_bytes(c)
        if pred <= 0:
            continue
        implied = c["us"] * 1e-6 * peak * 1e9
        allowance = c.get("launches", 1) * dispatch_us * 1e-6 * peak * 1e9
        needed = max(0.0, implied - allowance) / pred
        key = (tag, c["dtype"] == "f32")
        worst[key] = max(worst.get(key, 0.0), needed)

    out = dict(calibration.FALLBACK["ceilings"])
    f32 = worst.get(("native-xla", True))
    if f32:
        out["ratio_native"] = math.ceil(f32 * CEILING_HEADROOM)
        low = worst.get(("native-xla", False))
        if low:
            out["lowprec_factor"] = max(
                1.0, round(low * CEILING_HEADROOM / out["ratio_native"], 2))
    pal = worst.get(("pallas", True)) or worst.get(("pallas", False))
    if pal:
        out["ratio_pallas"] = max(2.0, math.ceil(pal * CEILING_HEADROOM))
    return out


def fit(payload: dict, source: str) -> dict:
    cells = payload.get("cells", [])
    run_engine = payload.get("meta", {}).get("engine", "")
    peak = float(payload["stream_triad_gbs"])
    dispatch = fit_dispatch(cells)
    if dispatch is None:
        dispatch = calibration.FALLBACK["dispatch_us"]
    samples = fit_engines(cells, run_engine)
    # the crossover is fitted on the einsum's lead samples (the engine
    # whose bandwidth actually collapses out of cache), then applied to
    # every engine's lead fit
    cross = fit_cache_crossover(samples.get(("native", "lead"), []))

    engines = {e: dict(prm) for e, prm in calibration.FALLBACK["engines"].items()}
    fitted = set()
    for (engine, cls), pairs in samples.items():
        prm = engines.setdefault(engine, {})
        if cls == "lead" and cross > 0:
            small = _geomean([g for b, g in pairs if b < cross])
            large = _geomean([g for b, g in pairs if b >= cross])
            if large is not None:
                prm["gbs_lead"] = round(large, 4)
                fitted.add(engine)
            if small is not None:
                prm["gbs_lead_small"] = round(small, 4)
                fitted.add(engine)
            continue
        val = _geomean([g for _, g in pairs])
        if val is None:
            continue
        prm["gbs" if cls == "single" else f"gbs_{cls}"] = round(val, 4)
        fitted.add(engine)
    # CPU dispatch overhead is a property of the jit call path, not of the
    # engine — share the fit across every CPU engine (pallas keeps its own)
    for e, prm in engines.items():
        if e != "pallas":
            prm["launch_us"] = round(dispatch, 2)
    # the einsum variants lower to the same XLA contraction as "native"
    # and time within run-to-run noise — mirror the fit so an absent
    # sample can never make a fallback constant look faster than measurement
    if "native" in fitted:
        for alias in ("looped", "unfolded"):
            if alias not in fitted:
                engines[alias] = dict(engines["native"])

    return {
        "schema": 1,
        "source": source,
        "fitted": {
            "bench_schema": payload.get("meta", {}).get("schema"),
            "bench_timestamp": payload.get("meta", {}).get("timestamp"),
            "backend": payload.get("meta", {}).get("backend"),
            "cells": len(cells),
            "engines": sorted(fitted),
            "dispatch_samples": sum(
                1 for c in cells if c.get("kind") == "tvc_batched"),
        },
        "stream_triad_gbs": round(peak, 4),
        "dispatch_us": round(dispatch, 2),
        "cache_bytes": round(cross, 0),
        "wire_frac": calibration.FALLBACK["wire_frac"],
        "engines": engines,
        "ceilings": fit_ceilings(cells, run_engine, peak, dispatch),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("bench", nargs="?", default="BENCH_TVC.json",
                    help="trajectory JSON to fit from (committed reference)")
    ap.add_argument("--out", default=str(calibration.DEFAULT_PATH),
                    help="calibration table to write")
    ap.add_argument("--dry-run", action="store_true",
                    help="print the fit without writing")
    args = ap.parse_args(argv)

    payload = json.loads(pathlib.Path(args.bench).read_text())
    table = fit(payload, source=pathlib.Path(args.bench).name)

    old = None
    out = pathlib.Path(args.out)
    if out.exists():
        try:
            old = json.loads(out.read_text())
        except ValueError:
            pass
    print(f"# calibrate: {args.bench} -> {args.out}")
    print(f"  dispatch_us       {table['dispatch_us']}")
    print(f"  stream_triad_gbs  {table['stream_triad_gbs']}")
    print(f"  cache_bytes       {table['cache_bytes']:.0f}")
    for e, prm in sorted(table["engines"].items()):
        tag = "fitted" if e in table["fitted"]["engines"] else (
            "mirrored" if prm == table["engines"].get("native") and
            e in ("looped", "unfolded") else "fallback")
        print(f"  {e:<10} {tag:<9} " + " ".join(
            f"{k}={v}" for k, v in sorted(prm.items())))
    print("  ceilings          " + " ".join(
        f"{k}={v}" for k, v in sorted(table["ceilings"].items())))
    if old is not None:
        moved = [k for k in ("dispatch_us", "cache_bytes", "engines",
                             "ceilings")
                 if old.get(k) != table[k]]
        print(f"  vs committed table: "
              f"{'moved ' + ', '.join(moved) if moved else 'unchanged'}")
    if args.dry_run:
        return 0
    out.write_text(json.dumps(table, indent=1, sort_keys=True) + "\n")
    calibration.invalidate()
    print(f"  wrote {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
