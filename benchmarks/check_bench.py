"""CI bandwidth-regression gate for the TVC bench trajectory files.

    PYTHONPATH=src python -m benchmarks.check_bench BENCH_TVC.smoke.json \
        [--ref BENCH_TVC.json] [...tolerances]

Three checks, strictest first:

1. **Schema** — the file parses, carries the same ``meta.schema`` as the
   committed reference (``--ref``), has a positive STREAM-triad peak, and
   every cell carries the full core key set (plus ``pad_overhead`` on
   single-mode cells and ``fused_saving`` on fused-pair cells).

2. **Streamed-bytes accounting** — each cell's recorded ``streamed_bytes``
   must not exceed the :mod:`repro.core.memory_model` prediction
   (``tvc_streamed_elems`` / ``tvc2_streamed_elems`` /
   ``tvc_batched_streamed_elems`` x itemsize) by more than ``--acct-tol``.
   The bench records bytes via ``core.tvc.tvc_bytes`` and the model
   predicts them independently, so this cross-validates the two accountings
   on *every* engine — including interpret-mode smoke runs whose wall times
   mean nothing.  Fused-pair cells must additionally predict strictly fewer
   streamed bytes than the two-launch reference (``fused_saving > 1`` — the
   whole point of the fused kernel).  Batched cells must beat their own B
   separate launches where it matters: the *geometric mean* of
   ``batched_speedup`` over the ``tvc_batched`` cells with
   ``batch >= --speedup-min-batch`` (default 16, i.e. the B = 64 cells)
   must exceed 1 — a same-engine relative measure (batched cells always run
   a timed engine and carry their own ``engine`` tag), aggregated so one
   timer-noise cell cannot flip CI while a real regression still fails.
   Sync-vs-pipelined ``dhopm3_overlap`` cells must carry launch counts that
   exactly match ``dhopm_launches_per_sweep`` (with and without
   ``overlap_chunks``), a ``dhopm_time_sweep`` prediction reproducible
   bit-for-bit from the recorded model inputs that predicts real wire
   hiding (``predicted_hidden_us > 0``), and a geomean ``overlap_speedup``
   above ``--overlap-speedup-min`` (a calibrated floor: the p = 1 cells pay
   the chunked-launch cost with no wire to hide).

3. **Time-implied traffic** (engines with real timings only) — the bytes a
   cell's wall time would stream at the measured STREAM peak,
   ``us * peak``, minus a per-launch dispatch allowance
   (``--dispatch-us * peak`` — the ROADMAP caveat: small-tensor cells are
   dispatch-dominated and must not be judged as bandwidth), must not exceed
   ``prediction * ratio``.  Batched cells get exactly ONE dispatch
   allowance per launch (one for a ``tvc_batched`` cell; ``launches`` for a
   whole-algorithm ``dhopm3_batched`` cell) — the per-launch ceiling of the
   unbatched equivalent would grant B times as many, so a batched cell that
   needs more is slower than B separate launches and fails.  The ratio is
   per engine: ``--ratio-pallas`` on TPU, ``--ratio-native`` for
   ``native-xla``, where low-precision cells additionally get
   ``--lowprec-factor`` (CPU XLA has no native bf16 and pays a
   convert/compute/convert round trip; TPU bf16 is native and gets no
   factor).  The defaults are no longer hand-tuned constants: they come
   from the committed ``kernels/calibration.json`` (worst needed ratio on
   the fitted trajectory x2 headroom — see ``benchmarks/calibrate.py``),
   the same table the ``repro.plan`` planner prices decisions with.
   ``pallas-interpret`` timings are interpreter overhead and are skipped.

4. **Planner cross-checks** (schema >= 6) — every cell must carry the
   ``plan`` auto would pick for its recorded inputs; the gate *recomputes*
   it via ``repro.plan.planner.plan_for_cell`` against the committed
   calibration table and fails on any divergence (a stale table or a moved
   decision rule can't slip through).  Cells with an explicit-flag sweep
   (``flags``: engine -> us) must satisfy ``auto_us <= --auto-cell-ratio
   x best(flags)`` per cell (catastrophic mis-pick bound; a wrong engine
   loses 2-4x on the measured margins) and geomean ``auto_us /
   best(flags) <= --auto-ratio`` over all swept cells (the tight tie —
   per-pair timing noise is ~10% one-sided, so it lives on the
   aggregate), the recorded ``auto_vs_best_flag`` /
   ``auto_vs_worst_flag`` ratios must reproduce from the recorded
   timings, and dispatch-dominated cells (time-implied ratio >=
   ``repro.plan.planner.DISPATCH_DOMINATED_X``) — the regime this planner
   exists for — must carry the sweep and post a geomean
   ``auto_vs_worst_flag`` above ``--auto-worst-min``.  Warm-start:
   every cell records a cold and a warm fresh-jit compile against the
   run's persistent compilation cache; geomean ``warm/cold`` must stay
   under ``--warm-compile-max`` (the cache must actually short-circuit
   recompilation).

5. **Serving gates** (schema >= 7, ``kind: "serving"`` cells from
   ``bench_serving``) — ``comp_launches`` must recompute exactly from the
   recorded ``comp_events`` as ``sweeps x dhopm_launches_per_sweep(d_view)``
   per grouped launch event (independent of the group size — one batched
   chain per same-view group, never a per-slot loop), ``streamed_bytes``
   must match the ``hopm_streamed_elems_sweep`` accounting over the same
   events, ``req_per_s`` must clear ``--serving-rps-min``, and
   compression-on cells must record events that price a real dense/factor
   saving.  Their ``engine: "serve-loop"`` tag keeps the time-implied
   check away (a serve loop's wall time is mostly model forwards).

6. **Arena gates** (schema >= 8, ``kind: "arena"`` cells from
   ``bench_arena``) — each cell times the SAME compression step under the
   legacy ``jnp.stack`` bucket assembly and the donated batched-operand
   arena (``repro.core.arena``).  ``stack_copy_removed_bytes`` must
   recompute VERBATIM from the recorded ``fill_events`` via the
   ``memory_model`` closed forms (``bucket_stack_elems`` minus
   ``arena_fill_elems`` per ``[b, view, cold]`` event x itemsize),
   ``launches`` and ``streamed_bytes`` must match the
   ``ranks x sweeps x dhopm_launches_per_sweep`` /
   ``hopm_streamed_elems_sweep`` accounting over the same events,
   ``arena_plan`` must equal the recomputed
   ``plan_compress(B, view).arena`` resolution, every B >=
   ``--speedup-min-batch`` cell must have removed real copy bytes, and the
   geomean ``arena_speedup`` (stacked us / arena us) over those cells must
   exceed 1.  The ``engine: "arena-loop"`` tag keeps the Python step loop
   out of the time-implied ratio map, like serving cells.

Exit code 0 = green; 1 = any cell failed (all failures listed).
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys

from repro.core.memory_model import (
    arena_fill_elems,
    bucket_stack_elems,
    dhopm_time_sweep,
    hopm_streamed_elems_sweep,
    simulate_sweep,
    simulate_sweep_batched,
    tvc2_streamed_elems,
    tvc_batched_streamed_elems,
    tvc_streamed_elems,
)
from repro.core.mixed_precision import get_policy
from repro.plan import calibration as plan_calibration
from repro.plan import planner as plan_planner
from repro.verify.rules import expected_launches

CORE_KEYS = frozenset({
    "kind", "order", "mode", "dtype", "layout", "shape", "blocks",
    "streamed_bytes", "us", "gbs", "pct_peak",
})
KIND_KEYS = {
    "tvc": ("pad_overhead",),
    "tvc2": ("fused_saving",),
    # "engine" is required so a batched cell can never silently inherit an
    # untimed run-level engine and dodge the time-implied ceiling
    "tvc_batched": ("engine", "batch", "sep_us", "batched_speedup",
                    "predicted_speedup"),
    # whole-algorithm batched cells: B split dHOPM_3 chains per launch
    # sequence; "launches" feeds the per-cell dispatch allowance
    "dhopm3_batched": ("engine", "batch", "sweeps", "p", "split", "fused",
                       "launches", "sep_us", "batched_speedup",
                       "predicted_speedup"),
    # sync-vs-pipelined cells: one split chain through both walkers, plus
    # the dhopm_time_sweep prediction at the reference distributed config
    "dhopm3_overlap": ("engine", "sweeps", "p", "split", "fused",
                       "overlap_chunks", "launches", "sync_launches",
                       "sync_us", "overlap_speedup", "model_p",
                       "model_wire_gbs", "model_dispatch_us",
                       "predicted_wire_us", "predicted_exposed_us",
                       "predicted_hidden_us"),
    # continuous-batching serve cells (schema 7): the engine tag
    # "serve-loop" keeps them out of the timed-engine ratio map — their
    # ``us`` is a Python serve loop full of model forwards, not one
    # contraction; the gates price throughput and compression accounting
    "serving": ("engine", "batch", "compress", "requests", "steps",
                "req_per_s", "p50_us", "p99_us", "slo_p50_us",
                "slo_p99_us", "sweeps", "comp_events", "comp_launches",
                "comp_dense_bytes", "comp_factor_bytes"),
    # stacked-vs-arena-filled compression step cells (schema 8): the
    # "arena-loop" tag likewise keeps the Python step loop out of the
    # time-implied map; the gates recompute the removed-copy bytes, the
    # launch/streamed accounting, and the planner's arena resolution from
    # the recorded fill events verbatim
    "arena": ("engine", "batch", "sweeps", "consumer", "ranks",
              "fill_events", "stack_us", "arena_speedup",
              "stack_copy_removed_bytes", "launches", "arena_plan"),
}
BATCHED_KINDS = ("tvc_batched", "dhopm3_batched")
TIMED_ENGINES = ("pallas", "native-xla")

#: per-launch dispatch allowance shared by the gate's --dispatch-us default
#: and the bench's recorded ``predicted_speedup`` (one value so the two
#: accountings can never drift apart) — fitted by benchmarks/calibrate.py,
#: falling back to the conservative constant on an uncalibrated checkout
DEFAULT_DISPATCH_US = plan_calibration.dispatch_us()

#: time-implied-traffic ceilings, from the same fitted table
DEFAULT_CEILINGS = plan_calibration.ceilings()

#: per-cell keys additionally required on schema >= 6 trajectories
SCHEMA6_KEYS = ("plan", "compile_cold_us", "compile_warm_us")
#: keys that must travel together whenever a cell carries a flag sweep
AUTO_KEYS = ("auto_us", "auto_vs_best_flag", "auto_vs_worst_flag")


def predicted_bytes(cell: dict) -> int:
    """memory_model's streamed-bytes prediction for one cell."""
    shape = tuple(cell["shape"])
    k = cell["mode"]
    itemsize = get_policy(cell["dtype"]).storage_bytes
    if cell["kind"] == "dhopm3_batched":
        # hypersquare closed form; split_alive=True — the runtime walkers
        # keep the split schedule even at p = 1
        per_sweep = simulate_sweep_batched(
            cell["batch"], shape[0], cell["order"], cell["p"],
            cell["split"], "hopm3_fused" if cell["fused"] else "hopm3",
            split_alive=True)
        return int(cell["sweeps"] * per_sweep) * itemsize
    if cell["kind"] == "dhopm3_overlap":
        # overlap-aware form: (C-1) extra vector re-reads per pipelined tail
        per_sweep = simulate_sweep(
            shape[0], cell["order"], cell["p"], cell["split"],
            "hopm3_fused" if cell["fused"] else "hopm3",
            split_alive=True, overlap_chunks=cell["overlap_chunks"])
        return int(cell["sweeps"] * per_sweep) * itemsize
    if cell["kind"] == "serving":
        # grouped KV compression traffic: every recorded [group_size, view]
        # launch event moves B_g lockstep power-iteration chains' worth of
        # streamed elements (same int truncation as the engine's accounting)
        return sum(
            int(b * cell["sweeps"] * hopm_streamed_elems_sweep(tuple(view)))
            * itemsize
            for b, view in cell["comp_events"])
    if cell["kind"] == "arena":
        # one deflation-rank chain set per fill event: ranks x sweeps x B_g
        # lockstep power-iteration chains' worth of streamed elements
        return sum(
            int(cell["ranks"] * cell["sweeps"] * b
                * hopm_streamed_elems_sweep(tuple(view))) * itemsize
            for b, view, _cold in cell["fill_events"])
    if cell["kind"] == "tvc2":
        u = math.prod(shape[:k])
        n1, n2 = shape[k], shape[k + 1]
        v = math.prod(shape[k + 2:])
        return tvc2_streamed_elems(u, n1, n2, v) * itemsize
    u = math.prod(shape[:k])
    v = math.prod(shape[k + 1:])
    if cell["kind"] == "tvc_batched":
        return tvc_batched_streamed_elems(cell["batch"], u, shape[k], v) \
            * itemsize
    return tvc_streamed_elems(u, shape[k], v) * itemsize


def _cell_name(c: dict) -> str:
    return (f"{c.get('kind', '?')}/d{c.get('order', '?')}m{c.get('mode', '?')}"
            f"/{c.get('dtype', '?')}/{c.get('layout', '?')}")


def check(payload: dict, ref: dict | None, *, acct_tol: float,
          dispatch_us: float, ratio_pallas: float,
          ratio_native: float, lowprec_factor: float = 3.0,
          speedup_min_batch: int = 16,
          overlap_speedup_min: float = 0.25,
          auto_ratio: float = 1.1,
          auto_cell_ratio: float = 1.3,
          auto_worst_min: float = 1.0,
          warm_compile_max: float = 0.6,
          serving_rps_min: float = 0.05) -> list[str]:
    """All failure messages for one trajectory payload ([] = green)."""
    fails: list[str] = []
    meta = payload.get("meta", {})
    cells = payload.get("cells", [])
    peak = payload.get("stream_triad_gbs", 0.0)
    engine = meta.get("engine")
    schema = meta.get("schema") or 0

    # -- 1. schema ----------------------------------------------------------
    if ref is not None:
        want = ref.get("meta", {}).get("schema")
        if meta.get("schema") != want:
            fails.append(f"schema {meta.get('schema')!r} != committed "
                         f"reference schema {want!r}")
    if not cells:
        fails.append("no cells")
    if not (isinstance(peak, (int, float)) and peak > 0):
        fails.append(f"stream_triad_gbs not positive: {peak!r}")
    for c in cells:
        missing = CORE_KEYS - set(c)
        for kind_key in KIND_KEYS.get(c.get("kind"), ()):
            if kind_key not in c:
                missing = missing | {kind_key}
        if schema >= 6:
            missing |= {k for k in SCHEMA6_KEYS if k not in c}
            if "flags" in c:
                missing |= {k for k in AUTO_KEYS if k not in c}
        if missing:
            fails.append(f"{_cell_name(c)}: missing keys {sorted(missing)}")
    if fails:
        return fails  # later checks would only cascade

    auto_worst_dd: list[float] = []   # auto_vs_worst_flag, dispatch-dominated
    auto_best_all: list[float] = []   # auto_us / best(flags), every swept cell
    warm_ratios: list[float] = []     # compile_warm_us / compile_cold_us
    for c in cells:
        name = _cell_name(c)
        pred = predicted_bytes(c)

        # -- 2. accounting --------------------------------------------------
        if c["streamed_bytes"] > pred * (1.0 + acct_tol):
            fails.append(
                f"{name}: recorded streamed_bytes {c['streamed_bytes']} "
                f"exceeds model prediction {pred} (tol {acct_tol})")
        if c["kind"] == "tvc2" and not c["fused_saving"] > 1.0:
            fails.append(
                f"{name}: fused pair predicts no saving over two launches "
                f"(fused_saving={c['fused_saving']})")
        if c["kind"] == "tvc" and c["pad_overhead"] < 1.0:
            fails.append(f"{name}: pad_overhead {c['pad_overhead']} < 1")
        if c["kind"] in BATCHED_KINDS:
            if not c["predicted_speedup"] > 1.0:
                fails.append(
                    f"{name}: launch-amortization model predicts no win "
                    f"(predicted_speedup={c['predicted_speedup']})")
        if c["kind"] == "dhopm3_overlap":
            # launch schedule: both walkers must match the closed form,
            # through the same expectation the static verifier gates on
            want = expected_launches({
                "kind": "chain", "d": c["order"], "s": c["split"],
                "fuse_pairs": c["fused"], "sweeps": c["sweeps"],
                "overlap_chunks": c["overlap_chunks"]})
            want_sync = expected_launches({
                "kind": "chain", "d": c["order"], "s": c["split"],
                "fuse_pairs": c["fused"], "sweeps": c["sweeps"]})
            if c["launches"] != want or c["sync_launches"] != want_sync:
                fails.append(
                    f"{name}: launch counts ({c['launches']}, "
                    f"{c['sync_launches']}) != model ({want}, {want_sync})")
            # the dhopm_time_sweep prediction must be exactly reproducible
            # from the cell's recorded model inputs ...
            model = dhopm_time_sweep(
                tuple(c["shape"]), c["model_p"],
                get_policy(c["dtype"]).storage_bytes, split=c["split"],
                overlap_chunks=c["overlap_chunks"], peak_gbs=peak,
                wire_gbs=c["model_wire_gbs"],
                dispatch_us=c["model_dispatch_us"])
            for key, mk in (("predicted_wire_us", "wire_us"),
                            ("predicted_exposed_us", "exposed_wire_us"),
                            ("predicted_hidden_us", "hidden_wire_us")):
                want_us = c["sweeps"] * model[mk]
                if not math.isclose(c[key], want_us,
                                    rel_tol=1e-9, abs_tol=1e-12):
                    fails.append(
                        f"{name}: {key}={c[key]} != recomputed "
                        f"dhopm_time_sweep {want_us}")
            # ... and must predict real hiding at the reference config
            if not c["predicted_hidden_us"] > 0.0:
                fails.append(
                    f"{name}: overlap model predicts no wire hiding "
                    f"(predicted_hidden_us={c['predicted_hidden_us']})")
        if c["kind"] == "serving":
            # launch accounting: ONE batched chain per group launch event
            # at sweeps x dhopm_launches_per_sweep(d_view) — independent of
            # the group size (the amortization guarantee; a per-slot loop
            # would scale with B_g and fail here immediately)
            want = sum(
                expected_launches({"kind": "chain", "d": len(view),
                                   "sweeps": c["sweeps"]})
                for _b, view in c["comp_events"])
            if c["comp_launches"] != want:
                fails.append(
                    f"{name}: comp_launches {c['comp_launches']} != "
                    f"{want} (sweeps x dhopm_launches_per_sweep per group "
                    f"event — compression is not launching one batched "
                    f"chain per same-view group)")
            if not c["req_per_s"] >= serving_rps_min:
                fails.append(
                    f"{name}: req_per_s {c['req_per_s']:.3f} below floor "
                    f"{serving_rps_min} (B={c['batch']}, "
                    f"compress={c['compress']})")
            if c["compress"]:
                if not c["comp_events"]:
                    fails.append(
                        f"{name}: compression on but no grouped launch "
                        f"events recorded")
                elif not c["comp_dense_bytes"] > c["comp_factor_bytes"]:
                    fails.append(
                        f"{name}: rank-1 factorization prices no saving "
                        f"(dense={c['comp_dense_bytes']}B, "
                        f"factors={c['comp_factor_bytes']}B)")
            elif c["comp_events"]:
                fails.append(
                    f"{name}: compression off but {len(c['comp_events'])} "
                    f"launch events recorded")
        if c["kind"] == "arena":
            isz = get_policy(c["dtype"]).storage_bytes
            # removed-copy bytes must recompute VERBATIM from the recorded
            # fill events via the memory_model closed forms — the arena's
            # headline number can never drift from the priced model
            want_removed = sum(
                (bucket_stack_elems(b, view, ranks=c["ranks"])
                 - arena_fill_elems(b, view, ranks=c["ranks"],
                                    cold=bool(cold))) * isz
                for b, view, cold in c["fill_events"])
            if c["stack_copy_removed_bytes"] != want_removed:
                fails.append(
                    f"{name}: stack_copy_removed_bytes "
                    f"{c['stack_copy_removed_bytes']} != {want_removed} "
                    f"recomputed from fill_events (bucket_stack_elems - "
                    f"arena_fill_elems per event)")
            want_l = sum(
                c["ranks"] * expected_launches(
                    {"kind": "chain", "d": len(view),
                     "sweeps": c["sweeps"]})
                for _b, view, _cold in c["fill_events"])
            if c["launches"] != want_l:
                fails.append(
                    f"{name}: launches {c['launches']} != {want_l} "
                    f"(ranks x sweeps x dhopm_launches_per_sweep per fill "
                    f"event)")
            want_arena = plan_planner.plan_compress(
                c["batch"], tuple(c["shape"]), itemsize=isz).arena
            if bool(c["arena_plan"]) != want_arena:
                fails.append(
                    f"{name}: arena_plan {c['arena_plan']} != recomputed "
                    f"plan_compress(...).arena {want_arena}")
            if c["batch"] >= speedup_min_batch \
                    and not c["stack_copy_removed_bytes"] > 0:
                fails.append(
                    f"{name}: B={c['batch']} arena cell removed no stack "
                    f"copies (stack_copy_removed_bytes="
                    f"{c['stack_copy_removed_bytes']})")

        # -- 3. time-implied traffic ---------------------------------------
        # batched cells always run a timed engine and carry their own tag;
        # everything else inherits the run-level engine
        cell_engine = c.get("engine", engine)
        cell_base = {"pallas": ratio_pallas,
                     "native-xla": ratio_native}.get(cell_engine)
        if cell_base is not None:
            cell_ratio = cell_base
            if cell_engine == "native-xla" and c["dtype"] not in ("f32",):
                cell_ratio *= lowprec_factor   # CPU XLA emulates bf16/f16
            implied = c["us"] * 1e-6 * peak * 1e9       # bytes at STREAM peak
            # ONE dispatch allowance per LAUNCH in the cell — for a batched
            # cell that is the whole point: the unbatched equivalent of its
            # B launches would be granted B allowances (B x launches for a
            # whole-algorithm dhopm3_batched cell), so fitting under the
            # batched launch count proves the batch amortized the rest away.
            allowance = c.get("launches", 1) * dispatch_us * 1e-6 * peak * 1e9
            if implied - allowance > pred * cell_ratio:
                fails.append(
                    f"{name}: time-implied traffic {implied / 1e6:.2f} MB "
                    f"(us={c['us']:.0f}, dispatch allowance "
                    f"{allowance / 1e6:.2f} MB) exceeds {cell_ratio}x the "
                    f"predicted {pred / 1e6:.2f} MB [{cell_engine}]")

        # -- 4. planner cross-checks (schema >= 6) --------------------------
        if "plan" in c:
            # recompute the plan from the cell's recorded inputs against the
            # committed calibration table — divergence means a stale table
            # or a decision rule that moved without regenerating the bench
            want_plan = plan_planner.plan_for_cell(c)
            if c["plan"] != want_plan:
                fails.append(
                    f"{name}: recorded plan {c['plan']} != recomputed "
                    f"{want_plan} (stale calibration.json or moved planner "
                    f"rule — rerun benchmarks/calibrate.py + the bench)")
        dominated = (cell_engine in ("pallas", "native-xla")
                     and c["kind"] in ("tvc", "tvc2")
                     and plan_planner.dispatch_dominated(c["us"], pred, peak))
        flags = c.get("flags") or {}
        if schema >= 6 and dominated and not flags:
            fails.append(
                f"{name}: dispatch-dominated (time-implied ratio >= "
                f"{plan_planner.DISPATCH_DOMINATED_X:g}) but carries no "
                f"explicit-flag sweep — the auto-vs-flags gate can't run")
        if flags and all(k in c for k in AUTO_KEYS):
            best, worst = min(flags.values()), max(flags.values())
            # per-cell: a catastrophic-mis-pick ceiling only.  A wrong
            # engine choice loses 2-4x on the measured margins; a right
            # one ties within per-pair timing noise (~10% between two
            # timings of the SAME executable), so the tight 1.1x bound
            # is enforced on the geomean below, not per cell.
            if c["auto_us"] > auto_cell_ratio * best:
                fails.append(
                    f"{name}: auto_us {c['auto_us']:.0f} exceeds "
                    f"{auto_cell_ratio}x the best explicit flag "
                    f"({min(flags, key=flags.get)}={best:.0f}us) — "
                    f"auto picked a losing engine")
            auto_best_all.append(c["auto_us"] / best)
            for key, flag_us in (("auto_vs_best_flag", best),
                                 ("auto_vs_worst_flag", worst)):
                if not math.isclose(c[key], flag_us / c["auto_us"],
                                    rel_tol=1e-9, abs_tol=1e-12):
                    fails.append(
                        f"{name}: {key}={c[key]} does not reproduce from "
                        f"the recorded timings ({flag_us:.0f}us / "
                        f"{c['auto_us']:.0f}us)")
            if dominated:
                auto_worst_dd.append(c["auto_vs_worst_flag"])
        if c.get("compile_cold_us", 0) > 0 and "compile_warm_us" in c:
            warm_ratios.append(c["compile_warm_us"] / c["compile_cold_us"])

    # -- batched speedup: geometric mean over the large-B cells -------------
    # (one batched launch vs B separate ones, same engine per cell;
    # aggregated so a single timer-noise cell cannot flip CI)
    sp = [c["batched_speedup"] for c in cells
          if c.get("kind") in BATCHED_KINDS
          and c.get("batch", 0) >= speedup_min_batch]
    if sp:
        geomean = math.exp(sum(math.log(max(s, 1e-9)) for s in sp) / len(sp))
        if not geomean > 1.0:
            fails.append(
                f"batched cells (batch >= {speedup_min_batch}): geomean "
                f"batched_speedup {geomean:.2f} <= 1 over {len(sp)} cells "
                f"({', '.join(f'{s:.2f}' for s in sp)}) — one batched "
                f"launch is not beating B separate launches")

    # -- arena speedup: geomean over the large-B stacked-vs-arena cells -----
    # (same aggregation logic as batched_speedup: the arena-filled step must
    # beat the jnp.stack-assembled step where the copy volume matters)
    ar = [c["arena_speedup"] for c in cells
          if c.get("kind") == "arena"
          and c.get("batch", 0) >= speedup_min_batch]
    if ar:
        geomean = math.exp(sum(math.log(max(s, 1e-9)) for s in ar) / len(ar))
        if not geomean > 1.0:
            fails.append(
                f"arena cells (batch >= {speedup_min_batch}): geomean "
                f"arena_speedup {geomean:.2f} <= 1 over {len(ar)} cells "
                f"({', '.join(f'{s:.2f}' for s in ar)}) — the arena-filled "
                f"step is not beating the stacked assembly")

    # -- overlap speedup: geomean floor over sync-vs-pipelined cells --------
    # (p = 1 cells measure the pipeline's launch cost — (C-1) extra, smaller
    # launches and re-read vectors with no wire to hide — so the floor is a
    # calibrated catastrophic-regression bound, not > 1; the wire-hiding win
    # itself is pinned by the recomputed dhopm_time_sweep prediction above)
    ov = [c["overlap_speedup"] for c in cells
          if c.get("kind") == "dhopm3_overlap"]
    if ov:
        geomean = math.exp(sum(math.log(max(s, 1e-9)) for s in ov) / len(ov))
        if not geomean > overlap_speedup_min:
            fails.append(
                f"dhopm3_overlap cells: geomean overlap_speedup "
                f"{geomean:.2f} <= floor {overlap_speedup_min} over "
                f"{len(ov)} cells ({', '.join(f'{s:.2f}' for s in ov)}) — "
                f"the pipelined walker is pathologically slower than sync")

    # -- auto must tie the best flags in aggregate --------------------------
    # (per-pair timing noise is ~10% one-sided, so the tight bound lives on
    # the geomean: auto picking right on every cell sits at ~1.0 here, one
    # systematic mis-pick on the measured 2-4x margins blows straight past
    # the ceiling)
    if auto_best_all:
        geomean = math.exp(sum(math.log(max(s, 1e-9))
                               for s in auto_best_all) / len(auto_best_all))
        if not geomean <= auto_ratio:
            fails.append(
                f"flag-swept cells: geomean auto_us/best_flag "
                f"{geomean:.3f} > ceiling {auto_ratio} over "
                f"{len(auto_best_all)} cells "
                f"({', '.join(f'{s:.2f}' for s in auto_best_all)}) — "
                f"auto dispatch is losing to the best explicit flags")

    # -- auto floor on the dispatch-dominated regime ------------------------
    # (the cells this planner exists for: auto must at least beat the worst
    # explicit flag in aggregate, or the cost model is choosing badly)
    if auto_worst_dd:
        geomean = math.exp(sum(math.log(max(s, 1e-9))
                               for s in auto_worst_dd) / len(auto_worst_dd))
        if not geomean > auto_worst_min:
            fails.append(
                f"dispatch-dominated cells: geomean auto_vs_worst_flag "
                f"{geomean:.2f} <= floor {auto_worst_min} over "
                f"{len(auto_worst_dd)} cells "
                f"({', '.join(f'{s:.2f}' for s in auto_worst_dd)}) — "
                f"auto dispatch is not beating the worst explicit flag")

    # -- warm-start: the persistent compile cache must actually bite --------
    if schema >= 6 and warm_ratios:
        geomean = math.exp(sum(math.log(max(r, 1e-9))
                               for r in warm_ratios) / len(warm_ratios))
        if not geomean < warm_compile_max:
            fails.append(
                f"warm-start: geomean compile_warm/compile_cold "
                f"{geomean:.2f} >= ceiling {warm_compile_max} over "
                f"{len(warm_ratios)} cells — the persistent compilation "
                f"cache is not short-circuiting recompiles")
    return fails


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("bench", help="trajectory JSON to gate")
    ap.add_argument("--ref", default=None,
                    help="committed reference file whose schema the gated "
                         "file must match (e.g. BENCH_TVC.json)")
    ap.add_argument("--acct-tol", type=float, default=0.0,
                    help="allowed fractional excess of recorded over "
                         "predicted streamed bytes (default: exact)")
    ap.add_argument("--dispatch-us", type=float, default=DEFAULT_DISPATCH_US,
                    help="per-launch dispatch-overhead allowance for the "
                         "time-implied check (ROADMAP small-cell caveat)")
    ap.add_argument("--ratio-pallas", type=float,
                    default=DEFAULT_CEILINGS["ratio_pallas"],
                    help="implied/predicted traffic ceiling on TPU "
                         "(calibrated; >= the paper's 50%%-of-STREAM floor)")
    ap.add_argument("--ratio-native", type=float,
                    default=DEFAULT_CEILINGS["ratio_native"],
                    help="ceiling for the CPU native-xla proxy "
                         "(calibrated catastrophic-regression bound)")
    ap.add_argument("--lowprec-factor", type=float,
                    default=DEFAULT_CEILINGS["lowprec_factor"],
                    help="extra native-xla headroom for non-f32 cells "
                         "(calibrated; CPU XLA emulates bf16/f16)")
    ap.add_argument("--speedup-min-batch", type=int, default=16,
                    help="gate batched_speedup > 1 only on batched cells "
                         "with at least this batch size (small-B cells are "
                         "noise-prone; B = 64 is the acceptance cell)")
    ap.add_argument("--overlap-speedup-min", type=float, default=0.25,
                    help="geomean floor for sync/pipelined wall-time ratio "
                         "of the dhopm3_overlap cells (p = 1 runs pay the "
                         "chunked-launch cost with no wire to hide; this "
                         "bounds catastrophic pipeline regressions)")
    ap.add_argument("--auto-ratio", type=float, default=1.1,
                    help="geomean ceiling for auto_us over the best "
                         "explicit-flag timing across all swept cells "
                         "(schema >= 6)")
    ap.add_argument("--auto-cell-ratio", type=float, default=1.3,
                    help="per-cell ceiling for auto_us over the best "
                         "explicit flag (catastrophic mis-pick bound; "
                         "per-pair timing noise makes a tighter per-cell "
                         "bound flake)")
    ap.add_argument("--auto-worst-min", type=float, default=1.0,
                    help="geomean floor for auto_vs_worst_flag over the "
                         "dispatch-dominated cells")
    ap.add_argument("--warm-compile-max", type=float, default=0.6,
                    help="geomean ceiling for compile_warm_us / "
                         "compile_cold_us (persistent-cache warm start)")
    ap.add_argument("--serving-rps-min", type=float, default=0.05,
                    help="per-cell requests/s floor for serving cells "
                         "(schema 7; a catastrophic-regression bound — the "
                         "smoke loop on a loaded CI box still clears it "
                         "with wide margin)")
    args = ap.parse_args(argv)

    payload = json.loads(pathlib.Path(args.bench).read_text())
    ref = (json.loads(pathlib.Path(args.ref).read_text())
           if args.ref else None)
    fails = check(payload, ref, acct_tol=args.acct_tol,
                  dispatch_us=args.dispatch_us,
                  ratio_pallas=args.ratio_pallas,
                  ratio_native=args.ratio_native,
                  lowprec_factor=args.lowprec_factor,
                  speedup_min_batch=args.speedup_min_batch,
                  overlap_speedup_min=args.overlap_speedup_min,
                  auto_ratio=args.auto_ratio,
                  auto_cell_ratio=args.auto_cell_ratio,
                  auto_worst_min=args.auto_worst_min,
                  warm_compile_max=args.warm_compile_max,
                  serving_rps_min=args.serving_rps_min)
    engine = payload.get("meta", {}).get("engine")
    n = len(payload.get("cells", []))
    if fails:
        for f in fails:
            print(f"FAIL {f}")
        print(f"# bandwidth gate: {len(fails)} failure(s) over {n} cells "
              f"({args.bench}, engine={engine})")
        return 1
    timed = "timed" if engine in TIMED_ENGINES else "accounting-only"
    print(f"# bandwidth gate: OK — {n} cells ({args.bench}, "
          f"engine={engine}, {timed})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
