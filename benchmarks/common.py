"""Benchmark utilities: timing, STREAM-triad reference bandwidth, the Table-1
tensor suite scaled to container RAM."""
from __future__ import annotations

import time
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

# Table 1 orders with sizes scaled so every tensor is ~128 MB of f32 —
# large enough to defeat L3, small enough for the container (the paper uses
# 7.5 GB on 48-core nodes; the methodology is identical).
TENSORS = {
    2: (5793, 5793),
    3: (322, 322, 322),
    4: (76, 76, 76, 76),
    5: (32, 32, 32, 32, 32),
    6: (18, 18, 18, 18, 18, 18),
    7: (12, 12, 12, 12, 12, 12, 12),
    8: (9, 9, 9, 9, 9, 9, 9, 9),
    9: (7, 7, 7, 7, 7, 7, 7, 7, 7),
    10: (6, 6, 6, 6, 6, 6, 6, 6, 6, 6),
}


def time_fn(fn, *args, reps: int = 5, warmup: int = 2, min_time: float = 0.2):
    """Median wall time of fn(*args) (block_until_ready'd)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    t_total = 0.0
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        dt = time.perf_counter() - t0
        times.append(dt)
        t_total += dt
        if t_total > min_time and len(times) >= 3:
            break
    return float(np.median(times))


_STREAM_CACHE: dict = {}


def stream_triad_gbs(n: int = 30_000_000) -> float:
    """Measured triad (a = b + alpha*c) bandwidth in GB/s — the reference
    peak for normalizing TVC/HOPM bandwidth, as the paper does with STREAM.
    The output buffer is donated so steady-state iterations allocate nothing
    (true STREAM semantics — fresh 120 MB allocations cost page faults)."""
    if "triad" in _STREAM_CACHE:
        return _STREAM_CACHE["triad"]
    b = jnp.arange(n, dtype=jnp.float32)
    c = jnp.ones((n,), jnp.float32)
    a = jnp.zeros((n,), jnp.float32)

    @partial(jax.jit, donate_argnums=(0,))
    def triad(a, b, c):
        del a  # buffer reused for the output
        return b + 1.5 * c

    # warmup (page-faults the pool)
    for _ in range(2):
        a = triad(a, b, c)
    jax.block_until_ready(a)
    best = float("inf")
    for _ in range(7):
        t0 = time.perf_counter()
        a = triad(a, b, c)
        jax.block_until_ready(a)
        best = min(best, time.perf_counter() - t0)
    gbs = 3 * n * 4 / best / 1e9    # read b, read c, write a
    _STREAM_CACHE["triad"] = gbs
    return gbs


def rand_tensor(shape, dtype=np.float32, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32).astype(dtype))


def emit(name: str, us_per_call: float, derived: str) -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line
