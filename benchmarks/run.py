"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--only tvc,hopm,...] [--smoke]

``--smoke`` runs suites that support it (currently ``tvc_kernel``) on tiny
shapes — CI uses it to keep the BENCH_TVC.json writer and schema exercised
on CPU without pretending the timings mean anything.
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time

SUITES = ("memory_model", "tvc", "tvc_kernel", "hopm", "mixed_precision",
          "scaling", "compression", "serving", "arena")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help=f"comma list from {SUITES}")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / schema-exercise mode")
    args = ap.parse_args()
    chosen = args.only.split(",") if args.only else list(SUITES)

    print("name,us_per_call,derived")
    t0 = time.time()
    failures = []
    for name in chosen:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        print(f"# == {name} ==", flush=True)
        kwargs = {}
        if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
            kwargs["smoke"] = True
        try:
            mod.run(**kwargs)
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            print(f"# FAILED {name}: {e}", flush=True)
    print(f"# total {time.time()-t0:.1f}s")
    if failures:
        for name, e in failures:
            print(f"# failure: {name}: {e}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
