"""Offline (bu, bk, bv) block-size sweep — regenerates the checked-in
``src/repro/kernels/block_table.json`` that the autotuner consults before its
heuristic grow loop.

    PYTHONPATH=src python -m benchmarks.sweep_blocks [--smoke] \
        [--dtypes f32,bf16] [--max-candidates N] [--out PATH] [--dry-run]

The grid is the bench harness's (order, mode-class, dtype) cells
(:mod:`benchmarks.bench_tvc_kernel` shapes): every single mode of each shape
(kind ``tvc3`` / ``tvc2`` by whether v == 1) plus the leading and tail
adjacent-mode pairs (kind ``tvc4`` / ``tvc2_pair``).  Winners are merged into
the table (replacing same-cell entries for this backend) and tagged with the
backend + engine, so a table swept here never steers other hardware — rerun
this script on each new machine (see README "Kernels").

On non-TPU backends the kernels run in interpret mode: the sweep still
exercises every candidate end-to-end (CI uses ``--smoke`` for exactly that),
but the timings rank interpreter overhead, not HBM streaming — regenerate on
TPU before trusting the winners.
"""
from __future__ import annotations

import argparse
import math
import time

import jax

from repro.core.mixed_precision import get_policy
from repro.kernels import block_table, sweep
from .bench_tvc_kernel import (
    BATCH_SHAPES,
    BATCH_SIZES,
    SHAPES,
    SMOKE_BATCH_SHAPES,
    SMOKE_SHAPES,
)
from .common import emit


def grid_cases(shapes_by_layout, dtypes, batch_shapes=None):
    """(kind, dims, order, mode_class, dtype) cells for the sweep."""
    cases = []
    for layout, by_order in shapes_by_layout.items():
        del layout  # aligned vs ragged share size buckets; sweep both shapes
        for d, shape in sorted(by_order.items()):
            for polname in dtypes:
                for k in range(d):
                    u = math.prod(shape[:k])
                    v = math.prod(shape[k + 1:])
                    if v == 1:
                        cases.append(("tvc2", (u, shape[k]), d, "matvec",
                                      polname))
                    else:
                        cases.append(("tvc3", (u, shape[k], v), d, "inner",
                                      polname))
                # adjacent pairs: leading (k1 = 0) and the chain tail
                # (k1 = d-2) — the two shapes dHOPM_3's fused chains see
                for k1 in {0, d - 2}:
                    u = math.prod(shape[:k1])
                    n1, n2 = shape[k1], shape[k1 + 1]
                    v = math.prod(shape[k1 + 2:])
                    if v == 1:
                        cases.append(("tvc2_pair", (u, n1, n2), d,
                                      "pair_tail", polname))
                    else:
                        cases.append(("tvc4", (u, n1, n2, v), d, "pair",
                                      polname))
    # batched kinds: the bench's small-tensor batch cells, every kernel body
    # (single inner + matvec tail, fused leading pair + pair tail)
    for shape in (batch_shapes or {}).values():
        d = len(shape)
        for polname in dtypes:
            for B in BATCH_SIZES:
                u1, n1, v1 = math.prod(shape[:1]), shape[1], \
                    math.prod(shape[2:])
                cases.append(("tvc3_batched", (B, u1, n1, v1), d,
                              "batched_inner", polname))
                cases.append(("tvc2_batched",
                              (B, math.prod(shape[:-1]), shape[-1]), d,
                              "batched_matvec", polname))
                cases.append(("tvc4_batched",
                              (B, 1, shape[0], shape[1],
                               math.prod(shape[2:])), d, "batched_pair",
                              polname))
                cases.append(("tvc2_pair_batched",
                              (B, math.prod(shape[:-2]), shape[-2],
                               shape[-1]), d, "batched_pair_tail", polname))
    # dedupe identical (kind, dims, dtype) cells across layouts/orders
    seen, out = set(), []
    for c in cases:
        key = (c[0], c[1], c[4])
        if key not in seen:
            seen.add(key)
            out.append(c)
    return out


def run(smoke: bool = False, dtypes=("f32", "bf16"), max_candidates: int = 48,
        out_path=None, dry_run: bool = False, reps: int = 3):
    shapes = SMOKE_SHAPES if smoke else SHAPES
    batch_shapes = SMOKE_BATCH_SHAPES if smoke else BATCH_SHAPES
    if smoke:
        max_candidates = min(max_candidates, 6)
        reps = 1
    engine = sweep.engine_name()
    backend = jax.default_backend()
    lines = []
    winners = []
    for kind, dims, order, mode_class, polname in grid_cases(
            shapes, dtypes, batch_shapes):
        prec = get_policy(polname)
        best, results = sweep.sweep_case(
            kind, dims, prec=prec, max_candidates=max_candidates, reps=reps)
        winners.append(block_table.entry(
            kind, dims, best.blocks, prec.storage, gbs=best.gbs, order=order,
            mode_class=mode_class, engine=engine, backend=backend,
        ))
        name = f"sweep_{kind}_{'x'.join(map(str, dims))}_{polname}"
        lines.append(emit(
            name, best.seconds * 1e6,
            f"blocks={'x'.join(map(str, best.blocks))}"
            f";{best.gbs:.2f}GB/s;{len(results)}cand"))

    if dry_run:
        print(f"# dry run: {len(winners)} winners NOT written")
        return lines, winners

    # merge: this backend's same-bucket cells are replaced, everything else
    # (other backends' winners) is preserved
    new_keys = {
        (w["kind"], w["dtype"], w["backend"],
         tuple(block_table.size_bucket(d) for d in w["dims"]))
        for w in winners
    }
    kept = [
        e for e in block_table.load(out_path)
        if (e.get("kind"), e.get("dtype"), e.get("backend"),
            tuple(block_table.size_bucket(d) for d in e.get("dims", [])))
        not in new_keys
    ]
    path = block_table.save(
        kept + winners, out_path,
        meta={
            "generated_by": "benchmarks/sweep_blocks.py",
            "engine": engine,
            "backend": backend,
            "jax": jax.__version__,
            "smoke": smoke,
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        },
    )
    print(f"# wrote {path} ({len(winners)} winners, {len(kept)} kept)",
          flush=True)
    return lines, winners


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, few candidates (CI machinery check)")
    ap.add_argument("--dtypes", default="f32,bf16")
    ap.add_argument("--max-candidates", type=int, default=48)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", default=None,
                    help="table path (default: the checked-in "
                         "src/repro/kernels/block_table.json)")
    ap.add_argument("--dry-run", action="store_true",
                    help="measure and print winners without writing")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke, dtypes=tuple(args.dtypes.split(",")),
        max_candidates=args.max_candidates, out_path=args.out,
        dry_run=args.dry_run, reps=args.reps)


if __name__ == "__main__":
    main()
