"""dHOPM_3 gradient compression end-to-end (the paper integrated into the
optimizer path).  Runs under 8 virtual devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/hopm_gradient_compression.py

Trains the same model twice — exact DP sync vs dHOPM_3 rank-r compression —
and reports final losses and per-step gradient wire bytes.  (Step counts are
sized for a single-core container; raise --steps on real hardware.)
"""
import os

if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

import jax  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.data import DataConfig, SyntheticLMData  # noqa: E402
from repro.models import registry  # noqa: E402
from repro.train import optimizer as opt_mod  # noqa: E402
from repro.train.grad_compress import CompressorCfg, wire_bytes_summary  # noqa: E402
from repro.train.train_loop import TrainConfig, train  # noqa: E402


def run(tcfg, cfg, mesh, steps=3):
    data = SyntheticLMData(DataConfig(cfg.vocab_size, 16, 8, seed=4), mesh)
    _, _, hist = train(cfg, mesh, tcfg, data.iterate(0), steps,
                       log_every=10)
    return hist


def main():
    assert jax.device_count() == 8
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    cfg = get_config("qwen2-1.5b", smoke=True)
    ocfg = opt_mod.OptConfig(lr=2e-3, warmup_steps=5, total_steps=40)

    print("== exact DP sync (baseline) ==")
    hist_exact = run(TrainConfig(opt=ocfg, mode="dp_explicit"), cfg, mesh)

    print("== dHOPM_3 compression (rank 4, 1 sweep, bf16 wire) ==")
    # single-core container: keep the compiled graph small — compress the
    # embedding + the largest matrices only (min_size gates the rest)
    ccfg = CompressorCfg(rank=4, sweeps=1, min_size=16384, prec="bf16")
    hist_comp = run(TrainConfig(opt=ocfg, mode="dp_explicit", compression=ccfg),
                    cfg, mesh)

    params = registry.get(cfg.family).init(cfg, jax.random.PRNGKey(0))
    stats = wire_bytes_summary(params, ccfg, 8)
    print(f"\nwire bytes/step/device: dense {stats['dense_bytes']/1e6:.2f} MB "
          f"-> compressed {stats['compressed_bytes']/1e6:.2f} MB "
          f"({stats['ratio']:.1f}x less)")
    print(f"final loss exact      : {hist_exact[-1]['loss']:.4f}")
    print(f"final loss compressed : {hist_comp[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
