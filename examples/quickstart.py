"""Quickstart: the paper's algorithms on one device in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Covers: mode-oblivious TVC (all impls incl. the Pallas kernel), the streamed
memory model (Fig. 2), sequential HOPM_3 rank-1 approximation, and mixed
precision (§5.5).
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core import tvc, tvc_bytes
from repro.core.dhopm import hopm3, rank1_residual
from repro.core.memory_model import H_inv, eta_inv, saved_contractions
from repro.kernels import ref

rng = np.random.default_rng(0)

# --- 1. TVC over every mode of a 4th-order tensor --------------------------
# (the Pallas kernel runs in interpret mode on CPU — correctness only, so the
#  demo tensor is small; timings of the compiled jnp paths are indicative)
A = jnp.asarray(rng.normal(size=(16, 12, 10, 8)).astype(np.float32))
print("== TVC (mode-oblivious) ==")
for k in range(A.ndim):
    x = jnp.asarray(rng.normal(size=(A.shape[k],)).astype(np.float32))
    outs = {}
    for impl in ("native", "looped", "unfolded", "pallas"):
        t0 = time.perf_counter()
        y = tvc(A, x, k, impl=impl).block_until_ready()
        outs[impl] = time.perf_counter() - t0
    err = float(jnp.max(jnp.abs(tvc(A, x, k) - ref.tvc_ref(A, x, k))))
    print(f"  mode {k}: streamed {tvc_bytes(A.shape, k, 4)/1e6:.2f} MB, "
          f"max|err| {err:.2e}, "
          + ", ".join(f"{n} {t*1e3:.1f}ms" for n, t in outs.items()))

# --- 2. streamed-memory model (paper Fig. 2) --------------------------------
print("\n== streamed-memory model ==")
print(f"  eta^-1(d=3, p=n, s=0)  = {eta_inv(979, 3, 979, 0):.2f}  (paper: >2)")
print(f"  H^-1(d=3)              = {H_inv(979, 3, 8, 2):.2f}  (paper: ~1.5x)")
print(f"  H^-1(d=10)             = {H_inv(8, 10, 8, 0):.2f}  (paper: ~5x)")
print(f"  contractions saved d=10: {saved_contractions(10)} per sweep")

# --- 3. HOPM_3: best rank-1 approximation ----------------------------------
print("\n== HOPM_3 rank-1 ==")
us = [rng.normal(size=(n,)).astype(np.float32) for n in (40, 30, 20)]
us = [u / np.linalg.norm(u) for u in us]
T = jnp.asarray(4.2 * np.einsum("i,j,k->ijk", *us)
                + 0.002 * rng.normal(size=(40, 30, 20)).astype(np.float32))
xs0 = [jnp.asarray(rng.normal(size=(n,)).astype(np.float32)) for n in T.shape]
xs, lam = hopm3(T, xs0, sweeps=4)
print(f"  lambda = {float(lam):.3f} (planted 4.2), "
      f"residual = {float(rank1_residual(T, xs, lam)):.3f} "
      f"(noise floor ~{0.002 * np.sqrt(40*30*20) / 4.2:.3f})")

# --- 4. mixed precision (§5.5) ----------------------------------------------
print("\n== mixed precision ==")
for pol in ("f32", "bf16", "f16"):
    Ab = A if pol == "f32" else A.astype(jnp.bfloat16 if pol == "bf16" else jnp.float16)
    xb = jnp.ones((48,), Ab.dtype)
    y = tvc(Ab, xb, 1, impl="pallas", prec=pol)
    yref = ref.tvc_ref(A, jnp.ones((48,)), 1)
    rel = float(jnp.max(jnp.abs(y.astype(jnp.float32) - yref))
                / jnp.max(jnp.abs(yref)))
    print(f"  storage={pol:>4}: bytes/elt {jnp.dtype(Ab.dtype).itemsize}, "
          f"rel err vs f32 = {rel:.2e}")
print("\nquickstart OK")
