"""Batched serving example: prefill + decode with KV cache, greedy and
sampled generation, across three model families (GQA, MLA, state-space).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import numpy as np
import jax

from repro.configs import get_config
from repro.models import registry
from repro.serve import DecodeEngine


def demo(arch: str, steps: int = 24):
    cfg = get_config(arch, smoke=True)
    params = registry.get(cfg.family).init(cfg, jax.random.PRNGKey(0))
    eng = DecodeEngine(cfg, params, max_seq=128, batch_size=4)
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.vocab_size, (4, 12))
    t0 = time.perf_counter()
    res = eng.generate(prompts, steps=steps, temperature=0.7, top_k=20, seed=7)
    dt = time.perf_counter() - t0
    print(f"{arch:>24} [{cfg.family:8}] {res.tokens.size} tokens "
          f"in {dt:5.2f}s — sample: {res.tokens[0][:10]}")


def main():
    for arch in ("qwen2-1.5b", "deepseek-v2-lite-16b", "rwkv6-3b",
                 "recurrentgemma-9b", "whisper-tiny"):
        if arch == "whisper-tiny":
            # enc-dec needs the audio stub
            cfg = get_config(arch, smoke=True)
            params = registry.get(cfg.family).init(cfg, jax.random.PRNGKey(0))
            eng = DecodeEngine(cfg, params, max_seq=128, batch_size=4)
            rng = np.random.default_rng(1)
            audio = rng.normal(size=(4, cfg.encdec.n_audio_ctx, cfg.d_model)
                               ).astype(np.float32)
            prompts = rng.integers(0, cfg.vocab_size, (4, 12))
            res = eng.generate(prompts, steps=16, extra=audio)
            print(f"{arch:>24} [encdec  ] {res.tokens.size} tokens "
                  f"— sample: {res.tokens[0][:10]}")
        else:
            demo(arch)


if __name__ == "__main__":
    main()
