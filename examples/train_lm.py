"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
on synthetic data, with checkpoints and restart (deliverable b).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

A ~100M model is built by shrinking the granite-8b family config; the loop
exercises the real substrate: data pipeline, AdamW + schedule, remat,
checkpoint/restart, watchdog.
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLMData
from repro.train import optimizer as opt_mod
from repro.train.train_loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = get_config("granite-8b")
    cfg = dataclasses.replace(
        base, name="granite-100m",
        n_layers=8, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab_size=32768, dtype="float32", remat=False,
        q_chunk=256, kv_chunk=256,
    )
    from repro.models import registry
    n = registry.get(cfg.family).param_count(cfg)
    print(f"model: {cfg.name}, {n/1e6:.1f}M params")

    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    tcfg = TrainConfig(
        opt=opt_mod.OptConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps,
                              weight_decay=0.01),
        ckpt_dir=args.ckpt_dir, ckpt_every=100)
    data = SyntheticLMData(DataConfig(cfg.vocab_size, 256, 8, seed=0), mesh)
    params, opt_state, hist = train(cfg, mesh, tcfg, data.iterate(0),
                                    args.steps, log_every=20)
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    assert hist[-1]["loss"] < hist[0]["loss"]


if __name__ == "__main__":
    main()
