"""repro: a multi-pod JAX training/inference framework built around the
distributed tensor-vector contraction algorithms of Martinez-Ferrer,
Yzelman & Beltran (2025)."""

from . import _compat  # noqa: F401  (installs jax version shims)

__version__ = "1.0.0"
