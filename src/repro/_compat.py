"""Compatibility shims across the supported range of jax versions.

The code base is written against the modern jax surface:

* ``jax.shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=...)``
* ``jax.sharding.AxisType`` and ``jax.make_mesh(..., axis_types=...)``
* ``jax.lax.axis_size(name)`` inside manual (shard_map) regions

On 0.4.x installs some of those spellings are missing (``shard_map`` lives in
``jax.experimental`` and takes ``check_rep``; meshes have no axis types; the
axis size must be recovered from the axis environment).  This module installs
small forwarding shims at import time — a no-op wherever the real API already
exists.  It is imported from the ``repro`` package ``__init__``, so any
``import repro.*`` activates it before user code touches jax.
"""
from __future__ import annotations

import enum
import functools
import inspect

import jax
from jax import lax


def _install_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
                  check_rep=None, **kwargs):
        if check_rep is None:
            check_rep = True if check_vma is None else check_vma
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_rep, **kwargs)

    jax.shard_map = shard_map


def _install_axis_type() -> None:
    if hasattr(jax.sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        """Stand-in for jax.sharding.AxisType on versions without explicit
        sharding modes (every mesh axis behaves as Auto there)."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


def _install_make_mesh() -> None:
    try:
        params = inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):  # pragma: no cover — exotic builds
        return
    if "axis_types" in params:
        return
    _make_mesh = jax.make_mesh

    @functools.wraps(_make_mesh)
    def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kwargs):
        del axis_types  # pre-AxisType meshes are implicitly Auto
        return _make_mesh(axis_shapes, axis_names, **kwargs)

    jax.make_mesh = make_mesh


def _install_axis_size() -> None:
    if hasattr(lax, "axis_size"):
        return

    def axis_size(axis_name) -> int:
        # psum of a static python scalar is evaluated statically from the
        # axis environment, so this returns a concrete int under tracing.
        return lax.psum(1, axis_name)

    lax.axis_size = axis_size


def _install_partitionable_threefry() -> None:
    # Newer jax defaults to partitionable threefry, whose bits do not depend
    # on the output sharding.  The legacy generator produces *different*
    # values under GSPMD-sharded outputs, which breaks this repo's
    # cross-mode oracles (gspmd vs dp_explicit init must agree bitwise).
    # NOTE: like every shim here this is process-global — on old jax,
    # importing repro aligns the whole process with the modern default, so
    # unrelated jax.random draws in the same process change relative to a
    # run without the import (exactly as they would on current jax).
    try:
        if not jax.config.jax_threefry_partitionable:
            jax.config.update("jax_threefry_partitionable", True)
    except AttributeError:  # pragma: no cover — flag removed upstream
        pass


def _install_optimization_barrier_batching() -> None:
    # ``lax.optimization_barrier`` is the identity on values, but on jax
    # versions in our support range it has no vmap batching rule, so any
    # barriered code path (the mulsum engine's fusion islands, dHOPM's
    # iterate barriers) would crash under jax.vmap.  The rule is trivial:
    # apply the barrier to the batched values, pass the batch dims through.
    try:
        from jax._src.interpreters import batching
        from jax._src.lax import control_flow
        prim = control_flow.optimization_barrier_p
    except (ImportError, AttributeError):  # pragma: no cover
        try:
            from jax.interpreters import batching
            from jax._src import lax as _lax_src
            prim = _lax_src.optimization_barrier_p
        except (ImportError, AttributeError):
            return
    if prim in batching.primitive_batchers:
        return

    def _rule(args, dims, **params):
        return prim.bind(*args, **params), dims

    batching.primitive_batchers[prim] = _rule


def install() -> None:
    _install_shard_map()
    _install_axis_type()
    _install_make_mesh()
    _install_axis_size()
    _install_partitionable_threefry()
    _install_optimization_barrier_batching()


install()
