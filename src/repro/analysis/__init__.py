"""Compiled-artifact analysis: roofline terms from cost_analysis + HLO."""
from .roofline import RooflineReport, analyze_compiled, collective_bytes  # noqa: F401
