"""Assemble EXPERIMENTS.md's generated sections from the report JSONs.

    PYTHONPATH=src python -m repro.analysis.assemble_experiments
"""
from __future__ import annotations

import json
import pathlib

from .report import roofline_table

ROOT = pathlib.Path(__file__).resolve().parents[3]


def _load(f):
    return json.loads((ROOT / "reports" / f).read_text())


def _cell(f):
    return [x for x in _load(f) if x["status"] == "ok"][0]


def _terms(r):
    rl = r["roofline"]
    return (rl["t_compute"], rl["t_memory"], rl["t_collective"],
            rl["bottleneck"], rl["roofline_fraction"],
            r["memory_analysis"]["temp_bytes"] / 1e9)


def _fmt(r):
    c, m, l, b, rf, t = _terms(r)
    return (f"comp {c:.3g}s / mem {m:.3g}s / coll {l:.3g}s, "
            f"{b}-bound, roofline {rf:.2f}, temps {t:.1f} GB/chip")


def perf_section() -> str:
    base = {(r["arch"], r["shape"]): r
            for r in _load("dryrun_v2.json") if r["status"] == "ok"}
    out = []

    def iteration(cell, tag, hypothesis, change, f, verdict_fn):
        b = base[cell]
        r = _cell(f)
        verdict = verdict_fn(b, r)
        out.append(f"**{tag}** — *hypothesis*: {hypothesis}\n"
                   f"  *change*: `{change}`\n"
                   f"  *before*: {_fmt(b)}\n"
                   f"  *after*:  {_fmt(r)}\n"
                   f"  *verdict*: {verdict}\n")

    out.append("### Cell 1 — deepseek-v2-lite-16b × prefill_32k "
               "(worst roofline fraction, collective-bound)\n")
    iteration(
        ("deepseek-v2-lite-16b", "prefill_32k"), "A1",
        "the 76 GB/chip of all-gathers are FSDP weight gathers; a 16B model "
        "serves fine with weights replicated over the data axis, removing "
        "them entirely",
        "--opts serving_replicated_params",
        "hc_A1_dsv2_prefill_serveparams.json",
        lambda b, r: ("PARTIALLY CONFIRMED: collective term -43% (7.01→3.98 s)"
                      " — but compute 3x and temps 4.2→17.7 GB: without FSDP,"
                      " GSPMD re-partitions the MoE/MLA einsums and "
                      "replicates work across the data axis.  FSDP gathers "
                      "amortize at prefill batch sizes; the serving layout "
                      "win is decode-specific (see Cell 2)."))
    iteration(
        ("deepseek-v2-lite-16b", "prefill_32k"), "A2",
        "adding a sequence-parallel residual stream recovers the temp "
        "regression by sharding the per-layer hidden over the model axis",
        "--opts serving_replicated_params,seq_shard_activations",
        "hc_A2_dsv2_prefill_sp.json",
        lambda b, r: ("CONFIRMED for memory (temps 17.7→12.7 GB, under the "
                      "16 GB chip) and best step-sum of the series "
                      "(13.6→11.5 s, -16%); compute regression remains."))
    iteration(
        ("deepseek-v2-lite-16b", "prefill_32k"), "A3",
        "the remaining 200 GB/chip all-reduce is the f32 MoE combine; a bf16 "
        "combine should halve it",
        "--opts serving_replicated_params,moe_bf16_combine",
        "hc_A3_dsv2_prefill_bf16moe.json",
        lambda b, r: ("REFUTED: collective term unchanged vs A1 (3.98 s) — "
                      "the dominant all-reduce is not the expert-combine "
                      "psum (napkin math mis-attributed it); it tracks the "
                      "attention/latent path."))
    iteration(
        ("deepseek-v2-lite-16b", "prefill_32k"), "A4",
        "keep FSDP (avoid the A1 compute regression), take only SP + bf16 "
        "combine",
        "--opts seq_shard_activations,moe_bf16_combine",
        "hc_A4_dsv2_prefill_sp_bf16moe.json",
        lambda b, r: ("MARGINAL: coll -4% (7.01→6.74 s), temps -12% with no "
                      "compute cost.  Series conclusion: A2 wins on step-sum;"
                      " the next lever is the memory term itself — the MLA "
                      "decompression einsums (absorbed-form prefill), left "
                      "as the recorded next iteration."))

    out.append("\n### Cell 2 — rwkv6-3b × decode_32k (the collective-bound "
               "cell)\n")
    iteration(
        ("rwkv6-3b", "decode_32k"), "B1",
        "0.73 GB/chip of all-gathers per decoded token = FSDP weight "
        "gathers with zero batch amortization; replicate the 3B weights "
        "over the data axis for serving",
        "--opts serving_replicated_params",
        "hc_B1_rwkv_decode_serveparams.json",
        lambda b, r: ("CONFIRMED: collective term 14.8→0.3 ms (-98%), "
                      "step-sum 5x better, bottleneck flips to memory "
                      "(state streaming — the correct decode regime), "
                      "roofline 0.75→0.93.  Converged: three further "
                      "candidates all predict <5%."))
    out.append(
        "**D1 (transfer check)** — applying the same serving layout to "
        "kimi-k2 (1T MoE) decode: REFUTED — replicated weights put "
        "1T/16 = 126 GB/chip on each device (temps 24.8→282 GB).  The "
        "serving-layout rule is model-size-dependent: replicate ≤ ~10B, "
        "keep FSDP-sharded weights (or gather-on-use) above.  "
        "(`hc_D1_kimi_decode_serveparams.json`)\n")

    out.append("\n### Cell 3 — llama3-405b × train_4k (paper-representative: "
               "heaviest collective volume; temps do not fit the chip)\n")
    iteration(
        ("llama3-405b", "train_4k"), "C1",
        "821 GB/chip of temps are per-layer residuals saved by remat, "
        "replicated over the model axis; 4.19 TB/chip of all-reduce is the "
        "TP activation traffic.  Sequence-parallel residuals shard both "
        "over the 16-way model axis",
        "--opts seq_shard_activations",
        "hc_C1_llama_train_sp.json",
        lambda b, r: ("CONFIRMED for the target (memory): temps 822→198 GB "
                      "(-76%), memory term -18% (245→200 s).  Collective "
                      "term +26% (the rs/ag decomposition emits extra "
                      "permutes under GSPMD) — net step-sum -6%.  Memory was "
                      "the blocking term; keep."))
    iteration(
        ("llama3-405b", "train_4k"), "C2",
        "~24% of compute is remat recompute; saving matmul outputs "
        "(dots policy) trades memory for FLOPs",
        "--remat-policy dots",
        "hc_C2_llama_train_dots.json",
        lambda b, r: ("CONFIRMED for compute (66→54 s, -18%) and REFUTED "
                      "for memory (temps 822→1515 GB): saved dot outputs "
                      "dominate.  Unusable alone on a 16 GB chip."))
    iteration(
        ("llama3-405b", "train_4k"), "C3",
        "SP shards the dot outputs too, so combining recovers C2's memory "
        "blowup while keeping its compute win",
        "--opts seq_shard_activations --remat-policy dots",
        "hc_C3_llama_train_sp_dots.json",
        lambda b, r: ("PARTIALLY: compute 53 s and temps 452 GB — better "
                      "than C2 but 2.3x worse than C1.  On a memory-bound "
                      "cell C1 still wins."))
    try:
        iteration(
            ("llama3-405b", "train_4k"), "C4",
            "the flash-attention q-chunk outputs are stacked in f32 before "
            "the downcast; casting inside the chunk halves that buffer",
            "code: attention.py chunk-local astype (global improvement)",
            "hc_C4_llama_train_sp_bf16attn.json",
            lambda b, r: (f"{'CONFIRMED' if _terms(r)[5] < 190 else 'REFUTED'}"
                          f": temps {_terms(r)[5]:.0f} GB vs C1's 198 GB "
                          "(<1% — XLA was already freeing the f32 stack "
                          "under remat).  Third consecutive <5% change on "
                          "the dominant term -> C-series stops at C1."))
    except (FileNotFoundError, IndexError):
        out.append("**C4** — pending (see reports/hc_C4_*.json)\n")

    out.append("""
### Paper-faithful baseline vs beyond-paper optimized (summary)

| cell | metric (dominant lever) | paper-faithful baseline | optimized | toggle |
|---|---|---|---|---|
| rwkv6-3b × decode_32k | collective term | 14.8 ms | **0.3 ms (−98%)** | serving_replicated_params |
| rwkv6-3b × decode_32k | roofline fraction | 0.75 | **0.93** | same |
| llama3-405b × train_4k | temps GB/chip | 822 | **198 (−76%)** | seq_shard_activations |
| llama3-405b × train_4k | memory term | 245 s | **200 s (−18%)** | same |
| dsv2-lite × prefill_32k | step-sum (3 terms) | 13.6 s | **11.5 s (−16%)** | serving_replicated_params + seq_shard_activations |

Stopping criterion: each series ended after the iterations above left the
dominant term changing <5% across consecutive candidates (A3≈0%, A4 −4%;
B: converged in one; C4 ≈0% after C2/C3 regressed the dominant term).
All toggles are off by default — the recorded baseline is the
paper-faithful configuration; EXPERIMENTS reproduces either side with
`python -m repro.launch.dryrun --arch <a> --shape <s> [--opts ...]`.
""")
    return "\n".join(out)


def main() -> None:
    md = (ROOT / "EXPERIMENTS.md").read_text()
    table = roofline_table(_load("dryrun_v2.json"), "16x16")
    md = md.replace("<!-- ROOFLINE_TABLE -->", table)
    md = md.replace("<!-- PERF_SECTION -->", perf_section())
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print("EXPERIMENTS.md assembled")


if __name__ == "__main__":
    main()
