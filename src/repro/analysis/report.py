"""Render the dry-run JSON into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.analysis.report reports/dryrun.json
"""
from __future__ import annotations

import json
import sys


def _fmt_s(x: float) -> str:
    if x >= 100:
        return f"{x:.0f}"
    if x >= 1:
        return f"{x:.1f}"
    if x >= 1e-3:
        return f"{x*1e3:.1f}m"
    return f"{x*1e6:.0f}u"


def roofline_table(results: list[dict], mesh: str) -> str:
    rows = [r for r in results if r["mesh"] == mesh]
    hdr = ("| arch | shape | status | t_comp (s) | t_mem (s) | t_coll (s) | "
           "bound | roofline | useful FLOPs | temp GB/chip | args GB/chip |")
    sep = "|" + "---|" * 11
    out = [hdr, sep]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted(rows, key=lambda r: (r["arch"], order.get(r["shape"], 9))):
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | "
                       f"{r['reason']} | | | | | | | | |")
            continue
        if r["status"] == "error":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR: "
                       f"{r['error'][:60]} | | | | | | | | |")
            continue
        rl = r["roofline"]
        ma = r["memory_analysis"]
        out.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {_fmt_s(rl['t_compute'])} | {_fmt_s(rl['t_memory'])} "
            f"| {_fmt_s(rl['t_collective'])} | {rl['bottleneck']} "
            f"| {rl['roofline_fraction']:.2f} "
            f"| {min(1.0, rl['useful_flops_fraction']):.2f} "
            f"| {ma['temp_bytes']/1e9:.2f} | {ma['argument_bytes']/1e9:.2f} |")
    return "\n".join(out)


def dryrun_summary(results: list[dict]) -> str:
    out = []
    for mesh in sorted({r["mesh"] for r in results}):
        rows = [r for r in results if r["mesh"] == mesh]
        ok = sum(r["status"] == "ok" for r in rows)
        sk = sum(r["status"] == "skipped" for r in rows)
        er = sum(r["status"] == "error" for r in rows)
        out.append(f"- mesh {mesh}: {ok} compiled OK, {sk} skipped "
                   f"(assignment rules), {er} errors")
    return "\n".join(out)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "reports/dryrun.json"
    results = json.loads(open(path).read())
    print(dryrun_summary(results))
    for mesh in sorted({r["mesh"] for r in results}):
        print(f"\n### Mesh {mesh}\n")
        print(roofline_table(results, mesh))


if __name__ == "__main__":
    main()
