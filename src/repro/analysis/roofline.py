"""Roofline terms from a compiled dry-run artifact (TPU v5e targets).

    compute    = HLO_FLOPs / peak_FLOPs          (per chip: cost_analysis of
    memory     = HLO_bytes / HBM_bw               the partitioned module is
    collective = collective_bytes / link_bw       already per-device)

collective_bytes is not in cost_analysis: we parse the (post-partitioning)
HLO text and sum the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import dataclasses
import math
import re

# TPU v5e constants (per assignment)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # B/s per chip
LINK_BW = 50e9             # B/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def cost_dict(compiled) -> dict:
    """Normalized ``compiled.cost_analysis()``: newer jax returns one dict,
    older versions a one-element list of dicts."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}

# e.g.:  %all-reduce.5 = f32[128,1024]{1,0} all-reduce(...)
_OP_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s("
    + "|".join(_COLLECTIVES) + r")\(")
# tuple-result collectives:  = (f32[8,4]{1,0}, f32[8,4]{1,0}) all-reduce(
# (shape layout annotations {1,0} contain commas — match them explicitly)
_ELT = r"[a-z0-9]+\[[0-9,]*\](?:\{[0-9,]*\})?"
_TUPLE_RE = re.compile(
    r"=\s*\(((?:\s*" + _ELT + r"\s*,?)+)\)\s*("
    + "|".join(_COLLECTIVES) + r")\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind result bytes (per device, post-partitioning)."""
    out = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        out[kind] += _shape_bytes(dtype, dims)
    for m in _TUPLE_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        for sm in _SHAPE_RE.finditer(shapes):
            out[kind] += _shape_bytes(sm.group(1), sm.group(2))
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: dict
    model_flops: float          # 6*N*D (train) / 2*N_active*tokens (decode)
    bytes_per_device: int       # peak memory (memory_analysis)
    argument_bytes: int
    output_bytes: int
    temp_bytes: int

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step spent on the bound resource if perfectly
        overlapped: dominant / sum — 1.0 means the bound resource is busy
        100% of the time (ideal)."""
        total = self.t_compute + self.t_memory + self.t_collective
        if total == 0:
            return 0.0
        return max(self.t_compute, self.t_memory, self.t_collective) / total

    @property
    def useful_flops_fraction(self) -> float:
        if self.hlo_flops == 0:
            return 0.0
        return self.model_flops / self.hlo_flops

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 roofline_fraction=self.roofline_fraction,
                 useful_flops_fraction=self.useful_flops_fraction)
        return d


def model_flops_for(cfg, shape, n_params_active: int) -> float:
    """MODEL_FLOPS convention: 6 N D for training (fwd+bwd), 2 N D for
    forward-only (prefill), 2 N per token for decode."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_params_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_params_active * tokens
    return 2.0 * n_params_active * shape.global_batch  # decode: 1 new token


def analyze_compiled(compiled, *, arch: str, shape, mesh, cfg=None,
                     per_device_flops: bool = True) -> RooflineReport:
    cost = cost_dict(compiled)
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    chips = math.prod(mesh.shape.values())
    from repro.models import registry
    n_active = registry.get(cfg.family).active_param_count(cfg) if cfg else 0
    mf = model_flops_for(cfg, shape, n_active) / chips if cfg else 0.0
    return RooflineReport(
        arch=arch, shape=shape.name,
        mesh="x".join(str(v) for v in mesh.shape.values()),
        chips=chips,
        hlo_flops=float(cost.get("flops", 0.0)),
        hlo_bytes=float(cost.get("bytes accessed", 0.0)),
        coll_bytes=float(coll["total"]),
        coll_breakdown={k: v for k, v in coll.items() if k != "total"},
        model_flops=mf,
        bytes_per_device=int(mem.temp_size_in_bytes + mem.argument_size_in_bytes),
        argument_bytes=int(mem.argument_size_in_bytes),
        output_bytes=int(mem.output_size_in_bytes),
        temp_bytes=int(mem.temp_size_in_bytes),
    )
