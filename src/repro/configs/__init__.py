"""Architecture configs (the 10 assigned archs).  Importing this package
registers every config; use base.get_config(name[, smoke=True])."""
from . import (  # noqa: F401
    deepseek_v2_lite_16b,
    granite_8b,
    internvl2_26b,
    kimi_k2_1t_a32b,
    llama3_405b,
    qwen2_1_5b,
    recurrentgemma_9b,
    rwkv6_3b,
    stablelm_1_6b,
    whisper_tiny,
)
from .base import ModelConfig, get_config, list_archs  # noqa: F401
from .shapes import SHAPES, ShapeSpec, get_shape, cell_is_runnable  # noqa: F401
