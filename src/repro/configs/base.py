"""Model configuration schema + registry for the 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    group_tokens: int = 4096     # dispatch sub-group size (memory bound)


@dataclasses.dataclass(frozen=True)
class MLACfg:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    q_lora_rank: Optional[int] = None  # V2-Lite: no q compression


@dataclasses.dataclass(frozen=True)
class RWKVCfg:
    head_dim: int = 64
    decay_lora: int = 64
    mix_lora: int = 32
    gate_lora: int = 64


@dataclasses.dataclass(frozen=True)
class GriffinCfg:
    lru_width: int = 4096
    conv_width: int = 4
    window: int = 2048
    pattern: Sequence[str] = ("rec", "rec", "attn")
    lru_c: float = 8.0


@dataclasses.dataclass(frozen=True)
class EncDecCfg:
    n_enc_layers: int = 4
    n_audio_ctx: int = 1500   # Whisper frame count (stub frontend output)


@dataclasses.dataclass(frozen=True)
class VLMCfg:
    n_img_tokens: int = 1024  # stub ViT frontend output length
    img_embed_dim: Optional[int] = None  # defaults to d_model


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | rwkv | griffin | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0   # stablelm2: 0.25 partial rotary
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    mlp: str = "swiglu"          # swiglu | geglu | gelu
    tie_embeddings: bool = False
    window: Optional[int] = None
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    rwkv: Optional[RWKVCfg] = None
    griffin: Optional[GriffinCfg] = None
    encdec: Optional[EncDecCfg] = None
    vlm: Optional[VLMCfg] = None
    # numerics / memory knobs
    dtype: str = "bfloat16"
    optimizer: str = "adamw"     # adamw | adafactor (405B/1T configs)
    remat: bool = True
    q_chunk: int = 2048
    kv_chunk: int = 1024
    vocab_pad_multiple: int = 128
    # long-context capability: sub-quadratic archs run the long_500k shape
    subquadratic: bool = False
    max_train_seq: int = 4096
    # lowering knobs (dry-run cost-model shadow configs + perf tuning):
    # python-loop the layer stack instead of lax.scan (XLA cost_analysis
    # counts while bodies once; unrolled modules cost-analyze correctly)
    unroll_layers: bool = False
    # unroll time scans (RWKV wkv) — only sane for small seq shadows
    time_scan_unroll: bool = False
    # remat policy for the layer scan: "full" (recompute everything) or
    # "dots" (save matmul outputs — less recompute, more memory)
    remat_policy: str = "full"

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks), for roofline
        MODEL_FLOPS = 6 N D."""
        from repro.models import registry
        return registry.get(self.family).param_count(self)

    def active_param_count(self) -> int:
        from repro.models import registry
        return registry.get(self.family).active_param_count(self)


_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str, full: Callable[[], ModelConfig],
             smoke: Callable[[], ModelConfig]) -> None:
    _REGISTRY[name] = full
    _SMOKE[name] = smoke


def get_config(name: str, *, smoke: bool = False) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers per-arch registration)
    table = _SMOKE if smoke else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]()


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
