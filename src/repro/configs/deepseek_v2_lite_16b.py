"""DeepSeek-V2-Lite 16B [arXiv:2405.04434; hf].
27L d_model=2048 16H d_ff=1408(expert) vocab=102400, MLA kv_lora=512,
2 shared + 64 routed experts top-6 (the "160 routed" in the pool line is the
full-V2 figure; 64 is the Lite config — see DESIGN.md)."""
from .base import MLACfg, ModelConfig, MoECfg, register


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=1408, vocab_size=102400,
        moe=MoECfg(n_experts=64, top_k=6, d_expert=1408, n_shared=2),
        mla=MLACfg(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                   v_head_dim=128, q_lora_rank=None),
        rope_theta=10000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=64, vocab_size=512,
        moe=MoECfg(n_experts=8, top_k=2, d_expert=64, n_shared=1,
                   capacity_factor=2.0, group_tokens=64),
        mla=MLACfg(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                   v_head_dim=16, q_lora_rank=None),
        dtype="float32", remat=False, q_chunk=32, kv_chunk=16,
    )


register("deepseek-v2-lite-16b", full, smoke)
