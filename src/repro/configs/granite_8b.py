"""Granite-8B (code) [arXiv:2405.04324; hf].
36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152; llama-arch."""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-8b", family="dense",
        n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=49152,
        rope_theta=10000000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-8b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
        dtype="float32", remat=False, q_chunk=32, kv_chunk=16,
    )


register("granite-8b", full, smoke)
