"""InternVL2-26B [arXiv:2404.16821; hf] — InternViT + InternLM2 backbone.
48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553; ViT frontend is a
STUB (precomputed patch embeddings)."""
from .base import ModelConfig, VLMCfg, register


def full() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b", family="vlm",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=16384, vocab_size=92553,
        vlm=VLMCfg(n_img_tokens=1024, img_embed_dim=3200),  # InternViT-6B width
        rope_theta=1000000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
        vlm=VLMCfg(n_img_tokens=8, img_embed_dim=32),
        dtype="float32", remat=False, q_chunk=32, kv_chunk=16,
    )


register("internvl2-26b", full, smoke)
