"""Kimi K2 — trillion-param MoE [arXiv:2501.kimi2; unverified].
61L d_model=7168 64H (GQA kv=8) d_ff=2048(expert) vocab=163840, MoE 384e top-8.
Assignment spec followed as given (GQA, not MLA); +1 shared expert per the
published K2 config."""
from .base import ModelConfig, MoECfg, register


def full() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
        d_ff=2048, vocab_size=163840,
        moe=MoECfg(n_experts=384, top_k=8, d_expert=2048, n_shared=1),
        rope_theta=50000.0, optimizer="adafactor",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab_size=512,
        moe=MoECfg(n_experts=8, top_k=2, d_expert=96, n_shared=1,
                   capacity_factor=2.0, group_tokens=64),
        dtype="float32", remat=False, q_chunk=32, kv_chunk=16,
    )


register("kimi-k2-1t-a32b", full, smoke)
