"""Llama-3 405B [arXiv:2407.21783; unverified].
126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256."""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b", family="dense",
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, head_dim=128,
        d_ff=53248, vocab_size=128256,
        rope_theta=500000.0, optimizer="adafactor",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=192, vocab_size=512,
        dtype="float32", remat=False, q_chunk=32, kv_chunk=16,
    )


register("llama3-405b", full, smoke)
