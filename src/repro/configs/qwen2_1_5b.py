"""Qwen2-1.5B [arXiv:2407.10671; hf].
28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936; QKV bias."""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b", family="dense",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
        d_ff=8960, vocab_size=151936,
        qkv_bias=True, rope_theta=1000000.0, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
        qkv_bias=True, dtype="float32", remat=False, q_chunk=32, kv_chunk=16,
    )


register("qwen2-1.5b", full, smoke)
