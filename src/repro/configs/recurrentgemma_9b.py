"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427; unverified].
38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000; RG-LRU + local
attention (window 2048), pattern rec,rec,attn (1 attn : 2 recurrent).
Sub-quadratic: runs the long_500k shape."""
from .base import GriffinCfg, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="griffin",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
        d_ff=12288, vocab_size=256000,
        mlp="geglu",
        griffin=GriffinCfg(lru_width=4096, conv_width=4, window=2048),
        subquadratic=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-smoke", family="griffin",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512,
        mlp="geglu",
        griffin=GriffinCfg(lru_width=64, conv_width=4, window=16),
        subquadratic=True,
        dtype="float32", remat=False, q_chunk=32, kv_chunk=16,
    )


register("recurrentgemma-9b", full, smoke)
