"""RWKV-6 "Finch" 3B [arXiv:2404.05892; hf].
32L d_model=2560 (attention-free, 40 heads x 64) d_ff=8960 vocab=65536.
Sub-quadratic: runs the long_500k shape (O(1) state decode)."""
from .base import ModelConfig, RWKVCfg, register


def full() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", family="rwkv",
        n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
        d_ff=8960, vocab_size=65536,
        rwkv=RWKVCfg(head_dim=64, decay_lora=64, mix_lora=32),
        subquadratic=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b-smoke", family="rwkv",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512,
        rwkv=RWKVCfg(head_dim=16, decay_lora=8, mix_lora=8),
        subquadratic=True,
        dtype="float32", remat=False, q_chunk=32, kv_chunk=16,
    )


register("rwkv6-3b", full, smoke)
