"""Assigned input shapes (one set, shared by all 10 LM-family archs)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k":    ShapeSpec("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeSpec("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeSpec("long_500k",   524_288, 1,   "decode"),
}


def get_shape(name: str) -> ShapeSpec:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; have {sorted(SHAPES)}")
    return SHAPES[name]


def cell_is_runnable(cfg, shape: ShapeSpec) -> tuple[bool, str]:
    """Implements the assignment's skip rules: long_500k needs sub-quadratic
    attention (SSM / hybrid archs only)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "skipped(full-attention arch; long_500k needs sub-quadratic)"
    return True, ""
