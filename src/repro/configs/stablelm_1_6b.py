"""StableLM-2 1.6B [hf:stabilityai/stablelm-2-1_6b; unverified].
24L d_model=2048 32H (MHA kv=32) d_ff=5632 vocab=100352; LayerNorm, partial
rotary (25%)."""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b", family="dense",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=5632, vocab_size=100352,
        norm="layernorm", rope_fraction=0.25, rope_theta=10000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512,
        norm="layernorm", rope_fraction=0.25,
        dtype="float32", remat=False, q_chunk=32, kv_chunk=16,
    )


register("stablelm-1.6b", full, smoke)
