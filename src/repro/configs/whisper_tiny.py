"""Whisper-tiny [arXiv:2212.04356; unverified].
4L enc + 4L dec, d_model=384 6H d_ff=1536 vocab=51865; conv audio frontend is
a STUB (precomputed frame embeddings)."""
from .base import EncDecCfg, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny", family="encdec",
        n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
        d_ff=1536, vocab_size=51865,
        norm="layernorm", mlp="gelu", qkv_bias=True,
        encdec=EncDecCfg(n_enc_layers=4, n_audio_ctx=1500),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny-smoke", family="encdec",
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=512,
        norm="layernorm", mlp="gelu", qkv_bias=True,
        encdec=EncDecCfg(n_enc_layers=2, n_audio_ctx=16),
        dtype="float32", remat=False, q_chunk=32, kv_chunk=16,
    )


register("whisper-tiny", full, smoke)
