"""The paper's primary contribution: native mode-oblivious TVC, distributed
TVC (dTVC), the three-buffer distributed higher-order power method (dHOPM_3),
the streamed-memory model, 1-D optimal splitting, and mixed precision."""
from .mixed_precision import F32, BF16_F32, F16_F32, Precision, get_policy  # noqa: F401
from .splitting import SplitPlan, best_split_dim, optimal_division, plan_split  # noqa: F401
from .tvc import (  # noqa: F401
    tvc, tvc2, tvc2_bytes, tvc_bytes, tvc_chain, tvc_shape, mode_uv,
    tvc_batched, tvc2_batched,
)
from . import memory_model  # noqa: F401
from .arena import BatchedArena, assemble_rows  # noqa: F401
