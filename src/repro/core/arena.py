"""Donation-aware batched-operand arena: zero-copy bucket streaming.

Both production consumers of :func:`repro.core.dhopm.hopm3_batched` assemble
their ``[B, ...]`` operands from B same-view members on every step —
``train.grad_compress`` stacks gradient+error-feedback rows per bucket, the
serve engine stacks retired KV contexts per retirement event.  ``jnp.stack``
prices that assembly at a full extra round trip of the bucket: the B member
rows are materialized, read back, and written into a *freshly allocated*
stacked buffer (then the results are sliced back out).  The paper's whole
thesis is that these chains are streamed-memory bound, so the assembly copy
is pure overhead — 2·B·prod(view) elements per event that
:func:`repro.core.memory_model.bucket_stack_elems` now prices in closed form.

The arena removes it two ways, sharing one layout:

* **Eager consumers** (the serve engine's retirement groups) hold a
  persistent :class:`BatchedArena`: one ``[B, *view]`` buffer per
  ``(tag, B, view, dtype)`` key, *donated* into a jitted scatter fill
  (``donate_argnums=(0,)`` + ``buf.at[i].set(row)``) on every event.  The
  fill program reads each member straight from its source (a cache row, an
  init-factor vector) and writes it into the arena row in place — no fresh
  allocation, no intermediate stacked copy, no ``concatenate`` primitive in
  the jaxpr.  A warm fill therefore costs zero copy elements beyond the row
  materialization the stacked path also pays
  (:func:`repro.core.memory_model.arena_fill_elems`); only a cold
  (first-allocation) fill behaves like one stack.

* **Traced consumers** (``grad_compress`` inside shard_map) can't hold
  Python-side buffers, but :func:`assemble_rows` gives them the same
  discipline in-trace: a ``dynamic_update_slice`` chain instead of a
  ``concatenate``, so a whole-step donation (the train step donates its
  gradient/compressor state) lets XLA write the bucket rows in place
  instead of materializing rows *and* a stacked copy of them.

Keys are exact ``(B, view)`` shapes — the same
:func:`repro.core.bucketing.tensor_view` rule both consumers bucket under —
so a buffer is bitwise-interchangeable with the ``jnp.stack`` it replaces:
same values in the same rows, hence identical ``hopm3_batched`` iterates
under the order-explicit ``mulsum`` engine.  Shape-churn regimes (every
event a new ``(B, view)`` key) would turn every fill cold; the arena caps
its key table at ``max_keys`` and refuses new keys past it
(:meth:`BatchedArena.acquire` returns ``None`` → the caller stacks), and
:func:`repro.plan.planner.plan_compress` keeps the stack path for singleton
buckets and caller-declared churn.

Donation invariant: the arena owns the ONLY live reference to each buffer
between fills.  Consumers may pass the filled buffer into non-donating
computations (the chain launch) and keep slices *of the chain outputs*, but
must never retain the buffer itself — the next fill donates it.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from . import memory_model as mm

__all__ = ["BatchedArena", "assemble_rows"]


def assemble_rows(rows, dtype=None):
    """In-trace arena fill: build a ``[B, *view]`` operand from B same-shape
    rows with a ``buf.at[i].set(row)`` scatter chain — value-identical to
    ``jnp.stack(rows)`` but with no ``concatenate`` primitive in the jaxpr,
    so under a whole-program donation XLA updates the destination rows in
    place instead of materializing the members and a fresh stacked copy of
    them."""
    rows = list(rows)
    b = len(rows)
    if b == 0:
        raise ValueError("assemble_rows needs at least one row")
    dt = jnp.dtype(dtype) if dtype is not None else jnp.result_type(rows[0])
    buf = jnp.zeros((b,) + tuple(rows[0].shape), dt)
    for i, r in enumerate(rows):
        buf = buf.at[i].set(r.astype(dt))
    return buf


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_rows(buf, *rows):
    """Donated row scatter: one in-place ``dynamic_update_slice`` per row on
    the persistent buffer (retraced per (B, view, dtype) key — exactly the
    arena's key granularity)."""
    for i, r in enumerate(rows):
        buf = buf.at[i].set(r.astype(buf.dtype))
    return buf


@dataclasses.dataclass
class ArenaStats:
    """Fill accounting — what the bench cells and serve stats record."""
    fills: int = 0
    cold_fills: int = 0
    stack_fallbacks: int = 0          # key-table full → caller stacked
    stack_copy_removed_bytes: int = 0
    fill_events: list = dataclasses.field(default_factory=list)
    #   one [b, view, cold] entry per fill (cold: 0/1) — check_bench
    #   recomputes stack_copy_removed_bytes from these verbatim


class BatchedArena:
    """Persistent donated ``[B, *view]`` operand/residual/factor buffers,
    keyed by ``(tag, B, tensor_view, dtype)``."""

    def __init__(self, max_keys: int = 64):
        self.max_keys = max_keys
        self._bufs: dict[tuple, jax.Array] = {}
        self.stats = ArenaStats()

    def __len__(self) -> int:
        return len(self._bufs)

    @staticmethod
    def _key(tag, b, view, dtype) -> tuple:
        return (tag, int(b), tuple(view), jnp.dtype(dtype).name)

    def acquire(self, tag, b: int, view, dtype):
        """``(buf, cold)`` — the persistent ``[b, *view]`` buffer for this
        key (freshly zero-allocated on a cold miss), or ``(None, False)``
        when the key table is full and the key is new (shape churn: the
        caller should take the stack path; recorded as a fallback).  The
        caller MUST donate ``buf`` into its fill and hand the filled buffer
        back via :meth:`commit` — after ``acquire`` the arena's stored
        reference is dropped (donation invalidates it)."""
        key = self._key(tag, b, view, dtype)
        buf = self._bufs.pop(key, None)
        if buf is not None:
            return buf, False
        if len(self._bufs) >= self.max_keys:
            self.stats.stack_fallbacks += 1
            return None, False
        return jnp.zeros((int(b),) + tuple(view), jnp.dtype(dtype)), True

    def commit(self, tag, b: int, view, dtype, buf, *, cold: bool,
               itemsize: int | None = None, ranks: int = 1,
               account: bool = True) -> None:
        """Store the filled buffer back and account the removed stack copy
        (:func:`~repro.core.memory_model.bucket_stack_elems` minus the
        fill's own :func:`~repro.core.memory_model.arena_fill_elems`).
        ``account=False`` stores without recording a fill event — for
        auxiliary buffers (a group's factor stacks) whose removal is already
        priced by the group's operand event via the ``ranks`` term."""
        self._bufs[self._key(tag, b, view, dtype)] = buf
        if not account:
            return
        isz = itemsize if itemsize is not None else jnp.dtype(dtype).itemsize
        self.stats.fills += 1
        self.stats.cold_fills += int(cold)
        self.stats.fill_events.append([int(b), list(view), int(cold)])
        self.stats.stack_copy_removed_bytes += (
            mm.bucket_stack_elems(b, view, ranks=ranks)
            - mm.arena_fill_elems(b, view, ranks=ranks, cold=cold)) * isz

    def fill_rows(self, tag, rows, *, dtype=None, ranks: int = 1,
                  account: bool = True):
        """Fill (or cold-allocate) the key's buffer from B already-
        materialized rows via the donated scatter.  Returns the filled
        ``[B, *view]`` buffer, or ``None`` on a key-table-full miss (caller
        stacks).  Bitwise-identical content to ``jnp.stack(rows)``."""
        rows = list(rows)
        dt = jnp.dtype(dtype) if dtype is not None \
            else jnp.result_type(rows[0])
        view = tuple(rows[0].shape)
        buf, cold = self.acquire(tag, len(rows), view, dt)
        if buf is None:
            return None
        buf = _scatter_rows(buf, *rows)
        self.commit(tag, len(rows), view, dt, buf, cold=cold, ranks=ranks,
                    account=account)
        return buf

    def reset(self) -> None:
        self._bufs.clear()
        self.stats = ArenaStats()

    def nbytes(self) -> int:
        """Resident arena footprint (all keys)."""
        return sum(math.prod(k[2]) * k[1] * jnp.dtype(k[3]).itemsize
                   for k in self._bufs)
