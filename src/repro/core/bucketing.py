"""Shape-bucketing helpers shared by the gradient compressor and the serve
engine's KV-cache compression path.

Both consumers face the same problem: a stream of low-rank compression jobs
over tensors of assorted shapes, where every group of SAME-view jobs can be
stacked and run through ONE :func:`repro.core.dhopm.hopm3_batched` chain per
step (launch count independent of the group size) instead of one chain per
job.  The two ingredients that make the groups line up live here:

* :func:`tensor_view` — flatten leading dims so the order drops to a fixed
  maximum while the trailing (low-rank-carrying) dims stay intact; lifted
  verbatim from ``train.grad_compress._tensor_view`` so gradient leaves and
  KV contexts bucket under the exact same rule.
* :func:`pad_extent` — round a ragged extent (a request's context length) up
  to a quantum so near-miss shapes land in the same bucket.  Zero-padding a
  mode is EXACT for the HOPM chains: the padded slab contributes ``+ 0.0``
  terms to every contraction (and the factor entries over the pad region of
  a zero slab stay exactly what the zero-input reduction produces), so the
  unpadded iterates are recovered by slicing — no approximation is
  introduced, only bucket alignment.
* :func:`group_indices` — order-preserving key -> indices grouping (the
  bucket map both consumers iterate).
"""
from __future__ import annotations

import math

__all__ = ["tensor_view", "pad_extent", "group_indices"]


def tensor_view(shape, max_order: int):
    """Flatten leading dims so order <= ``max_order`` (keeps the trailing
    matmul dims intact: those carry the low-rank structure)."""
    if len(shape) <= max_order:
        return tuple(shape)
    lead = math.prod(shape[: len(shape) - max_order + 1])
    return (lead,) + tuple(shape[len(shape) - max_order + 1:])


def pad_extent(n: int, quantum: int, cap: int | None = None) -> int:
    """``n`` rounded up to a multiple of ``quantum`` (optionally clamped to
    ``cap`` — e.g. the allocated KV timeline): the bucket-aligned extent a
    ragged mode is zero-padded to."""
    if quantum < 1:
        raise ValueError(f"quantum must be >= 1, got {quantum}")
    padded = -(-n // quantum) * quantum
    return min(padded, cap) if cap is not None else padded


def group_indices(keys) -> dict:
    """Order-preserving ``key -> [indices]`` map over an iterable of
    hashable bucket keys (first-seen key order, ascending indices — the
    deterministic iteration order both bucketed compressors rely on)."""
    groups: dict = {}
    for i, key in enumerate(keys):
        groups.setdefault(key, []).append(i)
    return groups
