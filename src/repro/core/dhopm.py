"""Higher-order power method — sequential HOPM and the paper's dHOPM_3
(Algorithm 1): three buffers, (d-1)(d-2)/2 skipped contractions per sweep,
and *delayed* collective reduction (only the final n_j-sized vector is
reduced/gathered per external iteration).

One chain walker implements every variant:

* ``hopm_classic`` — canonical two-buffer HOPM (Pawlowski et al. baseline);
* ``hopm3``        — sequential three-buffer variant (identical iterates,
  fewer contractions: the prefix cache W);
* ``dhopm3``       — the distributed version over a named mesh axis with 1-D
  tensor splitting (the paper's headline algorithm);
* ``hopm3_partial``— runs on *partial summands* (each process holds one
  addend of the global tensor, the implicit Eq. 2 decomposition) — this is
  the engine of HOPM gradient compression in repro.train.grad_compress.

All iterates are mathematically identical across variants (Gauss–Seidel HOPM
with freshest vectors), so cross-variant allclose is a correctness oracle.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.dist import collectives as coll
from .dtvc import (
    ShardState,
    dtvc2_local,
    dtvc2_local_batched,
    dtvc_local,
    dtvc_local_batched,
)
from .mixed_precision import F32, Precision, get_policy
from .tvc import _tree_sum_last

__all__ = [
    "hopm_classic", "hopm3", "dhopm3", "hopm3_partial", "hopm3_sharded",
    "hopm3_batched", "dhopm3_batched", "rank1", "rank1_residual",
    "hopm_init_factors", "OVERLAP_CHUNKS_DEFAULT",
]

_EPS = 1e-30

#: default chunk count of the pipelined (``overlap=True``) chain tail: the
#: delayed reduction of chunk c rides behind the contraction launch of chunk
#: c+1.  log2(p)+1 chunks fully drain a doubling reduction inside the tail
#: at p = 8; 4 is that sweet spot and keeps per-chunk launches coarse.
OVERLAP_CHUNKS_DEFAULT = 4


def _overlap_chunks(overlap) -> int:
    """Normalize the public ``overlap`` knob (False | True | int >= 1) to a
    chunk count; 1 means the synchronous walker."""
    if overlap is False or overlap is None:
        return 1
    if overlap is True:
        return OVERLAP_CHUNKS_DEFAULT
    c = int(overlap)
    if c != overlap or c < 1:
        raise ValueError(
            f"overlap must be False, True, or an int >= 1, got {overlap!r}")
    return c


def _resolve_walker(impl, fuse_pairs, overlap, *, shape, p, s, batch=1,
                    prec):
    """Route ``impl="auto"`` (and any fuse/overlap flag left at None)
    through the trace-time planner; explicit flags always win.  With a
    concrete impl, None flags resolve to the legacy static defaults
    (fuse_pairs=False, overlap=False)."""
    from repro.plan import planner as _planner
    return _planner.resolve_dhopm(
        impl, fuse_pairs, overlap, shape=tuple(shape), p=p, s=s,
        batch=batch, itemsize=prec.storage_bytes)


def _norm(v, compute):
    v = v.astype(compute)
    return jnp.sqrt(_tree_sum_last(v * v) + _EPS)


def _norm_batched(v, compute):
    """Per-batch-row norms of a (B, n) stack — literally the same
    elementwise add tree per row as :func:`_norm` on each row alone (the
    batched/per-leaf bitwise oracles depend on that; see
    :func:`repro.core.tvc._tree_sum_last` for why ``jnp.sum`` cannot give
    it)."""
    v = v.astype(compute)
    return jnp.sqrt(_tree_sum_last(v * v) + _EPS)


def _hopm_sweeps(
    A_loc: jax.Array,
    xs: Sequence[jax.Array],
    *,
    sweeps: int,
    split: int | None,
    partial_in: bool,
    axis_name: str | None,
    impl: str,
    prec: Precision,
    three_buffer: bool,
    fuse_pairs: bool = False,
    overlap=False,
):
    """Chain walker on one shard.  Mode ids are global; local axes are looked
    up through each intermediate's `modes` tuple.  Returns (xs, lambda).

    ``fuse_pairs`` (beyond-paper): contract adjacent-mode pairs in ONE
    streaming pass (tvc2), skipping the order-(d-1) intermediate — except at
    the W-cache boundary (which must materialize) and at the split mode
    (which needs the Eq. 2 slice path).  With ``impl="pallas"`` both the
    single and the fused contractions run through the zero-copy ragged
    kernels, so the ever-shrinking (and never block-multiple) chain
    intermediates stream without padding copies.

    ``overlap`` (the paper's §6 task-based overlap, bitwise-safe form):
    pipeline each external iteration's chain *tail* — the contraction that
    produces the delayed-reduction payload.  The Gauss–Seidel dependency
    pins everything else (xs[j] feeds iteration j+1's FIRST launch), so the
    only overlap window that cannot reorder a single rounding is *inside*
    the tail: chunk it along the output mode j, and walk chunk c's staged
    reduction one ppermute hop per subsequent chunk launch
    (:class:`~repro.dist.collectives.StagedAllreduce`).  Chunking the output
    dim leaves every element's contraction arithmetic untouched, and
    doubling hops are elementwise, so per-chunk reduction == whole-vector
    reduction bitwise.  The pipeline therefore only engages in the doubling
    regime (ring's chunk layout is payload-size-dependent) and drains to the
    synchronous path at the j == split all-gather boundary.  To keep sync
    and overlap hop-for-hop identical, *both* modes run the delayed Σ with
    ``force_schedule`` explicit doubling hops instead of ``lax.psum``.

    NOTE: :func:`_hopm_sweeps_batched` mirrors this schedule for stacked
    batches — keep the two walkers' predicates in lockstep."""
    d = A_loc.ndim
    xs = list(xs)
    st0 = ShardState(split=split, partial=partial_in)
    A_modes = tuple(range(d))
    lam = jnp.asarray(1.0, prec.compute)
    W = None  # (array, modes, state): A contracted along 0..j-1
    chunks = _overlap_chunks(overlap)
    p = coll._axis_size(axis_name) if axis_name is not None else 1

    for _ in range(sweeps):
        W = None  # vectors change every sweep; cache is intra-sweep only
        for j in range(d):
            if three_buffer and j >= 2 and W is not None:
                cur, modes, st = W
                chain = [j - 1] + list(range(j + 1, d))
            else:
                cur, modes, st = A_loc, A_modes, st0
                chain = [m for m in range(d) if m != j]

            new_W = None
            idx = 0
            vec = None  # set by the pipelined tail; else the sync path below
            while idx < len(chain):
                m = chain[idx]
                nxt = chain[idx + 1] if idx + 1 < len(chain) else None
                k_local = modes.index(m)
                hit_m = st.split is not None and k_local == st.split
                do_fuse = fuse_pairs and nxt == m + 1 and not hit_m
                if do_fuse:
                    hit_n = st.split is not None and modes.index(nxt) == st.split
                    done_after_first = (set(range(d)) - set(modes)) | {m}
                    captures_W = (three_buffer and j >= 1
                                  and done_after_first == set(range(j)))
                    do_fuse = not hit_n and not captures_W
                consumed = 2 if do_fuse else 1
                if chunks > 1 and idx + consumed == len(chain):
                    # Chain tail.  After it the iteration ends in a gather
                    # (j == split), a delayed Σ (partial / split consumed),
                    # or nothing (sequential p = 1) — pipeline the Σ/nothing
                    # cases in the doubling regime, drain at the gather.
                    gather_end = st.split is not None and not hit_m
                    reduce_end = st.partial or hit_m
                    out_ax = modes.index(j)
                    n_out = cur.shape[out_ax]
                    C = min(chunks, n_out)
                    algo = coll.allreduce_algo(n_out, p)
                    if C > 1 and not gather_end and \
                            (not reduce_end or algo == "doubling"):
                        # balanced chunk sizes: exactly C launches for
                        # any n_out >= C (the launch model counts on it)
                        base, rem = divmod(n_out, C)
                        raw = []       # pre-reduction chunks (W capture)
                        inflight = []  # staged per-chunk reductions
                        lo = 0
                        for c in range(C):
                            sz = base + (1 if c < rem else 0)
                            if do_fuse:
                                out_c, st_c = dtvc2_local(
                                    cur, xs[m], k_local, xs[nxt], st,
                                    impl=impl, prec=prec,
                                    rows=(out_ax, lo, sz))
                            else:
                                out_c, st_c = dtvc_local(
                                    cur, xs[m], k_local, st,
                                    axis_name=axis_name, impl=impl,
                                    prec=prec, rows=(out_ax, lo, sz))
                            raw.append(out_c)
                            # one wire hop per in-flight reduction per chunk
                            # launch: hop c-1 has no dependence on launch c,
                            # so the scheduler may put the wire behind the
                            # compute (program order states the intent)
                            inflight = [op.step() for op in inflight]
                            if reduce_end:
                                inflight.append(coll.staged_allreduce(
                                    out_c, axis_name, prec, algo=algo))
                            lo += sz
                        vec = (jnp.concatenate([op.drain() for op in inflight])
                               if reduce_end else jnp.concatenate(raw))
                        st = st_c
                        modes = (j,)
                        idx += consumed
                        if three_buffer and j >= 1 and \
                                set(range(d)) - set(modes) == set(range(j)):
                            # tail-position capture (j == d-1 only; the cache
                            # dies at the sweep boundary before reuse)
                            new_W = (vec if not reduce_end
                                     else jnp.concatenate(raw), modes, st)
                        continue
                if do_fuse:
                    # ONE launch for the adjacent pair (single-launch Pallas
                    # kernel under impl="pallas", incl. the chain tail)
                    cur, st = dtvc2_local(cur, xs[m], k_local, xs[nxt], st,
                                          impl=impl, prec=prec)
                    modes = tuple(mm for mm in modes if mm not in (m, nxt))
                    idx += 2
                else:
                    cur, st = dtvc_local(
                        cur, xs[m], k_local, st, axis_name=axis_name,
                        impl=impl, prec=prec,
                    )
                    modes = tuple(mm for mm in modes if mm != m)
                    idx += 1
                if three_buffer and j >= 1 and \
                        set(range(d)) - set(modes) == set(range(j)):
                    new_W = (cur, modes, st)
            if three_buffer:
                W = new_W if new_W is not None else W

            # Delayed reduction (Algorithm 1 lines 13-16): one small
            # collective — unless the pipelined tail already reduced it.
            # The Σ runs the schedule-explicit doubling hops (not psum) in
            # the doubling regime so the sync and overlap walkers share
            # hop-for-hop arithmetic (see mp_allreduce force_schedule).
            if vec is None:
                vec = cur
                if st.partial:
                    algo = coll.allreduce_algo(vec.shape[-1], p)
                    vec = coll.mp_allreduce(                         # Σ_p
                        vec, axis_name, prec, algo=algo,
                        force_schedule=(algo == "doubling"))
                elif st.split is not None:
                    vec = coll.all_gather_tiled(vec, axis_name, axis=0)  # ⊔_p
            # The barrier pins the external-iteration boundary: without it
            # XLA may fuse the reduction/normalization into its producers
            # differently in the batched and per-sample programs, drifting
            # the last bit — the cross-walker bitwise oracle (and the
            # bucketed-vs-per-leaf grad_compress guarantee) depends on both
            # walkers normalizing an identically-isolated vector.
            vec = lax.optimization_barrier(vec)
            lam = _norm(vec, prec.compute)
            xs[j] = lax.optimization_barrier(
                (vec.astype(prec.compute) / lam).astype(prec.storage))
    return xs, lam


def hopm_classic(A, xs, *, sweeps: int = 1, impl: str = "native",
                 prec: Precision | str = F32):
    """Canonical two-buffer sequential HOPM (restarts every chain from A)."""
    prec = get_policy(prec)
    impl, _, _ = _resolve_walker(impl, False, False, shape=A.shape, p=1,
                                 s=None, prec=prec)
    return _hopm_sweeps(
        A, xs, sweeps=sweeps, split=None, partial_in=False, axis_name=None,
        impl=impl, prec=prec, three_buffer=False,
    )


def hopm3(A, xs, *, sweeps: int = 1, impl: str = "native",
          prec: Precision | str = F32, fuse_pairs: bool | None = None,
          overlap=None):
    """Sequential dHOPM_3 (p = 1): the three-buffer contraction schedule.
    ``overlap`` chunks the chain tails exactly like the distributed walker
    (no wire to hide at p = 1, but identical launches/iterates — the
    sync-vs-pipelined bench baseline).  ``impl="auto"`` plans the engine
    (and any fuse/overlap flag left at None) from the cost model."""
    prec = get_policy(prec)
    impl, fuse_pairs, overlap = _resolve_walker(
        impl, fuse_pairs, overlap, shape=A.shape, p=1, s=None, prec=prec)
    return _hopm_sweeps(
        A, xs, sweeps=sweeps, split=None, partial_in=False, axis_name=None,
        impl=impl, prec=prec, three_buffer=True, fuse_pairs=fuse_pairs,
        overlap=overlap,
    )


def hopm3_partial(A_partial, xs, *, axis_name: str, sweeps: int = 1,
                  impl: str = "native", prec: Precision | str = F32,
                  three_buffer: bool = True, fuse_pairs: bool | None = None,
                  overlap=None):
    """dHOPM_3 over the *implicit sum* decomposition: each process holds one
    full-shape addend A^{(p)} with A = Σ_p A^{(p)} (the k = s case of Eq. 2
    for every chain).  Must run inside a shard_map manual region over
    ``axis_name``.  Communication: one n_j all-reduce per external iteration."""
    prec = get_policy(prec)
    impl, fuse_pairs, overlap = _resolve_walker(
        impl, fuse_pairs, overlap, shape=A_partial.shape,
        p=coll._axis_size(axis_name), s=None, prec=prec)
    return _hopm_sweeps(
        A_partial, xs, sweeps=sweeps, split=None, partial_in=True,
        axis_name=axis_name, impl=impl, prec=prec, three_buffer=three_buffer,
        fuse_pairs=fuse_pairs, overlap=overlap,
    )


def _hopm_sweeps_batched(
    A_b: jax.Array,
    xs: Sequence[jax.Array],
    *,
    sweeps: int,
    split: int | None,
    partial_in: bool,
    axis_name: str | None,
    impl: str,
    prec: Precision,
    fuse_pairs: bool = False,
    overlap=False,
):
    """The three-buffer chain walker over a stacked batch ``A_b[B, n_0..]``
    of independent same-shape tensors (or shards): identical schedule to
    :func:`_hopm_sweeps` (three buffers, W prefix cache, optional fused
    pairs, 1-D split state machine), but every contraction is ONE *batched*
    TVC — with ``impl="pallas"`` one kernel launch per chain position covers
    all B tensors, so a sweep's launch count is independent of B.

    ``split`` is the per-sample 1-D split dim of Algorithm 1 (each process
    holds B stacked same-shape slices of B global tensors): the split-mode
    chain takes the Eq. 2 slice path (one stacked ``dynamic_slice`` of the
    per-batch vectors), split/partial liveness rides the same
    :class:`~repro.core.dtvc.ShardState` machine as the unbatched walker —
    including the W-cache boundary — and the delayed reduction per external
    iteration is ONE stacked collective: ``mp_allreduce`` when the chain
    consumed the split (or for ``partial_in`` Eq. 2 summands), a tiled
    all-gather of the ``(B, n_j/p)`` stack when iteration j *is* the split.
    Reduction algos are dispatched on the **per-leaf** vector size n_j, not
    B * n_j, so the wire schedule (and its rounding behaviour) matches B
    separate per-leaf reductions.  ``overlap`` pipelines the chain tail
    exactly like :func:`_hopm_sweeps` (chunked along the per-sample output
    mode; staged stacked reductions — doubling hops on a ``(B, chunk)``
    stack are elementwise, so stacking preserves the per-leaf bitwise
    guarantee).  Returns (xs[B, n_j] list, lam[B]).

    NOTE: the chain schedule below (three buffers, W capture, fused-pair /
    split gating) deliberately mirrors :func:`_hopm_sweeps`; a change to
    either walker's schedule predicates must be mirrored in the other —
    ``test_hopm3_batched_matches_vmap_hopm3``, the dhopm3_batched bitwise
    dist checks, and the grad_compress bitwise regressions are the drift
    canaries."""
    d = A_b.ndim - 1
    xs = list(xs)
    st0 = ShardState(split=split, partial=partial_in)
    A_modes = tuple(range(d))
    B = A_b.shape[0]
    lam = jnp.ones((B,), prec.compute)
    W = None  # (array, modes, state): A_b contracted along 0..j-1
    chunks = _overlap_chunks(overlap)

    p = None
    if partial_in or split is not None:
        if axis_name is None:
            raise ValueError(
                "partial summands / a 1-D split need a mesh axis to reduce")
        p = coll._axis_size(axis_name)

    for _ in range(sweeps):
        W = None
        for j in range(d):
            if j >= 2 and W is not None:
                cur, modes, st = W
                chain = [j - 1] + list(range(j + 1, d))
            else:
                cur, modes, st = A_b, A_modes, st0
                chain = [m for m in range(d) if m != j]

            new_W = None
            idx = 0
            vec = None  # set by the pipelined tail; else the sync path below
            while idx < len(chain):
                m = chain[idx]
                nxt = chain[idx + 1] if idx + 1 < len(chain) else None
                k_local = modes.index(m)
                hit_m = st.split is not None and k_local == st.split
                do_fuse = fuse_pairs and nxt == m + 1 and not hit_m
                if do_fuse:
                    hit_n = st.split is not None and \
                        modes.index(nxt) == st.split
                    done_after_first = (set(range(d)) - set(modes)) | {m}
                    captures_W = j >= 1 and done_after_first == set(range(j))
                    do_fuse = not hit_n and not captures_W
                consumed = 2 if do_fuse else 1
                if chunks > 1 and idx + consumed == len(chain):
                    # Pipelined chain tail — the batched mirror of
                    # _hopm_sweeps: chunk along the per-sample output mode,
                    # stage each (B, chunk) stack's doubling reduction one
                    # hop per subsequent chunk launch, drain at the gather.
                    gather_end = st.split is not None and not hit_m
                    reduce_end = st.partial or hit_m
                    out_ax = modes.index(j)
                    n_out = cur.shape[out_ax + 1]
                    C = min(chunks, n_out)
                    algo = coll.allreduce_algo(n_out, p or 1)
                    if C > 1 and not gather_end and \
                            (not reduce_end or algo == "doubling"):
                        # balanced chunk sizes: exactly C launches for
                        # any n_out >= C (the launch model counts on it)
                        base, rem = divmod(n_out, C)
                        raw = []       # pre-reduction chunks (W capture)
                        inflight = []  # staged per-chunk stacked reductions
                        lo = 0
                        for c in range(C):
                            sz = base + (1 if c < rem else 0)
                            if do_fuse:
                                out_c, st_c = dtvc2_local_batched(
                                    cur, xs[m], k_local, xs[nxt], st,
                                    impl=impl, prec=prec,
                                    rows=(out_ax, lo, sz))
                            else:
                                out_c, st_c = dtvc_local_batched(
                                    cur, xs[m], k_local, st,
                                    axis_name=axis_name, impl=impl,
                                    prec=prec, rows=(out_ax, lo, sz))
                            raw.append(out_c)
                            inflight = [op.step() for op in inflight]
                            if reduce_end:
                                inflight.append(coll.staged_allreduce(
                                    out_c, axis_name, prec, algo=algo))
                            lo += sz
                        vec = (jnp.concatenate(
                                   [op.drain() for op in inflight], axis=1)
                               if reduce_end
                               else jnp.concatenate(raw, axis=1))
                        st = st_c
                        modes = (j,)
                        idx += consumed
                        if j >= 1 and \
                                set(range(d)) - set(modes) == set(range(j)):
                            new_W = (vec if not reduce_end
                                     else jnp.concatenate(raw, axis=1),
                                     modes, st)
                        continue
                if do_fuse:
                    # ONE batched launch for the adjacent pair of all B shards
                    cur, st = dtvc2_local_batched(
                        cur, xs[m], k_local, xs[nxt], st, impl=impl,
                        prec=prec)
                    modes = tuple(mm for mm in modes if mm not in (m, nxt))
                    idx += 2
                else:
                    cur, st = dtvc_local_batched(
                        cur, xs[m], k_local, st, axis_name=axis_name,
                        impl=impl, prec=prec)
                    modes = tuple(mm for mm in modes if mm != m)
                    idx += 1
                if j >= 1 and set(range(d)) - set(modes) == set(range(j)):
                    new_W = (cur, modes, st)
            W = new_W if new_W is not None else W

            # Delayed reduction: ONE stacked collective for the whole batch
            # (algo picked from the per-leaf size n_j, not B * n_j, so the
            # wire schedule matches B separate per-leaf reductions) — with
            # schedule-explicit doubling hops, matching the pipelined tail
            # hop-for-hop (see mp_allreduce force_schedule).
            if vec is not None:
                pass  # the pipelined tail already reduced it
            else:
                vec = cur  # (B, n_j) — or (B, n_j/p) slices when j == split
                if st.partial:
                    algo = coll.allreduce_algo(vec.shape[-1], p)
                    vec = coll.mp_allreduce(
                        vec, axis_name, prec, algo=algo,
                        force_schedule=(algo == "doubling"))
                elif st.split is not None:
                    vec = coll.all_gather_tiled(vec, axis_name, axis=1)  # ⊔_p
            # Same external-iteration barrier as _hopm_sweeps (see there):
            # both walkers must normalize an identically-isolated vector or
            # cross-program fusion drifts the last bit of the iterates.
            vec = lax.optimization_barrier(vec)
            lam = _norm_batched(vec, prec.compute)
            xs[j] = lax.optimization_barrier(
                (vec.astype(prec.compute)
                 / lam[:, None]).astype(prec.storage))
    return xs, lam


def hopm3_sharded(
    A_loc: jax.Array,
    xs: Sequence[jax.Array],
    *,
    axis_name: str,
    split: int,
    sweeps: int = 1,
    impl: str = "native",
    prec: Precision | str = F32,
    fuse_pairs: bool | None = None,
    overlap=None,
):
    """The per-shard body of :func:`dhopm3` (Algorithm 1 over a 1-D split)
    for callers already *inside* a shard_map manual region over
    ``axis_name``: ``A_loc`` is this process's slice of the global tensor
    along local dim ``split``.  Communication per external iteration: one
    delayed n_j-sized collective (``mp_allreduce`` for j != split, tiled
    all-gather for j == split).  This is the split-leaf engine of
    ``train.grad_compress`` (sharded gradients compressed in place)."""
    prec = get_policy(prec)
    impl, fuse_pairs, overlap = _resolve_walker(
        impl, fuse_pairs, overlap, shape=A_loc.shape,
        p=coll._axis_size(axis_name), s=split, prec=prec)
    return _hopm_sweeps(
        A_loc, xs, sweeps=sweeps, split=split, partial_in=False,
        axis_name=axis_name, impl=impl, prec=prec, three_buffer=True,
        fuse_pairs=fuse_pairs, overlap=overlap,
    )


def hopm3_batched(
    A_b: jax.Array,
    xs: Sequence[jax.Array],
    *,
    sweeps: int = 1,
    impl: str = "native",
    prec: Precision | str = F32,
    fuse_pairs: bool | None = None,
    partial: bool = False,
    split: int | None = None,
    axis_name: str | None = None,
    overlap=None,
):
    """dHOPM_3 over a *batch* of B stacked order-d tensors
    ``A_b[B, n_0..n_{d-1}]`` with per-batch factor vectors ``xs[j][B, n_j]``:
    the three-buffer schedule runs all B power iterations in lockstep, one
    (batched) contraction launch per chain position — launch count per sweep
    is independent of B, which is what amortizes dispatch overhead for
    many-small-tensor consumers (``train.grad_compress`` buckets, per-request
    rank-1 serving).  Iterates match ``jax.vmap``'d :func:`hopm3` exactly.

    ``partial=True`` is the stacked Eq. 2 setting (every rank holds one
    addend of each tensor in the batch): one ``mp_allreduce`` of the stacked
    ``(B, n_j)`` vector per external iteration, inside a shard_map region
    over ``axis_name``.

    ``split=s`` is the stacked *1-D split* setting of Algorithm 1 proper
    (every rank holds B same-shape slices along per-sample dim ``s``): the
    batched walker runs the Eq. 2 slice path at the split mode, tracks the
    split across the W-cache boundary exactly like the unbatched
    :func:`_hopm_sweeps`, and gathers the j == s iterate with one tiled
    all-gather of the ``(B, n_j/p)`` stack.  Mutually exclusive with
    ``partial``; must run inside a shard_map region over ``axis_name``
    (:func:`dhopm3_batched` is the global-array wrapper).
    Returns (xs, lam[B])."""
    prec = get_policy(prec)
    if partial and split is not None:
        raise ValueError(
            "partial summands and a 1-D split are mutually exclusive modes")
    impl, fuse_pairs, overlap = _resolve_walker(
        impl, fuse_pairs, overlap, shape=A_b.shape[1:],
        p=coll._axis_size(axis_name) if axis_name is not None else 1,
        s=split, batch=A_b.shape[0], prec=prec)
    return _hopm_sweeps_batched(
        A_b, xs, sweeps=sweeps, split=split, partial_in=partial,
        axis_name=axis_name, impl=impl, prec=prec, fuse_pairs=fuse_pairs,
        overlap=overlap,
    )


def dhopm3(
    A: jax.Array,
    xs: Sequence[jax.Array],
    mesh: jax.sharding.Mesh,
    axis_name: str = "model",
    s: int | None = None,
    *,
    sweeps: int = 1,
    impl: str = "native",
    prec: Precision | str = F32,
    three_buffer: bool = True,
    fuse_pairs: bool | None = None,
    overlap=None,
):
    """The paper's distributed HOPM over a 1-D split (Algorithm 1).

    ``s`` defaults to d-1 — the paper's recommendation (minimal streamed
    memory, Eq. 6).  ``A.shape[s]`` must divide the axis size.

    ``overlap`` (False | True | int chunks) pipelines each delayed
    reduction behind its own chain tail (see :func:`_hopm_sweeps`) —
    bitwise-equal iterates to the synchronous walker under the ``mulsum``
    engine."""
    prec = get_policy(prec)
    d = A.ndim
    if s is None:
        s = d - 1
    p = mesh.shape[axis_name]
    if A.shape[s] % p:
        raise ValueError(f"dim {s} ({A.shape[s]}) not divisible by p={p}")
    impl, fuse_pairs, overlap = _resolve_walker(
        impl, fuse_pairs, overlap, shape=A.shape, p=p, s=s, prec=prec)

    in_A = P(*[axis_name if i == s else None for i in range(d)])

    def body(a_loc, *xs_in):
        out_xs, lam = _hopm_sweeps(
            a_loc, list(xs_in), sweeps=sweeps, split=s, partial_in=False,
            axis_name=axis_name, impl=impl, prec=prec,
            three_buffer=three_buffer, fuse_pairs=fuse_pairs,
            overlap=overlap,
        )
        return tuple(out_xs), lam

    fn = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(in_A,) + tuple(P() for _ in xs),
        out_specs=(tuple(P() for _ in xs), P()),
        check_vma=False,
    )
    return jax.jit(fn)(A, *xs)


def dhopm3_batched(
    A_b: jax.Array,
    xs: Sequence[jax.Array],
    mesh: jax.sharding.Mesh,
    axis_name: str = "model",
    s: int | None = None,
    *,
    sweeps: int = 1,
    impl: str = "native",
    prec: Precision | str = F32,
    fuse_pairs: bool | None = None,
    overlap=None,
):
    """The paper's distributed HOPM (Algorithm 1) over a *batch* of B
    stacked order-d tensors ``A_b[B, n_0..n_{d-1}]``, each 1-D split along
    per-sample dim ``s`` over the mesh axis: dHOPM_3 itself batches B
    same-shape split tensors per mesh, one (batched) contraction launch per
    chain position — launch count per sweep independent of B (the
    :func:`~repro.core.memory_model.dhopm_launches_per_sweep` schedule),
    while communication stays at Algorithm 1's one delayed n_j-sized
    collective per external iteration (stacked: ``(B, n_j)`` payloads, algo
    dispatched on the per-leaf n_j).

    ``s`` defaults to d-1 — the paper's recommendation (minimal streamed
    memory, Eq. 6).  ``A_b.shape[s + 1]`` (the per-sample extent of dim
    ``s``) must divide the axis size.  Iterates match B independent
    :func:`dhopm3` runs — bitwise under the ``mulsum`` engine, whose batched
    accumulation order is identical to the per-sample one."""
    prec = get_policy(prec)
    d = A_b.ndim - 1
    if s is None:
        s = d - 1
    p = mesh.shape[axis_name]
    if A_b.shape[s + 1] % p:
        raise ValueError(
            f"per-sample dim {s} ({A_b.shape[s + 1]}) not divisible by p={p}")
    impl, fuse_pairs, overlap = _resolve_walker(
        impl, fuse_pairs, overlap, shape=A_b.shape[1:], p=p, s=s,
        batch=A_b.shape[0], prec=prec)

    in_A = P(*([None] + [axis_name if i == s else None for i in range(d)]))

    def body(a_loc, *xs_in):
        out_xs, lam = _hopm_sweeps_batched(
            a_loc, list(xs_in), sweeps=sweeps, split=s, partial_in=False,
            axis_name=axis_name, impl=impl, prec=prec, fuse_pairs=fuse_pairs,
            overlap=overlap,
        )
        return tuple(out_xs), lam

    fn = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(in_A,) + tuple(P() for _ in xs),
        out_specs=(tuple(P() for _ in xs), P()),
        check_vma=False,
    )
    return jax.jit(fn)(A_b, *xs)


def rank1(xs: Sequence[jax.Array], lam=1.0):
    """lam * x_0 ∘ x_1 ∘ ... (the best rank-1 approximation's reconstruction)."""
    out = functools.reduce(jnp.multiply.outer, [x.astype(jnp.float32) for x in xs])
    return lam * out


def hopm_init_factors(key, vshape: Sequence[int], rank: int = 1):
    """Warm-start factor vectors for ``rank`` deflation chains over a view
    of extents ``vshape``: unit-norm gaussian draws, one vector per mode per
    rank, all split deterministically from ``key``.  Shared by the gradient
    compressor's per-leaf state and the serve engine's per-request KV
    factors — callers derive ``key`` from a stable identity (crc32 of the
    leaf path / request id, never salted ``hash()``), so the same tensor
    always starts the power iteration from the same point regardless of
    process, host, or which batch slot it lands in."""
    keys = jax.random.split(key, rank * len(vshape))
    xs = []
    i = 0
    for _ in range(rank):
        vecs = []
        for n in vshape:
            v = jax.random.normal(keys[i], (n,), jnp.float32)
            vecs.append(v / jnp.linalg.norm(v))
            i += 1
        xs.append(tuple(vecs))
    return tuple(xs)


def rank1_residual(A, xs, lam) -> jax.Array:
    """||A - lam ⊗xs||_F / ||A||_F."""
    R = A.astype(jnp.float32) - rank1(xs, lam)
    return jnp.sqrt(jnp.sum(R * R) / jnp.sum(A.astype(jnp.float32) ** 2))
