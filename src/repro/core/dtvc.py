"""Distributed tensor–vector contraction (dTVC) — paper §4.1, Eqs. (1)–(2).

The input tensor is split along one dimension ``s`` over a named mesh axis
(1-D splitting: minimal communication, no unfolding, trivial reassembly).
The contraction vector is harmlessly replicated (uv >> n_k), except in the
suboptimal k = s case where each process contracts against its slice and the
results are full-size partial sums requiring a collective reduction.

API levels:

* :func:`dtvc_local` — the per-shard computation with symbolic split/partial
  bookkeeping (:class:`ShardState`); composable, used by dHOPM_3's chains.
* :func:`dtvc` — global-array convenience wrapper: shard_map over the mesh
  axis, optional assembly (⊔ all-gather for k != s, Σ all-reduce for k = s).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.dist import collectives as coll
from .mixed_precision import F32, Precision, get_policy
from .tvc import tvc, tvc2, tvc2_batched, tvc_batched

__all__ = [
    "ShardState", "dtvc_local", "dtvc2_local", "dtvc_local_batched",
    "dtvc2_local_batched", "dtvc",
]


@dataclasses.dataclass(frozen=True)
class ShardState:
    """Symbolic distribution state of a per-process tensor shard.

    ``split``   — local dim along which the global tensor is split (None if
                  the shard spans full extents).
    ``partial`` — True when the local values are one summand of a pending
                  global Σ (Eq. 2's delayed reduction).
    """

    split: int | None = None
    partial: bool = False

    def after_contraction(self, k: int, hit_split: bool) -> "ShardState":
        if hit_split:
            return ShardState(split=None, partial=True)
        split = self.split
        if split is not None and k < split:
            split = split - 1
        return ShardState(split=split, partial=self.partial)

    def after_pair_contraction(self, k: int) -> "ShardState":
        """State after a *fused* removal of adjacent local modes (k, k+1)
        (the tvc2 path): a split dim above the pair shifts down by exactly
        two.  The fused kernel cannot take the Eq. 2 slice path, so the
        split mode must not be part of the pair — callers gate on that."""
        split = self.split
        if split is not None:
            if split in (k, k + 1):
                raise ValueError(
                    f"fused pair ({k}, {k + 1}) may not include the split "
                    f"dim {split}; use the unfused Eq. 2 slice path")
            if split > k + 1:
                split = split - 2
        return ShardState(split=split, partial=self.partial)


def _apply_rows(A_loc: jax.Array, rows, contracted: tuple[int, ...],
                state: ShardState, batch_offset: int = 0) -> jax.Array:
    """Restrict a shard op to output rows ``rows = (axis, start, size)`` of
    the *local, per-sample* ``axis`` — the pipelined dHOPM3 walker's chunked
    chain tail.  Slicing an uncontracted axis leaves every surviving output
    element's arithmetic untouched (the bitwise lemma the pipeline rests on),
    so ``concat(chunks) == whole`` holds exactly for any engine.

    ``axis`` must be neither a contracted mode (slicing it would change the
    Σ) nor the split dim (its extent encodes this process's Eq. 2 range)."""
    if rows is None:
        return A_loc
    axis, start, size = rows
    if axis in contracted:
        raise ValueError(
            f"rows axis {axis} is contracted {contracted}; chunk the output "
            "axis only")
    if state.split is not None and axis == state.split:
        raise ValueError(
            f"rows axis {axis} is the split dim; drain the pipeline at the "
            "split boundary instead of chunking it")
    return lax.slice_in_dim(A_loc, start, start + size, axis=axis + batch_offset)


def _fusion_island(out: jax.Array, impl: str) -> jax.Array:
    """The ``mulsum`` engine's bitwise-batchability contract: every
    contraction is its own XLA fusion island, so the stacked and per-sample
    programs compile each multiply+reduce identically (cross-program fusion
    into surrounding collectives/chains would drift the last bit).  Applied
    here rather than in :func:`~repro.core.tvc._mulsum` because
    ``optimization_barrier`` has no vmap batching rule and the batched tvc
    wrappers vmap the per-sample oracle.  No-op for every other engine."""
    return lax.optimization_barrier(out) if impl == "mulsum" else out


def dtvc_local(
    A_loc: jax.Array,
    x: jax.Array,
    k: int,
    state: ShardState,
    *,
    axis_name: str | None,
    impl: str = "native",
    prec: Precision | str = F32,
    alpha: float = 1.0,
    beta: float = 0.0,
    y: jax.Array | None = None,
    rows: tuple[int, int, int] | None = None,
) -> tuple[jax.Array, ShardState]:
    """One TVC on a local shard; ``k`` is the *local* mode index of ``A_loc``.

    When ``k == state.split`` (Eq. 2) the function slices ``x`` to this
    process's range and marks the output partial — the global Σ is *delayed*
    (Algorithm 1) until the caller reduces.

    ``rows=(axis, start, size)`` restricts the contraction to a chunk of an
    uncontracted output ``axis`` (see :func:`_apply_rows`) — the pipelined
    chain tail contracts one chunk per launch so each chunk's delayed
    reduction can start while the next chunk computes.

    With ``impl="pallas"`` the shard streams through the zero-copy ragged
    kernels: local extents are almost never block multiples after a 1-D
    split, and the kernels handle that with in-kernel edge masking instead of
    padded copies, so per-shard traffic stays at
    :func:`~repro.core.tvc.tvc_bytes` of the *local* view.  The
    ``alpha``/``beta``/``y`` update is folded into the kernel epilogue.
    """
    prec = get_policy(prec)
    A_loc = _apply_rows(A_loc, rows, (k,), state)
    hit_split = state.split is not None and k == state.split
    if hit_split:
        if axis_name is None:
            raise ValueError("split contraction requires a mesh axis")
        chunk = A_loc.shape[k]
        idx = lax.axis_index(axis_name)
        x_use = lax.dynamic_slice_in_dim(x, idx * chunk, chunk)
    else:
        if x.shape[0] != A_loc.shape[k]:
            raise ValueError(
                f"x size {x.shape[0]} != local mode extent {A_loc.shape[k]}"
            )
        x_use = x
    out = tvc(A_loc, x_use, k, alpha=alpha, beta=beta, y=y, impl=impl, prec=prec)
    return _fusion_island(out, impl), state.after_contraction(k, hit_split)


def dtvc2_local(
    A_loc: jax.Array,
    x1: jax.Array,
    k: int,
    x2: jax.Array,
    state: ShardState,
    *,
    impl: str = "native",
    prec: Precision | str = F32,
    alpha: float = 1.0,
    beta: float = 0.0,
    y: jax.Array | None = None,
    rows: tuple[int, int, int] | None = None,
) -> tuple[jax.Array, ShardState]:
    """One *fused-pair* contraction of adjacent local modes (k, k+1) on a
    shard — the single-launch counterpart of two :func:`dtvc_local` calls,
    skipping the order-(d-1) intermediate entirely (dHOPM_3's chain fusion).

    The fused kernel cannot take the Eq. 2 slice path, so the split dim must
    not be part of the pair — :meth:`ShardState.after_pair_contraction`
    raises otherwise and dHOPM's chain walker gates fusion on exactly that.
    With ``impl="pallas"`` the pair streams through ONE ragged Pallas launch
    (the chain-tail kernel when the pair ends the mode list) with the
    ``alpha``/``beta``/``y`` update fused into its epilogue."""
    prec = get_policy(prec)
    new_state = state.after_pair_contraction(k)  # raises on split-in-pair
    A_loc = _apply_rows(A_loc, rows, (k, k + 1), state)
    if x1.shape[0] != A_loc.shape[k] or x2.shape[0] != A_loc.shape[k + 1]:
        raise ValueError(
            f"vector sizes ({x1.shape[0]}, {x2.shape[0]}) != local pair "
            f"extents {A_loc.shape[k:k + 2]}"
        )
    # looped/unfolded have no fused analogue (they are per-mode BLAS-2
    # schedules); the fused pass is native einsum, its bitwise-batchable
    # mulsum twin, or the Pallas pair kernel
    f_impl = impl if impl in ("native", "mulsum", "pallas") else "native"
    out = tvc2(A_loc, x1, k, x2, k + 1, alpha=alpha, beta=beta, y=y,
               impl=f_impl, prec=prec)
    return _fusion_island(out, f_impl), new_state


def dtvc_local_batched(
    A_b: jax.Array,
    x: jax.Array,
    k: int,
    state: ShardState,
    *,
    axis_name: str | None,
    impl: str = "native",
    prec: Precision | str = F32,
    alpha=1.0,
    beta=0.0,
    y: jax.Array | None = None,
    rows: tuple[int, int, int] | None = None,
) -> tuple[jax.Array, ShardState]:
    """Batched counterpart of :func:`dtvc_local`: ONE contraction launch over
    a stacked batch ``A_b[B, ...]`` of B same-shape local shards, with
    per-batch vectors ``x[B, n_k]``.  ``k`` and ``state.split`` index the
    *per-sample* (local) shape, exactly like the unbatched op — the batch dim
    is invisible to the distribution bookkeeping, because batching changes
    launch counts, never the split/partial semantics.

    When ``k == state.split`` (Eq. 2) every batch row's vector is sliced to
    this process's range (one ``dynamic_slice`` on axis 1 covers the whole
    stack) and the output is marked partial — the global Σ is delayed until
    the caller reduces, as ONE stacked collective for all B tensors.
    ``alpha``/``beta`` may be scalars or per-batch ``[B]`` arrays; with
    ``impl="pallas"`` they ride in the batched kernels' fused epilogue."""
    prec = get_policy(prec)
    A_b = _apply_rows(A_b, rows, (k,), state, batch_offset=1)
    B = A_b.shape[0]
    hit_split = state.split is not None and k == state.split
    if hit_split:
        if axis_name is None:
            raise ValueError("split contraction requires a mesh axis")
        chunk = A_b.shape[k + 1]
        idx = lax.axis_index(axis_name)
        x_use = lax.dynamic_slice_in_dim(x, idx * chunk, chunk, axis=1)
    else:
        if x.shape != (B, A_b.shape[k + 1]):
            raise ValueError(
                f"x shape {x.shape} != (batch {B}, local mode extent "
                f"{A_b.shape[k + 1]})"
            )
        x_use = x
    out = tvc_batched(A_b, x_use, k, alpha=alpha, beta=beta, y=y, impl=impl,
                      prec=prec)
    return _fusion_island(out, impl), state.after_contraction(k, hit_split)


def dtvc2_local_batched(
    A_b: jax.Array,
    x1: jax.Array,
    k: int,
    x2: jax.Array,
    state: ShardState,
    *,
    impl: str = "native",
    prec: Precision | str = F32,
    alpha=1.0,
    beta=0.0,
    y: jax.Array | None = None,
    rows: tuple[int, int, int] | None = None,
) -> tuple[jax.Array, ShardState]:
    """Batched fused-pair shard op: ONE launch contracts the adjacent local
    modes (k, k+1) of all B stacked shards (the single-launch counterpart of
    two :func:`dtvc_local_batched` calls, skipping the order-(d-1)
    intermediate).  The fused kernel cannot take the Eq. 2 slice path, so the
    split dim must not be part of the pair —
    :meth:`ShardState.after_pair_contraction` raises otherwise and the
    batched chain walker gates fusion on exactly that, mirroring the
    unbatched :func:`dtvc2_local`."""
    prec = get_policy(prec)
    new_state = state.after_pair_contraction(k)  # raises on split-in-pair
    A_b = _apply_rows(A_b, rows, (k, k + 1), state, batch_offset=1)
    B = A_b.shape[0]
    if x1.shape != (B, A_b.shape[k + 1]) or \
            x2.shape != (B, A_b.shape[k + 2]):
        raise ValueError(
            f"vector shapes ({x1.shape}, {x2.shape}) != batched local pair "
            f"extents {(B,) + tuple(A_b.shape[k + 1:k + 3])}"
        )
    f_impl = impl if impl in ("native", "mulsum", "pallas") else "native"
    out = tvc2_batched(A_b, x1, k, x2, k + 1, alpha=alpha, beta=beta, y=y,
                       impl=f_impl, prec=prec)
    return _fusion_island(out, f_impl), new_state


def _out_split_dim(k: int, s: int) -> int:
    return s - 1 if s > k else s


def dtvc(
    A: jax.Array,
    x: jax.Array,
    k: int,
    s: int,
    mesh: jax.sharding.Mesh,
    axis_name: str = "model",
    *,
    impl: str = "native",
    prec: Precision | str = F32,
    alpha: float = 1.0,
    beta: float = 0.0,
    y: jax.Array | None = None,
    assemble: bool = True,
) -> jax.Array:
    """Global dTVC: Eq. (1) for k != s, Eq. (2) for k = s.

    ``A.shape[s]`` must be divisible by the axis size (use
    :func:`repro.core.splitting.plan_split_for_mesh` + zero padding upstream;
    padding is exact for TVC).  With ``assemble=False`` and k != s the result
    is returned still split along the output dim (the paper's strong
    recommendation: keep outputs distributed).  k = s always reduces (the
    delayed-reduction variant lives in :func:`dtvc_local` / dHOPM_3).
    """
    prec = get_policy(prec)
    p = mesh.shape[axis_name]
    if A.shape[s] % p:
        raise ValueError(
            f"split dim {s} extent {A.shape[s]} not divisible by axis "
            f"'{axis_name}' size {p}; pad via plan_split_for_mesh first"
        )
    d = A.ndim
    in_spec_A = P(*[axis_name if i == s else None for i in range(d)])
    so = _out_split_dim(k, s)
    split_out = P(*[axis_name if i == so else None for i in range(d - 1)])
    have_y = y is not None
    if have_y and assemble and k != s:
        raise NotImplementedError(
            "beta-update with assembled output: assemble first, then axpby"
        )

    if k == s:
        out_spec, y_spec = P(), P()
    else:
        out_spec = P() if assemble else split_out
        y_spec = split_out

    def body(a_loc, x_full, *maybe_y):
        y_loc = maybe_y[0] if maybe_y else None
        if k == s:
            out, _ = dtvc_local(
                a_loc, x_full, k, ShardState(split=s), axis_name=axis_name,
                impl=impl, prec=prec, alpha=alpha,
            )
            out = coll.mp_allreduce(out, axis_name, prec)
            if y_loc is not None:
                out = out + jnp.asarray(beta, prec.compute) * y_loc.astype(prec.compute)
            return out.astype(prec.storage)
        out, _ = dtvc_local(
            a_loc, x_full, k, ShardState(split=s), axis_name=axis_name,
            impl=impl, prec=prec, alpha=alpha,
            beta=beta if y_loc is not None else 0.0, y=y_loc,
        )
        if assemble:
            out = coll.all_gather_tiled(out, axis_name, axis=so)
        return out

    in_specs = (in_spec_A, P()) + ((y_spec,) if have_y else ())
    fn = jax.shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_spec, check_vma=False
    )
    args = (A, x) + ((y,) if have_y else ())
    return jax.jit(fn)(*args)
