"""Analytical streamed-memory model — paper §4.2, Eqs. (3)–(7) and Fig. 2.

Two complementary tools:

1. The paper's closed-form expressions for hypersquare tensors
   (:func:`m_seq`, :func:`M_par`, :func:`eta_inv`, recursion :func:`M_par_rec`).
2. An exact event-level simulator (:func:`simulate_sweep`) that walks the
   contraction chains of the canonical two-buffer dHOPM and of dHOPM_3
   (Algorithm 1), counting every element read and written per process.  The
   simulator validates the closed forms and provides H^{-1} (Fig. 2b), for
   which the paper gives no closed form.

All quantities are *elements per process per full sweep* (d external
iterations); multiply by the itemsize for bytes.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

__all__ = [
    "m_seq", "M_seq", "m_par_j_eq_s", "m_par_j_ne_s", "M_par", "M_par_rec",
    "eta_inv", "ring_allreduce_touched", "simulate_sweep", "H_inv",
    "tvc_streamed_elems", "tvc_padded_copy_elems", "pad_overhead",
    "tvc2_streamed_elems", "tvc2_unfused_streamed_elems", "fused_pair_saving",
    "tvc_batched_streamed_elems", "tvc2_batched_streamed_elems",
    "launch_amortized_speedup", "simulate_sweep_batched",
    "dhopm_launches_per_sweep", "dhopm_wire_bytes_sweep",
    "dhopm_batched_wire_bytes_sweep", "dhopm_time_sweep",
    "hopm_streamed_elems_sweep", "rank1_factor_elems",
    "rank1_compression_ratio", "bucket_stack_elems", "arena_fill_elems",
]


# --------------------------------------------------------------------------
# Single-kernel streamed-memory accounting (paper §2/§5 bandwidth denominator)
# --------------------------------------------------------------------------

def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def tvc_streamed_elems(u: int, nk: int, v: int, beta: float = 0.0) -> int:
    """Elements streamed by ONE no-copy TVC on the (u, n_k, v) view: read A,
    read x, write Y (+ one read of Y when the beta-update is fused into the
    kernel epilogue).  This is what the ragged Pallas path actually moves —
    multiply by the storage itemsize for bytes."""
    y_traffic = u * v * (2 if beta else 1)
    return u * nk * v + nk + y_traffic


def tvc2_streamed_elems(u: int, n1: int, n2: int, v: int,
                        beta: float = 0.0) -> int:
    """Elements streamed by ONE single-launch fused-pair contraction
    ``Y[u,v] = alpha * sum_{a,b} A[u,a,b,v] x1[a] x2[b] + beta * Y``: read A
    once, read both vectors, write Y (+ one read of Y when the beta-update is
    fused into the kernel epilogue).  The order-(d-1) intermediate
    ``A x_{k1} x1`` never exists, so its write-then-read round trip — the
    dominant term of the unfused pair for small n1 — is simply absent."""
    y_traffic = u * v * (2 if beta else 1)
    return u * n1 * n2 * v + n1 + n2 + y_traffic


def tvc2_unfused_streamed_elems(u: int, n1: int, n2: int, v: int,
                                beta: float = 0.0) -> int:
    """Elements streamed by the same pair as TWO kernel launches: the first
    TVC writes the (u, n2, v) intermediate, the second reads it back.  This
    is the reference the fused kernel is predicted (and gated in CI) to beat:
    the difference is exactly ``2 * u * n2 * v`` intermediate traffic."""
    first = tvc_streamed_elems(u, n1, n2 * v)
    second = tvc_streamed_elems(u, n2, v, beta=beta)
    return first + second


def fused_pair_saving(u: int, n1: int, n2: int, v: int,
                      beta: float = 0.0) -> float:
    """Streamed-traffic ratio two-launch / fused (> 1 always: the fused pass
    never materializes the intermediate)."""
    return (tvc2_unfused_streamed_elems(u, n1, n2, v, beta)
            / tvc2_streamed_elems(u, n1, n2, v, beta))


def tvc_batched_streamed_elems(b: int, u: int, nk: int, v: int,
                               beta: float = 0.0) -> int:
    """Elements streamed by ONE *batched* TVC launch over B stacked
    same-shape contractions with per-batch vectors: exactly B times the
    single-launch traffic (read every A row, every x row, write every Y row
    — per-batch alpha/beta add only a negligible 2B-element operand, left
    out of the model).  Batching changes the *launch count*, never the
    streamed bytes: the win is dispatch amortization, which
    :func:`launch_amortized_speedup` predicts."""
    return b * tvc_streamed_elems(u, nk, v, beta)


def tvc2_batched_streamed_elems(b: int, u: int, n1: int, n2: int, v: int,
                                beta: float = 0.0) -> int:
    """Batched counterpart of :func:`tvc2_streamed_elems`: B stacked
    single-launch fused pairs, one launch, B times the traffic."""
    return b * tvc2_streamed_elems(u, n1, n2, v, beta)


def launch_amortized_speedup(b: int, streamed_bytes: float, peak_gbs: float,
                             dispatch_us: float) -> float:
    """Predicted wall-time ratio (B separate launches) / (one batched
    launch) for a cell whose single launch streams ``streamed_bytes`` at
    ``peak_gbs`` and pays ``dispatch_us`` of fixed per-launch dispatch
    overhead:

        t_sep     = B * (t_stream + t_dispatch)
        t_batched = B * t_stream + t_dispatch

    -> 1 as streaming dominates (big tensors), -> B as dispatch dominates
    (the small-cell regime PR 3's check_bench calibration measured at
    18-43x over the memory model on CPU).  The bench gate uses this to
    assert a batched cell beats B separate launches where the model says it
    must."""
    if b <= 0:
        raise ValueError(f"batch must be positive, got {b}")
    t_stream = streamed_bytes / (peak_gbs * 1e9) * 1e6   # us per launch
    return (b * (t_stream + dispatch_us)) / (b * t_stream + dispatch_us)


def tvc_padded_copy_elems(
    u: int, nk: int, v: int,
    blocks: tuple[int, int, int],
    beta: float = 0.0,
) -> int:
    """Elements the legacy pad-and-copy wrapper streamed for the same TVC:
    materializing a zero-padded copy of A (read original + write padded),
    streaming the *padded* view through the kernel, and — for beta != 0 — a
    separate full axpby pass (read kernel output, read Y, write Y) instead of
    the fused epilogue.  Kept as the reference point for the bandwidth
    harness's ``pad_overhead`` column."""
    bu, bk, bv = blocks
    up, kp, vp = _round_up(u, bu), _round_up(nk, bk), _round_up(v, bv)
    total = 0
    if (up, kp, vp) != (u, nk, v):
        total += u * nk * v + up * kp * vp      # jnp.pad: read A, write copy
    total += up * kp * vp + kp + up * vp        # kernel pass on the padded view
    if beta:
        total += 3 * u * v                      # axpby: read Y', read Y, write Y
    if (up, vp) != (u, v):
        total += 2 * u * v                      # slice-back copy: read + write
    return total


def pad_overhead(
    u: int, nk: int, v: int,
    blocks: tuple[int, int, int],
    beta: float = 0.0,
) -> float:
    """Streamed-traffic ratio legacy pad-and-copy / no-copy (>= 1; 1 when the
    shape is already a block multiple and beta == 0)."""
    return (tvc_padded_copy_elems(u, nk, v, blocks, beta)
            / tvc_streamed_elems(u, nk, v, beta))


# --------------------------------------------------------------------------
# Closed forms (hypersquare tensors, regular division approximation)
# --------------------------------------------------------------------------

def m_seq(n: int, d: int) -> float:
    """Eq. (3): touched memory of ONE external iteration, sequential HOPM."""
    return float(n) ** d + 2.0 * sum(float(n) ** k for k in range(2, d)) + (d + 3.0) * n


def M_seq(n: int, d: int) -> float:
    """Total sequential sweep: d external iterations."""
    return d * m_seq(n, d)


def m_par_j_eq_s(n: int, d: int, p: int) -> float:
    """Eq. (4) (approximate form): external iteration j == s."""
    return m_seq(n, d) / p + (p - 1.0) / p * (d - 1.0) * n


def m_par_j_ne_s(n: int, d: int, p: int, s: int, j: int) -> float:
    """Eq. (5): external iteration j != s; l = 0 if j < s else 1."""
    l = 0 if j < s else 1
    extra = 2.0 * sum(float(n) ** k for k in range(2, d - s - l + 1)) + (d + 2.0) * n
    return m_seq(n, d) / p + (p - 1.0) / p * extra


def M_par(n: int, d: int, p: int, s: int) -> float:
    """Eq. (6): total distributed sweep (classical dHOPM), per process."""
    total = m_par_j_eq_s(n, d, p)
    total += sum(m_par_j_ne_s(n, d, p, s, j) for j in range(0, s))
    total += sum(m_par_j_ne_s(n, d, p, s, j) for j in range(s + 1, d))
    return total


def M_par_rec(n: int, d: int, p: int, s: int) -> float:
    """Eq. (7): recursion M_par(s-1) = M_par(s) + (p-1)/p * (...).  Anchored at
    s = d-1 and recursed downward; used to cross-check Eq. (6)."""
    if s == d - 1:
        return M_par(n, d, p, d - 1)
    nxt = M_par_rec(n, d, p, s + 1)
    sp = s + 1  # recursion steps from s+1 down to s
    term = (p - 1.0) / p * (
        (d - sp - 1.0) * 2.0 * float(n) ** (d - sp) + (sp - 1.0) * 2.0 * float(n) ** (d - sp + 1)
    )
    return nxt + term


def eta_inv(n: int, d: int, p: int, s: int) -> float:
    """Fig. 2(a): eta^{-1} = p * M_par / M_seq (>= 1; 1 is ideal)."""
    return p * M_par(n, d, p, s) / M_seq(n, d)


def ring_allreduce_touched(n: int, p: int) -> float:
    """Paper §4.2 closing remark: bandwidth-optimal ring all-reduce touches
    4 n (p-1)/p extra elements per process."""
    return 4.0 * n * (p - 1.0) / p


# --------------------------------------------------------------------------
# Exact simulator (canonical two-buffer dHOPM vs dHOPM_3)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _T:
    """Symbolic intermediate: remaining global modes and split liveness."""
    modes: tuple[int, ...]      # global mode ids still present
    split: bool                 # split along mode s still alive?
    partial: bool               # full-size partial sum (post k==s contraction)

    def size(self, n: int, p: int) -> float:
        sz = float(n) ** len(self.modes)
        return sz / p if self.split else sz


def _contract(t: _T, m: int, s: int, n: int, p: int) -> tuple[_T, float, float]:
    """Contract mode m; returns (result, elements_read_from_input, x_read)."""
    read = t.size(n, p)
    if m == s and t.split:
        x_read = n / p          # slice x^{(p)} (Eq. 2)
        out = _T(tuple(mm for mm in t.modes if mm != m), split=False, partial=True)
    else:
        x_read = float(n)
        out = _T(tuple(mm for mm in t.modes if mm != m), split=t.split, partial=t.partial)
    return out, read, x_read


def simulate_sweep(
    n: int,
    d: int,
    p: int,
    s: int,
    algo: Literal["classic", "hopm3", "hopm3_fused"] = "classic",
    include_comm: bool = False,
    split_alive: bool | None = None,
    overlap_chunks: int = 1,
) -> float:
    """Elements streamed per process for one full sweep of d external
    iterations.  ``classic`` = canonical two-buffer distributed HOPM
    (Pawlowski et al. style chains, always restart from A); ``hopm3`` =
    Algorithm 1 with the three-buffer prefix cache; ``hopm3_fused`` =
    beyond-paper variant that additionally contracts adjacent-mode pairs in
    one streaming pass (never across the W boundary or the split mode).

    ``split_alive`` overrides whether the 1-D split state machine is active:
    the default (None = ``p > 1``) matches the paper's setting, but the
    runtime walkers keep the split schedule even at p = 1 (the split is
    structural — it blocks pair fusion and takes the Eq. 2 slice path with a
    full-extent chunk), so single-process accounting of a *split* run must
    pass ``split_alive=True``.

    ``overlap_chunks`` > 1 accounts the pipelined walker (``overlap=``): the
    chain tail runs as min(overlap_chunks, n) chunked launches, each
    re-reading the contracted-mode vector(s) — (C-1) extra x reads per
    pipelined tail (chunking the output dim partitions the tensor read and
    the output write, so only the vectors are re-streamed).  The pipeline
    drains at the j == s gather iteration, matching the runtime, and the
    model assumes the doubling-reduction regime (the runtime falls back to
    the synchronous tail for ring-regime payloads)."""
    A = _T(tuple(range(d)), split=(p > 1 if split_alive is None
                                   else split_alive), partial=False)
    total = 0.0
    W: _T | None = None   # hopm3 prefix cache: A contracted along 0..j-2
    three = algo in ("hopm3", "hopm3_fused")
    fused = algo == "hopm3_fused"

    for j in range(d):
        if three and j >= 2 and W is not None:
            cur = W
            chain = [j - 1] + list(range(j + 1, d))
        else:
            cur = A
            chain = [m for m in range(d) if m != j]

        new_W = None
        idx = 0
        while idx < len(chain):
            m = chain[idx]
            nxt = chain[idx + 1] if idx + 1 < len(chain) else None
            split_hit = cur.split and (m == s or nxt == s)
            done_after_first = (set(range(d)) - set(cur.modes)) | {m}
            captures_W = three and j >= 1 and done_after_first == set(range(j))
            do_fuse = (fused and nxt == m + 1 and not split_hit
                       and not captures_W)
            consumed = 2 if do_fuse else 1
            is_tail = idx + consumed == len(chain)
            # Pipelined tail (mirrors the walkers' engage predicate): the
            # gather iteration — split alive through a tail that doesn't
            # consume it — drains; everything else chunks.
            tail_hit = cur.split and m == s and not do_fuse
            pipelined = (is_tail and overlap_chunks > 1
                         and not (cur.split and not tail_hit))
            C = min(overlap_chunks, n) if pipelined else 1
            if do_fuse:
                read = cur.size(n, p)
                cur, _, x1 = _contract(cur, m, s, n, p)
                cur, _, x2 = _contract(cur, nxt, s, n, p)
                total += read + x1 + x2 + cur.size(n, p)
                total += (C - 1) * (x1 + x2)    # per-chunk vector re-reads
                idx += 2
            else:
                cur, read, x_read = _contract(cur, m, s, n, p)
                total += read + x_read + cur.size(n, p)
                total += (C - 1) * x_read       # per-chunk vector re-reads
                idx += 1
            if three and j >= 1 and \
                    set(range(d)) - set(cur.modes) == set(range(j)):
                new_W = cur
        if three:
            W = new_W

        # Final vector: reduce (j != s) or gather (j = s), then normalize.
        # Touched: output vector + ~3x vector for the normalization step,
        # matching the 4[n/p] / 4n accounting of Eqs. (4)-(5).
        vec = n / p if (j == s and p > 1) else float(n)
        total += 4.0 * vec
        if include_comm and p > 1:
            total += ring_allreduce_touched(n if j != s else n / p, p)
    return total


def H_inv(n: int, d: int, p: int, s: int) -> float:
    """Fig. 2(b): streamed-memory ratio classical dHOPM / dHOPM_3."""
    return simulate_sweep(n, d, p, s, "classic") / simulate_sweep(n, d, p, s, "hopm3")


def saved_contractions(d: int) -> int:
    """dHOPM_3 skips (d-1)(d-2)/2 contractions per sweep (paper §4.2)."""
    return (d - 1) * (d - 2) // 2


# --------------------------------------------------------------------------
# Split-aware batched dHOPM_3 accounting (dhopm3_batched): streamed bytes,
# launch schedule, and wire traffic.  Batching B tensors changes the LAUNCH
# COUNT only — never streamed bytes (B x the per-tensor traffic) and never
# wire bytes (stacked collectives carry B x the per-leaf payload).
# --------------------------------------------------------------------------

def simulate_sweep_batched(
    b: int,
    n: int,
    d: int,
    p: int,
    s: int,
    algo: Literal["classic", "hopm3", "hopm3_fused"] = "hopm3",
    split_alive: bool | None = None,
) -> float:
    """Elements streamed per process for one sweep of ``dhopm3_batched``
    over B stacked split tensors: exactly B times the per-tensor
    :func:`simulate_sweep` — the batched walker reads every stacked shard
    row, every per-batch vector, and writes every stacked intermediate, so
    batching amortizes dispatch, never traffic."""
    if b <= 0:
        raise ValueError(f"batch must be positive, got {b}")
    return b * simulate_sweep(n, d, p, s, algo, split_alive=split_alive)


def dhopm_launches_per_sweep(d: int, s: int | None = None,
                             fuse_pairs: bool = False,
                             overlap_chunks: int = 1) -> int:
    """Contraction-launch count of ONE dHOPM_3 sweep (the three-buffer
    walker of ``hopm3`` / ``dhopm3`` / their batched twins): d chains with
    the W prefix cache skipping (d-1)(d-2)/2 contractions, minus one launch
    per fused adjacent pair when ``fuse_pairs`` — fusion is gated off at the
    W-cache capture point and wherever the pair touches the 1-D split mode
    ``s`` (``None`` = no split).  The batched walker issues exactly this
    many *batched* launches per sweep, independent of B — the jaxpr-asserted
    guarantee the bench's dispatch-allowance accounting builds on.

    ``overlap_chunks`` > 1 counts the pipelined walker (``overlap=``): every
    chain tail that doesn't end at the j == s gather boundary runs as
    ``overlap_chunks`` chunked launches.  Assumes every n_j >=
    ``overlap_chunks`` and the doubling-reduction regime (the runtime's
    balanced chunking issues exactly this many launches then; it drains to
    one launch at the gather, as counted here)."""
    modes_A = tuple(range(d))
    launches = 0
    W = None  # (modes, split_alive)
    for j in range(d):
        if j >= 2 and W is not None:
            modes, split_alive = W
            chain = [j - 1] + list(range(j + 1, d))
        else:
            modes, split_alive = modes_A, s is not None
            chain = [m for m in range(d) if m != j]
        new_W = None
        idx = 0
        while idx < len(chain):
            m = chain[idx]
            nxt = chain[idx + 1] if idx + 1 < len(chain) else None
            hit = split_alive and (m == s or nxt == s)
            done_after_first = (set(range(d)) - set(modes)) | {m}
            captures_W = j >= 1 and done_after_first == set(range(j))
            do_fuse = (fuse_pairs and nxt == m + 1 and not hit
                       and not captures_W)
            consumed = 2 if do_fuse else 1
            is_tail = idx + consumed == len(chain)
            tail_hit = split_alive and m == s and not do_fuse
            pipelined = (is_tail and overlap_chunks > 1
                         and not (split_alive and not tail_hit))
            if do_fuse:
                modes = tuple(mm for mm in modes if mm not in (m, nxt))
                idx += 2
            else:
                if split_alive and m == s:
                    split_alive = False
                modes = tuple(mm for mm in modes if mm != m)
                idx += 1
            launches += overlap_chunks if pipelined else 1
            if j >= 1 and set(range(d)) - set(modes) == set(range(j)):
                new_W = (modes, split_alive)
        W = new_W if new_W is not None else W
    return launches


def hopm_streamed_elems_sweep(shape, fuse_pairs: bool = False) -> float:
    """Elements streamed by ONE single-process ``hopm3`` sweep over an
    order-d tensor with *heterogeneous* extents ``shape`` — the shape-general
    counterpart of :func:`simulate_sweep` (which prices hypersquare tensors
    only).  Walks the identical three-buffer schedule — W prefix cache, the
    same fusion gating — with per-mode extents, counting input read + vector
    read + output write per contraction and the 4 n_j vector finalize per
    external iteration.  At ``shape == (n,) * d`` this equals
    ``simulate_sweep(n, d, 1, s, algo, split_alive=False)`` exactly
    (regression-tested).

    This is the per-chain-per-sweep price of the serve engine's KV-cache
    compression launches (``hopm3_batched`` over B stacked contexts streams
    exactly B times this — batching amortizes dispatch, never traffic)."""
    d = len(shape)

    def size(modes) -> float:
        out = 1.0
        for m in modes:
            out *= shape[m]
        return out

    total = 0.0
    W: tuple | None = None       # surviving global mode ids of the W cache
    for j in range(d):
        if j >= 2 and W is not None:
            modes = W
            chain = [j - 1] + list(range(j + 1, d))
        else:
            modes = tuple(range(d))
            chain = [m for m in range(d) if m != j]
        new_W = None
        idx = 0
        while idx < len(chain):
            m = chain[idx]
            nxt = chain[idx + 1] if idx + 1 < len(chain) else None
            done_after_first = (set(range(d)) - set(modes)) | {m}
            captures_W = j >= 1 and done_after_first == set(range(j))
            do_fuse = fuse_pairs and nxt == m + 1 and not captures_W
            read = size(modes)
            if do_fuse:
                modes = tuple(mm for mm in modes if mm not in (m, nxt))
                total += read + shape[m] + shape[nxt] + size(modes)
                idx += 2
            else:
                modes = tuple(mm for mm in modes if mm != m)
                total += read + shape[m] + size(modes)
                idx += 1
            if j >= 1 and set(range(d)) - set(modes) == set(range(j)):
                new_W = modes
        W = new_W if new_W is not None else W
        total += 4.0 * shape[j]     # output vector + normalize (Eqs. 4-5)
    return total


def rank1_factor_elems(shape) -> int:
    """Elements of one rank-1 factorization of an order-d tensor: one factor
    vector per mode plus the scalar lambda — what a compressed KV context
    stores (and ships) instead of the dense ``prod(shape)`` slab."""
    return sum(shape) + 1


def rank1_compression_ratio(shape) -> float:
    """dense / factored storage ratio of one rank-1 factorization."""
    dense = 1
    for n in shape:
        dense *= n
    return dense / rank1_factor_elems(shape)


def bucket_stack_elems(b: int, view, ranks: int = 1) -> int:
    """Pure copy elements one ``jnp.stack`` bucket assembly moves per
    compression step: the B materialized member rows are read back and
    written into a freshly allocated ``[B, *view]`` buffer
    (``2 · B · prod(view)``), plus the warm-start factor gather — ``ranks``
    deflation ranks of d stacked ``(B, n_m)`` factor matrices, read + write
    each (``2 · ranks · B · Σ n_m``).  This traffic is assembly overhead on
    top of the chain's own streamed bytes
    (:func:`hopm_streamed_elems_sweep`); multiply by the itemsize for bytes.
    It is exactly what a counted trace of the stacked path's
    ``concatenate`` equations sums to (regression-tested in
    ``tests/_dist_checks.py``), and what the donation-aware arena removes
    (:mod:`repro.core.arena`)."""
    v = 1
    for n in view:
        v *= n
    return 2 * b * v + 2 * ranks * b * sum(view)


def arena_fill_elems(b: int, view, ranks: int = 1,
                     cold: bool = False) -> int:
    """Extra copy elements of a donated arena fill beyond the member rows'
    unavoidable materialization.

    A *warm* fill costs **0**: the jitted ``donate_argnums`` scatter writes
    each member straight into its persistent arena row — the write aliases
    the row materialization the stacked path also pays, the buffer already
    exists (no allocation), and no stacked copy is ever read back.  A
    *cold* fill (first event on a ``(B, view)`` key) must allocate and
    populate the buffer, which costs exactly one stack assembly
    (:func:`bucket_stack_elems`); steady-state buckets amortize it to
    nothing.  ``bucket_stack_elems - arena_fill_elems`` is the per-event
    ``stack_copy_removed_bytes`` the bench cells record and ``check_bench``
    recomputes."""
    return bucket_stack_elems(b, view, ranks=ranks) if cold else 0


def dhopm_wire_bytes_sweep(shape, p: int, itemsize: int,
                           split: int | None = None) -> float:
    """Per-process wire bytes of ONE dHOPM_3 sweep over an order-d tensor
    with extents ``shape``: Algorithm 1's delayed reduction is one small
    collective per external iteration j — an n_j-sized ``mp_allreduce``
    whose ring/doubling schedule is dispatched on n_j (matching the
    runtime's per-iteration dispatch, NOT one dispatch on Σ n_j), except
    the split iteration j == ``split``, which all-gathers the n_j/p local
    slice.  ``split=None`` is the Eq. 2 partial-summand setting (every
    iteration reduces) — the schedule ``train.grad_compress`` runs per
    deflation rank per sweep.  Batching multiplies this by B
    (:func:`dhopm_batched_wire_bytes_sweep`); stacked collectives keep the
    per-leaf dispatch."""
    from repro.dist.collectives import (
        allreduce_algo,
        wire_bytes_allgather,
        wire_bytes_allreduce,
    )
    total = 0.0
    for j, nj in enumerate(shape):
        if split is not None and j == split:
            total += wire_bytes_allgather(nj, p, itemsize)
        else:
            total += wire_bytes_allreduce(nj, p, itemsize,
                                          allreduce_algo(nj, p))
    return total


def dhopm_batched_wire_bytes_sweep(b: int, shape, p: int, itemsize: int,
                                   split: int | None = None) -> float:
    """Wire bytes of one *batched* dHOPM_3 sweep over B stacked tensors:
    exactly B times :func:`dhopm_wire_bytes_sweep` — the stacked (B, n_j)
    collectives carry B per-leaf payloads on the same per-leaf-dispatched
    schedule, so batching never changes wire traffic."""
    if b <= 0:
        raise ValueError(f"batch must be positive, got {b}")
    return b * dhopm_wire_bytes_sweep(shape, p, itemsize, split)


def _tail_stream_elems(shape, p: int, split: int | None, j: int) -> float:
    """Elements the iteration-j chain *tail* streams per process under the
    three-buffer (unfused) schedule: the tail contracts the last chain mode
    — mode d-1, or d-2 when j == d-1 — leaving the (n_j,) payload.  Local
    extents: the output mode is an n_j/p slice when j == split; the
    contracted mode is an n/p slice (Eq. 2) when IT is the split and the
    split survived the chain prefix (split == last != j)."""
    d = len(shape)
    last = d - 1 if j != d - 1 else d - 2
    nj = shape[j] / p if split == j else float(shape[j])
    nl = (shape[last] / p if (split == last and split != j)
          else float(shape[last]))
    return nj * nl + nl + nj      # read cur + read x (slice) + write payload


def dhopm_time_sweep(shape, p: int, itemsize: int, *,
                     split: int | None = None, overlap_chunks: int = 1,
                     peak_gbs: float, wire_gbs: float,
                     dispatch_us: float = 0.0) -> dict:
    """Overlap-aware time model of ONE dHOPM_3 sweep, extending
    :func:`dhopm_wire_bytes_sweep` from bytes to exposed wire *time*.

    Per external iteration j the delayed collective (wire) can only overlap
    the chain tail that produces its payload — the Gauss–Seidel dependency
    pins every other launch (see ``_hopm_sweeps``).  The synchronous walker
    exposes the full wire time; the pipelined walker splits the tail into C
    = min(overlap_chunks, n_j) balanced chunks and stages chunk c's
    reduction behind chunk c+1's launch, so per stage

        exposed_c = max(0, wire_c - tail_chunk_time),   c < C-1
        exposed_{C-1} = wire_{C-1}                      (nothing left to hide)

    with ``wire_c = wire_j / C`` and ``tail_chunk_time = tail_stream_time/C
    + dispatch_us``.  The gather iteration j == split (and ring-regime
    payloads — not modeled, the runtime drains them) stays fully exposed.
    Unfused tails only (``fuse_pairs`` tails chunk identically but stream a
    3-mode view; the bench's overlap cells run both, gated on the unfused
    accounting with the fused tail's smaller stream being conservative).

    Returns totals in microseconds: ``wire_us`` (all collectives),
    ``exposed_wire_us``, ``hidden_wire_us``, ``tail_stream_us``, and
    ``extra_dispatch_us`` ((C-1) extra launches per pipelined tail), plus
    the ``per_iteration`` stage list."""
    from repro.dist.collectives import (
        allreduce_algo,
        wire_bytes_allgather,
        wire_bytes_allreduce,
    )
    if overlap_chunks < 1:
        raise ValueError(
            f"overlap_chunks must be >= 1, got {overlap_chunks}")
    to_us = lambda nbytes, gbs: nbytes / (gbs * 1e9) * 1e6
    stages = []
    for j, nj in enumerate(shape):
        gather = split is not None and j == split
        if gather:
            wire_us = to_us(wire_bytes_allgather(nj, p, itemsize), wire_gbs)
        else:
            wire_us = to_us(
                wire_bytes_allreduce(nj, p, itemsize, allreduce_algo(nj, p)),
                wire_gbs)
        tail_us = to_us(_tail_stream_elems(shape, p, split, j) * itemsize,
                        peak_gbs)
        C = min(overlap_chunks, nj)
        pipelined = (C > 1 and not gather
                     and allreduce_algo(nj, p) == "doubling")
        if pipelined:
            w_c = wire_us / C
            t_c = tail_us / C + dispatch_us
            exposed_us = (C - 1) * max(0.0, w_c - t_c) + w_c
            extra_dispatch_us = (C - 1) * dispatch_us
        else:
            C = 1
            exposed_us = wire_us
            extra_dispatch_us = 0.0
        stages.append({
            "j": j, "chunks": C, "wire_us": wire_us, "tail_us": tail_us,
            "exposed_us": exposed_us, "extra_dispatch_us": extra_dispatch_us,
        })
    return {
        "per_iteration": stages,
        "wire_us": sum(st["wire_us"] for st in stages),
        "exposed_wire_us": sum(st["exposed_us"] for st in stages),
        "hidden_wire_us": sum(st["wire_us"] - st["exposed_us"]
                              for st in stages),
        "tail_stream_us": sum(st["tail_us"] for st in stages),
        "extra_dispatch_us": sum(st["extra_dispatch_us"] for st in stages),
    }
