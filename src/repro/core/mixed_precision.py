"""Mixed-precision policies (paper §5.5).

The paper stores tensors in a *low* precision and promotes to a *high*
precision immediately before arithmetic ("every arithmetic operation, besides
accumulations, is done in high precision"), then demotes results back to the
storage format.  Communication stays in the storage (wire) precision while
sums accumulate in the compute precision — this required ad-hoc MPI reduction
functions in the paper; here it is realized by kernels that take
``preferred_element_type`` accumulators and by the ppermute-based collectives
:func:`repro.dist.collectives.mp_allreduce` /
:func:`~repro.dist.collectives.mp_allreduce_ring` /
:func:`~repro.dist.collectives.mp_allreduce_doubling`, which demote every
wire hop to ``Precision.storage`` and add in ``Precision.compute`` (with a
``lax.psum`` fast path when the two dtypes coincide).  The analytic per-hop
byte accounting lives in
:func:`repro.dist.collectives.wire_bytes_allreduce`.

On TPU the paper's double/single pair maps to f32/bf16 (no f64 hardware);
the f16 ("half") storage format of §5.5 is kept as well.  CPU-only tests can
exercise f64 pairs by enabling jax_enable_x64 locally.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Precision:
    """A (storage, compute) dtype pair.

    ``storage`` is the in-memory & on-wire format; ``compute`` is the
    accumulation / arithmetic format.
    """

    storage: jnp.dtype
    compute: jnp.dtype
    name: str = ""

    def promote(self, x):
        return x.astype(self.compute) if x.dtype != self.compute else x

    def demote(self, x):
        return x.astype(self.storage) if x.dtype != self.storage else x

    @property
    def storage_bytes(self) -> int:
        return jnp.dtype(self.storage).itemsize

    @property
    def compute_bytes(self) -> int:
        return jnp.dtype(self.compute).itemsize


# The paper's precision ladder, adapted to TPU dtypes.
F32 = Precision(jnp.float32, jnp.float32, "single")             # paper: double
BF16_F32 = Precision(jnp.bfloat16, jnp.float32, "brain-single")  # paper: brain-single
F16_F32 = Precision(jnp.float16, jnp.float32, "half-single")     # paper: half-single
F32_F32 = F32

#: registry for CLI / config lookup
POLICIES = {
    "f32": F32,
    "single": F32,
    "bf16": BF16_F32,
    "brain-single": BF16_F32,
    "f16": F16_F32,
    "half-single": F16_F32,
}


def get_policy(name_or_policy) -> Precision:
    if isinstance(name_or_policy, Precision):
        return name_or_policy
    try:
        return POLICIES[str(name_or_policy)]
    except KeyError:
        raise ValueError(
            f"unknown precision policy {name_or_policy!r}; "
            f"choose from {sorted(POLICIES)}"
        ) from None


def f64_policy() -> Precision:
    """Paper-faithful double precision; valid only with jax_enable_x64 (CPU)."""
    return Precision(jnp.float64, jnp.float64, "double")


def f32_f64_policy() -> Precision:
    """Paper's single-double pair; valid only with jax_enable_x64 (CPU)."""
    return Precision(jnp.float32, jnp.float64, "single-double")
