"""One-dimensional tensor splitting (paper §4.1, Fig. 1).

The paper splits a d-order tensor along a single dimension ``s`` across ``p``
processes using the *optimal division* ``[n/p]``: a ceiling division with a
heuristic that promotes quotients that are multiples of the hardware vector
length.  On the paper's CPUs that quantum is 8 doubles (512-bit SIMD); on TPU
the natural quanta are the lane count (128) and sublane count (8).  Promoting
the quotient may *lower* the effective process count (Fig. 1, s=2:
``[4/3] -> 4/2`` uses only two of the three requested processes).

JAX shard_map requires equal-size shards, so the planner also reports the
padding needed to reach ``p_eff * chunk`` elements.  Padding is mathematically
safe for TVC/HOPM: padded slabs contribute exact zeros (k = s) or produce
output rows that are sliced away on assembly (k != s).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

#: TPU-oriented quanta: prefer full lane multiples, then sublane multiples.
LANE = 128
SUBLANE = 8


@dataclasses.dataclass(frozen=True)
class SplitPlan:
    """Plan for splitting mode ``s`` of size ``n`` over ``p`` requested procs."""

    n: int            # global extent of the split dimension
    p_requested: int  # processes asked for
    p: int            # processes actually used (may be < p_requested)
    chunk: int        # elements per process ([n/p], the optimal division)
    pad: int          # zeros appended so that p * chunk == n + pad
    s: int = 0        # split dimension (bookkeeping)

    @property
    def padded_n(self) -> int:
        return self.p * self.chunk

    def bounds(self, rank: int) -> tuple[int, int]:
        """Global [lo, hi) range owned by ``rank`` (unpadded extent)."""
        lo = rank * self.chunk
        hi = min(self.n, (rank + 1) * self.chunk)
        return lo, max(lo, hi)


def optimal_division(n: int, p: int, quantum: int = SUBLANE) -> int:
    """The paper's ``[n/p]``: ceiling division promoted to vector multiples.

    Rounds the ceiling quotient up to a multiple of ``quantum`` whenever the
    quotient is at least one quantum wide; otherwise plain ceiling division.
    """
    if p <= 0:
        raise ValueError(f"p must be positive, got {p}")
    chunk = -(-n // p)
    if quantum > 1 and chunk >= quantum and chunk % quantum:
        promoted = chunk + (quantum - chunk % quantum)
        # Never promote past the whole dimension.
        if promoted <= n:
            chunk = promoted
    return chunk


def plan_split(n: int, p: int, s: int = 0, quantum: int = SUBLANE) -> SplitPlan:
    """Build a :class:`SplitPlan` for splitting an ``n``-extent mode over ``p``."""
    chunk = optimal_division(n, p, quantum)
    p_eff = -(-n // chunk)
    pad = p_eff * chunk - n
    return SplitPlan(n=n, p_requested=p, p=p_eff, chunk=chunk, pad=pad, s=s)


def plan_split_for_mesh(n: int, p: int, s: int = 0, quantum: int = SUBLANE) -> SplitPlan:
    """Like :func:`plan_split` but always uses exactly ``p`` shards (mesh axes
    are fixed); the optimal-division heuristic only shapes the chunk, and any
    deficit is realized as padding (idle tail shards hold zeros)."""
    chunk = optimal_division(n, p, quantum)
    # A fixed mesh axis cannot drop processes; shrink the chunk back so that
    # p shards cover n with minimal padding, keeping quantum alignment when
    # possible.
    while (p - 1) * chunk >= n + chunk:  # an entire shard would be empty
        if chunk > quantum and chunk % quantum == 0 and chunk - quantum > 0:
            chunk -= quantum
        else:
            chunk = max(1, -(-n // p))
            break
    chunk = max(chunk, -(-n // p))
    pad = p * chunk - n
    return SplitPlan(n=n, p_requested=p, p=p, chunk=chunk, pad=pad, s=s)


def best_split_dim(shape: Sequence[int], p: int, *, avoid: int | None = None) -> int:
    """Paper guidance: split along the *last* dimension (minimum streamed
    memory, Eq. 6) whose extent can host ``p`` processes, avoiding the
    contraction mode ``avoid`` (Eq. 2 is the suboptimal k = s case)."""
    d = len(shape)
    for s in range(d - 1, -1, -1):
        if s == avoid:
            continue
        if shape[s] >= p:
            return s
    # Fall back to the largest dimension != avoid.
    order = sorted(range(d), key=lambda i: shape[i], reverse=True)
    for s in order:
        if s != avoid:
            return s
    return d - 1


def shard_shape(shape: Sequence[int], plan: SplitPlan) -> tuple[int, ...]:
    out = list(shape)
    out[plan.s] = plan.chunk
    return tuple(out)
