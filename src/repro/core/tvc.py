"""Tensor–vector contraction (TVC) — paper §2 and §4.1, single device.

For a d-order tensor ``A`` in last-order (C) layout and contraction mode
``k``, define ``u = prod(shape[:k])`` and ``v = prod(shape[k+1:])``.  The
contiguous 3-D *view* ``A[u, n_k, v]`` is free (a reshape, never a copy) and

    Y[u, v] = sum_k A[u, k, v] * x[k]            (arithmetic intensity 1–2)

Three algorithms are provided, mirroring the paper's taxonomy:

* ``native``   — the paper's mode-oblivious algorithm: one streaming pass over
  the (u, n_k, v) view.  On TPU this dispatches to the Pallas kernel in
  :mod:`repro.kernels`; elsewhere it is a single fused einsum with a
  high-precision accumulator.
* ``looped``   — the BLAS-2 baseline: one matvec for k = d-1, otherwise u
  batched vector–matrix products (the cblas_gemv_batch_strided /
  cublasGemvStridedBatched analogue).  Mode-aware, used as the baseline.
* ``unfolded`` — transpose the tensor to move mode k last, materialize the
  unfolding (extra data movement), then one single matvec.

All variants honour the BLAS-style update ``Y = alpha * (A x_k x) + beta * Y``
and a :class:`~repro.core.mixed_precision.Precision` policy (low-precision
storage, high-precision accumulation).
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from .mixed_precision import F32, Precision, get_policy

__all__ = [
    "mode_uv",
    "tvc_shape",
    "tvc",
    "tvc_bytes",
    "tvc2_bytes",
    "tvc_batched",
    "tvc2_batched",
    "IMPLS",
]

IMPLS = ("native", "looped", "unfolded", "pallas", "mulsum")


def mode_uv(shape: Sequence[int], k: int) -> tuple[int, int, int]:
    """(u, n_k, v) for contracting mode ``k`` of ``shape``."""
    d = len(shape)
    if not 0 <= k < d:
        raise ValueError(f"mode k={k} out of range for order-{d} tensor")
    u = math.prod(shape[:k])
    v = math.prod(shape[k + 1:])
    return u, shape[k], v


def tvc_shape(shape: Sequence[int], k: int) -> tuple[int, ...]:
    """Output shape: mode ``k`` removed."""
    return tuple(shape[:k]) + tuple(shape[k + 1:])


def tvc_bytes(shape: Sequence[int], k: int, itemsize: int, beta: float = 0.0) -> int:
    """Streamed (touched) memory of one TVC: read A, read x, write Y
    (+ read Y when beta != 0).  This is the denominator of the paper's
    bandwidth metric.

    The Pallas path now streams *exactly* these bytes: ragged shapes are
    handled with in-kernel edge masking (no padded copies of A), and the
    ``beta != 0`` update is fused into the kernel epilogue (one extra read of
    Y, not a second axpby pass).  See
    :func:`repro.core.memory_model.tvc_padded_copy_elems` for what the old
    pad-and-copy wrapper used to stream."""
    n = math.prod(shape)
    nk = shape[k]
    out = n // nk
    y_traffic = out * (2 if beta else 1)
    return (n + nk + y_traffic) * itemsize


def tvc2_bytes(shape: Sequence[int], k1: int, k2: int, itemsize: int,
               beta: float = 0.0) -> int:
    """Streamed (touched) memory of one *fused-pair* contraction over
    adjacent modes (k1, k2 = k1+1): read A, read both vectors, write Y
    (+ read Y when beta != 0).  The single-launch Pallas pair kernels move
    exactly these bytes — the order-(d-1) intermediate of the two-launch
    reference never exists (see
    :func:`repro.core.memory_model.tvc2_streamed_elems`)."""
    if k2 != k1 + 1:
        raise ValueError(f"tvc2 fuses adjacent modes only, got {k1},{k2}")
    n = math.prod(shape)
    n1, n2 = shape[k1], shape[k2]
    out = n // (n1 * n2)
    y_traffic = out * (2 if beta else 1)
    return (n + n1 + n2 + y_traffic) * itemsize


def _out_dtype(A, prec: Precision):
    """Output storage dtype under ``prec``: a storage-less policy keeps the
    input's dtype.  Shared by every tvc/tvc2 variant (single and batched) so
    no path can crash on ``prec.storage is None`` while another survives."""
    return A.dtype if prec.storage is None else prec.storage


def _contract_core(a3, x, prec: Precision):
    """Y[u,v] = sum_k A[u,k,v] x[k] with high-precision accumulation."""
    return jnp.einsum(
        "ukv,k->uv", a3, x, preferred_element_type=prec.compute
    )


def _native(a3, x, prec):
    return _contract_core(a3, x, prec)


def _tree_sum_axis(t: jax.Array, axis: int) -> jax.Array:
    """Sum along ``axis`` with an *order-explicit, contraction-proof*
    doubling tree, used by the bitwise-batchable ``mulsum`` engine and
    dHOPM's iterate norms.  Two cross-program drift sources are closed:

    1. **Reduce order** — XLA's reduce emitter picks its accumulation order
       per fusion context, and the same ``jnp.sum`` can compile with a
       *different* order in a batched program than in the per-sample one.
       Here the order is an explicit fold: zero-pad to the next power of
       two (IEEE-exact — x + 0 == x) and halve with elementwise adds, which
       cannot be reassociated, in any context, for any leading batch dims.

    2. **FMA contraction** — LLVM may contract a multiply feeding an add
       into a single-rounding fmuladd in one program but not the other
       (``optimization_barrier`` does not survive the CPU pipeline, and the
       contraction is value-changing whenever the product is inexact).
       The callers' products enter the first fold adds, so the tree scales
       every input by 0.5 and the result by 2.0 — both exact (power-of-two
       exponent shifts), and an fmuladd of an *exact* product rounds
       identically to the plain multiply-then-add, making any contraction
       harmless by construction.

    The price is materializing the fold intermediates (~2x the streamed
    traffic of a fused multiply+reduce) — the documented cost of the
    engine's bitwise guarantee."""
    n = t.shape[axis]
    m = 1 << max(n - 1, 0).bit_length()
    if m != n:
        pad = [(0, 0)] * t.ndim
        pad[axis] = (0, m - n)
        t = jnp.pad(t, pad)
    t = t * jnp.asarray(0.5, t.dtype)
    while t.shape[axis] > 1:
        h = t.shape[axis] // 2
        t = lax.slice_in_dim(t, 0, h, axis=axis) + \
            lax.slice_in_dim(t, h, 2 * h, axis=axis)
    return lax.squeeze(t, (axis % t.ndim,)) * jnp.asarray(2.0, t.dtype)


def _tree_sum_last(t: jax.Array) -> jax.Array:
    """:func:`_tree_sum_axis` over the trailing axis."""
    return _tree_sum_axis(t, t.ndim - 1)


def _mulsum(a3, x, prec):
    """Bitwise-batchable native variant: broadcast-multiply + axis
    reduction instead of a ``dot_general``.  Same math and streamed traffic
    as :func:`_native` (XLA fuses the multiply into the reduce), but the
    per-output-element accumulation order does not change when a leading
    batch dim is stacked in front — ``dot_general``'s does on CPU.  This is
    the engine :mod:`repro.train.grad_compress` runs so its bucketed
    (stacked) scheduler reproduces the per-leaf loop bit for bit.

    The multiply+reduce itself is bitwise-stable under batching, but when
    XLA fuses it into *surrounding* producers/consumers (collectives,
    chained contractions in a shard_map region) the fusion shape — and with
    it the last bit — can differ between the stacked and per-sample
    programs; the dtvc shard ops therefore wrap every mulsum contraction in
    an ``optimization_barrier`` fusion island (the barrier lives there, not
    here, because it has no vmap batching rule and ``tvc_batched`` vmaps
    this function).  Every reduce runs through the order-explicit
    :func:`_tree_sum_axis` — ``jnp.sum`` would leave the accumulation order
    to the fusion context, which differs between the stacked and per-sample
    programs."""
    a = a3.astype(prec.compute)
    xv = x.astype(prec.compute)
    return _tree_sum_axis(a * xv[None, :, None], 1)


def _looped(a3, x, prec):
    u, nk, v = a3.shape
    if v == 1:
        # k = d-1: one matrix-vector multiplication over A^{u x n_k}.
        a2 = a3.reshape(u, nk)
        y = lax.dot_general(
            a2, x, (((1,), (0,)), ((), ())), preferred_element_type=prec.compute
        )
        return y.reshape(u, 1)
    # k < d-1: u independent vector-matrix multiplications x^T A^{n_k x v}.
    def one(mat):  # (nk, v)
        return lax.dot_general(
            x, mat, (((0,), (0,)), ((), ())), preferred_element_type=prec.compute
        )
    return jax.vmap(one)(a3)  # (u, v)


def _unfolded(a3, x, prec):
    u, nk, v = a3.shape
    # Materialize the k-mode unfolding A^{uv x n_k}: a genuine transpose (the
    # paper's "additional computation and data movement").  The optimization
    # barrier stops XLA from fusing the transpose into the matvec, keeping the
    # algorithmic distinction observable.
    unf = jnp.transpose(a3, (0, 2, 1)).reshape(u * v, nk)
    unf = lax.optimization_barrier(unf)
    y = lax.dot_general(
        unf, x, (((1,), (0,)), ((), ())), preferred_element_type=prec.compute
    )
    return y.reshape(u, v)


def tvc(
    A: jax.Array,
    x: jax.Array,
    k: int,
    *,
    alpha: float = 1.0,
    beta: float = 0.0,
    y: jax.Array | None = None,
    impl: str = "native",
    prec: Precision | str = F32,
):
    """``Y = alpha * (A x_k x) + beta * Y`` — the paper's TVC (Eq. 1 local part).

    ``A`` may be any order >= 1; ``x`` must have shape ``(A.shape[k],)``.
    The result has ``A``'s shape with mode ``k`` removed and ``A``'s storage
    dtype under policy ``prec``.
    """
    prec = get_policy(prec)
    shape = A.shape
    u, nk, v = mode_uv(shape, k)
    if x.shape != (nk,):
        raise ValueError(f"x shape {x.shape} incompatible with mode {k} of {shape}")
    a3 = A.reshape(u, nk, v)
    out_dtype = _out_dtype(A, prec)

    if impl == "auto":
        from repro.plan import planner as _planner
        impl = _planner.resolve_impl("auto", "tvc", shape, k,
                                     itemsize=prec.storage_bytes)
    if impl == "pallas":
        from repro.kernels import ops as kops  # local import: optional dep cycle
        if isinstance(alpha, (int, float)) and isinstance(beta, (int, float)):
            # Static alpha/beta: the BLAS update runs inside the kernel
            # epilogue (one extra read of y, no second axpby pass).
            if float(beta) != 0.0 and y is None:
                raise ValueError("beta != 0 requires y")
            y_in = None if float(beta) == 0.0 else y.reshape(u, v)
            y2 = kops.tvc_pallas(a3, x, y_in, alpha=float(alpha),
                                 beta=float(beta), prec=prec)
            return y2.reshape(tvc_shape(shape, k)).astype(out_dtype)
        # Traced alpha/beta (rare): fall through to the generic epilogue —
        # a second launch, counted so the de-optimization is observable.
        from repro.plan import planner as _planner
        _planner.epilogue_fallback("tvc", impl)
        y2 = kops.tvc_pallas(a3, x, prec=prec)
    elif impl == "native":
        y2 = _native(a3, x, prec)
    elif impl == "mulsum":
        y2 = _mulsum(a3, x, prec)
    elif impl == "looped":
        y2 = _looped(a3, x, prec)
    elif impl == "unfolded":
        y2 = _unfolded(a3, x, prec)
    else:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")

    y2 = y2.astype(prec.compute)
    if isinstance(alpha, (int, float)) and isinstance(beta, (int, float)):
        if float(alpha) != 1.0:
            y2 = y2 * jnp.asarray(alpha, prec.compute)
        if float(beta) != 0.0:
            if y is None:
                raise ValueError("beta != 0 requires y")
            y2 = y2 + jnp.asarray(beta, prec.compute) * \
                y.reshape(u, v).astype(prec.compute)
    else:
        # traced scalars: never branch a Python bool on a tracer
        y2 = y2 * jnp.asarray(alpha, prec.compute)
        if y is not None:
            y2 = y2 + jnp.asarray(beta, prec.compute) * \
                y.reshape(u, v).astype(prec.compute)
    return y2.reshape(tvc_shape(shape, k)).astype(out_dtype)


def tvc2(
    A: jax.Array,
    x1: jax.Array,
    k1: int,
    x2: jax.Array,
    k2: int,
    *,
    alpha: float = 1.0,
    beta: float = 0.0,
    y: jax.Array | None = None,
    impl: str = "native",
    prec: Precision | str = F32,
):
    """BEYOND-PAPER: fused two-mode contraction — one streaming pass computes
    ``Y = alpha * ((A x_{k1} x1) x_{k2'} x2) + beta * Y`` without
    materializing the order-(d-1) intermediate, cutting the streamed memory
    of a contraction pair from N + 2N/n_{k1} + N/(n_{k1} n_{k2}) to
    N + N/(n_{k1} n_{k2}).  Requires k2 == k1 + 1 (HOPM chains contract
    consecutive modes).  With ``impl="pallas"`` this is ONE kernel launch:
    the pair kernels in repro.kernels (two sequential reduction grid dims;
    a dedicated tail kernel when the pair ends the mode list, v == 1) with
    the BLAS update fused into the emit epilogue, exactly like single-mode
    ``tvc``.
    """
    if k2 != k1 + 1:
        raise ValueError(f"tvc2 fuses adjacent modes only, got {k1},{k2}")
    prec = get_policy(prec)
    shape = A.shape
    u = math.prod(shape[:k1])
    n1, n2 = shape[k1], shape[k2]
    v = math.prod(shape[k2 + 1:])
    if x1.shape != (n1,) or x2.shape != (n2,):
        raise ValueError("vector shapes incompatible with fused modes")
    a4 = A.reshape(u, n1, n2, v)
    out_shape = tuple(shape[:k1]) + tuple(shape[k2 + 1:])
    static_ab = isinstance(alpha, (int, float)) and isinstance(beta, (int, float))
    if static_ab and float(beta) != 0.0 and y is None:
        raise ValueError("beta != 0 requires y")
    if impl == "auto":
        from repro.plan import planner as _planner
        impl = _planner.resolve_impl("auto", "tvc2", shape, k1,
                                     itemsize=prec.storage_bytes,
                                     static_ab=static_ab)
    if impl == "pallas":
        from repro.kernels import ops as kops
        if static_ab:
            # Static alpha/beta: the whole update runs inside the single
            # kernel launch (one extra read of y, no second pass).
            y_in = None if float(beta) == 0.0 else y.reshape(u, v)
            out = kops.tvc2_pallas(a4, x1, x2, y_in, alpha=float(alpha),
                                   beta=float(beta), prec=prec)
            return out.reshape(out_shape).astype(_out_dtype(A, prec))
        # Traced alpha/beta: the fused epilogue cannot run and the update
        # goes out as a SECOND launch.  The decision is routed through the
        # planner (plan_tvc2(static_ab=False) prices pallas at two
        # launches) and counted, so the former silent fallback is visible
        # in plan_report().
        from repro.plan import planner as _planner
        _planner.epilogue_fallback("tvc2", impl)
        out = kops.tvc2_pallas(a4, x1, x2, prec=prec)
    elif impl == "mulsum":
        # bitwise-batchable fused pair: the (n1, n2) reduce runs as ONE
        # order-explicit tree over the row-major-flattened pair axis (the
        # fusion-island barrier is applied by the dtvc shard ops; see
        # _mulsum / _tree_sum_axis)
        a = a4.astype(prec.compute)
        w = x1.astype(prec.compute)[None, :, None, None] * \
            x2.astype(prec.compute)[None, None, :, None]
        out = _tree_sum_axis((a * w).reshape(u, n1 * n2, v), 1)
    else:
        out = jnp.einsum("uabv,a,b->uv", a4, x1, x2,
                         preferred_element_type=prec.compute)
    out = out.astype(prec.compute)
    if static_ab:
        if float(alpha) != 1.0:
            out = out * jnp.asarray(alpha, prec.compute)
        if float(beta) != 0.0:
            out = out + jnp.asarray(beta, prec.compute) * \
                y.reshape(u, v).astype(prec.compute)
    else:
        # traced scalars: no Python-bool branching on tracer values — apply
        # the update unconditionally (a traced beta requires y; a traced
        # "beta == 0" is indistinguishable from any other runtime value)
        out = out * jnp.asarray(alpha, prec.compute)
        if y is not None:
            out = out + jnp.asarray(beta, prec.compute) * \
                y.reshape(u, v).astype(prec.compute)
    return out.reshape(out_shape).astype(_out_dtype(A, prec))


def _vmap_axes(y, alpha, beta):
    """in_axes for the per-sample oracle: arrays map over the batch, static
    scalars broadcast (vmapping a Python float would fail)."""
    ax = lambda s: 0 if hasattr(s, "ndim") and getattr(s, "ndim", 0) >= 1 \
        else None
    return (0 if y is not None else None, ax(alpha), ax(beta))


def tvc_batched(
    A: jax.Array,
    x: jax.Array,
    k: int,
    *,
    alpha=1.0,
    beta=0.0,
    y: jax.Array | None = None,
    impl: str = "native",
    prec: Precision | str = F32,
):
    """Batched TVC over a stacked ``A[B, n_0..n_{d-1}]``: B independent
    mode-``k`` contractions (``k`` indexes the *per-sample* shape) against
    per-batch vectors ``x[B, n_k]``.

    With ``impl="pallas"`` this is ONE kernel launch for the whole batch
    (leading batch grid dim — dispatch overhead paid once, the
    ``cublasGemvStridedBatched`` schedule of the paper's GPU baseline);
    every other impl is the ``jax.vmap`` of the per-sample oracle, which is
    also the correctness reference.  ``alpha``/``beta`` may be scalars or
    per-batch ``[B]`` arrays; ``y`` is the stacked update operand."""
    prec = get_policy(prec)
    B = A.shape[0]
    shape = A.shape[1:]
    u, nk, v = mode_uv(shape, k)
    if x.shape != (B, nk):
        raise ValueError(
            f"x shape {x.shape} incompatible with batch {B}, mode {k} of "
            f"{tuple(shape)}")
    out_shape = (B,) + tvc_shape(shape, k)
    if impl == "auto":
        from repro.plan import planner as _planner
        impl = _planner.resolve_impl("auto", "batched", tuple(shape), k,
                                     itemsize=prec.storage_bytes, batch=B)
    if impl == "pallas":
        from repro.kernels import ops as kops
        y_in = None if y is None else y.reshape(B, u, v)
        out = kops.tvc_pallas_batched(A.reshape(B, u, nk, v), x, y_in,
                                      alpha=alpha, beta=beta, prec=prec)
        return out.reshape(out_shape).astype(_out_dtype(A, prec))
    y_ax, a_ax, b_ax = _vmap_axes(y, alpha, beta)
    fn = jax.vmap(
        lambda A_, x_, y_, al_, be_: tvc(A_, x_, k, alpha=al_, beta=be_,
                                         y=y_, impl=impl, prec=prec),
        in_axes=(0, 0, y_ax, a_ax, b_ax))
    return fn(A.reshape((B,) + tuple(shape)), x,
              None if y is None else y.reshape((B,) + tvc_shape(shape, k)),
              alpha, beta).reshape(out_shape)


def tvc2_batched(
    A: jax.Array,
    x1: jax.Array,
    k1: int,
    x2: jax.Array,
    k2: int,
    *,
    alpha=1.0,
    beta=0.0,
    y: jax.Array | None = None,
    impl: str = "native",
    prec: Precision | str = F32,
):
    """Batched fused-pair contraction over a stacked ``A[B, ...]``: B
    independent adjacent-mode pairs (``k2 == k1 + 1`` in the per-sample
    shape) in ONE streaming pass — and, with ``impl="pallas"``, ONE kernel
    launch for the whole batch.  See :func:`tvc2` for the fused-pair
    semantics and :func:`tvc_batched` for the batching contract."""
    if k2 != k1 + 1:
        raise ValueError(f"tvc2 fuses adjacent modes only, got {k1},{k2}")
    prec = get_policy(prec)
    B = A.shape[0]
    shape = A.shape[1:]
    u = math.prod(shape[:k1])
    n1, n2 = shape[k1], shape[k2]
    v = math.prod(shape[k2 + 1:])
    if x1.shape != (B, n1) or x2.shape != (B, n2):
        raise ValueError("vector shapes incompatible with batched fused modes")
    out_shape = (B,) + tuple(shape[:k1]) + tuple(shape[k2 + 1:])
    if impl == "auto":
        from repro.plan import planner as _planner
        impl = _planner.resolve_impl("auto", "batched", tuple(shape), k1,
                                     itemsize=prec.storage_bytes, batch=B)
    if impl == "pallas":
        from repro.kernels import ops as kops
        y_in = None if y is None else y.reshape(B, u, v)
        out = kops.tvc2_pallas_batched(A.reshape(B, u, n1, n2, v), x1, x2,
                                       y_in, alpha=alpha, beta=beta,
                                       prec=prec)
        return out.reshape(out_shape).astype(_out_dtype(A, prec))
    y_ax, a_ax, b_ax = _vmap_axes(y, alpha, beta)
    fn = jax.vmap(
        lambda A_, x1_, x2_, y_, al_, be_: tvc2(
            A_, x1_, k1, x2_, k2, alpha=al_, beta=be_, y=y_, impl=impl,
            prec=prec),
        in_axes=(0, 0, 0, y_ax, a_ax, b_ax))
    return fn(A.reshape((B,) + tuple(shape)), x1, x2,
              None if y is None else y.reshape(out_shape),
              alpha, beta).reshape(out_shape)


def tvc_chain(
    A: jax.Array,
    xs: Sequence[jax.Array],
    modes: Sequence[int],
    *,
    impl: str = "native",
    prec: Precision | str = F32,
):
    """Contract ``A`` along the given *global* modes (ascending or not) with
    the matching vectors.  Mode indices refer to the original tensor; the
    helper tracks the shift as dimensions disappear.  Used by HOPM.
    """
    prec = get_policy(prec)
    remaining = list(range(A.ndim))
    cur = A
    for m in modes:
        ax = remaining.index(m)
        cur = tvc(cur, xs[m], ax, impl=impl, prec=prec)
        remaining.pop(ax)
    return cur
