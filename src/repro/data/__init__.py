"""Data substrate."""
from .pipeline import DataConfig, SyntheticLMData  # noqa: F401
