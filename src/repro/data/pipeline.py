"""Deterministic synthetic LM data pipeline with production semantics:
global-batch -> per-host shard -> device layout (DP over pod+data), async
prefetch, and stateless resume (the stream is a pure function of (seed, step),
so checkpoint/restart and elastic re-sharding replay exactly)."""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    extra_key: Optional[str] = None      # img_embeds | audio_embeds
    extra_shape: Optional[tuple] = None  # per-example shape of the stub input
    prefetch: int = 2


class SyntheticLMData:
    """Markov-ish synthetic tokens: deterministic per (seed, step, example).

    In a real multi-host deployment each process materializes only its
    addressable slice (jax.process_index-based row range); this container is
    single-process so the full global batch is built and then laid out with
    the DP sharding."""

    def __init__(self, cfg: DataConfig, mesh: Mesh | None = None):
        self.cfg = cfg
        self.mesh = mesh
        self._spec = None
        if mesh is not None:
            dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
            self._spec = P(dp if len(dp) > 1 else dp[0])

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        # low-entropy structured stream: learnable by small models in a few
        # hundred steps (next-token = affine function of current + noise)
        base = rng.integers(0, cfg.vocab_size, size=(cfg.global_batch, 1))
        steps = rng.integers(1, 7, size=(cfg.global_batch, 1))
        idx = np.arange(cfg.seq_len)[None, :]
        tokens = (base + steps * idx) % cfg.vocab_size
        noise = rng.random(size=tokens.shape) < 0.02
        tokens = np.where(noise, rng.integers(0, cfg.vocab_size, tokens.shape), tokens)
        out = {"tokens": tokens.astype(np.int32)}
        if cfg.extra_key:
            out[cfg.extra_key] = rng.normal(
                size=(cfg.global_batch,) + tuple(cfg.extra_shape)
            ).astype(np.float32)
        return out

    def device_put(self, batch: dict):
        if self.mesh is None or self._spec is None:
            return {k: jnp.asarray(v) for k, v in batch.items()}
        out = {}
        for k, v in batch.items():
            spec = P(*(self._spec + (None,) * (v.ndim - 1)))
            out[k] = jax.device_put(v, NamedSharding(self.mesh, spec))
        return out

    def __iter__(self) -> Iterator[dict]:
        return self.iterate(0)

    def iterate(self, start_step: int) -> Iterator[dict]:
        """Async-prefetched stream starting at ``start_step`` (resume point)."""
        q: queue.Queue = queue.Queue(maxsize=self.cfg.prefetch)
        stop = threading.Event()

        def producer():
            step = start_step
            while not stop.is_set():
                q.put(self.batch_at(step))
                step += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield self.device_put(q.get())
        finally:
            stop.set()
            try:
                q.get_nowait()  # unblock producer
            except queue.Empty:
                pass
