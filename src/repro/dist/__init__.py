"""Distribution layer: mixed-precision wire collectives (paper §5.5) and the
role-based sharding rule tables that map model parameter/cache trees onto
named mesh axes.

The split of responsibilities follows the paper (and Shi et al.'s extended
BLAS dispatch discipline): :mod:`repro.core` stays mode-oblivious and purely
local, while this package owns every byte that crosses the wire —

* :mod:`repro.dist.collectives` — ``mp_allreduce`` (Σ of Eq. 2, delayed
  reduction of Algorithm 1) with storage-precision hops and
  compute-precision accumulation, ``all_gather_tiled`` (⊔ of Eq. 1), and the
  analytic ``wire_bytes_allreduce`` ring/doubling cost models.
* :mod:`repro.dist.sharding` — ``AxisEnv`` + qualified path→role tables
  (tp/fsdp, divisibility-gated, replicate-on-mismatch) producing
  ``param_specs``/``cache_specs``/``named_shardings``, plus the
  activation-sharding context (``constrain``) and perf toggles
  (``set_opts``/``opt_enabled``).
"""
from . import collectives  # noqa: F401
from . import sharding  # noqa: F401
from .collectives import (  # noqa: F401
    all_gather_tiled,
    mp_allreduce,
    mp_allreduce_doubling,
    mp_allreduce_ring,
    wire_bytes_allgather,
    wire_bytes_allreduce,
)
from .sharding import (  # noqa: F401
    KNOWN_OPTS,
    AxisEnv,
    activation_sharding,
    axis_env_for,
    batch_spec,
    cache_specs,
    constrain,
    named_shardings,
    opt_enabled,
    param_specs,
    set_opts,
    spec_for_leaf,
)

__all__ = [
    "collectives",
    "sharding",
    "mp_allreduce",
    "mp_allreduce_ring",
    "mp_allreduce_doubling",
    "all_gather_tiled",
    "wire_bytes_allreduce",
    "wire_bytes_allgather",
    "AxisEnv",
    "activation_sharding",
    "axis_env_for",
    "batch_spec",
    "cache_specs",
    "constrain",
    "named_shardings",
    "opt_enabled",
    "param_specs",
    "set_opts",
    "spec_for_leaf",
    "KNOWN_OPTS",
]
