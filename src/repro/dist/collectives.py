"""Mixed-precision wire collectives (paper §5.5) and their analytic cost
models.

The paper's mixed-precision discipline keeps *storage* (and therefore every
byte on the wire) in a low format while *accumulating* partial sums in a high
format — "every arithmetic operation, besides accumulations, is done in high
precision".  MPI has no reduction that promotes mid-flight, which is why the
paper needed ad-hoc reduction functions; here the same semantics are built
from ``jax.lax.ppermute`` ring/doubling steps inside shard_map manual
regions: each hop demotes the payload to ``prec.storage`` before it crosses
the wire and promotes it back to ``prec.compute`` before adding.

Two all-reduce schedules are provided, mirroring the classic cost split that
Chakaravarthy et al. analyze for distributed Tucker (gather-heavy vs
reduce-heavy mode handling):

* ``ring`` — bandwidth-optimal: reduce-scatter then all-gather,
  2·(p-1)/p·n elements through every link (the large-tensor regime).
* ``doubling`` — latency-optimal recursive doubling: log2(p) exchanges of
  the full n elements (the small-vector regime of Algorithm 1's delayed
  n_j-sized reductions — exactly what dHOPM_3 and the gradient compressor
  put on the wire).

``wire_bytes_allreduce`` exposes the closed forms so
``train.grad_compress.wire_bytes_summary`` and the roofline report can
account wire traffic without compiling anything.

All ``mp_*`` functions must run inside a shard_map manual region over
``axis_name`` and return ``prec.compute``-dtype values (callers demote).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.mixed_precision import Precision, get_policy

__all__ = [
    "allreduce_algo",
    "mp_allreduce",
    "mp_allreduce_ring",
    "mp_allreduce_doubling",
    "mp_reduce_scatter",
    "all_gather_tiled",
    "StagedAllreduce",
    "staged_allreduce",
    "staged_tree_allreduce",
    "wire_bytes_allreduce",
    "wire_bytes_allgather",
]

#: payload size (elements) up to which the latency-optimal doubling schedule
#: beats ring on a power-of-two axis; above it ring's 2(p-1)/p·n bytes win
#: over doubling's log2(p)·n.  Chosen at the delayed-reduction scale: the
#: n_j-sized HOPM vectors sit far below it, dense gradient leaves far above.
DOUBLING_MAX_ELEMENTS = 1 << 16


def allreduce_algo(n: int, p: int) -> str:
    """Schedule the dispatcher (and the analytic accounting) agree on:
    recursive doubling for small payloads on power-of-two axes, ring
    otherwise."""
    if p & (p - 1) == 0 and n <= DOUBLING_MAX_ELEMENTS:
        return "doubling"
    return "ring"


def _axis_size(axis_name) -> int:
    return int(lax.axis_size(axis_name))


def _ring_perm(p: int):
    return [(i, (i + 1) % p) for i in range(p)]


def _rs_step(parts: jax.Array, s: int, r, axis_name: str, perm,
             prec: Precision) -> jax.Array:
    """One reduce-scatter hop: at step s, rank r forwards the partial sum of
    chunk (r - s) mod p and folds the incoming chunk (r - s - 1) mod p into
    its accumulator (demote on the wire, promote for the add)."""
    c_send = (r - s) % len(perm)
    c_recv = (r - s - 1) % len(perm)
    wire = lax.dynamic_slice_in_dim(parts, c_send, 1, 0).astype(prec.storage)
    recv = lax.ppermute(wire, axis_name, perm)
    cur = lax.dynamic_slice_in_dim(parts, c_recv, 1, 0)
    return lax.dynamic_update_slice_in_dim(
        parts, cur + recv.astype(prec.compute), c_recv, 0)


def _ring_pad(x: jax.Array, p: int, prec: Precision):
    """Flatten + zero-pad to p equal chunks; returns ((p, m) parts, n)."""
    flat = x.reshape(-1).astype(prec.compute)
    n = flat.shape[0]
    m = -(-n // p)
    if m * p != n:
        flat = jnp.pad(flat, (0, m * p - n))
    return flat.reshape(p, m), n


def mp_reduce_scatter(x: jax.Array, axis_name: str,
                      prec: Precision | str) -> jax.Array:
    """§5.5 reduce-scatter building block (the first half of the ring
    all-reduce): p-1 storage-precision hops, after which this process owns
    the fully reduced chunk (r+1) mod p of the flattened payload (zero-padded
    to p equal chunks of ceil(n/p) elements).  Returns that chunk in
    ``prec.compute``.  At p = 1 it degenerates to the promoted flat payload.
    """
    prec = get_policy(prec)
    p = _axis_size(axis_name)
    if p == 1:
        return x.reshape(-1).astype(prec.compute)
    parts, _ = _ring_pad(x, p, prec)
    r = lax.axis_index(axis_name)
    perm = _ring_perm(p)
    for s in range(p - 1):
        parts = _rs_step(parts, s, r, axis_name, perm, prec)
    own = (r + 1) % p
    return lax.dynamic_slice_in_dim(parts, own, 1, 0)[0]


def mp_allreduce_ring(x: jax.Array, axis_name: str,
                      prec: Precision | str) -> jax.Array:
    """Ring all-reduce with storage-precision hops (reduce-scatter +
    all-gather, the bandwidth-optimal schedule).

    The local value is flattened and padded to ``p`` equal chunks.  During
    reduce-scatter every partial-sum chunk is demoted to ``prec.storage``
    before each of the p-1 hops and re-promoted to ``prec.compute`` for the
    add; the final all-gather likewise moves storage-precision bytes only.
    Total wire traffic per process: 2·(p-1)·ceil(n/p) elements (the pad
    rides the wire too — ``wire_bytes_allreduce`` prices the same).
    """
    prec = get_policy(prec)
    p = _axis_size(axis_name)
    if p == 1:
        return x.reshape(-1).astype(prec.compute).reshape(x.shape)
    n = x.size
    mine = mp_reduce_scatter(x, axis_name, prec).astype(prec.storage)
    m = mine.shape[0]
    gathered = lax.all_gather(mine, axis_name, axis=0, tiled=True)  # (p*m,)
    # Rank j contributed chunk (j+1)%p, so chunk c sits at offset ((c-1)%p)*m:
    # chunk 0 is the last run and chunks 1..p-1 lead.  Restore chunk order by
    # concatenating the two runs — a static slice/concat, not a full-payload
    # jnp.roll copy.
    out = jnp.concatenate([gathered[(p - 1) * m:], gathered[:(p - 1) * m]])
    return out.astype(prec.compute)[:n].reshape(x.shape)


def mp_allreduce_doubling(x: jax.Array, axis_name: str,
                          prec: Precision | str) -> jax.Array:
    """Recursive-doubling all-reduce with storage-precision hops.

    log2(p) exchanges of the full payload with partners at distance
    2^s — the latency-optimal schedule for the small n_j-sized vectors of
    Algorithm 1's delayed reductions.  Requires a power-of-two axis size.
    """
    prec = get_policy(prec)
    p = _axis_size(axis_name)
    acc = x.astype(prec.compute)
    if p == 1:
        return acc
    if p & (p - 1):
        raise ValueError(
            f"recursive doubling needs a power-of-two axis size, got {p}; "
            "use mp_allreduce_ring (or mp_allreduce, which dispatches)")
    d = 1
    while d < p:
        perm = [(i, i ^ d) for i in range(p)]
        recv = lax.ppermute(acc.astype(prec.storage), axis_name, perm)
        acc = acc + recv.astype(prec.compute)
        d *= 2
    return acc


def mp_allreduce(x: jax.Array, axis_name: str, prec: Precision | str,
                 algo: str = "auto", force_schedule: bool = False) -> jax.Array:
    """The §5.5 mixed-precision Σ over ``axis_name``.

    Fast path: when ``prec.storage == prec.compute`` there is nothing to
    demote on the wire, and the reduction is exactly ``lax.psum`` — let XLA
    pick its native schedule.  Otherwise the explicit ppermute schedules
    above carry storage-precision bytes, dispatched by
    :func:`allreduce_algo`: ``doubling`` for small payloads on power-of-two
    axes (fewer roundings *and* fewer hops for the delayed-reduction
    vectors), ``ring`` for large tensors (bandwidth-optimal) — the same rule
    the analytic ``wire_bytes_summary`` accounting applies.

    ``force_schedule=True`` skips the psum fast path and runs the explicit
    ppermute schedule even when storage == compute (no precision change —
    demote is then the identity).  The pipelined dHOPM3 walker needs this so
    its synchronous and overlapped modes share hop-for-hop arithmetic: the
    staged reductions below are built from the same explicit hops, and
    psum's schedule is XLA's to choose.
    """
    prec = get_policy(prec)
    if not force_schedule and jnp.dtype(prec.storage) == jnp.dtype(prec.compute):
        return lax.psum(x.astype(prec.compute), axis_name)
    p = _axis_size(axis_name)
    if algo == "auto":
        algo = allreduce_algo(x.size, p)
    if algo == "ring":
        return mp_allreduce_ring(x, axis_name, prec)
    if algo == "doubling":
        return mp_allreduce_doubling(x, axis_name, prec)
    raise ValueError(f"unknown all-reduce algo {algo!r}; "
                     "choose from ('auto', 'ring', 'doubling')")


def all_gather_tiled(x: jax.Array, axis_name: str, axis: int = 0) -> jax.Array:
    """The ⊔ assembly of Eq. (1): concatenate the per-process shards along
    ``axis`` (tiled all-gather — no new leading processor dimension)."""
    return lax.all_gather(x, axis_name, axis=axis, tiled=True)


@dataclasses.dataclass(frozen=True)
class StagedAllreduce:
    """A resumable mp_allreduce: the same storage-precision hops, one wire
    exchange per ``step()``.

    This is the overlap seam of the pipelined dHOPM3 walker (paper §6's
    task-based overlap of communication and contraction): the caller launches
    an independent kernel, advances every in-flight reduction by one hop,
    launches the next kernel, and so on — each hop's ppermute has no data
    dependence on the interleaved launches, so XLA's latency-hiding
    scheduler is free to put the wire behind the compute.

    Hop arithmetic is identical to the monolithic schedules:

    * ``doubling`` — each step is one distance-2^s exchange-and-add of the
      full payload (elementwise, so per-chunk staging of a larger payload is
      bitwise-equal to reducing it whole).
    * ``ring`` — p-1 reduce-scatter steps (the ``_rs_step`` hops of
      :func:`mp_reduce_scatter`) followed by p-1 all-gather steps that walk
      each process's reduced chunk around the ring, scattering it straight
      into its global slot (layout-only, value-identical to the tiled
      all-gather + reorder epilogue of :func:`mp_allreduce_ring`).

    Instances are immutable; ``step()`` returns the advanced reduction.  Use
    within a single trace only (this is not a pytree).
    """
    axis_name: str
    prec: Precision
    algo: str
    p: int
    shape: tuple
    n: int
    hops_done: int
    hops_total: int
    payload: jax.Array        # doubling: compute-dtype accumulator
    gather: jax.Array | None = None   # ring: (storage wire chunk, (p, m) out)

    @property
    def done(self) -> bool:
        return self.hops_done >= self.hops_total

    def step(self) -> "StagedAllreduce":
        """Issue exactly one wire hop; returns the advanced reduction."""
        if self.done:
            return self
        if self.algo == "doubling":
            d = 1 << self.hops_done
            perm = [(i, i ^ d) for i in range(self.p)]
            recv = lax.ppermute(self.payload.astype(self.prec.storage),
                                self.axis_name, perm)
            nxt = self.payload + recv.astype(self.prec.compute)
            return dataclasses.replace(self, payload=nxt,
                                       hops_done=self.hops_done + 1)
        # ring: reduce-scatter phase, then chunk-walk all-gather phase
        p = self.p
        r = lax.axis_index(self.axis_name)
        if self.hops_done < p - 1:                      # reduce-scatter hop
            parts = _rs_step(self.payload, self.hops_done, r, self.axis_name,
                             _ring_perm(p), self.prec)
            nxt = self
            if self.hops_done + 1 == p - 1:             # RS done: seed gather
                own = (r + 1) % p
                mine = lax.dynamic_slice_in_dim(parts, own, 1, 0)
                out = jnp.zeros_like(parts, dtype=self.prec.storage)
                out = lax.dynamic_update_slice_in_dim(
                    out, mine.astype(self.prec.storage), own, 0)
                nxt = dataclasses.replace(
                    nxt, gather=(mine.astype(self.prec.storage), out))
            return dataclasses.replace(nxt, payload=parts,
                                       hops_done=self.hops_done + 1)
        # all-gather hop s: after s forwards rank r holds the chunk rank
        # (r - s) contributed, whose global slot is (r - s + 1) mod p.
        s = self.hops_done - (p - 1) + 1
        wire, out = self.gather
        wire = lax.ppermute(wire, self.axis_name, _ring_perm(p))
        out = lax.dynamic_update_slice_in_dim(out, wire, (r - s + 1) % p, 0)
        return dataclasses.replace(self, gather=(wire, out),
                                   hops_done=self.hops_done + 1)

    def result(self) -> jax.Array:
        """The reduced value (``prec.compute``, original shape).  Requires
        ``done``."""
        if not self.done:
            raise ValueError(
                f"staged all-reduce has {self.hops_total - self.hops_done} "
                "hops left; call step() (or drain()) first")
        if self.algo == "doubling" or self.p == 1:
            return self.payload.reshape(self.shape)
        _, out = self.gather
        return out.reshape(-1).astype(self.prec.compute)[:self.n].reshape(
            self.shape)

    def drain(self) -> jax.Array:
        """Run every remaining hop back-to-back and return the result —
        the synchronous tail of the pipeline (e.g. at the j == split
        all-gather boundary, or when no launches are left to interleave)."""
        op = self
        while not op.done:
            op = op.step()
        return op.result()


def staged_allreduce(x: jax.Array, axis_name: str, prec: Precision | str,
                     algo: str = "auto") -> StagedAllreduce:
    """Begin a resumable mixed-precision all-reduce of ``x`` over
    ``axis_name`` (see :class:`StagedAllreduce`).  Dispatch mirrors
    :func:`mp_allreduce`'s explicit schedules; drain() of the staged form is
    value-identical to ``mp_allreduce(..., force_schedule=True)`` — and
    bitwise-identical hop arithmetic, which is what lets the pipelined
    walker interleave the hops without perturbing a single rounding."""
    prec = get_policy(prec)
    p = _axis_size(axis_name)
    if algo == "auto":
        algo = allreduce_algo(x.size, p)
    if algo not in ("ring", "doubling"):
        raise ValueError(f"unknown all-reduce algo {algo!r}; "
                         "choose from ('auto', 'ring', 'doubling')")
    if p == 1:
        return StagedAllreduce(axis_name, prec, algo, p, x.shape, x.size,
                               0, 0, x.astype(prec.compute))
    if algo == "doubling":
        if p & (p - 1):
            raise ValueError(
                f"recursive doubling needs a power-of-two axis size, got {p}")
        return StagedAllreduce(axis_name, prec, algo, p, x.shape, x.size,
                               0, int(math.log2(p)), x.astype(prec.compute))
    parts, n = _ring_pad(x, p, prec)
    return StagedAllreduce(axis_name, prec, algo, p, x.shape, n,
                           0, 2 * (p - 1), parts)


def staged_tree_allreduce(tree, axis_name: str, prec: Precision | str):
    """Round-robin-stepped staged reduction over every leaf of ``tree``: all
    leaves start their schedules, then advance one hop each in turn, so leaf
    i's wire hop can overlap leaf j's — the adoption seam for train_loop's
    per-leaf gradient sync (TrainConfig.staged_wire).  Values match per-leaf
    ``mp_allreduce(..., force_schedule=True)`` with auto dispatch."""
    leaves, treedef = jax.tree.flatten(tree)
    ops = [staged_allreduce(leaf, axis_name, prec) for leaf in leaves]
    while any(not op.done for op in ops):
        ops = [op if op.done else op.step() for op in ops]
    return jax.tree.unflatten(treedef, [op.result() for op in ops])


def wire_bytes_allreduce(n: int, p: int, itemsize: int,
                         algo: str = "ring") -> float:
    """Per-process wire bytes of an n-element all-reduce over p processes.

    Closed forms (received bytes per process, the standard accounting):

    * ``ring``      — 2·(p-1)·ceil(n/p)·itemsize  (reduce-scatter +
      all-gather; the payload is padded to p equal chunks and the pad rides
      the wire, so pricing uses the padded chunk size, not n/p)
    * ``doubling``  — log2(p)·n·itemsize    (recursive doubling)
    """
    if p <= 1 or n <= 0:
        return 0.0
    if algo == "ring":
        m = -(-n // p)  # ceil(n / p): padded chunk length actually shipped
        return 2.0 * (p - 1) * m * itemsize
    if algo == "doubling":
        return math.ceil(math.log2(p)) * float(n) * itemsize
    raise ValueError(f"unknown all-reduce algo {algo!r}")


def wire_bytes_allgather(n: int, p: int, itemsize: int) -> float:
    """Per-process wire bytes of gathering an n-element result split over p
    processes (the Eq. 1 ⊔): (p-1)/p·n·itemsize received per process."""
    if p <= 1 or n <= 0:
        return 0.0
    return (p - 1) / p * n * itemsize
