"""Mixed-precision wire collectives (paper §5.5) and their analytic cost
models.

The paper's mixed-precision discipline keeps *storage* (and therefore every
byte on the wire) in a low format while *accumulating* partial sums in a high
format — "every arithmetic operation, besides accumulations, is done in high
precision".  MPI has no reduction that promotes mid-flight, which is why the
paper needed ad-hoc reduction functions; here the same semantics are built
from ``jax.lax.ppermute`` ring/doubling steps inside shard_map manual
regions: each hop demotes the payload to ``prec.storage`` before it crosses
the wire and promotes it back to ``prec.compute`` before adding.

Two all-reduce schedules are provided, mirroring the classic cost split that
Chakaravarthy et al. analyze for distributed Tucker (gather-heavy vs
reduce-heavy mode handling):

* ``ring`` — bandwidth-optimal: reduce-scatter then all-gather,
  2·(p-1)/p·n elements through every link (the large-tensor regime).
* ``doubling`` — latency-optimal recursive doubling: log2(p) exchanges of
  the full n elements (the small-vector regime of Algorithm 1's delayed
  n_j-sized reductions — exactly what dHOPM_3 and the gradient compressor
  put on the wire).

``wire_bytes_allreduce`` exposes the closed forms so
``train.grad_compress.wire_bytes_summary`` and the roofline report can
account wire traffic without compiling anything.

All ``mp_*`` functions must run inside a shard_map manual region over
``axis_name`` and return ``prec.compute``-dtype values (callers demote).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.mixed_precision import Precision, get_policy

__all__ = [
    "allreduce_algo",
    "mp_allreduce",
    "mp_allreduce_ring",
    "mp_allreduce_doubling",
    "all_gather_tiled",
    "wire_bytes_allreduce",
    "wire_bytes_allgather",
]

#: payload size (elements) up to which the latency-optimal doubling schedule
#: beats ring on a power-of-two axis; above it ring's 2(p-1)/p·n bytes win
#: over doubling's log2(p)·n.  Chosen at the delayed-reduction scale: the
#: n_j-sized HOPM vectors sit far below it, dense gradient leaves far above.
DOUBLING_MAX_ELEMENTS = 1 << 16


def allreduce_algo(n: int, p: int) -> str:
    """Schedule the dispatcher (and the analytic accounting) agree on:
    recursive doubling for small payloads on power-of-two axes, ring
    otherwise."""
    if p & (p - 1) == 0 and n <= DOUBLING_MAX_ELEMENTS:
        return "doubling"
    return "ring"


def _axis_size(axis_name) -> int:
    return int(lax.axis_size(axis_name))


def _ring_perm(p: int):
    return [(i, (i + 1) % p) for i in range(p)]


def mp_allreduce_ring(x: jax.Array, axis_name: str,
                      prec: Precision | str) -> jax.Array:
    """Ring all-reduce with storage-precision hops (reduce-scatter +
    all-gather, the bandwidth-optimal schedule).

    The local value is flattened and padded to ``p`` equal chunks.  During
    reduce-scatter every partial-sum chunk is demoted to ``prec.storage``
    before each of the p-1 hops and re-promoted to ``prec.compute`` for the
    add; the final all-gather likewise moves storage-precision bytes only.
    Total wire traffic per process: 2·(p-1)/p·n elements.
    """
    prec = get_policy(prec)
    p = _axis_size(axis_name)
    flat = x.reshape(-1).astype(prec.compute)
    if p == 1:
        return flat.reshape(x.shape)
    n = flat.shape[0]
    m = -(-n // p)
    if m * p != n:
        flat = jnp.pad(flat, (0, m * p - n))
    parts = flat.reshape(p, m)
    r = lax.axis_index(axis_name)
    perm = _ring_perm(p)

    # Reduce-scatter: at step s, rank r forwards the partial sum of chunk
    # (r - s) mod p and folds the incoming chunk (r - s - 1) mod p into its
    # accumulator.  After p-1 steps rank r owns the complete chunk (r+1)%p.
    for s in range(p - 1):
        c_send = (r - s) % p
        c_recv = (r - s - 1) % p
        wire = lax.dynamic_slice_in_dim(parts, c_send, 1, 0).astype(prec.storage)
        recv = lax.ppermute(wire, axis_name, perm)
        cur = lax.dynamic_slice_in_dim(parts, c_recv, 1, 0)
        parts = lax.dynamic_update_slice_in_dim(
            parts, cur + recv.astype(prec.compute), c_recv, 0)

    own = (r + 1) % p
    mine = lax.dynamic_slice_in_dim(parts, own, 1, 0)[0].astype(prec.storage)
    gathered = lax.all_gather(mine, axis_name, axis=0, tiled=True)  # (p*m,)
    # Rank j contributed chunk (j+1)%p, so chunk c sits at offset ((c-1)%p)*m;
    # one roll by m restores chunk order (== the original flat layout).
    out = jnp.roll(gathered.astype(prec.compute), m)[:n]
    return out.reshape(x.shape)


def mp_allreduce_doubling(x: jax.Array, axis_name: str,
                          prec: Precision | str) -> jax.Array:
    """Recursive-doubling all-reduce with storage-precision hops.

    log2(p) exchanges of the full payload with partners at distance
    2^s — the latency-optimal schedule for the small n_j-sized vectors of
    Algorithm 1's delayed reductions.  Requires a power-of-two axis size.
    """
    prec = get_policy(prec)
    p = _axis_size(axis_name)
    acc = x.astype(prec.compute)
    if p == 1:
        return acc
    if p & (p - 1):
        raise ValueError(
            f"recursive doubling needs a power-of-two axis size, got {p}; "
            "use mp_allreduce_ring (or mp_allreduce, which dispatches)")
    d = 1
    while d < p:
        perm = [(i, i ^ d) for i in range(p)]
        recv = lax.ppermute(acc.astype(prec.storage), axis_name, perm)
        acc = acc + recv.astype(prec.compute)
        d *= 2
    return acc


def mp_allreduce(x: jax.Array, axis_name: str, prec: Precision | str,
                 algo: str = "auto") -> jax.Array:
    """The §5.5 mixed-precision Σ over ``axis_name``.

    Fast path: when ``prec.storage == prec.compute`` there is nothing to
    demote on the wire, and the reduction is exactly ``lax.psum`` — let XLA
    pick its native schedule.  Otherwise the explicit ppermute schedules
    above carry storage-precision bytes, dispatched by
    :func:`allreduce_algo`: ``doubling`` for small payloads on power-of-two
    axes (fewer roundings *and* fewer hops for the delayed-reduction
    vectors), ``ring`` for large tensors (bandwidth-optimal) — the same rule
    the analytic ``wire_bytes_summary`` accounting applies.
    """
    prec = get_policy(prec)
    if jnp.dtype(prec.storage) == jnp.dtype(prec.compute):
        return lax.psum(x.astype(prec.compute), axis_name)
    p = _axis_size(axis_name)
    if algo == "auto":
        algo = allreduce_algo(x.size, p)
    if algo == "ring":
        return mp_allreduce_ring(x, axis_name, prec)
    if algo == "doubling":
        return mp_allreduce_doubling(x, axis_name, prec)
    raise ValueError(f"unknown all-reduce algo {algo!r}; "
                     "choose from ('auto', 'ring', 'doubling')")


def all_gather_tiled(x: jax.Array, axis_name: str, axis: int = 0) -> jax.Array:
    """The ⊔ assembly of Eq. (1): concatenate the per-process shards along
    ``axis`` (tiled all-gather — no new leading processor dimension)."""
    return lax.all_gather(x, axis_name, axis=axis, tiled=True)


def wire_bytes_allreduce(n: int, p: int, itemsize: int,
                         algo: str = "ring") -> float:
    """Per-process wire bytes of an n-element all-reduce over p processes.

    Closed forms (received bytes per process, the standard accounting):

    * ``ring``      — 2·(p-1)/p·n·itemsize  (reduce-scatter + all-gather)
    * ``doubling``  — log2(p)·n·itemsize    (recursive doubling)
    """
    if p <= 1 or n <= 0:
        return 0.0
    if algo == "ring":
        return 2.0 * (p - 1) / p * n * itemsize
    if algo == "doubling":
        return math.ceil(math.log2(p)) * float(n) * itemsize
    raise ValueError(f"unknown all-reduce algo {algo!r}")


def wire_bytes_allgather(n: int, p: int, itemsize: int) -> float:
    """Per-process wire bytes of gathering an n-element result split over p
    processes (the Eq. 1 ⊔): (p-1)/p·n·itemsize received per process."""
    if p <= 1 or n <= 0:
        return 0.0
    return (p - 1) / p * n * itemsize
