"""Role-based sharding rule tables + activation-sharding context.

Parameters, optimizer state, KV caches and activations are mapped onto the
named mesh axes (``pod``/``data``/``model``) through *roles* rather than raw
axis names, so the model code never mentions the mesh:

* ``tp``   — tensor-parallel: shard over the ``model`` axis;
* ``fsdp`` — fully-sharded data parallel: shard over the data axes
  (``pod``+``data`` when present), gated on a minimum leaf size;
* ``dp``   — batch dims of activations, over the data axes;
* ``sp``   — sequence-parallel activation/KV-timeline dims, over ``model``.

Every role is **divisibility-gated**: a dim that the target axes do not
divide evenly falls back to replication (never padded, never errored) — the
"replicate-on-mismatch" contract pinned by ``tests/test_sharding.py``.

The table is *qualified by path*: rules match on the leaf name, optionally
its parent (e.g. RWKV's channel-mix ``ffn/wv`` is an out-projection while
attention's ``att/wv`` is an in-projection), and the model config (MoE expert
tables carry a leading expert dim).  Leading layer-stack dims (``vmap``-ed
layer params) are implicitly replicated by left-padding the matched rule.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import Mapping, Optional, Sequence

import jax
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "AxisEnv",
    "DEFAULT_FSDP_MIN_SIZE",
    "KNOWN_OPTS",
    "activation_sharding",
    "axis_env_for",
    "batch_spec",
    "cache_specs",
    "constrain",
    "current_mesh",
    "named_shardings",
    "opt_enabled",
    "param_specs",
    "set_opts",
    "spec_for_leaf",
]

#: data-parallel axes in slowest-to-fastest order; ``model`` is the TP axis.
DP_AXES = ("pod", "data")
TP_AXIS = "model"

#: below this many elements a leaf is never FSDP-sharded (the all-gather
#: latency would dominate any memory win).
DEFAULT_FSDP_MIN_SIZE = 1 << 22


@dataclasses.dataclass(frozen=True)
class AxisEnv:
    """Mesh shape + FSDP policy, the only inputs the rule table needs.

    ``mesh_shape``    — axis name → size (pure shape: the rules are mesh-
                        geometry functions, testable on a 1-device mesh).
    ``fsdp_axes``     — axes the ``fsdp`` role shards over (empty disables
                        FSDP, e.g. for serving layouts).
    ``fsdp_min_size`` — element-count threshold below which ``fsdp`` leaves
                        replicate.
    """

    mesh_shape: Mapping[str, int]
    fsdp_axes: tuple[str, ...] = ()
    fsdp_min_size: int = DEFAULT_FSDP_MIN_SIZE

    def axis_size(self, name: str) -> int:
        return int(self.mesh_shape.get(name, 1))

    @property
    def fsdp_size(self) -> int:
        return math.prod(self.axis_size(a) for a in self.fsdp_axes) \
            if self.fsdp_axes else 1


def axis_env_for(mesh, *, fsdp: bool = True,
                 fsdp_min_size: int = DEFAULT_FSDP_MIN_SIZE) -> AxisEnv:
    """AxisEnv for a concrete mesh (training default: FSDP over pod+data)."""
    shape = dict(mesh.shape)
    axes = tuple(a for a in DP_AXES if a in shape) if fsdp else ()
    return AxisEnv(mesh_shape=shape, fsdp_axes=axes,
                   fsdp_min_size=fsdp_min_size)


# --------------------------------------------------------------------------
# qualified path -> role tables
# --------------------------------------------------------------------------

#: sentinel: replicate every dim of the leaf, whatever its rank.
REPLICATE = "replicate"

#: rules for a leaf's own (unstacked) dims, keyed by leaf name.  Leading
#: layer-stack dims are left-padded with None at match time.
_NAME_RULES: dict[str, object] = {
    # in-projections (d_model, out): FSDP the contraction dim, TP the output
    "wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
    "wg": ("fsdp", "tp"), "wr": ("fsdp", "tp"),
    "w_q": ("fsdp", "tp"), "w_gate": ("fsdp", "tp"), "w_up": ("fsdp", "tp"),
    "w_x": ("fsdp", "tp"), "w_a": ("fsdp", "tp"), "w_i": ("fsdp", "tp"),
    "img_proj": ("fsdp", "tp"),
    # out-projections (in, d_model): transposed roles
    "wo": ("tp", "fsdp"), "w_o": ("tp", "fsdp"), "w_out": ("tp", "fsdp"),
    "w_down": ("tp", "fsdp"),
    # embeddings: vocab over TP (divisibility-gated: only padded vocabs
    # shard), d_model over FSDP
    "tok": ("tp", "fsdp"),
    # biases ride the TP-sharded output dim of their matmul
    "bq": ("tp",), "bk": ("tp",), "bv": ("tp",), "b_up": ("tp",),
    # norms / small per-channel vectors: replicated
    "scale": REPLICATE, "bias": REPLICATE, "bo": REPLICATE,
    "b_down": REPLICATE, "b_a": REPLICATE, "b_i": REPLICATE,
    "lam": REPLICATE, "conv_w": REPLICATE, "conv_b": REPLICATE,
    "ln_x_scale": REPLICATE, "ln_x_bias": REPLICATE,
    "ckv_scale": REPLICATE, "bonus_u": REPLICATE,
    # RWKV data-dependent mixing/decay LoRAs: explicitly unsharded (tiny
    # inner rank; sharding them costs more collective latency than compute)
    "maa_x": REPLICATE, "maa_base": REPLICATE,
    "maa_w1": REPLICATE, "maa_w2": REPLICATE,
    "decay_base": REPLICATE, "decay_w1": REPLICATE, "decay_w2": REPLICATE,
    "mu_k": REPLICATE, "mu_r": REPLICATE,
}

#: (parent, name) rules — more specific than _NAME_RULES.
_QUALIFIED_RULES: dict[tuple[str, str], object] = {
    # RWKV channel-mix: wv is the (d_ff, d_model) out-projection while the
    # generic wv rule is the attention in-projection
    ("ffn", "wv"): ("tp", "fsdp"),
    ("ffn", "wk"): ("fsdp", "tp"),
    # MLA: latent down-projection replicates its small latent dim; the
    # decompression tables shard over heads (TP)
    ("mla", "w_dkv"): ("fsdp", None),
    ("mla", "w_uk"): (None, "tp", None),
    ("mla", "w_uv"): (None, "tp", None),
}

#: MoE expert tables (leading expert dim is the EP==TP dim); active when the
#: config has an MoE block and the leaf lives under ``ffn``.
_MOE_RULES: dict[str, object] = {
    "router": ("fsdp", None),
    "w_gate": ("tp", "fsdp", None),
    "w_up": ("tp", "fsdp", None),
    "w_down": ("tp", None, "fsdp"),
}


def _key_name(entry) -> str:
    """Normalize a tree-path entry (DictKey / GetAttrKey / plain str)."""
    for attr in ("key", "name", "idx"):
        if hasattr(entry, attr):
            return str(getattr(entry, attr))
    return str(entry)


def _roles_for(names: Sequence, shape: Sequence[int], cfg) -> tuple:
    """Resolve the per-dim roles for a leaf at qualified path ``names``.

    Returns a tuple of len(shape) entries from {"tp", "fsdp", None}.  The
    matched rule covers the leaf's own trailing dims; leading stack dims
    (vmapped layers / super-blocks) get None by left-padding.
    """
    names = [_key_name(n) for n in names]
    name = names[-1] if names else ""
    parent = names[-2] if len(names) >= 2 else ""
    rule = None
    if cfg is not None and getattr(cfg, "moe", None) is not None \
            and parent == "ffn" and name in _MOE_RULES:
        rule = _MOE_RULES[name]
    elif (parent, name) in _QUALIFIED_RULES:
        rule = _QUALIFIED_RULES[(parent, name)]
    elif name in _NAME_RULES:
        rule = _NAME_RULES[name]
    elif len(shape) >= 2:
        rule = ("fsdp", "tp")  # generic matmul weight: in-proj roles
    else:
        rule = REPLICATE
    if rule == REPLICATE:
        return (None,) * len(shape)
    rule = tuple(rule)
    if len(rule) > len(shape):
        rule = rule[len(rule) - len(shape):]
    return (None,) * (len(shape) - len(rule)) + rule


def _entry_for_role(role, dim: int, n_elements: int, ax: AxisEnv):
    """Role -> PartitionSpec entry, divisibility- and size-gated."""
    if role == "tp":
        if TP_AXIS in ax.mesh_shape and dim % ax.axis_size(TP_AXIS) == 0:
            return TP_AXIS
        return None
    if role == "fsdp":
        axes = ax.fsdp_axes
        if axes and n_elements >= ax.fsdp_min_size and dim % ax.fsdp_size == 0:
            return axes[0] if len(axes) == 1 else tuple(axes)
        return None
    return None


def spec_for_leaf(path, leaf, cfg, ax: AxisEnv) -> P:
    """PartitionSpec for one parameter leaf (path entries carry ``.key``)."""
    shape = tuple(leaf.shape)
    roles = _roles_for(list(path), shape, cfg)
    n = math.prod(shape) if shape else 0
    return P(*[_entry_for_role(r, d, n, ax) for d, r in zip(shape, roles)])


def param_specs(cfg, tree, mesh, *, fsdp: bool = True,
                env: Optional[AxisEnv] = None):
    """PartitionSpec tree aligned leaf-for-leaf with the parameter tree."""
    ax = env if env is not None else axis_env_for(mesh, fsdp=fsdp)
    return jax.tree_util.tree_map_with_path(
        lambda p, l: spec_for_leaf(p, l, cfg, ax), tree)


def named_shardings(cfg, tree, mesh, *, fsdp: bool = True,
                    env: Optional[AxisEnv] = None):
    """NamedSharding tree for jit ``in/out_shardings`` / ``device_put``."""
    ax = env if env is not None else axis_env_for(mesh, fsdp=fsdp)
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, spec_for_leaf(p, l, cfg, ax)), tree)


# --------------------------------------------------------------------------
# cache specs (serving layout)
# --------------------------------------------------------------------------

#: attention caches laid out (stack, B, ..., S, feat): batch over the data
#: axes, the KV *timeline* sequence-sharded over ``model`` (SP — the paper's
#: "keep outputs distributed" discipline applied to the cache).
_SEQ_CACHE_KEYS = frozenset({"k", "v", "xk", "xv", "c", "pe"})
#: recurrent states (stack, B, ...): batch-sharded only.
_BATCH_CACHE_KEYS = frozenset({"att_x", "ffn_x", "wkv", "h", "conv",
                               "tail_h", "tail_conv"})
_SCALAR_CACHE_KEYS = frozenset({"pos", "slot_pos"})


def _dp_entry(mesh_shape: Mapping[str, int], dim: int):
    axes = tuple(a for a in DP_AXES if a in mesh_shape)
    if not axes:
        return None
    total = math.prod(int(mesh_shape[a]) for a in axes)
    if dim % total:
        return None
    return axes[0] if len(axes) == 1 else axes


def cache_specs(cfg, tree, mesh):
    """PartitionSpec tree for a decode cache (KV timeline / recurrent
    states).  Sequence dims shard over ``model`` (SP), batch dims over the
    data axes; positions and ragged bookkeeping replicate."""
    shape_by_axis = dict(mesh.shape)
    tp = int(shape_by_axis.get(TP_AXIS, 1))

    def leaf(path, l):
        name = _key_name(path[-1]) if path else ""
        shape = tuple(l.shape)
        if not shape or name in _SCALAR_CACHE_KEYS:
            return P()
        entries = [None] * len(shape)
        if name in _SEQ_CACHE_KEYS and len(shape) >= 3:
            entries[1] = _dp_entry(shape_by_axis, shape[1])
            if TP_AXIS in shape_by_axis and shape[-2] % tp == 0:
                entries[-2] = TP_AXIS
        elif name in _BATCH_CACHE_KEYS and len(shape) >= 2:
            # batch dim: right after the stack dims (super-block states are
            # stacked twice)
            b_dim = 2 if name in ("h", "conv") and len(shape) >= 4 else 1
            entries[b_dim] = _dp_entry(shape_by_axis, shape[b_dim])
        return P(*entries)

    return jax.tree_util.tree_map_with_path(leaf, tree)


def batch_spec(mesh, ndim: int = 2) -> P:
    """Spec for a (B, ...) host batch: leading dim over the data axes."""
    axes = tuple(a for a in DP_AXES if a in mesh.shape)
    lead = (axes[0] if len(axes) == 1 else axes) if axes else None
    return P(lead, *(None,) * max(0, ndim - 1))


# --------------------------------------------------------------------------
# activation sharding context + perf toggles
# --------------------------------------------------------------------------

_MESH_STACK: list = []

#: perf toggles consumed across the stack (``--opts`` on the dry-run CLI):
#:   serving_replicated_params — serving cells drop FSDP weight sharding
#:   seq_shard_activations    — SP the residual stream between blocks
#:   moe_bf16_combine         — half-width EP combine psum
KNOWN_OPTS = frozenset({
    "serving_replicated_params",
    "seq_shard_activations",
    "moe_bf16_combine",
})
_ENABLED_OPTS: set = set()


def set_opts(names) -> None:
    """Replace the enabled perf-toggle set (validated against KNOWN_OPTS)."""
    names = set(names)
    unknown = names - KNOWN_OPTS
    if unknown:
        raise ValueError(
            f"unknown opts {sorted(unknown)}; choose from {sorted(KNOWN_OPTS)}")
    _ENABLED_OPTS.clear()
    _ENABLED_OPTS.update(names)


def opt_enabled(name: str) -> bool:
    if name not in KNOWN_OPTS:
        raise ValueError(f"unknown opt {name!r}; known: {sorted(KNOWN_OPTS)}")
    return name in _ENABLED_OPTS


@contextlib.contextmanager
def activation_sharding(mesh):
    """Make ``mesh`` the target of :func:`constrain` inside the block.

    Model code calls ``constrain(x, *roles)`` unconditionally; outside this
    context (unit tests, single-device runs) it is a literal no-op."""
    _MESH_STACK.append(mesh)
    try:
        yield mesh
    finally:
        _MESH_STACK.pop()


def current_mesh():
    return _MESH_STACK[-1] if _MESH_STACK else None


def _axis_is_manual(name: str) -> bool:
    """True when ``name`` is currently bound as a shard_map manual axis (the
    per-shard layout is explicit there; sharding constraints over it would be
    meaningless and are rejected by jax)."""
    try:
        lax.axis_size(name)
        return True
    except Exception:
        return False


def _strip_manual(entry):
    if entry is None:
        return None
    names = entry if isinstance(entry, tuple) else (entry,)
    kept = tuple(n for n in names if not _axis_is_manual(n))
    if not kept:
        return None
    return kept[0] if len(kept) == 1 else kept


def constrain(x, *roles):
    """with_sharding_constraint by role ("dp" | "sp" | "tp" | None per dim),
    against the mesh installed by :func:`activation_sharding`.  Identity when
    no mesh is active; every role is divisibility-gated like the rule table,
    and roles over axes the caller has already made manual (dp_explicit's
    shard_map region) are dropped."""
    mesh = current_mesh()
    if mesh is None:
        return x
    if len(roles) != x.ndim:
        raise ValueError(
            f"constrain got {len(roles)} roles for a rank-{x.ndim} array")
    shape_by_axis = dict(mesh.shape)
    entries = []
    for dim, role in zip(x.shape, roles):
        if role is None:
            entries.append(None)
        elif role == "dp":
            entries.append(_dp_entry(shape_by_axis, dim))
        elif role in ("sp", "tp"):
            tp = int(shape_by_axis.get(TP_AXIS, 1))
            entries.append(TP_AXIS if TP_AXIS in shape_by_axis
                           and dim % tp == 0 else None)
        else:
            raise ValueError(f"unknown activation role {role!r}")
    entries = [_strip_manual(e) for e in entries]
    if all(e is None for e in entries):
        return x
    return lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))
