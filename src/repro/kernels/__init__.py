"""Pallas TPU kernels for the perf-critical hot spots.

* :mod:`tvc_kernel` — the paper's native mode-oblivious TVC (HBM->VMEM
  streaming, mixed-precision accumulator, ragged ``pl.cdiv`` grids with
  in-kernel edge masking, fused alpha/beta epilogue).
* :mod:`axpby`      — the paper's §5.5 mixed-precision axpby (zero-copy).
* :mod:`autotune`   — VMEM-aware block-size selection (dtype tiling quantum,
  byte budget, view aspect ratio).
* :mod:`ops`        — jit'd wrappers (autotuned dispatch, views; no padding).
* :mod:`ref`        — pure-jnp oracles.
"""
from . import autotune, ops, ref  # noqa: F401
