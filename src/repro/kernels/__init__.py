"""Pallas TPU kernels for the perf-critical hot spots.

* :mod:`tvc_kernel` — the paper's native mode-oblivious TVC (HBM->VMEM
  streaming, mixed-precision accumulator).
* :mod:`axpby`      — the paper's §5.5 mixed-precision axpby.
* :mod:`ops`        — jit'd wrappers (padding, dispatch, views).
* :mod:`ref`        — pure-jnp oracles.
"""
from . import ops, ref  # noqa: F401
