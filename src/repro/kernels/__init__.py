"""Pallas TPU kernels for the perf-critical hot spots.

* :mod:`tvc_kernel` — the paper's native mode-oblivious TVC (HBM->VMEM
  streaming, mixed-precision accumulator, ragged ``pl.cdiv`` grids with
  in-kernel edge masking, fused alpha/beta epilogue), plus the *batched*
  variants: a leading batch grid dim streams B independent same-shape
  contractions per launch (per-batch vectors and alpha/beta).
* :mod:`axpby`      — the paper's §5.5 mixed-precision axpby (zero-copy,
  tiled ragged view; batched per-row variant).
* :mod:`autotune`   — block-size selection: offline sweep-table lookup first,
  VMEM-aware heuristic fallback (dtype tiling quantum, byte budget, view
  aspect ratio).
* :mod:`block_table`— the checked-in sweep winners the autotuner consults
  (regenerate with ``benchmarks/sweep_blocks.py``).
* :mod:`sweep`      — the offline (bu, bk, bv) candidate search itself.
* :mod:`ops`        — jit'd wrappers (autotuned dispatch, views; no padding).
* :mod:`ref`        — pure-jnp oracles.
"""
from . import autotune, block_table, ops, ref  # noqa: F401
