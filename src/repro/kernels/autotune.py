"""VMEM-aware block-size autotuning for the ragged TVC kernels.

The kernels in :mod:`repro.kernels.tvc_kernel` stream arbitrary (u, n_k, v)
views with ``pl.cdiv`` grids and in-kernel edge masking, so block sizes are a
pure performance knob — any choice is correct.  This module picks them from
three inputs, mirroring the paper's cache-blocking discussion (§3, §5):

* the dtype's native tiling quantum — TPU tiles the two minor dims of a VMEM
  block as (sublane, lane) = (8, 128) for f32, (16, 128) for bf16/f16 and
  (32, 128) for int8/fp8, so sublane-dim blocks are rounded to 8/16/32 and
  lane-dim blocks to 128;
* a VMEM byte budget — operand blocks are double-buffered by the Mosaic
  pipeline, so ``2 * inputs + accumulator + output`` must fit comfortably
  inside the ~16 MiB of VMEM (default budget: 8 MiB, override with the
  ``REPRO_TVC_VMEM_BUDGET`` env var or the ``budget`` argument);
* the view's aspect ratio — leftover budget is spent minor-dim first
  (v, then n_k, then u): v-blocks give the longest contiguous HBM runs in the
  last-order layout, and k-blocks amortize accumulator init/emit across the
  sequential reduction dim.

The heuristic is the *fallback*: every ``pick_*_blocks`` call first consults
the offline sweep table (:mod:`repro.kernels.block_table` — measured winners
per (kind, dtype, backend, size-bucket) cell, pinned by
``benchmarks/sweep_blocks.py``) and only runs the grow loop on a miss.
Table hits are sanitized to the dtype tiling quanta and clamped to the view,
so a stale or hand-edited table can cost bandwidth but never correctness.
Pass ``table=False`` (or set ``REPRO_TVC_DISABLE_TABLE=1``) to force the
heuristic.
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from . import block_table

__all__ = [
    "LANE",
    "sublane_quantum",
    "vmem_budget",
    "pick_tvc3_blocks",
    "pick_tvc2_blocks",
    "pick_tvc4_blocks",
    "pick_tvc2_pair_blocks",
    "pick_axpby_blocks",
    "pick_tvc3_batched_blocks",
    "pick_tvc2_batched_blocks",
    "pick_tvc4_batched_blocks",
    "pick_tvc2_pair_batched_blocks",
    "pick_axpby_batched_blocks",
]

#: lane (minormost-dim) tiling quantum — fixed across dtypes.
LANE = 128

_DEFAULT_BUDGET = 8 * 1024 * 1024


def sublane_quantum(dtype) -> int:
    """Native sublane (second-minor dim) tile for ``dtype``: 32 bytes of
    lanes-worth per sublane — 8 for f32, 16 for bf16/f16, 32 for int8."""
    return max(8, 32 // max(1, jnp.dtype(dtype).itemsize))


def vmem_budget(budget: int | None = None) -> int:
    """Resolve the VMEM byte budget (arg > env > 8 MiB default)."""
    if budget is not None:
        return int(budget)
    return int(os.environ.get("REPRO_TVC_VMEM_BUDGET", _DEFAULT_BUDGET))


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _clamp(block: int, dim: int, quantum: int) -> int:
    """Never exceed the dim rounded up to its quantum (a bigger block only
    adds masked lanes)."""
    return max(quantum, min(block, _round_up(dim, quantum)))


def _from_table(kind: str, dims: tuple[int, ...], storage,
                quanta: tuple[int, ...], cost, budget: int
                ) -> tuple[int, ...] | None:
    """Sweep-table hit for ``dims``, sanitized: each block rounded up to its
    dim's tiling quantum and clamped to the dim — block sizes are a pure
    perf knob (the kernels mask ragged edges in-kernel), so sanitizing keeps
    even a stale table entry correct.  Hits whose VMEM cost exceeds the
    caller's budget are rejected (the sweep may have run under a larger
    budget than this call site has)."""
    hit = block_table.lookup(kind, dims, storage)
    if hit is None or len(hit) != len(dims):
        return None
    blocks = tuple(
        _clamp(_round_up(max(1, int(b)), q), d, q)
        for b, d, q in zip(hit, dims, quanta)
    )
    return blocks if cost(*blocks) <= budget else None


def pick_tvc3_blocks(
    u: int,
    nk: int,
    v: int,
    *,
    storage=jnp.float32,
    compute=jnp.float32,
    has_y: bool = False,
    budget: int | None = None,
    table: bool = True,
) -> tuple[int, int, int]:
    """(bu, bk, bv) for the (u, n_k, v)-view kernel (lanes on v, sublanes on
    n_k).  Sweep-table winners (see :mod:`repro.kernels.block_table`) take
    precedence over the heuristic grow loop."""
    budget = vmem_budget(budget)
    ssz = jnp.dtype(storage).itemsize
    csz = jnp.dtype(compute).itemsize
    q = sublane_quantum(storage)

    def cost(bu: int, bk: int, bv: int) -> int:
        a_blk = 2 * bu * bk * bv * ssz          # double-buffered A stream
        x_blk = 2 * bk * ssz
        acc = bu * bv * csz
        out = bu * bv * ssz * (3 if has_y else 1)  # + double-buffered y-in
        return a_blk + x_blk + acc + out

    if table:
        hit = _from_table("tvc3", (u, nk, v), storage, (8, q, LANE),
                          cost, budget)
        if hit is not None:
            return hit

    bu = _clamp(64, u, 8)
    bk = _clamp(512, nk, q)
    bv = _clamp(512, v, LANE)
    # shrink to budget: u first (pure parallel), then k, then v
    while cost(bu, bk, bv) > budget:
        if bu > 8:
            bu = _clamp(bu // 2, u, 8)
        elif bk > q:
            bk = _clamp(_round_up(bk // 2, q), nk, q)
        elif bv > LANE:
            bv = _clamp(_round_up(bv // 2, LANE), v, LANE)
        else:
            break
    # spend leftover budget minor-dim first (aspect ratio: cover v, then k)
    for grow in ("v", "k", "u"):
        while True:
            nbu, nbk, nbv = bu, bk, bv
            if grow == "v" and bv < _round_up(v, LANE):
                nbv = _clamp(bv * 2, v, LANE)
            elif grow == "k" and bk < _round_up(nk, q):
                nbk = _clamp(bk * 2, nk, q)
            elif grow == "u" and bu < min(_round_up(u, 8), 256):
                nbu = _clamp(bu * 2, u, 8)
            else:
                break
            if (nbu, nbk, nbv) == (bu, bk, bv) or cost(nbu, nbk, nbv) > budget:
                break
            bu, bk, bv = nbu, nbk, nbv
    return bu, bk, bv


def pick_tvc2_blocks(
    u: int,
    nk: int,
    *,
    storage=jnp.float32,
    compute=jnp.float32,
    has_y: bool = False,
    budget: int | None = None,
    table: bool = True,
) -> tuple[int, int]:
    """(bu, bk) for the k = d-1 matvec kernel (lanes on n_k, sublanes on u) —
    note the quantum roles flip vs. the 3-D view: bu takes the dtype sublane
    quantum, bk the 128-lane quantum."""
    budget = vmem_budget(budget)
    ssz = jnp.dtype(storage).itemsize
    csz = jnp.dtype(compute).itemsize
    q = sublane_quantum(storage)

    def cost(bu: int, bk: int) -> int:
        return (2 * bu * bk * ssz + 2 * bk * ssz + bu * csz
                + bu * ssz * (3 if has_y else 1))

    if table:
        hit = _from_table("tvc2", (u, nk), storage, (q, LANE), cost, budget)
        if hit is not None:
            return hit

    bu = _clamp(8 * q, u, q)
    bk = _clamp(1024, nk, LANE)
    while cost(bu, bk) > budget:
        if bu > q:
            bu = _clamp(_round_up(bu // 2, q), u, q)
        elif bk > LANE:
            bk = _clamp(_round_up(bk // 2, LANE), nk, LANE)
        else:
            break
    for grow in ("k", "u"):
        while True:
            nbu, nbk = bu, bk
            if grow == "k" and bk < min(_round_up(nk, LANE), 4096):
                nbk = _clamp(bk * 2, nk, LANE)
            elif grow == "u" and bu < min(_round_up(u, q), 64 * q):
                nbu = _clamp(bu * 2, u, q)
            else:
                break
            if (nbu, nbk) == (bu, bk) or cost(nbu, nbk) > budget:
                break
            bu, bk = nbu, nbk
    return bu, bk


def pick_tvc4_blocks(
    u: int,
    n1: int,
    n2: int,
    v: int,
    *,
    storage=jnp.float32,
    compute=jnp.float32,
    has_y: bool = False,
    budget: int | None = None,
    table: bool = True,
) -> tuple[int, int, int, int]:
    """(bu, b1, b2, bv) for the fused-pair kernel: lanes on v, sublanes on
    n_2; n_1 and u are leading dims kept small so the 4-D block fits."""
    budget = vmem_budget(budget)
    ssz = jnp.dtype(storage).itemsize
    csz = jnp.dtype(compute).itemsize
    q = sublane_quantum(storage)

    def cost(bu: int, b1: int, b2: int, bv: int) -> int:
        return (2 * bu * b1 * b2 * bv * ssz + 2 * (b1 + b2) * ssz
                + bu * bv * csz + bu * bv * ssz * (3 if has_y else 1))

    if table:
        hit = _from_table("tvc4", (u, n1, n2, v), storage, (8, 8, q, LANE),
                          cost, budget)
        if hit is not None:
            return hit

    bu = _clamp(8, u, 8)
    b1 = _clamp(8, n1, 8)
    b2 = _clamp(8, n2, q)
    bv = _clamp(128, v, LANE)
    while cost(bu, b1, b2, bv) > budget and bv > LANE:
        bv = _clamp(_round_up(bv // 2, LANE), v, LANE)
    # grow minor-dim first; bu rides last (ROADMAP follow-up: no longer
    # pinned at 8 — leftover budget now covers the output tile too, and the
    # offline sweep enumerates the same axis)
    for grow in ("v", "2", "1", "u"):
        while True:
            nbu, nb1, nb2, nbv = bu, b1, b2, bv
            if grow == "v" and bv < min(_round_up(v, LANE), 512):
                nbv = _clamp(bv * 2, v, LANE)
            elif grow == "2" and b2 < min(_round_up(n2, q), 8 * q):
                nb2 = _clamp(b2 * 2, n2, q)
            elif grow == "1" and b1 < min(_round_up(n1, 8), 64):
                nb1 = _clamp(b1 * 2, n1, 8)
            elif grow == "u" and bu < min(_round_up(u, 8), 64):
                nbu = _clamp(bu * 2, u, 8)
            else:
                break
            if (nbu, nb1, nb2, nbv) == (bu, b1, b2, bv) \
                    or cost(nbu, nb1, nb2, nbv) > budget:
                break
            bu, b1, b2, bv = nbu, nb1, nb2, nbv
    return bu, b1, b2, bv


def pick_tvc2_pair_blocks(
    u: int,
    n1: int,
    n2: int,
    *,
    storage=jnp.float32,
    compute=jnp.float32,
    has_y: bool = False,
    budget: int | None = None,
    table: bool = True,
) -> tuple[int, int, int]:
    """(bu, b1, b2) for the fused-pair chain-tail kernel (v == 1): lanes on
    n_2 (the contiguous minor mode), sublanes on n_1; bu rides the output's
    sublane dim so it keeps the dtype quantum."""
    budget = vmem_budget(budget)
    ssz = jnp.dtype(storage).itemsize
    csz = jnp.dtype(compute).itemsize
    q = sublane_quantum(storage)

    def cost(bu: int, b1: int, b2: int) -> int:
        return (2 * bu * b1 * b2 * ssz + 2 * (b1 + b2) * ssz
                + bu * csz + bu * ssz * (3 if has_y else 1))

    if table:
        hit = _from_table("tvc2_pair", (u, n1, n2), storage, (q, q, LANE),
                          cost, budget)
        if hit is not None:
            return hit

    bu = _clamp(8 * q, u, q)
    b1 = _clamp(4 * q, n1, q)
    b2 = _clamp(512, n2, LANE)
    # shrink to budget: u first (pure parallel), then the outer reduction
    # dim, then the lanes
    while cost(bu, b1, b2) > budget:
        if bu > q:
            bu = _clamp(_round_up(bu // 2, q), u, q)
        elif b1 > q:
            b1 = _clamp(_round_up(b1 // 2, q), n1, q)
        elif b2 > LANE:
            b2 = _clamp(_round_up(b2 // 2, LANE), n2, LANE)
        else:
            break
    # grow minor-dim first: n_2 lanes give the contiguous HBM runs
    for grow in ("2", "1", "u"):
        while True:
            nbu, nb1, nb2 = bu, b1, b2
            if grow == "2" and b2 < min(_round_up(n2, LANE), 4096):
                nb2 = _clamp(b2 * 2, n2, LANE)
            elif grow == "1" and b1 < min(_round_up(n1, q), 16 * q):
                nb1 = _clamp(b1 * 2, n1, q)
            elif grow == "u" and bu < min(_round_up(u, q), 64 * q):
                nbu = _clamp(bu * 2, u, q)
            else:
                break
            if (nbu, nb1, nb2) == (bu, b1, b2) or cost(nbu, nb1, nb2) > budget:
                break
            bu, b1, b2 = nbu, nb1, nb2
    return bu, b1, b2


def pick_axpby_blocks(
    rows: int,
    cols: int,
    *,
    storage=jnp.float32,
    compute=jnp.float32,
    budget: int | None = None,
) -> tuple[int, int]:
    """(br, bc) for the elementwise axpby kernel over a (rows, cols) view."""
    budget = vmem_budget(budget)
    ssz = jnp.dtype(storage).itemsize
    q = sublane_quantum(storage)

    def cost(br: int, bc: int) -> int:
        return (2 + 2 + 1) * br * bc * ssz      # x, y double-buffered + out

    br = _clamp(8 * q, rows, q)
    bc = _clamp(1024, cols, LANE)
    while cost(br, bc) > budget:
        if br > q:
            br = _clamp(_round_up(br // 2, q), rows, q)
        elif bc > LANE:
            bc = _clamp(_round_up(bc // 2, LANE), cols, LANE)
        else:
            break
    return br, bc


# ---------------------------------------------------------------------------
# Batched picks: a leading batch block ``bb`` joins every tuple.  The batch
# dim is pure parallelism with no tiling quantum (it is always the outermost
# block dim), so the strategy is: size the per-sample blocks under the budget
# *divided across a target number of batch tiles*, then spend whatever is
# left growing bb — one grid step then streams many batch rows, which is the
# entire point of the batched kernels (dispatch amortization).
# ---------------------------------------------------------------------------

_BB_TARGET = 8


def _grow_bb(B: int, cost, budget: int) -> int:
    """Largest doubling bb <= B whose total block cost fits the budget
    (cost takes bb alone; at least 1 even when over budget)."""
    bb = 1
    while bb < B:
        nbb = _clamp(bb * 2, B, 1)
        if nbb == bb or cost(nbb) > budget:
            break
        bb = nbb
    return bb


def pick_tvc3_batched_blocks(
    B: int,
    u: int,
    nk: int,
    v: int,
    *,
    storage=jnp.float32,
    compute=jnp.float32,
    has_y: bool = False,
    has_ab: bool = False,
    budget: int | None = None,
    table: bool = True,
) -> tuple[int, int, int, int]:
    """(bb, bu, bk, bv) for the batched (B, u, n_k, v)-view kernel."""
    budget = vmem_budget(budget)
    ssz = jnp.dtype(storage).itemsize
    csz = jnp.dtype(compute).itemsize
    q = sublane_quantum(storage)

    def per_sample(bu: int, bk: int, bv: int) -> int:
        return (2 * bu * bk * bv * ssz + 2 * bk * ssz + bu * bv * csz
                + bu * bv * ssz * (3 if has_y else 1)
                + (4 * csz if has_ab else 0))

    def cost(bb: int, bu: int, bk: int, bv: int) -> int:
        return bb * per_sample(bu, bk, bv)

    if table:
        hit = _from_table("tvc3_batched", (B, u, nk, v), storage,
                          (1, 8, q, LANE), cost, budget)
        if hit is not None:
            return hit

    share = max(budget // min(B, _BB_TARGET), 64 * 1024)
    bu, bk, bv = pick_tvc3_blocks(
        u, nk, v, storage=storage, compute=compute, has_y=has_y,
        budget=share, table=False)
    bb = _grow_bb(B, lambda bb: cost(bb, bu, bk, bv), budget)
    return bb, bu, bk, bv


def pick_tvc2_batched_blocks(
    B: int,
    u: int,
    nk: int,
    *,
    storage=jnp.float32,
    compute=jnp.float32,
    has_y: bool = False,
    has_ab: bool = False,
    budget: int | None = None,
    table: bool = True,
) -> tuple[int, int, int]:
    """(bb, bu, bk) for the batched matvec kernel (lanes on n_k)."""
    budget = vmem_budget(budget)
    ssz = jnp.dtype(storage).itemsize
    csz = jnp.dtype(compute).itemsize
    q = sublane_quantum(storage)

    def cost(bb: int, bu: int, bk: int) -> int:
        return bb * (2 * bu * bk * ssz + 2 * bk * ssz + bu * csz
                     + bu * ssz * (3 if has_y else 1)
                     + (4 * csz if has_ab else 0))

    if table:
        hit = _from_table("tvc2_batched", (B, u, nk), storage,
                          (1, q, LANE), cost, budget)
        if hit is not None:
            return hit

    share = max(budget // min(B, _BB_TARGET), 64 * 1024)
    bu, bk = pick_tvc2_blocks(
        u, nk, storage=storage, compute=compute, has_y=has_y,
        budget=share, table=False)
    bb = _grow_bb(B, lambda bb: cost(bb, bu, bk), budget)
    return bb, bu, bk


def pick_tvc4_batched_blocks(
    B: int,
    u: int,
    n1: int,
    n2: int,
    v: int,
    *,
    storage=jnp.float32,
    compute=jnp.float32,
    has_y: bool = False,
    has_ab: bool = False,
    budget: int | None = None,
    table: bool = True,
) -> tuple[int, int, int, int, int]:
    """(bb, bu, b1, b2, bv) for the batched generic fused-pair kernel."""
    budget = vmem_budget(budget)
    ssz = jnp.dtype(storage).itemsize
    csz = jnp.dtype(compute).itemsize
    q = sublane_quantum(storage)

    def cost(bb: int, bu: int, b1: int, b2: int, bv: int) -> int:
        return bb * (2 * bu * b1 * b2 * bv * ssz + 2 * (b1 + b2) * ssz
                     + bu * bv * csz + bu * bv * ssz * (3 if has_y else 1)
                     + (4 * csz if has_ab else 0))

    if table:
        hit = _from_table("tvc4_batched", (B, u, n1, n2, v), storage,
                          (1, 8, 8, q, LANE), cost, budget)
        if hit is not None:
            return hit

    share = max(budget // min(B, _BB_TARGET), 64 * 1024)
    bu, b1, b2, bv = pick_tvc4_blocks(
        u, n1, n2, v, storage=storage, compute=compute, has_y=has_y,
        budget=share, table=False)
    bb = _grow_bb(B, lambda bb: cost(bb, bu, b1, b2, bv), budget)
    return bb, bu, b1, b2, bv


def pick_tvc2_pair_batched_blocks(
    B: int,
    u: int,
    n1: int,
    n2: int,
    *,
    storage=jnp.float32,
    compute=jnp.float32,
    has_y: bool = False,
    has_ab: bool = False,
    budget: int | None = None,
    table: bool = True,
) -> tuple[int, int, int, int]:
    """(bb, bu, b1, b2) for the batched fused-pair chain-tail kernel."""
    budget = vmem_budget(budget)
    ssz = jnp.dtype(storage).itemsize
    csz = jnp.dtype(compute).itemsize
    q = sublane_quantum(storage)

    def cost(bb: int, bu: int, b1: int, b2: int) -> int:
        return bb * (2 * bu * b1 * b2 * ssz + 2 * (b1 + b2) * ssz
                     + bu * csz + bu * ssz * (3 if has_y else 1)
                     + (4 * csz if has_ab else 0))

    if table:
        hit = _from_table("tvc2_pair_batched", (B, u, n1, n2), storage,
                          (1, q, q, LANE), cost, budget)
        if hit is not None:
            return hit

    share = max(budget // min(B, _BB_TARGET), 64 * 1024)
    bu, b1, b2 = pick_tvc2_pair_blocks(
        u, n1, n2, storage=storage, compute=compute, has_y=has_y,
        budget=share, table=False)
    bb = _grow_bb(B, lambda bb: cost(bb, bu, b1, b2), budget)
    return bb, bu, b1, b2


def pick_axpby_batched_blocks(
    B: int,
    n: int,
    *,
    storage=jnp.float32,
    compute=jnp.float32,
    budget: int | None = None,
) -> tuple[int, int]:
    """(bb, bc) for the batched per-row axpby kernel over a (B, n) stack."""
    budget = vmem_budget(budget)
    ssz = jnp.dtype(storage).itemsize
    csz = jnp.dtype(compute).itemsize
    q = sublane_quantum(storage)

    def cost(bb: int, bc: int) -> int:
        return bb * ((2 + 2 + 1) * bc * ssz + 4 * csz)

    bc = _clamp(1024, n, LANE)
    while cost(q, bc) > budget and bc > LANE:
        bc = _clamp(_round_up(bc // 2, LANE), n, LANE)
    # batch rows ride the sublane dim of the (bb, bc) block
    bb = max(q, _grow_bb(B, lambda bb: cost(_round_up(bb, q), bc), budget))
    return _clamp(_round_up(bb, q), B, q), bc
