"""Mixed-precision axpby Pallas kernel (paper §5.5 caching snippet).

``y := alpha*x + beta*y`` with *low-precision storage* and *high-precision
compute*.  The paper's CPU version needs an explicit cache-line work array
because software half-float conversion defeats vectorization; on TPU the
promote/compute/demote pipeline is native vector work, and the VMEM block IS
the cache-resident work array.  The kernel keeps the same contract: HBM
traffic in the storage dtype, arithmetic in the compute dtype.

Ragged sizes stream with zero copies, at full VPU-row utilization:

* lane-aligned n: the flat buffer is reinterpreted (a free reshape) as
  ``(n/128, 128)`` and tiled with :func:`axpby_2d` — no masking needed, the
  op is elementwise and partial edge blocks only ever put garbage into
  discarded out-of-bounds output lanes.
* lane-UNALIGNED n: ``(n/128, 128)`` is not a free reshape, so the buffer
  stays a ``(1, n)`` view — but instead of the old single-sublane ``(1, n)``
  blocks (1/8 of the VPU rows), :func:`axpby_tiled` streams ``(1, 128*bt)``
  lane runs and re-tiles each to ``(bt, 128)`` *inside* the kernel: HBM
  reads stay contiguous, compute runs on full (sublane, lane) rows.  The
  trailing partial block is masked in-kernel (garbage lanes zeroed before
  the promote — interior blocks skip the mask entirely), the matching
  out-of-bounds stores are discarded.

Standalone axpby passes over TVC outputs are mostly gone anyway: the
``beta != 0`` update is fused into the TVC kernel epilogue (see
:mod:`repro.kernels.tvc_kernel`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.mixed_precision import F32, Precision, get_policy
from .autotune import LANE

_cdiv = pl.cdiv


def _axpby_body(ab_ref, x_ref, y_ref, o_ref):
    cdt = ab_ref.dtype
    alpha = ab_ref[0, 0]
    beta = ab_ref[0, 1]
    o_ref[...] = (
        alpha * x_ref[...].astype(cdt) + beta * y_ref[...].astype(cdt)
    ).astype(o_ref.dtype)


def axpby_2d(
    alpha,
    x: jax.Array,
    beta,
    y: jax.Array,
    *,
    prec: Precision | str = F32,
    block: tuple[int, int] = (8, 128),
    interpret: bool = False,
) -> jax.Array:
    """x, y: 2-D arrays of identical, arbitrary (possibly ragged) shape."""
    prec = get_policy(prec)
    r, c = x.shape
    br, bc = block
    ab = jnp.asarray([alpha, beta], prec.compute).reshape(1, 2)
    return pl.pallas_call(
        _axpby_body,
        grid=(_cdiv(r, br), _cdiv(c, bc)),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), prec.storage),
        interpret=interpret,
    )(ab, x, y)


def _axpby_batched_body(ab_ref, x_ref, y_ref, o_ref):
    """Per-batch-row epilogue: o[z] = alpha_z * x[z] + beta_z * y[z] over a
    (B, n) stack — the batch grid dim streams bb rows per step and the tiny
    (bb, 2) ab block carries each row's scalars.  No masking anywhere: the op
    is elementwise, so garbage in partial edge blocks only ever reaches
    discarded out-of-bounds stores."""
    cdt = ab_ref.dtype
    alpha = ab_ref[:, 0][:, None]                   # (bb, 1)
    beta = ab_ref[:, 1][:, None]
    o_ref[...] = (
        alpha * x_ref[...].astype(cdt) + beta * y_ref[...].astype(cdt)
    ).astype(o_ref.dtype)


def axpby_batched(
    ab: jax.Array,
    x: jax.Array,
    y: jax.Array,
    *,
    prec: Precision | str = F32,
    block: tuple[int, int] = (8, 512),
    interpret: bool = False,
) -> jax.Array:
    """Batched mixed-precision axpby: ``out[z] = ab[z,0]*x[z] + ab[z,1]*y[z]``
    for all B rows of a (B, n) stack in ONE launch (the per-leaf axpby loop
    collapsed into a leading batch grid dimension)."""
    prec = get_policy(prec)
    B, n = x.shape
    bb, bc = block
    return pl.pallas_call(
        _axpby_batched_body,
        grid=(_cdiv(B, bb), _cdiv(n, bc)),
        in_specs=[
            pl.BlockSpec((bb, 2), lambda z, j: (z, 0)),
            pl.BlockSpec((bb, bc), lambda z, j: (z, j)),
            pl.BlockSpec((bb, bc), lambda z, j: (z, j)),
        ],
        out_specs=pl.BlockSpec((bb, bc), lambda z, j: (z, j)),
        out_shape=jax.ShapeDtypeStruct((B, n), prec.storage),
        interpret=interpret,
    )(ab.astype(prec.compute), x, y)


def _axpby_tiled_body(ab_ref, x_ref, y_ref, o_ref, *, n: int, bt: int,
                      blocks: int, mask_tail: bool):
    """(1, bt*128) lane-run blocks over a flat (1, n) view, re-tiled to
    (bt, 128) in-kernel so compute uses full VPU rows."""
    cdt = ab_ref.dtype
    alpha = ab_ref[0, 0]
    beta = ab_ref[0, 1]
    i = pl.program_id(0)
    width = bt * LANE

    def _store(masked: bool):
        x = x_ref[...].astype(cdt)                  # (1, bt*128)
        y = y_ref[...].astype(cdt)
        if masked:
            # trailing partial block: zero the garbage lanes past n before
            # the promote/compute (out-of-bounds lanes are undefined)
            lim = n - i * width
            m = lax.broadcasted_iota(jnp.int32, (1, width), 1) < lim
            x = jnp.where(m, x, 0)
            y = jnp.where(m, y, 0)
        out = alpha * x.reshape(bt, LANE) + beta * y.reshape(bt, LANE)
        o_ref[...] = out.reshape(1, width).astype(o_ref.dtype)

    if mask_tail:
        # only the last block carries garbage lanes; interior blocks skip
        # the iota/select entirely
        last = i == blocks - 1
        pl.when(last)(lambda: _store(True))
        pl.when(jnp.logical_not(last))(lambda: _store(False))
    else:
        _store(False)


def axpby_tiled(
    alpha,
    x: jax.Array,
    beta,
    y: jax.Array,
    *,
    prec: Precision | str = F32,
    bt: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """x, y: flat (1, n) views with lane-unaligned n > 128.  One launch,
    zero copies, full sublane rows via the in-kernel (bt, 128) re-tile."""
    prec = get_policy(prec)
    _, n = x.shape
    width = bt * LANE
    blocks = _cdiv(n, width)
    ab = jnp.asarray([alpha, beta], prec.compute).reshape(1, 2)
    kernel = functools.partial(
        _axpby_tiled_body, n=n, bt=bt, blocks=blocks,
        mask_tail=n % width != 0,
    )
    return pl.pallas_call(
        kernel,
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
            pl.BlockSpec((1, width), lambda i: (0, i)),
            pl.BlockSpec((1, width), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, width), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), prec.storage),
        interpret=interpret,
    )(ab, x, y)
