"""Mixed-precision axpby Pallas kernel (paper §5.5 caching snippet).

``y := alpha*x + beta*y`` with *low-precision storage* and *high-precision
compute*.  The paper's CPU version needs an explicit cache-line work array
because software half-float conversion defeats vectorization; on TPU the
promote/compute/demote pipeline is native vector work, and the VMEM block IS
the cache-resident work array.  The kernel keeps the same contract: HBM
traffic in the storage dtype, arithmetic in the compute dtype.

Ragged sizes stream with zero copies: the grid uses ``pl.cdiv`` and partial
edge blocks need no in-kernel masking at all — the op is elementwise, so
garbage in out-of-bounds input lanes only ever lands in out-of-bounds output
lanes, which are discarded.  (Contrast the TVC kernels, whose *reduction*
edge blocks must be masked.)  Standalone axpby passes over TVC outputs are
mostly gone anyway: the ``beta != 0`` update is fused into the TVC kernel
epilogue (see :mod:`repro.kernels.tvc_kernel`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.mixed_precision import F32, Precision, get_policy

_cdiv = pl.cdiv


def _axpby_body(ab_ref, x_ref, y_ref, o_ref):
    cdt = ab_ref.dtype
    alpha = ab_ref[0, 0]
    beta = ab_ref[0, 1]
    o_ref[...] = (
        alpha * x_ref[...].astype(cdt) + beta * y_ref[...].astype(cdt)
    ).astype(o_ref.dtype)


def axpby_2d(
    alpha,
    x: jax.Array,
    beta,
    y: jax.Array,
    *,
    prec: Precision | str = F32,
    block: tuple[int, int] = (8, 128),
    interpret: bool = False,
) -> jax.Array:
    """x, y: 2-D arrays of identical, arbitrary (possibly ragged) shape."""
    prec = get_policy(prec)
    r, c = x.shape
    br, bc = block
    ab = jnp.asarray([alpha, beta], prec.compute).reshape(1, 2)
    return pl.pallas_call(
        _axpby_body,
        grid=(_cdiv(r, br), _cdiv(c, bc)),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), prec.storage),
        interpret=interpret,
    )(ab, x, y)
