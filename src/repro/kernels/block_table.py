"""Checked-in (bu, bk, bv) sweep-table lookup for the TVC kernels.

The autotuner's grow loop (:mod:`repro.kernels.autotune`) is a heuristic —
good shape-independent defaults, but Peise et al. ("On the Performance
Prediction of BLAS-based Tensor Contractions") show per-shape selection from
*offline measurements* beats any single heuristic.  This module is the
measured side of that split:

* ``benchmarks/sweep_blocks.py`` runs the offline search
  (:mod:`repro.kernels.sweep`) over (order, mode-class, dtype) cells and pins
  each winner into ``kernels/block_table.json`` — a checked-in artifact, so
  every later run (and CI) selects from measurements instead of re-deriving;
* :func:`lookup` is consulted by every ``pick_*_blocks`` call *before* the
  heuristic grow loop.  A hit must match the kernel kind, storage dtype,
  backend, and the log2 size bucket of every view dim (block choice is a
  bandwidth property of the *magnitude* of each extent, not its exact value
  — and ragged extents would otherwise never hit).

Entries record the backend they were measured on and lookups are filtered by
the *current* backend, so a table swept on CPU never steers a TPU run (and
vice versa) — regenerate per hardware, see the README "Kernels" section.

``REPRO_TVC_BLOCK_TABLE`` overrides the table path;
``REPRO_TVC_DISABLE_TABLE=1`` turns lookups off (heuristic only).
:func:`pin` injects in-memory entries (tests, fresh sweep results) that take
precedence over the file.
"""
from __future__ import annotations

import json
import os
import pathlib
from typing import Any, Iterable

import jax
import jax.numpy as jnp

__all__ = [
    "KINDS", "DEFAULT_PATH", "size_bucket", "dtype_name",
    "load", "save", "lookup", "pin", "clear",
]

#: kernel kinds, keyed by the view the wrapper dispatches on:
#:   tvc3      — (u, n_k, v) single mode, v > 1
#:   tvc2      — (u, n_k) matvec, mode k = d-1
#:   tvc4      — (u, n1, n2, v) fused pair, v > 1
#:   tvc2_pair — (u, n1, n2) fused pair chain tail, v == 1
#: plus the ``*_batched`` variants, whose dims gain a leading batch extent B
#: and whose blocks gain the leading batch block ``bb``.
KINDS = ("tvc2", "tvc3", "tvc4", "tvc2_pair",
         "tvc2_batched", "tvc3_batched", "tvc4_batched", "tvc2_pair_batched")

DEFAULT_PATH = pathlib.Path(__file__).with_name("block_table.json")

_file_cache: dict[str, list[dict]] = {}
_pinned: list[dict] = []


def size_bucket(n: int) -> int:
    """log2 bucket of a view extent: 0, 1, 2, ... for 0/1, 2, 3-4, 5-8, ...
    (``int.bit_length`` of n-1, i.e. ceil(log2 n))."""
    n = int(n)
    return max(0, n - 1).bit_length()


def dtype_name(dtype) -> str:
    return jnp.dtype(dtype).name


def _table_path(path=None) -> pathlib.Path:
    if path is not None:
        return pathlib.Path(path)
    env = os.environ.get("REPRO_TVC_BLOCK_TABLE")
    return pathlib.Path(env) if env else DEFAULT_PATH


def load(path=None) -> list[dict]:
    """Entries from the table file (cached per path; [] when absent).  A
    file that exists but does not parse raises — silently ignoring it would
    disable every sweep winner with no signal."""
    p = _table_path(path)
    key = str(p)
    if key not in _file_cache:
        try:
            text = p.read_text()
        except OSError:
            _file_cache[key] = []        # no table yet: heuristic only
            return _file_cache[key]
        try:
            payload = json.loads(text)
            _file_cache[key] = list(payload.get("entries", []))
        except (ValueError, AttributeError) as e:
            raise ValueError(f"corrupt block table {p}: {e}") from e
    return _file_cache[key]


def save(entries: Iterable[dict], path=None, meta: dict | None = None) -> pathlib.Path:
    """Write (and re-cache) the table file; ``benchmarks/sweep_blocks.py`` is
    the normal caller."""
    p = _table_path(path)
    entries = sorted(
        entries,
        key=lambda e: (e.get("kind", ""), e.get("dtype", ""),
                       e.get("backend", ""), list(e.get("dims", []))),
    )
    payload = {"meta": {"schema": 1, **(meta or {})}, "entries": entries}
    p.write_text(json.dumps(payload, indent=1) + "\n")
    _file_cache[str(p)] = entries
    return p


def clear() -> None:
    """Drop pinned entries and the file cache (tests)."""
    _pinned.clear()
    _file_cache.clear()


def pin(entry: dict) -> None:
    """Register an in-memory entry that outranks the file (tests / a sweep
    that has not been committed yet).  Required keys: kind, dtype, dims,
    blocks; backend defaults to the current one."""
    e = dict(entry)
    e.setdefault("backend", jax.default_backend())
    missing = {"kind", "dtype", "dims", "blocks"} - set(e)
    if missing:
        raise ValueError(f"pinned entry missing {sorted(missing)}")
    _pinned.append(e)


def _matches(e: dict, kind: str, dname: str, backend: str,
             buckets: tuple[int, ...]) -> bool:
    if e.get("kind") != kind or e.get("dtype") != dname:
        return False
    if e.get("backend") != backend:
        return False
    dims = e.get("dims", ())
    if len(dims) != len(buckets):
        return False
    return tuple(size_bucket(d) for d in dims) == buckets


def lookup(kind: str, dims: tuple[int, ...], storage,
           backend: str | None = None, path=None) -> tuple[int, ...] | None:
    """Best pinned-or-filed blocks for a (kind, dtype, backend, size-bucket)
    cell, or None (caller falls back to the heuristic).  Ties/multiple hits
    resolve to the highest measured GB/s."""
    if os.environ.get("REPRO_TVC_DISABLE_TABLE"):
        return None
    if kind not in KINDS:
        raise ValueError(f"kind must be one of {KINDS}, got {kind!r}")
    dname = dtype_name(storage)
    backend = backend or jax.default_backend()
    buckets = tuple(size_bucket(d) for d in dims)

    def _best(entries) -> dict | None:
        best: dict | None = None
        for e in entries:
            if not _matches(e, kind, dname, backend, buckets):
                continue
            if best is None or e.get("gbs", 0.0) > best.get("gbs", 0.0):
                best = e
        return best

    # pinned entries outrank the file outright (a fresh sweep result or a
    # test override must win regardless of the stale entry's measured gbs)
    hit = _best(_pinned) or _best(load(path))
    if hit is None:
        return None
    return tuple(int(b) for b in hit["blocks"])


def entry(kind: str, dims, blocks, storage, *, gbs: float = 0.0,
          order: int | None = None, mode_class: str | None = None,
          engine: str | None = None, backend: str | None = None,
          **extra: Any) -> dict:
    """Normalized table entry (shared by the sweep writer and tests)."""
    return {
        "kind": kind,
        "dtype": dtype_name(storage),
        "backend": backend or jax.default_backend(),
        "engine": engine,
        "order": order,
        "mode_class": mode_class,
        "dims": [int(d) for d in dims],
        "blocks": [int(b) for b in blocks],
        "gbs": float(gbs),
        **extra,
    }
