"""Jit-ready wrappers around the Pallas kernels: zero-copy ragged dispatch
(``pl.cdiv`` grids + in-kernel edge masking — nothing is ever padded),
VMEM-aware block autotuning, backend dispatch (compiled on TPU, interpret
elsewhere), and view plumbing from arbitrary-order tensors.

The BLAS-style update ``Y = alpha * (A x_k x) + beta * Y`` is fused into the
kernel epilogue: ``alpha``/``beta`` are static (trace-time) arguments baked
into the kernel, and ``y`` rides along as one extra input ref, so a
``beta != 0`` update reads Y exactly once instead of spending a second full
axpby pass over it.

The ``*_batched`` wrappers stream B independent same-shape contractions
(stacked operands, per-batch vectors) through ONE launch; their
``alpha``/``beta`` additionally accept per-batch ``[B]`` arrays, normalized
into one tiny ``(B, 2)`` kernel operand.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.mixed_precision import F32, Precision, get_policy
from . import autotune as _at
from . import axpby as _axpby
from . import tvc_kernel as _tvc


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit,
         static_argnames=("alpha", "beta", "prec", "bu", "bk", "bv",
                          "interpret"))
def tvc_pallas(
    a3: jax.Array,
    x: jax.Array,
    y: jax.Array | None = None,
    *,
    alpha: float = 1.0,
    beta: float = 0.0,
    prec: Precision | str = F32,
    bu: int | None = None,
    bk: int | None = None,
    bv: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Mode-oblivious TVC on the (u, n_k, v) view with the fused
    ``alpha``/``beta`` epilogue.  Arbitrary (ragged) dims stream exactly once
    — no padding copies; block sizes default to the VMEM-aware autotuner
    (pass ``bu``/``bk``/``bv`` to override).  Dispatches to the matvec kernel
    when v == 1."""
    prec = get_policy(prec)
    if interpret is None:
        interpret = _interpret_default()
    alpha, beta = float(alpha), float(beta)
    u, nk, v = a3.shape
    if beta != 0.0 and y is None:
        raise ValueError("beta != 0 requires y")
    has_y = y is not None and beta != 0.0

    if v == 1:
        bu2, bk2 = _at.pick_tvc2_blocks(
            u, nk, storage=prec.storage, compute=prec.compute, has_y=has_y)
        if bu is not None:
            bu2 = bu
        if bk is not None:
            bk2 = bk
        y_in = y.reshape(u, 1) if has_y else None
        return _tvc.tvc2(a3.reshape(u, nk), x, prec=prec, bu=bu2, bk=bk2,
                         alpha=alpha, beta=beta, y_in=y_in,
                         interpret=interpret).reshape(u, 1)

    bu_, bk_, bv_ = _at.pick_tvc3_blocks(
        u, nk, v, storage=prec.storage, compute=prec.compute, has_y=has_y)
    bu_, bk_, bv_ = bu or bu_, bk or bk_, bv or bv_
    y_in = y.reshape(u, v) if has_y else None
    return _tvc.tvc3(a3, x, prec=prec, bu=bu_, bk=bk_, bv=bv_,
                     alpha=alpha, beta=beta, y_in=y_in, interpret=interpret)


def tvc(
    A: jax.Array,
    x: jax.Array,
    k: int,
    *,
    alpha: float = 1.0,
    beta: float = 0.0,
    y: jax.Array | None = None,
    prec: Precision | str = F32,
    interpret: bool | None = None,
) -> jax.Array:
    """Arbitrary-order mode-k TVC through the Pallas kernel, honouring the
    full BLAS update ``Y = alpha * (A x_k x) + beta * Y`` (drop-in for
    ``repro.core.tvc.tvc(impl="pallas")``)."""
    u = math.prod(A.shape[:k])
    v = math.prod(A.shape[k + 1:])
    out_shape = A.shape[:k] + A.shape[k + 1:]
    y_in = None if y is None else y.reshape(u, v)
    out = tvc_pallas(A.reshape(u, A.shape[k], v), x, y_in, alpha=alpha,
                     beta=beta, prec=get_policy(prec), interpret=interpret)
    return out.reshape(out_shape)


@partial(jax.jit,
         static_argnames=("alpha", "beta", "prec", "bu", "b1", "b2", "bv",
                          "interpret"))
def tvc2_pallas(
    a4: jax.Array,
    x1: jax.Array,
    x2: jax.Array,
    y: jax.Array | None = None,
    *,
    alpha: float = 1.0,
    beta: float = 0.0,
    prec: Precision | str = F32,
    bu: int | None = None,
    b1: int | None = None,
    b2: int | None = None,
    bv: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused two-mode contraction on the (u, n1, n2, v) view in ONE kernel
    launch, with the BLAS update ``Y = alpha * (A x1 x2) + beta * Y`` fused
    into the emit epilogue — ragged-safe, zero-copy, autotuned blocks (pass
    ``bu``/``b1``/``b2``/``bv`` to override).  Dispatches to the dedicated
    chain-tail kernel when v == 1 (the pair (d-2, d-1) of dHOPM_3's fused
    chains), which lanes on n_2 instead of wasting a 128-lane block on the
    singleton v."""
    prec = get_policy(prec)
    if interpret is None:
        interpret = _interpret_default()
    alpha, beta = float(alpha), float(beta)
    u, n1, n2, v = a4.shape
    if beta != 0.0 and y is None:
        raise ValueError("beta != 0 requires y")
    has_y = y is not None and beta != 0.0

    if v == 1:
        bu_, b1_, b2_ = _at.pick_tvc2_pair_blocks(
            u, n1, n2, storage=prec.storage, compute=prec.compute,
            has_y=has_y)
        bu_, b1_, b2_ = bu or bu_, b1 or b1_, b2 or b2_
        y_in = y.reshape(u, 1) if has_y else None
        return _tvc.tvc2_pair(
            a4.reshape(u, n1, n2), x1, x2, prec=prec, bu=bu_, b1=b1_, b2=b2_,
            alpha=alpha, beta=beta, y_in=y_in, interpret=interpret,
        ).reshape(u, 1)

    bu_, b1_, b2_, bv_ = _at.pick_tvc4_blocks(
        u, n1, n2, v, storage=prec.storage, compute=prec.compute, has_y=has_y)
    bu_, b1_, b2_, bv_ = bu or bu_, b1 or b1_, b2 or b2_, bv or bv_
    y_in = y.reshape(u, v) if has_y else None
    return _tvc.tvc4(a4, x1, x2, prec=prec, bu=bu_, b1=b1_, b2=b2_, bv=bv_,
                     alpha=alpha, beta=beta, y_in=y_in, interpret=interpret)


def _batched_ab(alpha, beta, B: int, compute):
    """Normalize the batched epilogue scalars.  Returns (ab, alpha, beta):
    ``ab`` is None when both are static Python scalars (the kernel bakes
    them), otherwise a (B, 2) array — per-batch values pass through, scalars
    (including traced 0-d ones) broadcast across the batch."""
    if isinstance(alpha, (int, float)) and isinstance(beta, (int, float)):
        return None, float(alpha), float(beta)
    al = jnp.broadcast_to(jnp.asarray(alpha, compute).reshape(-1), (B,))
    be = jnp.broadcast_to(jnp.asarray(beta, compute).reshape(-1), (B,))
    return jnp.stack([al, be], axis=1), 1.0, 0.0


@partial(jax.jit,
         static_argnames=("alpha", "beta", "prec", "bb", "bu", "bk", "bv",
                          "interpret"))
def _tvc_pallas_batched_call(a3, x, ab, y, *, alpha, beta, prec, bb, bu, bk,
                             bv, interpret):
    B, u, nk, v = a3.shape
    has_y = y is not None
    has_ab = ab is not None
    if v == 1:
        bb_, bu_, bk_ = _at.pick_tvc2_batched_blocks(
            B, u, nk, storage=prec.storage, compute=prec.compute,
            has_y=has_y, has_ab=has_ab)
        bb_, bu_, bk_ = bb or bb_, bu or bu_, bk or bk_
        y_in = y.reshape(B, u, 1) if has_y else None
        return _tvc.tvc2_batched(
            a3.reshape(B, u, nk), x, prec=prec, bb=bb_, bu=bu_, bk=bk_,
            alpha=alpha, beta=beta, ab=ab, y_in=y_in, interpret=interpret,
        ).reshape(B, u, 1)
    bb_, bu_, bk_, bv_ = _at.pick_tvc3_batched_blocks(
        B, u, nk, v, storage=prec.storage, compute=prec.compute,
        has_y=has_y, has_ab=has_ab)
    bb_, bu_, bk_, bv_ = bb or bb_, bu or bu_, bk or bk_, bv or bv_
    y_in = y.reshape(B, u, v) if has_y else None
    return _tvc.tvc3_batched(a3, x, prec=prec, bb=bb_, bu=bu_, bk=bk_,
                             bv=bv_, alpha=alpha, beta=beta, ab=ab,
                             y_in=y_in, interpret=interpret)


def tvc_pallas_batched(
    a3: jax.Array,
    x: jax.Array,
    y: jax.Array | None = None,
    *,
    alpha=1.0,
    beta=0.0,
    prec: Precision | str = F32,
    bb: int | None = None,
    bu: int | None = None,
    bk: int | None = None,
    bv: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Batched mode-oblivious TVC: B independent contractions on stacked
    (B, u, n_k, v) views against per-batch vectors ``x[B, n_k]`` in ONE
    kernel launch (the ``cublasGemvStridedBatched`` analogue) — dispatch
    overhead is paid once, not B times.  ``alpha``/``beta`` may be Python
    scalars (baked into the kernel) or per-batch ``[B]`` arrays (one tiny
    (B, 2) operand feeding the per-row epilogue); ``y`` is the stacked
    (B, u, v) update operand.  Dispatches to the batched matvec kernel when
    v == 1."""
    prec = get_policy(prec)
    if interpret is None:
        interpret = _interpret_default()
    B = a3.shape[0]
    if x.shape[0] != B:
        raise ValueError(f"x batch {x.shape[0]} != A batch {B}")
    ab, alpha_s, beta_s = _batched_ab(alpha, beta, B, prec.compute)
    static_beta_zero = isinstance(beta, (int, float)) and float(beta) == 0.0
    if not static_beta_zero and y is None:
        raise ValueError("beta != 0 requires y")
    y_use = None if static_beta_zero else y
    return _tvc_pallas_batched_call(a3, x, ab, y_use, alpha=alpha_s,
                                    beta=beta_s, prec=prec, bb=bb, bu=bu,
                                    bk=bk, bv=bv, interpret=interpret)


@partial(jax.jit,
         static_argnames=("alpha", "beta", "prec", "bb", "bu", "b1", "b2",
                          "bv", "interpret"))
def _tvc2_pallas_batched_call(a4, x1, x2, ab, y, *, alpha, beta, prec, bb,
                              bu, b1, b2, bv, interpret):
    B, u, n1, n2, v = a4.shape
    has_y = y is not None
    has_ab = ab is not None
    if v == 1:
        bb_, bu_, b1_, b2_ = _at.pick_tvc2_pair_batched_blocks(
            B, u, n1, n2, storage=prec.storage, compute=prec.compute,
            has_y=has_y, has_ab=has_ab)
        bb_, bu_, b1_, b2_ = bb or bb_, bu or bu_, b1 or b1_, b2 or b2_
        y_in = y.reshape(B, u, 1) if has_y else None
        return _tvc.tvc2_pair_batched(
            a4.reshape(B, u, n1, n2), x1, x2, prec=prec, bb=bb_, bu=bu_,
            b1=b1_, b2=b2_, alpha=alpha, beta=beta, ab=ab, y_in=y_in,
            interpret=interpret,
        ).reshape(B, u, 1)
    bb_, bu_, b1_, b2_, bv_ = _at.pick_tvc4_batched_blocks(
        B, u, n1, n2, v, storage=prec.storage, compute=prec.compute,
        has_y=has_y, has_ab=has_ab)
    bb_, bu_, b1_, b2_, bv_ = (bb or bb_, bu or bu_, b1 or b1_, b2 or b2_,
                               bv or bv_)
    y_in = y.reshape(B, u, v) if has_y else None
    return _tvc.tvc4_batched(a4, x1, x2, prec=prec, bb=bb_, bu=bu_, b1=b1_,
                             b2=b2_, bv=bv_, alpha=alpha, beta=beta, ab=ab,
                             y_in=y_in, interpret=interpret)


def tvc2_pallas_batched(
    a4: jax.Array,
    x1: jax.Array,
    x2: jax.Array,
    y: jax.Array | None = None,
    *,
    alpha=1.0,
    beta=0.0,
    prec: Precision | str = F32,
    bb: int | None = None,
    bu: int | None = None,
    b1: int | None = None,
    b2: int | None = None,
    bv: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Batched fused-pair contraction on stacked (B, u, n1, n2, v) views in
    ONE kernel launch, with per-batch vectors and the same scalar-or-[B]
    ``alpha``/``beta`` epilogue as :func:`tvc_pallas_batched`.  Dispatches
    to the batched chain-tail kernel when v == 1."""
    prec = get_policy(prec)
    if interpret is None:
        interpret = _interpret_default()
    B = a4.shape[0]
    if x1.shape[0] != B or x2.shape[0] != B:
        raise ValueError("vector batch dims != A batch dim")
    ab, alpha_s, beta_s = _batched_ab(alpha, beta, B, prec.compute)
    static_beta_zero = isinstance(beta, (int, float)) and float(beta) == 0.0
    if not static_beta_zero and y is None:
        raise ValueError("beta != 0 requires y")
    y_use = None if static_beta_zero else y
    return _tvc2_pallas_batched_call(a4, x1, x2, ab, y_use, alpha=alpha_s,
                                     beta=beta_s, prec=prec, bb=bb, bu=bu,
                                     b1=b1, b2=b2, bv=bv, interpret=interpret)


def axpby_pallas_batched(
    alpha,
    x: jax.Array,
    beta,
    y: jax.Array,
    *,
    prec: Precision | str = F32,
    interpret: bool | None = None,
) -> jax.Array:
    """Per-batch-row mixed-precision axpby over stacked (B, ...) arrays in
    ONE launch: ``out[z] = alpha_z * x[z] + beta_z * y[z]``.  ``alpha`` /
    ``beta`` are scalars or [B] arrays; rows are flattened to a (B, n)
    view (a free reshape on contiguous stacks)."""
    prec = get_policy(prec)
    if interpret is None:
        interpret = _interpret_default()
    B = x.shape[0]
    shape = x.shape
    n = math.prod(shape[1:]) if len(shape) > 1 else 1
    ab, alpha_s, beta_s = _batched_ab(alpha, beta, B, prec.compute)
    if ab is None:
        ab = jnp.broadcast_to(
            jnp.asarray([alpha_s, beta_s], prec.compute), (B, 2))
    block = _at.pick_axpby_batched_blocks(
        B, n, storage=prec.storage, compute=prec.compute)
    out = _axpby.axpby_batched(ab, x.reshape(B, n), y.reshape(B, n),
                               prec=prec, block=block, interpret=interpret)
    return out.reshape(shape)


@partial(jax.jit, static_argnames=("prec", "interpret"))
def axpby_pallas(
    alpha,
    x: jax.Array,
    beta,
    y: jax.Array,
    *,
    prec: Precision | str = F32,
    interpret: bool | None = None,
) -> jax.Array:
    """Mixed-precision ``alpha*x + beta*y`` over arbitrary-shape arrays.

    Zero-copy: lane-aligned sizes reinterpret the flat view as
    (n/128, 128) (a free reshape, full VPU sublane utilization); lane-
    UNALIGNED sizes larger than one lane run keep the flat (1, n) view but
    stream (1, 128*bt) lane runs re-tiled to (bt, 128) *inside* the kernel
    with an in-kernel masked tail — full sublane rows either way, never a
    single-sublane pass, never a padding copy."""
    prec = get_policy(prec)
    if interpret is None:
        interpret = _interpret_default()
    shape = x.shape
    n = math.prod(shape) if shape else 1
    if n % _at.LANE == 0:
        rows, cols = n // _at.LANE, _at.LANE
        block = _at.pick_axpby_blocks(
            rows, cols, storage=prec.storage, compute=prec.compute)
        out = _axpby.axpby_2d(
            alpha, x.reshape(rows, cols), beta, y.reshape(rows, cols),
            prec=prec, block=block, interpret=interpret,
        )
    elif n > _at.LANE:
        # ragged: same (bt, 128) tiling, via the in-kernel re-tile
        bt, _ = _at.pick_axpby_blocks(
            -(-n // _at.LANE), _at.LANE,
            storage=prec.storage, compute=prec.compute)
        out = _axpby.axpby_tiled(
            alpha, x.reshape(1, n), beta, y.reshape(1, n),
            prec=prec, bt=bt, interpret=interpret,
        )
    else:
        out = _axpby.axpby_2d(
            alpha, x.reshape(1, n), beta, y.reshape(1, n),
            prec=prec, block=(1, _at.LANE), interpret=interpret,
        )
    return out.reshape(shape)
