"""Jit-ready wrappers around the Pallas kernels: zero-padding to block
multiples (exact for contractions/sums), backend dispatch (compiled on TPU,
interpret elsewhere), and view plumbing from arbitrary-order tensors."""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.mixed_precision import F32, Precision, get_policy
from . import axpby as _axpby
from . import tvc_kernel as _tvc


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _pad_axis(a: jax.Array, axis: int, to: int) -> jax.Array:
    pad = to - a.shape[axis]
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _pick(block: int, dim: int, quantum: int) -> int:
    """Shrink the block to the padded dim when the dim is small."""
    return min(block, _round_up(dim, quantum))


@partial(jax.jit, static_argnames=("prec", "bu", "bk", "bv", "interpret"))
def tvc_pallas(
    a3: jax.Array,
    x: jax.Array,
    *,
    prec: Precision | str = F32,
    bu: int = 8,
    bk: int = 128,
    bv: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Mode-oblivious TVC on the (u, n_k, v) view.  Zero-pads every dim to a
    block multiple (exact: padded rows/cols contribute zero), dispatches to
    the matvec kernel when v == 1."""
    prec = get_policy(prec)
    if interpret is None:
        interpret = _interpret_default()
    u, nk, v = a3.shape

    if v == 1:
        a2 = a3.reshape(u, nk)
        bu2 = _pick(8, u, 8)
        bk2 = _pick(512, nk, 128)
        a2 = _pad_axis(_pad_axis(a2, 0, _round_up(u, bu2)), 1, _round_up(nk, bk2))
        xp = _pad_axis(x, 0, _round_up(nk, bk2))
        y = _tvc.tvc2_padded(a2, xp, prec=prec, bu=bu2, bk=bk2, interpret=interpret)
        return y[:u].reshape(u, 1)

    bu_ = _pick(bu, u, 8)
    bk_ = _pick(bk, nk, 8)
    bv_ = _pick(bv, v, 128)
    ap = a3
    ap = _pad_axis(ap, 0, _round_up(u, bu_))
    ap = _pad_axis(ap, 1, _round_up(nk, bk_))
    ap = _pad_axis(ap, 2, _round_up(v, bv_))
    xp = _pad_axis(x, 0, _round_up(nk, bk_))
    y = _tvc.tvc3_padded(ap, xp, prec=prec, bu=bu_, bk=bk_, bv=bv_, interpret=interpret)
    return y[:u, :v]


def tvc(
    A: jax.Array,
    x: jax.Array,
    k: int,
    *,
    prec: Precision | str = F32,
    interpret: bool | None = None,
) -> jax.Array:
    """Arbitrary-order mode-k TVC through the Pallas kernel."""
    u = math.prod(A.shape[:k])
    v = math.prod(A.shape[k + 1:])
    y = tvc_pallas(A.reshape(u, A.shape[k], v), x, prec=get_policy(prec),
                   interpret=interpret)
    return y.reshape(A.shape[:k] + A.shape[k + 1:])


@partial(jax.jit, static_argnames=("prec", "interpret"))
def tvc2_pallas(
    a4: jax.Array,
    x1: jax.Array,
    x2: jax.Array,
    *,
    prec: Precision | str = F32,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused two-mode contraction on the (u, n1, n2, v) view (zero-padded)."""
    prec = get_policy(prec)
    if interpret is None:
        interpret = _interpret_default()
    u, n1, n2, v = a4.shape
    bu = _pick(8, u, 8)
    b1 = _pick(8, n1, 8)
    b2 = _pick(8, n2, 8)
    bv = _pick(128, v, 128)
    ap = a4
    ap = _pad_axis(ap, 0, _round_up(u, bu))
    ap = _pad_axis(ap, 1, _round_up(n1, b1))
    ap = _pad_axis(ap, 2, _round_up(n2, b2))
    ap = _pad_axis(ap, 3, _round_up(v, bv))
    x1p = _pad_axis(x1, 0, _round_up(n1, b1))
    x2p = _pad_axis(x2, 0, _round_up(n2, b2))
    y = _tvc.tvc4_padded(ap, x1p, x2p, prec=prec, bu=bu, b1=b1, b2=b2, bv=bv,
                         interpret=interpret)
    return y[:u, :v]


@partial(jax.jit, static_argnames=("prec", "interpret"))
def axpby_pallas(
    alpha,
    x: jax.Array,
    beta,
    y: jax.Array,
    *,
    prec: Precision | str = F32,
    interpret: bool | None = None,
) -> jax.Array:
    """Mixed-precision ``alpha*x + beta*y`` over arbitrary-shape arrays."""
    prec = get_policy(prec)
    if interpret is None:
        interpret = _interpret_default()
    shape = x.shape
    n = math.prod(shape) if shape else 1
    cols = 128
    rows = _round_up(max(1, -(-n // cols)), 8)
    flat = _pad_axis(x.reshape(-1), 0, rows * cols).reshape(rows, cols)
    flaty = _pad_axis(y.reshape(-1), 0, rows * cols).reshape(rows, cols)
    out = _axpby.axpby_padded(
        alpha, flat, beta, flaty, prec=prec, block=(8, 128), interpret=interpret
    )
    return out.reshape(-1)[:n].reshape(shape)
