"""Pure-jnp oracles for the Pallas kernels (interpret-mode validation)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.mixed_precision import F32, Precision, get_policy


def tvc3_ref(a3, x, prec: Precision | str = F32):
    """Y[u,v] = sum_k A[u,k,v] x[k] with high-precision accumulation."""
    prec = get_policy(prec)
    y = jnp.einsum(
        "ukv,k->uv",
        a3.astype(prec.compute),
        x.astype(prec.compute),
        preferred_element_type=prec.compute,
    )
    return y.astype(prec.storage)


def tvc_ref(A, x, k, prec: Precision | str = F32):
    """Mode-k TVC oracle on an arbitrary-order tensor."""
    import math

    prec = get_policy(prec)
    u = math.prod(A.shape[:k])
    v = math.prod(A.shape[k + 1:])
    y = tvc3_ref(A.reshape(u, A.shape[k], v), x, prec)
    return y.reshape(A.shape[:k] + A.shape[k + 1:])


def axpby_ref(alpha, x, beta, y, prec: Precision | str = F32):
    """y := alpha*x + beta*y, promoted to compute dtype (paper §5.5 snippet)."""
    prec = get_policy(prec)
    out = (
        jnp.asarray(alpha, prec.compute) * x.astype(prec.compute)
        + jnp.asarray(beta, prec.compute) * y.astype(prec.compute)
    )
    return out.astype(prec.storage)
