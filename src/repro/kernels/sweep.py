"""Offline block-size search for the TVC kernels.

The autotuner's grow loop picks one (bu, bk, bv) per view from a fixed
heuristic; this module *measures* instead: enumerate every quantum-aligned
power-of-two block candidate that fits the VMEM budget, time the actual
kernel launch on each, and return the winner.  The candidate axes cover the
4-D pair kernel's ``bu`` (which the heuristic long pinned at 8) and, for
the ``*_batched`` kinds, the leading batch block ``bb`` (quantum 1 — the
batch dim is pure parallelism; its cost multiplies across the bb tiles).  ``benchmarks/sweep_blocks.py``
drives it over the (order, mode-class, dtype) bench grid and pins the winners
into :mod:`repro.kernels.block_table`, which the autotuner consults before
the heuristic on every later run.

Timings are only meaningful where the kernels compile (TPU — engine
``pallas``).  Elsewhere the sweep still runs end-to-end through interpret
mode (engine ``pallas-interpret``) so the machinery is exercised in CI, and
the resulting entries are tagged with the CPU backend, which
:func:`block_table.lookup` filters on — interpreter noise never steers a TPU
run.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.memory_model import (
    tvc2_batched_streamed_elems,
    tvc2_streamed_elems,
    tvc_batched_streamed_elems,
    tvc_streamed_elems,
)
from repro.core.mixed_precision import Precision, get_policy
from . import autotune as _at
from . import block_table
from . import ops

__all__ = ["SweepResult", "candidates", "streamed_bytes", "time_blocks",
           "sweep_case", "engine_name"]


@dataclasses.dataclass(frozen=True)
class SweepResult:
    kind: str
    dims: tuple[int, ...]
    blocks: tuple[int, ...]
    seconds: float
    gbs: float


def engine_name() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "pallas-interpret"


def _pow2_multiples(quantum: int, dim: int, cap: int) -> list[int]:
    """quantum, 2*quantum, 4*quantum, ... clipped to min(cap, dim rounded up
    to the quantum) — every size a block along this dim can usefully take."""
    top = min(cap, _at._round_up(max(1, dim), quantum))
    out, b = [], quantum
    while b <= top:
        out.append(b)
        b *= 2
    if not out or out[-1] < top:
        out.append(top)
    return out


def _quanta_and_cost(kind: str, storage, compute,
                     has_y: bool) -> tuple[tuple[int, ...], Callable]:
    """Per-dim (quantum, cap) axes and the double-buffered VMEM cost model
    for each kernel kind (mirrors the autotuner's budgets)."""
    ssz = jnp.dtype(storage).itemsize
    csz = jnp.dtype(compute).itemsize
    q = _at.sublane_quantum(storage)
    L = _at.LANE
    yf = 3 if has_y else 1
    if kind == "tvc3":
        axes = ((8, 256), (q, 4096), (L, 2048))
        cost = lambda bu, bk, bv: (2 * bu * bk * bv * ssz + 2 * bk * ssz
                                   + bu * bv * csz + bu * bv * ssz * yf)
    elif kind == "tvc2":
        axes = ((q, 64 * q), (L, 8192))
        cost = lambda bu, bk: (2 * bu * bk * ssz + 2 * bk * ssz
                               + bu * csz + bu * ssz * yf)
    elif kind == "tvc4":
        axes = ((8, 64), (8, 64), (q, 16 * q), (L, 1024))
        cost = lambda bu, b1, b2, bv: (2 * bu * b1 * b2 * bv * ssz
                                       + 2 * (b1 + b2) * ssz
                                       + bu * bv * csz + bu * bv * ssz * yf)
    elif kind == "tvc2_pair":
        axes = ((q, 64 * q), (q, 32 * q), (L, 8192))
        cost = lambda bu, b1, b2: (2 * bu * b1 * b2 * ssz
                                   + 2 * (b1 + b2) * ssz
                                   + bu * csz + bu * ssz * yf)
    elif kind.endswith("_batched"):
        # one leading (quantum-1) batch-block axis; the per-sample cost is
        # the unbatched kind's, multiplied across the bb tiles
        axes, per = _quanta_and_cost(kind[: -len("_batched")], storage,
                                     compute, has_y)
        axes = ((1, 64),) + axes
        cost = lambda bb, *blocks: bb * per(*blocks)
    else:
        raise ValueError(f"kind must be one of {block_table.KINDS}, got {kind!r}")
    return axes, cost


def candidates(
    kind: str,
    dims: Sequence[int],
    *,
    storage=jnp.float32,
    compute=jnp.float32,
    has_y: bool = False,
    budget: int | None = None,
    max_candidates: int = 48,
) -> list[tuple[int, ...]]:
    """Quantum-aligned power-of-two block tuples that fit the VMEM budget,
    largest-block-first, capped at ``max_candidates`` (the heuristic pick is
    always included so the sweep can only match or beat it)."""
    budget = _at.vmem_budget(budget)
    axes, cost = _quanta_and_cost(kind, storage, compute, has_y)
    if len(axes) != len(dims):
        raise ValueError(f"{kind} wants {len(axes)} dims, got {dims}")
    per_dim = [_pow2_multiples(qt, d, cap)
               for (qt, cap), d in zip(axes, dims)]
    grid = [c for c in itertools.product(*per_dim) if cost(*c) <= budget]
    # biggest A-block first: those amortize init/emit best and are the
    # likeliest winners, so truncation keeps the interesting region
    grid.sort(key=lambda c: (-np.prod(c), c))
    heur = _heuristic(kind, dims, storage, compute, has_y, budget)
    if heur in grid:
        grid.remove(heur)
    grid = [heur] + grid[: max(0, max_candidates - 1)]
    return grid


def _heuristic(kind, dims, storage, compute, has_y, budget):
    kw = dict(storage=storage, compute=compute, budget=budget, table=False)
    picks = {
        "tvc3": _at.pick_tvc3_blocks,
        "tvc2": _at.pick_tvc2_blocks,
        "tvc4": _at.pick_tvc4_blocks,
        "tvc2_pair": _at.pick_tvc2_pair_blocks,
        "tvc3_batched": _at.pick_tvc3_batched_blocks,
        "tvc2_batched": _at.pick_tvc2_batched_blocks,
        "tvc4_batched": _at.pick_tvc4_batched_blocks,
        "tvc2_pair_batched": _at.pick_tvc2_pair_batched_blocks,
    }
    return picks[kind](*dims, has_y=has_y, **kw)


def streamed_bytes(kind: str, dims: Sequence[int], storage) -> int:
    """Model-predicted streamed bytes of one launch — the GB/s denominator
    (and what the CI bandwidth gate checks measured cells against)."""
    ssz = jnp.dtype(storage).itemsize
    if kind.endswith("_batched"):
        b, rest = dims[0], tuple(dims[1:])
        base = kind[: -len("_batched")]
        if base in ("tvc3", "tvc2"):
            u, nk = rest[:2]
            v = rest[2] if base == "tvc3" else 1
            return tvc_batched_streamed_elems(b, u, nk, v) * ssz
        u, n1, n2 = rest[:3]
        v = rest[3] if base == "tvc4" else 1
        return tvc2_batched_streamed_elems(b, u, n1, n2, v) * ssz
    if kind == "tvc3":
        u, nk, v = dims
        return tvc_streamed_elems(u, nk, v) * ssz
    if kind == "tvc2":
        u, nk = dims
        return tvc_streamed_elems(u, nk, 1) * ssz
    u, n1, n2 = dims[:3]
    v = dims[3] if kind == "tvc4" else 1
    return tvc2_streamed_elems(u, n1, n2, v) * ssz


def _operands(kind: str, dims, storage, seed: int = 0):
    rng = np.random.default_rng(seed)

    def r(shape):
        return jnp.asarray(rng.standard_normal(shape).astype(np.float32)
                           ).astype(storage)

    if kind.endswith("_batched"):
        b, rest = dims[0], tuple(dims[1:])
        base = kind[: -len("_batched")]
        if base in ("tvc3", "tvc2"):
            u, nk = rest[:2]
            v = rest[2] if base == "tvc3" else 1
            return (r((b, u, nk, v)), r((b, nk)))
        u, n1, n2 = rest[:3]
        v = rest[3] if base == "tvc4" else 1
        return (r((b, u, n1, n2, v)), r((b, n1)), r((b, n2)))
    if kind == "tvc3":
        u, nk, v = dims
        return (r((u, nk, v)), r((nk,)))
    if kind == "tvc2":
        u, nk = dims
        return (r((u, nk, 1)), r((nk,)))
    u, n1, n2 = dims[:3]
    v = dims[3] if kind == "tvc4" else 1
    return (r((u, n1, n2, v)), r((n1,)), r((n2,)))


def _launch(kind: str, operands, blocks, prec: Precision):
    if kind == "tvc3_batched":
        a3, x = operands
        bb, bu, bk, bv = blocks
        return ops.tvc_pallas_batched(a3, x, prec=prec,
                                      bb=bb, bu=bu, bk=bk, bv=bv)
    if kind == "tvc2_batched":
        a3, x = operands
        bb, bu, bk = blocks
        return ops.tvc_pallas_batched(a3, x, prec=prec, bb=bb, bu=bu, bk=bk)
    if kind == "tvc4_batched":
        a4, x1, x2 = operands
        bb, bu, b1, b2, bv = blocks
        return ops.tvc2_pallas_batched(a4, x1, x2, prec=prec, bb=bb, bu=bu,
                                       b1=b1, b2=b2, bv=bv)
    if kind == "tvc2_pair_batched":
        a4, x1, x2 = operands
        bb, bu, b1, b2 = blocks
        return ops.tvc2_pallas_batched(a4, x1, x2, prec=prec, bb=bb, bu=bu,
                                       b1=b1, b2=b2)
    if kind == "tvc3":
        a3, x = operands
        bu, bk, bv = blocks
        return ops.tvc_pallas(a3, x, prec=prec, bu=bu, bk=bk, bv=bv)
    if kind == "tvc2":
        a3, x = operands
        bu, bk = blocks
        return ops.tvc_pallas(a3, x, prec=prec, bu=bu, bk=bk)
    a4, x1, x2 = operands
    if kind == "tvc4":
        bu, b1, b2, bv = blocks
        return ops.tvc2_pallas(a4, x1, x2, prec=prec,
                               bu=bu, b1=b1, b2=b2, bv=bv)
    bu, b1, b2 = blocks
    return ops.tvc2_pallas(a4, x1, x2, prec=prec, bu=bu, b1=b1, b2=b2)


def time_blocks(kind: str, operands, blocks, prec: Precision, *,
                reps: int = 3, warmup: int = 1) -> float:
    """Median wall seconds of one launch with the given blocks."""
    for _ in range(warmup):
        jax.block_until_ready(_launch(kind, operands, blocks, prec))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(_launch(kind, operands, blocks, prec))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def sweep_case(
    kind: str,
    dims: Sequence[int],
    *,
    prec: Precision | str = "f32",
    budget: int | None = None,
    max_candidates: int = 48,
    reps: int = 3,
    warmup: int = 1,
) -> tuple[SweepResult, list[SweepResult]]:
    """Measure every candidate for one (kind, dims, dtype) cell; returns
    (winner, all results sorted fastest-first)."""
    prec = get_policy(prec)
    dims = tuple(int(d) for d in dims)
    operands = _operands(kind, dims, prec.storage)
    nbytes = streamed_bytes(kind, dims, prec.storage)
    results = []
    for blocks in candidates(kind, dims, storage=prec.storage,
                             compute=prec.compute, budget=budget,
                             max_candidates=max_candidates):
        sec = time_blocks(kind, operands, blocks, prec,
                          reps=reps, warmup=warmup)
        results.append(SweepResult(kind, dims, tuple(blocks), sec,
                                   nbytes / sec / 1e9))
    results.sort(key=lambda r: r.seconds)
    return results[0], results
