"""Mode-oblivious native TVC as a Pallas TPU kernel.

TPU adaptation of the paper's native algorithm (§2, §4.1): the tensor is
interpreted through its free (u, n_k, v) view and streamed through VMEM
exactly once, independent of the contraction mode k.  The paper's CPU kernel
distributes the column space of A^{n_k x uv} over cores with 512-bit SIMD; the
TPU analogue tiles (u, v) over the grid with (sublane, lane)-aligned VMEM
blocks and reduces n_k over the minor (sequential) grid dimension,
accumulating in a high-precision VMEM scratch (mixed precision §5.5: storage
dtype on HBM, compute dtype in the accumulator).

Ragged shapes stream with **zero copies**: grids use ``pl.cdiv`` so arbitrary
(u, n_k, v) extents map straight onto block multiples, and the partial edge
blocks are handled in-kernel — ``broadcasted_iota`` masks zero the garbage
lanes of the trailing reduction block (both the A block and the x block must
be masked: out-of-bounds lanes are undefined, and ``0 * garbage`` is only
zero when *both* factors are zeroed), while partial u/v *output* blocks need
no masking at all because out-of-bounds stores are discarded.  Nothing is
ever ``jnp.pad``-ed, so streamed HBM traffic equals
:func:`repro.core.tvc.tvc_bytes` exactly.

Two kernel bodies cover every single mode with one streaming pass each:
  * v > 1  : blocks (bu, bk, bv), lanes on v          (modes k < d-1)
  * v == 1 : blocks (bu, bk),     lanes on n_k        (mode  k = d-1, matvec)

and two more cover a *fused pair* of adjacent modes — one launch contracts
both, never materializing the order-(d-1) intermediate (dHOPM_3's chain
fusion, see :func:`repro.core.tvc.tvc2`):
  * v > 1  : blocks (bu, b1, b2, bv), lanes on v      (pairs k2 < d-1)
  * v == 1 : blocks (bu, b1, b2),     lanes on n_2    (pair (d-2, d-1) — the
             chain-tail kernel ``_tvc2_pair_body``, which puts lanes on the
             contiguous minor mode instead of wasting a 128-lane block on a
             size-1 v)

All bodies fold the BLAS-style update ``Y = alpha * (A x_k x) + beta * Y``
into the emit epilogue: ``alpha``/``beta`` are trace-time constants and the
optional y operand rides in as one extra input ref, so ``beta != 0`` costs a
single extra read of Y instead of a second full axpby pass.

Every body also runs **batched**: a leading batch grid dimension streams B
independent same-shape contractions — ``A[B, ...]`` against per-batch vectors
``x[B, n_k]`` — through ONE ``pallas_call``, so a chain step over B stacked
tensors pays one dispatch instead of B (the ``cublasGemvStridedBatched``
analogue of Shi et al.'s extended-BLAS batching).  The batched epilogue
optionally takes *per-batch* ``alpha``/``beta`` as one tiny ``(B, 2)``
operand (``ab``); the batch dim needs no edge masking — a partial trailing
batch block only ever produces garbage in out-of-bounds output rows, which
are discarded (each batch row accumulates independently; nothing reduces
across the batch).  The ``tvc*_batched`` wrappers at the bottom mirror the
unbatched ones one-for-one.

Block sizes come from :mod:`repro.kernels.autotune` (dtype tiling quantum,
VMEM budget — divided across the ``bb`` batch tiles in the batched variants,
aspect ratio); the wrappers live in :mod:`repro.kernels.ops`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.mixed_precision import F32, Precision, get_policy

_cdiv = pl.cdiv


def _compiler_params(n_parallel: int, n_arbitrary: int = 1):
    """dimension_semantics: parallel over output tiles, arbitrary over the
    reduction dims (must stay sequential for accumulation)."""
    try:
        return pltpu.CompilerParams(
            dimension_semantics=("parallel",) * n_parallel
            + ("arbitrary",) * n_arbitrary
        )
    except Exception:  # pragma: no cover - older/newer pallas API fallback
        return None


def _edge_mask(shape: tuple[int, ...], dim: int, limit) -> jax.Array:
    """Boolean mask over a broadcastable block view: True where the global
    index along ``dim`` is < ``limit`` (>= 2-D iota, as TPU requires)."""
    return lax.broadcasted_iota(jnp.int32, shape, dim) < limit


def _emit_update(acc, y_ref, yin_ref, alpha: float, beta: float,
                 ab_ref=None):
    """Fused epilogue: y = alpha * acc + beta * y_in, demoted to storage.
    alpha/beta are Python floats folded into the kernel at trace time —
    unless ``ab_ref`` (a per-batch ``(bb, 2)`` block) is present, in which
    case each batch row gets its own alpha/beta broadcast over the block."""
    out = acc
    if ab_ref is not None:
        ab = ab_ref[...].astype(out.dtype)          # (bb, 2)
        bshape = (-1,) + (1,) * (out.ndim - 1)
        out = out * ab[:, 0].reshape(bshape)
        if yin_ref is not None:
            out = out + ab[:, 1].reshape(bshape) * \
                yin_ref[...].astype(out.dtype)
    else:
        if alpha != 1.0:
            out = out * alpha
        if yin_ref is not None:
            out = out + beta * yin_ref[...].astype(out.dtype)
    y_ref[...] = out.astype(y_ref.dtype)


def _epilogue_refs(rest, has_ab: bool, has_y: bool):
    """(ab_ref, yin_ref, y_ref, acc_ref) from a body's trailing refs; the
    optional per-batch ab block rides before the optional y-in block."""
    idx = 0
    ab_ref = rest[idx] if has_ab else None
    idx += 1 if has_ab else 0
    yin_ref = rest[idx] if has_y else None
    return ab_ref, yin_ref, rest[-2], rest[-1]


def _tvc3_body(x_ref, a_ref, *rest, nk: int, bk: int, k_blocks: int,
               mask_k: bool, alpha: float, beta: float, has_y: bool,
               has_ab: bool = False, batched: bool = False):
    ab_ref, yin_ref, y_ref, acc_ref = _epilogue_refs(rest, has_ab, has_y)
    kk = pl.program_id(3 if batched else 2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _accum(masked: bool):
        a = a_ref[...].astype(acc_ref.dtype)    # (bu, bk, bv) | (bb, bu, bk, bv)
        xv = x_ref[...].astype(acc_ref.dtype)   # (1, bk)      | (bb, bk)
        if masked:                              # trailing partial k-block
            lim = nk - kk * bk
            kdim = 2 if batched else 1
            a = jnp.where(_edge_mask((1,) * kdim + (bk,) + (1,), kdim, lim),
                          a, 0)
            xv = jnp.where(_edge_mask((1, bk), 1, lim), xv, 0)
        if batched:
            acc_ref[...] += jnp.sum(a * xv[:, None, :, None], axis=2)
        else:
            acc_ref[...] += jnp.sum(a * xv[0][None, :, None], axis=1)

    if mask_k:
        # only the last k-block has garbage lanes — interior blocks skip the
        # iota/select work entirely
        last = kk == k_blocks - 1
        pl.when(last)(lambda: _accum(True))
        pl.when(jnp.logical_not(last))(lambda: _accum(False))
    else:
        _accum(False)

    @pl.when(kk == k_blocks - 1)
    def _emit():
        _emit_update(acc_ref[...], y_ref, yin_ref, alpha, beta, ab_ref)


def _tvc2_body(x_ref, a_ref, *rest, nk: int, bk: int, k_blocks: int,
               mask_k: bool, alpha: float, beta: float, has_y: bool,
               has_ab: bool = False, batched: bool = False):
    ab_ref, yin_ref, y_ref, acc_ref = _epilogue_refs(rest, has_ab, has_y)
    kk = pl.program_id(2 if batched else 1)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _accum(masked: bool):
        a = a_ref[...].astype(acc_ref.dtype)    # (bu, bk) | (bb, bu, bk)
        xv = x_ref[...].astype(acc_ref.dtype)   # (1, bk)  | (bb, bk)
        if masked:
            lim = nk - kk * bk
            kdim = 2 if batched else 1
            a = jnp.where(_edge_mask((1,) * kdim + (bk,), kdim, lim), a, 0)
            xv = jnp.where(_edge_mask((1, bk), 1, lim), xv, 0)
        if batched:
            acc_ref[...] += jnp.sum(a * xv[:, None, :], axis=2,
                                    keepdims=True)
        else:
            acc_ref[...] += jnp.sum(a * xv, axis=1, keepdims=True)

    if mask_k:
        last = kk == k_blocks - 1
        pl.when(last)(lambda: _accum(True))
        pl.when(jnp.logical_not(last))(lambda: _accum(False))
    else:
        _accum(False)

    @pl.when(kk == k_blocks - 1)
    def _emit():
        _emit_update(acc_ref[...], y_ref, yin_ref, alpha, beta, ab_ref)


def _tvc4_body(x1_ref, x2_ref, a_ref, *rest, n1: int, b1: int, n2: int,
               b2: int, k1_blocks: int, k2_blocks: int, mask_1: bool,
               mask_2: bool, alpha: float, beta: float, has_y: bool,
               has_ab: bool = False, batched: bool = False):
    ab_ref, yin_ref, y_ref, acc_ref = _epilogue_refs(rest, has_ab, has_y)
    kk1 = pl.program_id(3 if batched else 2)
    kk2 = pl.program_id(4 if batched else 3)

    @pl.when((kk1 == 0) & (kk2 == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _accum(m1: bool, m2: bool):
        a = a_ref[...].astype(acc_ref.dtype)    # (bu,b1,b2,bv)|(bb,bu,b1,b2,bv)
        x1 = x1_ref[...].astype(acc_ref.dtype)  # (1, b1)      | (bb, b1)
        x2 = x2_ref[...].astype(acc_ref.dtype)  # (1, b2)      | (bb, b2)
        off = 1 if batched else 0
        if m1:
            lim1 = n1 - kk1 * b1
            sh = (1,) * (1 + off) + (b1,) + (1, 1)
            a = jnp.where(_edge_mask(sh, 1 + off, lim1), a, 0)
            x1 = jnp.where(_edge_mask((1, b1), 1, lim1), x1, 0)
        if m2:
            lim2 = n2 - kk2 * b2
            sh = (1,) * (2 + off) + (b2,) + (1,)
            a = jnp.where(_edge_mask(sh, 2 + off, lim2), a, 0)
            x2 = jnp.where(_edge_mask((1, b2), 1, lim2), x2, 0)
        if batched:
            w = x1[:, :, None] * x2[:, None, :]       # (bb, b1, b2)
            acc_ref[...] += jnp.einsum("zuabv,zab->zuv", a, w)
            return
        w = x1[0][:, None] * x2[0][None, :]           # (b1, b2)
        acc_ref[...] += jnp.einsum("uabv,ab->uv", a, w)

    if mask_1 or mask_2:
        # edge blocks (any trailing partial reduction block) take the masked
        # path; interior blocks skip the iota/select work.  Masking a dim
        # whose block happens to be full is harmless (lim >= b -> all-True).
        conds = []
        if mask_1:
            conds.append(kk1 == k1_blocks - 1)
        if mask_2:
            conds.append(kk2 == k2_blocks - 1)
        edge = conds[0] if len(conds) == 1 else conds[0] | conds[1]
        pl.when(edge)(lambda: _accum(mask_1, mask_2))
        pl.when(jnp.logical_not(edge))(lambda: _accum(False, False))
    else:
        _accum(False, False)

    @pl.when((kk1 == k1_blocks - 1) & (kk2 == k2_blocks - 1))
    def _emit():
        _emit_update(acc_ref[...], y_ref, yin_ref, alpha, beta, ab_ref)


def _tvc2_pair_body(x1_ref, x2_ref, a_ref, *rest, n1: int, b1: int, n2: int,
                    b2: int, k1_blocks: int, k2_blocks: int, mask_1: bool,
                    mask_2: bool, alpha: float, beta: float, has_y: bool,
                    has_ab: bool = False, batched: bool = False):
    """Fused-pair chain tail (v == 1): y[u] = sum_{a,b} A[u,a,b] x1[a] x2[b]
    in one launch.  Lanes ride on n_2 (the contiguous minor mode), sublanes
    on n_1; both reduction grid dims are sequential."""
    ab_ref, yin_ref, y_ref, acc_ref = _epilogue_refs(rest, has_ab, has_y)
    kk1 = pl.program_id(2 if batched else 1)
    kk2 = pl.program_id(3 if batched else 2)

    @pl.when((kk1 == 0) & (kk2 == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _accum(m1: bool, m2: bool):
        a = a_ref[...].astype(acc_ref.dtype)    # (bu, b1, b2)|(bb, bu, b1, b2)
        x1 = x1_ref[...].astype(acc_ref.dtype)  # (1, b1)     | (bb, b1)
        x2 = x2_ref[...].astype(acc_ref.dtype)  # (1, b2)     | (bb, b2)
        off = 1 if batched else 0
        if m1:
            lim1 = n1 - kk1 * b1
            sh = (1,) * (1 + off) + (b1,) + (1,)
            a = jnp.where(_edge_mask(sh, 1 + off, lim1), a, 0)
            x1 = jnp.where(_edge_mask((1, b1), 1, lim1), x1, 0)
        if m2:
            lim2 = n2 - kk2 * b2
            sh = (1,) * (2 + off) + (b2,)
            a = jnp.where(_edge_mask(sh, 2 + off, lim2), a, 0)
            x2 = jnp.where(_edge_mask((1, b2), 1, lim2), x2, 0)
        if batched:
            w = x1[:, :, None] * x2[:, None, :]       # (bb, b1, b2)
            acc_ref[...] += jnp.sum(a * w[:, None], axis=(2, 3))[:, :, None]
            return
        w = x1[0][:, None] * x2[0][None, :]           # (b1, b2)
        acc_ref[...] += jnp.sum(a * w[None], axis=(1, 2), keepdims=False)[:, None]

    if mask_1 or mask_2:
        conds = []
        if mask_1:
            conds.append(kk1 == k1_blocks - 1)
        if mask_2:
            conds.append(kk2 == k2_blocks - 1)
        edge = conds[0] if len(conds) == 1 else conds[0] | conds[1]
        pl.when(edge)(lambda: _accum(mask_1, mask_2))
        pl.when(jnp.logical_not(edge))(lambda: _accum(False, False))
    else:
        _accum(False, False)

    @pl.when((kk1 == k1_blocks - 1) & (kk2 == k2_blocks - 1))
    def _emit():
        _emit_update(acc_ref[...], y_ref, yin_ref, alpha, beta, ab_ref)


def _update_operands(y_in, alpha: float, beta: float, out_spec):
    """(extra_inputs, extra_specs, has_y) for the fused epilogue; the y input
    shares the output BlockSpec so partial edge blocks line up."""
    if beta != 0.0 and y_in is None:
        raise ValueError("beta != 0 requires a y operand")
    if y_in is None or beta == 0.0:
        return (), (), False
    return (y_in,), (out_spec,), True


def _update_operands_batched(ab, y_in, alpha: float, beta: float,
                             ab_spec, out_spec):
    """(extra_inputs, extra_specs, has_ab, has_y) for a batched epilogue.
    ``ab`` is the optional per-batch ``(B, 2)`` alpha/beta operand; when
    present the static alpha/beta are ignored by the body.  With per-batch
    betas the y operand is required whenever ``ab`` rides along with a y —
    callers that know their betas are all zero simply pass ``y_in=None``."""
    if ab is None and beta != 0.0 and y_in is None:
        raise ValueError("beta != 0 requires a y operand")
    extra_in, extra_specs = [], []
    has_ab = ab is not None
    if has_ab:
        extra_in.append(ab)
        extra_specs.append(ab_spec)
    has_y = y_in is not None and (has_ab or beta != 0.0)
    if has_y:
        extra_in.append(y_in)
        extra_specs.append(out_spec)
    return tuple(extra_in), tuple(extra_specs), has_ab, has_y


def tvc3(
    a3: jax.Array,
    x: jax.Array,
    *,
    prec: Precision | str = F32,
    bu: int = 8,
    bk: int = 128,
    bv: int = 128,
    alpha: float = 1.0,
    beta: float = 0.0,
    y_in: jax.Array | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Y[u,v] = alpha * sum_k A[u,k,v] x[k] + beta * y_in[u,v]; arbitrary
    (possibly ragged) dims, streamed once with no padding copies."""
    prec = get_policy(prec)
    u, nk, v = a3.shape
    grid = (_cdiv(u, bu), _cdiv(v, bv), _cdiv(nk, bk))
    out_spec = pl.BlockSpec((bu, bv), lambda i, j, kk: (i, j))
    extra_in, extra_specs, has_y = _update_operands(y_in, alpha, beta, out_spec)
    kernel = functools.partial(
        _tvc3_body, nk=nk, bk=bk, k_blocks=grid[2], mask_k=nk % bk != 0,
        alpha=alpha, beta=beta, has_y=has_y,
    )
    params = _compiler_params(2)
    kwargs = {"compiler_params": params} if (params and not interpret) else {}
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bk), lambda i, j, kk: (0, kk)),
            pl.BlockSpec((bu, bk, bv), lambda i, j, kk: (i, kk, j)),
            *extra_specs,
        ],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((u, v), prec.storage),
        scratch_shapes=[pltpu.VMEM((bu, bv), prec.compute)],
        interpret=interpret,
        **kwargs,
    )(x.reshape(1, nk), a3, *extra_in)


def tvc4(
    a4: jax.Array,
    x1: jax.Array,
    x2: jax.Array,
    *,
    prec: Precision | str = F32,
    bu: int = 8,
    b1: int = 8,
    b2: int = 8,
    bv: int = 128,
    alpha: float = 1.0,
    beta: float = 0.0,
    y_in: jax.Array | None = None,
    interpret: bool = False,
) -> jax.Array:
    """BEYOND-PAPER fused pair: Y[u,v] = sum_{a,b} A[u,a,b,v] x1[a] x2[b] in
    one streaming pass (two sequential reduction grid dims), ragged-safe."""
    prec = get_policy(prec)
    u, n1, n2, v = a4.shape
    grid = (_cdiv(u, bu), _cdiv(v, bv), _cdiv(n1, b1), _cdiv(n2, b2))
    out_spec = pl.BlockSpec((bu, bv), lambda i, j, a, b: (i, j))
    extra_in, extra_specs, has_y = _update_operands(y_in, alpha, beta, out_spec)
    kernel = functools.partial(
        _tvc4_body, n1=n1, b1=b1, n2=n2, b2=b2,
        k1_blocks=grid[2], k2_blocks=grid[3],
        mask_1=n1 % b1 != 0, mask_2=n2 % b2 != 0,
        alpha=alpha, beta=beta, has_y=has_y,
    )
    params = _compiler_params(2, 2)
    kwargs = {"compiler_params": params} if (params and not interpret) else {}
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, b1), lambda i, j, a, b: (0, a)),
            pl.BlockSpec((1, b2), lambda i, j, a, b: (0, b)),
            pl.BlockSpec((bu, b1, b2, bv), lambda i, j, a, b: (i, a, b, j)),
            *extra_specs,
        ],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((u, v), prec.storage),
        scratch_shapes=[pltpu.VMEM((bu, bv), prec.compute)],
        interpret=interpret,
        **kwargs,
    )(x1.reshape(1, n1), x2.reshape(1, n2), a4, *extra_in)


def tvc2_pair(
    a3: jax.Array,
    x1: jax.Array,
    x2: jax.Array,
    *,
    prec: Precision | str = F32,
    bu: int = 8,
    b1: int = 8,
    b2: int = 128,
    alpha: float = 1.0,
    beta: float = 0.0,
    y_in: jax.Array | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Fused-pair chain tail: Y[u] = alpha * sum_{a,b} A[u,a,b] x1[a] x2[b]
    + beta * y_in[u] in ONE streaming pass — the pair (d-2, d-1) of dHOPM_3's
    fused chains, where v == 1 and the generic 4-D kernel would burn a
    128-lane block on a singleton dim.  Lanes on n_2, ragged-safe, no
    padding copies."""
    prec = get_policy(prec)
    u, n1, n2 = a3.shape
    grid = (_cdiv(u, bu), _cdiv(n1, b1), _cdiv(n2, b2))
    out_spec = pl.BlockSpec((bu, 1), lambda i, a, b: (i, 0))
    extra_in, extra_specs, has_y = _update_operands(y_in, alpha, beta, out_spec)
    kernel = functools.partial(
        _tvc2_pair_body, n1=n1, b1=b1, n2=n2, b2=b2,
        k1_blocks=grid[1], k2_blocks=grid[2],
        mask_1=n1 % b1 != 0, mask_2=n2 % b2 != 0,
        alpha=alpha, beta=beta, has_y=has_y,
    )
    params = _compiler_params(1, 2)
    kwargs = {"compiler_params": params} if (params and not interpret) else {}
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, b1), lambda i, a, b: (0, a)),
            pl.BlockSpec((1, b2), lambda i, a, b: (0, b)),
            pl.BlockSpec((bu, b1, b2), lambda i, a, b: (i, a, b)),
            *extra_specs,
        ],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((u, 1), prec.storage),
        scratch_shapes=[pltpu.VMEM((bu, 1), prec.compute)],
        interpret=interpret,
        **kwargs,
    )(x1.reshape(1, n1), x2.reshape(1, n2), a3, *extra_in)


def tvc2(
    a2: jax.Array,
    x: jax.Array,
    *,
    prec: Precision | str = F32,
    bu: int = 8,
    bk: int = 512,
    alpha: float = 1.0,
    beta: float = 0.0,
    y_in: jax.Array | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Y[u] = alpha * sum_k A[u,k] x[k] + beta * y_in[u] (the k = d-1
    matvec); arbitrary dims, no padding copies."""
    prec = get_policy(prec)
    u, nk = a2.shape
    grid = (_cdiv(u, bu), _cdiv(nk, bk))
    out_spec = pl.BlockSpec((bu, 1), lambda i, kk: (i, 0))
    extra_in, extra_specs, has_y = _update_operands(y_in, alpha, beta, out_spec)
    kernel = functools.partial(
        _tvc2_body, nk=nk, bk=bk, k_blocks=grid[1], mask_k=nk % bk != 0,
        alpha=alpha, beta=beta, has_y=has_y,
    )
    params = _compiler_params(1)
    kwargs = {"compiler_params": params} if (params and not interpret) else {}
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bk), lambda i, kk: (0, kk)),
            pl.BlockSpec((bu, bk), lambda i, kk: (i, kk)),
            *extra_specs,
        ],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((u, 1), prec.storage),
        scratch_shapes=[pltpu.VMEM((bu, 1), prec.compute)],
        interpret=interpret,
        **kwargs,
    )(x.reshape(1, nk), a2, *extra_in)


# ---------------------------------------------------------------------------
# Batched variants: B independent same-shape contractions, ONE launch each.
# The leading grid dim walks batch blocks of size bb; per-batch vectors ride
# as (B, n) operands, the optional per-batch alpha/beta as one (B, 2) block.
# ---------------------------------------------------------------------------

def tvc3_batched(
    a3: jax.Array,
    x: jax.Array,
    *,
    prec: Precision | str = F32,
    bb: int = 1,
    bu: int = 8,
    bk: int = 128,
    bv: int = 128,
    alpha: float = 1.0,
    beta: float = 0.0,
    ab: jax.Array | None = None,
    y_in: jax.Array | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Y[z,u,v] = alpha_z * sum_k A[z,u,k,v] x[z,k] + beta_z * y_in[z,u,v]
    for all B batch rows in ONE launch; ragged dims stream with no padding
    copies, the batch dim needs no masking at all (out-of-bounds batch rows
    only feed discarded out-of-bounds output rows)."""
    prec = get_policy(prec)
    B, u, nk, v = a3.shape
    grid = (_cdiv(B, bb), _cdiv(u, bu), _cdiv(v, bv), _cdiv(nk, bk))
    out_spec = pl.BlockSpec((bb, bu, bv), lambda z, i, j, kk: (z, i, j))
    ab_spec = pl.BlockSpec((bb, 2), lambda z, i, j, kk: (z, 0))
    extra_in, extra_specs, has_ab, has_y = _update_operands_batched(
        ab, y_in, alpha, beta, ab_spec, out_spec)
    kernel = functools.partial(
        _tvc3_body, nk=nk, bk=bk, k_blocks=grid[3], mask_k=nk % bk != 0,
        alpha=alpha, beta=beta, has_y=has_y, has_ab=has_ab, batched=True,
    )
    params = _compiler_params(3)
    kwargs = {"compiler_params": params} if (params and not interpret) else {}
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bk), lambda z, i, j, kk: (z, kk)),
            pl.BlockSpec((bb, bu, bk, bv), lambda z, i, j, kk: (z, i, kk, j)),
            *extra_specs,
        ],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((B, u, v), prec.storage),
        scratch_shapes=[pltpu.VMEM((bb, bu, bv), prec.compute)],
        interpret=interpret,
        **kwargs,
    )(x, a3, *extra_in)


def tvc2_batched(
    a2: jax.Array,
    x: jax.Array,
    *,
    prec: Precision | str = F32,
    bb: int = 1,
    bu: int = 8,
    bk: int = 512,
    alpha: float = 1.0,
    beta: float = 0.0,
    ab: jax.Array | None = None,
    y_in: jax.Array | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Batched k = d-1 matvec: Y[z,u] = alpha_z * sum_k A[z,u,k] x[z,k]
    + beta_z * y_in[z,u], ONE launch for all B rows."""
    prec = get_policy(prec)
    B, u, nk = a2.shape
    grid = (_cdiv(B, bb), _cdiv(u, bu), _cdiv(nk, bk))
    out_spec = pl.BlockSpec((bb, bu, 1), lambda z, i, kk: (z, i, 0))
    ab_spec = pl.BlockSpec((bb, 2), lambda z, i, kk: (z, 0))
    extra_in, extra_specs, has_ab, has_y = _update_operands_batched(
        ab, y_in, alpha, beta, ab_spec, out_spec)
    kernel = functools.partial(
        _tvc2_body, nk=nk, bk=bk, k_blocks=grid[2], mask_k=nk % bk != 0,
        alpha=alpha, beta=beta, has_y=has_y, has_ab=has_ab, batched=True,
    )
    params = _compiler_params(2)
    kwargs = {"compiler_params": params} if (params and not interpret) else {}
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bk), lambda z, i, kk: (z, kk)),
            pl.BlockSpec((bb, bu, bk), lambda z, i, kk: (z, i, kk)),
            *extra_specs,
        ],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((B, u, 1), prec.storage),
        scratch_shapes=[pltpu.VMEM((bb, bu, 1), prec.compute)],
        interpret=interpret,
        **kwargs,
    )(x, a2, *extra_in)


def tvc4_batched(
    a4: jax.Array,
    x1: jax.Array,
    x2: jax.Array,
    *,
    prec: Precision | str = F32,
    bb: int = 1,
    bu: int = 8,
    b1: int = 8,
    b2: int = 8,
    bv: int = 128,
    alpha: float = 1.0,
    beta: float = 0.0,
    ab: jax.Array | None = None,
    y_in: jax.Array | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Batched fused pair: Y[z,u,v] = sum_{a,b} A[z,u,a,b,v] x1[z,a] x2[z,b]
    (+ per-batch alpha/beta/y epilogue), ONE launch for all B rows."""
    prec = get_policy(prec)
    B, u, n1, n2, v = a4.shape
    grid = (_cdiv(B, bb), _cdiv(u, bu), _cdiv(v, bv),
            _cdiv(n1, b1), _cdiv(n2, b2))
    out_spec = pl.BlockSpec((bb, bu, bv), lambda z, i, j, a, b: (z, i, j))
    ab_spec = pl.BlockSpec((bb, 2), lambda z, i, j, a, b: (z, 0))
    extra_in, extra_specs, has_ab, has_y = _update_operands_batched(
        ab, y_in, alpha, beta, ab_spec, out_spec)
    kernel = functools.partial(
        _tvc4_body, n1=n1, b1=b1, n2=n2, b2=b2,
        k1_blocks=grid[3], k2_blocks=grid[4],
        mask_1=n1 % b1 != 0, mask_2=n2 % b2 != 0,
        alpha=alpha, beta=beta, has_y=has_y, has_ab=has_ab, batched=True,
    )
    params = _compiler_params(3, 2)
    kwargs = {"compiler_params": params} if (params and not interpret) else {}
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, b1), lambda z, i, j, a, b: (z, a)),
            pl.BlockSpec((bb, b2), lambda z, i, j, a, b: (z, b)),
            pl.BlockSpec((bb, bu, b1, b2, bv),
                         lambda z, i, j, a, b: (z, i, a, b, j)),
            *extra_specs,
        ],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((B, u, v), prec.storage),
        scratch_shapes=[pltpu.VMEM((bb, bu, bv), prec.compute)],
        interpret=interpret,
        **kwargs,
    )(x1, x2, a4, *extra_in)


def tvc2_pair_batched(
    a3: jax.Array,
    x1: jax.Array,
    x2: jax.Array,
    *,
    prec: Precision | str = F32,
    bb: int = 1,
    bu: int = 8,
    b1: int = 8,
    b2: int = 128,
    alpha: float = 1.0,
    beta: float = 0.0,
    ab: jax.Array | None = None,
    y_in: jax.Array | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Batched fused-pair chain tail (v == 1): Y[z,u] = sum_{a,b} A[z,u,a,b]
    x1[z,a] x2[z,b] (+ per-batch alpha/beta/y), ONE launch for all B rows."""
    prec = get_policy(prec)
    B, u, n1, n2 = a3.shape
    grid = (_cdiv(B, bb), _cdiv(u, bu), _cdiv(n1, b1), _cdiv(n2, b2))
    out_spec = pl.BlockSpec((bb, bu, 1), lambda z, i, a, b: (z, i, 0))
    ab_spec = pl.BlockSpec((bb, 2), lambda z, i, a, b: (z, 0))
    extra_in, extra_specs, has_ab, has_y = _update_operands_batched(
        ab, y_in, alpha, beta, ab_spec, out_spec)
    kernel = functools.partial(
        _tvc2_pair_body, n1=n1, b1=b1, n2=n2, b2=b2,
        k1_blocks=grid[2], k2_blocks=grid[3],
        mask_1=n1 % b1 != 0, mask_2=n2 % b2 != 0,
        alpha=alpha, beta=beta, has_y=has_y, has_ab=has_ab, batched=True,
    )
    params = _compiler_params(2, 2)
    kwargs = {"compiler_params": params} if (params and not interpret) else {}
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, b1), lambda z, i, a, b: (z, a)),
            pl.BlockSpec((bb, b2), lambda z, i, a, b: (z, b)),
            pl.BlockSpec((bb, bu, b1, b2), lambda z, i, a, b: (z, i, a, b)),
            *extra_specs,
        ],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((B, u, 1), prec.storage),
        scratch_shapes=[pltpu.VMEM((bb, bu, 1), prec.compute)],
        interpret=interpret,
        **kwargs,
    )(x1, x2, a3, *extra_in)
