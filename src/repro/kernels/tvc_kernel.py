"""Mode-oblivious native TVC as a Pallas TPU kernel.

TPU adaptation of the paper's native algorithm (§2, §4.1): the tensor is
interpreted through its free (u, n_k, v) view and streamed through VMEM
exactly once, independent of the contraction mode k.  The paper's CPU kernel
distributes the column space of A^{n_k x uv} over cores with 512-bit SIMD; the
TPU analogue tiles (u, v) over the grid with (sublane, lane)-aligned VMEM
blocks and reduces n_k over the minor (sequential) grid dimension,
accumulating in a high-precision VMEM scratch (mixed precision §5.5: storage
dtype on HBM, compute dtype in the accumulator).

Two kernel bodies cover every mode with one streaming pass each:
  * v > 1  : blocks (bu, bk, bv), lanes on v          (modes k < d-1)
  * v == 1 : blocks (bu, bk),     lanes on n_k        (mode  k = d-1, matvec)

The wrapper in :mod:`repro.kernels.ops` zero-pads to block multiples (exact
for sums) and slices the result back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.mixed_precision import F32, Precision, get_policy


def _compiler_params(n_parallel: int):
    """dimension_semantics: parallel over output tiles, arbitrary over the
    reduction dim (must stay sequential for accumulation)."""
    try:
        return pltpu.CompilerParams(
            dimension_semantics=("parallel",) * n_parallel + ("arbitrary",)
        )
    except Exception:  # pragma: no cover - older/newer pallas API fallback
        return None


def _tvc3_body(x_ref, a_ref, y_ref, acc_ref, *, k_blocks: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(acc_ref.dtype)        # (bu, bk, bv)
    xv = x_ref[...].astype(acc_ref.dtype)       # (1, bk)
    acc_ref[...] += jnp.sum(a * xv[0][None, :, None], axis=1)

    @pl.when(kk == k_blocks - 1)
    def _emit():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


def _tvc2_body(x_ref, a_ref, y_ref, acc_ref, *, k_blocks: int):
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(acc_ref.dtype)        # (bu, bk)
    xv = x_ref[...].astype(acc_ref.dtype)       # (1, bk)
    acc_ref[...] += jnp.sum(a * xv, axis=1, keepdims=True)

    @pl.when(kk == k_blocks - 1)
    def _emit():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


def tvc3_padded(
    a3: jax.Array,
    x: jax.Array,
    *,
    prec: Precision | str = F32,
    bu: int = 8,
    bk: int = 128,
    bv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Y[u,v] = sum_k A[u,k,v] x[k]; dims must already be block multiples."""
    prec = get_policy(prec)
    u, nk, v = a3.shape
    assert u % bu == 0 and nk % bk == 0 and v % bv == 0, (a3.shape, bu, bk, bv)
    grid = (u // bu, v // bv, nk // bk)
    kernel = functools.partial(_tvc3_body, k_blocks=grid[2])
    params = _compiler_params(2)
    kwargs = {"compiler_params": params} if (params and not interpret) else {}
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bk), lambda i, j, kk: (0, kk)),
            pl.BlockSpec((bu, bk, bv), lambda i, j, kk: (i, kk, j)),
        ],
        out_specs=pl.BlockSpec((bu, bv), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((u, v), prec.storage),
        scratch_shapes=[pltpu.VMEM((bu, bv), prec.compute)],
        interpret=interpret,
        **kwargs,
    )(x.reshape(1, nk), a3)


def _tvc4_body(x1_ref, x2_ref, a_ref, y_ref, acc_ref, *, k1_blocks: int,
               k2_blocks: int):
    kk1 = pl.program_id(2)
    kk2 = pl.program_id(3)

    @pl.when((kk1 == 0) & (kk2 == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(acc_ref.dtype)          # (bu, b1, b2, bv)
    x1 = x1_ref[...].astype(acc_ref.dtype)        # (1, b1)
    x2 = x2_ref[...].astype(acc_ref.dtype)        # (1, b2)
    w = x1[0][:, None] * x2[0][None, :]           # (b1, b2)
    acc_ref[...] += jnp.einsum("uabv,ab->uv", a, w)

    @pl.when((kk1 == k1_blocks - 1) & (kk2 == k2_blocks - 1))
    def _emit():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


def tvc4_padded(
    a4: jax.Array,
    x1: jax.Array,
    x2: jax.Array,
    *,
    prec: Precision | str = F32,
    bu: int = 8,
    b1: int = 8,
    b2: int = 8,
    bv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """BEYOND-PAPER fused pair: Y[u,v] = sum_{a,b} A[u,a,b,v] x1[a] x2[b] in
    one streaming pass (two sequential reduction grid dims)."""
    prec = get_policy(prec)
    u, n1, n2, v = a4.shape
    assert u % bu == 0 and n1 % b1 == 0 and n2 % b2 == 0 and v % bv == 0
    grid = (u // bu, v // bv, n1 // b1, n2 // b2)
    kernel = functools.partial(_tvc4_body, k1_blocks=grid[2], k2_blocks=grid[3])
    params = _compiler_params(2)
    kwargs = {}
    if params is not None and not interpret:
        try:
            kwargs["compiler_params"] = pltpu.CompilerParams(
                dimension_semantics=("parallel", "parallel",
                                     "arbitrary", "arbitrary"))
        except Exception:  # pragma: no cover
            pass
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, b1), lambda i, j, a, b: (0, a)),
            pl.BlockSpec((1, b2), lambda i, j, a, b: (0, b)),
            pl.BlockSpec((bu, b1, b2, bv), lambda i, j, a, b: (i, a, b, j)),
        ],
        out_specs=pl.BlockSpec((bu, bv), lambda i, j, a, b: (i, j)),
        out_shape=jax.ShapeDtypeStruct((u, v), prec.storage),
        scratch_shapes=[pltpu.VMEM((bu, bv), prec.compute)],
        interpret=interpret,
        **kwargs,
    )(x1.reshape(1, n1), x2.reshape(1, n2), a4)


def tvc2_padded(
    a2: jax.Array,
    x: jax.Array,
    *,
    prec: Precision | str = F32,
    bu: int = 8,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Y[u] = sum_k A[u,k] x[k] (the k = d-1 matvec); block-multiple dims."""
    prec = get_policy(prec)
    u, nk = a2.shape
    assert u % bu == 0 and nk % bk == 0, (a2.shape, bu, bk)
    grid = (u // bu, nk // bk)
    kernel = functools.partial(_tvc2_body, k_blocks=grid[1])
    params = _compiler_params(1)
    kwargs = {"compiler_params": params} if (params and not interpret) else {}
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bk), lambda i, kk: (0, kk)),
            pl.BlockSpec((bu, bk), lambda i, kk: (i, kk)),
        ],
        out_specs=pl.BlockSpec((bu, 1), lambda i, kk: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((u, 1), prec.storage),
        scratch_shapes=[pltpu.VMEM((bu, 1), prec.compute)],
        interpret=interpret,
        **kwargs,
    )(x.reshape(1, nk), a2)
    return out
