import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (architecture x input-shape x
mesh) cell on 512 placeholder devices, record memory_analysis /
cost_analysis / collective schedule for EXPERIMENTS.md §Dry-run + §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
        [--mesh single|multi|both] [--out reports/dryrun.json]
        [--debug-mesh]   # tiny (2,4) mesh for CI

Every cell result is appended to the JSON incrementally, so a partial sweep
is still usable.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.analysis.roofline import analyze_compiled  # noqa: E402
from repro.configs import SHAPES, cell_is_runnable, get_config, list_archs  # noqa: E402
from repro.dist.sharding import activation_sharding  # noqa: E402
from repro.launch import specs as specs_mod  # noqa: E402
from repro.launch.mesh import make_debug_mesh, make_production_mesh  # noqa: E402


def _compile_costs(cfg, shape, mesh) -> dict:
    """Lower+compile one config and return per-device cost numbers."""
    from repro.analysis.roofline import collective_bytes, cost_dict
    with activation_sharding(mesh):
        fn, args = specs_mod.build_cell(cfg, shape, mesh)
        with mesh:
            compiled = jax.jit(fn).lower(*args).compile()
    cost = cost_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    out = {"flops": float(cost.get("flops", 0.0)),
           "bytes": float(cost.get("bytes accessed", 0.0)),
           "coll": coll}
    del compiled
    return out


def _lin2(f1, f2, x1, x2, x):
    """Linear extrapolation through (x1,f1),(x2,f2) evaluated at x."""
    slope = (f2 - f1) / (x2 - x1)
    return f1 + slope * (x - x1)


def shadow_costs(cfg, shape, mesh) -> dict:
    """Corrected per-device HLO flops/bytes/collective-bytes.

    XLA cost_analysis counts while-loop bodies ONCE, so the scanned
    production module undercounts by ~n_layers (and by the attention chunk
    counts).  The shadow configs unroll the layer stack (at reduced L, full
    width), use single-chunk attention, and extrapolate linearly in L (and in
    S for the linear-time rwkv family whose time scan can only be unrolled at
    small S).  Every number still comes from a compiled artifact.
    """
    import dataclasses as dc
    fam = cfg.family
    seq_extrap = fam == "rwkv" and shape.kind in ("train", "prefill")

    def shadow(L, S):
        c = dc.replace(
            cfg, n_layers=L, unroll_layers=True,
            time_scan_unroll=seq_extrap,
            q_chunk=max(S, 16), kv_chunk=max(S, 16))
        if cfg.moe is not None:
            c = dc.replace(c, moe=dc.replace(
                cfg.moe, group_tokens=max(shape.global_batch * S, 16)))
        sh = dc.replace(shape, seq_len=S) if S != shape.seq_len else shape
        return _compile_costs(c, sh, mesh)

    def merge(vals, fn):
        """Apply fn across the scalar fields incl. collective breakdown."""
        out = {"flops": fn([v["flops"] for v in vals]),
               "bytes": fn([v["bytes"] for v in vals])}
        keys = vals[0]["coll"].keys()
        out["coll"] = {k: max(0.0, fn([v["coll"][k] for v in vals]))
                       for k in keys}
        return out

    S = shape.seq_len
    if fam == "griffin":
        pat = len(cfg.griffin.pattern)
        n_super, tail = cfg.n_layers // pat, cfg.n_layers % pat
        f1, f2 = shadow(pat, S), shadow(2 * pat, S)
        parts = [f1, f2]
        ftail = shadow(pat + tail, S) if tail else None

        def combine(vs):
            v1, v2 = vs[0], vs[1]
            total = v1 + (n_super - 1) * (v2 - v1)
            if ftail is not None:
                total += vs[2] - vs[0]
            return total

        vals = [f1, f2] + ([ftail] if ftail else [])
        return merge(vals, combine)

    if seq_extrap:
        S1, S2 = 8, 16
        f11, f21 = shadow(1, S1), shadow(2, S1)
        f12, f22 = shadow(1, S2), shadow(2, S2)

        def combine(vs):
            v11, v21, v12, v22 = vs
            d = (v22 - v21 - v12 + v11) / ((2 - 1) * (S2 - S1))
            b = (v21 - v11) / (2 - 1) - d * S1
            c0 = (v12 - v11) / (S2 - S1) - d * 1
            a = v11 - b * 1 - c0 * S1 - d * 1 * S1
            return a + b * cfg.n_layers + c0 * S + d * cfg.n_layers * S

        return merge([f11, f21, f12, f22], combine)

    f1, f2 = shadow(1, S), shadow(2, S)
    return merge([f1, f2], lambda vs: _lin2(vs[0], vs[1], 1, 2, cfg.n_layers))


def run_cell(arch: str, shape_name: str, mesh, mesh_tag: str,
             smoke: bool = False, costs: bool = True,
             cfg_overrides: dict | None = None) -> dict:
    import dataclasses as _dc
    cfg = get_config(arch, smoke=smoke)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    runnable, why = cell_is_runnable(cfg, shape)
    if not runnable:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                "status": "skipped", "reason": why}
    t0 = time.time()
    try:
        with activation_sharding(mesh):
            fn, args = specs_mod.build_cell(cfg, shape, mesh)
            with mesh:
                lowered = jax.jit(fn).lower(*args)
                compiled = lowered.compile()
        mem = compiled.memory_analysis()
        report = analyze_compiled(compiled, arch=arch, shape=shape,
                                  mesh=mesh, cfg=cfg)
        raw = {"flops": report.hlo_flops, "bytes": report.hlo_bytes,
               "coll_bytes": report.coll_bytes}
        del compiled, lowered
        if costs:
            corr = shadow_costs(cfg, shape, mesh)
            report.hlo_flops = corr["flops"]
            report.hlo_bytes = corr["bytes"]
            report.coll_bytes = corr["coll"]["total"]
            report.coll_breakdown = {k: v for k, v in corr["coll"].items()
                                     if k != "total"}
        out = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "status": "ok", "compile_s": round(time.time() - t0, 1),
               "memory_analysis": {
                   "argument_bytes": int(mem.argument_size_in_bytes),
                   "output_bytes": int(mem.output_size_in_bytes),
                   "temp_bytes": int(mem.temp_size_in_bytes),
                   "code_bytes": int(mem.generated_code_size_in_bytes),
               },
               "raw_scanned_costs": raw,
               "roofline": report.to_dict()}
        return out
    except Exception as e:  # noqa: BLE001 — cell failures are data
        return {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                "status": "error", "compile_s": round(time.time() - t0, 1),
                "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="reports/dryrun.json")
    ap.add_argument("--debug-mesh", action="store_true")
    ap.add_argument("--smoke-configs", action="store_true")
    ap.add_argument("--no-costs", action="store_true",
                    help="skip the shadow cost compiles (proof-only pass)")
    ap.add_argument("--opts", default="",
                    help="comma list of perf toggles (see dist.sharding.KNOWN_OPTS)")
    ap.add_argument("--remat-policy", default=None, choices=[None, "full", "dots"])
    args = ap.parse_args()
    cfg_overrides = {"remat_policy": args.remat_policy} if args.remat_policy else None
    if args.opts:
        from repro.dist.sharding import set_opts
        set_opts(set(args.opts.split(",")))

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = []
    if args.debug_mesh:
        meshes.append(("debug2x4", make_debug_mesh()))
    else:
        if args.mesh in ("single", "both"):
            meshes.append(("16x16", make_production_mesh(multi_pod=False)))
        if args.mesh in ("multi", "both"):
            meshes.append(("2x16x16", make_production_mesh(multi_pod=True)))

    out_path = pathlib.Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results = []
    if out_path.exists():
        results = json.loads(out_path.read_text())
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") == "ok"}

    for mesh_tag, mesh in meshes:
        for arch in archs:
            for shape_name in shapes:
                if (arch, shape_name, mesh_tag) in done:
                    continue
                print(f"[dryrun] {arch} x {shape_name} x {mesh_tag} ...",
                      flush=True)
                # roofline costs are a single-pod deliverable; multi-pod pass
                # is the sharding proof (§Dry-run)
                want_costs = not args.no_costs and mesh_tag != "2x16x16"
                res = run_cell(arch, shape_name, mesh, mesh_tag,
                               smoke=args.smoke_configs, costs=want_costs,
                               cfg_overrides=cfg_overrides)
                print(f"  -> {res['status']}"
                      + (f" ({res.get('compile_s')}s)"
                         if "compile_s" in res else "")
                      + (f" {res.get('reason', res.get('error', ''))}"
                         if res["status"] != "ok" else ""), flush=True)
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"])
                           != (arch, shape_name, mesh_tag)]
                results.append(res)
                out_path.write_text(json.dumps(results, indent=1))

    ok = sum(r["status"] == "ok" for r in results)
    sk = sum(r["status"] == "skipped" for r in results)
    err = sum(r["status"] == "error" for r in results)
    print(f"[dryrun] done: {ok} ok, {sk} skipped, {err} errors -> {out_path}")
    if err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
