"""Production meshes.  A FUNCTION (not a module-level constant) so importing
this module never touches jax device state."""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()[:need]
    if len(devices) < need:
        raise RuntimeError(
            f"need {need} devices for {shape}, have {len(devices)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return jax.make_mesh(
        shape, axes, devices=devices,
        axis_types=(jax.sharding.AxisType.Auto,) * len(shape))


def make_debug_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CI-scale dry-run tests (8 virtual devices)."""
    need = math.prod(shape)
    return jax.make_mesh(
        shape, axes, devices=jax.devices()[:need],
        axis_types=(jax.sharding.AxisType.Auto,) * len(shape))
