"""Serving launcher: continuous batching through the decode engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --batch 4 --prompt-len 16 --steps 32 [--temperature 0.8 --top-k 40] \
        [--no-compress]
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.configs import get_config
from repro.models import extra_input_key, registry
from repro.serve import DecodeEngine, Request, RequestQueue


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=None)
    ap.add_argument("--requests", type=int, default=1)
    ap.add_argument("--no-compress", action="store_true",
                    help="skip rank-1 KV compression of retired contexts")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    mod = registry.get(cfg.family)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    eng = DecodeEngine(cfg, params, max_seq=args.max_seq, batch_size=args.batch)

    rng = np.random.default_rng(0)

    def one_extra():
        if extra_input_key(cfg) == "audio_embeds":
            return rng.normal(size=(1, cfg.encdec.n_audio_ctx, cfg.d_model)
                              ).astype(np.float32)
        if extra_input_key(cfg) == "img_embeds":
            d = cfg.vlm.img_embed_dim or cfg.d_model
            return rng.normal(size=(1, cfg.vlm.n_img_tokens, d)
                              ).astype(np.float32)
        return None

    n = args.batch * args.requests
    queue = RequestQueue(
        Request(rid=i,
                tokens=rng.integers(0, cfg.vocab_size, args.prompt_len
                                    ).astype(np.int32),
                max_new_tokens=args.steps, extra=one_extra())
        for i in range(n))
    t0 = time.perf_counter()
    results, stats = eng.serve(queue, temperature=args.temperature,
                               top_k=args.top_k,
                               compress=not args.no_compress)
    dt = time.perf_counter() - t0
    toks = stats.generated_tokens
    line = (f"served {stats.completed} requests / {toks} tokens in {dt:.2f}s "
            f"({stats.completed / dt:.2f} req/s, {toks / dt:.1f} tok/s, "
            f"recycled {stats.recycled} slots)")
    if not args.no_compress:
        line += (f"; kv compressed {stats.comp_dense_bytes}B -> "
                 f"{stats.comp_factor_bytes}B "
                 f"({stats.compression_ratio:.1f}x, "
                 f"{stats.comp_launches} launches)")
    print(line)
    print("first request:", results[0].tokens[:16])


if __name__ == "__main__":
    main()
