"""Serving launcher: batched generation with the decode engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --batch 4 --prompt-len 16 --steps 32 [--temperature 0.8 --top-k 40]
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.configs import get_config
from repro.models import extra_input_key, registry
from repro.serve import DecodeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=None)
    ap.add_argument("--requests", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    mod = registry.get(cfg.family)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    eng = DecodeEngine(cfg, params, max_seq=args.max_seq, batch_size=args.batch)

    rng = np.random.default_rng(0)
    extra = None
    if extra_input_key(cfg) == "audio_embeds":
        extra = rng.normal(size=(args.batch, cfg.encdec.n_audio_ctx,
                                 cfg.d_model)).astype(np.float32)
    elif extra_input_key(cfg) == "img_embeds":
        d = cfg.vlm.img_embed_dim or cfg.d_model
        extra = rng.normal(size=(args.batch, cfg.vlm.n_img_tokens, d)
                           ).astype(np.float32)

    batches = [rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len))
               for _ in range(args.requests)]
    t0 = time.perf_counter()
    results = eng.serve_queue(batches, args.steps, temperature=args.temperature,
                              top_k=args.top_k, extra=extra)
    dt = time.perf_counter() - t0
    toks = sum(r.tokens.size for r in results)
    print(f"generated {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, first batch: {results[0].tokens[0][:16]})")


if __name__ == "__main__":
    main()
