"""Abstract (ShapeDtypeStruct) inputs + step functions for every
(architecture x input-shape x mesh) dry-run cell.  Nothing here allocates
device memory: params/optimizer/cache are sharded ShapeDtypeStructs and the
step functions are lowered with .lower(...) only."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.dist.sharding import cache_specs, named_shardings, param_specs
from repro.models import extra_input_key, registry
from repro.train import optimizer as opt_mod
from repro.train.train_loop import TrainConfig


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def _dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _batch_entry(mesh: Mesh, b: int):
    dp = _dp_axes(mesh)
    sz = math.prod(mesh.shape[a] for a in dp)
    if dp and b % sz == 0:
        return dp if len(dp) > 1 else dp[0]
    return None


def abstract_params(cfg: ModelConfig, mesh: Mesh, *, fsdp: bool = True):
    mod = registry.get(cfg.family)
    shapes = jax.eval_shape(lambda k: mod.init(cfg, k), jax.random.PRNGKey(0))
    shardings = named_shardings(cfg, shapes, mesh, fsdp=fsdp)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)


def _opt_spec_from_param(pspec: P, pshape, sshape) -> P:
    """Optimizer leaves mirror the param spec; factored stats drop dims."""
    if len(sshape) == len(pshape):
        return pspec
    if len(sshape) == len(pshape) - 1:
        # vr drops the last dim; vc drops the second-to-last
        if tuple(sshape) == tuple(pshape[:-1]):
            return P(*pspec[:-1]) if len(pspec) else P()
        if tuple(sshape) == tuple(pshape[:-2] + pshape[-1:]):
            ent = list(pspec[:-2]) + list(pspec[-1:]) if len(pspec) >= 2 else []
            return P(*ent)
    return P()


def abstract_opt_state(cfg: ModelConfig, mesh: Mesh, params_abs, ocfg):
    pspecs = param_specs(cfg, params_abs, mesh)
    shapes = jax.eval_shape(lambda: opt_mod.init(ocfg, params_abs))

    def build(ps, pa, leaf_states):
        out = {}
        for name, s in leaf_states.items():
            spec = _opt_spec_from_param(ps, pa.shape, s.shape)
            out[name] = _sds(s.shape, s.dtype, mesh, spec)
        return out

    leaves = jax.tree.map(build, pspecs, params_abs, shapes["leaves"],
                          is_leaf=lambda x: isinstance(x, P))
    return {"step": _sds((), jnp.int32, mesh, P()), "leaves": leaves}


def abstract_batch(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                   seq_len: int | None = None):
    b = shape.global_batch
    s = seq_len if seq_len is not None else shape.seq_len
    dpe = _batch_entry(mesh, b)
    batch = {"tokens": _sds((b, s), jnp.int32, mesh, P(dpe))}
    extra = extra_input_key(cfg)
    if extra == "img_embeds":
        d = cfg.vlm.img_embed_dim or cfg.d_model
        batch[extra] = _sds((b, cfg.vlm.n_img_tokens, d), jnp.bfloat16, mesh, P(dpe))
    elif extra == "audio_embeds":
        batch[extra] = _sds((b, cfg.encdec.n_audio_ctx, cfg.d_model),
                            jnp.bfloat16, mesh, P(dpe))
    return batch


def abstract_cache(cfg: ModelConfig, mesh: Mesh, batch: int, max_seq: int):
    mod = registry.get(cfg.family)
    shapes = jax.eval_shape(lambda: mod.init_cache(cfg, batch, max_seq))
    specs = cache_specs(cfg, shapes, mesh)
    return jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), shapes, specs)


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh):
    """Returns (fn, abstract_args) for the cell's step function:
    train -> train_step; prefill -> prefill; decode -> one decode_step with a
    full-length cache."""
    mod = registry.get(cfg.family)
    ocfg = opt_mod.OptConfig(kind=cfg.optimizer)
    tcfg = TrainConfig(opt=ocfg, mode="gspmd")

    if shape.kind == "train":
        from repro.train.train_loop import make_train_step
        step, _ = make_train_step(cfg, mesh, tcfg)
        params = abstract_params(cfg, mesh)
        opt_state = abstract_opt_state(cfg, mesh, params, ocfg)
        batch = abstract_batch(cfg, shape, mesh)
        return step, (params, opt_state, {}, batch)

    # serving cells: optionally drop FSDP weight sharding (training layout
    # != serving layout — no optimizer state to shard at inference)
    from repro.dist.sharding import opt_enabled
    serve_fsdp = not opt_enabled("serving_replicated_params")
    params = abstract_params(cfg, mesh, fsdp=serve_fsdp)
    if shape.kind == "prefill":
        batch = abstract_batch(cfg, shape, mesh)
        total_seq = shape.seq_len + (
            cfg.vlm.n_img_tokens if cfg.family == "vlm" else 0)
        cache = abstract_cache(cfg, mesh, shape.global_batch, total_seq)
        extra = extra_input_key(cfg)

        if extra:
            def fn(p, tokens, cache, extra_in):
                return mod.prefill(cfg, p, tokens, cache, extra_in)
            return fn, (params, batch["tokens"], cache, batch[extra])

        def fn(p, tokens, cache):
            return mod.prefill(cfg, p, tokens, cache)
        return fn, (params, batch["tokens"], cache)

    # decode: one new token against a seq_len cache
    b = shape.global_batch
    cache = abstract_cache(cfg, mesh, b, shape.seq_len)
    tokens1 = _sds((b, 1), jnp.int32, mesh, P(_batch_entry(mesh, b)))

    def fn(p, cache, toks):
        return mod.decode_step(cfg, p, cache, toks)
    return fn, (params, cache, tokens1)
