"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
        --steps 200 --batch 8 --seq 64 --mesh 1x1 [--mode dp_explicit]
        [--compress] [--mp-wire bf16] [--staged-wire] [--ckpt-dir ckpts/run1]

On the real cluster the same entry point runs under a (16,16) or (2,16,16)
mesh; on this container use --mesh 1x1 (or a virtual-device XLA flag).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLMData
from repro.dist.sharding import activation_sharding
from repro.models import extra_input_key
from repro.train import optimizer as opt_mod
from repro.train.grad_compress import CompressorCfg
from repro.train.train_loop import TrainConfig, train


def parse_mesh(s: str):
    dims = tuple(int(x) for x in s.split("x"))
    axes = {1: ("data",), 2: ("data", "model"), 3: ("pod", "data", "model")}[len(dims)]
    return jax.make_mesh(dims, axes,
                         devices=jax.devices()[: int(__import__("math").prod(dims))],
                         axis_types=(jax.sharding.AxisType.Auto,) * len(dims))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--mode", default="gspmd", choices=["gspmd", "dp_explicit"])
    ap.add_argument("--compress", action="store_true",
                    help="dHOPM_3 gradient compression (dp_explicit mode)")
    ap.add_argument("--compress-rank", type=int, default=4)
    ap.add_argument("--compress-sweeps", type=int, default=2)
    ap.add_argument("--mp-wire", default=None,
                    help="mixed-precision gradient collectives, e.g. bf16")
    ap.add_argument("--staged-wire", action="store_true",
                    help="run the mp-wire gradient sync through the staged "
                         "(resumable per-hop) collective")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = parse_mesh(args.mesh)
    comp = None
    if args.compress:
        args.mode = "dp_explicit"
        comp = CompressorCfg(rank=args.compress_rank, sweeps=args.compress_sweeps)
    tcfg = TrainConfig(
        opt=opt_mod.OptConfig(kind=cfg.optimizer, lr=args.lr,
                              warmup_steps=max(2, args.steps // 20),
                              total_steps=args.steps),
        mode=args.mode, compression=comp, mp_wire=args.mp_wire,
        staged_wire=args.staged_wire,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)

    extra = extra_input_key(cfg)
    extra_shape = None
    if extra == "img_embeds":
        extra_shape = (cfg.vlm.n_img_tokens, cfg.vlm.img_embed_dim or cfg.d_model)
    elif extra == "audio_embeds":
        extra_shape = (cfg.encdec.n_audio_ctx, cfg.d_model)
    data = SyntheticLMData(
        DataConfig(cfg.vocab_size, args.seq, args.batch, seed=0,
                   extra_key=extra, extra_shape=extra_shape), mesh)

    with activation_sharding(mesh):
        params, opt_state, hist = train(
            cfg, mesh, tcfg, data.iterate(0), args.steps,
            log_every=args.log_every)
    print(f"final loss: {hist[-1]['loss']:.4f} (first {hist[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
