"""Model zoo: family -> module registry.  Every module implements
init, forward, loss_fn, init_cache, prefill, decode_step,
param_count, active_param_count (uniform API, pure functions over pytrees)."""
from __future__ import annotations

from . import encdec, griffin, rwkv, transformer


class _Registry:
    _map = {
        "dense": transformer,
        "moe": transformer,
        "vlm": transformer,
        "rwkv": rwkv,
        "griffin": griffin,
        "encdec": encdec,
    }

    def get(self, family: str):
        try:
            return self._map[family]
        except KeyError:
            raise KeyError(f"unknown model family {family!r}; "
                           f"have {sorted(self._map)}") from None


registry = _Registry()


def extra_input_key(cfg) -> str | None:
    """The stubbed-frontend input each family expects in its batch."""
    if cfg.family == "vlm":
        return "img_embeds"
    if cfg.family == "encdec":
        return "audio_embeds"
    return None
