"""Attention: memory-safe chunked (online-softmax / flash-style) attention
for train & prefill, plus single-token decode against a KV cache.

Layouts: q (B, KV, G, S, hd), k/v (B, KV, S, hd) — GQA groups G = H/KV kept
as an explicit dim so kv is never materialized H-wide.  Scores and the
softmax run in f32 (storage stays bf16), per the mixed-precision discipline.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

F32 = jnp.float32
NEG = -1e30


def _ceil_to(n, m):
    return -(-n // m) * m


def chunked_attention(
    q, k, v, *,
    causal: bool = True,
    window: int | None = None,
    q_chunk: int = 2048,
    kv_chunk: int = 1024,
    q_offset: int = 0,
):
    """q: (B, H, Sq, hd); k, v: (B, KV, Skv, hd).  Returns (B, H, Sq, hd).

    Outer lax.map over q chunks, inner lax.scan over kv chunks with online
    softmax — peak score memory is (B, H, Cq, Ck) regardless of sequence
    length.  ``q_offset`` positions q tokens at ``q_offset + i`` within the
    kv timeline (used by prefill-with-prefix and tests).
    """
    B, H, Sq, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    Skv = k.shape[2]
    hdv = v.shape[-1]           # may differ from q/k head dim (MLA)
    cq = min(q_chunk, Sq)
    ck = min(kv_chunk, Skv)
    # pad to chunk multiples (padded kv masked out; padded q rows sliced off)
    Sq_p, Skv_p = _ceil_to(Sq, cq), _ceil_to(Skv, ck)
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sq_p - Sq), (0, 0)))
    if Skv_p != Skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Skv_p - Skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Skv_p - Skv), (0, 0)))
    nq, nk = Sq_p // cq, Skv_p // ck
    qg = q.reshape(B, KV, G, Sq_p, hd)
    scale = hd ** -0.5

    kc = k.reshape(B, KV, nk, ck, hd)
    vc = v.reshape(B, KV, nk, ck, hdv)

    def do_q_chunk(iq):
        qi = lax.dynamic_slice_in_dim(qg, iq * cq, cq, axis=3)  # (B,KV,G,cq,hd)
        q_pos = q_offset + iq * cq + jnp.arange(cq)

        def kv_step(carry, inputs):
            m, l, acc = carry
            ik, k_i, v_i = inputs
            k_pos = ik * ck + jnp.arange(ck)
            s = jnp.einsum("bkgqh,bkch->bkgqc", qi.astype(F32), k_i.astype(F32))
            s = s * scale
            mask = k_pos[None, :] < Skv  # mask kv padding
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= q_pos[:, None] - k_pos[None, :] < window
            s = jnp.where(mask[None, None, None], s, NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqc,bkch->bkgqh", p, v_i.astype(F32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, cq), NEG, F32)
        l0 = jnp.zeros((B, KV, G, cq), F32)
        a0 = jnp.zeros((B, KV, G, cq, hdv), F32)
        ks = jnp.moveaxis(kc, 2, 0)  # (nk, B, KV, ck, hd)
        vs = jnp.moveaxis(vc, 2, 0)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0),
                                  (jnp.arange(nk), ks, vs))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # cast INSIDE the chunk: the stacked (nq, B, KV, G, cq, hdv) buffer
        # then lives in the storage dtype, halving its footprint (§Perf C4)
        return out.astype(q.dtype)

    outs = lax.map(do_q_chunk, jnp.arange(nq))      # (nq,B,KV,G,cq,hdv)
    out = jnp.moveaxis(outs, 0, 3).reshape(B, KV, G, Sq_p, hdv)
    out = out.reshape(B, H, Sq_p, hdv)[:, :, :Sq]
    return out


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int | None = None):
    """q: (B, H, 1, hd); caches: (B, KV, S, hd); cache_len: scalar number of
    valid positions (the new token's kv must already be written).
    Padded/unwritten positions are masked.  Returns (B, H, 1, hd)."""
    B, H, _, hd = q.shape
    KV = k_cache.shape[1]
    G = H // KV
    S = k_cache.shape[2]
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bksh->bkgs", qg.astype(F32), k_cache.astype(F32))
    s = s * (hd ** -0.5)
    pos = jnp.arange(S)
    mask = pos[None, :] < cache_len
    if window is not None:
        mask &= pos[None, :] > cache_len - 1 - window
    s = jnp.where(mask[None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bksh->bkgh", p, v_cache.astype(F32))
    return out.reshape(B, H, 1, hd).astype(q.dtype)


def reference_attention(q, k, v, *, causal=True, window=None, q_offset: int = 0):
    """Dense oracle for tests (no chunking)."""
    B, H, Sq, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    Skv = k.shape[2]
    qg = q.reshape(B, KV, G, Sq, hd)
    s = jnp.einsum("bkgqh,bksh->bkgqs", qg.astype(F32), k.astype(F32)) * hd ** -0.5
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    s = jnp.where(mask[None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bksh->bkgqh", p, v.astype(F32))
    return out.reshape(B, H, Sq, hd).astype(q.dtype)
