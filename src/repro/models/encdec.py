"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv/audio frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, n_audio_ctx, d_model).  Encoder blocks are
non-causal self-attention; decoder blocks are causal self-attention +
cross-attention to the encoder output.  LayerNorm + GELU MLP + biases, learned
positions replaced by fixed sinusoidal tables (backbone-equivalent compute).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.sharding import constrain
from .attention import chunked_attention, decode_attention
from .layers import (
    apply_mlp, apply_norm, cross_entropy, dense_init, embed_init, init_mlp,
    init_norm, logits_from_hidden, scan_layers,
)

F32 = jnp.float32


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _sinusoid(length: int, channels: int):
    pos = np.arange(length)[:, None]
    dim = np.arange(channels // 2)[None]
    inv = np.exp(-np.log(10000.0) * dim / max(1, channels // 2 - 1))
    ang = pos * inv
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], axis=1),
                       jnp.float32)


def _init_attn(key, cfg, dtype):
    D, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (D, H * hd), dtype),
        "bq": jnp.zeros((H * hd,), dtype),
        "wk": dense_init(ks[1], (D, H * hd), dtype),
        "wv": dense_init(ks[2], (D, H * hd), dtype),
        "bv": jnp.zeros((H * hd,), dtype),
        "wo": dense_init(ks[3], (H * hd, D), dtype),
        "bo": jnp.zeros((D,), dtype),
    }


def _qkv(cfg, p, xq, xkv):
    B, Sq, D = xq.shape
    Skv = xkv.shape[1]
    H, hd = cfg.n_heads, cfg.hd
    q = (xq @ p["wq"] + p["bq"]).reshape(B, Sq, H, hd).transpose(0, 2, 1, 3)
    k = (xkv @ p["wk"]).reshape(B, Skv, H, hd).transpose(0, 2, 1, 3)
    v = (xkv @ p["wv"] + p["bv"]).reshape(B, Skv, H, hd).transpose(0, 2, 1, 3)
    return q, k, v


def _attn_out(cfg, p, out, B, S):
    out = out.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.hd)
    return out @ p["wo"] + p["bo"]


def _init_enc_layer(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": init_norm(cfg, dtype),
        "attn": _init_attn(ks[0], cfg, dtype),
        "ln2": init_norm(cfg, dtype),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, "gelu", dtype),
    }


def _init_dec_layer(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {
        "ln1": init_norm(cfg, dtype),
        "self": _init_attn(ks[0], cfg, dtype),
        "ln_x": init_norm(cfg, dtype),
        "cross": _init_attn(ks[1], cfg, dtype),
        "ln2": init_norm(cfg, dtype),
        "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, "gelu", dtype),
    }


def init(cfg, key):
    dtype = _dtype(cfg)
    e = cfg.encdec
    ks = jax.random.split(key, 4)
    return {
        "embed": {"tok": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model), dtype)},
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(
            jax.random.split(ks[1], e.n_enc_layers)),
        "enc_ln": init_norm(cfg, dtype),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(
            jax.random.split(ks[2], cfg.n_layers)),
        "dec_ln": init_norm(cfg, dtype),
    }


def encode(cfg, params, audio_embeds):
    """audio_embeds: (B, n_audio_ctx, D) — the stubbed conv frontend output."""
    x = audio_embeds.astype(_dtype(cfg))
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)

    def body(h, lp):
        u = apply_norm(cfg, lp["ln1"], h)
        q, k, v = _qkv(cfg, lp["attn"], u, u)
        out = chunked_attention(q, k, v, causal=False,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        h = h + _attn_out(cfg, lp["attn"], out, h.shape[0], h.shape[1])
        h = h + apply_mlp(lp["mlp"], apply_norm(cfg, lp["ln2"], h), "gelu")
        return h, None

    x, _ = scan_layers(body, x, params["enc_layers"],
                       unroll=cfg.unroll_layers, remat=cfg.remat)
    return apply_norm(cfg, params["enc_ln"], x)


def _dec_block(cfg, lp, h, enc_out, positions):
    h = constrain(h, "dp", None, None)
    u = apply_norm(cfg, lp["ln1"], h)
    q, k, v = _qkv(cfg, lp["self"], u, u)
    out = chunked_attention(q, k, v, causal=True,
                            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    h = h + _attn_out(cfg, lp["self"], out, h.shape[0], h.shape[1])
    u = apply_norm(cfg, lp["ln_x"], h)
    q2, k2, v2 = _qkv(cfg, lp["cross"], u, enc_out)
    out2 = chunked_attention(q2, k2, v2, causal=False,
                             q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    h = h + _attn_out(cfg, lp["cross"], out2, h.shape[0], h.shape[1])
    h = h + apply_mlp(lp["mlp"], apply_norm(cfg, lp["ln2"], h), "gelu")
    return h, (k, v)


def forward(cfg, params, tokens, audio_embeds=None):
    """Teacher-forced training forward.  tokens: (B, S_dec)."""
    enc_out = encode(cfg, params, audio_embeds)
    B, S = tokens.shape
    x = params["embed"]["tok"][tokens] + _sinusoid(S, cfg.d_model).astype(_dtype(cfg))
    positions = jnp.arange(S)

    def body(h, lp):
        h, _ = _dec_block(cfg, lp, h, enc_out, positions)
        return h, None

    x, _ = scan_layers(body, x, params["dec_layers"],
                       unroll=cfg.unroll_layers, remat=cfg.remat)
    x = apply_norm(cfg, params["dec_ln"], x)
    return logits_from_hidden(params["embed"], x, cfg.vocab_size), {"moe_aux": jnp.zeros((), F32)}


def loss_fn(cfg, params, batch):
    tokens = batch["tokens"]
    logits, _ = forward(cfg, params, tokens, batch["audio_embeds"])
    ce = cross_entropy(logits[:, :-1], tokens[:, 1:], cfg.vocab_size)
    return ce, {"ce": ce}


def init_cache(cfg, batch: int, max_seq: int, dtype=None):
    dtype = dtype or _dtype(cfg)
    L, H, hd = cfg.n_layers, cfg.n_heads, cfg.hd
    e = cfg.encdec
    return {
        "k": jnp.zeros((L, batch, H, max_seq, hd), dtype),
        "v": jnp.zeros((L, batch, H, max_seq, hd), dtype),
        "xk": jnp.zeros((L, batch, H, e.n_audio_ctx, hd), dtype),
        "xv": jnp.zeros((L, batch, H, e.n_audio_ctx, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(cfg, params, tokens, cache, audio_embeds=None):
    """Encode audio, precompute cross-attention K/V, run the prompt."""
    enc_out = encode(cfg, params, audio_embeds)
    B, S = tokens.shape
    x = params["embed"]["tok"][tokens] + _sinusoid(S, cfg.d_model).astype(_dtype(cfg))
    positions = jnp.arange(S)

    def body(h, lp):
        h, kv = _dec_block(cfg, lp, h, enc_out, positions)
        # cross K/V are prompt-independent; compute once
        xk = (enc_out @ lp["cross"]["wk"]).reshape(
            B, -1, cfg.n_heads, cfg.hd).transpose(0, 2, 1, 3)
        xv = (enc_out @ lp["cross"]["wv"] + lp["cross"]["bv"]).reshape(
            B, -1, cfg.n_heads, cfg.hd).transpose(0, 2, 1, 3)
        return h, (kv[0], kv[1], xk, xv)

    x, (k, v, xk, xv) = scan_layers(body, x, params["dec_layers"],
                                    unroll=cfg.unroll_layers)
    k = constrain(k, None, "dp", None, "sp", None)
    v = constrain(v, None, "dp", None, "sp", None)
    x = apply_norm(cfg, params["dec_ln"], x[:, -1:])
    cache = dict(cache)
    cache["k"] = lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), 0, axis=3)
    cache["v"] = lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), 0, axis=3)
    cache["xk"] = xk.astype(cache["xk"].dtype)
    cache["xv"] = xv.astype(cache["xv"].dtype)
    cache["pos"] = jnp.asarray(S, jnp.int32)
    return cache, logits_from_hidden(params["embed"], x, cfg.vocab_size)


def decode_step(cfg, params, cache, tokens_1):
    B = tokens_1.shape[0]
    pos = cache["pos"]
    H, hd = cfg.n_heads, cfg.hd
    x = params["embed"]["tok"][tokens_1]
    x = x + lax.dynamic_slice_in_dim(
        _sinusoid(cache["k"].shape[3], cfg.d_model), pos, 1, axis=0
    ).astype(x.dtype)

    def body(h, inputs):
        lp, kc, vc, xk, xv = inputs
        u = apply_norm(cfg, lp["ln1"], h)
        q, k, v = _qkv(cfg, lp["self"], u, u)
        kc = lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos, axis=2)
        vc = lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos, axis=2)
        out = decode_attention(q, kc, vc, pos + 1)
        h = h + _attn_out(cfg, lp["self"], out, B, 1)
        u = apply_norm(cfg, lp["ln_x"], h)
        q2 = (u @ lp["cross"]["wq"] + lp["cross"]["bq"]).reshape(
            B, 1, H, hd).transpose(0, 2, 1, 3)
        out2 = decode_attention(q2, xk, xv, xk.shape[2])
        h = h + _attn_out(cfg, lp["cross"], out2, B, 1)
        h = h + apply_mlp(lp["mlp"], apply_norm(cfg, lp["ln2"], h), "gelu")
        return h, (kc, vc)

    x, (kc, vc) = scan_layers(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]), unroll=cfg.unroll_layers)
    x = apply_norm(cfg, params["dec_ln"], x)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = kc, vc
    new_cache["pos"] = pos + 1
    return new_cache, logits_from_hidden(params["embed"], x, cfg.vocab_size)


def param_count(cfg) -> int:
    D, H, hd, F = cfg.d_model, cfg.n_heads, cfg.hd, cfg.d_ff
    attn = 4 * D * H * hd
    mlp = 2 * D * F
    enc = cfg.encdec.n_enc_layers * (attn + mlp)
    dec = cfg.n_layers * (2 * attn + mlp)
    return cfg.padded_vocab * D + enc + dec


def active_param_count(cfg) -> int:
    return param_count(cfg)
