"""RecurrentGemma / Griffin (arXiv:2402.19427): RG-LRU recurrent blocks
interleaved 2:1 with local (windowed, MQA) attention.

RG-LRU:  r_t = σ(W_a x_t + b_a),  i_t = σ(W_i x_t + b_i)
         log a_t = -c · softplus(Λ) · r_t          (per channel)
         h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

The diagonal linear recurrence is evaluated with lax.associative_scan
(log-depth parallel prefix) for train/prefill — the TPU-native alternative to
the paper-family's sequential CUDA scan — and as a single fused step at
decode.  Local attention uses a ring-buffer KV cache of one window.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.sharding import constrain, opt_enabled
from .attention import chunked_attention
from .layers import (
    apply_norm, apply_rope, cross_entropy, dense_init, embed_init,
    init_mlp, apply_mlp, init_norm, logits_from_hidden, scan_layers,
)

F32 = jnp.float32
NEG = -1e30


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _counts(cfg):
    """(n_super, n_tail): super-blocks follow cfg.griffin.pattern; the tail
    layers (n_layers % len(pattern)) are recurrent blocks."""
    pat = len(cfg.griffin.pattern)
    return cfg.n_layers // pat, cfg.n_layers % pat


# ---------------- blocks ----------------

def _init_rec(key, cfg, dtype):
    g = cfg.griffin
    D, W = cfg.d_model, g.lru_width
    ks = jax.random.split(key, 6)
    return {
        "ln": init_norm(cfg, dtype),
        "w_gate": dense_init(ks[0], (D, W), dtype),
        "w_x": dense_init(ks[1], (D, W), dtype),
        "conv_w": dense_init(ks[2], (g.conv_width, W), dtype, scale=0.3),
        "conv_b": jnp.zeros((W,), dtype),
        "w_a": dense_init(ks[3], (W, W), dtype),
        "b_a": jnp.zeros((W,), dtype),
        "w_i": dense_init(ks[4], (W, W), dtype),
        "b_i": jnp.zeros((W,), dtype),
        "lam": jnp.full((W,), 1.0, F32),     # softplus(Λ) init ~ 1.3
        "w_out": dense_init(ks[5], (W, D), dtype),
        "mlp_ln": init_norm(cfg, dtype),
        "mlp": init_mlp(jax.random.fold_in(key, 7), D, cfg.d_ff, cfg.mlp, dtype),
    }


def _init_attn_block(key, cfg, dtype):
    D, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    KV = cfg.n_kv_heads  # 1 (MQA)
    ks = jax.random.split(key, 5)
    return {
        "ln": init_norm(cfg, dtype),
        "wq": dense_init(ks[0], (D, H * hd), dtype),
        "wk": dense_init(ks[1], (D, KV * hd), dtype),
        "wv": dense_init(ks[2], (D, KV * hd), dtype),
        "wo": dense_init(ks[3], (H * hd, D), dtype),
        "mlp_ln": init_norm(cfg, dtype),
        "mlp": init_mlp(ks[4], D, cfg.d_ff, cfg.mlp, dtype),
    }


def _conv1d(p, x, conv_state=None):
    """Depthwise causal conv, width cw.  x: (B,S,W).  conv_state: (B,cw-1,W)
    carry-in from the previous segment.  Returns (y, new_state)."""
    cw = p["conv_w"].shape[0]
    B, S, W = x.shape
    if conv_state is None:
        conv_state = jnp.zeros((B, cw - 1, W), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)           # (B, S+cw-1, W)
    y = sum(xp[:, i:i + S] * p["conv_w"][i] for i in range(cw)) + p["conv_b"]
    return y.astype(x.dtype), xp[:, -(cw - 1):]


def _rg_lru(p, x, h0, c: float):
    """x: (B,S,W) f32; h0: (B,W) carry.  Parallel prefix over time."""
    r = jax.nn.sigmoid((x @ p["w_a"].astype(F32)) + p["b_a"].astype(F32))
    i = jax.nn.sigmoid((x @ p["w_i"].astype(F32)) + p["b_i"].astype(F32))
    log_a = -c * jax.nn.softplus(p["lam"]) * r              # (B,S,W)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * x)
    # fold the initial state into the first step: h_1 = a_1 h_0 + b_1
    b = gated.at[:, 0].add(a[:, 0] * h0)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    A, Bc = lax.associative_scan(combine, (a, b), axis=1)
    return Bc, Bc[:, -1]                                     # (B,S,W), (B,W)


def _rec_block(cfg, p, x, state):
    """state: {"conv": (B,cw-1,W), "h": (B,W)}."""
    g = cfg.griffin
    u = apply_norm(cfg, p["ln"], x)
    gate = jax.nn.gelu((u @ p["w_gate"]).astype(F32))
    xb = u @ p["w_x"]
    xb, conv_state = _conv1d(p, xb, state["conv"])
    h, h_last = _rg_lru(p, xb.astype(F32), state["h"], g.lru_c)
    y = ((gate * h).astype(x.dtype)) @ p["w_out"]
    x = x + y
    x = x + apply_mlp(p["mlp"], apply_norm(cfg, p["mlp_ln"], x), cfg.mlp)
    return x, {"conv": conv_state, "h": h_last}


def _attn_block(cfg, p, x, positions):
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    u = apply_norm(cfg, p["ln"], x)
    q = (u @ p["wq"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = (u @ p["wk"]).reshape(B, S, KV, hd).transpose(0, 2, 1, 3)
    v = (u @ p["wv"]).reshape(B, S, KV, hd).transpose(0, 2, 1, 3)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = chunked_attention(q, k, v, causal=True, window=cfg.griffin.window,
                            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    x = x + out @ p["wo"]
    x = x + apply_mlp(p["mlp"], apply_norm(cfg, p["mlp_ln"], x), cfg.mlp)
    return x, (k, v)


# ---------------- model ----------------

def init(cfg, key):
    dtype = _dtype(cfg)
    n_super, n_tail = _counts(cfg)
    ks = jax.random.split(key, 4)

    def init_super(k):
        kk = jax.random.split(k, len(cfg.griffin.pattern))
        return {
            "rec": jax.vmap(lambda kx: _init_rec(kx, cfg, dtype))(
                kk[: len(cfg.griffin.pattern) - 1]),
            "attn": _init_attn_block(kk[-1], cfg, dtype),
        }

    params = {
        "embed": {"tok": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model), dtype)},
        "supers": jax.vmap(init_super)(jax.random.split(ks[1], n_super)),
        "ln_f": init_norm(cfg, dtype),
    }
    if n_tail:
        params["tail"] = jax.vmap(lambda kx: _init_rec(kx, cfg, dtype))(
            jax.random.split(ks[2], n_tail))
    return params


def _zero_states(cfg, batch, dtype):
    g = cfg.griffin
    n_super, n_tail = _counts(cfg)
    n_rec_per = len(g.pattern) - 1
    W = g.lru_width
    states = {
        "conv": jnp.zeros((n_super, n_rec_per, batch, g.conv_width - 1, W), dtype),
        "h": jnp.zeros((n_super, n_rec_per, batch, W), F32),
    }
    if n_tail:
        states["tail_conv"] = jnp.zeros((n_tail, batch, g.conv_width - 1, W), dtype)
        states["tail_h"] = jnp.zeros((n_tail, batch, W), F32)
    return states


def _run_layers(cfg, params, x, states, positions, collect_kv: bool):
    n_super, n_tail = _counts(cfg)
    n_rec_per = len(cfg.griffin.pattern) - 1

    def super_body(carry, inputs):
        h = carry
        seq_role = "sp" if opt_enabled("seq_shard_activations") else None
        h = constrain(h, "dp", seq_role, None)
        sp, conv, hs = inputs

        def rec_body(hh, rin):
            rp, st_conv, st_h = rin
            hh, new_st = _rec_block(cfg, rp, hh, {"conv": st_conv, "h": st_h})
            return hh, (new_st["conv"], new_st["h"])

        h, (new_conv, new_h) = scan_layers(rec_body, h, (sp["rec"], conv, hs),
                                           unroll=cfg.unroll_layers)
        h, kv = _attn_block(cfg, sp["attn"], h, positions)
        outs = (new_conv, new_h) + ((kv,) if collect_kv else ())
        return h, outs

    x, outs = scan_layers(super_body, x,
                          (params["supers"], states["conv"], states["h"]),
                          unroll=cfg.unroll_layers, remat=cfg.remat,
                          remat_policy=cfg.remat_policy)
    new_states = {"conv": outs[0], "h": outs[1]}
    kvs = outs[2] if collect_kv else None

    if n_tail:
        def tail_body(hh, rin):
            rp, st_conv, st_h = rin
            hh, new_st = _rec_block(cfg, rp, hh, {"conv": st_conv, "h": st_h})
            return hh, (new_st["conv"], new_st["h"])

        x, (tc, th) = scan_layers(
            tail_body, x, (params["tail"], states["tail_conv"], states["tail_h"]),
            unroll=cfg.unroll_layers)
        new_states["tail_conv"] = tc
        new_states["tail_h"] = th
    return x, new_states, kvs


def forward(cfg, params, tokens, img_embeds=None):
    x = params["embed"]["tok"][tokens]
    states = _zero_states(cfg, tokens.shape[0], _dtype(cfg))
    x, _, _ = _run_layers(cfg, params, x, states, jnp.arange(x.shape[1]), False)
    x = apply_norm(cfg, params["ln_f"], x)
    return logits_from_hidden(params["embed"], x, cfg.vocab_size), {"moe_aux": jnp.zeros((), F32)}


def loss_fn(cfg, params, batch):
    tokens = batch["tokens"]
    logits, _ = forward(cfg, params, tokens)
    ce = cross_entropy(logits[:, :-1], tokens[:, 1:], cfg.vocab_size)
    return ce, {"ce": ce}


def init_cache(cfg, batch: int, max_seq: int, dtype=None):
    """Recurrent states + one-window ring KV per attention layer + slot
    position table (shared across layers)."""
    dtype = dtype or _dtype(cfg)
    g = cfg.griffin
    n_super, _ = _counts(cfg)
    W = min(g.window, max_seq)
    cache = _zero_states(cfg, batch, dtype)
    cache["k"] = jnp.zeros((n_super, batch, cfg.n_kv_heads, W, cfg.hd), dtype)
    cache["v"] = jnp.zeros((n_super, batch, cfg.n_kv_heads, W, cfg.hd), dtype)
    cache["slot_pos"] = jnp.full((W,), -1, jnp.int32)
    cache["pos"] = jnp.zeros((), jnp.int32)
    return cache


def prefill(cfg, params, tokens, cache, img_embeds=None):
    x = params["embed"]["tok"][tokens]
    S = x.shape[1]
    positions = jnp.arange(S)
    x, new_states, kvs = _run_layers(cfg, params, x, cache, positions, True)
    k_full, v_full = kvs                     # (n_super, B, KV, S, hd)
    W = cache["k"].shape[3]
    # last W positions into the ring buffer, slot = pos % W
    take = min(W, S)
    last_pos = positions[-take:]
    slots = last_pos % W
    cache = dict(cache)
    cache.update(new_states)
    cache["k"] = cache["k"].at[:, :, :, slots].set(k_full[:, :, :, -take:])
    cache["v"] = cache["v"].at[:, :, :, slots].set(v_full[:, :, :, -take:])
    cache["slot_pos"] = cache["slot_pos"].at[slots].set(last_pos)
    cache["pos"] = jnp.asarray(S, jnp.int32)
    x = apply_norm(cfg, params["ln_f"], x[:, -1:])
    return cache, logits_from_hidden(params["embed"], x, cfg.vocab_size)


def _attn_decode(cfg, p, x_t, k_ring, v_ring, slot_pos, pos):
    """Ring-buffer windowed MQA decode.  x_t: (B,1,D)."""
    B = x_t.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    Wr = k_ring.shape[2]      # (B,KV,W,hd)
    u = apply_norm(cfg, p["ln"], x_t)
    q = (u @ p["wq"]).reshape(B, 1, H, hd).transpose(0, 2, 1, 3)
    k = (u @ p["wk"]).reshape(B, 1, KV, hd).transpose(0, 2, 1, 3)
    v = (u @ p["wv"]).reshape(B, 1, KV, hd).transpose(0, 2, 1, 3)
    q = apply_rope(q, pos[None], cfg.rope_theta)
    k = apply_rope(k, pos[None], cfg.rope_theta)
    slot = pos % Wr
    k_ring = lax.dynamic_update_slice_in_dim(k_ring, k.astype(k_ring.dtype), slot, axis=2)
    v_ring = lax.dynamic_update_slice_in_dim(v_ring, v.astype(v_ring.dtype), slot, axis=2)
    slot_pos = lax.dynamic_update_slice_in_dim(slot_pos, pos[None], slot, axis=0)
    # positions define validity (window + written)
    valid = (slot_pos >= 0) & (slot_pos > pos - Wr) & (slot_pos <= pos)
    qg = q.reshape(B, KV, H // KV, hd)
    s = jnp.einsum("bkgh,bksh->bkgs", qg.astype(F32), k_ring.astype(F32)) * hd ** -0.5
    s = jnp.where(valid[None, None, None], s, NEG)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bksh->bkgh", w, v_ring.astype(F32))
    out = out.reshape(B, 1, H * hd).astype(x_t.dtype) @ p["wo"]
    x_t = x_t + out
    x_t = x_t + apply_mlp(p["mlp"], apply_norm(cfg, p["mlp_ln"], x_t), cfg.mlp)
    return x_t, k_ring, v_ring, slot_pos


def decode_step(cfg, params, cache, tokens_1):
    x = params["embed"]["tok"][tokens_1]
    pos = cache["pos"]
    n_super, n_tail = _counts(cfg)
    slot_pos = cache["slot_pos"]

    # single-token path reuses the segment machinery for rec blocks (S = 1)
    def super_body(carry, inputs):
        h, sp_state = carry
        sp, conv, hs, k_ring, v_ring = inputs

        def rec_body(hh, rin):
            rp, st_conv, st_h = rin
            hh, new_st = _rec_block(cfg, rp, hh, {"conv": st_conv, "h": st_h})
            return hh, (new_st["conv"], new_st["h"])

        h, (new_conv, new_h) = scan_layers(rec_body, h, (sp["rec"], conv, hs),
                                           unroll=cfg.unroll_layers)
        h, k_ring, v_ring, new_slot = _attn_decode(
            cfg, sp["attn"], h, k_ring, v_ring, sp_state, pos)
        return (h, new_slot), (new_conv, new_h, k_ring, v_ring)

    (x, slot_pos), (conv, hs, kr, vr) = scan_layers(
        super_body, (x, slot_pos),
        (params["supers"], cache["conv"], cache["h"], cache["k"], cache["v"]),
        unroll=cfg.unroll_layers)
    new_cache = dict(cache)
    new_cache.update({"conv": conv, "h": hs, "k": kr, "v": vr,
                      "slot_pos": slot_pos, "pos": pos + 1})
    if n_tail:
        def tail_body(hh, rin):
            rp, st_conv, st_h = rin
            hh, new_st = _rec_block(cfg, rp, hh, {"conv": st_conv, "h": st_h})
            return hh, (new_st["conv"], new_st["h"])
        x, (tc, th) = scan_layers(
            tail_body, x, (params["tail"], cache["tail_conv"], cache["tail_h"]),
            unroll=cfg.unroll_layers)
        new_cache["tail_conv"] = tc
        new_cache["tail_h"] = th
    x = apply_norm(cfg, params["ln_f"], x)
    return new_cache, logits_from_hidden(params["embed"], x, cfg.vocab_size)


def param_count(cfg) -> int:
    g = cfg.griffin
    D, W, F, hd = cfg.d_model, g.lru_width, cfg.d_ff, cfg.hd
    n_super, n_tail = _counts(cfg)
    n_rec = n_super * (len(g.pattern) - 1) + n_tail
    mlp = (3 if cfg.mlp in ("swiglu", "geglu") else 2) * D * F
    rec = 2 * D * W + g.conv_width * W + 2 * W * W + W * D + mlp
    attn = D * cfg.n_heads * hd + 2 * D * cfg.n_kv_heads * hd + cfg.n_heads * hd * D + mlp
    return cfg.padded_vocab * D + n_rec * rec + n_super * attn


def active_param_count(cfg) -> int:
    return param_count(cfg)
