"""Shared model building blocks: norms, RoPE, MLPs, embeddings.

Conventions:
* params are nested dicts of jnp arrays; a parallel tree of PartitionSpecs is
  produced by each model's ``param_specs``.
* compute happens in f32 (norms, softmax, rotary) with bf16 storage, matching
  the paper's storage-low/compute-high mixed-precision discipline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def _split(key, n):
    return jax.random.split(key, n)


def scan_layers(body, carry, xs_tree, *, unroll: bool = False,
                remat: bool = False, remat_policy: str = "full"):
    """lax.scan over stacked layer params, or a python loop when ``unroll``
    (used by the dry-run cost shadows: XLA cost_analysis counts while-loop
    bodies once, unrolled modules are counted correctly — and unroll-vs-scan
    is itself a lowering trade-off knob)."""
    if remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if remat_policy == "dots" else None)
        body = jax.checkpoint(body, policy=policy)
    if not unroll:
        return jax.lax.scan(body, carry, xs_tree)
    L = jax.tree.leaves(xs_tree)[0].shape[0]
    ys = []
    for i in range(L):
        x_i = jax.tree.map(lambda a: a[i], xs_tree)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys_st = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys_st = None
    return carry, ys_st


def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, F32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, F32)).astype(dtype)


# ---------------- norms ----------------

def init_norm(cfg, dtype):
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def apply_norm(cfg, p, x, eps: float = 1e-6):
    xf = x.astype(F32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * p["scale"].astype(F32) + p["bias"].astype(F32)
    else:  # rmsnorm
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(F32)
    return out.astype(x.dtype)


def rms_norm_simple(x, scale, eps: float = 1e-6):
    xf = x.astype(F32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(F32)).astype(x.dtype)


# ---------------- RoPE ----------------

def rope_freqs(dim: int, theta: float):
    return theta ** (-jnp.arange(0, dim, 2, dtype=F32) / dim)


def apply_rope(x, positions, theta: float, fraction: float = 1.0):
    """x: (..., S, hd); positions: (S,) or broadcastable.  Rotates the first
    ``fraction`` of the head dim (partial rotary, stablelm-style)."""
    hd = x.shape[-1]
    rot = int(hd * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    freqs = rope_freqs(rot, theta)                      # (rot/2,)
    ang = positions.astype(F32)[..., None] * freqs       # (..., S, rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = xr[..., 0::2].astype(F32), xr[..., 1::2].astype(F32)
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if rot < hd else out


# ---------------- MLPs ----------------

def init_mlp(key, d_model: int, d_ff: int, kind: str, dtype):
    ks = _split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], (d_model, d_ff), dtype),
            "w_up": dense_init(ks[1], (d_model, d_ff), dtype),
            "w_down": dense_init(ks[2], (d_ff, d_model), dtype),
        }
    return {  # plain gelu (whisper)
        "w_up": dense_init(ks[0], (d_model, d_ff), dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), dtype),
        "b_down": jnp.zeros((d_model,), dtype),
    }


def apply_mlp(p, x, kind: str):
    if kind in ("swiglu", "geglu"):
        g = x @ p["w_gate"]
        act = jax.nn.silu(g.astype(F32)) if kind == "swiglu" else jax.nn.gelu(g.astype(F32))
        h = act.astype(x.dtype) * (x @ p["w_up"])
        return h @ p["w_down"]
    h = jax.nn.gelu((x @ p["w_up"] + p["b_up"]).astype(F32)).astype(x.dtype)
    return h @ p["w_down"] + p["b_down"]


def mlp_specs(kind: str, P, tp, fsdp):
    if kind in ("swiglu", "geglu"):
        return {"w_gate": P(fsdp, tp), "w_up": P(fsdp, tp), "w_down": P(tp, fsdp)}
    return {"w_up": P(fsdp, tp), "b_up": P(tp), "w_down": P(tp, fsdp), "b_down": P(None)}


# ---------------- embeddings / logits ----------------

def init_embed(key, cfg, dtype):
    return {"tok": embed_init(key, (cfg.padded_vocab, cfg.d_model), dtype)}


def embed_tokens(p, tokens, d_model: int):
    return p["tok"][tokens] * (d_model ** -0.5)


def logits_from_hidden(p_embed, x, vocab_size: int, w_unembed=None):
    w = p_embed["tok"] if w_unembed is None else w_unembed
    logits = x @ w.T if w_unembed is None else x @ w
    return logits  # padded vocab; mask in the loss


def cross_entropy(logits, labels, vocab_size: int):
    """Mean CE over tokens; padded vocab columns masked out."""
    V = logits.shape[-1]
    logits = logits.astype(F32)
    if V > vocab_size:
        neg = jnp.full((V - vocab_size,), -1e30, F32)
        logits = logits.at[..., vocab_size:].add(neg)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)
