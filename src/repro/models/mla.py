"""Multi-head Latent Attention (DeepSeek-V2) — compressed KV cache.

Train/prefill: decompress the latent per kv-chunk and run standard MHA
(chunked).  Decode: the *absorbed* formulation — W_uk folds into the query
and W_uv into the output so attention runs entirely in the latent space; the
cache holds only (kv_lora_rank + qk_rope_dim) per token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import chunked_attention
from .layers import apply_rope, dense_init, rms_norm_simple

F32 = jnp.float32
NEG = -1e30


def init_mla(key, cfg, dtype):
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    qd = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 6)
    p = {
        "w_q": dense_init(ks[0], (D, H * qd), dtype),
        "w_dkv": dense_init(ks[1], (D, m.kv_lora_rank + m.qk_rope_dim), dtype),
        "ckv_scale": jnp.ones((m.kv_lora_rank,), dtype),
        "w_uk": dense_init(ks[2], (m.kv_lora_rank, H, m.qk_nope_dim), dtype),
        "w_uv": dense_init(ks[3], (m.kv_lora_rank, H, m.v_head_dim), dtype),
        "w_o": dense_init(ks[4], (H * m.v_head_dim, D), dtype),
    }
    return p


def mla_specs(cfg, P, tp, fsdp):
    return {
        "w_q": P(fsdp, tp),
        "w_dkv": P(fsdp, None),
        "ckv_scale": P(None),
        "w_uk": P(None, tp, None),
        "w_uv": P(None, tp, None),
        "w_o": P(tp, fsdp),
    }


def _project_latent(cfg, p, x, positions):
    """x: (B,S,D) -> (c, k_rope): c (B,S,R) normalized latent,
    k_rope (B,S,rope) position-encoded shared key."""
    m = cfg.mla
    ckv = x @ p["w_dkv"]                                   # (B,S,R+rope)
    c = rms_norm_simple(ckv[..., : m.kv_lora_rank], p["ckv_scale"])
    k_pe = ckv[..., m.kv_lora_rank:]
    k_pe = apply_rope(k_pe, positions, cfg.rope_theta)
    return c, k_pe


def _queries(cfg, p, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q = (x @ p["w_q"]).reshape(B, S, H, m.qk_nope_dim + m.qk_rope_dim)
    q_nope = q[..., : m.qk_nope_dim]
    # layout (B,S,H,rope): S is not second-to-last; give positions an H axis
    q_pe = apply_rope(q[..., m.qk_nope_dim:], positions[:, None], cfg.rope_theta)
    return q_nope, q_pe


def mla_forward(cfg, p, x, positions):
    """Full-sequence MLA (train / prefill compute).  Returns (out, (c, k_pe))
    so prefill can store the compressed cache."""
    m = cfg.mla
    B, S, D = x.shape
    H = cfg.n_heads
    c, k_pe = _project_latent(cfg, p, x, positions)
    q_nope, q_pe = _queries(cfg, p, x, positions)
    # Decompress keys/values (sharded over H under TP).
    k_nope = jnp.einsum("bsr,rhn->bshn", c, p["w_uk"].astype(c.dtype))
    v = jnp.einsum("bsr,rhv->bshv", c, p["w_uv"].astype(c.dtype))
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None], (B, S, H, m.qk_rope_dim))], -1
    )
    q = jnp.concatenate([q_nope, q_pe], -1)
    # MHA layout: (B, H, S, hd); KV == H (no GQA after decompression).
    out = chunked_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True,
        q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
    )  # (B,H,S,v?) — note: v_head_dim == qk dims handled by attention shapes
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * m.v_head_dim)
    return out @ p["w_o"], (c, k_pe)


def mla_decode(cfg, p, x_t, cache_c, cache_pe, pos):
    """Absorbed decode step.  x_t: (B,1,D); cache_c: (B,Smax,R);
    cache_pe: (B,Smax,rope); pos: int32 scalar (index of the new token).
    Returns (out (B,1,D), new_c (B,1,R), new_pe (B,1,rope))."""
    m = cfg.mla
    B = x_t.shape[0]
    H = cfg.n_heads
    positions = pos[None] if pos.ndim == 0 else pos
    c_t, pe_t = _project_latent(cfg, p, x_t, positions)
    q_nope, q_pe = _queries(cfg, p, x_t, positions)        # (B,1,H,*)
    cache_c = jax.lax.dynamic_update_slice_in_dim(cache_c, c_t.astype(cache_c.dtype), pos, axis=1)
    cache_pe = jax.lax.dynamic_update_slice_in_dim(cache_pe, pe_t.astype(cache_pe.dtype), pos, axis=1)

    # absorb W_uk into q: (B,1,H,nope) x (R,H,nope) -> (B,H,R)
    q_lat = jnp.einsum("bqhn,rhn->bhr", q_nope.astype(F32), p["w_uk"].astype(F32))
    s_lat = jnp.einsum("bhr,bsr->bhs", q_lat, cache_c.astype(F32))
    s_pe = jnp.einsum("bqhp,bsp->bhs", q_pe.astype(F32), cache_pe.astype(F32))
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    s = (s_lat + s_pe) * scale
    valid = jnp.arange(cache_c.shape[1])[None, :] <= pos
    s = jnp.where(valid[:, None], s, NEG)
    w = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", w, cache_c.astype(F32))          # latent ctx
    out_h = jnp.einsum("bhr,rhv->bhv", ctx, p["w_uv"].astype(F32))    # absorb W_uv
    out = out_h.reshape(B, 1, H * m.v_head_dim).astype(x_t.dtype) @ p["w_o"]
    return out, cache_c, cache_pe
