"""Mixture-of-Experts with capacity-slot dispatch (EP over the TP axis).

Design (see DESIGN.md §4): under tensor parallelism the token activations are
replicated across the `model` axis, so experts sharded over `model` (EP) need
NO all-to-all — each shard gathers the tokens routed to its local experts and
the per-token combine ends in the same single psum a row-parallel dense MLP
needs.  Dispatch is sort-based (argsort + capacity slots), never
materializing the (T, E, C) one-hot of GShard — at 384 experts that tensor is
intractable.  Token groups of ``group_tokens`` bound the (E, C, d) gather.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .layers import dense_init

F32 = jnp.float32


def init_moe(key, cfg, dtype):
    m = cfg.moe
    D, E, F = cfg.d_model, m.n_experts, m.d_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E), F32),  # router kept in f32
        "w_gate": dense_init(ks[1], (E, D, F), dtype),
        "w_up": dense_init(ks[2], (E, D, F), dtype),
        "w_down": dense_init(ks[3], (E, F, D), dtype),
    }
    if m.n_shared:
        from .layers import init_mlp
        p["shared"] = init_mlp(ks[4], D, F * m.n_shared, "swiglu", dtype)
    return p


def moe_specs(cfg, P, tp, fsdp):
    m = cfg.moe
    specs = {
        "router": P(fsdp, None),
        "w_gate": P(tp, fsdp, None),
        "w_up": P(tp, fsdp, None),
        "w_down": P(tp, None, fsdp),
    }
    if m.n_shared:
        from .layers import mlp_specs
        specs["shared"] = mlp_specs("swiglu", P, tp, fsdp)
    return specs


def _capacity(g: int, k: int, E: int, factor: float) -> int:
    c = int(g * k / E * factor) + 1
    return max(8, -(-c // 8) * 8)


def _dispatch_group(xg, idx, w, E: int, C: int):
    """xg: (g, D); idx/w: (g, K) routing.  Returns (xe, tbl, wtbl):
    xe (E, C, D) gathered tokens, tbl (E, C) token ids (g = padding row),
    wtbl (E, C) combine weights."""
    g, K = idx.shape
    flat_e = idx.reshape(-1)
    order = jnp.argsort(flat_e)                     # stable
    se = flat_e[order]
    # rank within each expert's run of sorted entries
    pos = jnp.arange(g * K) - jnp.searchsorted(se, se, side="left")
    tok = order // K
    wflat = w.reshape(-1)[order]
    tbl = jnp.full((E, C), g, jnp.int32)
    wtbl = jnp.zeros((E, C), F32)
    # capacity overflow (pos >= C) handled by scatter mode="drop"
    tbl = tbl.at[se, pos].set(tok.astype(jnp.int32), mode="drop")
    wtbl = wtbl.at[se, pos].set(wflat, mode="drop")
    xg_pad = jnp.concatenate([xg, jnp.zeros((1, xg.shape[1]), xg.dtype)], 0)
    xe = xg_pad[tbl]                                # (E, C, D)
    return xe, tbl, wtbl


def _expert_ffn(p, xe):
    """xe: (E, C, D) -> (E, C, D), batched SwiGLU over the expert dim."""
    gate = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    h = jax.nn.silu(gate.astype(F32)).astype(xe.dtype) * up
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def apply_moe(cfg, p, x):
    """x: (T, D) -> (T, D), plus the load-balance aux loss."""
    m = cfg.moe
    T, D = x.shape
    E, K = m.n_experts, m.top_k
    logits = (x.astype(F32) @ p["router"]).astype(F32)       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = lax.top_k(probs, K)                             # (T, K)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)      # renormalize top-k

    # load-balance aux (Switch-style): E * sum_e f_e * p_e
    counts = jnp.zeros((E,), F32).at[idx.reshape(-1)].add(1.0)
    f = counts / (T * K)
    pbar = probs.mean(0)
    aux = E * jnp.sum(f * pbar)

    g = min(m.group_tokens, T)
    n_groups = -(-T // g)
    Tp = n_groups * g
    if Tp != T:
        x_p = jnp.pad(x, ((0, Tp - T), (0, 0)))
        idx_p = jnp.pad(idx, ((0, Tp - T), (0, 0)))
        w_p = jnp.pad(w, ((0, Tp - T), (0, 0)))  # zero weight: no contribution
    else:
        x_p, idx_p, w_p = x, idx, w
    C = _capacity(g, K, E, m.capacity_factor)

    # combine dtype: f32 by default; bf16 when the moe_bf16_combine toggle is
    # on — the cross-shard EP psum then rides the wire at half the bytes
    # (per-token accumulation depth is only top_k, so bf16 is safe)
    from repro.dist.sharding import opt_enabled
    comb_dt = x.dtype if opt_enabled("moe_bf16_combine") else F32

    def per_group(args):
        xg, ig, wg = args
        xe, tbl, wtbl = _dispatch_group(xg, ig, wg, E, C)
        ye = _expert_ffn(p, xe)                              # (E, C, D)
        out = jnp.zeros((g + 1, D), comb_dt)
        out = out.at[tbl].add((ye.astype(F32) * wtbl[..., None]).astype(comb_dt))
        return out[:g]

    xs = (x_p.reshape(n_groups, g, D),
          idx_p.reshape(n_groups, g, K),
          w_p.reshape(n_groups, g, K))
    if n_groups == 1:
        routed = per_group((xs[0][0], xs[1][0], xs[2][0]))
    else:
        routed = lax.map(per_group, xs).reshape(Tp, D)[:T]
    routed = routed.astype(x.dtype)

    if m.n_shared:
        from .layers import apply_mlp
        routed = routed + apply_mlp(p["shared"], x, "swiglu")
    return routed, aux


def moe_ref(cfg, p, x):
    """Dense oracle: run every expert on every token, combine by routing
    weights.  O(T*E) — tests only."""
    m = cfg.moe
    T, D = x.shape
    logits = (x.astype(F32) @ p["router"]).astype(F32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = lax.top_k(probs, m.top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    ye = _expert_ffn(p, jnp.broadcast_to(x[None], (m.n_experts, T, D)))  # (E,T,D)
    full_w = jnp.zeros((T, m.n_experts), F32)
    full_w = full_w.at[jnp.arange(T)[:, None], idx].set(w)
    out = jnp.einsum("te,etd->td", full_w, ye.astype(F32)).astype(x.dtype)
    if m.n_shared:
        from .layers import apply_mlp
        out = out + apply_mlp(p["shared"], x, "swiglu")
    return out
