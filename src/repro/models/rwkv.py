"""RWKV-6 "Finch" — attention-free LM with data-dependent decay (arXiv:2404.05892).

Time-mix: token-shift with data-dependent (LoRA) interpolation across the
five streams (w,k,v,r,g), per-channel data-dependent decay w̄ = exp(-exp(w)),
and the WKV6 state recurrence

    o_t = r_t · (S_{t-1} + (u ⊙ k_t) v_tᵀ);   S_t = diag(w̄_t) S_{t-1} + k_t v_tᵀ

implemented as a lax.scan over time (the state (B,H,hd,hd) is the "KV cache":
O(1) in sequence length, which is why this arch runs the long_500k shape).
Channel-mix: token-shift + squared-ReLU FFN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.sharding import constrain, opt_enabled
from .layers import cross_entropy, dense_init, embed_init, logits_from_hidden, scan_layers

F32 = jnp.float32


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _heads(cfg):
    hd = cfg.rwkv.head_dim
    assert cfg.d_model % hd == 0
    return cfg.d_model // hd, hd


def init(cfg, key):
    dtype = _dtype(cfg)
    D = cfg.d_model
    H, hd = _heads(cfg)
    r = cfg.rwkv
    ks = jax.random.split(key, 4)

    def init_layer(k):
        kk = jax.random.split(k, 12)
        return {
            "ln1": {"scale": jnp.ones((D,), dtype), "bias": jnp.zeros((D,), dtype)},
            "ln2": {"scale": jnp.ones((D,), dtype), "bias": jnp.zeros((D,), dtype)},
            "att": {
                # data-dependent token-shift mixing (5 streams via LoRA)
                "maa_x": jnp.zeros((D,), dtype),
                "maa_base": jnp.zeros((5, D), dtype),
                "maa_w1": dense_init(kk[0], (D, 5 * r.mix_lora), dtype),
                "maa_w2": dense_init(kk[1], (5, r.mix_lora, D), dtype, scale=0.01),
                # decay LoRA
                "decay_base": jnp.full((D,), -6.0, dtype),
                "decay_w1": dense_init(kk[2], (D, r.decay_lora), dtype),
                "decay_w2": dense_init(kk[3], (r.decay_lora, D), dtype, scale=0.01),
                "bonus_u": jnp.zeros((H, hd), dtype),
                "wr": dense_init(kk[4], (D, D), dtype),
                "wk": dense_init(kk[5], (D, D), dtype),
                "wv": dense_init(kk[6], (D, D), dtype),
                "wg": dense_init(kk[7], (D, D), dtype),
                "wo": dense_init(kk[8], (D, D), dtype),
                "ln_x_scale": jnp.ones((D,), dtype),
                "ln_x_bias": jnp.zeros((D,), dtype),
            },
            "ffn": {
                "mu_k": jnp.full((D,), 0.5, dtype),
                "mu_r": jnp.full((D,), 0.5, dtype),
                "wk": dense_init(kk[9], (D, cfg.d_ff), dtype),
                "wv": dense_init(kk[10], (cfg.d_ff, D), dtype),
                "wr": dense_init(kk[11], (D, D), dtype),
            },
        }

    layers = jax.vmap(init_layer)(jax.random.split(ks[1], cfg.n_layers))
    return {
        "embed": {"tok": embed_init(ks[0], (cfg.padded_vocab, D), dtype)},
        "ln0": {"scale": jnp.ones((D,), dtype), "bias": jnp.zeros((D,), dtype)},
        "layers": layers,
        "ln_f": {"scale": jnp.ones((D,), dtype), "bias": jnp.zeros((D,), dtype)},
    }


def _ln(x, p, eps=1e-5):
    xf = x.astype(F32)
    mu = xf.mean(-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * lax.rsqrt(var + eps) * p["scale"].astype(F32)
            + p["bias"].astype(F32)).astype(x.dtype)


def _group_norm_heads(x, scale, bias, H, eps=1e-5):
    """Per-head layernorm of (..., H*hd) features (RWKV's GroupNorm(H))."""
    shp = x.shape
    xh = x.reshape(shp[:-1] + (H, shp[-1] // H)).astype(F32)
    mu = xh.mean(-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    xh = (xh - mu) * lax.rsqrt(var + eps)
    out = xh.reshape(shp) * scale.astype(F32) + bias.astype(F32)
    return out.astype(x.dtype)


def _time_mix_streams(p, x, sx):
    """x, sx: (B, S, D) current and previous tokens.  Returns the five
    mixed streams (w, k, v, r, g), each (B, S, D)."""
    dx = sx - x
    xxx = x + dx * p["maa_x"]
    lora = jnp.tanh(xxx @ p["maa_w1"])                     # (B,S,5*ml)
    B, S, _ = lora.shape
    ml = p["maa_w2"].shape[1]
    lora = lora.reshape(B, S, 5, ml).transpose(2, 0, 1, 3)  # (5,B,S,ml)
    deltas = jnp.einsum("nbsm,nmd->nbsd", lora, p["maa_w2"])
    mixed = [x + dx * (p["maa_base"][i] + deltas[i]) for i in range(5)]
    return mixed  # [xw, xk, xv, xr, xg]


def _decay(p, xw):
    w = p["decay_base"].astype(F32) + jnp.tanh(xw @ p["decay_w1"]).astype(F32) @ p["decay_w2"].astype(F32)
    return jnp.exp(-jnp.exp(w))          # in (0,1), per channel


def _wkv_scan(r, k, v, wbar, u, state, unroll=False):
    """r,k,v,wbar: (B,S,H,hd); u: (H,hd); state: (B,H,hd,hd) carry.
    Returns (out (B,S,H,hd), final_state)."""
    def step(S_, inp):
        r_t, k_t, v_t, w_t = inp           # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]            # (B,H,hd,hd)
        o = jnp.einsum("bhk,bhkv->bhv", r_t,
                       S_ + u[None, :, :, None] * kv)
        S_new = w_t[..., :, None] * S_ + kv
        return S_new, o

    seq = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
           v.transpose(1, 0, 2, 3), wbar.transpose(1, 0, 2, 3))
    state, out = lax.scan(step, state, seq, unroll=r.shape[1] if unroll else 1)
    return out.transpose(1, 0, 2, 3), state


def _time_mix(cfg, p, x, sx_last, state):
    """x: (B,S,D); sx_last: (B,D) last token of the previous segment;
    state: (B,H,hd,hd).  Returns (out, new_sx_last, new_state)."""
    B, S, D = x.shape
    H, hd = _heads(cfg)
    sx = jnp.concatenate([sx_last[:, None], x[:, :-1]], axis=1)
    xw, xk, xv, xr, xg = _time_mix_streams(p, x, sx)
    wbar = _decay(p, xw).reshape(B, S, H, hd)
    r = (xr @ p["wr"]).reshape(B, S, H, hd).astype(F32)
    k = (xk @ p["wk"]).reshape(B, S, H, hd).astype(F32)
    v = (xv @ p["wv"]).reshape(B, S, H, hd).astype(F32)
    g = jax.nn.silu((xg @ p["wg"]).astype(F32))
    out, state = _wkv_scan(r, k, v, wbar, p["bonus_u"].astype(F32), state,
                           unroll=cfg.time_scan_unroll)
    out = out.reshape(B, S, D)
    out = _group_norm_heads(out, p["ln_x_scale"], p["ln_x_bias"], H)
    out = (out.astype(F32) * g).astype(x.dtype) @ p["wo"]
    return out, x[:, -1], state


def _channel_mix(p, x, sx_last):
    sx = jnp.concatenate([sx_last[:, None], x[:, :-1]], axis=1)
    xk = x + (sx - x) * p["mu_k"]
    xr = x + (sx - x) * p["mu_r"]
    h = jnp.square(jax.nn.relu((xk @ p["wk"]).astype(F32))).astype(x.dtype)
    return jax.nn.sigmoid((xr @ p["wr"]).astype(F32)).astype(x.dtype) * (h @ p["wv"]), x[:, -1]


def _segment(cfg, params, x, cache):
    """Run all layers over a segment x (B,S,D), threading recurrent caches.
    cache: {"att_x": (L,B,D), "ffn_x": (L,B,D), "wkv": (L,B,H,hd,hd)}."""
    def body(h, inputs):
        lp, att_x, ffn_x, wkv = inputs
        seq_role = "sp" if opt_enabled("seq_shard_activations") else None
        h = constrain(h, "dp", seq_role, None)
        a, att_x, wkv = _time_mix(cfg, lp["att"], _ln(h, lp["ln1"]), att_x, wkv)
        h = h + a
        f, ffn_x = _channel_mix(lp["ffn"], _ln(h, lp["ln2"]), ffn_x)
        return h + f, (att_x, ffn_x, wkv)

    x, (att_x, ffn_x, wkv) = scan_layers(
        body, x, (params["layers"], cache["att_x"], cache["ffn_x"], cache["wkv"]),
        unroll=cfg.unroll_layers, remat=cfg.remat,
        remat_policy=cfg.remat_policy)
    return x, {"att_x": att_x, "ffn_x": ffn_x, "wkv": wkv, "pos": cache["pos"] + x.shape[1]}


def _zero_cache(cfg, batch, dtype):
    H, hd = _heads(cfg)
    L, D = cfg.n_layers, cfg.d_model
    return {
        "att_x": jnp.zeros((L, batch, D), dtype),
        "ffn_x": jnp.zeros((L, batch, D), dtype),
        "wkv": jnp.zeros((L, batch, H, hd, hd), F32),
        "pos": jnp.zeros((), jnp.int32),
    }


def forward(cfg, params, tokens, img_embeds=None):
    x = _ln(params["embed"]["tok"][tokens], params["ln0"])
    cache = _zero_cache(cfg, tokens.shape[0], _dtype(cfg))
    x, _ = _segment(cfg, params, x, cache)
    x = _ln(x, params["ln_f"])
    return logits_from_hidden(params["embed"], x, cfg.vocab_size), {"moe_aux": jnp.zeros((), F32)}


def loss_fn(cfg, params, batch):
    tokens = batch["tokens"]
    logits, _ = forward(cfg, params, tokens)
    ce = cross_entropy(logits[:, :-1], tokens[:, 1:], cfg.vocab_size)
    return ce, {"ce": ce}


def init_cache(cfg, batch: int, max_seq: int, dtype=None):
    # state-space cache: O(1) in max_seq (that's the point of this family)
    return _zero_cache(cfg, batch, dtype or _dtype(cfg))


def prefill(cfg, params, tokens, cache, img_embeds=None):
    x = _ln(params["embed"]["tok"][tokens], params["ln0"])
    x, cache = _segment(cfg, params, x, cache)
    x = _ln(x[:, -1:], params["ln_f"])
    return cache, logits_from_hidden(params["embed"], x, cfg.vocab_size)


def decode_step(cfg, params, cache, tokens_1):
    cache, logits = prefill(cfg, params, tokens_1, cache)
    return cache, logits


def param_count(cfg) -> int:
    D, L, F = cfg.d_model, cfg.n_layers, cfg.d_ff
    r = cfg.rwkv
    att = 5 * D * D + D * 5 * r.mix_lora + 5 * r.mix_lora * D + D * r.decay_lora + r.decay_lora * D
    ffn = D * F + F * D + D * D
    return cfg.padded_vocab * D + L * (att + ffn)


def active_param_count(cfg) -> int:
    return param_count(cfg)
