"""Decoder-only transformer LM covering the dense / GQA / MoE / MLA / VLM
families (kimi-k2, deepseek-v2-lite, stablelm, qwen2, llama3, granite,
internvl2).  Layers run under lax.scan with stacked per-layer params
(small HLO, fast 512-device compiles) and optional remat.

Caches: GQA -> (L, B, KV, S, hd) k/v; MLA -> (L, B, S, R) + (L, B, S, rope).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.sharding import constrain, opt_enabled
from . import mla as mla_mod
from . import moe as moe_mod
from .attention import chunked_attention, decode_attention
from .layers import (
    apply_mlp, apply_norm, apply_rope, cross_entropy, dense_init, embed_init,
    init_mlp, init_norm, logits_from_hidden, scan_layers,
)

F32 = jnp.float32


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# -------------------- init --------------------

def _init_attn(key, cfg, dtype):
    if cfg.mla is not None:
        return {"mla": mla_mod.init_mla(key, cfg, dtype)}
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H * hd), dtype),
        "wk": dense_init(ks[1], (D, KV * hd), dtype),
        "wv": dense_init(ks[2], (D, KV * hd), dtype),
        "wo": dense_init(ks[3], (H * hd, D), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def _init_ffn(key, cfg, dtype):
    if cfg.moe is not None:
        return moe_mod.init_moe(key, cfg, dtype)
    return init_mlp(key, cfg.d_model, cfg.d_ff, cfg.mlp, dtype)


def _init_layer(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": init_norm(cfg, dtype),
        "ln2": init_norm(cfg, dtype),
        "attn": _init_attn(ks[0], cfg, dtype),
        "ffn": _init_ffn(ks[1], cfg, dtype),
    }


def init(cfg, key):
    dtype = _dtype(cfg)
    k_embed, k_layers, k_extra = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(k, cfg, dtype))(layer_keys)
    params = {
        "embed": {"tok": embed_init(k_embed, (cfg.padded_vocab, cfg.d_model), dtype)},
        "layers": layers,
        "ln_f": init_norm(cfg, dtype),
    }
    if cfg.vlm is not None:
        img_d = cfg.vlm.img_embed_dim or cfg.d_model
        params["img_proj"] = dense_init(k_extra, (img_d, cfg.d_model), dtype)
    return params


# -------------------- forward --------------------

def _attn_full(cfg, lp, x, positions):
    """Full-sequence attention (train/prefill). Returns (out, kv_for_cache)."""
    if cfg.mla is not None:
        return mla_mod.mla_forward(cfg, lp["mla"], x, positions)
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ lp["wq"]
    k = x @ lp["wk"]
    v = x @ lp["wv"]
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = k.reshape(B, S, KV, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, KV, hd).transpose(0, 2, 1, 3)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    out = chunked_attention(q, k, v, causal=True, window=cfg.window,
                            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    return out @ lp["wo"], (k, v)


def _ffn(cfg, lp, x):
    """Returns (out, aux)."""
    if cfg.moe is not None:
        B, S, D = x.shape
        out, aux = moe_mod.apply_moe(cfg, lp, x.reshape(B * S, D))
        return out.reshape(B, S, D), aux
    return apply_mlp(lp, x, cfg.mlp), jnp.zeros((), F32)


def _block(cfg, lp, x, positions):
    # SP: seq-shard the residual stream between blocks when enabled
    seq_role = "sp" if opt_enabled("seq_shard_activations") else None
    x = constrain(x, "dp", seq_role, None)
    a, kv = _attn_full(cfg, lp["attn"], apply_norm(cfg, lp["ln1"], x), positions)
    x = x + a
    f, aux = _ffn(cfg, lp["ffn"], apply_norm(cfg, lp["ln2"], x))
    return x + f, aux, kv


def _embed_inputs(cfg, params, tokens, img_embeds=None):
    x = params["embed"]["tok"][tokens]
    if cfg.vlm is not None and img_embeds is not None:
        img = img_embeds.astype(x.dtype) @ params["img_proj"]
        x = jnp.concatenate([img, x], axis=1)
    return x


def forward(cfg, params, tokens, img_embeds=None):
    """tokens: (B, S) -> logits (B, S_total, Vpad), aux dict."""
    x = _embed_inputs(cfg, params, tokens, img_embeds)
    S = x.shape[1]
    positions = jnp.arange(S)

    def body(carry, lp):
        h, aux = carry
        h2, a, _ = _block(cfg, lp, h, positions)
        return (h2, aux + a), None

    (x, aux), _ = scan_layers(body, (x, jnp.zeros((), F32)), params["layers"],
                              unroll=cfg.unroll_layers, remat=cfg.remat,
                              remat_policy=cfg.remat_policy)
    x = apply_norm(cfg, params["ln_f"], x)
    logits = logits_from_hidden(params["embed"], x, cfg.vocab_size)
    logits = constrain(logits, "dp", None, "tp")
    return logits, {"moe_aux": aux / max(1, cfg.n_layers)}


def loss_fn(cfg, params, batch):
    """batch: {"tokens": (B,S) int32, ["img_embeds"]}.  Next-token CE over
    the text positions."""
    tokens = batch["tokens"]
    logits, aux = forward(cfg, params, tokens, batch.get("img_embeds"))
    if cfg.vlm is not None and "img_embeds" in batch:
        n_img = batch["img_embeds"].shape[1]
        logits = logits[:, n_img:]
    ce = cross_entropy(logits[:, :-1], tokens[:, 1:], cfg.vocab_size)
    aux_w = cfg.moe.router_aux_weight if cfg.moe is not None else 0.0
    return ce + aux_w * aux["moe_aux"], {"ce": ce, **aux}


# -------------------- caches / decode --------------------

def init_cache(cfg, batch: int, max_seq: int, dtype=None):
    dtype = dtype or _dtype(cfg)
    L = cfg.n_layers
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "c": jnp.zeros((L, batch, max_seq, m.kv_lora_rank), dtype),
            "pe": jnp.zeros((L, batch, max_seq, m.qk_rope_dim), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros((L, batch, cfg.n_kv_heads, max_seq, cfg.hd), dtype),
        "v": jnp.zeros((L, batch, cfg.n_kv_heads, max_seq, cfg.hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(cfg, params, tokens, cache, img_embeds=None):
    """Run the full prompt, write the cache, return (cache, last_logits)."""
    x = _embed_inputs(cfg, params, tokens, img_embeds)
    S = x.shape[1]
    positions = jnp.arange(S)

    def body(carry, lp):
        h = carry
        h2, _, kv = _block(cfg, lp, h, positions)
        # cache layout: sequence-shard the KV timeline over the model axis (SP)
        if cfg.mla is not None:
            kv = (constrain(kv[0], "dp", "sp", None),
                  constrain(kv[1], "dp", "sp", None))
        else:
            kv = (constrain(kv[0], "dp", None, "sp", None),
                  constrain(kv[1], "dp", None, "sp", None))
        return h2, kv

    x, kvs = scan_layers(body, x, params["layers"], unroll=cfg.unroll_layers)
    x = apply_norm(cfg, params["ln_f"], x)
    logits = logits_from_hidden(params["embed"], x[:, -1:], cfg.vocab_size)

    if cfg.mla is not None:
        c, pe = kvs  # (L,B,S,R), (L,B,S,rope)
        cache = dict(cache)
        cache["c"] = lax.dynamic_update_slice_in_dim(
            cache["c"], c.astype(cache["c"].dtype), 0, axis=2)
        cache["pe"] = lax.dynamic_update_slice_in_dim(
            cache["pe"], pe.astype(cache["pe"].dtype), 0, axis=2)
    else:
        k, v = kvs  # (L,B,KV,S,hd)
        cache = dict(cache)
        cache["k"] = lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), 0, axis=3)
        cache["v"] = lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), 0, axis=3)
    cache["pos"] = jnp.asarray(S, jnp.int32)
    return cache, logits


def _attn_decode(cfg, lp, x_t, layer_cache, pos):
    if cfg.mla is not None:
        out, c_new, pe_new = mla_mod.mla_decode(
            cfg, lp["mla"], x_t, layer_cache["c"], layer_cache["pe"], pos)
        return out, {"c": c_new, "pe": pe_new}
    B = x_t.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x_t @ lp["wq"]
    k = x_t @ lp["wk"]
    v = x_t @ lp["wv"]
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    positions = pos[None]
    q = apply_rope(q.reshape(B, 1, H, hd).transpose(0, 2, 1, 3),
                   positions, cfg.rope_theta, cfg.rope_fraction)
    k = apply_rope(k.reshape(B, 1, KV, hd).transpose(0, 2, 1, 3),
                   positions, cfg.rope_theta, cfg.rope_fraction)
    v = v.reshape(B, 1, KV, hd).transpose(0, 2, 1, 3)
    kc = lax.dynamic_update_slice_in_dim(
        layer_cache["k"], k.astype(layer_cache["k"].dtype), pos, axis=2)
    vc = lax.dynamic_update_slice_in_dim(
        layer_cache["v"], v.astype(layer_cache["v"].dtype), pos, axis=2)
    out = decode_attention(q, kc, vc, pos + 1, window=cfg.window)
    out = out.reshape(B, H * hd) @ lp["wo"]
    return out[:, None], {"k": kc, "v": vc}


def decode_step(cfg, params, cache, tokens_1):
    """tokens_1: (B, 1).  One token for every sequence in the batch."""
    x = params["embed"]["tok"][tokens_1]          # (B,1,D)
    pos = cache["pos"]

    cache_layers = {k: v for k, v in cache.items() if k != "pos"}

    def body(h, inputs):
        lp, lc = inputs
        a, new_lc = _attn_decode(cfg, lp["attn"], apply_norm(cfg, lp["ln1"], h), lc, pos)
        h = h + a
        f, _ = _ffn(cfg, lp["ffn"], apply_norm(cfg, lp["ln2"], h))
        return h + f, new_lc

    x, new_layers = scan_layers(body, x, (params["layers"], cache_layers),
                                unroll=cfg.unroll_layers)
    x = apply_norm(cfg, params["ln_f"], x)
    logits = logits_from_hidden(params["embed"], x, cfg.vocab_size)
    new_cache = dict(new_layers)
    new_cache["pos"] = pos + 1
    return new_cache, logits


# -------------------- bookkeeping --------------------

def param_count(cfg) -> int:
    D, H, KV, hd, L = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.n_layers
    if cfg.mla is not None:
        m = cfg.mla
        attn = (D * H * (m.qk_nope_dim + m.qk_rope_dim)
                + D * (m.kv_lora_rank + m.qk_rope_dim)
                + m.kv_lora_rank * H * (m.qk_nope_dim + m.v_head_dim)
                + H * m.v_head_dim * D)
    else:
        attn = D * H * hd + 2 * D * KV * hd + H * hd * D
    if cfg.moe is not None:
        mo = cfg.moe
        ffn = D * mo.n_experts + 3 * D * mo.d_expert * mo.n_experts
        ffn += 3 * D * mo.d_expert * mo.n_shared
    else:
        ffn = (3 if cfg.mlp in ("swiglu", "geglu") else 2) * D * cfg.d_ff
    return cfg.padded_vocab * D + L * (attn + ffn)


def active_param_count(cfg) -> int:
    if cfg.moe is None:
        return param_count(cfg)
    D, L = cfg.d_model, cfg.n_layers
    mo = cfg.moe
    dense = param_count(cfg) - L * 3 * D * mo.d_expert * mo.n_experts
    return dense + L * 3 * D * mo.d_expert * mo.top_k
