"""Trace-time execution planner (cost-model dispatch + AOT warm start).

Two halves:

* :mod:`repro.plan.planner` — a frozen, hashable :class:`~repro.plan.planner.Plan`
  chosen per (shape, split, dtype, p) from the closed forms in
  :mod:`repro.core.memory_model` plus the measured calibration table
  (``kernels/calibration.json``).  ``impl="auto"`` on the core entry points
  routes through it; every explicit flag still overrides.
* :mod:`repro.plan.aot` — AOT lower+compile of the (plan, shape-signature)
  entry points, wired to JAX's persistent compilation cache, with hit/miss
  counters surfaced by :func:`~repro.plan.report.plan_report`.

``REPRO_TVC_DISABLE_PLAN=1`` turns auto dispatch into the legacy static
defaults (no calibration consulted); explicit impls are never affected.
"""
from . import aot, calibration, planner, report
from .aot import enable_persistent_cache, warmup
from .planner import (
    AUTO,
    Plan,
    plan_batched,
    plan_compress,
    plan_dhopm3,
    plan_for_cell,
    plan_tvc,
    plan_tvc2,
    resolve_dhopm,
    resolve_impl,
)
from .report import plan_report, reset_plan_report

__all__ = [
    "AUTO",
    "Plan",
    "aot",
    "calibration",
    "enable_persistent_cache",
    "plan_batched",
    "plan_compress",
    "plan_dhopm3",
    "plan_for_cell",
    "plan_report",
    "plan_tvc",
    "plan_tvc2",
    "planner",
    "report",
    "reset_plan_report",
    "resolve_dhopm",
    "resolve_impl",
    "warmup",
]
