"""Cold-start elimination: AOT lower+compile behind JAX's persistent
compilation cache.

:func:`enable_persistent_cache` points JAX's compilation cache at a
durable directory (``REPRO_TVC_COMPILE_CACHE`` or
``~/.cache/repro_tvc/xla``) with thresholds dropped to "cache everything",
so a process that compiles an entry point once leaves a deserializable
executable behind for every later process (CI persists the directory
across workflow runs).

:func:`warmup` AOT-compiles a callable for one (name, plan,
shape-signature) key ahead of first use: a repeated in-process warmup is a
dictionary hit (no tracing, no compile), a cross-process warmup hits the
persistent cache (deserialize instead of compile — measured ~10x cheaper on
CPU).  Hit/miss counters for both layers feed
:func:`repro.plan.report.plan_report`.

Cache-key caveat baked into the API: JAX's persistent cache key includes
the jitted computation *name*, so warmup helpers must hand ``jax.jit`` the
same-named function across processes — :func:`warmup` requires an explicit
``name`` and re-wraps plain callables under it.
"""
from __future__ import annotations

import os
import pathlib
import time

import jax

from . import report

__all__ = [
    "enable_persistent_cache",
    "persistent_cache_dir",
    "reset",
    "signature",
    "stats",
    "warmup",
]

_ENV_DIR = "REPRO_TVC_COMPILE_CACHE"
_EVENT_HIT = "/jax/compilation_cache/cache_hits"
_EVENT_MISS = "/jax/compilation_cache/cache_misses"

_cache_dir: pathlib.Path | None = None
_listener_on = False
_persistent = {"hits": 0, "misses": 0}
#: (name, plan, signature) -> compiled executable + metadata
_entries: dict = {}


def _on_event(event, *args, **kwargs):
    if event == _EVENT_HIT:
        _persistent["hits"] += 1
        report.note("aot.persistent_hit")
    elif event == _EVENT_MISS:
        _persistent["misses"] += 1
        report.note("aot.persistent_miss")


def _install_listener() -> None:
    global _listener_on
    if _listener_on:
        return
    try:
        from jax._src import monitoring
        monitoring.register_event_listener(_on_event)
        _listener_on = True
    except Exception:  # pragma: no cover - jax internals moved
        pass


def persistent_cache_dir() -> pathlib.Path | None:
    """The directory the persistent cache writes to (None until enabled)."""
    return _cache_dir


def enable_persistent_cache(cache_dir=None) -> pathlib.Path:
    """Turn on JAX's persistent compilation cache (idempotent).

    Resolution order: explicit ``cache_dir`` > ``REPRO_TVC_COMPILE_CACHE``
    > ``~/.cache/repro_tvc/xla``.  Thresholds are dropped so every
    compile — including the sub-second CPU ones this repo's cells live
    in — is cached."""
    global _cache_dir
    d = pathlib.Path(
        cache_dir
        or os.environ.get(_ENV_DIR)
        or pathlib.Path.home() / ".cache" / "repro_tvc" / "xla")
    d.mkdir(parents=True, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", str(d))
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    if d != _cache_dir:
        # the cache backend binds its directory lazily at the first compile;
        # a process that compiled anything before this call has it pinned to
        # "disabled" (or to the previous dir) until explicitly reset
        try:
            from jax._src import compilation_cache
            compilation_cache.reset_cache()
        except Exception:  # pragma: no cover - jax internals moved
            pass
    _install_listener()
    _cache_dir = d
    return d


def _leaf_sig(x):
    shape = getattr(x, "shape", None)
    if shape is None:
        return repr(x)
    dtype = getattr(x, "dtype", None)
    return (tuple(shape), getattr(dtype, "name", str(dtype)))


def signature(*args) -> tuple:
    """Hashable shape/dtype signature of a pytree of call arguments."""
    leaves, treedef = jax.tree.flatten(args)
    return (tuple(_leaf_sig(leaf) for leaf in leaves), str(treedef))


def warmup(fn, *args, name: str, plan=None, donate_argnums=()) -> dict:
    """AOT lower+compile ``fn`` for ``args``' shape signature.

    ``fn`` may be a plain callable or an existing ``jax.jit`` object (its
    donation/static configuration is kept).  Returns a report dict:
    ``cache`` is ``"in_process"`` when this exact (name, plan, signature)
    was already warmed in this process, else ``"persistent"`` /``"cold"``
    depending on whether the compile deserialized from the persistent
    cache; ``compile_us`` is the lower+compile wall time."""
    key = (name, plan, signature(*args))
    hit = _entries.get(key)
    if hit is not None:
        hit["in_process_hits"] += 1
        report.note("aot.in_process_hit")
        return {"name": name, "cache": "in_process", "compile_us": 0.0,
                "executable": hit["executable"]}
    report.note("aot.in_process_miss")
    _install_listener()
    if hasattr(fn, "lower"):
        jfn = fn
    else:
        jfn = jax.jit(fn, donate_argnums=donate_argnums)
    before = dict(_persistent)
    t0 = time.perf_counter()
    compiled = jfn.lower(*args).compile()
    dt_us = (time.perf_counter() - t0) * 1e6
    persistent_hit = _persistent["hits"] > before["hits"]
    _entries[key] = {
        "name": name,
        "executable": compiled,
        "compile_us": dt_us,
        "in_process_hits": 0,
    }
    return {
        "name": name,
        "cache": "persistent" if persistent_hit else "cold",
        "compile_us": dt_us,
        "executable": compiled,
    }


def stats() -> dict:
    """AOT-layer counters for :func:`repro.plan.report.plan_report`."""
    return {
        "entries": len(_entries),
        "in_process_hits": sum(e["in_process_hits"]
                               for e in _entries.values()),
        "persistent": dict(_persistent),
        "cache_dir": str(_cache_dir) if _cache_dir else None,
    }


def reset() -> None:
    """Drop warmed executables and zero counters (tests)."""
    _entries.clear()
    _persistent["hits"] = 0
    _persistent["misses"] = 0
