"""Measured calibration table behind the planner's cost model.

``kernels/calibration.json`` is a checked-in artifact fitted from the
committed ``BENCH_TVC.json`` trajectory by ``benchmarks/calibrate.py`` —
per-engine launch overhead (µs) and achieved GB/s, split by contraction
class (a *leading*-mode contraction reduces the slowest-varying axes, where
the XLA einsum collapses to a strided GEMV and the broadcast-multiply
``mulsum`` engine streams several times faster; *inner*/tail contractions
are the other way around).  ``check_bench`` derives its time-implied-traffic
ceilings from the same file, so the CI gate and the planner share one
source of truth.

``REPRO_TVC_CALIBRATION`` overrides the table path;
``REPRO_TVC_DISABLE_PLAN=1`` disables auto dispatch entirely (the planner
returns the legacy static defaults without consulting the table).
Missing file or missing fields fall back to conservative constants so the
planner never hard-fails on an uncalibrated host.
"""
from __future__ import annotations

import json
import os
import pathlib

__all__ = [
    "DEFAULT_PATH",
    "cache_bytes",
    "ceilings",
    "disabled",
    "dispatch_us",
    "engine_gbs",
    "engine_launch_us",
    "engines",
    "invalidate",
    "load",
    "peak_gbs",
    "table_path",
    "wire_gbs",
]

DEFAULT_PATH = (pathlib.Path(__file__).resolve().parent.parent
                / "kernels" / "calibration.json")

#: Conservative fallbacks when no table is committed / a field is missing.
#: GB/s figures reflect the committed CPU trajectory's orderings (mulsum
#: streams leading-mode pairs ~4x faster than the einsum; the einsum wins
#: inner/tail modes) and deliberately understate TPU pallas so an
#: uncalibrated accelerator host still dispatches to the compiled kernels.
FALLBACK = {
    "schema": 1,
    "source": None,
    "stream_triad_gbs": 5.0,
    "dispatch_us": 200.0,
    "wire_frac": 1 / 8.0,
    # size (bytes) below which a leading-mode pair is priced with the
    # cache-resident ``gbs_lead_small`` figures; 0 disables the regime
    # split (uncalibrated hosts keep the single-bandwidth model)
    "cache_bytes": 0.0,
    "engines": {
        "native": {"launch_us": 200.0, "gbs": 1.5,
                   "gbs_lead": 0.15, "gbs_inner": 0.45},
        "mulsum": {"launch_us": 200.0, "gbs": 0.9,
                   "gbs_lead": 0.70, "gbs_inner": 0.25},
        "pallas": {"launch_us": 30.0, "gbs": 3.0,
                   "gbs_lead": 3.0, "gbs_inner": 3.0},
    },
    "ceilings": {"ratio_pallas": 2.0, "ratio_native": 32.0,
                 "lowprec_factor": 3.0},
}

_cache: dict | None = None
_cache_key: tuple | None = None


def table_path(path=None) -> pathlib.Path:
    if path is not None:
        return pathlib.Path(path)
    env = os.environ.get("REPRO_TVC_CALIBRATION")
    return pathlib.Path(env) if env else DEFAULT_PATH


def disabled() -> bool:
    """True when auto dispatch is turned off (legacy static defaults)."""
    return bool(os.environ.get("REPRO_TVC_DISABLE_PLAN"))


def invalidate() -> None:
    """Drop the in-process table cache (tests / after refitting)."""
    global _cache, _cache_key
    _cache = None
    _cache_key = None


def load(path=None) -> dict:
    """The calibration table, merged over :data:`FALLBACK` (never raises)."""
    global _cache, _cache_key
    p = table_path(path)
    key = (str(p),)
    if _cache is not None and _cache_key == key:
        return _cache
    table = {k: (dict(v) if isinstance(v, dict) else v)
             for k, v in FALLBACK.items()}
    table["engines"] = {e: dict(prm) for e, prm in FALLBACK["engines"].items()}
    try:
        payload = json.loads(p.read_text())
    except (OSError, ValueError):
        payload = {}
    for k, v in payload.items():
        if k == "engines" and isinstance(v, dict):
            for e, prm in v.items():
                table["engines"].setdefault(e, {}).update(prm or {})
        elif k == "ceilings" and isinstance(v, dict):
            table["ceilings"].update(v)
        else:
            table[k] = v
    _cache, _cache_key = table, key
    return table


def peak_gbs(path=None) -> float:
    return float(load(path)["stream_triad_gbs"])


def dispatch_us(path=None) -> float:
    return float(load(path)["dispatch_us"])


def wire_gbs(path=None) -> float:
    """Reference interconnect bandwidth for the overlap time model."""
    t = load(path)
    return float(t["stream_triad_gbs"]) * float(t["wire_frac"])


def engines(path=None) -> dict:
    return load(path)["engines"]


def _engine(engine: str, path=None) -> dict:
    table = engines(path)
    return table.get(engine) or FALLBACK["engines"]["native"]


def engine_launch_us(engine: str, path=None) -> float:
    prm = _engine(engine, path)
    return float(prm.get("launch_us", load(path)["dispatch_us"]))


def cache_bytes(path=None) -> float:
    """Fitted cache-residency crossover for leading-mode pairs (bytes);
    0 = no split fitted."""
    return float(load(path).get("cache_bytes", 0.0))


def engine_gbs(engine: str, *, leading: bool | None = None,
               nbytes: float | None = None, path=None) -> float:
    """Achieved GB/s for ``engine``; ``leading`` selects the contraction
    class (None = the pooled single-mode figure).

    Leading-mode bandwidth is *bimodal* on the measured trajectory: the
    XLA einsum holds ~1 GB/s while the operand is cache-resident and
    collapses ~5x once it streams from DRAM, while ``mulsum`` is flat —
    so when ``nbytes`` is given and falls under the fitted
    :func:`cache_bytes` crossover, the cache-resident ``gbs_lead_small``
    figure is used instead of ``gbs_lead``."""
    prm = _engine(engine, path)
    if leading is None:
        return float(prm.get("gbs", FALLBACK["engines"]["native"]["gbs"]))
    key = "gbs_lead" if leading else "gbs_inner"
    if leading and nbytes is not None:
        cross = cache_bytes(path)
        if 0 < nbytes < cross and "gbs_lead_small" in prm:
            key = "gbs_lead_small"
    return float(prm.get(key, prm.get("gbs", 1.0)))


def ceilings(path=None) -> dict:
    """Time-implied-traffic gate allowances shared with ``check_bench``."""
    return dict(load(path)["ceilings"])
