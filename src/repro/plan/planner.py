"""Cost-model dispatch: a frozen, trace-stable :class:`Plan` per call site.

The planner inverts the repo's flag-driven engine selection: ``impl="auto"``
on :func:`repro.core.tvc.tvc` / ``tvc2`` / the batched variants, the
``hopm3*``/``dhopm3*`` walkers and ``train.grad_compress`` resolves, at
trace time, to a concrete (engine, pair-fusion, bucketing, overlap-chunk,
allreduce-algorithm) choice computed from the closed forms in
:mod:`repro.core.memory_model` priced with the measured calibration table
(:mod:`repro.plan.calibration`).  Explicit flags always override — auto
only ever fills values the caller left unset.

Decision rules (each one measured on the committed trajectory, see
``benchmarks/calibrate.py``):

* **Single-mode TVC** picks among the einsum-family engines by
  ``launch_us + bytes / gbs``.  The ``mulsum`` engine is *excluded* from
  single-mode auto on CPU: its measured behavior is bimodal (3x faster than
  the einsum on some shapes, 30-100x pathological on others with identical
  byte counts), and the planner's contract is "never pathological".
* **Fused pairs (tvc2)** price the two calibrated contraction classes: a
  *leading* pair (``k1 == 0``) reduces the slowest-varying axes, where the
  XLA einsum degrades to a strided pass and ``mulsum`` streams 3-6x faster
  — but only once the operand streams from DRAM: while it is cache-resident
  (under the fitted ``cache_bytes`` crossover) the einsum holds ~1 GB/s and
  wins, so the lead-pair choice flips with tensor size.  *Inner* pairs go
  to the einsum at every size.
* **Chains** (``hopm3*``/``dhopm3*``/``grad_compress``) pin the
  bitwise-batchable engine (``mulsum`` on CPU, ``pallas`` on TPU) — the
  distributed / batched bitwise-reproducibility guarantees hold only there,
  and auto never trades determinism for speed.  Pair fusion turns on when
  :func:`~repro.core.memory_model.dhopm_launches_per_sweep` says it strictly
  reduces launches; overlap chunks minimize the
  :func:`~repro.core.memory_model.dhopm_time_sweep` exposed-wire +
  extra-dispatch total (at p = 1 there is no wire to hide, so auto stays
  synchronous rather than paying the pipeline's extra launches).
* **Batched bucketing** turns on when
  :func:`~repro.core.memory_model.launch_amortized_speedup` > 1.

Plans are hashable frozen dataclasses computed from static (Python-level)
shapes only, so jit tracing/caching is unaffected.
"""
from __future__ import annotations

import dataclasses
import functools

from . import calibration, report

__all__ = [
    "AUTO",
    "Plan",
    "dispatch_dominated",
    "epilogue_fallback",
    "plan_batched",
    "plan_compress",
    "plan_dhopm3",
    "plan_for_cell",
    "plan_tvc",
    "plan_tvc2",
    "resolve_dhopm",
    "resolve_impl",
    "time_implied_ratio",
]

AUTO = "auto"

#: Overlap chunk counts the planner searches (the walker clamps to n_j).
OVERLAP_CANDIDATES = (1, 2, 4, 8)

#: Relative cost band inside which the earlier (more robust) candidate
#: engine wins — keeps choices stable under calibration-fit jitter.
TIEBREAK_BAND = 0.05

#: A cell is "dispatch-dominated" when its time-implied traffic exceeds
#: this multiple of the streamed bytes (the 18-43x cells in the committed
#: trajectory motivating this planner).
DISPATCH_DOMINATED_X = 8.0


@dataclasses.dataclass(frozen=True)
class Plan:
    """Frozen, hashable execution plan for one contraction call site."""
    kind: str                 # "tvc" | "tvc2" | "batched" | "dhopm3" | "compress"
    impl: str                 # concrete engine (never "auto")
    fused: bool = False       # adjacent-mode pair fusion
    overlap_chunks: int = 1   # 1 = synchronous walker
    bucket: bool = True       # batched bucketing (grad_compress / batched)
    algo: str = "none"        # allreduce schedule for the dominant payload
    two_launch: bool = False  # tvc2 epilogue ran as a second launch
    arena: bool = False       # donation-aware batched-operand arena fill
    #                           (compress buckets: scatter into persistent
    #                           [B, ...] buffers instead of jnp.stack)
    reason: str = ""          # why the engine was picked/pinned

    def as_cell_dict(self) -> dict:
        """The bench-schema-6 per-cell plan record (what the gate recomputes)."""
        return {"engine": self.impl, "fused": self.fused,
                "overlap_chunks": self.overlap_chunks, "algo": self.algo}


def _backend(backend: str | None) -> str:
    if backend is not None:
        return backend
    import jax
    return jax.default_backend()


def time_implied_ratio(us: float, streamed_bytes: float,
                       peak_gbs: float) -> float:
    """Measured-time-implied traffic over modeled streamed bytes."""
    if streamed_bytes <= 0:
        return float("inf")
    return us * 1e-6 * peak_gbs * 1e9 / streamed_bytes


def dispatch_dominated(us: float, streamed_bytes: float, peak_gbs: float,
                       factor: float = DISPATCH_DOMINATED_X) -> bool:
    return time_implied_ratio(us, streamed_bytes, peak_gbs) >= factor


def _cost_us(engine: str, nbytes: float, *, leading: bool | None,
             launches: int = 1) -> float:
    gbs = calibration.engine_gbs(engine, leading=leading, nbytes=nbytes)
    return (launches * calibration.engine_launch_us(engine)
            + nbytes / (gbs * 1e9) * 1e6)


def _pick(candidates, nbytes: float, *, leading: bool | None,
          launches=None) -> tuple[str, str]:
    """Cheapest candidate engine; earlier candidates win inside the
    tiebreak band (stable under fit jitter)."""
    launches = launches or {}
    costs = [(_cost_us(e, nbytes, leading=leading,
                       launches=launches.get(e, 1)), e) for e in candidates]
    best = min(c for c, _ in costs)
    for c, e in costs:
        if c <= best * (1.0 + TIEBREAK_BAND):
            return e, f"cost-model({c:.0f}us)"
    return costs[0][1], "cost-model"


def _chain_engine(backend: str) -> tuple[str, str]:
    """Chains pin the bitwise-batchable engine — determinism over speed."""
    if backend == "tpu":
        return "pallas", "bitwise-batchable engine on tpu"
    return "mulsum", "bitwise-batchable engine (cpu)"


def _legacy_impl(kind: str, backend: str) -> str:
    """What auto resolves to with REPRO_TVC_DISABLE_PLAN set (the
    pre-planner static defaults)."""
    if kind in ("dhopm3", "compress", "batched"):
        return "pallas" if backend == "tpu" else "mulsum"
    return "pallas" if backend == "tpu" else "native"


# ---------------------------------------------------------------------------
# plan producers (cached on their static arguments)

@functools.lru_cache(maxsize=4096)
def _plan_tvc(shape, k, itemsize, backend, disabled):
    from repro.core.tvc import tvc_bytes
    if disabled:
        return Plan("tvc", _legacy_impl("tvc", backend),
                    reason="plan-disabled")
    nbytes = tvc_bytes(shape, k, itemsize)
    cands = (("pallas", "native") if backend == "tpu"
             else ("native", "looped", "unfolded"))
    impl, why = _pick(cands, nbytes, leading=None)
    return Plan("tvc", impl, reason=why)


def plan_tvc(shape, k: int, *, itemsize: int = 4,
             backend: str | None = None) -> Plan:
    report.note("plan.tvc")
    return _plan_tvc(tuple(shape), k, itemsize, _backend(backend),
                     calibration.disabled())


@functools.lru_cache(maxsize=4096)
def _plan_tvc2(shape, k1, itemsize, static_ab, backend, disabled):
    from repro.core.tvc import tvc2_bytes
    if disabled:
        return Plan("tvc2", _legacy_impl("tvc2", backend), fused=True,
                    two_launch=(backend == "tpu" and not static_ab),
                    reason="plan-disabled")
    nbytes = tvc2_bytes(shape, k1, k1 + 1, itemsize)
    leading = k1 == 0
    if backend == "tpu":
        cands = ("pallas", "mulsum", "native")
        # a traced alpha/beta forces the pallas epilogue into a second
        # launch — price it so auto can route around the de-optimization
        launches = {"pallas": 1 if static_ab else 2}
    else:
        cands = ("native", "mulsum")
        launches = {}
    impl, why = _pick(cands, nbytes, leading=leading, launches=launches)
    return Plan("tvc2", impl, fused=True,
                two_launch=(impl == "pallas" and not static_ab), reason=why)


def plan_tvc2(shape, k1: int, *, itemsize: int = 4, static_ab: bool = True,
              backend: str | None = None) -> Plan:
    report.note("plan.tvc2")
    return _plan_tvc2(tuple(shape), k1, itemsize, bool(static_ab),
                      _backend(backend), calibration.disabled())


@functools.lru_cache(maxsize=4096)
def _plan_batched(b, shape, k, itemsize, backend, disabled):
    from repro.core.memory_model import launch_amortized_speedup
    from repro.core.tvc import tvc_bytes
    impl, why = _chain_engine(backend)
    if disabled:
        return Plan("batched", _legacy_impl("batched", backend),
                    reason="plan-disabled")
    one = tvc_bytes(shape, k, itemsize)
    bucket = b > 1 and launch_amortized_speedup(
        b, one, calibration.peak_gbs(), calibration.dispatch_us()) > 1.0
    return Plan("batched", impl, bucket=bucket, reason=why)


def plan_batched(b: int, shape, k: int, *, itemsize: int = 4,
                 backend: str | None = None) -> Plan:
    """Plan for B same-shape single-mode contractions (one bucket)."""
    report.note("plan.batched")
    return _plan_batched(b, tuple(shape), k, itemsize, _backend(backend),
                         calibration.disabled())


@functools.lru_cache(maxsize=4096)
def _plan_dhopm3(shape, p, s, batch, itemsize, fuse_pairs, overlap, backend,
                 disabled):
    from repro.core.memory_model import (
        dhopm_launches_per_sweep,
        dhopm_time_sweep,
    )
    from repro.dist.collectives import allreduce_algo
    d = len(shape)
    impl, why = _chain_engine(backend)
    algo = allreduce_algo(max(shape), p)
    if disabled:
        return Plan("dhopm3", _legacy_impl("dhopm3", backend),
                    fused=bool(fuse_pairs),
                    overlap_chunks=(overlap if overlap else 1),
                    algo=algo, reason="plan-disabled")
    if fuse_pairs is None:
        fused = (dhopm_launches_per_sweep(d, s, fuse_pairs=True)
                 < dhopm_launches_per_sweep(d, s, fuse_pairs=False))
    else:
        fused = bool(fuse_pairs)
    if overlap is None:
        best_c, best_t = 1, None
        for c in OVERLAP_CANDIDATES:
            t = dhopm_time_sweep(
                shape, p, itemsize, split=s, overlap_chunks=c,
                peak_gbs=calibration.peak_gbs(),
                wire_gbs=calibration.wire_gbs(),
                dispatch_us=calibration.dispatch_us())
            total = t["exposed_wire_us"] + t["extra_dispatch_us"]
            if best_t is None or total < best_t * (1.0 - TIEBREAK_BAND):
                best_c, best_t = c, total
        chunks = best_c
    else:
        chunks = max(1, int(overlap))
    return Plan("dhopm3", impl, fused=fused, overlap_chunks=chunks,
                algo=algo, reason=why)


def plan_dhopm3(shape, *, p: int = 1, s: int | None = None, batch: int = 1,
                itemsize: int = 4, fuse_pairs: bool | None = None,
                overlap: int | None = None,
                backend: str | None = None) -> Plan:
    """Plan for one (optionally batched, ``s=None`` = unsplit sequential)
    dHOPM_3 chain walker.

    ``fuse_pairs`` / ``overlap`` None mean "let the model decide"; explicit
    values pass through unchanged (caller override).  ``overlap`` follows
    the walker convention: False = sync, True = default chunking, int =
    that many chunks."""
    report.note("plan.dhopm3")
    if overlap is False:
        overlap = 1
    elif overlap is True:
        from repro.core.dhopm import OVERLAP_CHUNKS_DEFAULT
        overlap = OVERLAP_CHUNKS_DEFAULT
    elif overlap is not None:
        overlap = int(overlap)
    return _plan_dhopm3(tuple(shape), p, s, batch, itemsize,
                        fuse_pairs, overlap, _backend(backend),
                        calibration.disabled())


def plan_compress(b: int, shape, *, itemsize: int = 4,
                  backend: str | None = None, churn: bool = False) -> Plan:
    """Plan for one grad_compress bucket: B stacked same-shape views.

    The engine is pinned to ``mulsum`` on EVERY backend — grad_compress's
    bucketed==per-leaf bitwise guarantee depends on the order-explicit
    accumulation tree, which no other engine provides — so auto only ever
    decides the bucketing (and how the bucket is *assembled*) here.

    ``arena`` resolves the assembly: a bucketed B > 1 group fills a
    persistent donated ``[B, ...]`` arena buffer in place
    (:mod:`repro.core.arena`) instead of paying the ``jnp.stack`` round
    trip — the fill is value-identical, so the bitwise guarantee is
    unaffected.  Singleton buckets (nothing to stack) and caller-declared
    shape churn (``churn=True`` — every event a new ``(B, view)`` key, so
    every fill would be a cold allocation) keep the stack path, as does
    ``REPRO_TVC_DISABLE_PLAN`` (legacy static behavior)."""
    report.note("plan.compress")
    disabled = calibration.disabled()
    base = _plan_batched(b, tuple(shape), len(shape) - 1, itemsize,
                         _backend(backend), disabled)
    arena = bool(base.bucket and b > 1 and not churn and not disabled)
    return dataclasses.replace(
        base, kind="compress", impl="mulsum", arena=arena,
        reason="bitwise-batchable engine (grad_compress guarantee)")


# ---------------------------------------------------------------------------
# runtime hooks

def resolve_impl(impl: str, kind: str, shape, k: int, *, itemsize: int = 4,
                 batch: int = 1, static_ab: bool = True,
                 backend: str | None = None) -> str:
    """Resolve ``impl="auto"`` for the flat tvc entry points; explicit
    impls pass through untouched."""
    if impl != AUTO:
        return impl
    if kind == "tvc":
        return plan_tvc(shape, k, itemsize=itemsize, backend=backend).impl
    if kind == "tvc2":
        return plan_tvc2(shape, k, itemsize=itemsize, static_ab=static_ab,
                         backend=backend).impl
    if kind == "batched":
        return plan_batched(batch, shape, k, itemsize=itemsize,
                            backend=backend).impl
    raise ValueError(f"unknown planner kind {kind!r}")


def resolve_dhopm(impl: str, fuse_pairs, overlap, *, shape,
                  p: int = 1, s: int | None = None, batch: int = 1,
                  itemsize: int = 4, backend: str | None = None):
    """Resolve (impl, fuse_pairs, overlap) for the chain walkers.

    Explicit flags always win; with ``impl="auto"`` any flag left at None
    comes from the plan.  Returns concrete ``(impl, fuse_pairs, overlap)``
    ready for ``_hopm_sweeps``."""
    if impl != AUTO:
        return (impl,
                False if fuse_pairs is None else fuse_pairs,
                False if overlap is None else overlap)
    plan = plan_dhopm3(
        shape, p=p, s=s, batch=batch, itemsize=itemsize,
        fuse_pairs=None if fuse_pairs is None else bool(fuse_pairs),
        overlap=None if overlap is None else overlap,
        backend=backend)
    overlap_out = plan.overlap_chunks if plan.overlap_chunks > 1 else False
    if overlap is not None:
        overlap_out = overlap
    return (plan.impl,
            plan.fused if fuse_pairs is None else fuse_pairs,
            overlap_out)


def epilogue_fallback(kind: str, impl: str) -> None:
    """Record a silent de-optimization: the fused kernel epilogue could not
    run (traced alpha/beta) and the update went out as a second launch."""
    report.note(f"{kind}.two_launch_fallback")


# ---------------------------------------------------------------------------
# bench integration

def _cell_itemsize(cell) -> int:
    return 2 if cell.get("dtype") == "bf16" else 4


def plan_for_cell(cell: dict, backend: str | None = None) -> dict:
    """The plan auto would choose for a bench cell's recorded inputs —
    written by ``bench_tvc_kernel`` at measure time and recomputed verbatim
    by ``check_bench`` (the schema-6 plan-divergence gate)."""
    kind = cell["kind"]
    shape = tuple(cell["shape"])
    itemsize = _cell_itemsize(cell)
    if backend is None:
        eng = cell.get("engine", "")
        backend = "tpu" if eng == "pallas" else "cpu"
    if kind == "tvc":
        p = plan_tvc(shape, cell["mode"], itemsize=itemsize, backend=backend)
    elif kind == "tvc2":
        p = plan_tvc2(shape, cell["mode"], itemsize=itemsize,
                      backend=backend)
    elif kind == "tvc_batched":
        p = plan_batched(cell["batch"], shape, cell["mode"],
                         itemsize=itemsize, backend=backend)
    elif kind in ("dhopm3_batched", "dhopm3_overlap"):
        p = plan_dhopm3(shape, p=cell.get("p", 1), s=cell.get("split"),
                        batch=cell.get("batch", 1), itemsize=itemsize,
                        backend=backend)
    elif kind == "serving":
        # the serve engine's KV-compression groups plan exactly like
        # grad_compress buckets: B stacked same-view tensors, mulsum pinned
        p = plan_compress(cell["batch"], shape, itemsize=itemsize,
                          backend=backend)
    elif kind == "arena":
        # stacked-vs-arena-filled compression step cells: same compress
        # plan; the arena-vs-stack resolution itself is gated separately
        # via the cell's recorded ``arena_plan`` field
        p = plan_compress(cell["batch"], shape, itemsize=itemsize,
                          backend=backend)
    else:
        raise ValueError(f"no plan rule for bench kind {kind!r}")
    return p.as_cell_dict()
