"""Observability for the planner: every auto dispatch, silent fallback and
AOT cache hit/miss increments a named counter here, and
:func:`plan_report` snapshots them — so de-optimizations (e.g. the tvc2
two-launch epilogue fallback under traced alpha/beta) are visible instead
of silent.
"""
from __future__ import annotations

import collections
import threading

__all__ = ["counters", "note", "plan_report", "reset_plan_report"]

_lock = threading.Lock()
_counts: collections.Counter = collections.Counter()


def note(event: str, n: int = 1) -> None:
    """Count one planner/AOT event (trace-time only — never traced)."""
    with _lock:
        _counts[event] += n


def counters() -> dict:
    with _lock:
        return dict(_counts)


def plan_report() -> dict:
    """Snapshot of planner decisions, fallbacks and AOT cache traffic."""
    from . import aot, calibration
    return {
        "counters": counters(),
        "aot": aot.stats(),
        "calibration": str(calibration.table_path()),
        "calibrated": calibration.table_path().exists(),
        "disabled": calibration.disabled(),
    }


def reset_plan_report() -> None:
    """Zero all counters (tests)."""
    with _lock:
        _counts.clear()
