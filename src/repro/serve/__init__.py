"""Serving substrate: continuous-batching decode engine + sampling."""
from .engine import (  # noqa: F401
    CompressedKV,
    DecodeEngine,
    GenerationResult,
    Request,
    RequestQueue,
    ServeResult,
    ServeStats,
)
