"""Serving substrate: batched decode engine + sampling."""
from .engine import DecodeEngine  # noqa: F401
