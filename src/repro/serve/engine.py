"""Batched decode engine: prefill + step loop over a fixed slot batch, with
per-sequence EOS retirement and continuous slot refill from a request queue.

On a mesh the KV cache is sequence-sharded over the model axis (SP — the
paper's "keep outputs distributed" discipline applied to the KV timeline) and
the batch over the DP axes; shardings come from dist.sharding.cache_specs."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ModelConfig
from repro.dist.sharding import cache_specs
from repro.models import extra_input_key, registry
from .sampling import sample


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, steps); rows are eos_id-padded past EOS
    steps: int
    prefill_tokens: int
    lengths: np.ndarray = None  # (B,) true generated length per sequence
    #                             (including the EOS token itself)


class DecodeEngine:
    def __init__(self, cfg: ModelConfig, params, *, mesh: Optional[Mesh] = None,
                 max_seq: int = 4096, batch_size: int = 8,
                 eos_id: Optional[int] = None):
        self.cfg = cfg
        self.mod = registry.get(cfg.family)
        self.params = params
        self.mesh = mesh
        self.max_seq = max_seq
        self.batch_size = batch_size
        self.eos_id = eos_id

        def _prefill(params, tokens, cache, extra):
            if extra is None:
                return self.mod.prefill(cfg, params, tokens, cache)
            return self.mod.prefill(cfg, params, tokens, cache, extra)

        def _step(params, cache, toks):
            return self.mod.decode_step(cfg, params, cache, toks)

        self._prefill = jax.jit(_prefill, static_argnames=())
        self._step = jax.jit(_step, donate_argnums=(1,))

    def new_cache(self):
        cache = self.mod.init_cache(self.cfg, self.batch_size, self.max_seq)
        if self.mesh is not None:
            shapes = jax.eval_shape(lambda: cache)
            specs = cache_specs(self.cfg, shapes, self.mesh)
            cache = jax.tree.map(
                lambda a, s: jax.device_put(a, NamedSharding(self.mesh, s)),
                cache, specs)
        return cache

    def warmup(self, prompt_len: int, *, extra=None,
               include_step: bool = True) -> dict:
        """AOT lower+compile the (batch, ``prompt_len``) prefill and the
        decode-step entry points ahead of the first request.

        Pair with :func:`repro.plan.aot.enable_persistent_cache` and the
        compile happens once per fleet, not once per process: later
        processes deserialize from the persistent cache, and a repeated
        in-process warmup is a dictionary hit.  Returns the per-entry
        :func:`repro.plan.aot.warmup` reports (``cache`` is
        ``"in_process"`` / ``"persistent"`` / ``"cold"`` plus
        ``compile_us``); counters land in
        :func:`repro.plan.report.plan_report`."""
        from repro.plan import aot
        toks = jnp.zeros((self.batch_size, prompt_len), jnp.int32)
        cache = self.new_cache()
        name = f"decode_prefill_{self.cfg.family}"
        reports = {"prefill": aot.warmup(
            self._prefill, self.params, toks, cache, extra, name=name)}
        if include_step:
            cur = jnp.zeros((self.batch_size, 1), jnp.int32)
            reports["step"] = aot.warmup(
                self._step, self.params, cache, cur,
                name=f"decode_step_{self.cfg.family}")
        return reports

    def generate(self, prompt_tokens, steps: int, *, temperature: float = 0.0,
                 top_k: Optional[int] = None, extra=None, seed: int = 0
                 ) -> GenerationResult:
        """prompt_tokens: (B, S) int32 with B == batch_size."""
        toks = jnp.asarray(prompt_tokens, jnp.int32)
        B, S = toks.shape
        assert B == self.batch_size, (B, self.batch_size)
        cache = self.new_cache()
        cache, logits = self._prefill(self.params, toks, cache, extra)
        rng = jax.random.PRNGKey(seed)
        out = []
        alive = np.ones((B,), bool)
        lengths = np.zeros((B,), np.int64)
        cur = sample(logits, rng, vocab_size=self.cfg.vocab_size,
                     temperature=temperature, top_k=top_k)
        for t in range(steps):
            tok = np.asarray(cur)[:, 0].copy()
            if self.eos_id is not None:
                # EOS-retired slots keep stepping (static batch), but their
                # sampled tokens are garbage — freeze the record at eos_id
                # so callers never see post-EOS tokens.
                tok[~alive] = self.eos_id
            out.append(tok)
            lengths += alive
            if self.eos_id is not None:
                alive &= tok != self.eos_id
                if not alive.any():
                    break
            cache, logits = self._step(self.params, cache, cur)
            rng, sub = jax.random.split(rng)
            cur = sample(logits, sub, vocab_size=self.cfg.vocab_size,
                         temperature=temperature, top_k=top_k)
        return GenerationResult(np.stack(out, 1), len(out), S * B, lengths)

    def serve_queue(self, requests, steps_per_req: int, **kw):
        """Continuous-batching-lite: consume a list of (B, S) prompt batches,
        reusing compiled step functions across batches."""
        results = []
        for prompts in requests:
            results.append(self.generate(prompts, steps_per_req, **kw))
        return results
