"""Continuous-batching decode engine with HOPM low-rank KV compression.

Two serving paths share the compiled model entry points:

* :meth:`DecodeEngine.generate` — the original fixed-batch loop (prefill one
  (B, S) batch, step to completion, freeze-at-eos bookkeeping).
* :meth:`DecodeEngine.serve` — slot-based continuous batching: a
  :class:`RequestQueue` of ragged prompts feeds a fixed slot batch; each
  slot is an exact batch-1 model cache, the whole batch steps through ONE
  vmapped ``decode_step`` launch, and an EOS-/budget-retired slot is
  recycled mid-generation with a per-slot prefill scattered into the
  stacked cache (the freeze-at-eos seam turned into admission).

On retirement a request's KV context is compressed to a rank-1 HOPM
factorization: contexts are sliced to their true length, zero-padded up to
a ``ctx_quantum`` (exact for the power iteration — a zero slab adds
``+ 0.0`` to every reduction), bucketed by their
:func:`repro.core.bucketing.tensor_view` shape exactly the way
``train.grad_compress`` buckets gradient leaves, and every same-shape group
runs through ONE :func:`repro.core.dhopm.hopm3_batched` chain per step —
launch count independent of the group size, bitwise-equal to per-slot
:func:`~repro.core.dhopm.hopm3` under the order-explicit ``mulsum`` engine
(``impl="auto"`` resolves through :func:`repro.plan.planner.plan_compress`,
which pins it).  Streamed traffic and the dense/factored byte ratio are
priced by :mod:`repro.core.memory_model`
(:func:`~repro.core.memory_model.hopm_streamed_elems_sweep` /
:func:`~repro.core.memory_model.rank1_factor_elems`).

On a mesh the fixed-batch cache is sequence-sharded over the model axis (SP)
and batch over the DP axes (``dist.sharding.cache_specs``); the slot-stacked
cache shards its leading slot dim over the DP axes.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
import zlib
from typing import Any, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import memory_model as mm
from repro.core.arena import BatchedArena
from repro.core.bucketing import group_indices, pad_extent, tensor_view
from repro.core.dhopm import hopm3_batched, hopm_init_factors
from repro.dist.sharding import _dp_entry, cache_specs
from repro.models import registry
from .sampling import sample, sample_slots

#: cache leaves that carry a per-request KV timeline on axis -2 (the
#: compressible context); recurrent-state families have none and serve
#: with compression as a no-op
_KV_TIMELINE_KEYS = ("k", "v", "c", "pe")

#: bucketing order for KV context views (grad_compress's default max_order:
#: the shared tensor_view rule keeps trailing low-rank dims intact)
_KV_MAX_ORDER = 4


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, steps); rows are eos_id-padded past EOS
    steps: int
    prefill_tokens: int
    lengths: Optional[np.ndarray] = None
    # (B,) true generated length per sequence (including the EOS token
    # itself).  Constructed when omitted — with no EOS bookkeeping every
    # sequence ran the full step count — so requests/s accounting downstream
    # can always sum a real length vector.

    def __post_init__(self):
        if self.lengths is None:
            b = self.tokens.shape[0] if self.tokens.ndim else 0
            self.lengths = np.full((b,), self.steps, np.int64)


@dataclasses.dataclass
class Request:
    """One ragged serving request: its prompt and generation budget."""
    rid: int
    tokens: np.ndarray              # (S,) int32 prompt
    max_new_tokens: int = 32
    extra: Any = None               # per-request conditioning (vlm/encdec)


class RequestQueue:
    """FIFO admission queue feeding the slot batch."""

    def __init__(self, requests=()):
        self._q = collections.deque(requests)

    def push(self, req: Request) -> None:
        self._q.append(req)

    def pop(self) -> Request:
        return self._q.popleft()

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)


@dataclasses.dataclass
class CompressedKV:
    """Rank-1 HOPM factorization of one retired KV-cache leaf."""
    xs: tuple                       # one factor vector per view mode
    lam: jax.Array                  # dominant singular value
    view: tuple                     # padded bucketing view shape
    ctx: int                        # true (unpadded) context length
    dense_bytes: int
    factor_bytes: int


@dataclasses.dataclass
class ServeResult:
    """One completed request."""
    rid: int
    prompt_len: int
    tokens: np.ndarray              # (length,) generated, incl. EOS if hit
    length: int
    steps: int                      # engine steps the request was resident
    compressed: dict | None = None  # leaf name -> CompressedKV


@dataclasses.dataclass
class ServeStats:
    """Aggregate accounting of one :meth:`DecodeEngine.serve` run."""
    admitted: int = 0
    completed: int = 0
    steps: int = 0
    prefills: int = 0
    prefill_tokens: int = 0
    generated_tokens: int = 0
    recycled: int = 0               # admissions into a previously used slot
    comp_events: list = dataclasses.field(default_factory=list)
    #   one [group_size, view] entry per hopm3_batched group launch event
    comp_launches: int = 0          # batched contraction launches issued
    comp_streamed_bytes: int = 0    # modeled (hopm_streamed_elems_sweep)
    comp_dense_bytes: int = 0       # dense KV context footprint
    comp_factor_bytes: int = 0      # rank-1 factor footprint
    arena_fills: int = 0            # group operand fills through the arena
    arena_cold_fills: int = 0       # first-allocation fills (cost one stack)
    stack_copy_removed_bytes: int = 0
    #   bucket-assembly copy traffic the arena removed vs jnp.stack
    #   (memory_model.bucket_stack_elems - arena_fill_elems, per fill)
    step_us: list = dataclasses.field(default_factory=list)

    @property
    def compression_ratio(self) -> float:
        return self.comp_dense_bytes / max(1, self.comp_factor_bytes)


@functools.partial(jax.jit, static_argnames=("sweeps", "impl"))
def _compress_group(A_b, xs_b, *, sweeps: int, impl: str):
    """ONE batched rank-1 chain for a same-view group of B retired
    contexts: launch count per sweep independent of B, bitwise-equal to B
    per-slot ``hopm3`` runs under the ``mulsum`` engine."""
    return hopm3_batched(A_b, list(xs_b), sweeps=sweeps, impl=impl)


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("names", "stops", "view"))
def _arena_fill_kv(buf, kv, slots, *, names, stops, view):
    """Fused donated arena fill straight from the slot-stacked cache: one
    program per (B, view, member-pattern) that indexes each member's slot
    row, drops the batch-1 dim, slices the timeline to its stop, and writes
    the reshaped view into the donated ``[B, *view]`` buffer in place —
    no eager per-slot slice materialization, no ``jnp.stack``, no
    ``concatenate`` primitive in the jaxpr.  Bitwise-identical rows to the
    eager ``_kv_view`` + ``jnp.stack`` path (pure indexing/reshape, no
    arithmetic).  Retraces per member pattern; ``ctx_quantum`` padding keeps
    the pattern count small."""
    for r, (name, stop) in enumerate(zip(names, stops)):
        a = lax.dynamic_index_in_dim(kv[name], slots[r], axis=0,
                                     keepdims=False)
        a = a.reshape(a.shape[:1] + a.shape[2:])       # drop batch-1 dim
        a = lax.slice_in_dim(a, 0, stop, axis=a.ndim - 2)
        buf = buf.at[r].set(a.reshape(view).astype(buf.dtype))
    return buf


@functools.partial(jax.jit,
                   static_argnames=("vocab_size", "temperature", "top_k"))
def _sample_slots_jit(logits, req_keys, counts, *, vocab_size, temperature,
                      top_k):
    keys = jax.vmap(jax.random.fold_in)(req_keys, counts)
    return sample_slots(logits, keys, vocab_size=vocab_size,
                        temperature=temperature, top_k=top_k)


def _request_key(rid, seed: int):
    """Stable per-request PRNG root: crc32 of the request id (salted
    ``hash()`` would break cross-process determinism), folded with the
    serve seed — slot- and admission-order-independent."""
    return jax.random.PRNGKey(
        (seed + zlib.crc32(str(rid).encode())) % (2 ** 31))


class DecodeEngine:
    def __init__(self, cfg: ModelConfig, params, *, mesh: Optional[Mesh] = None,
                 max_seq: int = 4096, batch_size: int = 8,
                 eos_id: Optional[int] = None):
        self.cfg = cfg
        self.mod = registry.get(cfg.family)
        self.params = params
        self.mesh = mesh
        self.max_seq = max_seq
        self.batch_size = batch_size
        self.eos_id = eos_id

        def _prefill(params, tokens, cache, extra):
            if extra is None:
                return self.mod.prefill(cfg, params, tokens, cache)
            return self.mod.prefill(cfg, params, tokens, cache, extra)

        def _step(params, cache, toks):
            return self.mod.decode_step(cfg, params, cache, toks)

        def _step_slots(params, caches, toks):
            # each slot is an exact batch-1 model cache; one vmapped launch
            # steps the whole slot batch with per-slot positions
            def one(c, t):
                return self.mod.decode_step(cfg, params, c, t)
            return jax.vmap(one)(caches, toks)

        def _adopt(caches, one, i):
            # scatter a freshly prefilled (or zeroed) batch-1 cache into
            # slot i of the stacked cache — the recycling seam
            return jax.tree.map(lambda full, a: full.at[i].set(a),
                                caches, one)

        self._prefill = jax.jit(_prefill, static_argnames=())
        self._step = jax.jit(_step, donate_argnums=(1,))
        self._step_slots = jax.jit(_step_slots, donate_argnums=(1,))
        self._adopt = jax.jit(_adopt, donate_argnums=(0,))
        # persistent donated [B, *view] operand/factor buffers for the
        # retirement compression groups (repro.core.arena); keys are the
        # same (B, tensor_view, dtype) the groups bucket under
        self._arena = BatchedArena()

    # -- caches -------------------------------------------------------------

    def new_cache(self):
        cache = self.mod.init_cache(self.cfg, self.batch_size, self.max_seq)
        if self.mesh is not None:
            shapes = jax.eval_shape(lambda: cache)
            specs = cache_specs(self.cfg, shapes, self.mesh)
            cache = jax.tree.map(
                lambda a, s: jax.device_put(a, NamedSharding(self.mesh, s)),
                cache, specs)
        return cache

    def _slot_cache(self):
        """A fresh zeroed batch-1 cache (one slot's private state)."""
        return self.mod.init_cache(self.cfg, 1, self.max_seq)

    def new_slot_caches(self):
        """The slot-stacked cache: B batch-1 caches on a new leading axis
        (sharded over the DP axes on a mesh)."""
        one = self._slot_cache()
        stacked = jax.tree.map(
            lambda a: jnp.zeros((self.batch_size,) + a.shape, a.dtype), one)
        if self.mesh is not None:
            ent = _dp_entry(dict(self.mesh.shape), self.batch_size)
            stacked = jax.tree.map(
                lambda a: jax.device_put(a, NamedSharding(
                    self.mesh, P(*([ent] + [None] * (a.ndim - 1))))),
                stacked)
        return stacked

    def warmup(self, prompt_len: int, *, extra=None,
               include_step: bool = True) -> dict:
        """AOT lower+compile the (batch, ``prompt_len``) prefill and the
        decode-step entry points ahead of the first request.

        Pair with :func:`repro.plan.aot.enable_persistent_cache` and the
        compile happens once per fleet, not once per process: later
        processes deserialize from the persistent cache, and a repeated
        in-process warmup is a dictionary hit.  Returns the per-entry
        :func:`repro.plan.aot.warmup` reports (``cache`` is
        ``"in_process"`` / ``"persistent"`` / ``"cold"`` plus
        ``compile_us``); counters land in
        :func:`repro.plan.report.plan_report`."""
        from repro.plan import aot
        toks = jnp.zeros((self.batch_size, prompt_len), jnp.int32)
        cache = self.new_cache()
        name = f"decode_prefill_{self.cfg.family}"
        reports = {"prefill": aot.warmup(
            self._prefill, self.params, toks, cache, extra, name=name)}
        if include_step:
            cur = jnp.zeros((self.batch_size, 1), jnp.int32)
            reports["step"] = aot.warmup(
                self._step, self.params, cache, cur,
                name=f"decode_step_{self.cfg.family}")
        return reports

    # -- fixed-batch generation ---------------------------------------------

    def generate(self, prompt_tokens, steps: int, *, temperature: float = 0.0,
                 top_k: Optional[int] = None, extra=None, seed: int = 0
                 ) -> GenerationResult:
        """prompt_tokens: (B, S) int32 with B == batch_size."""
        toks = jnp.asarray(prompt_tokens, jnp.int32)
        B, S = toks.shape
        assert B == self.batch_size, (B, self.batch_size)
        cache = self.new_cache()
        cache, logits = self._prefill(self.params, toks, cache, extra)
        rng = jax.random.PRNGKey(seed)
        out = []
        alive = np.ones((B,), bool)
        lengths = np.zeros((B,), np.int64)
        cur = sample(logits, rng, vocab_size=self.cfg.vocab_size,
                     temperature=temperature, top_k=top_k)
        for t in range(steps):
            tok = np.asarray(cur)[:, 0].copy()
            if self.eos_id is not None:
                # EOS-retired slots keep stepping (static batch), but their
                # sampled tokens are garbage — freeze the record at eos_id
                # so callers never see post-EOS tokens.
                tok[~alive] = self.eos_id
            out.append(tok)
            lengths += alive
            if self.eos_id is not None:
                alive &= tok != self.eos_id
                if not alive.any():
                    break
            cache, logits = self._step(self.params, cache, cur)
            rng, sub = jax.random.split(rng)
            cur = sample(logits, sub, vocab_size=self.cfg.vocab_size,
                         temperature=temperature, top_k=top_k)
        return GenerationResult(np.stack(out, 1), len(out), S * B, lengths)

    # -- continuous batching --------------------------------------------------

    def _kv_context(self, caches, i: int, ctx_padded: int) -> dict:
        """Slot i's KV timeline leaves, squeezed to batch-free views and
        sliced to the (quantum-padded) context length.  The pad region
        [ctx, ctx_padded) was never written (fresh prefill + sequential
        decode writes), so it is exactly zero — bucket-aligning is exact."""
        out = {}
        for name, leaf in caches.items():
            if name not in _KV_TIMELINE_KEYS or not hasattr(leaf, "ndim"):
                continue
            a = leaf[i]                      # (L, 1, ..., S, hd)
            a = a.reshape(a.shape[:1] + a.shape[2:])   # drop batch-1 dim
            # ring-buffer families keep a window < max_seq on the timeline
            stop = min(ctx_padded, a.shape[a.ndim - 2])
            out[name] = lax.slice_in_dim(a, 0, stop, axis=a.ndim - 2)
        return out

    @staticmethod
    def _kv_sliced_shape(leaf, ctx_padded: int):
        """The shape :meth:`_kv_context` would slice leaf ``[i]`` to —
        computed statically (no materialization): drop the slot and batch-1
        dims, clamp the timeline to the (quantum-padded) context."""
        shp = tuple(leaf.shape[1:])                   # drop slot dim
        shp = shp[:1] + shp[2:]                       # drop batch-1 dim
        stop = min(ctx_padded, shp[-2])
        return shp[:-2] + (stop,) + shp[-1:], stop

    def _kv_view(self, caches, name: str, slot: int, stop: int, view):
        """One member's context, eagerly sliced and reshaped to its
        bucketing view — the legacy (stacked-path) assembly unit."""
        a = caches[name][slot]
        a = a.reshape(a.shape[:1] + a.shape[2:])
        a = lax.slice_in_dim(a, 0, stop, axis=a.ndim - 2)
        return a.reshape(view)

    def _compress_retired(self, items, *, caches, sweeps: int, impl: str,
                          arena, stats: ServeStats):
        """Compress this step's retirements: bucket same-view contexts,
        run ONE batched rank-1 chain per group, unstack the factors.

        ``items``: list of (slot_record, slot_index, padded_ctx).  Returns
        one ``{leaf: CompressedKV}`` dict per item, order-aligned.

        Group assembly is arena-or-stack per group (``arena`` explicit flag
        wins; ``"auto"`` asks :func:`repro.plan.planner.plan_compress` —
        arena for B > 1 groups): the arena path fills a persistent donated
        ``[B, *view]`` operand buffer straight from the cache leaves
        (:func:`_arena_fill_kv` — no eager slice materialization, no
        stack) and scatter-fills the per-mode init-factor stacks through
        the same arena; the stacked path is the legacy eager
        slice-and-``jnp.stack`` assembly.  Both feed bitwise-identical
        operands into ``_compress_group``, so the factors match bit for
        bit."""
        flat = []   # (item_idx, leaf_name, slot, stop, view, dtype, ctx)
        if isinstance(caches, dict):
            for idx, (rec, slot, ctx_p) in enumerate(items):
                for name, leaf in caches.items():
                    if name not in _KV_TIMELINE_KEYS \
                            or not hasattr(leaf, "ndim"):
                        continue
                    sliced, stop = self._kv_sliced_shape(leaf, ctx_p)
                    view = tensor_view(sliced, _KV_MAX_ORDER)
                    flat.append((idx, name, slot, stop, view,
                                 jnp.dtype(leaf.dtype).name, rec["ctx"]))
        results: list[dict] = [{} for _ in items]
        groups = group_indices((f[4], f[5]) for f in flat)
        for (view, dname), members in groups.items():
            b = len(members)
            itemsize = jnp.dtype(dname).itemsize
            eng = impl
            use_arena = arena
            if eng == "auto" or use_arena == "auto":
                from repro.plan import planner
                plan = planner.plan_compress(b, view, itemsize=itemsize)
                eng = plan.impl if eng == "auto" else eng
                use_arena = plan.arena if use_arena == "auto" \
                    else bool(use_arena)
            xs0 = []
            for m in members:
                idx, name, _, _, _, _, _ = flat[m]
                rid = items[idx][0]["rid"]
                key = _request_key(f"kv/{rid}/{name}", 0)
                xs0.append(hopm_init_factors(key, view)[0])
            A_b = xs_b = None
            if use_arena:
                buf, cold = self._arena.acquire("kv", b, view, dname)
                if buf is not None:
                    names = tuple(flat[m][1] for m in members)
                    stops = tuple(flat[m][3] for m in members)
                    kv = {n: caches[n] for n in set(names)}
                    slots_arr = jnp.asarray(
                        [flat[m][2] for m in members], jnp.int32)
                    buf = _arena_fill_kv(buf, kv, slots_arr, names=names,
                                         stops=stops, view=view)
                    # one event per group: ranks=1 prices the operand
                    # stack AND the per-mode factor gathers it replaces
                    self._arena.commit("kv", b, view, dname, buf,
                                       cold=cold, ranks=1)
                    A_b = buf
                    # factor stacks ride the arena too (accounting already
                    # covered by the group event's ranks term)
                    xs_b = tuple(
                        self._arena.fill_rows(
                            ("kv_x", mode), [x[mode] for x in xs0],
                            account=False)
                        for mode in range(len(view)))
                    stats.arena_fills += 1
                    stats.arena_cold_fills += int(cold)
                    stats.stack_copy_removed_bytes += (
                        mm.bucket_stack_elems(b, view, ranks=1)
                        - mm.arena_fill_elems(b, view, ranks=1, cold=cold)
                    ) * itemsize
            if A_b is None:     # stacked path (or arena key-table full)
                A_b = jnp.stack([
                    self._kv_view(caches, flat[m][1], flat[m][2],
                                  flat[m][3], view) for m in members])
                xs_b = tuple(jnp.stack([x[mode] for x in xs0])
                             for mode in range(len(view)))
            if xs_b is None or any(x is None for x in xs_b):
                # factor-arena overflow: fall back to stacking factors
                xs_b = tuple(jnp.stack([x[mode] for x in xs0])
                             for mode in range(len(view)))
            xs, lam = _compress_group(A_b, xs_b, sweeps=sweeps, impl=eng)
            dense = int(np.prod(view)) * itemsize
            factor = mm.rank1_factor_elems(view) * itemsize
            for pos, m in enumerate(members):
                idx, name, _, _, _, _, ctx = flat[m]
                results[idx][name] = CompressedKV(
                    xs=tuple(x[pos] for x in xs), lam=lam[pos],
                    view=view, ctx=ctx, dense_bytes=dense,
                    factor_bytes=factor)
            stats.comp_events.append([b, list(view)])
            stats.comp_launches += sweeps * mm.dhopm_launches_per_sweep(
                len(view))
            stats.comp_streamed_bytes += int(
                b * sweeps * mm.hopm_streamed_elems_sweep(view)) * itemsize
            stats.comp_dense_bytes += b * dense
            stats.comp_factor_bytes += b * factor
        return results

    def serve(self, queue, *, temperature: float = 0.0,
              top_k: Optional[int] = None, seed: int = 0,
              compress: bool = True, comp_sweeps: int = 2,
              comp_impl: str = "auto", comp_arena: str | bool = "auto",
              ctx_quantum: int = 16):
        """Serve a :class:`RequestQueue` (or iterable of :class:`Request`)
        through the slot batch until drained.  Returns
        ``(results, stats)`` — one :class:`ServeResult` per request in
        completion order, plus the run's :class:`ServeStats`.

        Per engine step: admit queued requests into free slots (per-slot
        prefill at the prompt's exact length, scattered into the stacked
        cache), step every slot through one vmapped ``decode_step`` launch,
        sample per-slot request-seeded tokens, retire EOS/budget-exhausted
        slots, and compress this step's retired KV contexts — one
        ``hopm3_batched`` launch chain per same-view group, its operands
        assembled through the persistent donated batched-operand arena
        (``comp_arena``: ``True``/``False`` forces arena/stack assembly,
        ``"auto"`` asks the planner; both assemblies are bitwise-equal)."""
        if not isinstance(queue, RequestQueue):
            queue = RequestQueue(queue)
        B = self.batch_size
        caches = self.new_slot_caches()
        fresh = self._slot_cache()
        slots: list[Optional[dict]] = [None] * B
        req_keys = np.zeros((B, 2), np.uint32)
        counts = np.zeros((B,), np.int32)
        cur = np.zeros((B, 1), np.int32)
        used = np.zeros((B,), bool)         # slot ever admitted a request?
        results: list[ServeResult] = []
        stats = ServeStats()
        eos = self.eos_id

        def admit() -> None:
            nonlocal caches
            for i in range(B):
                if slots[i] is not None or not queue:
                    continue
                req = queue.pop()
                toks = jnp.asarray(np.asarray(req.tokens), jnp.int32)[None]
                c1, logits1 = self._prefill(
                    self.params, toks, self._slot_cache(), req.extra)
                caches = self._adopt(caches, c1, i)
                rk = _request_key(req.rid, seed)
                req_keys[i] = np.asarray(rk, np.uint32).reshape(2)
                counts[i] = 0
                t0 = sample(logits1, jax.random.fold_in(rk, 0),
                            vocab_size=self.cfg.vocab_size,
                            temperature=temperature, top_k=top_k)
                cur[i] = np.asarray(t0)[0]
                slots[i] = {"rid": req.rid, "prompt_len": int(toks.shape[1]),
                            "out": [int(cur[i, 0])],
                            "budget": int(req.max_new_tokens),
                            "steps": 0, "ctx": int(toks.shape[1]) + 1}
                stats.admitted += 1
                stats.prefills += 1
                stats.prefill_tokens += int(toks.shape[1])
                stats.recycled += bool(used[i])
                used[i] = True

        def retire() -> None:
            """Collect finished slots; compress this step's retirements in
            same-view groups (one batched launch chain per group)."""
            nonlocal caches
            done = []
            for i in range(B):
                rec = slots[i]
                if rec is None:
                    continue
                tok = rec["out"][-1]
                if (eos is not None and tok == eos) \
                        or len(rec["out"]) >= rec["budget"]:
                    done.append((i, rec))
            if not done:
                return
            comp = [None] * len(done)
            if compress:
                items = []
                for i, rec in done:
                    ctx_p = pad_extent(rec["ctx"], ctx_quantum,
                                       cap=self.max_seq)
                    items.append((rec, i, ctx_p))
                comp = self._compress_retired(
                    items, caches=caches, sweeps=comp_sweeps,
                    impl=comp_impl, arena=comp_arena, stats=stats)
            for (i, rec), c in zip(done, comp):
                results.append(ServeResult(
                    rid=rec["rid"], prompt_len=rec["prompt_len"],
                    tokens=np.asarray(rec["out"], np.int32),
                    length=len(rec["out"]), steps=rec["steps"],
                    compressed=c if compress else None))
                stats.completed += 1
                stats.generated_tokens += len(rec["out"])
                slots[i] = None
                # reset the slot so its free-running decode restarts at
                # pos 0 on a zero cache (next admission replaces it whole);
                # this also keeps the pad region of any later context slice
                # exactly zero — the padding-exactness invariant
                caches = self._adopt(caches, fresh, i)

        while True:
            admit()
            retire()
            if not any(s is not None for s in slots):
                if not queue:
                    break
                continue        # retirement freed slots; admit again
            t0 = time.perf_counter()
            active = np.array([s is not None for s in slots])
            counts[active] += 1
            caches, logits = self._step_slots(
                self.params, caches, jnp.asarray(cur)[:, None, :])
            toks = _sample_slots_jit(
                logits[:, 0], jnp.asarray(req_keys), jnp.asarray(counts),
                vocab_size=self.cfg.vocab_size, temperature=temperature,
                top_k=top_k)
            toks = np.asarray(toks)
            stats.step_us.append((time.perf_counter() - t0) * 1e6)
            stats.steps += 1
            for i in range(B):
                if slots[i] is None:
                    continue
                cur[i] = toks[i]
                slots[i]["out"].append(int(toks[i, 0]))
                slots[i]["steps"] += 1
                slots[i]["ctx"] += 1
        return results, stats

    def serve_queue(self, requests, steps_per_req: int, **kw):
        """Continuous-batching wrapper over :meth:`serve` for the legacy
        batch-of-batches call shape: flattens (B, S) prompt batches into
        one request stream and serves it through the slot batch."""
        queue = RequestQueue()
        rid = 0
        for prompts in requests:
            for row in np.asarray(prompts):
                queue.push(Request(rid=rid, tokens=row.astype(np.int32),
                                   max_new_tokens=steps_per_req))
                rid += 1
        return self.serve(queue, **kw)
