"""Token sampling: greedy / temperature / top-k, vocab-padding aware."""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def sample(logits, rng, *, vocab_size: int, temperature: float = 0.0,
           top_k: int | None = None):
    """logits: (B, 1, Vpad) or (B, Vpad) -> tokens (B, 1) int32."""
    if logits.ndim == 3:
        logits = logits[:, -1]
    logits = logits.astype(F32)
    V = logits.shape[-1]
    if V > vocab_size:  # never sample padding columns
        mask = jnp.arange(V) >= vocab_size
        logits = jnp.where(mask[None], -1e30, logits)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    logits = logits / temperature
    if top_k is not None and top_k < V:
        vals, _ = jax.lax.top_k(logits, top_k)
        kth = vals[:, -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)[:, None]


def sample_slots(logits, keys, *, vocab_size: int, temperature: float = 0.0,
                 top_k: int | None = None):
    """Per-slot sampling for the continuous-batching engine: one PRNG stream
    per slot.

    ``logits``: (B, 1, Vpad) or (B, Vpad); ``keys``: (B, 2) uint32 — one key
    per slot, derived by the engine from the *request* identity (crc32 of
    the request id folded with its emitted-token count), so a request's
    sampled tokens never depend on which slot admitted it, when it was
    admitted, or what ran in that slot before — the recycled-slot
    determinism guarantee.  Returns (B, 1) int32."""
    if logits.ndim == 3:
        logits = logits[:, -1]

    def one(lg, key):
        return sample(lg[None], key, vocab_size=vocab_size,
                      temperature=temperature, top_k=top_k)[0]

    return jax.vmap(one)(logits, keys)
