"""Training substrate: optimizers, dHOPM_3 gradient compression, data
pipeline, checkpoint/restart, and the train-step builders."""
