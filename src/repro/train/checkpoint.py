"""Checkpoint/restart: sharded pytree save with atomic manifests, async
writes, retention, and elastic restore (re-shard onto a different mesh).

Format: one raw-bytes .bin per leaf (dtype recorded in the manifest — works
for bf16 via ml_dtypes) + manifest.json with the treedef paths, shapes,
dtypes, step and user metadata.  Writes go to ``<dir>/tmp-<step>`` and are
renamed to ``<dir>/step-<step>`` only when complete, so a crash mid-write
never corrupts the latest checkpoint."""
from __future__ import annotations

import json
import pathlib
import shutil
import threading

import numpy as np
import jax
import jax.numpy as jnp

_SEP = "/"


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        out[key] = leaf
    return out


def _unflatten_into(skeleton, flat: dict):
    paths = jax.tree_util.tree_flatten_with_path(skeleton)[0]
    treedef = jax.tree_util.tree_structure(skeleton)
    leaves = []
    for path, leaf in paths:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(ckpt_dir, step: int, tree, *, metadata: dict | None = None,
         keep_last: int = 3, async_write: bool = False):
    """Save ``tree`` at ``step``.  Returns the (eventual) checkpoint path;
    with async_write=True the copy happens on a daemon thread after the
    host-side fetch (so the train loop can proceed)."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    # fetch to host synchronously (cheap vs write), write async if asked
    host = {k: np.asarray(v) for k, v in flat.items()}

    def write():
        tmp = ckpt_dir / f"tmp-{step}"
        final = ckpt_dir / f"step-{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "metadata": metadata or {}, "leaves": {}}
        for i, (key, arr) in enumerate(sorted(host.items())):
            fname = f"leaf-{i:05d}.bin"
            (tmp / fname).write_bytes(arr.tobytes())
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
            }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        # retention
        steps = sorted(
            (int(p.name.split("-")[1]) for p in ckpt_dir.glob("step-*")))
        for s in steps[:-keep_last]:
            shutil.rmtree(ckpt_dir / f"step-{s}", ignore_errors=True)

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return ckpt_dir / f"step-{step}", t
    write()
    return ckpt_dir / f"step-{step}", None


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    steps = sorted(int(p.name.split("-")[1]) for p in ckpt_dir.glob("step-*"))
    return steps[-1] if steps else None


def load(ckpt_dir, step: int | None = None) -> tuple[dict, dict]:
    """Returns (flat {path: np.ndarray}, manifest)."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = ckpt_dir / f"step-{step}"
    manifest = json.loads((path / "manifest.json").read_text())
    flat = {}
    for key, info in manifest["leaves"].items():
        raw = (path / info["file"]).read_bytes()
        dtype = jnp.dtype(info["dtype"])  # handles bfloat16 via ml_dtypes
        flat[key] = np.frombuffer(raw, dtype=dtype).reshape(info["shape"])
    return flat, manifest


def restore(ckpt_dir, skeleton, *, step: int | None = None, shardings=None):
    """Restore into the structure of ``skeleton``.  ``shardings`` (same tree
    shape, NamedSharding leaves) re-lays the arrays onto a possibly DIFFERENT
    mesh — the elastic-restart path."""
    flat, manifest = load(ckpt_dir, step)
    tree = _unflatten_into(skeleton, flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree, manifest
