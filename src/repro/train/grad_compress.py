"""dHOPM_3 gradient compression — the paper's algorithm on the training
critical path (DESIGN.md §3).

Data-parallel gradient sync is exactly the paper's Eq. (2) setting: every DP
rank holds one full-size addend of G = Σ_p G^(p).  TVC linearity means
dHOPM_3's local chains + *delayed* n_j-sized all-reduces compute the exact
HOPM iterates of the *global* gradient while the wire carries only factor
vectors.  Rank-r via deflation; PowerSGD-style error feedback keeps the
compression unbiased-in-the-limit; warm-started factors amortize sweeps.

Per tensor of shape (n_0..n_{d-1}) and rank r, wire cost per step:
    r * sweeps * Σ_j n_j   (+ exact mp-allreduce for small/1-D leaves)
vs the dense Σ_j Π n_i all-reduce.

All functions run inside a shard_map manual region over the DP axis.
"""
from __future__ import annotations

import dataclasses
import math
from functools import reduce

import jax
import jax.numpy as jnp

from repro.core.dhopm import hopm3_batched, hopm3_partial
from repro.core.mixed_precision import F32 as PREC_F32, Precision, get_policy
from repro.dist import collectives as coll

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class CompressorCfg:
    rank: int = 4
    sweeps: int = 2
    min_size: int = 65_536       # smaller leaves go through exact mp-allreduce
    max_order: int = 4           # flatten higher-order leaves down to this
    prec: str | Precision = "bf16"   # wire/storage policy for collectives
    ef_dtype: str = "float32"    # error-feedback buffer dtype
    bucket: bool = True          # batch same-view leaves through ONE
    #                              hopm3_batched chain per bucket (same
    #                              iterates as the per-leaf loop; False
    #                              forces the per-leaf reference path)


def _eligible(shape, cfg: CompressorCfg) -> bool:
    return len(shape) >= 2 and math.prod(shape) >= cfg.min_size


def _tensor_view(shape, cfg: CompressorCfg):
    """Flatten leading dims so order <= max_order (keeps the trailing matmul
    dims intact: those carry the low-rank structure)."""
    if len(shape) <= cfg.max_order:
        return tuple(shape)
    lead = math.prod(shape[: len(shape) - cfg.max_order + 1])
    return (lead,) + tuple(shape[len(shape) - cfg.max_order + 1:])


def init_state(params, cfg: CompressorCfg, seed: int = 0,
               stack: int | None = None):
    """Factor vectors (warm start) + error-feedback buffers, per leaf.
    ``stack``: leading DP-axis dim for the per-rank error buffers (the
    buffers are genuinely rank-local state; outside shard_map they live
    stacked and sharded over the DP axis)."""
    def leaf(path, p):
        if not _eligible(p.shape, cfg):
            return {}
        vshape = _tensor_view(p.shape, cfg)
        key = jax.random.PRNGKey((seed + hash(str(path))) % (2 ** 31))
        keys = jax.random.split(key, cfg.rank * len(vshape))
        xs = []
        i = 0
        for _ in range(cfg.rank):
            vecs = []
            for n in vshape:
                v = jax.random.normal(keys[i], (n,), F32)
                vecs.append(v / jnp.linalg.norm(v))
                i += 1
            xs.append(tuple(vecs))
        eshape = ((stack,) if stack else ()) + tuple(p.shape)
        return {
            "xs": tuple(xs),
            "e": jnp.zeros(eshape, jnp.dtype(cfg.ef_dtype)),
        }

    return jax.tree_util.tree_map_with_path(
        leaf, params, is_leaf=lambda x: hasattr(x, "shape"))


def wire_bytes_summary(params, cfg: CompressorCfg, p_dp: int) -> dict:
    """Analytic wire traffic per step (per device): compressed vs dense.
    Uses the same size-based ring/doubling dispatch as ``mp_allreduce``
    (``coll.allreduce_algo``), so the accounting matches the runtime
    schedule."""
    prec = get_policy(cfg.prec)
    dense = compressed = 0
    for leaf in jax.tree.leaves(params):
        n = math.prod(leaf.shape)
        dense += coll.wire_bytes_allreduce(n, p_dp, prec.storage_bytes,
                                           coll.allreduce_algo(n, p_dp))
        if _eligible(leaf.shape, cfg):
            vshape = _tensor_view(leaf.shape, cfg)
            vec = sum(vshape)
            compressed += (cfg.rank * cfg.sweeps
                           * coll.wire_bytes_allreduce(
                               vec, p_dp, prec.storage_bytes,
                               coll.allreduce_algo(vec, p_dp)))
        else:
            compressed += coll.wire_bytes_allreduce(
                n, p_dp, prec.storage_bytes, coll.allreduce_algo(n, p_dp))
    return {"dense_bytes": dense, "compressed_bytes": compressed,
            "ratio": dense / max(1, compressed)}


def _rank1_outer(xs, lam):
    out = reduce(jnp.multiply.outer, [x.astype(F32) for x in xs])
    return lam * out


def _compress_leaf(g, s, cfg: CompressorCfg, axis_name: str, prec, p):
    """The per-leaf reference path: rank-r deflation through
    :func:`hopm3_partial`, one chain (and one B=1 launch sequence) per
    leaf."""
    vshape = _tensor_view(g.shape, cfg)
    resid = g.astype(F32) + s["e"].astype(F32)       # error feedback
    resid_v = resid.reshape(vshape)
    approx = jnp.zeros(vshape, F32)
    new_xs = []
    for r in range(cfg.rank):
        xs0 = [x for x in s["xs"][r]]
        # local addend of the deflated global tensor: each rank owns 1/p
        # of the already-extracted components.
        # impl="mulsum": the bitwise-batchable contraction engine, so the
        # bucketed scheduler reproduces this path exactly (see
        # core.tvc._mulsum)
        xs_r, lam = hopm3_partial(
            resid_v - approx / p, xs0, axis_name=axis_name,
            sweeps=cfg.sweeps, impl="mulsum", prec=prec)
        # lam is the magnitude of the GLOBAL sum; each rank reconstructs
        # identically and owns 1/p of it for the mean.
        contrib = _rank1_outer(xs_r, lam)
        approx = approx + contrib
        new_xs.append(tuple(x.astype(F32) for x in xs_r))
    ghat_mean = (approx / p).astype(g.dtype).reshape(g.shape)
    e_new = (resid_v - approx / p).reshape(g.shape)
    return ghat_mean, {"xs": tuple(new_xs), "e": e_new.astype(s["e"].dtype)}


def _compress_bucket(gs, ss, cfg: CompressorCfg, axis_name: str, prec, p):
    """One shape bucket of B >= 2 same-view leaves, stacked and compressed
    through ONE :func:`hopm3_batched` chain per deflation rank — one
    (batched) contraction launch per chain step for the whole bucket
    instead of B per-leaf chains.  The batched walker runs the exact same
    schedule as B per-leaf walkers (stacked delayed reductions dispatch
    their wire algo on the per-leaf vector size), so the unstacked results
    match the per-leaf loop bit for bit whenever the reduction is
    elementwise — psum (storage == compute), recursive doubling, or p == 1;
    only the ring schedule's payload chunking perturbs the last bit (its
    chunk boundaries move when B leaves stack)."""
    B = len(gs)
    vshape = _tensor_view(gs[0].shape, cfg)
    resid_b = jnp.stack([
        (g.astype(F32) + s["e"].astype(F32)).reshape(vshape)
        for g, s in zip(gs, ss)])
    approx_b = jnp.zeros((B,) + tuple(vshape), F32)
    new_xs_b = []
    for r in range(cfg.rank):
        xs0 = [jnp.stack([s["xs"][r][m] for s in ss])
               for m in range(len(vshape))]
        xs_r, lam = hopm3_batched(
            resid_b - approx_b / p, xs0, axis_name=axis_name,
            sweeps=cfg.sweeps, impl="mulsum", prec=prec, partial=True)
        contrib = jax.vmap(_rank1_outer)(xs_r, lam)
        approx_b = approx_b + contrib
        new_xs_b.append([x.astype(F32) for x in xs_r])
    outs = []
    for i, (g, s) in enumerate(zip(gs, ss)):
        ghat_mean = (approx_b[i] / p).astype(g.dtype).reshape(g.shape)
        e_new = (resid_b[i] - approx_b[i] / p).reshape(g.shape)
        new_xs = tuple(
            tuple(new_xs_b[r][m][i] for m in range(len(vshape)))
            for r in range(cfg.rank))
        outs.append((ghat_mean,
                     {"xs": new_xs, "e": e_new.astype(s["e"].dtype)}))
    return outs


def compress_and_sync(grads, state, cfg: CompressorCfg, axis_name: str):
    """grads: local (per-DP-rank) gradient pytree.  Returns
    (synced_mean_grads, new_state, stats).  Must run inside shard_map over
    ``axis_name``.

    With ``cfg.bucket`` (the default) eligible leaves are grouped by their
    ``_tensor_view`` shape (and dtypes), each bucket is stacked, and the
    per-leaf compression loop collapses into one :func:`hopm3_batched` call
    per bucket — one launch per chain step for dozens of gradient leaves.
    Single-leaf buckets keep the per-leaf path.  Bucketed results equal the
    per-leaf loop bitwise whenever the delayed reduction is elementwise
    (psum when storage == compute, recursive doubling, or p == 1); the ring
    schedule's payload chunking moves when B leaves stack, so with a
    low-precision wire on ring-dispatched cells (non-power-of-two p, or
    n_j past the doubling cutoff) the two paths agree only to rounding."""
    prec = get_policy(cfg.prec)
    p = jax.lax.axis_size(axis_name)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_s = treedef.flatten_up_to(state)
    n = len(flat_g)
    out_g, out_s = [None] * n, [None] * n

    buckets: dict = {}   # view-key -> list of leaf indices, in tree order
    for i, (g, s) in enumerate(zip(flat_g, flat_s)):
        if not s:  # exact path: mixed-precision all-reduce (paper §5.5)
            total = coll.mp_allreduce(g, axis_name, prec)
            out_g[i] = (total / p).astype(g.dtype)
            out_s[i] = s
            continue
        key = (_tensor_view(g.shape, cfg), jnp.dtype(g.dtype).name,
               jnp.dtype(s["e"].dtype).name)
        buckets.setdefault(key, []).append(i)

    for idxs in buckets.values():
        if cfg.bucket and len(idxs) > 1:
            results = _compress_bucket(
                [flat_g[i] for i in idxs], [flat_s[i] for i in idxs],
                cfg, axis_name, prec, p)
        else:
            results = [_compress_leaf(flat_g[i], flat_s[i], cfg, axis_name,
                                      prec, p) for i in idxs]
        for i, (ghat, new_s) in zip(idxs, results):
            out_g[i] = ghat
            out_s[i] = new_s

    new_grads = jax.tree_util.tree_unflatten(treedef, out_g)
    new_state = jax.tree_util.tree_unflatten(treedef, out_s)
    return new_grads, new_state, {}
