"""dHOPM_3 gradient compression — the paper's algorithm on the training
critical path (DESIGN.md §3).

Data-parallel gradient sync is exactly the paper's Eq. (2) setting: every DP
rank holds one full-size addend of G = Σ_p G^(p).  TVC linearity means
dHOPM_3's local chains + *delayed* n_j-sized all-reduces compute the exact
HOPM iterates of the *global* gradient while the wire carries only factor
vectors.  Rank-r via deflation; PowerSGD-style error feedback keeps the
compression unbiased-in-the-limit; warm-started factors amortize sweeps.

Per tensor of shape (n_0..n_{d-1}) and rank r, wire cost per step:
    r * sweeps * Σ_j n_j   (+ exact mp-allreduce for small/1-D leaves)
vs the dense Σ_j Π n_i all-reduce.

All functions run inside a shard_map manual region over the DP axis.
"""
from __future__ import annotations

import dataclasses
import math
from functools import reduce

import jax
import jax.numpy as jnp

from repro.core.dhopm import hopm3_partial
from repro.core.mixed_precision import F32 as PREC_F32, Precision, get_policy
from repro.dist import collectives as coll

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class CompressorCfg:
    rank: int = 4
    sweeps: int = 2
    min_size: int = 65_536       # smaller leaves go through exact mp-allreduce
    max_order: int = 4           # flatten higher-order leaves down to this
    prec: str | Precision = "bf16"   # wire/storage policy for collectives
    ef_dtype: str = "float32"    # error-feedback buffer dtype


def _eligible(shape, cfg: CompressorCfg) -> bool:
    return len(shape) >= 2 and math.prod(shape) >= cfg.min_size


def _tensor_view(shape, cfg: CompressorCfg):
    """Flatten leading dims so order <= max_order (keeps the trailing matmul
    dims intact: those carry the low-rank structure)."""
    if len(shape) <= cfg.max_order:
        return tuple(shape)
    lead = math.prod(shape[: len(shape) - cfg.max_order + 1])
    return (lead,) + tuple(shape[len(shape) - cfg.max_order + 1:])


def init_state(params, cfg: CompressorCfg, seed: int = 0,
               stack: int | None = None):
    """Factor vectors (warm start) + error-feedback buffers, per leaf.
    ``stack``: leading DP-axis dim for the per-rank error buffers (the
    buffers are genuinely rank-local state; outside shard_map they live
    stacked and sharded over the DP axis)."""
    def leaf(path, p):
        if not _eligible(p.shape, cfg):
            return {}
        vshape = _tensor_view(p.shape, cfg)
        key = jax.random.PRNGKey((seed + hash(str(path))) % (2 ** 31))
        keys = jax.random.split(key, cfg.rank * len(vshape))
        xs = []
        i = 0
        for _ in range(cfg.rank):
            vecs = []
            for n in vshape:
                v = jax.random.normal(keys[i], (n,), F32)
                vecs.append(v / jnp.linalg.norm(v))
                i += 1
            xs.append(tuple(vecs))
        eshape = ((stack,) if stack else ()) + tuple(p.shape)
        return {
            "xs": tuple(xs),
            "e": jnp.zeros(eshape, jnp.dtype(cfg.ef_dtype)),
        }

    return jax.tree_util.tree_map_with_path(
        leaf, params, is_leaf=lambda x: hasattr(x, "shape"))


def wire_bytes_summary(params, cfg: CompressorCfg, p_dp: int) -> dict:
    """Analytic wire traffic per step (per device): compressed vs dense.
    Uses the same size-based ring/doubling dispatch as ``mp_allreduce``
    (``coll.allreduce_algo``), so the accounting matches the runtime
    schedule."""
    prec = get_policy(cfg.prec)
    dense = compressed = 0
    for leaf in jax.tree.leaves(params):
        n = math.prod(leaf.shape)
        dense += coll.wire_bytes_allreduce(n, p_dp, prec.storage_bytes,
                                           coll.allreduce_algo(n, p_dp))
        if _eligible(leaf.shape, cfg):
            vshape = _tensor_view(leaf.shape, cfg)
            vec = sum(vshape)
            compressed += (cfg.rank * cfg.sweeps
                           * coll.wire_bytes_allreduce(
                               vec, p_dp, prec.storage_bytes,
                               coll.allreduce_algo(vec, p_dp)))
        else:
            compressed += coll.wire_bytes_allreduce(
                n, p_dp, prec.storage_bytes, coll.allreduce_algo(n, p_dp))
    return {"dense_bytes": dense, "compressed_bytes": compressed,
            "ratio": dense / max(1, compressed)}


def _rank1_outer(xs, lam):
    out = reduce(jnp.multiply.outer, [x.astype(F32) for x in xs])
    return lam * out


def compress_and_sync(grads, state, cfg: CompressorCfg, axis_name: str):
    """grads: local (per-DP-rank) gradient pytree.  Returns
    (synced_mean_grads, new_state, stats).  Must run inside shard_map over
    ``axis_name``."""
    prec = get_policy(cfg.prec)
    p = jax.lax.axis_size(axis_name)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_s = treedef.flatten_up_to(state)
    out_g, out_s = [], []
    for g, s in zip(flat_g, flat_s):
        if not s:  # exact path: mixed-precision all-reduce (paper §5.5)
            total = coll.mp_allreduce(g, axis_name, prec)
            out_g.append((total / p).astype(g.dtype))
            out_s.append(s)
            continue
        vshape = _tensor_view(g.shape, cfg)
        resid = g.astype(F32) + s["e"].astype(F32)       # error feedback
        resid_v = resid.reshape(vshape)
        approx = jnp.zeros(vshape, F32)
        new_xs = []
        for r in range(cfg.rank):
            xs0 = [x for x in s["xs"][r]]
            # local addend of the deflated global tensor: each rank owns 1/p
            # of the already-extracted components.
            xs_r, lam = hopm3_partial(
                resid_v - approx / p, xs0, axis_name=axis_name,
                sweeps=cfg.sweeps, impl="native", prec=prec)
            # lam is the magnitude of the GLOBAL sum; each rank reconstructs
            # identically and owns 1/p of it for the mean.
            contrib = _rank1_outer(xs_r, lam)
            approx = approx + contrib
            new_xs.append(tuple(x.astype(F32) for x in xs_r))
        ghat_mean = (approx / p).astype(g.dtype).reshape(g.shape)
        e_new = (resid_v - approx / p).reshape(g.shape)
        out_g.append(ghat_mean)
        out_s.append({"xs": tuple(new_xs), "e": e_new.astype(s["e"].dtype)})

    new_grads = jax.tree_util.tree_unflatten(treedef, out_g)
    new_state = jax.tree_util.tree_unflatten(treedef, out_s)
    return new_grads, new_state, {}
