"""dHOPM_3 gradient compression — the paper's algorithm on the training
critical path (DESIGN.md §3).

Data-parallel gradient sync is exactly the paper's Eq. (2) setting: every DP
rank holds one full-size addend of G = Σ_p G^(p).  TVC linearity means
dHOPM_3's local chains + *delayed* n_j-sized all-reduces compute the exact
HOPM iterates of the *global* gradient while the wire carries only factor
vectors.  Rank-r via deflation; PowerSGD-style error feedback keeps the
compression unbiased-in-the-limit; warm-started factors amortize sweeps.

Per tensor of shape (n_0..n_{d-1}) and rank r, wire cost per step:
    r * sweeps * Σ_j n_j   (+ exact mp-allreduce for small/1-D leaves)
vs the dense Σ_j Π n_i all-reduce.

All functions run inside a shard_map manual region over the DP axis.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from functools import reduce

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import arena as arena_mod
from repro.core import memory_model as mm
from repro.core.bucketing import tensor_view
from repro.core.dhopm import (
    hopm3_batched,
    hopm3_partial,
    hopm3_sharded,
    hopm_init_factors,
)
from repro.core.mixed_precision import Precision, get_policy
from repro.dist import collectives as coll

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class CompressorCfg:
    rank: int = 4
    sweeps: int = 2
    min_size: int = 65_536       # smaller leaves go through exact mp-allreduce
    max_order: int = 4           # flatten higher-order leaves down to this
    prec: str | Precision = "bf16"   # wire/storage policy for collectives
    ef_dtype: str = "float32"    # error-feedback buffer dtype
    impl: str = "auto"           # contraction engine for the HOPM chains;
    #                              "auto" routes through the planner, which
    #                              pins the bitwise-batchable mulsum engine
    #                              on every backend (the bucketed==per-leaf
    #                              guarantee is engine-order-dependent)
    bucket: bool | str = "auto"  # batch same-view leaves through ONE
    #                              hopm3_batched chain per bucket (same
    #                              iterates as the per-leaf loop; False
    #                              forces the per-leaf reference path;
    #                              "auto" asks the planner's
    #                              launch-amortization model per bucket)
    arena: bool | str = "auto"   # bucket assembly: scatter rows into the
    #                              batched-operand arena layout
    #                              (repro.core.arena.assemble_rows — an
    #                              in-place dynamic-update-slice chain,
    #                              value-identical to jnp.stack but with no
    #                              concatenate in the jaxpr, so a donated
    #                              train step writes bucket rows in place)
    #                              instead of the jnp.stack round trip.
    #                              "auto" asks the planner
    #                              (plan_compress(...).arena: on for
    #                              bucketed B > 1 groups, off for singleton
    #                              buckets / shape churn / disabled plans)
    splits: tuple[tuple[str, int], ...] = ()
    #   1-D split annotations: (leaf path string -> split dim in *view*
    #   coordinates).  An annotated leaf is a per-rank SLICE of an
    #   already-summed global gradient along that dim (ZeRO-style sharded
    #   leaf) rather than an Eq. 2 partial summand; its chains run the
    #   paper's Algorithm 1 split schedule (hopm3_sharded / the split-aware
    #   batched walker) and its factors live at GLOBAL extents.
    split_world: int = 1
    #   shard count along the split axis (== the DP axis size at runtime;
    #   needed statically by init_state/wire accounting to size global
    #   factor vectors).


def _engine(cfg: CompressorCfg) -> str:
    """The chain engine for this compressor — ``cfg.impl`` verbatim, or the
    planner's pick for ``"auto"`` (pinned to the bitwise-batchable
    ``mulsum``; see :func:`repro.plan.planner.plan_compress`)."""
    if cfg.impl != "auto":
        return cfg.impl
    from repro.plan import planner
    return planner.plan_compress(1, (1, 1)).impl


def _use_bucket(cfg: CompressorCfg, b: int, view, itemsize: int) -> bool:
    """Resolve the per-bucket batching decision (explicit flag wins;
    ``"auto"`` asks the launch-amortization model)."""
    if cfg.bucket != "auto":
        return bool(cfg.bucket)
    from repro.plan import planner
    return planner.plan_compress(b, view, itemsize=itemsize).bucket


def _use_arena(cfg: CompressorCfg, b: int, view, itemsize: int) -> bool:
    """Resolve the bucket-assembly decision (explicit flag wins; ``"auto"``
    asks the planner — arena for bucketed B > 1 groups, stack otherwise)."""
    if cfg.arena != "auto":
        return bool(cfg.arena)
    from repro.plan import planner
    return planner.plan_compress(b, view, itemsize=itemsize).arena


def _assemble(rows, use_arena: bool):
    """Bucket-assembly seam: the arena's in-place scatter discipline or the
    legacy ``jnp.stack`` — bitwise-identical contents either way."""
    if use_arena:
        return arena_mod.assemble_rows(rows)
    return jnp.stack(rows)


def _gather_warm_factors(ss, cfg: CompressorCfg, nmodes: int,
                         use_arena: bool):
    """ONE per-bucket gather of every deflation rank's warm-start factors:
    ``(rank, B, n_m)`` per mode, sliced per rank inside the deflation loop.
    Only the residual changes between ranks, so re-gathering d ``(B, n_m)``
    factor stacks on every rank (the old per-rank ``jnp.stack``) was pure
    repeated assembly — hoisting it prices the factor gather ONCE per step
    (the ``ranks`` term of
    :func:`repro.core.memory_model.bucket_stack_elems`)."""
    B = len(ss)
    out = []
    for m in range(nmodes):
        flat = _assemble([s["xs"][r][m] for r in range(cfg.rank)
                          for s in ss], use_arena)
        out.append(flat.reshape((cfg.rank, B) + flat.shape[1:]))
    return out


def _split_for(path_str: str, cfg: CompressorCfg) -> int | None:
    for key, s_dim in cfg.splits:
        if key == path_str:
            return s_dim
    return None


def _eligible(shape, cfg: CompressorCfg, split: int | None = None) -> bool:
    n = math.prod(shape) * (cfg.split_world if split is not None else 1)
    return len(shape) >= 2 and n >= cfg.min_size


def _tensor_view(shape, cfg: CompressorCfg):
    """Bucketing view of a leaf (shared rule: :mod:`repro.core.bucketing` —
    the serve engine's KV compression groups under the same one)."""
    return tensor_view(shape, cfg.max_order)


def _factor_view(local_vshape, cfg: CompressorCfg, split: int | None):
    """Factor-vector extents for a leaf: the local view, with the split dim
    scaled to its GLOBAL extent (a split leaf's factors span the whole
    tensor; only its slice of dim ``split`` is local)."""
    if split is None:
        return tuple(local_vshape)
    if not 0 <= split < len(local_vshape):
        raise ValueError(
            f"split dim {split} out of range for view {tuple(local_vshape)}")
    return tuple(n * cfg.split_world if m == split else n
                 for m, n in enumerate(local_vshape))


def init_state(params, cfg: CompressorCfg, seed: int = 0,
               stack: int | None = None):
    """Factor vectors (warm start) + error-feedback buffers, per leaf.
    ``stack``: leading DP-axis dim for the per-rank error buffers (the
    buffers are genuinely rank-local state; outside shard_map they live
    stacked and sharded over the DP axis).  Leaves annotated in
    ``cfg.splits`` get GLOBAL-extent factors along their split dim
    (:func:`_factor_view`); their error buffers stay local-shard shaped.

    Seeding is ``zlib.crc32`` of the leaf path — NOT Python ``hash``, whose
    string hashing is salted per process (``PYTHONHASHSEED``): salted seeds
    would draw different warm-start factors on every host/restart, silently
    breaking multi-host reproducibility and any resume-from-checkpoint
    comparison (the same bug class as the decode-batch flake fixed in the
    model smoke tests)."""
    def leaf(path, p):
        s_dim = _split_for(jax.tree_util.keystr(path), cfg)
        if not _eligible(p.shape, cfg, s_dim):
            return {}
        vshape = _factor_view(_tensor_view(p.shape, cfg), cfg, s_dim)
        key = jax.random.PRNGKey(
            (seed + zlib.crc32(jax.tree_util.keystr(path).encode()))
            % (2 ** 31))
        xs = hopm_init_factors(key, vshape, rank=cfg.rank)
        eshape = ((stack,) if stack else ()) + tuple(p.shape)
        return {
            "xs": tuple(xs),
            "e": jnp.zeros(eshape, jnp.dtype(cfg.ef_dtype)),
        }

    return jax.tree_util.tree_map_with_path(
        leaf, params, is_leaf=lambda x: hasattr(x, "shape"))


def wire_bytes_summary(params, cfg: CompressorCfg, p_dp: int) -> dict:
    """Analytic wire traffic per step (per device): compressed vs dense.

    The compressed path is priced at the *per-sweep ordering the runtime
    actually uses* (:func:`repro.core.memory_model.dhopm_wire_bytes_sweep`):
    one n_j-sized collective per external iteration, its ring/doubling
    schedule dispatched on each n_j separately — NOT one dispatch on the
    concatenated Σ n_j vector, whose algo choice can differ from every
    per-iteration choice and mis-price the wire.  Split-annotated leaves
    (``cfg.splits``) swap the j == split iteration's all-reduce for the
    Eq. 1 all-gather of the n_j/p slice, and their dense baseline is the
    all-gather that would assemble the sharded gradient.  The closed form
    is regression-tested against a counted trace of the runtime's
    collective calls (``_dist_checks``).

    Alongside the wire, the summary prices the LOCAL bucket-assembly copy
    traffic per step (satellite of the arena work):
    ``assembly_stack_bytes`` is what the legacy ``jnp.stack`` path pays to
    assemble every bucketed group (F32 assembly;
    :func:`repro.core.memory_model.bucket_stack_elems` with the compressor's
    deflation rank — residual stack plus the hoisted once-per-step factor
    gather), ``assembly_bytes`` is what the *resolved* path pays (arena
    buckets scatter in place: a warm fill adds zero copy elements), and
    ``stack_copy_removed_bytes`` is the difference.  The stack closed form
    is regression-tested against counted ``concatenate`` traffic in the
    traced jaxpr (``tests/test_arena.py``)."""
    prec = get_policy(cfg.prec)
    dense = compressed = 0
    buckets: dict = {}   # mirror of compress_and_sync's grouping rule
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        s_dim = _split_for(jax.tree_util.keystr(path), cfg)
        n = math.prod(leaf.shape)
        if s_dim is None:
            dense += coll.wire_bytes_allreduce(
                n, p_dp, prec.storage_bytes, coll.allreduce_algo(n, p_dp))
        else:
            # sharded leaf: the dense baseline assembles the global tensor
            dense += coll.wire_bytes_allgather(
                n * cfg.split_world, p_dp, prec.storage_bytes)
        if _eligible(leaf.shape, cfg, s_dim):
            vshape = _factor_view(_tensor_view(leaf.shape, cfg), cfg, s_dim)
            compressed += (cfg.rank * cfg.sweeps
                           * mm.dhopm_wire_bytes_sweep(
                               vshape, p_dp, prec.storage_bytes,
                               split=s_dim))
            bkey = (_tensor_view(leaf.shape, cfg),
                    jnp.dtype(leaf.dtype).name, s_dim)
            buckets[bkey] = buckets.get(bkey, 0) + 1
        elif s_dim is None:
            compressed += coll.wire_bytes_allreduce(
                n, p_dp, prec.storage_bytes, coll.allreduce_algo(n, p_dp))
        # ineligible split leaves are already-synced shards: no wire at all
    assembly_stack = assembly = 0
    for (view, dname, s_dim), b in buckets.items():
        isz = jnp.dtype(dname).itemsize
        if b > 1 and _use_bucket(cfg, b, view, isz):
            # assembly runs in F32 (error feedback accumulates in F32)
            e = mm.bucket_stack_elems(b, view, ranks=cfg.rank) * 4
            assembly_stack += e
            if not _use_arena(cfg, b, view, isz):
                assembly += e   # warm arena fills add zero copy elements
    return {"dense_bytes": dense, "compressed_bytes": compressed,
            "ratio": dense / max(1, compressed),
            "assembly_stack_bytes": assembly_stack,
            "assembly_bytes": assembly,
            "stack_copy_removed_bytes": assembly_stack - assembly}


def _rank1_outer(xs, lam):
    out = reduce(jnp.multiply.outer, [x.astype(F32) for x in xs])
    return lam * out


def _compress_leaf(g, s, cfg: CompressorCfg, axis_name: str, prec, p):
    """The per-leaf reference path: rank-r deflation through
    :func:`hopm3_partial`, one chain (and one B=1 launch sequence) per
    leaf."""
    vshape = _tensor_view(g.shape, cfg)
    resid = g.astype(F32) + s["e"].astype(F32)       # error feedback
    resid_v = resid.reshape(vshape)
    approx = jnp.zeros(vshape, F32)
    new_xs = []
    for r in range(cfg.rank):
        xs0 = [x for x in s["xs"][r]]
        # local addend of the deflated global tensor: each rank owns 1/p
        # of the already-extracted components.
        # the engine resolves to the bitwise-batchable mulsum, so the
        # bucketed scheduler reproduces this path exactly (see
        # core.tvc._mulsum)
        xs_r, lam = hopm3_partial(
            resid_v - approx / p, xs0, axis_name=axis_name,
            sweeps=cfg.sweeps, impl=_engine(cfg), prec=prec)
        # lam is the magnitude of the GLOBAL sum; each rank reconstructs
        # identically and owns 1/p of it for the mean.
        contrib = _rank1_outer(xs_r, lam)
        approx = approx + contrib
        new_xs.append(tuple(x.astype(F32) for x in xs_r))
    ghat_mean = (approx / p).astype(g.dtype).reshape(g.shape)
    e_new = (resid_v - approx / p).reshape(g.shape)
    return ghat_mean, {"xs": tuple(new_xs), "e": e_new.astype(s["e"].dtype)}


def _local_factors(xs, s_dim: int, chunk: int, axis_name: str):
    """Slice the split dim's GLOBAL factor vector(s) to this process's
    range (rank-1 reconstruction of a split leaf touches only the local
    slice).  Works for both (n,) per-leaf and (B, n) stacked factors — the
    slice rides on the last axis."""
    idx = lax.axis_index(axis_name)
    return [x if m != s_dim else
            lax.dynamic_slice_in_dim(x, idx * chunk, chunk, axis=x.ndim - 1)
            for m, x in enumerate(xs)]


def _compress_leaf_split(g, s, cfg: CompressorCfg, axis_name: str, prec, p,
                         s_dim: int):
    """Per-leaf reference path for a *split-annotated* leaf: ``g`` is this
    rank's slice (along view dim ``s_dim``) of an already-summed global
    gradient, so the deflation chains run the paper's Algorithm 1 split
    schedule (:func:`hopm3_sharded` — Eq. 2 slice path at the split mode,
    one delayed n_j collective per external iteration, all-gather at
    j == split).  The returned gradient is the compressed LOCAL slice (no
    1/p mean — the values are already global), and error feedback stays
    rank-local on the slice."""
    vshape = _tensor_view(g.shape, cfg)
    resid = g.astype(F32) + s["e"].astype(F32)       # error feedback
    resid_v = resid.reshape(vshape)
    approx = jnp.zeros(vshape, F32)
    new_xs = []
    for r in range(cfg.rank):
        xs0 = [x for x in s["xs"][r]]
        xs_r, lam = hopm3_sharded(
            resid_v - approx, xs0, axis_name=axis_name, split=s_dim,
            sweeps=cfg.sweeps, impl=_engine(cfg), prec=prec)
        loc = _local_factors(xs_r, s_dim, vshape[s_dim], axis_name)
        approx = approx + _rank1_outer(loc, lam)
        new_xs.append(tuple(x.astype(F32) for x in xs_r))
    ghat = approx.astype(g.dtype).reshape(g.shape)
    e_new = (resid_v - approx).reshape(g.shape)
    return ghat, {"xs": tuple(new_xs), "e": e_new.astype(s["e"].dtype)}


def _compress_bucket_split(gs, ss, cfg: CompressorCfg, axis_name: str, prec,
                           p, s_dim: int, use_arena: bool = False):
    """One bucket of B >= 2 same-view *split-annotated* leaves, assembled
    (arena scatter or stack — bitwise-identical contents) and compressed
    through ONE split-aware :func:`hopm3_batched` chain per
    deflation rank — the batched walker runs the identical Algorithm 1
    schedule as B per-leaf :func:`hopm3_sharded` chains (stacked Eq. 2
    slices, stacked delayed reductions dispatched on the per-leaf n_j,
    stacked j == split all-gather), so the unstacked results match the
    per-leaf loop bit for bit under the ``mulsum`` engine whenever the
    reduction is elementwise (psum when storage == compute, recursive
    doubling, or p == 1) — the same guarantee as the partial-mode buckets."""
    B = len(gs)
    vshape = _tensor_view(gs[0].shape, cfg)
    resid_b = _assemble([
        (g.astype(F32) + s["e"].astype(F32)).reshape(vshape)
        for g, s in zip(gs, ss)], use_arena)
    approx_b = jnp.zeros((B,) + tuple(vshape), F32)
    xs_all = _gather_warm_factors(ss, cfg, len(vshape), use_arena)
    new_xs_b = []
    for r in range(cfg.rank):
        xs0 = [xs_all[m][r] for m in range(len(vshape))]
        xs_r, lam = hopm3_batched(
            resid_b - approx_b, xs0, axis_name=axis_name, split=s_dim,
            sweeps=cfg.sweeps, impl=_engine(cfg), prec=prec)
        loc = _local_factors(xs_r, s_dim, vshape[s_dim], axis_name)
        approx_b = approx_b + jax.vmap(_rank1_outer)(loc, lam)
        new_xs_b.append([x.astype(F32) for x in xs_r])
    outs = []
    for i, (g, s) in enumerate(zip(gs, ss)):
        ghat = approx_b[i].astype(g.dtype).reshape(g.shape)
        e_new = (resid_b[i] - approx_b[i]).reshape(g.shape)
        new_xs = tuple(
            tuple(new_xs_b[r][m][i] for m in range(len(vshape)))
            for r in range(cfg.rank))
        outs.append((ghat, {"xs": new_xs, "e": e_new.astype(s["e"].dtype)}))
    return outs


def _compress_bucket(gs, ss, cfg: CompressorCfg, axis_name: str, prec, p,
                     use_arena: bool = False):
    """One shape bucket of B >= 2 same-view leaves, assembled (arena
    scatter or stack — bitwise-identical contents) and compressed
    through ONE :func:`hopm3_batched` chain per deflation rank — one
    (batched) contraction launch per chain step for the whole bucket
    instead of B per-leaf chains.  The batched walker runs the exact same
    schedule as B per-leaf walkers (stacked delayed reductions dispatch
    their wire algo on the per-leaf vector size), so the unstacked results
    match the per-leaf loop bit for bit whenever the reduction is
    elementwise — psum (storage == compute), recursive doubling, or p == 1;
    only the ring schedule's payload chunking perturbs the last bit (its
    chunk boundaries move when B leaves stack)."""
    B = len(gs)
    vshape = _tensor_view(gs[0].shape, cfg)
    resid_b = _assemble([
        (g.astype(F32) + s["e"].astype(F32)).reshape(vshape)
        for g, s in zip(gs, ss)], use_arena)
    approx_b = jnp.zeros((B,) + tuple(vshape), F32)
    xs_all = _gather_warm_factors(ss, cfg, len(vshape), use_arena)
    new_xs_b = []
    for r in range(cfg.rank):
        xs0 = [xs_all[m][r] for m in range(len(vshape))]
        xs_r, lam = hopm3_batched(
            resid_b - approx_b / p, xs0, axis_name=axis_name,
            sweeps=cfg.sweeps, impl=_engine(cfg), prec=prec, partial=True)
        contrib = jax.vmap(_rank1_outer)(xs_r, lam)
        approx_b = approx_b + contrib
        new_xs_b.append([x.astype(F32) for x in xs_r])
    outs = []
    for i, (g, s) in enumerate(zip(gs, ss)):
        ghat_mean = (approx_b[i] / p).astype(g.dtype).reshape(g.shape)
        e_new = (resid_b[i] - approx_b[i] / p).reshape(g.shape)
        new_xs = tuple(
            tuple(new_xs_b[r][m][i] for m in range(len(vshape)))
            for r in range(cfg.rank))
        outs.append((ghat_mean,
                     {"xs": new_xs, "e": e_new.astype(s["e"].dtype)}))
    return outs


def compress_and_sync(grads, state, cfg: CompressorCfg, axis_name: str):
    """grads: local (per-DP-rank) gradient pytree.  Returns
    (synced_mean_grads, new_state, stats).  Must run inside shard_map over
    ``axis_name``.

    With ``cfg.bucket`` (the default) eligible leaves are grouped by their
    ``_tensor_view`` shape (and dtypes, and split annotation), each bucket
    is stacked, and the per-leaf compression loop collapses into one
    :func:`hopm3_batched` call per bucket — one launch per chain step for
    dozens of gradient leaves.  Single-leaf buckets keep the per-leaf path.
    Bucketed results equal the per-leaf loop bitwise whenever the delayed
    reduction is elementwise (psum when storage == compute, recursive
    doubling, or p == 1); the ring schedule's payload chunking moves when B
    leaves stack, so with a low-precision wire on ring-dispatched cells
    (non-power-of-two p, or n_j past the doubling cutoff) the two paths
    agree only to rounding.

    Leaves annotated in ``cfg.splits`` are per-rank *slices* of
    already-summed global gradients (ZeRO-style): their buckets route
    through the split-aware batched walker
    (:func:`_compress_bucket_split` / :func:`_compress_leaf_split`), and
    ineligible split leaves pass through untouched (they are already
    synced — an all-reduce would double-count the shards)."""
    prec = get_policy(cfg.prec)
    p = jax.lax.axis_size(axis_name)

    flat_wp, treedef = jax.tree_util.tree_flatten_with_path(grads)
    paths = [jax.tree_util.keystr(pth) for pth, _ in flat_wp]
    flat_g = [g for _, g in flat_wp]
    flat_s = treedef.flatten_up_to(state)
    n = len(flat_g)
    out_g, out_s = [None] * n, [None] * n

    buckets: dict = {}   # (view, dtypes, split)-key -> leaf indices, in order
    for i, (g, s) in enumerate(zip(flat_g, flat_s)):
        s_dim = _split_for(paths[i], cfg)
        if not s:
            if s_dim is not None:
                # already-synced shard of a global gradient: nothing to do
                out_g[i] = g
                out_s[i] = s
                continue
            # exact path: mixed-precision all-reduce (paper §5.5)
            total = coll.mp_allreduce(g, axis_name, prec)
            out_g[i] = (total / p).astype(g.dtype)
            out_s[i] = s
            continue
        key = (_tensor_view(g.shape, cfg), jnp.dtype(g.dtype).name,
               jnp.dtype(s["e"].dtype).name, s_dim)
        buckets.setdefault(key, []).append(i)

    for key, idxs in buckets.items():
        s_dim = key[-1]
        gs = [flat_g[i] for i in idxs]
        ss = [flat_s[i] for i in idxs]
        if len(idxs) > 1 and _use_bucket(cfg, len(idxs), key[0],
                                         jnp.dtype(key[1]).itemsize):
            use_arena = _use_arena(cfg, len(idxs), key[0],
                                   jnp.dtype(key[1]).itemsize)
            if s_dim is None:
                results = _compress_bucket(gs, ss, cfg, axis_name, prec, p,
                                           use_arena)
            else:
                results = _compress_bucket_split(gs, ss, cfg, axis_name,
                                                 prec, p, s_dim, use_arena)
        elif s_dim is None:
            results = [_compress_leaf(g, s, cfg, axis_name, prec, p)
                       for g, s in zip(gs, ss)]
        else:
            results = [_compress_leaf_split(g, s, cfg, axis_name, prec, p,
                                            s_dim) for g, s in zip(gs, ss)]
        for i, (ghat, new_s) in zip(idxs, results):
            out_g[i] = ghat
            out_s[i] = new_s

    new_grads = jax.tree_util.tree_unflatten(treedef, out_g)
    new_state = jax.tree_util.tree_unflatten(treedef, out_s)
    return new_grads, new_state, {}
