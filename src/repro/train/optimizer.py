"""Optimizers (built here, no optax): AdamW with fp32 state, and an
Adafactor-style factored-second-moment mode so the 405B/1T configs' optimizer
state fits in 16 GB/chip (see DESIGN.md §6).  Pure functions over pytrees;
state shards like the params (GSPMD propagates the param specs)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"          # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    min_lr_frac: float = 0.1
    # adafactor specifics
    factored_min_dim: int = 128
    clip_rms: float = 1.0


def schedule(cfg: OptConfig, step):
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(F32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def _is_factored(shape, cfg: OptConfig) -> bool:
    return (len(shape) >= 2 and shape[-1] >= cfg.factored_min_dim
            and shape[-2] >= cfg.factored_min_dim)


def init(cfg: OptConfig, params) -> dict:
    def leaf_state(p):
        if cfg.kind == "adamw":
            return {"m": jnp.zeros(p.shape, F32), "v": jnp.zeros(p.shape, F32)}
        if _is_factored(p.shape, cfg):
            return {
                "vr": jnp.zeros(p.shape[:-1], F32),          # row stats
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], F32),  # col stats
            }
        return {"v": jnp.zeros(p.shape, F32)}

    return {
        "step": jnp.zeros((), jnp.int32),
        "leaves": jax.tree.map(leaf_state, params),
    }


def global_norm(tree):
    sq = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(F32))), tree, jnp.zeros((), F32))
    return jnp.sqrt(sq)


def update(cfg: OptConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"]
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else jnp.ones((), F32)
    t = (step + 1).astype(F32)

    def upd(p, g, s):
        g = g.astype(F32) * scale
        if cfg.kind == "adamw":
            m = cfg.b1 * s["m"] + (1 - cfg.b1) * g
            v = cfg.b2 * s["v"] + (1 - cfg.b2) * g * g
            mhat = m / (1 - cfg.b1 ** t)
            vhat = v / (1 - cfg.b2 ** t)
            step_dir = mhat / (jnp.sqrt(vhat) + cfg.eps)
            new_s = {"m": m, "v": v}
        else:  # adafactor (factored RMS, momentum-free)
            b2 = 1.0 - t ** -0.8
            g2 = g * g + 1e-30
            if "vr" in s:
                vr = b2 * s["vr"] + (1 - b2) * g2.mean(axis=-1)
                vc = b2 * s["vc"] + (1 - b2) * g2.mean(axis=-2)
                denom = (vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), 1e-30))[..., None] * vc[..., None, :]
                step_dir = g * jax.lax.rsqrt(jnp.maximum(denom, 1e-30))
                new_s = {"vr": vr, "vc": vc}
            else:
                v = b2 * s["v"] + (1 - b2) * g2
                step_dir = g * jax.lax.rsqrt(jnp.maximum(v, 1e-30))
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(step_dir * step_dir) + 1e-30)
            step_dir = step_dir / jnp.maximum(1.0, rms / cfg.clip_rms)
        new_p = p.astype(F32) - lr * step_dir
        if cfg.weight_decay and p.ndim >= 2:
            new_p = new_p - lr * cfg.weight_decay * p.astype(F32)
        return new_p.astype(p.dtype), new_s

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state["leaves"])
    out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_leaves = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_state = {"step": step + 1, "leaves": new_leaves}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
