"""Train-step builders and the fault-tolerant training loop.

Two step modes:

* ``gspmd`` — pure pjit: params FSDP+TP sharded via the rule tables, the DP
  gradient all-reduce is compiler-inserted.  Default for the >= 70B configs.
* ``dp_explicit`` — the *paper mode*: shard_map manual over the DP axes with
  the model axis left to GSPMD (auto), so gradient synchronization is an
  explicit collective we control — either the §5.5 mixed-precision all-reduce
  or full dHOPM_3 gradient compression (core of the paper integration).

The loop adds: checkpoint/restart (atomic, async, retention), emergency save
on exceptions, a straggler/step-time watchdog, and metrics.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist import collectives as coll
from repro.dist.sharding import named_shardings
from repro.models import registry
from . import checkpoint as ckpt_mod
from . import grad_compress as gc_mod
from . import optimizer as opt_mod

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: opt_mod.OptConfig = dataclasses.field(default_factory=opt_mod.OptConfig)
    mode: str = "gspmd"                 # gspmd | dp_explicit
    compression: Optional[gc_mod.CompressorCfg] = None
    mp_wire: Optional[str] = None       # e.g. "bf16": mixed-precision grad sync
    staged_wire: bool = False           # mp_wire via the staged (resumable)
                                        # collective: leaf hops round-robin so
                                        # wire time can overlap across leaves
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 200
    keep_last: int = 3
    watchdog_factor: float = 3.0        # flag steps slower than factor*median
    warmup: bool = False                # AOT-compile the step on the first
                                        # batch's shapes before the loop (see
                                        # repro.plan.aot; pairs with the
                                        # persistent compilation cache)


def _dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def make_train_step(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig):
    """Returns (step_fn, shardings) where step_fn(params, opt_state,
    comp_state, batch) -> (params, opt_state, comp_state, metrics)."""
    mod = registry.get(cfg.family)

    def loss_fn(params, batch):
        return mod.loss_fn(cfg, params, batch)

    if tcfg.mode == "gspmd":
        def step(params, opt_state, comp_state, batch):
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            params, opt_state, om = opt_mod.update(tcfg.opt, params, grads, opt_state)
            return params, opt_state, comp_state, {"loss": loss, **aux, **om}

        return step, None

    # ---- dp_explicit: fully-manual data parallelism ----------------------
    # Every mesh axis acts as DP (params replicated).  Gradient sync is
    # hierarchical, as on real multi-pod systems: exact psum over the fast
    # secondary axes, then the paper's collective over the PRIMARY (slowest)
    # axis — either the §5.5 mixed-precision all-reduce or full dHOPM_3
    # compression.  (TP+compression composition is future work: partial-auto
    # shard_map + AD currently trips JAX's _unmatch path; see DESIGN.md.)
    all_axes = tuple(mesh.axis_names)
    primary = "pod" if "pod" in all_axes else all_axes[0]
    secondary = tuple(a for a in all_axes if a != primary)
    p_total = 1
    for a in all_axes:
        p_total *= mesh.shape[a]
    p_primary = mesh.shape[primary]

    def step_body(params, opt_state, comp_state, batch):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        if secondary:
            grads = jax.tree.map(lambda g: jax.lax.psum(g, secondary), grads)
        if tcfg.compression is not None:
            comp_local = jax.tree_util.tree_map_with_path(
                lambda pth, v: v[0] if _is_e(pth) else v, comp_state)
            grads, comp_local, _ = gc_mod.compress_and_sync(
                grads, comp_local, tcfg.compression, primary)
            grads = jax.tree.map(
                lambda g: (g * (p_primary / p_total)).astype(g.dtype), grads)
            comp_state = jax.tree_util.tree_map_with_path(
                lambda pth, v: v[None] if _is_e(pth) else v, comp_local)
        elif tcfg.mp_wire is not None:
            if tcfg.staged_wire:
                dtypes = jax.tree.map(lambda g: g.dtype, grads)
                summed = coll.staged_tree_allreduce(
                    grads, primary, tcfg.mp_wire)
                grads = jax.tree.map(
                    lambda g, dt: (g / p_total).astype(dt), summed, dtypes)
            else:
                grads = jax.tree.map(
                    lambda g: (coll.mp_allreduce(g, primary, tcfg.mp_wire)
                               / p_total).astype(g.dtype), grads)
        else:
            grads = jax.tree.map(
                lambda g: jax.lax.psum(g, primary) / p_total, grads)
        loss = jax.lax.pmean(loss, all_axes)
        params, opt_state, om = opt_mod.update(tcfg.opt, params, grads, opt_state)
        return params, opt_state, comp_state, {"loss": loss, **aux, **om}

    def _is_e(path):
        last = path[-1]
        return str(getattr(last, "key", "")) == "e"

    def step(params, opt_state, comp_state, batch):
        batch_specs = jax.tree.map(
            lambda v: P(*((all_axes,) + (None,) * (v.ndim - 1))), batch)
        comp_specs = jax.tree_util.tree_map_with_path(
            lambda pth, v: P(primary) if _is_e(pth) else P(), comp_state)
        fn = jax.shard_map(
            step_body,
            mesh=mesh,
            in_specs=(P(), P(), comp_specs, batch_specs),
            out_specs=(P(), P(), comp_specs, P()),
            check_vma=False,
        )
        return fn(params, opt_state, comp_state, batch)

    return step, None


def setup(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig, rng=None):
    """Init (or restore) params/opt/compressor with proper shardings."""
    mod = registry.get(cfg.family)
    rng = jax.random.PRNGKey(0) if rng is None else rng

    params_shape = jax.eval_shape(lambda k: mod.init(cfg, k), rng)
    if tcfg.mode == "gspmd":
        shardings = named_shardings(cfg, params_shape, mesh)
        params = jax.jit(
            lambda k: mod.init(cfg, k), out_shardings=shardings)(rng)
        comp_state = {}
    else:
        # dp_explicit: params fully replicated (pure/hierarchical DP).
        shardings = jax.tree.map(
            lambda _: NamedSharding(mesh, P()), params_shape)
        params = jax.jit(lambda k: mod.init(cfg, k), out_shardings=shardings)(rng)
        comp_state = {}
        if tcfg.compression is not None:
            primary = "pod" if "pod" in mesh.shape else mesh.axis_names[0]
            comp_state = gc_mod.init_state(
                params, tcfg.compression, stack=mesh.shape[primary])
            comp_state = jax.tree_util.tree_map_with_path(
                lambda pth, v: jax.device_put(v, NamedSharding(
                    mesh, P(primary) if str(getattr(pth[-1], "key", "")) == "e"
                    else P())), comp_state)
    opt_state = opt_mod.init(tcfg.opt, params)
    return params, opt_state, comp_state, shardings


def train(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig, data_iter,
          num_steps: int, *, log_every: int = 10, log=print):
    """The fault-tolerant loop: restore-if-present, periodic async
    checkpoints, emergency save on failure, straggler watchdog."""
    params, opt_state, comp_state, shardings = setup(cfg, mesh, tcfg)
    start_step = 0
    if tcfg.ckpt_dir:
        last = ckpt_mod.latest_step(tcfg.ckpt_dir)
        if last is not None:
            (params, opt_state), manifest = ckpt_mod.restore(
                tcfg.ckpt_dir, (params, opt_state))
            start_step = manifest["step"]
            log(f"[restore] resumed from step {start_step}")

    step_fn, _ = make_train_step(cfg, mesh, tcfg)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1, 2)) \
        if tcfg.mode == "gspmd" else step_fn

    if tcfg.warmup:
        # Peek (not consume) the first batch to learn the step's shapes and
        # AOT-compile before timing starts.  In gspmd mode the jitted step is
        # warmed directly; in dp_explicit the step runs eagerly (shard_map
        # outside jit), so warming a jitted wrapper only seeds the persistent
        # compilation cache — the loop itself still traces on first call.
        from repro.plan import aot
        first = next(data_iter)
        data_iter = itertools.chain([first], data_iter)
        target = step_fn if tcfg.mode == "gspmd" else jax.jit(step_fn)
        rep = aot.warmup(target, params, opt_state, comp_state, first,
                         name=f"train_step_{tcfg.mode}_{cfg.family}")
        log(f"[warmup] train step: {rep['cache']} "
            f"({rep['compile_us'] / 1e3:.1f} ms)")

    times: list[float] = []
    metrics_hist = []
    pending_ckpt = None
    step = start_step
    try:
        for step in range(start_step, num_steps):
            batch = next(data_iter)
            t0 = time.perf_counter()
            params, opt_state, comp_state, metrics = step_fn(
                params, opt_state, comp_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            times.append(dt)
            med = sorted(times)[len(times) // 2]
            if len(times) > 5 and dt > tcfg.watchdog_factor * med:
                log(f"[watchdog] step {step} took {dt:.3f}s "
                    f"(median {med:.3f}s) — straggler suspected")
            if step % log_every == 0:
                log(f"step {step}: loss={float(metrics['loss']):.4f} "
                    f"lr={float(metrics.get('lr', 0)):.2e} {dt*1e3:.0f} ms")
            metrics_hist.append({k: float(v) for k, v in metrics.items()
                                 if jnp.ndim(v) == 0})
            if tcfg.ckpt_dir and (step + 1) % tcfg.ckpt_every == 0:
                _, pending_ckpt = ckpt_mod.save(
                    tcfg.ckpt_dir, step + 1, (params, opt_state),
                    metadata={"arch": cfg.name}, keep_last=tcfg.keep_last,
                    async_write=True)
    except Exception:
        if tcfg.ckpt_dir:
            log(f"[emergency] failure at step {step}; saving state")
            ckpt_mod.save(tcfg.ckpt_dir, step, (params, opt_state),
                          metadata={"arch": cfg.name, "emergency": True},
                          keep_last=tcfg.keep_last + 1)
        raise
    if pending_ckpt is not None:
        pending_ckpt.join()
    if tcfg.ckpt_dir:
        ckpt_mod.save(tcfg.ckpt_dir, num_steps, (params, opt_state),
                      metadata={"arch": cfg.name}, keep_last=tcfg.keep_last)
    return params, opt_state, metrics_hist
