"""Static verification of the repo's structural performance contracts.

The paper's data-movement claims (zero-copy streaming, launch counts
independent of batch size, per-hop mixed-precision wire demotion) are
*statically decidable* from traced jaxprs.  This package turns the one-off
jaxpr asserts the test suite accumulated into a real analyzer:

- :mod:`repro.verify.walker` — the single recursive eqn walker every
  counting check in the repo goes through,
- :mod:`repro.verify.rules` — the rule registry (severity, waivers) with
  expectations recomputed from ``core.memory_model`` closed forms,
- :mod:`repro.verify.entrypoints` — the traced entry points under check,
- ``python -m repro.verify`` — the CLI / CI gate with a JSON report.
"""
from .walker import (  # noqa: F401
    count_named_calls, count_primitive, iter_eqns, primitive_counts,
)
from .rules import Finding, Rule, RULES, load_waivers, run_rules  # noqa: F401
from .entrypoints import ENTRYPOINTS, EntryPoint, get_entrypoints  # noqa: F401
from .report import run_entrypoint, run_verify  # noqa: F401
