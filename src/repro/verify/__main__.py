"""CLI: ``python -m repro.verify`` — the static verification gate.

Examples::

    python -m repro.verify --all
    python -m repro.verify --list
    python -m repro.verify --entry dhopm3_p8_doubling_f32 --json report.json
    python -m repro.verify --tag p8 --real-mesh   # under 8 devices
    python -m repro.verify --all --waivers verify_waivers.json

Exit status is 0 iff every entry point passes (waived findings do not
block; warnings do not block).
"""
from __future__ import annotations

import argparse
import json
import sys

from .entrypoints import get_entrypoints
from .report import run_verify
from .rules import RULES, load_waivers


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.verify",
        description="statically verify kernel/wire/arena contracts "
                    "from traced jaxprs",
    )
    ap.add_argument("--all", action="store_true",
                    help="run every entry point (the default)")
    ap.add_argument("--entry", action="append", default=None,
                    help="run a single entry point (repeatable)")
    ap.add_argument("--tag", action="append", default=None,
                    help="restrict to entry points carrying a tag")
    ap.add_argument("--list", action="store_true",
                    help="list entry points and rules, then exit")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full JSON report to PATH")
    ap.add_argument("--waivers", metavar="PATH",
                    help="JSON waiver file "
                         '([{"entrypoint","rule","reason"}])')
    ap.add_argument("--real-mesh", action="store_true",
                    help="trace p=8 entries over a real device mesh "
                         "(needs >= 8 devices)")
    args = ap.parse_args(argv)

    if args.list:
        print("rules:")
        for r in RULES.values():
            print(f"  {r.rule_id:<22} [{r.severity}] {r.description}")
        print("entry points:")
        for ep in get_entrypoints():
            tags = ",".join(sorted(ep.tags))
            print(f"  {ep.name:<28} [{tags}] rules: {', '.join(ep.rules)}")
        return 0

    waivers = load_waivers(args.waivers) if args.waivers else None
    report = run_verify(args.entry, args.tag, waivers,
                        real_mesh=args.real_mesh)

    for r in report["entrypoints"]:
        mark = "ok  " if r["ok"] else "FAIL"
        print(f"{mark} {r['entrypoint']:<28} rules: {', '.join(r['rules'])}")
        for f in r["findings"]:
            w = " (waived)" if f["waived"] else ""
            print(f"      {f['rule']} [{f['severity']}]{w}: {f['message']}")
    s = report["summary"]
    print(f"{s['entrypoints']} entry points, {s['rules_checked']} rule "
          f"checks, {s['findings']} finding(s), {s['waived']} waived")

    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2)
        print(f"report written to {args.json}")

    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
