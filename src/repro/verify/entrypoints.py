"""The traced entry points the static verifier proves contracts over.

Each entry point builds a :class:`~repro.verify.rules.TraceCtx` — a jaxpr
traced at a representative (d, s, B, overlap, fuse_pairs) point plus the
rule parameters whose expectations the rules recompute from the
``memory_model`` closed forms.

Distributed (p=8) entries trace through ``jax.sharding.AbstractMesh`` by
default, so the full schedule is verified on a single device; the
8-virtual-device distributed suite re-runs the same entries over a real
mesh (``real_mesh=True``) to cover concrete shard_map lowering too.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import dhopm as dh
from repro.core.arena import _scatter_rows, assemble_rows
from repro.core.tvc import tvc, tvc2, tvc_batched
from repro.train import grad_compress as gc

from .rules import TraceCtx

P8 = 8


@dataclasses.dataclass(frozen=True)
class EntryPoint:
    name: str
    build: Callable[..., TraceCtx]
    rules: tuple
    tags: frozenset = frozenset()

    def ctx(self, *, real_mesh: bool = False) -> TraceCtx:
        if "p8" in self.tags:
            return self.build(_mesh(P8, real=real_mesh))
        return self.build()


def _mesh(p: int, *, real: bool = False):
    if real:
        return jax.make_mesh((p,), ("x",))
    return jax.sharding.AbstractMesh((("x", p),))


def _zeros(shape):
    return jnp.zeros(shape, jnp.float32)


def _vecs(shape):
    return [jnp.zeros((n,), jnp.float32) for n in shape]


ENTRYPOINTS: list[EntryPoint] = []


def entrypoint(name, rules, tags=()):
    def deco(fn):
        ENTRYPOINTS.append(
            EntryPoint(name, fn, tuple(rules), frozenset(tags)))
        return fn

    return deco


# ---- TVC kernels: mode-oblivious single launch, zero padding ---------------

@entrypoint("tvc_pallas_m1", ["no_pad", "launch_count"], tags=["kernel"])
def _tvc_m1():
    shape = (8, 6, 16)
    jx = jax.make_jaxpr(
        lambda A, x: tvc(A, x, 1, impl="pallas"))(_zeros(shape), _zeros(6))
    return TraceCtx("tvc_pallas_m1", jx, {"launch": {"kind": "tvc"}})


@entrypoint("tvc_pallas_epilogue", ["no_pad", "launch_count"],
            tags=["kernel"])
def _tvc_epilogue():
    shape = (8, 6, 16)
    y = jnp.ones((8, 16), jnp.float32)
    jx = jax.make_jaxpr(
        lambda A, x, y: tvc(A, x, 1, impl="pallas", alpha=0.5, beta=2.0,
                            y=y))(_zeros(shape), _zeros(6), y)
    return TraceCtx("tvc_pallas_epilogue", jx, {"launch": {"kind": "tvc"}})


@entrypoint("tvc2_pallas_pair", ["no_pad", "launch_count"], tags=["kernel"])
def _tvc2_pair():
    shape = (8, 6, 16)
    jx = jax.make_jaxpr(
        lambda A, x1, x2: tvc2(A, x1, 1, x2, 2, impl="pallas"))(
            _zeros(shape), _zeros(6), _zeros(16))
    return TraceCtx("tvc2_pallas_pair", jx, {"launch": {"kind": "tvc"}})


@entrypoint("tvc_batched_pallas_B8", ["no_pad", "launch_count"],
            tags=["kernel"])
def _tvc_batched():
    shape = (8, 6, 16)
    jx = jax.make_jaxpr(
        lambda A, x: tvc_batched(A, x, 1, impl="pallas"))(
            _zeros((8,) + shape), _zeros((8, 6)))
    return TraceCtx("tvc_batched_pallas_B8", jx, {"launch": {"kind": "tvc"}})


# ---- HOPM3 sweep chains: closed-form launch counts -------------------------

@entrypoint("hopm3_pallas_d4_fused", ["no_pad", "launch_count"],
            tags=["kernel"])
def _hopm3_fused():
    shape = (8, 6, 16, 4)
    jx = jax.make_jaxpr(
        lambda A, *x: dh.hopm3(A, list(x), sweeps=2, impl="pallas",
                               fuse_pairs=True)[0])(
            _zeros(shape), *_vecs(shape))
    return TraceCtx("hopm3_pallas_d4_fused", jx, {
        "pad_scope": "kernel",
        "launch": {"kind": "chain", "d": 4, "s": None,
                   "fuse_pairs": "auto", "sweeps": 2},
    })


@entrypoint("hopm3_mulsum_bitwise", ["mulsum_determinism", "no_stack"],
            tags=["kernel"])
def _hopm3_mulsum():
    shape = (8, 6, 16)
    jx = jax.make_jaxpr(
        lambda A, *x: dh.hopm3(A, list(x), sweeps=2, impl="mulsum")[0])(
            _zeros(shape), *_vecs(shape))
    return TraceCtx("hopm3_mulsum_bitwise", jx, {})


@entrypoint("hopm3_batched_pallas_B5", ["no_pad", "launch_count"],
            tags=["kernel"])
def _hopm3_batched():
    shape = (8, 6, 16)
    B = 5
    jx = jax.make_jaxpr(
        lambda A, *x: dh.hopm3_batched(A, list(x), sweeps=2,
                                       impl="pallas")[0])(
            _zeros((B,) + shape), *[_zeros((B, n)) for n in shape])
    return TraceCtx("hopm3_batched_pallas_B5", jx, {
        "pad_scope": "kernel",
        "launch": {"kind": "chain", "d": 3, "s": None, "sweeps": 2},
    })


# ---- dHOPM3 at p=8: launches, collective schedule, wire demotion -----------

_DHOPM_RULES = ["no_pad", "launch_count", "collective_schedule",
                "wire_demotion"]


def _dhopm3_ctx(name, mesh, shape, *, s, prec, overlap=False,
                fuse_pairs=None, sweeps=1, batch=None):
    chunks = dh.OVERLAP_CHUNKS_DEFAULT if overlap else 1
    if batch is None:
        def fn(A, *x):
            return dh.dhopm3(
                A, list(x), mesh, "x", s=s, sweeps=sweeps, impl="pallas",
                prec=prec, fuse_pairs=fuse_pairs, overlap=overlap)[0]

        args = (_zeros(shape), *_vecs(shape))
    else:
        def fn(A, *x):
            return dh.dhopm3_batched(
                A, list(x), mesh, "x", s=s, sweeps=sweeps, impl="pallas",
                prec=prec, fuse_pairs=fuse_pairs, overlap=overlap)[0]

        args = (_zeros((batch,) + shape),
                *[_zeros((batch, n)) for n in shape])
    jx = jax.make_jaxpr(fn)(*args)
    fuse = "auto" if fuse_pairs else ()
    return TraceCtx(name, jx, {
        "pad_scope": "kernel",
        "launch": {"kind": "chain", "d": len(shape), "s": s,
                   "fuse_pairs": fuse, "overlap_chunks": chunks,
                   "sweeps": sweeps},
        "schedule": {"shape": shape, "p": P8, "s": s, "prec": prec,
                     "overlap_chunks": chunks, "sweeps": sweeps},
    })


@entrypoint("dhopm3_p8_doubling_f32", _DHOPM_RULES, tags=["p8", "dist"])
def _dhopm3_doubling_f32(mesh):
    return _dhopm3_ctx("dhopm3_p8_doubling_f32", mesh, (8, 6, 16),
                       s=0, prec="f32", sweeps=2)


@entrypoint("dhopm3_p8_doubling_bf16", _DHOPM_RULES, tags=["p8", "dist"])
def _dhopm3_doubling_bf16(mesh):
    return _dhopm3_ctx("dhopm3_p8_doubling_bf16", mesh, (8, 6, 16),
                       s=2, prec="bf16")


@entrypoint("dhopm3_p8_ring_f32", _DHOPM_RULES, tags=["p8", "dist"])
def _dhopm3_ring_f32(mesh):
    # mode 0 is past DOUBLING_MAX_ELEMENTS: the ring regime, whose f32
    # fast path is a single psum per delayed reduction
    return _dhopm3_ctx("dhopm3_p8_ring_f32", mesh, (80000, 8, 8),
                       s=1, prec="f32")


@entrypoint("dhopm3_p8_ring_bf16", _DHOPM_RULES, tags=["p8", "dist"])
def _dhopm3_ring_bf16(mesh):
    return _dhopm3_ctx("dhopm3_p8_ring_bf16", mesh, (80000, 8, 8),
                       s=1, prec="bf16")


@entrypoint("dhopm3_p8_overlap_bf16", _DHOPM_RULES, tags=["p8", "dist"])
def _dhopm3_overlap(mesh):
    return _dhopm3_ctx("dhopm3_p8_overlap_bf16", mesh, (8, 6, 16),
                       s=0, prec="bf16", overlap=True)


@entrypoint("dhopm3_p8_fused_d4", _DHOPM_RULES, tags=["p8", "dist"])
def _dhopm3_fused(mesh):
    return _dhopm3_ctx("dhopm3_p8_fused_d4", mesh, (8, 6, 16, 8),
                       s=0, prec="f32", fuse_pairs=True)


@entrypoint("dhopm3_batched_p8_B4",
            _DHOPM_RULES + ["no_stack"], tags=["p8", "dist"])
def _dhopm3_batched(mesh):
    return _dhopm3_ctx("dhopm3_batched_p8_B4", mesh, (8, 6, 16),
                       s=0, prec="f32", batch=4)


# ---- train / serve steps ---------------------------------------------------

@entrypoint("grad_compress_arena_step",
            ["no_stack", "mulsum_determinism"], tags=["train"])
def _grad_step():
    cfg = gc.CompressorCfg(rank=2, sweeps=2, min_size=16, prec="f32",
                           bucket=True, arena=True)
    params = {f"w{i}": jnp.zeros((8, 6), jnp.float32) for i in range(3)}
    state = gc.init_state(params, cfg)
    mesh = jax.make_mesh((1,), ("dp",))

    def body(g):
        ng, ns, _ = gc.compress_and_sync(g, state, cfg, "dp")
        return ng, ns

    fn = jax.shard_map(body, mesh=mesh, in_specs=(P(),),
                       out_specs=(P(), P()), check_vma=False)
    jx = jax.make_jaxpr(fn)(params)
    return TraceCtx("grad_compress_arena_step", jx, {})


@entrypoint("serve_compress_group_B3",
            ["no_pad", "launch_count", "no_stack"], tags=["serve"])
def _serve_group():
    from repro.serve.engine import _compress_group
    view = (2, 2, 16, 8)
    B = 3
    jx = jax.make_jaxpr(functools.partial(
        _compress_group, sweeps=2, impl="pallas"))(
            _zeros((B,) + view),
            tuple(_zeros((B, n)) for n in view))
    return TraceCtx("serve_compress_group_B3", jx, {
        "pad_scope": "kernel",
        "launch": {"kind": "chain", "d": 4, "s": None, "sweeps": 2},
    })


@entrypoint("serve_compress_group_mulsum",
            ["mulsum_determinism", "no_stack"], tags=["serve"])
def _serve_group_mulsum():
    from repro.serve.engine import _compress_group
    view = (2, 2, 16, 8)
    B = 3
    jx = jax.make_jaxpr(functools.partial(
        _compress_group, sweeps=2, impl="mulsum"))(
            _zeros((B,) + view),
            tuple(_zeros((B, n)) for n in view))
    return TraceCtx("serve_compress_group_mulsum", jx, {})


# ---- arena: zero-copy assembly and real donation ---------------------------

@entrypoint("arena_assemble_rows", ["no_stack"], tags=["arena"])
def _arena_assemble():
    rows = [jnp.zeros((5, 7), jnp.float32) for _ in range(4)]
    jx = jax.make_jaxpr(lambda *rs: assemble_rows(rs))(*rows)
    return TraceCtx("arena_assemble_rows", jx, {})


@entrypoint("arena_scatter_donation", ["donation"], tags=["arena"])
def _arena_donation():
    def compiled_text():
        buf = jnp.zeros((3, 5), jnp.float32)
        rows = [jnp.ones((5,), jnp.float32) for _ in range(3)]
        return _scatter_rows.lower(buf, *rows).compile().as_text()

    return TraceCtx("arena_scatter_donation", None, {
        "donation": {"compiled_text": compiled_text, "donated": [0]},
    })


# ---- source-level determinism hygiene --------------------------------------

@entrypoint("source_no_hash_seed", ["no_hash_seed"], tags=["source"])
def _source_hash():
    return TraceCtx("source_no_hash_seed", None, {})


def get_entrypoints(names=None, tags=None) -> list[EntryPoint]:
    eps = ENTRYPOINTS
    if names is not None:
        wanted = set(names)
        unknown = wanted - {e.name for e in eps}
        if unknown:
            raise KeyError(f"unknown entry point(s): {sorted(unknown)}")
        eps = [e for e in eps if e.name in wanted]
    if tags is not None:
        eps = [e for e in eps if e.tags & set(tags)]
    return eps
