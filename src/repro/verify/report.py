"""Run the rule registry over the entry points and build the JSON report."""
from __future__ import annotations

from .entrypoints import EntryPoint, get_entrypoints
from .rules import run_rules


def run_entrypoint(ep: EntryPoint, waivers=None, *,
                   real_mesh: bool = False) -> dict:
    ctx = ep.ctx(real_mesh=real_mesh)
    findings = run_rules(ctx, ep.rules, waivers)
    blocking = [f for f in findings
                if f.severity == "error" and not f.waived]
    return {
        "entrypoint": ep.name,
        "tags": sorted(ep.tags),
        "rules": list(ep.rules),
        "findings": [f.to_json() for f in findings],
        "ok": not blocking,
    }


def run_verify(names=None, tags=None, waivers=None, *,
               real_mesh: bool = False) -> dict:
    results = [
        run_entrypoint(ep, waivers, real_mesh=real_mesh)
        for ep in get_entrypoints(names, tags)
    ]
    n_findings = sum(len(r["findings"]) for r in results)
    n_waived = sum(
        1 for r in results for f in r["findings"] if f["waived"])
    return {
        "entrypoints": results,
        "summary": {
            "entrypoints": len(results),
            "rules_checked": sum(len(r["rules"]) for r in results),
            "findings": n_findings,
            "waived": n_waived,
            "real_mesh": real_mesh,
        },
        "ok": all(r["ok"] for r in results),
    }
