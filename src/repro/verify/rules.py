"""Rule registry for the static verifier.

Each rule statically checks one structural contract on a traced entry point
(see :mod:`repro.verify.entrypoints`).  Expectations are *recomputed* from
the ``core.memory_model`` closed forms and the ``dist.collectives`` dispatch
— the verifier hardcodes no counts, so a change to the closed forms and a
change to the kernels must agree before the gate goes green.

Rule ids (grouped by the invariant family they prove):

- ``no_pad``             zero ``pad`` eqns in the kernel layer
- ``no_stack``           zero ``concatenate`` under arena assembly
- ``launch_count``       pallas-call count == closed-form launches
- ``collective_schedule``  ppermute/psum/all-gather counts match the
  per-iteration schedule ``dhopm_wire_bytes_sweep`` prices
- ``wire_demotion``      every ppermute hop carries storage precision
- ``donation``           donated buffers alias in the compiled output
- ``mulsum_determinism`` mulsum paths carry no bare reductions
- ``no_hash_seed``       no salted ``hash(`` seeding in source
"""
from __future__ import annotations

import ast
import dataclasses
import json
import math
import pathlib
import re
from typing import Callable

import numpy as np

from repro.core import memory_model as mm
from repro.core.mixed_precision import get_policy
from repro.dist.collectives import allreduce_algo

from . import walker

SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    entrypoint: str
    severity: str
    message: str
    waived: bool = False

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Rule:
    rule_id: str
    severity: str
    description: str
    fn: Callable


@dataclasses.dataclass
class TraceCtx:
    """What an entry point hands the rules: a trace plus rule parameters."""
    name: str
    jaxpr: object = None
    params: dict = dataclasses.field(default_factory=dict)


RULES: dict[str, Rule] = {}


def rule(rule_id: str, *, severity: str = "error", description: str):
    assert severity in SEVERITIES, severity

    def deco(fn):
        RULES[rule_id] = Rule(rule_id, severity, description, fn)
        return fn

    return deco


# ---- closed-form expectations ---------------------------------------------

def expected_launches(spec: dict) -> int:
    """Expected pallas-call count, recomputed from ``memory_model``.

    ``spec["kind"]``:
      - ``"chain"``: a (d)HOPM3 sweep chain — ``sweeps x
        dhopm_launches_per_sweep(d, s, fuse_pairs, overlap_chunks)``.
      - ``"tvc"``: ``calls`` fused TVC kernel launches (one per tvc/tvc2
        call — the mode-oblivious single-launch contract).
    """
    kind = spec["kind"]
    if kind == "chain":
        per_sweep = mm.dhopm_launches_per_sweep(
            spec["d"],
            spec.get("s"),
            spec.get("fuse_pairs", ()),
            overlap_chunks=spec.get("overlap_chunks", 1),
        )
        return spec.get("sweeps", 1) * per_sweep
    if kind == "tvc":
        return spec.get("calls", 1)
    raise ValueError(f"unknown launch spec kind: {kind!r}")


def expected_collectives(spec: dict) -> dict:
    """Per-trace collective counts for a dHOPM3 sweep chain.

    Mirrors the per-iteration dispatch ``memory_model.dhopm_wire_bytes_sweep``
    prices: the split mode all-gathers its 1-D piece; every other mode runs
    one delayed allreduce whose algorithm is ``allreduce_algo(n_j, p)`` —
    doubling issues ``log2(p)`` staged hops per overlap chunk, ring issues a
    ``psum`` when the wire needs no demotion (storage == compute) and
    ``(p - 1)`` reduce-scatter hops plus a tiled all-gather otherwise.
    """
    shape = spec["shape"]
    p = spec["p"]
    s = spec.get("s")
    prec = get_policy(spec.get("prec", "f32"))
    chunks = spec.get("overlap_chunks", 1)
    sweeps = spec.get("sweeps", 1)
    ppermute = psum = all_gather = 0
    for j, nj in enumerate(shape):
        if j == s:
            all_gather += 1
            continue
        if allreduce_algo(nj, p) == "doubling":
            ppermute += int(math.log2(p)) * chunks
        elif prec.storage == prec.compute:
            psum += 1
        else:
            ppermute += p - 1
            all_gather += 1
    return {
        "ppermute": sweeps * ppermute,
        "psum": sweeps * psum,
        "all_gather": sweeps * all_gather,
    }


# ---- jaxpr rules -----------------------------------------------------------

@rule("no_pad", description="zero pad eqns in the kernel layer")
def _no_pad(ctx: TraceCtx) -> list[str]:
    scope = ctx.params.get("pad_scope", "trace")
    n = walker.count_primitive(
        ctx.jaxpr, "pad", kernel_only=(scope == "kernel")
    )
    if n:
        return [f"{n} pad eqn(s) in the {scope} scope (expected 0)"]
    return []


@rule("no_stack", description="zero concatenate under bucket/arena assembly")
def _no_stack(ctx: TraceCtx) -> list[str]:
    n = walker.count_primitive(ctx.jaxpr, "concatenate")
    if n:
        return [f"{n} concatenate eqn(s) (expected 0: rows are scattered)"]
    return []


@rule("launch_count",
      description="pallas-call count equals the memory_model closed form")
def _launch_count(ctx: TraceCtx) -> list[str]:
    want = expected_launches(ctx.params["launch"])
    got = walker.count_primitive(ctx.jaxpr, "pallas_call")
    if got != want:
        return [f"traced {got} pallas_call eqn(s), closed form says {want}"]
    return []


@rule("collective_schedule",
      description="ppermute/psum/all-gather counts match the priced schedule")
def _collective_schedule(ctx: TraceCtx) -> list[str]:
    want = expected_collectives(ctx.params["schedule"])
    counts = walker.primitive_counts(ctx.jaxpr)
    got = {k: counts.get(k, 0) for k in want}
    if got != want:
        return [f"collective counts {got} != priced schedule {want}"]
    return []


@rule("wire_demotion",
      description="every ppermute hop carries the storage precision")
def _wire_demotion(ctx: TraceCtx) -> list[str]:
    prec = get_policy(ctx.params["schedule"].get("prec", "f32"))
    storage = np.dtype(prec.storage)
    bad = sorted({
        str(eqn.invars[0].aval.dtype)
        for eqn, _ in walker.iter_eqns(ctx.jaxpr)
        if eqn.primitive.name == "ppermute"
        and np.dtype(eqn.invars[0].aval.dtype) != storage
    })
    if bad:
        return [
            f"ppermute hop(s) carry {bad} on the wire, "
            f"storage precision is {storage.name}"
        ]
    return []


@rule("mulsum_determinism",
      description="mulsum paths carry only order-explicit doubling-tree adds")
def _mulsum_determinism(ctx: TraceCtx) -> list[str]:
    out = []
    for prim in ("reduce_sum", "dot_general"):
        n = walker.count_primitive(ctx.jaxpr, prim)
        if n:
            out.append(
                f"{n} bare {prim} eqn(s) in a bitwise-mulsum path "
                f"(adds must go through the explicit doubling tree)"
            )
    return out


# ---- compiled-output rule --------------------------------------------------

_ALIAS_PARAM_RE = re.compile(r"\((\d+),\s*\{")


def donated_params(compiled_text: str) -> set[int]:
    """Parameter indices the compiled HLO aliases to outputs."""
    key = "input_output_alias={"
    start = compiled_text.find(key)
    if start < 0:
        return set()
    i, depth = start + len(key), 1
    while i < len(compiled_text) and depth:
        depth += {"{": 1, "}": -1}.get(compiled_text[i], 0)
        i += 1
    body = compiled_text[start + len(key):i - 1]
    return {int(n) for n in _ALIAS_PARAM_RE.findall(body)}


@rule("donation",
      description="donated buffers alias outputs in the compiled executable")
def _donation(ctx: TraceCtx) -> list[str]:
    spec = ctx.params["donation"]
    text = spec["compiled_text"]() if callable(spec["compiled_text"]) \
        else spec["compiled_text"]
    want = set(spec["donated"])
    got = donated_params(text)
    missing = want - got
    if missing:
        return [
            f"donated parameter(s) {sorted(missing)} do not alias any "
            f"output in the compiled executable (defensive copy)"
        ]
    return []


# ---- source-level AST rule -------------------------------------------------

def hash_seed_sites(source: str, filename: str = "<src>") -> list[str]:
    """Locations of salted ``hash(`` calls in ``source``.

    ``hash()`` is salted per process (PYTHONHASHSEED), so seeding anything
    from it breaks cross-process determinism — the bug class PRs 3 and 5
    each fixed once (the cure is ``zlib.crc32`` of the stable name).
    """
    tree = ast.parse(source, filename=filename)
    sites = []
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "hash"):
            sites.append(f"{filename}:{node.lineno}")
    return sites


@rule("no_hash_seed",
      description="no salted hash() seeding anywhere under src/repro")
def _no_hash_seed(ctx: TraceCtx) -> list[str]:
    root = pathlib.Path(ctx.params.get(
        "source_root", pathlib.Path(__file__).resolve().parents[1]))
    sites = []
    for path in sorted(root.rglob("*.py")):
        sites.extend(hash_seed_sites(path.read_text(), str(path)))
    if sites:
        return [f"salted hash() call(s) at: {', '.join(sites)}"]
    return []


# ---- runner ----------------------------------------------------------------

def load_waivers(path) -> dict[tuple[str, str], str]:
    """Waiver file: ``[{"entrypoint": ..., "rule": ..., "reason": ...}]``."""
    data = json.loads(pathlib.Path(path).read_text())
    out = {}
    for item in data:
        out[(item["entrypoint"], item["rule"])] = item.get("reason", "")
    return out


def run_rules(ctx: TraceCtx, rule_ids, waivers=None) -> list[Finding]:
    waivers = waivers or {}
    findings = []
    for rid in rule_ids:
        r = RULES[rid]
        for msg in r.fn(ctx):
            findings.append(Finding(
                rule=rid,
                entrypoint=ctx.name,
                severity=r.severity,
                message=msg,
                waived=(ctx.name, rid) in waivers,
            ))
    return findings
