"""The one recursive jaxpr walker.

Every eqn-counting check in the repo (tests, the CLI verifier, the bench
gate's trace replays) goes through :func:`iter_eqns` so there is exactly one
traversal implementation.  The traversal descends into sub-jaxprs held in
eqn params, covering every container shape jax uses:

- ``ClosedJaxpr`` params (``pjit``'s ``jaxpr``, ``cond``'s ``branches``
  members) — unwrapped via ``.jaxpr``,
- raw ``Jaxpr`` params (``pallas_call``'s ``jaxpr``, ``shard_map``'s body),
- list/tuple params holding either of the above (``cond``'s ``branches``),
- ``ClosedJaxpr``-wrapping-``ClosedJaxpr`` nests (historically produced by
  ``shard_map``) — handled by unwrapping ``.jaxpr`` until eqns appear.

A previous private copy of this walker (``tests/test_serving.py``) only
recursed into params that themselves had a ``.jaxpr`` attribute, silently
skipping list/tuple params such as ``cond`` branches; the regression test
``tests/test_verify.py::test_walker_descends_into_cond_branches`` pins the
fix.
"""
from __future__ import annotations

from collections import Counter
from typing import Iterator


def _as_jaxpr(obj):
    """Unwrap ClosedJaxpr(-nests) to a raw Jaxpr, or return None."""
    for _ in range(3):          # ClosedJaxpr -> (ClosedJaxpr ->) Jaxpr
        if hasattr(obj, "eqns"):
            return obj
        obj = getattr(obj, "jaxpr", None)
        if obj is None:
            return None
    return obj if hasattr(obj, "eqns") else None


def _sub_jaxprs(eqn) -> Iterator:
    for v in eqn.params.values():
        for item in (v if isinstance(v, (list, tuple)) else [v]):
            inner = _as_jaxpr(item)
            if inner is not None:
                yield inner


def iter_eqns(jaxpr, *, in_kernel: bool = False):
    """Yield ``(eqn, in_kernel)`` for every eqn reachable from ``jaxpr``.

    ``jaxpr`` may be a ``ClosedJaxpr``, a raw ``Jaxpr``, or the object
    returned by ``jax.make_jaxpr``.  ``in_kernel`` is True for eqns nested
    (at any depth) inside a ``pallas_call`` body — the "kernel layer" the
    no-pad rule is scoped to.
    """
    root = _as_jaxpr(jaxpr)
    if root is None:
        raise TypeError(f"not a jaxpr: {type(jaxpr).__name__}")
    for eqn in root.eqns:
        yield eqn, in_kernel
        kernel = in_kernel or eqn.primitive.name == "pallas_call"
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, in_kernel=kernel)


def count_primitive(jaxpr, name: str, *, kernel_only: bool = False) -> int:
    """Count eqns whose primitive is ``name`` anywhere under ``jaxpr``."""
    return sum(
        1
        for eqn, in_kernel in iter_eqns(jaxpr)
        if eqn.primitive.name == name and (in_kernel or not kernel_only)
    )


def primitive_counts(jaxpr, *, kernel_only: bool = False) -> Counter:
    """Histogram of primitive names reachable from ``jaxpr``."""
    c: Counter = Counter()
    for eqn, in_kernel in iter_eqns(jaxpr):
        if in_kernel or not kernel_only:
            c[eqn.primitive.name] += 1
    return c


def count_named_calls(jaxpr, substr: str) -> int:
    """Count call-like eqns whose ``name`` param contains ``substr``.

    Subsumes the old ``tests/test_collectives.py::_count_named_calls``
    (used to prove the ring reorder lowers to slice+concat, not roll).
    """
    return sum(
        1
        for eqn, _ in iter_eqns(jaxpr)
        if substr in str(eqn.params.get("name", ""))
    )


def collect_eqns(jaxpr) -> list:
    """All eqns reachable from ``jaxpr`` (the old ``_walk_eqns`` helper)."""
    return [eqn for eqn, _ in iter_eqns(jaxpr)]
