"""Multi-device distributed checks. Run as:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python tests/_dist_checks.py

Prints "OK <name>" per passing check; the pytest wrapper asserts the full set.
Kept out-of-process so the main test session keeps a single CPU device.
"""
import os

if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    )

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import dtvc as dtvc_mod  # noqa: E402
from repro.core import dhopm as dh  # noqa: E402
from repro.core.mixed_precision import BF16_F32, F32  # noqa: E402
from repro.dist import collectives as coll  # noqa: E402
from repro.kernels import ref  # noqa: E402

PASS = []


def ok(name):
    PASS.append(name)
    print(f"OK {name}", flush=True)


def main():
    assert jax.device_count() == 8, jax.devices()
    mesh = jax.make_mesh((8,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.default_rng(7)

    # ---- dTVC, k != s and k == s, all (k, s) pairs on an order-3 tensor ----
    shape = (16, 24, 8)
    A = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    for k in range(3):
        x = jnp.asarray(rng.normal(size=(shape[k],)).astype(np.float32))
        want = ref.tvc_ref(A, x, k)
        for s in range(3):
            got = dtvc_mod.dtvc(A, x, k, s, mesh, "x", assemble=True)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=2e-5, atol=2e-5)
    ok("dtvc_all_k_s")

    # distributed (non-assembled) output keeps the split and matches on gather
    got = dtvc_mod.dtvc(A, jnp.ones((24,), jnp.float32), 1, 0, mesh, "x",
                        assemble=False)
    want = ref.tvc_ref(A, jnp.ones((24,), jnp.float32), 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5)
    ok("dtvc_unassembled")

    # alpha/beta update, k == s (Eq. 2 with BLAS scalars)
    x1 = jnp.asarray(rng.normal(size=(24,)).astype(np.float32))
    y0 = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    got = dtvc_mod.dtvc(A, x1, 1, 1, mesh, "x", alpha=2.0, beta=-0.5, y=y0)
    want = 2.0 * ref.tvc_ref(A, x1, 1) - 0.5 * y0
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
    ok("dtvc_eq2_alphabeta")

    # ragged local shards through the zero-copy Pallas path.  k != s with
    # assemble=False routes alpha/beta/y into dtvc_local -> tvc(impl=
    # "pallas"), so the update really runs in the fused kernel epilogue
    # inside the shard_map body (per-shard view (1, 16, 5): nothing is a
    # block multiple); k == s applies beta after the collective reduction.
    A_r = jnp.asarray(rng.normal(size=(8, 16, 5)).astype(np.float32))
    x_r = jnp.asarray(rng.normal(size=(16,)).astype(np.float32))
    y_r = jnp.asarray(rng.normal(size=(8, 5)).astype(np.float32))
    want = 2.0 * ref.tvc_ref(A_r, x_r, 1) - 0.5 * y_r
    got = dtvc_mod.dtvc(A_r, x_r, 1, 0, mesh, "x", impl="pallas",
                        alpha=2.0, beta=-0.5, y=y_r, assemble=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    got = dtvc_mod.dtvc(A_r, x_r, 1, 1, mesh, "x", impl="pallas",
                        alpha=2.0, beta=-0.5, y=y_r)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    ok("dtvc_pallas_ragged")

    # fused-pair local op on ragged shards: ONE Pallas launch per adjacent
    # pair with the alpha/beta update in its epilogue, split tracked across
    # the pair — both the generic (v > 1) and the chain-tail (v == 1) kernel
    A_q = jnp.asarray(rng.normal(size=(8, 6, 10, 3)).astype(np.float32))
    x1q = jnp.asarray(rng.normal(size=(6,)).astype(np.float32))
    x2q = jnp.asarray(rng.normal(size=(10,)).astype(np.float32))
    for k1 in (1, 2):
        out_extents = tuple(n for i, n in enumerate(A_q.shape)
                            if i not in (k1, k1 + 1))
        y_q = jnp.asarray(rng.normal(size=out_extents).astype(np.float32))
        xa = x1q if k1 == 1 else jnp.asarray(
            rng.normal(size=(10,)).astype(np.float32))
        xb = x2q if k1 == 1 else jnp.asarray(
            rng.normal(size=(3,)).astype(np.float32))

        def pair_body(a_loc, xa, xb, y_loc, k1=k1):
            out, st = dtvc_mod.dtvc2_local(
                a_loc, xa, k1, xb, dtvc_mod.ShardState(split=0),
                impl="pallas", alpha=2.0, beta=-0.5, y=y_loc)
            assert st.split == 0    # split below the pair is untouched
            return out

        fnp = jax.shard_map(pair_body, mesh=mesh,
                            in_specs=(P("x"), P(), P(), P("x")),
                            out_specs=P("x"), check_vma=False)
        got = jax.jit(fnp)(A_q, xa, xb, y_q)
        mid = np.tensordot(np.asarray(A_q), np.asarray(xa), axes=(k1, 0))
        full = np.tensordot(mid, np.asarray(xb), axes=(k1, 0))
        want = 2.0 * full - 0.5 * np.asarray(y_q)
        np.testing.assert_allclose(np.asarray(got), want,
                                   rtol=2e-5, atol=2e-5)
    ok("dtvc2_pair_local")

    # ---- mixed-precision collectives --------------------------------------
    def run_coll(fn, v):
        f = jax.shard_map(fn, mesh=mesh, in_specs=P("x"), out_specs=P("x"),
                          check_vma=False)
        return jax.jit(f)(v)

    v = jnp.asarray(rng.normal(size=(8, 1000)).astype(np.float32))
    want = np.asarray(v).sum(0)

    got = run_coll(lambda t: coll.mp_allreduce_doubling(t[0], "x", F32)[None], v)
    np.testing.assert_allclose(np.asarray(got[0]), want, rtol=1e-5, atol=1e-5)
    ok("mp_doubling_f32_exact")

    got = run_coll(lambda t: coll.mp_allreduce_ring(t[0], "x", F32)[None], v)
    np.testing.assert_allclose(np.asarray(got[0]), want, rtol=1e-5, atol=1e-5)
    ok("mp_ring_f32_exact")

    got = run_coll(lambda t: coll.mp_allreduce_ring(t[0], "x", BF16_F32)[None], v)
    err = np.abs(np.asarray(got[0]) - want).max() / (np.abs(want).max() + 1e-9)
    assert err < 0.05, f"bf16 ring wire error too large: {err}"
    ok("mp_ring_bf16_bounded")

    got = run_coll(lambda t: coll.mp_allreduce_doubling(t[0], "x", BF16_F32)[None], v)
    err = np.abs(np.asarray(got[0]) - want).max() / (np.abs(want).max() + 1e-9)
    assert err < 0.05, f"bf16 doubling wire error too large: {err}"
    ok("mp_doubling_bf16_bounded")

    # ring with non-divisible length
    v2 = jnp.asarray(rng.normal(size=(8, 37)).astype(np.float32))
    got = run_coll(lambda t: coll.mp_allreduce_ring(t[0], "x", F32)[None], v2)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(v2).sum(0),
                               rtol=1e-5, atol=1e-5)
    ok("mp_ring_ragged")

    # the dispatching entry point: bf16 storage must track a f32 psum within
    # bf16-wire tolerance (paper §5.5: sums accumulate high, wire moves low)
    got = run_coll(lambda t: coll.mp_allreduce(t[0], "x", BF16_F32)[None], v)
    want_psum = run_coll(lambda t: jax.lax.psum(t[0], "x")[None], v)
    err = np.abs(np.asarray(got[0]) - np.asarray(want_psum[0])).max() \
        / (np.abs(np.asarray(want_psum[0])).max() + 1e-9)
    assert err < 0.02, f"mp_allreduce(bf16) vs psum(f32): {err}"
    ok("mp_allreduce_matches_psum")

    # ---- dHOPM_3 ------------------------------------------------------------
    shape = (8, 24, 16)
    A = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    xs0 = [jnp.asarray(rng.normal(size=(n,)).astype(np.float32)) for n in shape]
    xs_seq, lam_seq = dh.hopm3(A, xs0, sweeps=3)
    xs_cls, lam_cls = dh.hopm_classic(A, xs0, sweeps=3)
    for a, b in zip(xs_seq, xs_cls):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(lam_seq), float(lam_cls), rtol=1e-4)
    ok("hopm3_equals_classic")

    for s in range(3):
        xs_d, lam_d = dh.dhopm3(A, xs0, mesh, "x", s=s, sweeps=3)
        for a, b in zip(xs_d, xs_seq):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(float(lam_d), float(lam_seq), rtol=1e-3)
    ok("dhopm3_matches_sequential_all_s")

    # regression: tvc2 pair fusion with a split dim above the fused pair
    # (s = d-1, the paper's recommended split) must not mis-track ShardState
    for s in (0, 2):
        xs_f, lam_f = dh.dhopm3(A, xs0, mesh, "x", s=s, sweeps=3,
                                fuse_pairs=True)
        for a, b in zip(xs_f, xs_seq):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(float(lam_f), float(lam_seq), rtol=1e-3)
    ok("dhopm3_fused_matches_sequential")

    # same schedule through the ragged Pallas kernels: local shards of the
    # s=2 split are (8, 24, 2) — nothing is block-multiple, nothing is padded
    xs_kp, lam_kp = dh.dhopm3(A, xs0, mesh, "x", s=2, sweeps=3,
                              impl="pallas", fuse_pairs=True)
    for a, b in zip(xs_kp, xs_seq):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(float(lam_kp), float(lam_seq), rtol=1e-3)
    ok("dhopm3_pallas_ragged")

    # exact rank-1 recovery in one sweep
    us = [rng.normal(size=(n,)).astype(np.float32) for n in shape]
    us = [u / np.linalg.norm(u) for u in us]
    lam_true = 5.0
    A1 = jnp.asarray(lam_true * np.einsum("i,j,k->ijk", *us))
    xs_r, lam_r = dh.dhopm3(A1, xs0, mesh, "x", s=2, sweeps=2)
    assert abs(float(lam_r) - lam_true) / lam_true < 1e-3
    res = float(dh.rank1_residual(A1, xs_r, lam_r))
    assert res < 1e-3, res
    ok("dhopm3_rank1_recovery")

    # ---- hopm3_partial: implicit-sum decomposition (gradient-compression core)
    addends = jnp.asarray(rng.normal(size=(8,) + shape).astype(np.float32))
    A_sum = jnp.sum(addends, axis=0)
    xs_ref, lam_ref = dh.hopm3(A_sum, xs0, sweeps=2)

    def body(a_loc, *xs_in):
        out, lam = dh.hopm3_partial(a_loc[0], list(xs_in), axis_name="x", sweeps=2)
        return tuple(out), lam

    fn = jax.shard_map(body, mesh=mesh,
                       in_specs=(P("x"),) + tuple(P() for _ in xs0),
                       out_specs=(tuple(P() for _ in xs0), P()),
                       check_vma=False)
    xs_p, lam_p = jax.jit(fn)(addends, *xs0)
    for a, b in zip(xs_p, xs_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(float(lam_p), float(lam_ref), rtol=1e-3)
    ok("hopm3_partial_implicit_sum")

    # bf16 storage dHOPM_3 still converges on the rank-1 tensor
    xs_b, lam_b = dh.dhopm3(A1.astype(jnp.bfloat16),
                            [x.astype(jnp.bfloat16) for x in xs0],
                            mesh, "x", s=2, sweeps=2, prec=BF16_F32)
    assert abs(float(lam_b) - lam_true) / lam_true < 0.02
    ok("dhopm3_bf16")

    # ---- split-aware batched dHOPM_3 (the dhopm3_batched acceptance) -------
    # B same-shape tensors, every split, unfused + fused: the batched walker
    # must match B INDEPENDENT dhopm3 runs bit for bit under the mulsum
    # engine (stacked psum/all-gather are elementwise; the order-explicit
    # contraction-proof tree reduces make the per-row arithmetic identical).
    B = 3
    A_b = jnp.asarray(rng.normal(size=(B, 8, 24, 16)).astype(np.float32))
    xs_b = [jnp.asarray(rng.normal(size=(B, n)).astype(np.float32))
            for n in (8, 24, 16)]
    for s in range(3):
        for fuse in (False, True):
            xb, lb = dh.dhopm3_batched(A_b, xs_b, mesh, "x", s=s, sweeps=3,
                                       impl="mulsum", fuse_pairs=fuse)
            for i in range(B):
                xi, li = dh.dhopm3(A_b[i], [x[i] for x in xs_b], mesh, "x",
                                   s=s, sweeps=3, impl="mulsum",
                                   fuse_pairs=fuse)
                assert np.array_equal(np.asarray(lb)[i], np.asarray(li)), \
                    (s, fuse, i)
                for a, b in zip(xb, xi):
                    assert np.array_equal(np.asarray(a)[i], np.asarray(b)), \
                        (s, fuse, i)
    ok("dhopm3_batched_split_bitwise")

    # pallas engine through the same split batched walker (interpret on CPU)
    xk, lk = dh.dhopm3_batched(A_b, xs_b, mesh, "x", s=2, sweeps=2,
                               impl="pallas", fuse_pairs=True)
    xr, lr = dh.dhopm3_batched(A_b, xs_b, mesh, "x", s=2, sweeps=2,
                               impl="native", fuse_pairs=True)
    np.testing.assert_allclose(np.asarray(lk), np.asarray(lr), rtol=1e-3)
    for a, b in zip(xk, xr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)
    ok("dhopm3_batched_pallas_split")

    # ---- pipelined dHOPM3 (overlap=) ---------------------------------------
    check_staged_allreduce(mesh)
    check_mp_allreduce_prime_pad(mesh)
    check_ring_wire_counted_trace(mesh)
    check_dhopm3_overlap(mesh)
    check_dhopm3_batched_overlap(mesh)
    check_dhopm3_auto_plan(mesh)

    # ---- training integration ----------------------------------------------
    check_training()
    check_grad_compression()
    check_grad_compression_bucketed()
    check_grad_compression_split()
    check_wire_summary_trace()
    check_elastic_restore()

    # ---- continuous-batching serving ---------------------------------------
    check_serve_compress_bucketed()
    check_slot_recycle_prefill_sharded()

    # ---- batched-operand arena ---------------------------------------------
    check_grad_compress_arena_bitwise()
    check_serve_compress_arena_bitwise()

    # ---- static verifier over a REAL p=8 mesh ------------------------------
    check_verify_static_gate_p8()

    print(f"ALL_DIST_OK {len(PASS)}")


def check_training():
    """dp_explicit (manual DP shard_map + mp collectives) must match the pure
    GSPMD step on identical params/batch; compression must still converge."""
    from repro.configs import get_config
    from repro.data import DataConfig, SyntheticLMData
    from repro.train import optimizer as opt_mod
    from repro.train.train_loop import TrainConfig, make_train_step, setup
    from repro.train.grad_compress import CompressorCfg

    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = get_config("qwen2-1.5b", smoke=True)
    ocfg = opt_mod.OptConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    data = SyntheticLMData(DataConfig(cfg.vocab_size, 32, 8, seed=3), mesh)
    batch = data.device_put(data.batch_at(0))

    results = {}
    for key, mode, extra in [
        ("gspmd", "gspmd", {}),
        ("dp_explicit", "dp_explicit", {}),
        ("dp_explicit+mp", "dp_explicit", {"mp_wire": "bf16"}),
        ("dp_explicit+mp+staged", "dp_explicit",
         {"mp_wire": "bf16", "staged_wire": True}),
    ]:
        tcfg = TrainConfig(opt=ocfg, mode=mode, **extra)
        params, opt_state, comp_state, _ = setup(cfg, mesh, tcfg)
        step_fn, _ = make_train_step(cfg, mesh, tcfg)
        p2, o2, c2, m = step_fn(params, opt_state, comp_state, batch)
        results[key] = (float(m["loss"]), p2)
    base_loss, base_p = results["gspmd"]
    expl_loss, expl_p = results["dp_explicit"]
    assert abs(base_loss - expl_loss) / base_loss < 1e-4, (base_loss, expl_loss)
    # parameters after one step agree (same grads up to collective order)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        base_p, expl_p)
    assert max(jax.tree.leaves(diffs)) < 5e-3, max(jax.tree.leaves(diffs))
    mp_loss, mp_p = results["dp_explicit+mp"]
    assert abs(base_loss - mp_loss) / base_loss < 5e-3
    # the staged collective is leaf-for-leaf the same hops: bitwise params
    st_loss, st_p = results["dp_explicit+mp+staged"]
    assert st_loss == mp_loss, (st_loss, mp_loss)
    for a, b in zip(jax.tree.leaves(mp_p), jax.tree.leaves(st_p)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    ok("dp_explicit_matches_gspmd")


def check_grad_compression():
    from repro.train import grad_compress as gc
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((8,), ("x",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.default_rng(11)
    ccfg = gc.CompressorCfg(rank=4, sweeps=3, min_size=64, prec="f32")

    # low-rank global gradient split into 8 partial addends
    U = rng.normal(size=(48, 3)).astype(np.float32)
    V = rng.normal(size=(64, 3)).astype(np.float32)
    G = U @ V.T
    parts = rng.normal(size=(8, 48, 64)).astype(np.float32) * 0.0
    parts[0] = G  # rank 0 holds all of it; sum is still G
    grads_tree = {"w": jnp.asarray(parts)}
    params_like = {"w": jnp.zeros((48, 64), jnp.float32)}
    state = gc.init_state(params_like, ccfg)

    def body(gl):
        g_local = {"w": gl["w"][0]}
        synced, new_state, _ = gc.compress_and_sync(g_local, state, ccfg, "x")
        return synced["w"][None], new_state["w"]["e"][None]

    fn = jax.shard_map(body, mesh=mesh, in_specs=({"w": P("x")},),
                       out_specs=(P("x"), P("x")), check_vma=False)
    synced, efs = jax.jit(fn)(grads_tree)
    got_mean = np.asarray(synced)[0]          # identical on every rank
    want_mean = G / 8.0
    rel = np.linalg.norm(got_mean - want_mean) / np.linalg.norm(want_mean)
    assert rel < 0.05, f"rank-4 HOPM should capture a rank-3 gradient: {rel}"
    # error feedback conservation: sum_p e_new = G - Ghat
    e_sum = np.asarray(efs).sum(0)
    ghat = got_mean * 8.0
    np.testing.assert_allclose(e_sum, G - ghat, rtol=1e-3, atol=1e-3)
    # wire accounting says compression wins (realistic leaf size)
    big = {"w": jnp.zeros((4096, 4096), jnp.float32)}
    stats = gc.wire_bytes_summary(big, ccfg, 8)
    assert stats["ratio"] > 50, stats
    ok("grad_compression_lowrank_and_ef")


def check_grad_compression_bucketed():
    """The shape-bucketed scheduler (one hopm3_batched chain per bucket of
    same-view leaves) reproduces the per-leaf loop bit for bit on a real
    8-way DP mesh — the delayed reductions run as ONE stacked collective
    per external iteration (f32 -> psum, elementwise, so stacking cannot
    perturb rounding)."""
    import dataclasses
    from repro.train import grad_compress as gc
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((8,), ("x",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.default_rng(13)
    ccfg = gc.CompressorCfg(rank=2, sweeps=2, min_size=32, prec="f32")
    params_like = {"q": jnp.zeros((12, 16), jnp.float32),
                   "k": jnp.zeros((12, 16), jnp.float32),
                   "v": jnp.zeros((12, 16), jnp.float32),
                   "o": jnp.zeros((6, 5, 4), jnp.float32)}
    grads = {n: jnp.asarray(rng.normal(size=(8,) + p.shape)
                            .astype(np.float32))
             for n, p in params_like.items()}
    state = gc.init_state(params_like, ccfg)

    def run(cfg):
        def body(gl):
            g_local = {n: g[0] for n, g in gl.items()}
            synced, new_state, _ = gc.compress_and_sync(
                g_local, state, cfg, "x")
            return (jax.tree.map(lambda t: t[None], synced),
                    jax.tree.map(lambda t: t[None], new_state))

        fn = jax.shard_map(
            body, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P("x"), grads),),
            out_specs=(jax.tree.map(lambda _: P("x"), grads),
                       jax.tree.map(lambda _: P("x"), state)),
            check_vma=False)
        return jax.jit(fn)(grads)

    got_b = run(ccfg)
    got_l = run(dataclasses.replace(ccfg, bucket=False))
    for a, b in zip(jax.tree.leaves(got_b), jax.tree.leaves(got_l)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    ok("grad_compression_bucketed_bitwise")


def check_grad_compression_split():
    """Split-annotated (ZeRO-style sharded) gradient leaves route through
    the split-aware batched walker: bucketed == per-leaf BITWISE on a real
    8-way mesh, error feedback conserves the local slice exactly, and the
    assembled compressed gradient matches a single-process run of the same
    compression on the assembled global gradient (to f32 collective
    rounding)."""
    import dataclasses
    from repro.train import grad_compress as gc
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((8,), ("x",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.default_rng(17)
    splits = (("['qa']", 1), ("['qb']", 1))
    ccfg = gc.CompressorCfg(rank=2, sweeps=2, min_size=64, prec="f32",
                            splits=splits, split_world=8)
    params_local = {"qa": jnp.zeros((16, 8), jnp.float32),
                    "qb": jnp.zeros((16, 8), jnp.float32)}
    G = {k: rng.normal(size=(16, 64)).astype(np.float32)
         for k in ("qa", "qb")}
    grads = {k: jnp.stack([jnp.asarray(G[k][:, r * 8:(r + 1) * 8])
                           for r in range(8)]) for k in ("qa", "qb")}
    state = gc.init_state(params_local, ccfg)

    def run(cfg):
        def body(gl):
            g_local = {n: g[0] for n, g in gl.items()}
            synced, new_state, _ = gc.compress_and_sync(
                g_local, state, cfg, "x")
            return (jax.tree.map(lambda t: t[None], synced),
                    jax.tree.map(lambda t: t[None], new_state))

        fn = jax.shard_map(
            body, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P("x"), grads),),
            out_specs=(jax.tree.map(lambda _: P("x"), grads),
                       jax.tree.map(lambda _: P("x"), state)),
            check_vma=False)
        return jax.jit(fn)(grads)

    gb, sb = run(ccfg)
    gl, sl = run(dataclasses.replace(ccfg, bucket=False))
    for a, b in zip(jax.tree.leaves((gb, sb)), jax.tree.leaves((gl, sl))):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # ghat + e reconstructs each rank's slice exactly
    for k in ("qa", "qb"):
        recon = np.asarray(gb[k]) + np.asarray(sb[k]["e"])
        np.testing.assert_allclose(recon, np.asarray(grads[k]),
                                   rtol=1e-5, atol=1e-5)
    # single-process oracle: same compression of the assembled global G
    mesh1 = jax.make_mesh((1,), ("y",))
    cfg1 = dataclasses.replace(ccfg, split_world=1)
    params1 = {k: jnp.zeros((16, 64), jnp.float32) for k in ("qa", "qb")}
    state1 = gc.init_state(params1, cfg1)

    def body1(gl, s_):
        ng, ns, _ = gc.compress_and_sync(gl, s_, cfg1, "y")
        return ng, ns

    fn1 = jax.shard_map(body1, mesh=mesh1, in_specs=(P(), P()),
                        out_specs=(P(), P()), check_vma=False)
    g1, _ = jax.jit(fn1)({k: jnp.asarray(G[k]) for k in ("qa", "qb")},
                         state1)
    for k in ("qa", "qb"):
        assembled = np.concatenate(
            [np.asarray(gb[k])[r] for r in range(8)], axis=1)
        rel = np.linalg.norm(assembled - np.asarray(g1[k])) \
            / np.linalg.norm(np.asarray(g1[k]))
        assert rel < 1e-5, (k, rel)
    ok("grad_compression_split_leaves")


def check_staged_allreduce(mesh):
    """StagedAllreduce.drain() must equal the monolithic explicit schedule
    BITWISE — ring and doubling, f32 and bf16 wire, divisible and prime
    payloads.  (Hop-for-hop identical arithmetic is the foundation of the
    pipelined walker's bitwise guarantee.)"""
    rng = np.random.default_rng(23)
    for n in (37, 101, 128):
        v = jnp.asarray(rng.normal(size=(8, n)).astype(np.float32))
        for prec in (F32, BF16_F32):
            for algo in ("ring", "doubling"):
                def body(t, algo=algo, prec=prec):
                    sync = coll.mp_allreduce(t[0], "x", prec, algo=algo,
                                             force_schedule=True)
                    staged = coll.staged_allreduce(t[0], "x", prec,
                                                   algo=algo).drain()
                    return sync[None], staged[None]
                f = jax.shard_map(body, mesh=mesh, in_specs=P("x"),
                                  out_specs=(P("x"), P("x")), check_vma=False)
                sync, staged = jax.jit(f)(v)
                assert np.array_equal(np.asarray(sync), np.asarray(staged)), \
                    (n, algo, prec)
    ok("staged_allreduce_matches_sync")


def check_mp_allreduce_prime_pad(mesh):
    """Payloads not divisible by p: the ring pad path must still produce the
    exact sum (f32) for prime sizes, under both explicit ring and auto
    dispatch."""
    rng = np.random.default_rng(29)
    for n in (37, 101):
        v = jnp.asarray(rng.normal(size=(8, n)).astype(np.float32))
        want = np.asarray(v).sum(0)
        for algo in ("ring", "auto"):
            def body(t, algo=algo):
                return coll.mp_allreduce(t[0], "x", F32, algo=algo,
                                         force_schedule=True)[None]
            f = jax.shard_map(body, mesh=mesh, in_specs=P("x"),
                              out_specs=P("x"), check_vma=False)
            got = jax.jit(f)(v)
            np.testing.assert_allclose(np.asarray(got[0]), want,
                                       rtol=1e-5, atol=1e-5)
    ok("mp_allreduce_prime_pad")


def _count_wire_bytes(jaxpr) -> float:
    """Received bytes per process from a traced collective: every ppermute
    ships its operand; every (tiled) all_gather receives out - in."""
    total = 0.0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "ppermute":
            a = eqn.invars[0].aval
            total += a.size * a.dtype.itemsize
        elif eqn.primitive.name == "all_gather":
            i, o = eqn.invars[0].aval, eqn.outvars[0].aval
            total += (o.size - i.size) * i.dtype.itemsize
        for v in eqn.params.values():
            for item in (v if isinstance(v, (list, tuple)) else [v]):
                inner = getattr(item, "jaxpr", item)
                if hasattr(inner, "eqns"):
                    total += _count_wire_bytes(inner)
    return total


def check_ring_wire_counted_trace(mesh):
    """The padded ring closed form 2·(p-1)·ceil(n/p)·itemsize must equal a
    counted ppermute/all_gather trace of what the runtime actually ships —
    monolithic mp_allreduce_ring AND the staged schedule, f32 (4 B hops)
    and bf16 wire (2 B hops), prime and divisible payloads."""
    p = 8
    for n in (37, 101, 128):
        for prec, itemsize in ((F32, 4), (BF16_F32, 2)):
            want = coll.wire_bytes_allreduce(n, p, itemsize, "ring")
            x = jnp.ones((n,), jnp.float32)
            for fn in (
                lambda t, prec=prec: coll.mp_allreduce_ring(t, "x", prec),
                lambda t, prec=prec: coll.staged_allreduce(
                    t, "x", prec, algo="ring").drain(),
            ):
                f = jax.shard_map(fn, mesh=mesh, in_specs=P(), out_specs=P(),
                                  check_vma=False)
                counted = _count_wire_bytes(jax.make_jaxpr(f)(x).jaxpr)
                assert counted == want, (n, itemsize, counted, want)
    ok("ring_wire_matches_counted_trace")


def check_dhopm3_overlap(mesh):
    """Acceptance (p = 8 half): dhopm3(overlap=True) is BITWISE equal to the
    synchronous walker under the mulsum engine — fused and unfused, split at
    both ends — and still converges on the sequential oracle."""
    rng = np.random.default_rng(31)
    shape = (8, 24, 16)
    A = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    xs0 = [jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
           for n in shape]
    xs_seq, lam_seq = dh.hopm3(A, xs0, sweeps=2, impl="mulsum")
    for s in (0, 2):
        for fuse in (False, True):
            ref_xs, ref_lam = dh.dhopm3(A, xs0, mesh, "x", s=s, sweeps=2,
                                        impl="mulsum", fuse_pairs=fuse)
            got_xs, got_lam = dh.dhopm3(A, xs0, mesh, "x", s=s, sweeps=2,
                                        impl="mulsum", fuse_pairs=fuse,
                                        overlap=True)
            assert np.array_equal(np.asarray(ref_lam), np.asarray(got_lam)), \
                (s, fuse)
            for a, b in zip(ref_xs, got_xs):
                assert np.array_equal(np.asarray(a), np.asarray(b)), (s, fuse)
    # overlapped distributed run tracks the sequential oracle
    got_xs, got_lam = dh.dhopm3(A, xs0, mesh, "x", s=2, sweeps=2,
                                impl="mulsum", overlap=True)
    for a, b in zip(got_xs, xs_seq):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(float(got_lam), float(lam_seq), rtol=1e-3)
    # bf16 wire: the staged hops demote/promote exactly like the sync ones
    ref_xs, ref_lam = dh.dhopm3(A, xs0, mesh, "x", s=0, sweeps=2,
                                impl="mulsum", prec=BF16_F32)
    got_xs, got_lam = dh.dhopm3(A, xs0, mesh, "x", s=0, sweeps=2,
                                impl="mulsum", prec=BF16_F32, overlap=True)
    assert np.array_equal(np.asarray(ref_lam), np.asarray(got_lam))
    for a, b in zip(ref_xs, got_xs):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    ok("dhopm3_overlap_bitwise")


def check_dhopm3_batched_overlap(mesh):
    """Acceptance (p = 8 half, batched): dhopm3_batched(overlap=True) is
    bitwise equal to the synchronous batched walker AND to B independent
    overlapped dhopm3 runs under mulsum."""
    rng = np.random.default_rng(37)
    B, shape = 3, (8, 24, 16)
    A_b = jnp.asarray(rng.normal(size=(B,) + shape).astype(np.float32))
    xs_b = [jnp.asarray(rng.normal(size=(B, n)).astype(np.float32))
            for n in shape]
    for s in (0, 2):
        ref = dh.dhopm3_batched(A_b, xs_b, mesh, "x", s=s, sweeps=2,
                                impl="mulsum")
        got = dh.dhopm3_batched(A_b, xs_b, mesh, "x", s=s, sweeps=2,
                                impl="mulsum", overlap=True)
        assert np.array_equal(np.asarray(ref[1]), np.asarray(got[1])), s
        for a, b in zip(ref[0], got[0]):
            assert np.array_equal(np.asarray(a), np.asarray(b)), s
    for i in range(B):
        xi, li = dh.dhopm3(A_b[i], [x[i] for x in xs_b], mesh, "x", s=2,
                           sweeps=2, impl="mulsum", overlap=True)
        assert np.array_equal(np.asarray(got[1])[i], np.asarray(li))
        for a, b in zip(got[0], xi):
            assert np.array_equal(np.asarray(a)[i], np.asarray(b))
    ok("dhopm3_batched_overlap_bitwise")


def check_dhopm3_auto_plan(mesh):
    """Acceptance (p = 8): dhopm3(impl="auto") — the planner resolving the
    engine, pair fusion and overlap chunking — is BITWISE equal to the
    explicitly-flagged mulsum walker run with the exact flags the plan
    resolved to.  Auto must never trade the distributed bitwise guarantee
    for speed."""
    from repro.plan import planner

    rng = np.random.default_rng(41)
    shape = (8, 24, 16)
    A = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    xs0 = [jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
           for n in shape]
    for s in (0, 2):
        plan = planner.plan_dhopm3(shape, p=8, s=s, itemsize=4,
                                   backend="cpu")
        assert plan.impl == "mulsum", plan  # the bitwise-batchable engine
        overlap = plan.overlap_chunks if plan.overlap_chunks > 1 else False
        ref_xs, ref_lam = dh.dhopm3(
            A, xs0, mesh, "x", s=s, sweeps=2, impl=plan.impl,
            fuse_pairs=plan.fused, overlap=overlap)
        got_xs, got_lam = dh.dhopm3(A, xs0, mesh, "x", s=s, sweeps=2,
                                    impl="auto")
        assert np.array_equal(np.asarray(ref_lam), np.asarray(got_lam)), s
        for a, b in zip(ref_xs, got_xs):
            assert np.array_equal(np.asarray(a), np.asarray(b)), s
    ok("dhopm3_auto_plan_bitwise")


def check_wire_summary_trace():
    """wire_bytes_summary's closed form == a counted trace of the
    collectives the compression actually issues: every mp_allreduce /
    all_gather_tiled call is recorded during tracing (payload + per-leaf
    size), priced with the same ring/doubling closed forms, and the totals
    must agree exactly — partial leaves, split leaves (all-gather at
    j == split), bucketed stacks, and the exact small-leaf path."""
    from repro.train import grad_compress as gc
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((8,), ("x",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    p = 8
    splits = (("['sa']", 1), ("['sb']", 1))
    ccfg = gc.CompressorCfg(rank=2, sweeps=2, min_size=64, prec="f32",
                            splits=splits, split_world=p)
    params_local = {"w": jnp.zeros((40, 64), jnp.float32),
                    "sa": jnp.zeros((16, 8), jnp.float32),
                    "sb": jnp.zeros((16, 8), jnp.float32),
                    "bias": jnp.zeros((5,), jnp.float32)}
    grads = jax.tree.map(lambda t: jnp.ones_like(t), params_local)
    state = gc.init_state(params_local, ccfg)
    itemsize = 4

    events = []
    orig_ar, orig_ag = coll.mp_allreduce, coll.all_gather_tiled

    def rec_ar(x, axis_name, prec, algo="auto", **kw):
        events.append(("ar", int(np.prod(x.shape)), int(x.shape[-1])))
        return orig_ar(x, axis_name, prec, algo=algo, **kw)

    def rec_ag(x, axis_name, axis=0):
        events.append(("ag", int(np.prod(x.shape))))
        return orig_ag(x, axis_name, axis=axis)

    coll.mp_allreduce = rec_ar
    coll.all_gather_tiled = rec_ag
    try:
        def body(gl, s_):
            ng, ns, _ = gc.compress_and_sync(gl, s_, ccfg, "x")
            return ng, ns

        fn = jax.shard_map(body, mesh=mesh, in_specs=(P(), P()),
                           out_specs=(P(), P()), check_vma=False)
        jax.eval_shape(fn, grads, state)   # trace only: records every call
    finally:
        coll.mp_allreduce = orig_ar
        coll.all_gather_tiled = orig_ag

    priced = 0.0
    for ev in events:
        if ev[0] == "ar":
            _, total, per_leaf = ev
            # stacked (B, n_j) payloads keep the per-leaf n_j dispatch;
            # both wire forms are linear in n, so pricing the total at the
            # per-leaf algo equals B per-leaf collectives
            priced += coll.wire_bytes_allreduce(
                total, p, itemsize, coll.allreduce_algo(per_leaf, p))
        else:
            _, local_total = ev
            priced += coll.wire_bytes_allgather(local_total * p, p, itemsize)
    want = gc.wire_bytes_summary(params_local, ccfg, p)["compressed_bytes"]
    assert priced == want, (priced, want, events)
    ok("wire_summary_matches_counted_trace")


def check_elastic_restore():
    import tempfile
    from repro.train import checkpoint as ck
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh_a = jax.make_mesh((4, 2), ("data", "model"),
                           axis_types=(jax.sharding.AxisType.Auto,) * 2)
    mesh_b = jax.make_mesh((2, 4), ("data", "model"),
                           axis_types=(jax.sharding.AxisType.Auto,) * 2)
    tree = {
        "w": jax.device_put(jnp.arange(64.0).reshape(8, 8),
                            NamedSharding(mesh_a, P("data", "model"))),
        "b": jnp.arange(8.0),
        "step": jnp.asarray(7, jnp.int32),
    }
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, 7, tree)
        shardings = {
            "w": NamedSharding(mesh_b, P("data", "model")),
            "b": NamedSharding(mesh_b, P()),
            "step": NamedSharding(mesh_b, P()),
        }
        restored, manifest = ck.restore(d, tree, shardings=shardings)
        assert manifest["step"] == 7
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.arange(64.0).reshape(8, 8))
        assert restored["w"].sharding.mesh.shape["data"] == 2
    ok("elastic_reshard_restore")


def check_serve_compress_bucketed():
    """The serve engine's grouped KV compression — one ``hopm3_batched``
    chain per same-view group — must be BITWISE equal to per-slot ``hopm3``
    under the order-explicit ``mulsum`` engine, with the recorded launch
    accounting independent of the group size, and the whole serve run
    (tokens + compressed factors) deterministic across repeats."""
    from repro.configs import get_config
    from repro.core.memory_model import dhopm_launches_per_sweep
    from repro.models import registry
    from repro.serve import DecodeEngine, Request, RequestQueue
    from repro.serve.engine import _compress_group

    # bitwise seam: a mixed bucket of views, grouped exactly as the engine
    # groups retired contexts
    rng = np.random.default_rng(23)
    view = (2, 2, 16, 8)
    for B in (3, 9):
        A_b = jnp.asarray(rng.standard_normal((B,) + view), np.float32)
        xs0 = [dh.hopm_init_factors(jax.random.PRNGKey(i), view)[0]
               for i in range(B)]
        xs_b = tuple(jnp.stack([x[m] for x in xs0])
                     for m in range(len(view)))
        xs, lam = _compress_group(A_b, xs_b, sweeps=2, impl="mulsum")
        for b in range(B):
            x1, l1 = dh.hopm3(A_b[b], list(xs0[b]), sweeps=2, impl="mulsum")
            assert np.array_equal(np.asarray(lam[b]), np.asarray(l1))
            for m in range(len(view)):
                assert np.array_equal(np.asarray(xs[m][b]),
                                      np.asarray(x1[m])), (B, b, m)

    # end-to-end: the engine's accounting and outputs repeat bitwise
    cfg = get_config("qwen2-1.5b", smoke=True)
    params = registry.get(cfg.family).init(cfg, jax.random.PRNGKey(0))
    eng = DecodeEngine(cfg, params, batch_size=4, max_seq=64, eos_id=7)

    def one_run():
        q = RequestQueue(
            Request(rid=i,
                    tokens=np.arange(3 + i % 4, dtype=np.int32) + 1,
                    max_new_tokens=4)
            for i in range(8))
        return eng.serve(q, temperature=0.6, seed=0, compress=True,
                         comp_sweeps=2, comp_impl="mulsum")

    res1, st1 = one_run()
    res2, st2 = one_run()
    # launch accounting depends only on the view order, never group size
    want = sum(2 * dhopm_launches_per_sweep(len(v))
               for _b, v in st1.comp_events)
    assert st1.comp_launches == want, (st1.comp_launches, want)
    assert st1.comp_events == st2.comp_events
    m1 = {r.rid: r for r in res1}
    m2 = {r.rid: r for r in res2}
    for rid, r1 in m1.items():
        r2 = m2[rid]
        assert np.array_equal(r1.tokens, r2.tokens), rid
        for leaf, c1 in r1.compressed.items():
            c2 = r2.compressed[leaf]
            assert np.array_equal(np.asarray(c1.lam), np.asarray(c2.lam))
            for a, b in zip(c1.xs, c2.xs):
                assert np.array_equal(np.asarray(a), np.asarray(b))
    ok("serve_compress_bucketed_bitwise")


def check_grad_compress_arena_bitwise():
    """The donation-arena bucket assembly (``assemble_rows`` — a
    dynamic-update-slice chain instead of ``jnp.stack``) must reproduce the
    stacked bucket path AND the per-leaf reference loop bit for bit on a
    real 8-way DP mesh, split-annotated (ZeRO-style sharded) leaves
    included — the arena only changes HOW the ``[B, ...]`` operand is
    materialized, never its values, so the mulsum chains see identical
    inputs."""
    import dataclasses
    from repro.train import grad_compress as gc
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((8,), ("x",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.default_rng(29)
    splits = (("['qa']", 1), ("['qb']", 1))
    ccfg = gc.CompressorCfg(rank=2, sweeps=2, min_size=32, prec="f32",
                            splits=splits, split_world=8, bucket=True,
                            arena=True)
    # one partial-mode bucket (q, k, v) + one split bucket (qa, qb)
    params_like = {"q": jnp.zeros((12, 16), jnp.float32),
                   "k": jnp.zeros((12, 16), jnp.float32),
                   "v": jnp.zeros((12, 16), jnp.float32),
                   "qa": jnp.zeros((16, 8), jnp.float32),
                   "qb": jnp.zeros((16, 8), jnp.float32)}
    G = {k: rng.normal(size=(16, 64)).astype(np.float32)
         for k in ("qa", "qb")}
    grads = {n: jnp.asarray(rng.normal(size=(8,) + params_like[n].shape)
                            .astype(np.float32)) for n in ("q", "k", "v")}
    grads.update({k: jnp.stack([jnp.asarray(G[k][:, r * 8:(r + 1) * 8])
                                for r in range(8)]) for k in ("qa", "qb")})
    state = gc.init_state(params_like, ccfg)

    def run(cfg):
        def body(gl):
            g_local = {n: g[0] for n, g in gl.items()}
            synced, new_state, _ = gc.compress_and_sync(
                g_local, state, cfg, "x")
            return (jax.tree.map(lambda t: t[None], synced),
                    jax.tree.map(lambda t: t[None], new_state))

        fn = jax.shard_map(
            body, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P("x"), grads),),
            out_specs=(jax.tree.map(lambda _: P("x"), grads),
                       jax.tree.map(lambda _: P("x"), state)),
            check_vma=False)
        return jax.jit(fn)(grads)

    got_arena = run(ccfg)
    got_stack = run(dataclasses.replace(ccfg, arena=False))
    got_leaf = run(dataclasses.replace(ccfg, bucket=False))
    for a, b in zip(jax.tree.leaves(got_arena), jax.tree.leaves(got_stack)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(got_arena), jax.tree.leaves(got_leaf)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    ok("grad_compress_arena_bitwise")


def check_serve_compress_arena_bitwise():
    """The serve engine's arena-assembled retirement compression (fused
    donated fill straight from the slot-stacked cache) must reproduce the
    stacked assembly bit for bit across a full continuous-batching run —
    identical tokens AND identical rank-1 factors, through mid-generation
    slot recycling and warm arena reuse across retirement events."""
    from repro.configs import get_config
    from repro.models import registry
    from repro.serve import DecodeEngine, Request, RequestQueue

    cfg = get_config("qwen2-1.5b", smoke=True)
    params = registry.get(cfg.family).init(cfg, jax.random.PRNGKey(0))

    def run(comp_arena):
        eng = DecodeEngine(cfg, params, batch_size=4, max_seq=64, eos_id=7)
        q = RequestQueue(
            Request(rid=i,
                    tokens=np.arange(3 + i % 4, dtype=np.int32) + 1,
                    max_new_tokens=4)
            for i in range(10))
        res, st = eng.serve(q, temperature=0.6, seed=0, compress=True,
                            comp_sweeps=2, comp_impl="mulsum",
                            comp_arena=comp_arena)
        return res, st, eng

    res_a, st_a, eng_a = run(True)
    res_s, st_s, _ = run(False)
    assert st_a.recycled > 0 and st_a.recycled == st_s.recycled
    assert st_a.comp_events == st_s.comp_events        # same grouping
    assert st_a.comp_launches == st_s.comp_launches
    # the arena really ran: fills recorded, warm reuse after the cold ones
    assert st_a.arena_fills > 0
    assert st_a.arena_fills > st_a.arena_cold_fills
    assert st_a.stack_copy_removed_bytes > 0
    assert st_s.arena_fills == 0 and st_s.stack_copy_removed_bytes == 0
    ma = {r.rid: r for r in res_a}
    ms = {r.rid: r for r in res_s}
    for rid, ra in ma.items():
        rs = ms[rid]
        assert np.array_equal(ra.tokens, rs.tokens), rid
        for leaf, ca in ra.compressed.items():
            cs = rs.compressed[leaf]
            assert np.array_equal(np.asarray(ca.lam), np.asarray(cs.lam))
            for a, b in zip(ca.xs, cs.xs):
                assert np.array_equal(np.asarray(a), np.asarray(b)), \
                    (rid, leaf)
    ok("serve_compress_arena_bitwise")


def check_slot_recycle_prefill_sharded():
    """Continuous batching on a (data, model) mesh — slot-stacked caches
    sharded over the data axis, per-slot prefill scattered into the sharded
    tree — must complete the same request stream with the same greedy
    tokens as the unsharded engine, through multiple slot-recycle cycles."""
    from repro.configs import get_config
    from repro.models import registry
    from repro.serve import DecodeEngine, Request, RequestQueue

    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = get_config("qwen2-1.5b", smoke=True)
    params = registry.get(cfg.family).init(cfg, jax.random.PRNGKey(0))

    def run(m):
        eng = DecodeEngine(cfg, params, mesh=m, batch_size=4, max_seq=64,
                           eos_id=7)
        q = RequestQueue(
            Request(rid=i,
                    tokens=np.arange(2 + i % 5, dtype=np.int32) + 1,
                    max_new_tokens=5)
            for i in range(10))
        return eng.serve(q, temperature=0.0, seed=0, compress=True,
                         comp_sweeps=1, comp_impl="mulsum")

    res_m, st_m = run(mesh)
    res_h, st_h = run(None)
    assert st_m.completed == st_h.completed == 10
    assert st_m.recycled > 0 and st_m.recycled == st_h.recycled
    assert st_m.comp_events == st_h.comp_events
    mm_ = {r.rid: r for r in res_m}
    mh = {r.rid: r for r in res_h}
    for rid, rh in mh.items():
        assert np.array_equal(mm_[rid].tokens, rh.tokens), rid
    ok("slot_recycle_prefill_sharded")


def check_verify_static_gate_p8():
    """The static verifier's p=8 entry points re-traced over a REAL
    8-device mesh: concrete shard_map lowering must satisfy the same
    launch-count / collective-schedule / wire-demotion / no-pad contracts
    the AbstractMesh traces prove in the single-device static gate."""
    from repro.verify import run_verify

    report = run_verify(tags=["p8"], real_mesh=True)
    assert report["summary"]["entrypoints"] >= 7, report["summary"]
    assert report["ok"], [
        f for r in report["entrypoints"] for f in r["findings"]]
    ok("verify_static_gate_p8")


if __name__ == "__main__":
    main()
