"""Donation-aware batched-operand arena: closed forms, buffer lifecycle,
bitwise equality of arena vs stacked bucket assembly (grad_compress and the
serve engine's retirement groups), the no-concatenate jaxpr guarantee, the
counted-trace regression of the assembly-copy pricing, and fill-order
determinism across hash salts."""
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import memory_model as mm
from repro.core.arena import BatchedArena, assemble_rows
from repro.plan import planner
from repro.train import grad_compress as gc
from repro.verify.walker import collect_eqns


# ---- closed forms ----------------------------------------------------------

def test_bucket_stack_elems_closed_form():
    # 2 x b x prod(view) operand round trip + 2 x ranks x b x sum(view)
    # factor gathers
    assert mm.bucket_stack_elems(3, (8, 6)) == 2 * 3 * 48 + 2 * 3 * 14
    assert mm.bucket_stack_elems(3, (8, 6), ranks=2) \
        == 2 * 3 * 48 + 2 * 2 * 3 * 14
    assert mm.bucket_stack_elems(1, (4,)) == 2 * 4 + 2 * 4


def test_arena_fill_elems_warm_is_free_cold_is_one_stack():
    # a warm fill's scatter write aliases the row materialization the
    # stacked path also pays; only the first (cold) allocation stacks
    assert mm.arena_fill_elems(3, (8, 6), ranks=2) == 0
    assert mm.arena_fill_elems(3, (8, 6), ranks=2, cold=True) \
        == mm.bucket_stack_elems(3, (8, 6), ranks=2)


# ---- assemble_rows (in-trace fill) ----------------------------------------

def test_assemble_rows_matches_stack_bitwise():
    rng = np.random.default_rng(3)
    rows = [jnp.asarray(rng.standard_normal((5, 7)), np.float32)
            for _ in range(4)]
    got = assemble_rows(rows)
    want = jnp.stack(rows)
    assert got.dtype == want.dtype and got.shape == want.shape
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_assemble_rows_no_concatenate_in_jaxpr():
    rows = [jnp.zeros((5, 7), jnp.float32) for _ in range(4)]
    jx = jax.make_jaxpr(lambda *rs: assemble_rows(rs))(*rows)
    prims = {e.primitive.name for e in jx.jaxpr.eqns}
    assert "concatenate" not in prims, prims
    stacked = jax.make_jaxpr(lambda *rs: jnp.stack(rs))(*rows)
    assert any(e.primitive.name == "concatenate"
               for e in stacked.jaxpr.eqns)


def test_assemble_rows_empty_raises():
    with pytest.raises(ValueError):
        assemble_rows([])


# ---- BatchedArena lifecycle ------------------------------------------------

def test_arena_cold_then_warm_and_removed_bytes():
    ar = BatchedArena()
    rows = [jnp.full((4, 3), float(i)) for i in range(2)]
    b1 = ar.fill_rows("t", rows)
    assert np.array_equal(np.asarray(b1), np.asarray(jnp.stack(rows)))
    assert ar.stats.fills == 1 and ar.stats.cold_fills == 1
    # cold fill removes nothing (it pays one stack itself)
    assert ar.stats.stack_copy_removed_bytes == 0
    assert ar.stats.fill_events == [[2, [4, 3], 1]]
    b2 = ar.fill_rows("t", [r + 1 for r in rows])
    assert np.array_equal(np.asarray(b2),
                          np.asarray(jnp.stack([r + 1 for r in rows])))
    assert ar.stats.cold_fills == 1 and ar.stats.fills == 2
    # warm fill removes exactly one stack's worth
    assert ar.stats.stack_copy_removed_bytes \
        == mm.bucket_stack_elems(2, (4, 3)) * 4
    assert len(ar) == 1


def test_arena_key_table_overflow_falls_back():
    ar = BatchedArena(max_keys=2)
    assert ar.fill_rows("a", [jnp.zeros((2,))]) is not None
    assert ar.fill_rows("b", [jnp.zeros((3,))]) is not None
    # table full: a NEW key refuses (caller stacks), existing keys still hit
    assert ar.fill_rows("c", [jnp.zeros((4,))]) is None
    assert ar.stats.stack_fallbacks == 1
    assert ar.fill_rows("a", [jnp.ones((2,))]) is not None


def test_arena_account_false_records_no_event():
    ar = BatchedArena()
    ar.fill_rows("x", [jnp.zeros((3,))], account=False)
    assert ar.stats.fills == 0 and ar.stats.fill_events == []
    assert len(ar) == 1


def test_arena_reset_and_nbytes():
    ar = BatchedArena()
    ar.fill_rows("t", [jnp.zeros((4, 3), jnp.float32)] * 2)
    assert ar.nbytes() == 2 * 12 * 4
    ar.reset()
    assert len(ar) == 0 and ar.nbytes() == 0 and ar.stats.fills == 0


# ---- planner arena resolution ----------------------------------------------

def test_planner_arena_rule():
    view = (64, 48)
    p = planner.plan_compress(8, view)
    assert p.bucket and p.arena          # bucketed B > 1 group: arena
    assert planner.plan_compress(1, view).arena is False   # singleton
    assert planner.plan_compress(8, view, churn=True).arena is False
    # the cell dict deliberately excludes the arena field (committed cells
    # from earlier schemas must still recompute verbatim)
    assert "arena" not in p.as_cell_dict()


# ---- grad_compress: arena == stacked == per-leaf, p = 1 --------------------

def _grad_setup(nleaves=3, view=(8, 6), extra=None):
    import dataclasses  # noqa: F401
    params = {f"w{i}": jnp.zeros(view, jnp.float32) for i in range(nleaves)}
    if extra:
        params.update(extra)
    key = jax.random.PRNGKey(0)
    grads = {k: jax.random.normal(jax.random.fold_in(key, i), v.shape,
                                  v.dtype)
             for i, (k, v) in enumerate(params.items())}
    return params, grads


def _run_p1(cfg, params, grads):
    mesh = jax.make_mesh((1,), ("dp",))
    state = gc.init_state(params, cfg)

    def body(g):
        ng, ns, _ = gc.compress_and_sync(g, state, cfg, "dp")
        return ng, ns

    fn = jax.shard_map(body, mesh=mesh, in_specs=(P(),),
                       out_specs=(P(), P()), check_vma=False)
    return jax.jit(fn)(grads)


def test_grad_arena_bitwise_p1():
    import dataclasses
    cfg = gc.CompressorCfg(rank=2, sweeps=2, min_size=16, prec="f32",
                           bucket=True, arena=True)
    params, grads = _grad_setup()
    got_a = _run_p1(cfg, params, grads)
    got_s = _run_p1(dataclasses.replace(cfg, arena=False), params, grads)
    got_l = _run_p1(dataclasses.replace(cfg, bucket=False), params, grads)
    for a, b in zip(jax.tree.leaves(got_a), jax.tree.leaves(got_s)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(got_a), jax.tree.leaves(got_l)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def _grad_trace_eqns(cfg, params, grads):
    mesh = jax.make_mesh((1,), ("dp",))
    state = gc.init_state(params, cfg)

    def body(g):
        ng, ns, _ = gc.compress_and_sync(g, state, cfg, "dp")
        return ng, ns

    fn = jax.shard_map(body, mesh=mesh, in_specs=(P(),),
                       out_specs=(P(), P()), check_vma=False)
    return collect_eqns(jax.make_jaxpr(fn)(grads))


def test_grad_arena_step_jaxpr_has_no_stack():
    """The acceptance-criterion trace check: the arena step's jaxpr carries
    NO concatenate (the primitive jnp.stack lowers to) anywhere, while the
    stacked step's does — the bucket members are scattered in place."""
    import dataclasses
    cfg = gc.CompressorCfg(rank=2, sweeps=2, min_size=16, prec="f32",
                           bucket=True, arena=True)
    params, grads = _grad_setup()
    eq_a = _grad_trace_eqns(cfg, params, grads)
    eq_s = _grad_trace_eqns(dataclasses.replace(cfg, arena=False),
                            params, grads)
    n_a = sum(e.primitive.name == "concatenate" for e in eq_a)
    n_s = sum(e.primitive.name == "concatenate" for e in eq_s)
    assert n_a == 0, f"arena trace still concatenates ({n_a} eqns)"
    assert n_s > 0, "stacked trace lost its concatenates (test is vacuous)"


def test_assembly_pricing_matches_counted_trace():
    """wire_bytes_summary's assembly_stack_bytes must equal the counted
    concatenate traffic (read + write elements x 4) of the stacked step's
    actual trace — the closed form prices what the runtime really copies."""
    import dataclasses
    cfg = gc.CompressorCfg(rank=2, sweeps=2, min_size=16, prec="f32",
                           bucket=True, arena=False)
    params, grads = _grad_setup()
    eqns = _grad_trace_eqns(cfg, params, grads)
    counted = sum(
        (int(np.prod(e.outvars[0].aval.shape))
         + sum(int(np.prod(v.aval.shape)) for v in e.invars))
        for e in eqns if e.primitive.name == "concatenate") * 4
    summary = gc.wire_bytes_summary(params, cfg, 1)
    assert summary["assembly_stack_bytes"] == counted, \
        (summary["assembly_stack_bytes"], counted)
    assert counted == mm.bucket_stack_elems(3, (8, 6), ranks=2) * 4


def test_wire_summary_arena_fields():
    cfg = gc.CompressorCfg(rank=2, sweeps=2, min_size=16, prec="f32",
                           bucket=True, arena=True)
    params, _ = _grad_setup()
    s = gc.wire_bytes_summary(params, cfg, 1)
    want = mm.bucket_stack_elems(3, (8, 6), ranks=2) * 4
    assert s["assembly_stack_bytes"] == want
    assert s["assembly_bytes"] == 0                 # warm arena fills: free
    assert s["stack_copy_removed_bytes"] == want
    # singleton buckets never bucket, so nothing is priced either way
    solo = {"w0": jnp.zeros((8, 6), jnp.float32)}
    s1 = gc.wire_bytes_summary(solo, cfg, 1)
    assert s1["assembly_stack_bytes"] == 0
    assert s1["stack_copy_removed_bytes"] == 0


# ---- serve fill-order determinism across hash salts ------------------------

_ARENA_DIGEST = r"""
import zlib
import numpy as np
import jax
from repro.configs import get_config
from repro.models import registry
from repro.serve import DecodeEngine, Request, RequestQueue

cfg = get_config("qwen2-1.5b", smoke=True)
params = registry.get(cfg.family).init(cfg, jax.random.PRNGKey(0))
eng = DecodeEngine(cfg, params, batch_size=2, max_seq=64, eos_id=7)
q = RequestQueue(Request(rid=f"req-{i}",
                         tokens=np.arange(3 + i % 3, dtype=np.int32) + 1,
                         max_new_tokens=3)
                 for i in range(6))
res, stats = eng.serve(q, temperature=0.8, seed=0, compress=True,
                       comp_sweeps=1, comp_impl="mulsum", comp_arena=True)
assert stats.recycled > 0 and stats.arena_fills > 0
buf = repr(eng._arena.stats.fill_events).encode()
buf += repr(stats.stack_copy_removed_bytes).encode()
buf += b"".join(
    np.asarray(r.tokens).tobytes()
    + b"".join(np.asarray(x).tobytes()
               for c in sorted(r.compressed) for x in r.compressed[c].xs)
    for r in sorted(res, key=lambda r: r.rid))
print(zlib.crc32(buf))
"""


def test_arena_fill_order_determinism_across_hash_seeds():
    """The arena's fill events (order, sizes, cold/warm pattern) and the
    served outputs must be identical under different PYTHONHASHSEED salts —
    grouping iterates insertion-ordered dicts keyed by crc32-stable
    identities, never salted hash()."""
    root = pathlib.Path(__file__).resolve().parent.parent
    digests = []
    for salt in ("0", "424242"):
        env = dict(os.environ)
        env["PYTHONHASHSEED"] = salt
        env["PYTHONPATH"] = str(root / "src")
        proc = subprocess.run(
            [sys.executable, "-c", _ARENA_DIGEST],
            capture_output=True, text=True, env=env, timeout=600)
        assert proc.returncode == 0, proc.stderr[-2000:]
        digests.append(proc.stdout.strip())
    assert digests[0] == digests[1], digests
