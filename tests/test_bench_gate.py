"""Unit tests for the CI bandwidth-regression gate (benchmarks/check_bench)
and the offline block sweep (repro.kernels.sweep + block_table round trip).
The gate's job: recorded streamed bytes must never exceed the memory_model
prediction, fused pairs must predict a real saving, and real-engine timings
must stay inside the dispatch-overhead-aware traffic ceiling."""
import json
import pathlib
import sys

import numpy as np
import pytest
import jax.numpy as jnp

ROOT = pathlib.Path(__file__).resolve().parent.parent
if str(ROOT) not in sys.path:  # `python -m pytest` from the repo root has it
    sys.path.insert(0, str(ROOT))

from benchmarks import check_bench  # noqa: E402
from repro.core import memory_model as mm  # noqa: E402
from repro.kernels import block_table, sweep  # noqa: E402


def _cell(kind="tvc", shape=(7, 13, 129), mode=1, dtype="f32", us=100.0,
          peak=10.0, **over):
    itemsize = 4 if dtype == "f32" else 2
    if kind == "tvc":
        u = int(np.prod(shape[:mode]))
        v = int(np.prod(shape[mode + 1:]))
        nbytes = mm.tvc_streamed_elems(u, shape[mode], v) * itemsize
        extra = {"pad_overhead": 1.5}
    else:
        u = int(np.prod(shape[:mode]))
        v = int(np.prod(shape[mode + 2:]))
        nbytes = mm.tvc2_streamed_elems(u, shape[mode], shape[mode + 1],
                                        v) * itemsize
        extra = {"fused_saving": mm.fused_pair_saving(
            u, shape[mode], shape[mode + 1], v)}
    gbs = nbytes / (us * 1e-6) / 1e9
    cell = {
        "kind": kind, "order": len(shape), "mode": mode, "dtype": dtype,
        "layout": "ragged", "shape": list(shape), "blocks": [8, 8, 128],
        "streamed_bytes": nbytes, "us": us, "gbs": gbs,
        "pct_peak": gbs / peak * 100.0, **extra,
    }
    cell.update(over)
    return cell


def _payload(cells, engine="pallas-interpret", peak=10.0, schema=2):
    return {
        "meta": {"schema": schema, "engine": engine, "backend": "cpu",
                 "smoke": True},
        "stream_triad_gbs": peak,
        "cells": cells,
    }


def _run(payload, ref=None, **kw):
    kw.setdefault("acct_tol", 0.0)
    kw.setdefault("dispatch_us", 200.0)
    kw.setdefault("ratio_pallas", 2.0)
    kw.setdefault("ratio_native", 32.0)
    return check_bench.check(payload, ref, **kw)


def test_gate_green_on_consistent_payload():
    p = _payload([_cell(), _cell(kind="tvc2", mode=0)])
    assert _run(p, ref=p) == []


def test_gate_fails_on_inflated_streamed_bytes():
    c = _cell()
    c["streamed_bytes"] = int(c["streamed_bytes"] * 1.5)  # accounting drift
    fails = _run(_payload([c]))
    assert len(fails) == 1 and "exceeds model prediction" in fails[0]
    # tolerance forgives it
    assert _run(_payload([c]), acct_tol=0.6) == []


def test_gate_fails_on_schema_mismatch_and_missing_keys():
    p = _payload([_cell()])
    ref = _payload([_cell()], schema=1)
    assert any("schema" in f for f in _run(p, ref=ref))
    c = _cell()
    del c["streamed_bytes"]
    assert any("missing keys" in f for f in _run(_payload([c])))
    c2 = _cell(kind="tvc2", mode=0)
    del c2["fused_saving"]
    assert any("missing keys" in f for f in _run(_payload([c2])))
    assert any("no cells" in f for f in _run(_payload([])))


def test_gate_fails_when_fused_pair_saves_nothing():
    c = _cell(kind="tvc2", mode=0, fused_saving=1.0)
    assert any("no saving" in f for f in _run(_payload([c])))


def test_gate_time_implied_traffic_is_engine_and_dispatch_aware():
    # 100 us at 10 GB/s peak = 1 MB implied on a ~36 KB cell: a huge ratio
    slow = _cell(us=100.0)
    # interpret timings are skipped entirely
    assert _run(_payload([slow], engine="pallas-interpret")) == []
    # on a real engine the same cell fails ...
    fails = _run(_payload([slow], engine="pallas"), dispatch_us=0.0)
    assert any("time-implied" in f for f in fails)
    # ... unless the dispatch allowance covers it (ROADMAP small-cell caveat)
    assert _run(_payload([slow], engine="pallas"), dispatch_us=200.0) == []
    # native-xla gets the loose catastrophic bound + low-precision factor
    assert _run(_payload([slow], engine="native-xla"), dispatch_us=0.0,
                ratio_native=64.0) == []


def _batched_cell(B=64, shape=(16, 16, 16), mode=2, dtype="f32", us=100.0,
                  sep_us=400.0, peak=10.0, **over):
    itemsize = 4 if dtype == "f32" else 2
    u = int(np.prod(shape[:mode]))
    v = int(np.prod(shape[mode + 1:]))
    one = mm.tvc_streamed_elems(u, shape[mode], v) * itemsize
    nbytes = B * one
    gbs = nbytes / (us * 1e-6) / 1e9
    cell = {
        "kind": "tvc_batched", "order": len(shape), "mode": mode,
        "dtype": dtype, "layout": "aligned", "shape": list(shape),
        "engine": "native-xla", "batch": B, "blocks": [8, 8, 8, 128],
        "streamed_bytes": nbytes, "us": us, "sep_us": sep_us, "gbs": gbs,
        "pct_peak": gbs / peak * 100.0, "batched_speedup": sep_us / us,
        "predicted_speedup": mm.launch_amortized_speedup(B, one, peak,
                                                         200.0),
    }
    cell.update(over)
    return cell


def test_gate_green_with_batched_cells():
    p = _payload([_cell(), _batched_cell()])
    assert _run(p, ref=p) == []


def test_gate_batched_speedup_geomean():
    # geomean of (0.5, 0.9) < 1: the batched path lost to B separate
    # launches -> fail, and the message names both cells' speedups
    losing = [_batched_cell(us=200.0, sep_us=100.0),
              _batched_cell(mode=1, us=100.0, sep_us=90.0)]
    fails = _run(_payload(losing))
    assert any("geomean" in f for f in fails)
    # one noisy cell is tolerated as long as the aggregate still wins
    mixed = [_batched_cell(us=200.0, sep_us=100.0),
             _batched_cell(mode=1, us=100.0, sep_us=500.0)]
    assert _run(_payload(mixed)) == []
    # small-B cells are never speedup-gated (noise-prone)
    small = [_batched_cell(B=8, us=200.0, sep_us=100.0)]
    assert _run(_payload(small)) == []


def test_gate_batched_predicted_speedup_and_keys():
    c = _batched_cell(predicted_speedup=0.9)
    assert any("predicts no win" in f for f in _run(_payload([c])))
    c = _batched_cell()
    del c["sep_us"]
    assert any("missing keys" in f for f in _run(_payload([c])))


def test_gate_batched_cells_use_their_own_engine_tag():
    """A batched cell is ceiling-checked with its OWN engine even inside an
    interpret-mode smoke payload, and gets exactly ONE dispatch allowance."""
    # 10 ms on a ~1 MB batched cell (100 MB implied at 10 GB/s) busts the
    # 32x ceiling with one 200 us (2 MB) allowance
    slow = _batched_cell(us=10_000.0, sep_us=50_000.0)
    fails = _run(_payload([slow], engine="pallas-interpret"))
    assert any("time-implied" in f and "native-xla" in f for f in fails)
    # B allowances would have forgiven it: 64 * 200 us * 10 GB/s = 128 MB
    assert _run(_payload([slow], engine="pallas-interpret"),
                dispatch_us=64 * 200.0) == []


def test_gate_batched_predicted_bytes():
    c = _batched_cell()
    assert check_bench.predicted_bytes(c) == c["streamed_bytes"]
    assert check_bench.predicted_bytes(c) == \
        mm.tvc_batched_streamed_elems(64, 256, 16, 1) * 4


def _overlap_cell(shape=(8, 8, 8, 8), fused=False, us=40.0, sync_us=36.0,
                  peak=10.0, chunks=4, model_p=8, **over):
    d = len(shape)
    s = d - 1
    nbytes = int(mm.simulate_sweep(
        shape[0], d, 1, s, "hopm3_fused" if fused else "hopm3",
        split_alive=True, overlap_chunks=chunks)) * 4
    model = mm.dhopm_time_sweep(shape, model_p, 4, split=s,
                                overlap_chunks=chunks, peak_gbs=peak,
                                wire_gbs=peak / 8.0, dispatch_us=0.0)
    gbs = nbytes / (us * 1e-6) / 1e9
    cell = {
        "kind": "dhopm3_overlap", "order": d, "mode": s, "dtype": "f32",
        "layout": "aligned", "shape": list(shape), "engine": "native-xla",
        "sweeps": 1, "p": 1, "split": s, "fused": fused,
        "overlap_chunks": chunks,
        "launches": mm.dhopm_launches_per_sweep(d, s, fused,
                                                overlap_chunks=chunks),
        "sync_launches": mm.dhopm_launches_per_sweep(d, s, fused),
        "blocks": [], "streamed_bytes": nbytes, "us": us, "sync_us": sync_us,
        "gbs": gbs, "pct_peak": gbs / peak * 100.0,
        "overlap_speedup": sync_us / us,
        "model_p": model_p, "model_wire_gbs": peak / 8.0,
        "model_dispatch_us": 0.0,
        "predicted_wire_us": model["wire_us"],
        "predicted_exposed_us": model["exposed_wire_us"],
        "predicted_hidden_us": model["hidden_wire_us"],
    }
    cell.update(over)
    return cell


def test_gate_green_with_overlap_cells():
    p = _payload([_cell(), _overlap_cell(), _overlap_cell(fused=True)])
    assert _run(p, ref=p) == []


def test_gate_overlap_launch_count_recompute():
    c = _overlap_cell(launches=99)
    assert any("launch counts" in f for f in _run(_payload([c])))
    c = _overlap_cell(sync_launches=1)
    assert any("launch counts" in f for f in _run(_payload([c])))


def test_gate_overlap_model_recompute_and_hiding():
    # drifted prediction: the recorded numbers must be reproducible from
    # the cell's model inputs bit-for-bit
    c = _overlap_cell()
    c["predicted_exposed_us"] *= 1.01
    assert any("recomputed dhopm_time_sweep" in f for f in _run(_payload([c])))
    # a config where the model predicts no hiding must fail: chunks=1 makes
    # the whole wire exposed (hidden == 0)
    c = _overlap_cell(chunks=1)
    assert any("predicts no wire hiding" in f for f in _run(_payload([c])))


def test_gate_overlap_speedup_floor():
    # 0.1 geomean: pathological pipeline cost -> fail
    slow = [_overlap_cell(us=400.0, sync_us=40.0),
            _overlap_cell(fused=True, us=400.0, sync_us=40.0)]
    fails = _run(_payload(slow))
    assert any("overlap_speedup" in f and "floor" in f for f in fails)
    # above the floor (even if < 1, the expected p = 1 regime) is green
    okc = [_overlap_cell(us=50.0, sync_us=36.0)]
    assert _run(_payload(okc)) == []
    # the floor is tunable
    assert _run(_payload(okc), overlap_speedup_min=0.9) != []


def test_gate_overlap_predicted_bytes():
    c = _overlap_cell()
    assert check_bench.predicted_bytes(c) == c["streamed_bytes"]
    c2 = _overlap_cell(fused=True)
    assert check_bench.predicted_bytes(c2) == c2["streamed_bytes"]
    # the overlap form strictly exceeds the sync form (extra vector re-reads)
    sync = int(mm.simulate_sweep(8, 4, 1, 3, "hopm3", split_alive=True)) * 4
    assert c["streamed_bytes"] > sync


def test_gate_overlap_missing_keys():
    c = _overlap_cell()
    del c["predicted_hidden_us"]
    assert any("missing keys" in f for f in _run(_payload([c])))


def test_gate_runs_green_on_committed_trajectory():
    path = ROOT / "BENCH_TVC.json"
    payload = json.loads(path.read_text())
    assert _run(payload, ref=payload) == []


def test_gate_main_exit_codes(tmp_path):
    good = _payload([_cell()])
    f = tmp_path / "b.json"
    f.write_text(json.dumps(good))
    assert check_bench.main([str(f)]) == 0
    bad = _payload([_cell(streamed_bytes=10**12)])
    f.write_text(json.dumps(bad))
    assert check_bench.main([str(f)]) == 1


# ---- sweep + table round trip ---------------------------------------------

def test_sweep_candidates_fit_budget_and_include_heuristic():
    from repro.kernels import autotune
    for kind, dims in [("tvc3", (16, 32, 200)), ("tvc2", (64, 300)),
                       ("tvc2_pair", (16, 8, 200)), ("tvc4", (4, 8, 8, 130))]:
        cands = sweep.candidates(kind, dims, max_candidates=12)
        assert 1 <= len(cands) <= 12
        assert len(set(cands)) == len(cands)
        heur = sweep._heuristic(kind, dims, jnp.float32, jnp.float32, False,
                                autotune.vmem_budget(None))
        assert cands[0] == heur


def test_sweep_case_times_every_candidate_and_ranks():
    best, results = sweep.sweep_case("tvc2_pair", (8, 5, 9), reps=1,
                                    max_candidates=4)
    assert best is results[0]
    assert all(r.seconds >= best.seconds for r in results)
    assert best.gbs > 0
    want = sweep.streamed_bytes("tvc2_pair", (8, 5, 9), jnp.float32)
    assert want == mm.tvc2_streamed_elems(8, 5, 9, 1) * 4


def test_block_table_save_load_roundtrip(tmp_path):
    path = tmp_path / "table.json"
    e = block_table.entry("tvc3", (16, 32, 200), (8, 32, 256), jnp.float32,
                          gbs=3.0, order=3, mode_class="inner",
                          backend="cpu")
    block_table.save([e], path)
    block_table.clear()
    got = block_table.lookup("tvc3", (16, 32, 200), jnp.float32,
                             backend="cpu", path=path)
    assert got == (8, 32, 256)
    # same buckets, different extents: still the same winner
    assert block_table.lookup("tvc3", (9, 20, 129), jnp.float32,
                              backend="cpu", path=path) == (8, 32, 256)
    # other dtype / backend / kind: miss
    assert block_table.lookup("tvc3", (16, 32, 200), jnp.bfloat16,
                              backend="cpu", path=path) is None
    assert block_table.lookup("tvc3", (16, 32, 200), jnp.float32,
                              backend="tpu", path=path) is None
    assert block_table.lookup("tvc2_pair", (16, 32, 200), jnp.float32,
                              backend="cpu", path=path) is None
    block_table.clear()


def test_pinned_entry_outranks_file(tmp_path):
    """pin()'s contract: a fresh pinned entry wins even when the file holds
    a higher-gbs entry for the same cell; corrupt files raise, absent files
    mean heuristic-only."""
    path = tmp_path / "table.json"
    filed = block_table.entry("tvc3", (16, 32, 200), (8, 32, 256),
                              jnp.float32, gbs=500.0, backend="cpu")
    block_table.save([filed], path)
    block_table.clear()
    block_table.pin(block_table.entry("tvc3", (16, 32, 200), (16, 32, 128),
                                      jnp.float32, backend="cpu"))  # gbs 0.0
    assert block_table.lookup("tvc3", (16, 32, 200), jnp.float32,
                              backend="cpu", path=path) == (16, 32, 128)
    block_table.clear()
    assert block_table.lookup("tvc3", (16, 32, 200), jnp.float32,
                              backend="cpu", path=path) == (8, 32, 256)
    block_table.clear()
    assert block_table.lookup("tvc3", (16, 32, 200), jnp.float32,
                              backend="cpu",
                              path=tmp_path / "absent.json") is None
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    block_table.clear()
    with pytest.raises(ValueError, match="corrupt block table"):
        block_table.load(bad)
    block_table.clear()


def test_committed_block_table_parses():
    entries = block_table.load(block_table.DEFAULT_PATH)
    assert entries, "checked-in block_table.json is empty"
    for e in entries:
        assert e["kind"] in block_table.KINDS
        assert len(e["blocks"]) == len(e["dims"])
        assert e["backend"]
    block_table.clear()


def test_smoke_writer_matches_gate_prediction():
    """predicted_bytes agrees with the model for both kinds (the invariant
    the smoke gate enforces end-to-end in CI)."""
    c = _cell()
    assert check_bench.predicted_bytes(c) == c["streamed_bytes"]
    c2 = _cell(kind="tvc2", mode=0)
    assert check_bench.predicted_bytes(c2) == c2["streamed_bytes"]
