"""Mixed-precision wire collectives: analytic cost models + single-device
semantics.  (The 8-device numerical checks — ring/doubling exactness in f32,
bf16-wire error bounds, and mp_allreduce-vs-psum — run in the subprocess
suite, tests/_dist_checks.py via tests/test_distributed.py.)"""
import math

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.mixed_precision import BF16_F32, F32
from repro.dist import collectives as coll


@pytest.mark.parametrize("p", [2, 4, 8])
@pytest.mark.parametrize("n,itemsize", [(1000, 4), (8192, 2), (37, 4)])
def test_wire_bytes_ring_closed_form(p, n, itemsize):
    got = coll.wire_bytes_allreduce(n, p, itemsize, "ring")
    assert got == pytest.approx(2.0 * (p - 1) / p * n * itemsize)


@pytest.mark.parametrize("p", [2, 4, 8])
@pytest.mark.parametrize("n,itemsize", [(1000, 4), (8192, 2)])
def test_wire_bytes_doubling_closed_form(p, n, itemsize):
    got = coll.wire_bytes_allreduce(n, p, itemsize, "doubling")
    assert got == pytest.approx(math.log2(p) * n * itemsize)


def test_allreduce_algo_dispatch():
    """Runtime schedule and analytic accounting share one rule: doubling for
    small payloads on power-of-two axes, ring for large tensors."""
    small = coll.DOUBLING_MAX_ELEMENTS
    assert coll.allreduce_algo(small, 8) == "doubling"
    assert coll.allreduce_algo(small + 1, 8) == "ring"     # dense-leaf regime
    assert coll.allreduce_algo(small, 6) == "ring"         # non-pow2 axis
    # and the dispatch picks the cheaper closed form in each regime (p >= 4)
    for p in (4, 8):
        for n in (256, 1 << 20):
            algo = coll.allreduce_algo(n, p)
            other = "ring" if algo == "doubling" else "doubling"
            if n > small:
                assert coll.wire_bytes_allreduce(n, p, 4, algo) <= \
                    coll.wire_bytes_allreduce(n, p, 4, other)


def test_wire_bytes_degenerate_and_ordering():
    # p = 1: nothing crosses the wire
    assert coll.wire_bytes_allreduce(4096, 1, 4, "ring") == 0.0
    assert coll.wire_bytes_allreduce(4096, 1, 4, "doubling") == 0.0
    assert coll.wire_bytes_allgather(4096, 1, 4) == 0.0
    # large-n regime: ring moves fewer bytes than doubling for p >= 4
    for p in (4, 8):
        ring = coll.wire_bytes_allreduce(1 << 20, p, 4, "ring")
        dbl = coll.wire_bytes_allreduce(1 << 20, p, 4, "doubling")
        assert ring < dbl
    # gather is half the ring all-reduce (the Eq. 1 vs Eq. 2 cost split)
    assert coll.wire_bytes_allgather(1000, 8, 4) == pytest.approx(
        coll.wire_bytes_allreduce(1000, 8, 4, "ring") / 2)
    with pytest.raises(ValueError):
        coll.wire_bytes_allreduce(10, 2, 4, "bogus")


def _run_p1(fn, x):
    """Run a collective on a 1-device mesh (the main test session keeps a
    single CPU device per the project rule)."""
    mesh = jax.make_mesh((1,), ("x",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    f = jax.shard_map(fn, mesh=mesh, in_specs=P(), out_specs=P(),
                      check_vma=False)
    return jax.jit(f)(x)


def test_mp_allreduce_single_process_identity():
    """p = 1 edge: every schedule degenerates to a promote-only identity."""
    x = jnp.asarray(np.random.default_rng(0).normal(size=(37,)), jnp.float32)
    for fn in (coll.mp_allreduce, coll.mp_allreduce_ring,
               coll.mp_allreduce_doubling):
        got = _run_p1(lambda t, fn=fn: fn(t, "x", F32), x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x))
        assert got.dtype == jnp.float32


def test_mp_allreduce_bf16_promotes_to_compute():
    """bf16-storage inputs come back in the compute dtype (f32), matching
    the §5.5 accumulate-high contract."""
    x = jnp.asarray([1.0, 2.0, 3.0], jnp.bfloat16)
    got = _run_p1(lambda t: coll.mp_allreduce(t, "x", BF16_F32), x)
    assert got.dtype == jnp.float32


def test_mp_allreduce_rejects_unknown_algo():
    x = jnp.ones((4,), jnp.float32)
    with pytest.raises(ValueError):
        _run_p1(lambda t: coll.mp_allreduce(t, "x", BF16_F32, algo="nope"), x)


def test_all_gather_tiled_p1_identity():
    x = jnp.arange(6.0).reshape(2, 3)
    got = _run_p1(lambda t: coll.all_gather_tiled(t, "x", axis=1), x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))
