"""Mixed-precision wire collectives: analytic cost models + single-device
semantics.  (The 8-device numerical checks — ring/doubling exactness in f32,
bf16-wire error bounds, and mp_allreduce-vs-psum — run in the subprocess
suite, tests/_dist_checks.py via tests/test_distributed.py.)"""
import math

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.mixed_precision import BF16_F32, F32
from repro.dist import collectives as coll
from repro.verify.walker import count_named_calls


@pytest.mark.parametrize("p", [2, 4, 8])
@pytest.mark.parametrize("n,itemsize", [(1000, 4), (8192, 2), (37, 4)])
def test_wire_bytes_ring_closed_form(p, n, itemsize):
    """Ring pricing uses the *padded* chunk size ceil(n/p): the runtime pads
    the payload to p equal chunks and the pad rides the wire, so the closed
    form must price 2·(p-1)·ceil(n/p) elements, not 2·(p-1)/p·n."""
    got = coll.wire_bytes_allreduce(n, p, itemsize, "ring")
    m = -(-n // p)
    assert got == pytest.approx(2.0 * (p - 1) * m * itemsize)
    if n % p == 0:   # divisible payloads keep the classic unpadded form
        assert got == pytest.approx(2.0 * (p - 1) / p * n * itemsize)
    else:            # pad overhead is strictly positive but < one full round
        assert got > 2.0 * (p - 1) / p * n * itemsize
        assert got <= 2.0 * (p - 1) / p * (n + p - 1) * itemsize


@pytest.mark.parametrize("p", [2, 4, 8])
@pytest.mark.parametrize("n,itemsize", [(1000, 4), (8192, 2)])
def test_wire_bytes_doubling_closed_form(p, n, itemsize):
    got = coll.wire_bytes_allreduce(n, p, itemsize, "doubling")
    assert got == pytest.approx(math.log2(p) * n * itemsize)


def test_allreduce_algo_dispatch():
    """Runtime schedule and analytic accounting share one rule: doubling for
    small payloads on power-of-two axes, ring for large tensors."""
    small = coll.DOUBLING_MAX_ELEMENTS
    assert coll.allreduce_algo(small, 8) == "doubling"
    assert coll.allreduce_algo(small + 1, 8) == "ring"     # dense-leaf regime
    assert coll.allreduce_algo(small, 6) == "ring"         # non-pow2 axis
    # and the dispatch picks the cheaper closed form in each regime (p >= 4)
    for p in (4, 8):
        for n in (256, 1 << 20):
            algo = coll.allreduce_algo(n, p)
            other = "ring" if algo == "doubling" else "doubling"
            if n > small:
                assert coll.wire_bytes_allreduce(n, p, 4, algo) <= \
                    coll.wire_bytes_allreduce(n, p, 4, other)


def test_wire_bytes_degenerate_and_ordering():
    # p = 1: nothing crosses the wire
    assert coll.wire_bytes_allreduce(4096, 1, 4, "ring") == 0.0
    assert coll.wire_bytes_allreduce(4096, 1, 4, "doubling") == 0.0
    assert coll.wire_bytes_allgather(4096, 1, 4) == 0.0
    # large-n regime: ring moves fewer bytes than doubling for p >= 4
    for p in (4, 8):
        ring = coll.wire_bytes_allreduce(1 << 20, p, 4, "ring")
        dbl = coll.wire_bytes_allreduce(1 << 20, p, 4, "doubling")
        assert ring < dbl
    # gather is half the ring all-reduce (the Eq. 1 vs Eq. 2 cost split)
    assert coll.wire_bytes_allgather(1000, 8, 4) == pytest.approx(
        coll.wire_bytes_allreduce(1000, 8, 4, "ring") / 2)
    with pytest.raises(ValueError):
        coll.wire_bytes_allreduce(10, 2, 4, "bogus")


def _run_p1(fn, x):
    """Run a collective on a 1-device mesh (the main test session keeps a
    single CPU device per the project rule)."""
    mesh = jax.make_mesh((1,), ("x",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    f = jax.shard_map(fn, mesh=mesh, in_specs=P(), out_specs=P(),
                      check_vma=False)
    return jax.jit(f)(x)


def test_mp_allreduce_single_process_identity():
    """p = 1 edge: every schedule degenerates to a promote-only identity."""
    x = jnp.asarray(np.random.default_rng(0).normal(size=(37,)), jnp.float32)
    for fn in (coll.mp_allreduce, coll.mp_allreduce_ring,
               coll.mp_allreduce_doubling):
        got = _run_p1(lambda t, fn=fn: fn(t, "x", F32), x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x))
        assert got.dtype == jnp.float32


def test_mp_allreduce_bf16_promotes_to_compute():
    """bf16-storage inputs come back in the compute dtype (f32), matching
    the §5.5 accumulate-high contract."""
    x = jnp.asarray([1.0, 2.0, 3.0], jnp.bfloat16)
    got = _run_p1(lambda t: coll.mp_allreduce(t, "x", BF16_F32), x)
    assert got.dtype == jnp.float32


def test_mp_allreduce_rejects_unknown_algo():
    x = jnp.ones((4,), jnp.float32)
    with pytest.raises(ValueError):
        _run_p1(lambda t: coll.mp_allreduce(t, "x", BF16_F32, algo="nope"), x)


def test_all_gather_tiled_p1_identity():
    x = jnp.arange(6.0).reshape(2, 3)
    got = _run_p1(lambda t: coll.all_gather_tiled(t, "x", axis=1), x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


def test_mp_reduce_scatter_p1_identity():
    """p = 1: the reduce-scatter 'chunk' is the whole promoted payload."""
    x = jnp.asarray(np.random.default_rng(1).normal(size=(2, 5)), jnp.bfloat16)
    got = _run_p1(lambda t: coll.mp_reduce_scatter(t, "x", BF16_F32), x)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(x, np.float32).reshape(-1))


def test_staged_allreduce_p1_identity():
    """p = 1: zero hops — born done, result is the promoted input, and
    step() on a finished reduction is the identity."""
    x = jnp.asarray(np.random.default_rng(2).normal(size=(37,)), jnp.float32)

    def run(t):
        op = coll.staged_allreduce(t, "x", F32)
        assert op.done and op.hops_total == 0
        assert op.step() is op
        return op.result()

    got = _run_p1(run, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


def test_staged_tree_allreduce_p1_identity():
    rng = np.random.default_rng(3)
    tree = {"a": jnp.asarray(rng.normal(size=(7,)), jnp.float32),
            "b": (jnp.asarray(rng.normal(size=(3, 2)), jnp.float32),)}
    got = _run_p1(lambda t: coll.staged_tree_allreduce(t, "x", F32), tree)
    for g, want in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(want))


# ---- p = 8 abstract-mesh traces (structure-only; numerics run in the
# ---- subprocess dist suite) ---------------------------------------------

def _trace_p8(fn, x):
    mesh = jax.sharding.AbstractMesh((("x", 8),))
    f = jax.shard_map(fn, mesh=mesh, in_specs=P(), out_specs=P(),
                      check_vma=False)
    return jax.make_jaxpr(f)(x)


def _count_named_calls(jaxpr, substr: str) -> int:
    return count_named_calls(jaxpr, substr)


def test_ring_reorder_is_slice_concat_not_roll():
    """The chunk-reorder epilogue of mp_allreduce_ring must be a slice/concat
    of the two runs — no full-payload jnp.roll copy may survive in the
    jaxpr (jnp.roll traces as a pjit named ``_roll_static``)."""
    x = jnp.ones((296,), jnp.float32)
    jaxpr = _trace_p8(lambda t: coll.mp_allreduce_ring(t, "x", BF16_F32), x)
    assert _count_named_calls(jaxpr.jaxpr, "roll") == 0
    # sanity: the detector does fire on an actual roll
    roll = jax.make_jaxpr(lambda t: jnp.roll(t, 5))(x)
    assert _count_named_calls(roll.jaxpr, "roll") == 1


def test_staged_allreduce_result_before_done_raises():
    """result() demands a drained schedule (p = 8 ring: 2·(p-1) hops)."""
    x = jnp.ones((37,), jnp.float32)
    with pytest.raises(ValueError, match="hops left"):
        _trace_p8(
            lambda t: coll.staged_allreduce(t, "x", F32, algo="ring").result(),
            x)


def test_staged_allreduce_hop_counts():
    """doubling = log2(p) hops, ring = 2·(p-1) hops — the budget the
    pipelined walker interleaves against."""
    x = jnp.ones((37,), jnp.float32)

    def probe(t, algo):
        op = coll.staged_allreduce(t, "x", F32, algo=algo)
        hops = 0
        while not op.done:
            op = op.step()
            hops += 1
        assert hops == op.hops_total == (3 if algo == "doubling" else 14)
        return op.result()

    for algo in ("doubling", "ring"):
        _trace_p8(lambda t, a=algo: probe(t, a), x)
