"""Component oracles: chunked attention vs dense reference, MoE capacity-slot
dispatch vs run-every-expert reference, RoPE shift invariance."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

pytest.importorskip("hypothesis", reason="optional dep: pip install -e .[test]")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_config
from repro.models.attention import chunked_attention, decode_attention, reference_attention
from repro.models import moe as moe_mod
from repro.models.layers import apply_rope

RNG = np.random.default_rng(5)


def rand(shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


@pytest.mark.parametrize("B,H,KV,Sq,Skv,hd", [
    (2, 4, 4, 16, 16, 8),     # MHA
    (2, 8, 2, 32, 32, 16),    # GQA
    (1, 4, 1, 24, 24, 8),     # MQA
    (2, 2, 2, 7, 7, 4),       # ragged
])
@pytest.mark.parametrize("window", [None, 8])
def test_chunked_attention_matches_dense(B, H, KV, Sq, Skv, hd, window):
    q, k, v = rand((B, H, Sq, hd)), rand((B, KV, Skv, hd)), rand((B, KV, Skv, hd))
    got = chunked_attention(q, k, v, causal=True, window=window,
                            q_chunk=8, kv_chunk=4)
    want = reference_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_chunked_attention_non_causal():
    q, k, v = rand((2, 4, 10, 8)), rand((2, 4, 14, 8)), rand((2, 4, 14, 8))
    got = chunked_attention(q, k, v, causal=False, q_chunk=4, kv_chunk=4)
    want = reference_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_chunked_attention_q_offset():
    """Prefix decoding: q tokens live at positions offset..offset+Sq."""
    q, k, v = rand((1, 2, 4, 8)), rand((1, 2, 12, 8)), rand((1, 2, 12, 8))
    got = chunked_attention(q, k, v, causal=True, q_offset=8, q_chunk=2, kv_chunk=4)
    want = reference_attention(q, k, v, causal=True, q_offset=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_last_row():
    B, H, KV, S, hd = 2, 4, 2, 12, 8
    q = rand((B, H, 1, hd))
    k, v = rand((B, KV, S, hd)), rand((B, KV, S, hd))
    got = decode_attention(q, k, v, jnp.asarray(S))
    want = reference_attention(q, k, v, causal=True, q_offset=S - 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(seq=st.integers(2, 20), kvc=st.integers(1, 8), qc=st.integers(1, 8),
       seed=st.integers(0, 2**30))
def test_chunked_attention_property(seq, kvc, qc, seed):
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.normal(size=(1, 2, seq, 4)).astype(np.float32))
    k = jnp.asarray(r.normal(size=(1, 2, seq, 4)).astype(np.float32))
    v = jnp.asarray(r.normal(size=(1, 2, seq, 4)).astype(np.float32))
    got = chunked_attention(q, k, v, causal=True, q_chunk=qc, kv_chunk=kvc)
    want = reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


# ---- MoE -------------------------------------------------------------------

def test_moe_dispatch_matches_dense_reference():
    cfg = get_config("kimi-k2-1t-a32b", smoke=True)
    # generous capacity => no drops => exact match with the dense oracle
    params = moe_mod.init_moe(jax.random.PRNGKey(1), cfg, jnp.float32)
    x = rand((40, cfg.d_model))
    got, aux = moe_mod.apply_moe(cfg, params, x)
    want = moe_mod.moe_ref(cfg, params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)
    assert float(aux) > 0.0


def test_moe_grouping_invariance():
    """Dispatch in one group == dispatch in many groups (pure routing)."""
    import dataclasses
    cfg = get_config("kimi-k2-1t-a32b", smoke=True)
    params = moe_mod.init_moe(jax.random.PRNGKey(2), cfg, jnp.float32)
    x = rand((64, cfg.d_model))
    out1, _ = moe_mod.apply_moe(cfg, params, x)
    cfg2 = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, group_tokens=16))
    out2, _ = moe_mod.apply_moe(cfg2, params, x)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=2e-4, atol=2e-4)


def test_moe_capacity_drop_is_bounded():
    """With capacity_factor=1.0 some tokens may drop, but the output stays
    finite and within the convex hull scale of expert outputs."""
    import dataclasses
    cfg = get_config("kimi-k2-1t-a32b", smoke=True)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=1.0))
    params = moe_mod.init_moe(jax.random.PRNGKey(3), cfg, jnp.float32)
    x = rand((128, cfg.d_model))
    out, _ = moe_mod.apply_moe(cfg, params, x)
    assert bool(jnp.isfinite(out).all())


# ---- RoPE ------------------------------------------------------------------

def test_rope_relative_shift_invariance():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    hd = 16
    q, k = rand((1, 1, 1, hd)), rand((1, 1, 1, hd))

    def dot_at(i, j):
        qr = apply_rope(q, jnp.asarray([i]), 10000.0)
        kr = apply_rope(k, jnp.asarray([j]), 10000.0)
        return float(jnp.sum(qr * kr))

    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3
    assert abs(dot_at(0, 0) - dot_at(50, 50)) < 1e-3


def test_rope_partial_fraction_preserves_tail():
    x = rand((1, 4, 16))
    out = apply_rope(x, jnp.arange(4), 10000.0, fraction=0.25)
    np.testing.assert_allclose(np.asarray(out[..., 4:]), np.asarray(x[..., 4:]))
    assert not np.allclose(np.asarray(out[..., :4]), np.asarray(x[..., :4]))
