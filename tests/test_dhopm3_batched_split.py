"""Split-aware batched dHOPM_3 coverage (single device, p = 1 mesh): the
launch-count guarantee (one batched contraction launch per chain step,
independent of B, equal to the unbatched dhopm3 schedule and to the
memory_model launch closed form, unfused and fused), the bitwise oracle
(dhopm3_batched == B independent dhopm3 runs under the mulsum engine), the
batched shard ops' split bookkeeping (Eq. 2 slice path, split-in-pair
rejection), and the grad_compress split-leaf routing (bucketed == per-leaf
bitwise; split mode == partial mode at p = 1).  The p = 8 halves of these
acceptance criteria live in tests/_dist_checks.py."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import dhopm as dh
from repro.core import memory_model as mm
from repro.core.dtvc import ShardState, dtvc2_local_batched, dtvc_local_batched
from repro.train import grad_compress as gc
from repro.verify.walker import count_primitive

RNG = np.random.default_rng(41)


def rand(shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


def mesh1():
    return jax.make_mesh((1,), ("x",))


def _count_pallas(jaxpr) -> int:
    return count_primitive(jaxpr, "pallas_call")


# ---- launch schedule: one batched launch per chain step, any B -----------

@pytest.mark.parametrize("shape,s", [((5, 4, 6, 3), 1), ((5, 4, 6, 3), 3),
                                     ((4, 6, 8), 2)])
@pytest.mark.parametrize("fuse", [False, True])
def test_dhopm3_batched_launches_match_model_and_unbatched(shape, s, fuse):
    """Acceptance: a split batched sweep issues exactly the unbatched
    dhopm3 schedule's launch count — independent of B and equal to
    memory_model.dhopm_launches_per_sweep."""
    mesh = mesh1()
    d = len(shape)
    want = mm.dhopm_launches_per_sweep(d, s, fuse)

    counts = set()
    for B in (1, 2, 5):
        A = rand((B,) + shape)
        xs = [rand((B, n)) for n in shape]
        jx = jax.make_jaxpr(lambda A, *x: dh.dhopm3_batched(
            A, list(x), mesh, "x", s=s, sweeps=1, impl="pallas",
            fuse_pairs=fuse)[0])(A, *xs)
        counts.add(_count_pallas(jx.jaxpr))
    A1 = rand(shape)
    x1 = [rand((n,)) for n in shape]
    j1 = jax.make_jaxpr(lambda A, *x: dh.dhopm3(
        A, list(x), mesh, "x", s=s, sweeps=1, impl="pallas",
        fuse_pairs=fuse)[0])(A1, *x1)
    assert counts == {want} == {_count_pallas(j1.jaxpr)}, (counts, want)


def test_split_blocks_pair_fusion_in_model():
    # no split: d=4 fuses two pairs (9 -> 7); split at the chain tail
    # blocks one of them (9 -> 8); d=3 split at s=2 blocks the only pair
    assert mm.dhopm_launches_per_sweep(4) == 9
    assert mm.dhopm_launches_per_sweep(4, fuse_pairs=True) == 7
    assert mm.dhopm_launches_per_sweep(4, 3, True) == 8
    assert mm.dhopm_launches_per_sweep(3, 2, True) == \
        mm.dhopm_launches_per_sweep(3, 2) == 5


# ---- bitwise oracle at p = 1 ---------------------------------------------

@pytest.mark.parametrize("shape", [(4, 6, 8, 2), (7, 5, 3)])
@pytest.mark.parametrize("fuse", [False, True])
def test_dhopm3_batched_bitwise_vs_independent_runs(shape, fuse):
    """Acceptance (p = 1 half): dhopm3_batched matches B independent
    dhopm3 runs BITWISE under the mulsum engine, for every split."""
    mesh = mesh1()
    B, d = 3, len(shape)
    A = rand((B,) + shape)
    xs = [rand((B, n)) for n in shape]
    for s in range(d):
        xb, lb = dh.dhopm3_batched(A, xs, mesh, "x", s=s, sweeps=2,
                                   impl="mulsum", fuse_pairs=fuse)
        for i in range(B):
            xi, li = dh.dhopm3(A[i], [x[i] for x in xs], mesh, "x", s=s,
                               sweeps=2, impl="mulsum", fuse_pairs=fuse)
            assert np.array_equal(np.asarray(lb)[i], np.asarray(li))
            for a, b in zip(xb, xi):
                assert np.array_equal(np.asarray(a)[i], np.asarray(b))


def test_dhopm3_batched_matches_unbatched_allclose_native():
    """The native engine agrees to tolerance (bitwise is mulsum-only)."""
    mesh = mesh1()
    shape, B = (5, 4, 6), 4
    A = rand((B,) + shape)
    xs = [rand((B, n)) for n in shape]
    xb, lb = dh.dhopm3_batched(A, xs, mesh, "x", s=2, sweeps=3,
                               impl="native")
    for i in range(B):
        xi, li = dh.dhopm3(A[i], [x[i] for x in xs], mesh, "x", s=2,
                           sweeps=3, impl="native")
        np.testing.assert_allclose(np.asarray(lb)[i], np.asarray(li),
                                   rtol=1e-5)
        for a, b in zip(xb, xi):
            np.testing.assert_allclose(np.asarray(a)[i], np.asarray(b),
                                       rtol=1e-4, atol=1e-5)


def test_dhopm3_batched_rejects_indivisible_split():
    mesh = jax.make_mesh((1,), ("x",))
    A = rand((2, 4, 6))
    xs = [rand((2, 4)), rand((2, 6))]
    with pytest.raises(ValueError):
        # per-sample dim extent must divide p; build a fake 2-mesh check by
        # asking for a split dim whose extent can't match axis size... at
        # p=1 everything divides, so check the partial/split exclusivity
        dh.hopm3_batched(A, xs, partial=True, split=0, axis_name="x")


# ---- batched shard ops ----------------------------------------------------

def test_dtvc_local_batched_split_slice_path():
    """k == split takes the Eq. 2 slice path: each batch row contracts
    against this process's slice of its global vector, and the result is
    marked partial."""
    mesh = mesh1()
    B, shape = 3, (4, 6, 5)
    A = rand((B,) + shape)
    xg = rand((B, 6))

    def body(a, x):
        out, st = dtvc_local_batched(a, x, 1, ShardState(split=1),
                                     axis_name="x", impl="mulsum")
        assert st.partial and st.split is None
        return out

    fn = jax.shard_map(body, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                       check_vma=False)
    got = jax.jit(fn)(A, xg)
    for i in range(B):
        want = np.tensordot(np.asarray(A[i]), np.asarray(xg[i]), axes=(1, 0))
        np.testing.assert_allclose(np.asarray(got)[i], want, rtol=1e-5,
                                   atol=1e-5)


def test_dtvc2_local_batched_rejects_split_in_pair():
    B, shape = 2, (4, 6, 5)
    A = rand((B,) + shape)
    x1, x2 = rand((B, 6)), rand((B, 5))
    for split in (1, 2):
        with pytest.raises(ValueError):
            dtvc2_local_batched(A, x1, 1, x2, ShardState(split=split),
                                impl="mulsum")
    # split below the pair survives, shifted down by two
    out, st = dtvc2_local_batched(A, x1, 1, x2, ShardState(split=0),
                                  impl="mulsum")
    assert st.split == 0 and out.shape == (B, 4)


# ---- grad_compress split routing at p = 1 --------------------------------

def _run_compress(cfg, grads, state, mesh, axis):
    def body(g, s):
        ng, ns, _ = gc.compress_and_sync(g, s, cfg, axis)
        return ng, ns

    fn = jax.shard_map(body, mesh=mesh, in_specs=(P(), P()),
                       out_specs=(P(), P()), check_vma=False)
    return jax.jit(fn)(grads, state)


def test_grad_compress_split_bucketed_bitwise_p1():
    """Acceptance (p = 1 half): split-annotated buckets through the
    split-aware batched walker reproduce the per-leaf hopm3_sharded loop
    bit for bit."""
    splits = (("['wa']", 1), ("['wb']", 1))
    cfg = gc.CompressorCfg(rank=2, sweeps=2, min_size=32, prec="f32",
                           splits=splits, split_world=1)
    params = {"wa": jnp.zeros((8, 12)), "wb": jnp.zeros((8, 12)),
              "solo": jnp.zeros((6, 7))}
    grads = {k: rand(v.shape) for k, v in params.items()}
    state = gc.init_state(params, cfg, seed=5)
    mesh = jax.make_mesh((1,), ("dp",))
    g1, s1 = _run_compress(cfg, grads, state, mesh, "dp")
    g0, s0 = _run_compress(dataclasses.replace(cfg, bucket=False),
                           grads, state, mesh, "dp")
    for a, b in zip(jax.tree.leaves((g1, s1)), jax.tree.leaves((g0, s0))):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_grad_compress_split_equals_partial_at_p1():
    """At p = 1 a 'slice' is the whole tensor and a 'summand' is the whole
    sum, so the split path and the Eq. 2 partial path must coincide
    bitwise — a cheap cross-mode oracle for the split schedule."""
    params = {"w": jnp.zeros((10, 16))}
    grads = {"w": rand((10, 16))}
    cfg_split = gc.CompressorCfg(rank=2, sweeps=2, min_size=32, prec="f32",
                                 splits=(("['w']", 1),), split_world=1)
    cfg_part = gc.CompressorCfg(rank=2, sweeps=2, min_size=32, prec="f32")
    mesh = jax.make_mesh((1,), ("dp",))
    gs, ss = _run_compress(cfg_split, grads,
                           gc.init_state(params, cfg_split), mesh, "dp")
    gp, sp = _run_compress(cfg_part, grads,
                           gc.init_state(params, cfg_part), mesh, "dp")
    for a, b in zip(jax.tree.leaves((gs, ss)), jax.tree.leaves((gp, sp))):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_grad_compress_ineligible_split_leaf_passes_through():
    """An ineligible split leaf is an already-synced shard: it must come
    back untouched (an all-reduce would double-count the slices)."""
    params = {"tiny": jnp.zeros((4, 4))}
    grads = {"tiny": rand((4, 4))}
    cfg = gc.CompressorCfg(rank=2, sweeps=1, min_size=10_000, prec="f32",
                           splits=(("['tiny']", 1),), split_world=1)
    mesh = jax.make_mesh((1,), ("dp",))
    g, s = _run_compress(cfg, grads, gc.init_state(params, cfg), mesh, "dp")
    assert np.array_equal(np.asarray(g["tiny"]), np.asarray(grads["tiny"]))


def test_init_state_split_factors_are_global_extent():
    cfg = gc.CompressorCfg(rank=1, sweeps=1, min_size=16, prec="f32",
                           splits=(("['w']", 1),), split_world=8)
    st = gc.init_state({"w": jnp.zeros((8, 4))}, cfg)
    assert tuple(x.shape for x in st["w"]["xs"][0]) == ((8,), (32,))
    assert st["w"]["e"].shape == (8, 4)   # error feedback stays local
    with pytest.raises(ValueError):
        gc.init_state({"w": jnp.zeros((8, 4))}, dataclasses.replace(
            cfg, splits=(("['w']", 5),)))


# ---- wire accounting ------------------------------------------------------

def test_wire_summary_split_vs_partial_pricing():
    """Split leaves price the j == split iteration as the Eq. 1 all-gather
    (cheaper than an all-reduce) and their dense baseline as assembling the
    global tensor; per-iteration dispatch is priced on each n_j."""
    from repro.dist import collectives as coll
    p = 8
    params = {"w": jnp.zeros((64, 128))}
    cfg_p = gc.CompressorCfg(rank=2, sweeps=2, min_size=64, prec="f32")
    cfg_s = gc.CompressorCfg(rank=2, sweeps=2, min_size=64, prec="f32",
                             splits=(("['w']", 1),), split_world=p)
    sp_ = gc.wire_bytes_summary(params, cfg_p, p)
    ss_ = gc.wire_bytes_summary(params, cfg_s, p)
    # closed form reproduced with explicit per-iteration events
    want_p = 2 * 2 * sum(
        coll.wire_bytes_allreduce(n, p, 4, coll.allreduce_algo(n, p))
        for n in (64, 128))
    assert sp_["compressed_bytes"] == want_p
    want_s = 2 * 2 * (
        coll.wire_bytes_allreduce(64, p, 4, coll.allreduce_algo(64, p))
        + coll.wire_bytes_allgather(128 * p, p, 4))
    assert ss_["compressed_bytes"] == want_s
    assert ss_["compressed_bytes"] < 2 * 2 * sum(
        coll.wire_bytes_allreduce(n, p, 4, coll.allreduce_algo(n, p))
        for n in (64, 128 * p))


def test_wire_summary_per_iteration_dispatch_differs_from_concat():
    """The old accounting dispatched ONE algo on Σ n_j; the runtime
    dispatches per n_j.  Pick extents where the two disagree (each n_j
    under the doubling cutoff, the concatenation above it) and check the
    summary prices the per-iteration schedule."""
    from repro.dist import collectives as coll
    p = 8
    n = 40_000   # < 2**16 cutoff; 2n > cutoff
    params = {"w": jnp.zeros((n, n))}
    cfg = gc.CompressorCfg(rank=1, sweeps=1, min_size=64, prec="f32")
    got = gc.wire_bytes_summary(params, cfg, p)["compressed_bytes"]
    per_iter = 2 * coll.wire_bytes_allreduce(n, p, 4, "doubling")
    concat = coll.wire_bytes_allreduce(2 * n, p, 4,
                                       coll.allreduce_algo(2 * n, p))
    assert got == per_iter != concat


def test_batched_wire_and_streamed_accounting_scale_linearly():
    for b in (1, 8, 64):
        assert mm.dhopm_batched_wire_bytes_sweep(b, (8, 24, 16), 8, 4, 2) \
            == b * mm.dhopm_wire_bytes_sweep((8, 24, 16), 8, 4, 2)
        assert mm.simulate_sweep_batched(b, 16, 3, 8, 2, "hopm3") \
            == b * mm.simulate_sweep(16, 3, 8, 2, "hopm3")
    with pytest.raises(ValueError):
        mm.simulate_sweep_batched(0, 16, 3, 8, 2)


def test_simulate_sweep_split_alive_override():
    """The runtime walkers keep the split schedule at p = 1 (blocks pair
    fusion -> more streamed traffic than the fused no-split schedule)."""
    forced = mm.simulate_sweep(8, 4, 1, 3, "hopm3_fused", split_alive=True)
    auto = mm.simulate_sweep(8, 4, 1, 3, "hopm3_fused")
    assert forced > auto
    # unfused hypersquare accounting is split-agnostic at p = 1
    assert mm.simulate_sweep(8, 4, 1, 3, "hopm3", split_alive=True) == \
        mm.simulate_sweep(8, 4, 1, 3, "hopm3")
