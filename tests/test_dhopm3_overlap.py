"""Pipelined dHOPM3 coverage (single device, p = 1 mesh): the bitwise
guarantee (overlap= chunked tails change no iterate bit vs the synchronous
walker under the mulsum engine — sequential, split, and batched), the launch
schedule (chunked tails issue exactly the memory_model closed form), the
overlap_chunks normalizer, and the analytic overlap models
(simulate_sweep(overlap_chunks=) extra vector re-reads and the
dhopm_time_sweep exposed-wire accounting).  The p = 8 halves — actual wire
hops staged behind launches, ring/doubling regime switching — run in the
subprocess suite (tests/_dist_checks.py: dhopm3_overlap_bitwise and
friends)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import dhopm as dh
from repro.core import memory_model as mm
from repro.dist import collectives as coll
from repro.verify.walker import count_primitive

RNG = np.random.default_rng(57)


def rand(shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


def mesh1():
    return jax.make_mesh((1,), ("x",))


def _count_pallas(jaxpr) -> int:
    return count_primitive(jaxpr, "pallas_call")


# ---- overlap_chunks normalizer -------------------------------------------

def test_overlap_chunks_normalizer():
    assert dh._overlap_chunks(False) == 1
    assert dh._overlap_chunks(None) == 1
    assert dh._overlap_chunks(True) == dh.OVERLAP_CHUNKS_DEFAULT == 4
    assert dh._overlap_chunks(1) == 1
    assert dh._overlap_chunks(7) == 7
    for bad in (0, -2, 2.5, "four"):
        with pytest.raises(ValueError):
            dh._overlap_chunks(bad)


# ---- bitwise: pipelining must not move a single rounding -----------------

@pytest.mark.parametrize("fuse", [False, True])
@pytest.mark.parametrize("overlap", [True, 2, 3, 8])
def test_hopm3_overlap_bitwise(fuse, overlap):
    """Sequential tentpole guarantee: the chunked tail partitions the output
    mode, leaving every element's contraction arithmetic untouched — iterates
    and lambda identical bit-for-bit under mulsum."""
    shape = (5, 4, 6, 3)
    A = rand(shape)
    xs = [rand((n,)) for n in shape]
    ref_xs, ref_lam = dh.hopm3(A, xs, sweeps=2, impl="mulsum",
                               fuse_pairs=fuse)
    got_xs, got_lam = dh.hopm3(A, xs, sweeps=2, impl="mulsum",
                               fuse_pairs=fuse, overlap=overlap)
    assert np.array_equal(np.asarray(ref_lam), np.asarray(got_lam))
    for a, b in zip(ref_xs, got_xs):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("fuse", [False, True])
def test_dhopm3_overlap_bitwise_all_splits(fuse):
    """Split walker (p = 1, split state machine still structural): overlap
    drains at the j == s gather, chunks everywhere else — bitwise."""
    mesh = mesh1()
    shape = (4, 6, 8, 2)
    A = rand(shape)
    xs = [rand((n,)) for n in shape]
    for s in range(len(shape)):
        ref_xs, ref_lam = dh.dhopm3(A, xs, mesh, "x", s=s, sweeps=2,
                                    impl="mulsum", fuse_pairs=fuse)
        got_xs, got_lam = dh.dhopm3(A, xs, mesh, "x", s=s, sweeps=2,
                                    impl="mulsum", fuse_pairs=fuse,
                                    overlap=True)
        assert np.array_equal(np.asarray(ref_lam), np.asarray(got_lam))
        for a, b in zip(ref_xs, got_xs):
            assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("s", [None, 0, 2])
def test_dhopm3_batched_overlap_bitwise(s):
    """Batched walker mirrors the unbatched engage predicate — stacked
    chunked tails are bitwise too (and still match B independent runs)."""
    mesh = mesh1()
    shape, B = (5, 4, 6), 3
    A = rand((B,) + shape)
    xs = [rand((B, n)) for n in shape]
    kw = dict(sweeps=2, impl="mulsum")
    if s is None:
        ref = dh.hopm3_batched(A, xs, **kw)
        got = dh.hopm3_batched(A, xs, overlap=True, **kw)
    else:
        ref = dh.dhopm3_batched(A, xs, mesh, "x", s=s, **kw)
        got = dh.dhopm3_batched(A, xs, mesh, "x", s=s, overlap=True, **kw)
    assert np.array_equal(np.asarray(ref[1]), np.asarray(got[1]))
    for a, b in zip(ref[0], got[0]):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_overlap_chunks_exceeding_extent_still_bitwise():
    """C caps at n_out (balanced chunking would otherwise emit empty
    launches); tiny extents just run fewer chunks."""
    shape = (3, 2, 4)
    A = rand(shape)
    xs = [rand((n,)) for n in shape]
    ref = dh.hopm3(A, xs, sweeps=2, impl="mulsum")
    got = dh.hopm3(A, xs, sweeps=2, impl="mulsum", overlap=16)
    assert np.array_equal(np.asarray(ref[1]), np.asarray(got[1]))
    for a, b in zip(ref[0], got[0]):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---- launch schedule ------------------------------------------------------

@pytest.mark.parametrize("s", [None, 0, 1, 3])
@pytest.mark.parametrize("fuse", [False, True])
@pytest.mark.parametrize("C", [1, 3, 4])
def test_overlap_launch_count_matches_model(s, fuse, C):
    """Acceptance: the pipelined walker still issues exactly
    memory_model.dhopm_launches_per_sweep(..., overlap_chunks) Pallas
    launches — each tail chunk is one launch, the gather tail drains to
    one."""
    mesh = mesh1()
    shape = (8, 8, 8, 8)
    A = rand(shape)
    xs = [rand((n,)) for n in shape]
    want = mm.dhopm_launches_per_sweep(len(shape), s, fuse, overlap_chunks=C)
    if s is None:
        fn = lambda A, *x: dh.hopm3(A, list(x), sweeps=1, impl="pallas",
                                    fuse_pairs=fuse, overlap=C)[0]
    else:
        fn = lambda A, *x: dh.dhopm3(A, list(x), mesh, "x", s=s, sweeps=1,
                                     impl="pallas", fuse_pairs=fuse,
                                     overlap=C)[0]
    jx = jax.make_jaxpr(fn)(A, *xs)
    assert _count_pallas(jx.jaxpr) == want


def test_overlap_launch_count_batched_independent_of_B():
    mesh = mesh1()
    shape, s, C = (6, 6, 6), 1, 3
    want = mm.dhopm_launches_per_sweep(3, s, False, overlap_chunks=C)
    counts = set()
    for B in (1, 4):
        A = rand((B,) + shape)
        xs = [rand((B, n)) for n in shape]
        jx = jax.make_jaxpr(lambda A, *x: dh.dhopm3_batched(
            A, list(x), mesh, "x", s=s, sweeps=1, impl="pallas",
            overlap=C)[0])(A, *xs)
        counts.add(_count_pallas(jx.jaxpr))
    assert counts == {want}


# ---- analytic overlap models ---------------------------------------------

def test_simulate_sweep_overlap_extra_reads():
    """overlap_chunks=C adds exactly the per-chunk vector re-reads: (C-1)
    extra x reads per pipelined tail, nothing else.  Hand count for n=6,
    d=3, p=1: unfused, no split -> every tail pipelined, x read = n each ->
    +3*(C-1)*6; fused -> tails read 2n + n + n -> +(C-1)*24."""
    n, d = 6, 3
    for algo, extra_per_chunk in (("hopm3", 3 * n), ("hopm3_fused", 4 * n)):
        base = mm.simulate_sweep(n, d, 1, 0, algo, split_alive=False)
        for C in (2, 4):
            got = mm.simulate_sweep(n, d, 1, 0, algo, split_alive=False,
                                    overlap_chunks=C)
            assert got == pytest.approx(base + (C - 1) * extra_per_chunk)
    # split alive: the j == s gather iteration drains (one tail unpipelined)
    base = mm.simulate_sweep(n, d, 1, 2, "hopm3", split_alive=True)
    got = mm.simulate_sweep(n, d, 1, 2, "hopm3", split_alive=True,
                            overlap_chunks=2)
    assert got - base < 3 * n  # strictly fewer than d pipelined tails


def test_dhopm_time_sweep_sync_exposes_everything():
    t = mm.dhopm_time_sweep((64, 64, 64), 8, 4, split=2, overlap_chunks=1,
                            peak_gbs=100.0, wire_gbs=10.0)
    assert t["exposed_wire_us"] == pytest.approx(t["wire_us"])
    assert t["hidden_wire_us"] == pytest.approx(0.0)
    assert t["extra_dispatch_us"] == 0.0
    wire = sum(
        coll.wire_bytes_allgather(64, 8, 4) if j == 2 else
        coll.wire_bytes_allreduce(64, 8, 4, coll.allreduce_algo(64, 8))
        for j in range(3)) / (10.0 * 1e9) * 1e6
    assert t["wire_us"] == pytest.approx(wire)


def test_dhopm_time_sweep_pipelined_hides_wire():
    """Slow compute (tail chunk >= wire chunk) hides all but the last
    chunk's wire: exposed == wire/C per pipelined stage; the j == split
    gather stage stays fully exposed."""
    C = 4
    t = mm.dhopm_time_sweep((64, 64, 64), 8, 4, split=2, overlap_chunks=C,
                            peak_gbs=0.001, wire_gbs=100.0)
    for st in t["per_iteration"]:
        if st["j"] == 2:
            assert st["chunks"] == 1
            assert st["exposed_us"] == pytest.approx(st["wire_us"])
        else:
            assert st["chunks"] == C
            assert st["exposed_us"] == pytest.approx(st["wire_us"] / C)
    assert t["hidden_wire_us"] > 0
    # instant compute: nothing to hide behind -> fully exposed again
    t2 = mm.dhopm_time_sweep((64, 64, 64), 8, 4, split=2, overlap_chunks=C,
                             peak_gbs=1e12, wire_gbs=100.0)
    assert t2["exposed_wire_us"] == pytest.approx(t2["wire_us"])


def test_dhopm_time_sweep_ring_regime_stays_exposed():
    """Payloads past the doubling cutoff (or non-pow2 p) dispatch to ring;
    the runtime drains those tails, and the model prices them exposed."""
    big = coll.DOUBLING_MAX_ELEMENTS * 2
    t = mm.dhopm_time_sweep((big, 8, 8), 8, 4, split=None, overlap_chunks=4,
                            peak_gbs=0.001, wire_gbs=100.0)
    st = t["per_iteration"][0]
    assert st["chunks"] == 1 and st["exposed_us"] == pytest.approx(
        st["wire_us"])
    # non-pow2 axis: every payload is ring -> nothing pipelines
    t6 = mm.dhopm_time_sweep((64, 64, 64), 6, 4, split=None, overlap_chunks=4,
                             peak_gbs=0.001, wire_gbs=100.0)
    assert t6["exposed_wire_us"] == pytest.approx(t6["wire_us"])


def test_dhopm_time_sweep_dispatch_allowance_and_validation():
    C, disp = 4, 7.5
    t = mm.dhopm_time_sweep((64, 64, 64), 8, 4, split=2, overlap_chunks=C,
                            peak_gbs=100.0, wire_gbs=10.0, dispatch_us=disp)
    pipelined = [st for st in t["per_iteration"] if st["chunks"] > 1]
    assert t["extra_dispatch_us"] == pytest.approx(
        len(pipelined) * (C - 1) * disp)
    with pytest.raises(ValueError):
        mm.dhopm_time_sweep((8, 8), 8, 4, overlap_chunks=0,
                            peak_gbs=1.0, wire_gbs=1.0)


def test_p1_wire_free_time_model():
    t = mm.dhopm_time_sweep((16, 16, 16), 1, 4, overlap_chunks=4,
                            peak_gbs=100.0, wire_gbs=10.0)
    assert t["wire_us"] == t["exposed_wire_us"] == 0.0
