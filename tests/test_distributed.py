"""Runs the multi-device checks in a subprocess with 8 virtual CPU devices
(the main pytest process keeps 1 device, per the project rule)."""
import os
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

EXPECTED = [
    "dtvc_all_k_s",
    "dtvc_unassembled",
    "dtvc_eq2_alphabeta",
    "dtvc_pallas_ragged",
    "dtvc2_pair_local",
    "mp_doubling_f32_exact",
    "mp_ring_f32_exact",
    "mp_ring_bf16_bounded",
    "mp_doubling_bf16_bounded",
    "mp_ring_ragged",
    "mp_allreduce_matches_psum",
    "hopm3_equals_classic",
    "dhopm3_matches_sequential_all_s",
    "dhopm3_fused_matches_sequential",
    "dhopm3_pallas_ragged",
    "dhopm3_rank1_recovery",
    "hopm3_partial_implicit_sum",
    "dhopm3_bf16",
    "dhopm3_batched_split_bitwise",
    "dhopm3_batched_pallas_split",
    "staged_allreduce_matches_sync",
    "mp_allreduce_prime_pad",
    "ring_wire_matches_counted_trace",
    "dhopm3_overlap_bitwise",
    "dhopm3_batched_overlap_bitwise",
    "dhopm3_auto_plan_bitwise",
    "dp_explicit_matches_gspmd",
    "grad_compression_lowrank_and_ef",
    "grad_compression_bucketed_bitwise",
    "grad_compression_split_leaves",
    "wire_summary_matches_counted_trace",
    "elastic_reshard_restore",
    "serve_compress_bucketed_bitwise",
    "slot_recycle_prefill_sharded",
    "grad_compress_arena_bitwise",
    "serve_compress_arena_bitwise",
    "verify_static_gate_p8",
]


def test_distributed_suite():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "_dist_checks.py")],
        capture_output=True, text=True, env=env, timeout=900,
    )
    out = proc.stdout
    assert proc.returncode == 0, f"stdout:\n{out}\nstderr:\n{proc.stderr[-4000:]}"
    for name in EXPECTED:
        assert f"OK {name}" in out, f"missing check {name}:\n{out}"
    assert f"ALL_DIST_OK {len(EXPECTED)}" in out
