"""CI-scale dry-run: lower+compile cells on a (2,4) debug mesh with smoke
configs in a subprocess (the full 512-device sweep is reported in
EXPERIMENTS.md).  Plus unit tests for the roofline HLO parser."""
import json
import os
import pathlib
import subprocess
import sys
import tempfile

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _run_dryrun(arch: str, shapes: str, tmp: str) -> list[dict]:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = pathlib.Path(tmp) / f"dryrun_{arch}.json"
    code = (
        "import os;"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8';"
        "import sys, runpy;"
        f"sys.argv=['dryrun','--debug-mesh','--smoke-configs',"
        f"'--arch','{arch}','--shape','{shapes}','--out',r'{out}'];"
        "runpy.run_module('repro.launch.dryrun', run_name='__main__')"
    )
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout + proc.stderr[-3000:]
    return json.loads(out.read_text())


@pytest.mark.parametrize("arch,shape", [
    ("granite-8b", "train_4k"),
    ("kimi-k2-1t-a32b", "decode_32k"),
    ("rwkv6-3b", "long_500k"),
    ("whisper-tiny", "prefill_32k"),
])
def test_dryrun_cell_compiles(arch, shape):
    with tempfile.TemporaryDirectory() as tmp:
        results = _run_dryrun(arch, shape, tmp)
    (r,) = results
    assert r["status"] == "ok", r
    rl = r["roofline"]
    assert rl["hlo_flops"] > 0
    assert rl["hlo_bytes"] > 0
    assert rl["bottleneck"] in ("compute", "memory", "collective")
    assert 0 < rl["roofline_fraction"] <= 1.0
    assert r["memory_analysis"]["temp_bytes"] >= 0


def test_dryrun_skip_rule():
    with tempfile.TemporaryDirectory() as tmp:
        results = _run_dryrun("qwen2-1.5b", "long_500k", tmp)
    (r,) = results
    assert r["status"] == "skipped"
    assert "sub-quadratic" in r["reason"]


# ---- roofline parser units --------------------------------------------------

def test_collective_bytes_parser():
    from repro.analysis.roofline import collective_bytes
    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={}
  %ag.1 = bf16[16,32]{1,0} all-gather(bf16[16,8]{1,0} %y), dimensions={1}
  %cp = f32[64]{0} collective-permute(f32[64]{0} %z), source_target_pairs={{0,1}}
  %rs = (f32[8,4]{1,0}, f32[8,4]{1,0}) reduce-scatter(f32[64,4]{1,0} %a, f32[64,4]{1,0} %b), dimensions={0}
  %dot = f32[128,128]{1,0} dot(f32[128,64]{1,0} %p, f32[64,128]{1,0} %q)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 128 * 256 * 4
    assert out["all-gather"] == 16 * 32 * 2
    assert out["collective-permute"] == 64 * 4
    assert out["reduce-scatter"] == 2 * 8 * 4 * 4
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_roofline_terms_math():
    from repro.analysis.roofline import PEAK_FLOPS, HBM_BW, LINK_BW, RooflineReport
    r = RooflineReport(
        arch="x", shape="train_4k", mesh="16x16", chips=256,
        hlo_flops=PEAK_FLOPS, hlo_bytes=HBM_BW * 2, coll_bytes=LINK_BW / 2,
        coll_breakdown={}, model_flops=PEAK_FLOPS / 2, bytes_per_device=1,
        argument_bytes=1, output_bytes=1, temp_bytes=0)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(2.0)
    assert r.t_collective == pytest.approx(0.5)
    assert r.bottleneck == "memory"
    assert r.useful_flops_fraction == pytest.approx(0.5)
