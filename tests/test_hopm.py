"""Sequential HOPM properties: variant equivalence, contraction savings,
rank-1 recovery, convergence."""
import numpy as np
import pytest
import jax.numpy as jnp

import repro.core.dhopm as dh

RNG = np.random.default_rng(23)


def rand(shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


@pytest.mark.parametrize("shape", [(6, 7), (5, 6, 4), (3, 4, 3, 5), (2, 3, 2, 3, 2)])
def test_hopm3_equals_classic(shape):
    A = rand(shape)
    xs0 = [rand((n,)) for n in shape]
    xs3, lam3 = dh.hopm3(A, xs0, sweeps=3)
    xsc, lamc = dh.hopm_classic(A, xs0, sweeps=3)
    for a, b in zip(xs3, xsc):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)
    assert abs(float(lam3) - float(lamc)) / float(lamc) < 1e-4


def _count_contractions(monkeypatch, fn):
    calls = []
    orig = dh.dtvc_local

    def spy(*a, **k):
        calls.append(1)
        return orig(*a, **k)

    monkeypatch.setattr(dh, "dtvc_local", spy)
    fn()
    return len(calls)


def test_hopm3_saves_contractions(monkeypatch):
    d = 5
    A = rand((3,) * d)
    xs0 = [rand((3,)) for _ in range(d)]
    n3 = _count_contractions(monkeypatch, lambda: dh.hopm3(A, xs0, sweeps=1))
    nc = _count_contractions(monkeypatch, lambda: dh.hopm_classic(A, xs0, sweeps=1))
    assert nc == d * (d - 1)
    assert nc - n3 == (d - 1) * (d - 2) // 2  # the paper's saving


def test_rank1_exact_recovery():
    us = [RNG.normal(size=(n,)).astype(np.float32) for n in (8, 5, 7)]
    us = [u / np.linalg.norm(u) for u in us]
    A = jnp.asarray(3.5 * np.einsum("i,j,k->ijk", *us))
    xs0 = [rand((n,)) for n in (8, 5, 7)]
    xs, lam = dh.hopm3(A, xs0, sweeps=2)
    assert abs(float(lam) - 3.5) < 1e-3
    assert float(dh.rank1_residual(A, xs, lam)) < 1e-3


def test_residual_decreases_with_sweeps():
    A = rand((6, 7, 5))
    xs0 = [rand((n,)) for n in A.shape]
    res = []
    for sweeps in (1, 2, 4, 8):
        xs, lam = dh.hopm3(A, xs0, sweeps=sweeps)
        res.append(float(dh.rank1_residual(A, xs, lam)))
    assert res[-1] <= res[0] + 1e-5
    # all residuals are valid fractions
    assert all(0.0 <= r <= 1.0 + 1e-5 for r in res)


def test_matrix_case_matches_svd():
    """d = 2 HOPM is the power method: lambda -> sigma_max."""
    A = rand((20, 12))
    xs0 = [rand((20,)), rand((12,))]
    xs, lam = dh.hopm3(A, xs0, sweeps=25)
    smax = float(np.linalg.svd(np.asarray(A), compute_uv=False)[0])
    assert abs(float(lam) - smax) / smax < 1e-3


def test_rank1_reconstruction_shape():
    xs = [rand((3,)), rand((4,)), rand((5,))]
    R = dh.rank1(xs, 2.0)
    assert R.shape == (3, 4, 5)


def test_fused_pairs_equal_plain():
    """BEYOND-PAPER: tvc2 pair fusion must not change HOPM iterates."""
    for shape in [(6, 7), (5, 6, 4), (4, 5, 3, 4), (3, 3, 3, 3, 3)]:
        A = rand(shape)
        xs0 = [rand((n,)) for n in shape]
        a, la = dh.hopm3(A, xs0, sweeps=3)
        b, lb = dh.hopm3(A, xs0, sweeps=3, fuse_pairs=True)
        for u, v in zip(a, b):
            np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                       rtol=1e-4, atol=1e-5)
        assert abs(float(la) - float(lb)) / float(la) < 1e-4


def test_shard_state_pair_contraction_bookkeeping():
    """Regression: a fused pair removal below the split dim must shift the
    split index by exactly 2 (and leave it alone when the split is below)."""
    from repro.core.dtvc import ShardState

    # split above the pair: d-1 style split, fused pair at (0, 1)
    st = ShardState(split=3).after_pair_contraction(0)
    assert st.split == 1 and not st.partial
    # split immediately above the pair
    st = ShardState(split=2).after_pair_contraction(0)
    assert st.split == 0
    # split below the pair: untouched
    st = ShardState(split=0).after_pair_contraction(1)
    assert st.split == 0
    # the pair transition must agree with two sequential removals
    for split in (0, 3, 4, 5):
        for k in (1, 2):
            if split in (k, k + 1):
                continue
            seq = ShardState(split=split)
            seq = seq.after_contraction(k, False)
            seq = seq.after_contraction(k, False)
            assert ShardState(split=split).after_pair_contraction(k) == seq
    # a pair overlapping the split is a caller bug, not a silent mis-track
    with pytest.raises(ValueError):
        ShardState(split=2).after_pair_contraction(1)
    with pytest.raises(ValueError):
        ShardState(split=1).after_pair_contraction(1)


def test_fused_streamed_memory_strictly_better():
    from repro.core import memory_model as mm
    for d, n in [(4, 175), (6, 31), (10, 8)]:
        h = mm.simulate_sweep(n, d, 1, d - 1, "hopm3")
        f = mm.simulate_sweep(n, d, 1, d - 1, "hopm3_fused")
        assert f < h
    # d=10: fused beats the paper's own ratio (~4.7x) vs classic
    c = mm.simulate_sweep(8, 10, 1, 9, "classic")
    f = mm.simulate_sweep(8, 10, 1, 9, "hopm3_fused")
    assert c / f > 5.0
