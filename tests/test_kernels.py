"""Pallas kernel validation: shape/dtype sweeps + hypothesis property tests
against the pure-jnp oracles, all in interpret mode (CPU)."""
import numpy as np
import pytest
import jax.numpy as jnp

pytest.importorskip("hypothesis", reason="optional dep: pip install -e .[test]")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ops, ref

RNG = np.random.default_rng(3)


def rand(shape, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(dtype))


# explicit sweep: edge shapes incl. non-multiples of (8, 128) tiles
UVK = [
    (1, 8, 1),        # k = d-1 on a vector-ish tensor
    (8, 128, 128),    # perfectly tiled
    (5, 7, 3),        # all ragged
    (16, 1, 256),     # nk = 1
    (1, 513, 130),    # u = 1 (k = 0), ragged lanes
    (64, 17, 1),      # v = 1 matvec path, ragged k
    (3, 1000, 1),     # v = 1, large k
]


@pytest.mark.parametrize("u,nk,v", UVK)
@pytest.mark.parametrize("polname", ["f32", "bf16", "f16"])
def test_tvc_kernel_sweep(u, nk, v, polname):
    dt = {"f32": np.float32, "bf16": None, "f16": np.float16}[polname]
    a = rand((u, nk, v))
    x = rand((nk,))
    if polname == "bf16":
        a, x = a.astype(jnp.bfloat16), x.astype(jnp.bfloat16)
    elif dt is not np.float32:
        a, x = a.astype(dt), x.astype(dt)
    got = ops.tvc_pallas(a, x, prec=polname)
    want = ref.tvc3_ref(a, x, prec=polname)
    assert got.shape == (u, v) and got.dtype == want.dtype
    tol = 1e-5 if polname == "f32" else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


@settings(max_examples=25, deadline=None)
@given(
    u=st.integers(1, 33),
    nk=st.integers(1, 160),
    v=st.integers(1, 140),
    seed=st.integers(0, 2**31),
)
def test_tvc_kernel_property(u, nk, v, seed):
    r = np.random.default_rng(seed)
    a = jnp.asarray(r.normal(size=(u, nk, v)).astype(np.float32))
    x = jnp.asarray(r.normal(size=(nk,)).astype(np.float32))
    got = ops.tvc_pallas(a, x)
    want = ref.tvc3_ref(a, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_tvc_kernel_linearity():
    a = rand((4, 24, 12))
    x1, x2 = rand((24,)), rand((24,))
    lhs = ops.tvc_pallas(a, x1 + 2.0 * x2)
    rhs = ops.tvc_pallas(a, x1) + 2.0 * ops.tvc_pallas(a, x2)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4, atol=1e-5)


def test_tvc_kernel_via_mode_view():
    A = rand((4, 6, 5, 3))
    for k in range(4):
        x = rand((A.shape[k],))
        got = ops.tvc(A, x, k)
        want = ref.tvc_ref(A, x, k)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n", [1, 127, 128, 1000, 8 * 128, 5000])
@pytest.mark.parametrize("polname", ["f32", "bf16"])
def test_axpby_kernel(n, polname):
    x = rand((n,))
    y = rand((n,))
    if polname == "bf16":
        x, y = x.astype(jnp.bfloat16), y.astype(jnp.bfloat16)
    got = ops.axpby_pallas(1.25, x, -0.5, y, prec=polname)
    want = ref.axpby_ref(1.25, x, -0.5, y, prec=polname)
    assert got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-2 if polname == "bf16" else 1e-6,
                               atol=1e-2 if polname == "bf16" else 1e-6)


def test_axpby_2d_shape_preserved():
    x = rand((13, 9))
    got = ops.axpby_pallas(2.0, x, 0.0, jnp.zeros_like(x))
    np.testing.assert_allclose(np.asarray(got), 2.0 * np.asarray(x), rtol=1e-6)


@pytest.mark.parametrize("u,n1,n2,v", [
    (1, 8, 8, 1), (4, 5, 7, 3), (8, 16, 16, 128), (2, 9, 130, 5),
])
def test_tvc2_fused_kernel(u, n1, n2, v):
    """Fused two-mode contraction kernel vs composed oracle."""
    a = rand((u, n1, n2, v))
    x1, x2 = rand((n1,)), rand((n2,))
    got = ops.tvc2_pallas(a, x1, x2)
    want = ref.tvc3_ref(
        ref.tvc3_ref(a.reshape(u, n1, n2 * v), x1).reshape(u, n2, v), x2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
