"""Pallas kernel validation: shape/dtype sweeps + hypothesis property tests
against the pure-jnp oracles, all in interpret mode (CPU).  Ragged
(non-block-multiple) dims go through the zero-copy path: ``pl.cdiv`` grids
with in-kernel edge masking, never a padded copy (asserted on the jaxpr in
:mod:`tests.test_kernels_ragged`)."""
import numpy as np
import pytest
import jax.numpy as jnp

try:  # optional dep: pip install -e .[test] — only gates the property test
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import tvc as core_tvc
from repro.kernels import ops, ref

RNG = np.random.default_rng(3)


def rand(shape, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(dtype))


def cast_policy(arrs, polname):
    if polname == "bf16":
        return [a.astype(jnp.bfloat16) for a in arrs]
    if polname == "f16":
        return [a.astype(jnp.float16) for a in arrs]
    return list(arrs)


# explicit sweep: edge shapes incl. non-multiples of (8, 128) tiles
UVK = [
    (1, 8, 1),        # k = d-1 on a vector-ish tensor
    (8, 128, 128),    # perfectly tiled
    (5, 7, 3),        # all ragged
    (16, 1, 256),     # nk = 1
    (1, 513, 130),    # u = 1 (k = 0), ragged lanes
    (64, 17, 1),      # v = 1 matvec path, ragged k
    (3, 1000, 1),     # v = 1, large k
    (7, 13, 129),     # all-prime view, ragged in every dim
    (129, 255, 7),    # ragged sublane/lane split across u and nk
]


@pytest.mark.parametrize("u,nk,v", UVK)
@pytest.mark.parametrize("polname", ["f32", "bf16", "f16"])
def test_tvc_kernel_sweep(u, nk, v, polname):
    a, x = cast_policy([rand((u, nk, v)), rand((nk,))], polname)
    got = ops.tvc_pallas(a, x, prec=polname)
    want = ref.tvc3_ref(a, x, prec=polname)
    assert got.shape == (u, v) and got.dtype == want.dtype
    tol = 1e-5 if polname == "f32" else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


# order-5 odd-shape tensor: every mode through the mode-oblivious view,
# both precision policies, all ragged dims (satellite: non-block-multiple
# coverage for the Pallas path)
@pytest.mark.parametrize("shape", [(3, 5, 7, 2, 9), (7, 13, 129)])
@pytest.mark.parametrize("polname", ["f32", "bf16"])
def test_tvc_kernel_ragged_every_mode(shape, polname):
    (A,) = cast_policy([rand(shape)], polname)
    tol = 1e-4 if polname == "f32" else 6e-2
    for k in range(len(shape)):
        (x,) = cast_policy([rand((shape[k],))], polname)
        got = ops.tvc(A, x, k, prec=polname)
        want = core_tvc(A, x, k, impl="native", prec=polname)
        assert got.shape == want.shape and got.dtype == want.dtype
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=tol, atol=tol,
        )


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_tvc_kernel_property():
    @settings(max_examples=25, deadline=None)
    @given(
        u=st.integers(1, 33),
        nk=st.integers(1, 160),
        v=st.integers(1, 140),
        seed=st.integers(0, 2**31),
    )
    def check(u, nk, v, seed):
        r = np.random.default_rng(seed)
        a = jnp.asarray(r.normal(size=(u, nk, v)).astype(np.float32))
        x = jnp.asarray(r.normal(size=(nk,)).astype(np.float32))
        got = ops.tvc_pallas(a, x)
        want = ref.tvc3_ref(a, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    check()


def test_tvc_kernel_linearity():
    a = rand((4, 24, 12))
    x1, x2 = rand((24,)), rand((24,))
    lhs = ops.tvc_pallas(a, x1 + 2.0 * x2)
    rhs = ops.tvc_pallas(a, x1) + 2.0 * ops.tvc_pallas(a, x2)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4, atol=1e-5)


def test_tvc_kernel_via_mode_view():
    A = rand((4, 6, 5, 3))
    for k in range(4):
        x = rand((A.shape[k],))
        got = ops.tvc(A, x, k)
        want = ref.tvc_ref(A, x, k)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n", [1, 127, 128, 129, 1000, 8 * 128, 8 * 128 + 5,
                               5000])
@pytest.mark.parametrize("polname", ["f32", "bf16"])
def test_axpby_kernel(n, polname):
    x, y = cast_policy([rand((n,)), rand((n,))], polname)
    got = ops.axpby_pallas(1.25, x, -0.5, y, prec=polname)
    want = ref.axpby_ref(1.25, x, -0.5, y, prec=polname)
    assert got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-2 if polname == "bf16" else 1e-6,
                               atol=1e-2 if polname == "bf16" else 1e-6)


def test_axpby_2d_shape_preserved():
    x = rand((13, 9))
    got = ops.axpby_pallas(2.0, x, 0.0, jnp.zeros_like(x))
    np.testing.assert_allclose(np.asarray(got), 2.0 * np.asarray(x), rtol=1e-6)


@pytest.mark.parametrize("u,n1,n2,v", [
    (1, 8, 8, 1), (4, 5, 7, 3), (8, 16, 16, 128), (2, 9, 130, 5),
])
def test_tvc2_fused_kernel(u, n1, n2, v):
    """Fused two-mode contraction kernel vs composed oracle."""
    a = rand((u, n1, n2, v))
    x1, x2 = rand((n1,)), rand((n2,))
    got = ops.tvc2_pallas(a, x1, x2)
    want = ref.tvc3_ref(
        ref.tvc3_ref(a.reshape(u, n1, n2 * v), x1).reshape(u, n2, v), x2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
