"""Zero-copy guarantees of the ragged Pallas path: no ``pad`` primitive in
the jaxpr, the fused alpha/beta epilogue, the VMEM-aware block autotuner, and
the streamed-bytes accounting that backs the bandwidth harness.  No optional
deps — this file runs everywhere the kernels do."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import memory_model as mm
from repro.core.tvc import mode_uv, tvc as core_tvc, tvc_bytes
from repro.core.mixed_precision import get_policy
from repro.kernels import autotune, ops, ref

RNG = np.random.default_rng(5)


def rand(shape, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(dtype))


# ---- no-copy: the jaxpr of the Pallas path must not contain `pad` ---------

def _primitives(jaxpr, acc):
    """All primitive names in a jaxpr, recursing into sub-jaxpr params
    (incl. the pallas_call kernel body)."""
    for eqn in jaxpr.eqns:
        acc.add(eqn.primitive.name)
        for v in eqn.params.values():
            for item in (v if isinstance(v, (list, tuple)) else [v]):
                inner = getattr(item, "jaxpr", item)
                if hasattr(inner, "eqns"):
                    _primitives(inner, acc)
    return acc


@pytest.mark.parametrize("shape,k", [
    ((7, 13, 129), 1),           # all-prime order-3, middle mode
    ((7, 13, 129), 2),           # matvec path (v == 1)
    ((3, 5, 7, 2, 9), 2),        # order-5 odd shape
])
def test_no_pad_in_pallas_jaxpr(shape, k):
    A, x = rand(shape), rand((shape[k],))
    jaxpr = jax.make_jaxpr(
        lambda A, x: core_tvc(A, x, k, impl="pallas"))(A, x)
    prims = _primitives(jaxpr.jaxpr, set())
    assert "pallas_call" in prims
    assert "pad" not in prims, sorted(prims)


def test_no_pad_in_pallas_jaxpr_with_update():
    A, x, y = rand((7, 13, 129)), rand((13,)), rand((7, 129))
    jaxpr = jax.make_jaxpr(
        lambda A, x, y: core_tvc(A, x, 1, alpha=2.0, beta=-0.5, y=y,
                                 impl="pallas"))(A, x, y)
    prims = _primitives(jaxpr.jaxpr, set())
    assert "pad" not in prims, sorted(prims)


def test_no_pad_in_axpby_jaxpr():
    x, y = rand((999,)), rand((999,))   # ragged: 999 % 128 != 0
    jaxpr = jax.make_jaxpr(
        lambda x, y: ops.axpby_pallas(1.25, x, -0.5, y))(x, y)
    prims = _primitives(jaxpr.jaxpr, set())
    assert "pad" not in prims, sorted(prims)


def test_no_pad_in_tvc2_jaxpr():
    a, x1, x2 = rand((4, 5, 7, 3)), rand((5,)), rand((7,))
    jaxpr = jax.make_jaxpr(
        lambda a, x1, x2: ops.tvc2_pallas(a, x1, x2))(a, x1, x2)
    prims = _primitives(jaxpr.jaxpr, set())
    assert "pad" not in prims, sorted(prims)


# ---- fused alpha/beta epilogue --------------------------------------------

@pytest.mark.parametrize("u,nk,v", [(7, 13, 129), (5, 7, 3), (37, 129, 1)])
@pytest.mark.parametrize("polname", ["f32", "bf16"])
def test_fused_epilogue_matches_oracle(u, nk, v, polname):
    prec = get_policy(polname)
    a = rand((u, nk, v)).astype(prec.storage)
    x = rand((nk,)).astype(prec.storage)
    y = rand((u, v)).astype(prec.storage)
    got = ops.tvc_pallas(a, x, y, alpha=2.5, beta=-0.5, prec=polname)
    base = np.asarray(ref.tvc3_ref(a, x, prec=polname), np.float32)
    want = 2.5 * base - 0.5 * np.asarray(y, np.float32)
    tol = 1e-4 if polname == "f32" else 6e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               rtol=tol, atol=tol)


def test_pallas_beta_requires_y():
    with pytest.raises(ValueError):
        ops.tvc_pallas(rand((3, 4, 5)), rand((4,)), beta=1.0)


def test_ops_tvc_wrapper_honours_update():
    """Satellite: the arbitrary-order wrapper is drop-in for
    core.tvc(impl="pallas") including alpha/beta/y."""
    A, k = rand((3, 5, 7, 2)), 2
    x, y = rand((7,)), rand((3, 5, 2))
    got = ops.tvc(A, x, k, alpha=0.5, beta=1.5, y=y)
    want = core_tvc(A, x, k, alpha=0.5, beta=1.5, y=y, impl="native")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_core_tvc_pallas_update_matches_native_ragged():
    A = rand((7, 13, 129))
    for k in range(3):
        x = rand((A.shape[k],))
        y = rand(core_tvc(A, x, k).shape)
        got = core_tvc(A, x, k, alpha=3.0, beta=-2.0, y=y, impl="pallas")
        want = core_tvc(A, x, k, alpha=3.0, beta=-2.0, y=y, impl="native")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


# ---- axpby: zero-copy ragged ----------------------------------------------

@pytest.mark.parametrize("shape", [(1,), (999,), (13, 9), (7, 11, 3)])
def test_axpby_ragged_shapes(shape):
    x, y = rand(shape), rand(shape)
    got = ops.axpby_pallas(1.25, x, -0.5, y)
    np.testing.assert_allclose(
        np.asarray(got), 1.25 * np.asarray(x) - 0.5 * np.asarray(y),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", [129, 257, 8 * 128 + 1, 37 * 128 - 1])
def test_axpby_tiled_masked_tail(n):
    """Satellite: lane-UNALIGNED sizes take the tiled (bt, 128) re-tile path
    with the in-kernel masked tail — the old single-sublane (1, n) fallback
    is gone for n > 128 — and every element, tail included, is exact."""
    x, y = rand((n,)), rand((n,))
    got = ops.axpby_pallas(2.0, x, 3.0, y)
    np.testing.assert_allclose(
        np.asarray(got), 2.0 * np.asarray(x) + 3.0 * np.asarray(y),
        rtol=1e-5, atol=1e-5)
    # and it is still copy-free
    jaxpr = jax.make_jaxpr(lambda x, y: ops.axpby_pallas(2.0, x, 3.0, y))(x, y)
    prims = _primitives(jaxpr.jaxpr, set())
    assert "pad" not in prims, sorted(prims)


# ---- autotuner -------------------------------------------------------------

def test_sublane_quantum_is_dtype_aware():
    assert autotune.sublane_quantum(jnp.float32) == 8
    assert autotune.sublane_quantum(jnp.bfloat16) == 16
    assert autotune.sublane_quantum(jnp.float16) == 16
    assert autotune.sublane_quantum(jnp.int8) == 32


@pytest.mark.parametrize("storage", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("u,nk,v", [
    (7, 13, 129), (4096, 4096, 4096), (1, 1, 1), (64, 17, 513),
])
def test_tvc3_blocks_respect_quanta_and_budget(storage, u, nk, v):
    q = autotune.sublane_quantum(storage)
    bu, bk, bv = autotune.pick_tvc3_blocks(u, nk, v, storage=storage)
    assert bu % 8 == 0 and bk % q == 0 and bv % autotune.LANE == 0
    ssz = jnp.dtype(storage).itemsize
    blk_bytes = 2 * bu * bk * bv * ssz + bu * bv * 4
    assert blk_bytes <= autotune.vmem_budget(), (bu, bk, bv, blk_bytes)
    # never more than one fully-masked block along any dim
    assert (bu - 8 < u or u <= 8) and bk - q < nk + q and bv - 128 < v + 128


def test_tvc2_blocks_flip_quantum_roles():
    """Satellite regression: the matvec path lanes on n_k (quantum 128) and
    sublanes on u (dtype quantum) — the seed had bk quantum 8 vs 128 mixed
    up between the two paths."""
    for storage in (jnp.float32, jnp.bfloat16):
        q = autotune.sublane_quantum(storage)
        bu, bk = autotune.pick_tvc2_blocks(1000, 1000, storage=storage)
        assert bk % autotune.LANE == 0
        assert bu % q == 0


def test_vmem_budget_shrinks_blocks():
    big = autotune.pick_tvc3_blocks(4096, 4096, 4096)
    small = autotune.pick_tvc3_blocks(4096, 4096, 4096, budget=256 * 1024)
    assert np.prod(small) < np.prod(big)
    bu, bk, bv = small
    assert 2 * bu * bk * bv * 4 <= 256 * 1024


def test_explicit_block_override_wins():
    a, x = rand((64, 256, 256)), rand((256,))
    got = ops.tvc_pallas(a, x, bu=8, bk=16, bv=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.tvc3_ref(a, x)),
                               rtol=1e-4, atol=1e-4)


# ---- streamed-bytes accounting --------------------------------------------

def test_streamed_elems_matches_tvc_bytes():
    shape, k = (7, 13, 129), 1
    u, nk, v = mode_uv(shape, k)
    assert mm.tvc_streamed_elems(u, nk, v) * 4 == tvc_bytes(shape, k, 4)
    assert mm.tvc_streamed_elems(u, nk, v, beta=1.0) * 4 == \
        tvc_bytes(shape, k, 4, beta=1.0)


def test_pad_overhead_identity_when_aligned():
    assert mm.pad_overhead(64, 128, 128, (8, 128, 128)) == pytest.approx(1.0)


def test_pad_overhead_ragged_exceeds_one():
    # the motivating case: non-block-multiple dims used to force a full
    # zero-padded copy of A — more than 2x streamed traffic for small blocks
    ratio = mm.pad_overhead(7, 13, 129, (8, 128, 128))
    assert ratio > 2.0
    # and the old beta path paid a second full pass over Y
    assert mm.pad_overhead(64, 128, 128, (8, 128, 128), beta=1.0) > 1.0
