"""Streamed-memory model: closed forms vs exact simulator vs paper's Fig. 2."""
import math

import pytest

pytest.importorskip("hypothesis", reason="optional dep: pip install -e .[test]")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import memory_model as mm

# The paper's Table 1 hypersquare suite.
TABLE1 = {2: 30623, 3: 979, 4: 175, 5: 63, 6: 31, 7: 19, 8: 13, 9: 10, 10: 8}


def test_m_seq_structure():
    # Eq. (3): n^d + 2 sum n^k + (d+3) n
    assert mm.m_seq(10, 3) == 1000 + 2 * 100 + 6 * 10
    assert mm.M_seq(10, 3) == 3 * mm.m_seq(10, 3)


@pytest.mark.parametrize("d,n", sorted(TABLE1.items()))
def test_eq6_matches_recursion(d, n):
    for p in (2, 4, 8):
        for s in range(d):
            a = mm.M_par(n, d, p, s)
            b = mm.M_par_rec(n, d, p, s)
            assert math.isclose(a, b, rel_tol=1e-9), (d, p, s)


@pytest.mark.parametrize("d,n", sorted(TABLE1.items()))
def test_simulator_matches_closed_form_classic(d, n):
    for p in (1, 4, 8):
        for s in range(d):
            sim = mm.simulate_sweep(n, d, p, s, "classic")
            cf = mm.M_par(n, d, p, s)
            # Eqs. (4)-(6) carry the paper's own ~(p-1)/p vector-term
            # approximations; exact counts agree to well under 1%.
            assert abs(sim - cf) / cf < 0.01, (d, p, s, sim, cf)


def test_paper_fig2a_values():
    # "the data movement more than doubles for s_hat = 0 and p_hat = 1"
    assert mm.eta_inv(979, 3, 979, 0) > 2.0
    assert mm.eta_inv(8, 10, 8, 0) > 2.0
    # and s = d-1 keeps M_par ~ M_seq / p
    assert mm.eta_inv(979, 3, 979, 2) < 1.05
    assert mm.eta_inv(8, 10, 8, 9) < 1.10


def test_paper_fig2b_values():
    # "economizes about 1.5x of the touched memory for d = 3 and roughly a
    #  fivefold for d = 10 (with the presence of a minimum of about 3.3x)"
    assert 1.4 < mm.H_inv(979, 3, 8, 2) < 1.6
    assert 4.3 < mm.H_inv(8, 10, 8, 0) < 5.3
    grid = [mm.H_inv(8, 10, p, s) for p in range(1, 9) for s in range(10)]
    assert 3.1 < min(grid) < 3.5
    assert max(grid) < 5.3


def test_hopm3_never_streams_more():
    for d, n in TABLE1.items():
        for p in (1, 2, 8):
            for s in range(d):
                assert (mm.simulate_sweep(n, d, p, s, "hopm3")
                        <= mm.simulate_sweep(n, d, p, s, "classic") + 1e-6)


def test_saved_contractions():
    assert mm.saved_contractions(3) == 1
    assert mm.saved_contractions(10) == 36


def test_ring_term():
    # 4n(p-1)/p; paper: worst case d=2, p_hat=1 adds ~57% over M_par_min
    n = 30623
    p = n
    ring = mm.ring_allreduce_touched(n, p)
    assert abs(ring - 4 * n * (p - 1) / p) < 1e-6


@settings(max_examples=40, deadline=None)
@given(d=st.integers(2, 10), p=st.integers(1, 16), s_frac=st.floats(0, 1))
def test_split_last_dim_is_never_worse(d, p, s_frac):
    """Paper's recommendation: s = d-1 minimizes streamed memory."""
    n = TABLE1[d]
    s = min(d - 1, int(s_frac * d))
    assert (mm.simulate_sweep(n, d, p, d - 1, "hopm3")
            <= mm.simulate_sweep(n, d, p, s, "hopm3") * (1 + 1e-9))
