"""Per-arch smoke tests on reduced configs: forward shapes + no NaNs, one
train-step gradient, and the decode-vs-forward consistency oracle (decode
logits from a KV/state cache must match the full-sequence forward)."""
import zlib

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.models import extra_input_key, registry

ARCHS = [
    "kimi-k2-1t-a32b", "deepseek-v2-lite-16b", "whisper-tiny", "stablelm-1.6b",
    "qwen2-1.5b", "llama3-405b", "granite-8b", "rwkv6-3b", "internvl2-26b",
    "recurrentgemma-9b",
]

B, S = 2, 24


def make_batch(cfg, rng):
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(B, S)), jnp.int32)}
    key = extra_input_key(cfg)
    if key == "img_embeds":
        d = cfg.vlm.img_embed_dim or cfg.d_model
        batch[key] = jnp.asarray(
            rng.normal(size=(B, cfg.vlm.n_img_tokens, d)).astype(np.float32))
    elif key == "audio_embeds":
        batch[key] = jnp.asarray(
            rng.normal(size=(B, cfg.encdec.n_audio_ctx, cfg.d_model)).astype(np.float32))
    return batch


def setup(arch):
    cfg = get_config(arch, smoke=True)
    mod = registry.get(cfg.family)
    # crc32, NOT hash(): str hashing is salted per process (PYTHONHASHSEED),
    # so hash(arch) drew a fresh token batch every run — and for the MoE
    # archs an unlucky batch can disagree between full-forward and decode
    # routing (different token counts compete for capacity slots), which is
    # exactly the test_decode_matches_forward[kimi-k2-1t-a32b] flake.  A
    # stable seed makes every run the same (passing) run.
    rng = np.random.default_rng(zlib.crc32(arch.encode()) % 2**31)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, rng)
    return cfg, mod, params, batch


def test_registry_covers_assignment():
    assert sorted(ARCHS) == list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg, mod, params, batch = setup(arch)
    extra = batch.get(extra_input_key(cfg)) if extra_input_key(cfg) else None
    if extra is not None:
        logits, _ = mod.forward(cfg, params, batch["tokens"], extra)
    else:
        logits, _ = mod.forward(cfg, params, batch["tokens"])
    S_total = S + (cfg.vlm.n_img_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_total, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_and_grad_step(arch):
    cfg, mod, params, batch = setup(arch)

    def loss(p):
        l, _ = mod.loss_fn(cfg, p, batch)
        return l

    val, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(val)) and float(val) > 0
    gnorm = jax.tree.reduce(
        lambda a, g: a + float(jnp.sum(jnp.square(g.astype(jnp.float32)))),
        grads, 0.0)
    assert np.isfinite(gnorm) and gnorm > 0
    # one SGD step changes the loss
    new_params = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype), params, grads)
    val2, _ = mod.loss_fn(cfg, new_params, batch)
    assert float(val2) != float(val)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Golden oracle: prefill(t0) + step-by-step decode must reproduce the
    full-forward logits at every decoded position."""
    cfg, mod, params, batch = setup(arch)
    tokens = batch["tokens"]
    extra_key = extra_input_key(cfg)
    extra = batch.get(extra_key) if extra_key else None

    if extra is not None:
        full_logits, _ = mod.forward(cfg, params, tokens, extra)
    else:
        full_logits, _ = mod.forward(cfg, params, tokens)
    if cfg.family == "vlm":
        full_logits = full_logits[:, cfg.vlm.n_img_tokens:]

    t0 = S // 2
    cache = mod.init_cache(cfg, B, S + 8)
    if cfg.family == "vlm":
        # prefill consumes image prefix + prompt
        cache = mod.init_cache(cfg, B, S + 8 + cfg.vlm.n_img_tokens)
        cache, logits = mod.prefill(cfg, params, tokens[:, :t0], cache, extra)
    elif extra is not None:
        cache, logits = mod.prefill(cfg, params, tokens[:, :t0], cache, extra)
    else:
        cache, logits = mod.prefill(cfg, params, tokens[:, :t0], cache)

    np.testing.assert_allclose(
        np.asarray(logits[:, 0], np.float32),
        np.asarray(full_logits[:, t0 - 1], np.float32), rtol=2e-2, atol=2e-2)

    for t in range(t0, S):
        cache, logits = mod.decode_step(cfg, params, cache, tokens[:, t:t + 1])
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full_logits[:, t], np.float32), rtol=2e-2, atol=2e-2,
            err_msg=f"{arch}: decode diverges at position {t}")


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_positive(arch):
    cfg = get_config(arch, smoke=True)
    full = get_config(arch)
    mod = registry.get(cfg.family)
    assert mod.param_count(cfg) > 0
    assert mod.active_param_count(full) <= mod.param_count(full)


def test_full_param_counts_match_published_scale():
    """Full configs should land near their published parameter counts."""
    expect = {
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "deepseek-v2-lite-16b": (13e9, 18e9),
        "llama3-405b": (380e9, 430e9),
        "granite-8b": (7e9, 9.5e9),
        "qwen2-1.5b": (1.2e9, 2.0e9),
        "stablelm-1.6b": (1.3e9, 2.0e9),
        "rwkv6-3b": (2.5e9, 3.6e9),
        "internvl2-26b": (18e9, 27e9),   # LM backbone share of 26B
        "recurrentgemma-9b": (7.5e9, 11e9),
        "whisper-tiny": (25e6, 60e6),
    }
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        n = registry.get(cfg.family).param_count(cfg)
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.2e}, {hi:.2e}]"
