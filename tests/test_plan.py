"""Planner + AOT warm-start tests: table-driven engine choices, choice
monotonicity, plan hashability/trace-stability, the tvc2 two-launch
fallback counter, and the in-process warmup cache."""
import json
import pathlib

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import tvc, tvc2
from repro.plan import (
    aot,
    calibration,
    plan_batched,
    plan_compress,
    plan_dhopm3,
    plan_report,
    plan_tvc,
    plan_tvc2,
    planner,
    reset_plan_report,
)

ROOT = pathlib.Path(__file__).resolve().parent.parent

BIG = (256, 256, 256)
SMALL = (8, 8, 8)


# ---- calibration table ----

def test_committed_calibration_table_loads():
    # the checked-in artifact benchmarks/calibrate.py fitted from the
    # committed trajectory: present, parsed, and actually used
    assert calibration.DEFAULT_PATH.exists()
    table = calibration.load()
    assert table["source"] is not None
    assert calibration.dispatch_us() > 0
    assert calibration.peak_gbs() > 0
    for engine in ("native", "mulsum"):
        assert calibration.engine_gbs(engine, leading=True) > 0
        assert calibration.engine_gbs(engine, leading=False) > 0
    ceil = calibration.ceilings()
    assert ceil["ratio_native"] > 1 and ceil["ratio_pallas"] >= 2.0


def test_calibrate_fit_from_committed_bench():
    from benchmarks.calibrate import fit
    payload = json.loads((ROOT / "BENCH_TVC.json").read_text())
    table = fit(payload, source="BENCH_TVC.json")
    assert table["dispatch_us"] > 0
    assert "native" in table["fitted"]["engines"]
    # every CPU engine ends up with a usable bandwidth estimate, whether
    # fitted from its own flag-sweep samples (schema 6) or mirrored
    for engine in ("native", "looped", "unfolded", "mulsum"):
        assert table["engines"][engine]["gbs"] > 0, engine


# ---- table-driven choices ----

CHOICE_TABLE = [
    # (planner call, expected engine)
    (lambda: plan_tvc(BIG, 0, itemsize=4, backend="cpu"), "native"),
    (lambda: plan_tvc(BIG, 2, itemsize=4, backend="cpu"), "native"),
    # leading pair: mulsum streams several times faster than the einsum
    (lambda: plan_tvc2(BIG, 0, itemsize=4, backend="cpu"), "mulsum"),
    (lambda: plan_tvc2((64,) * 4, 0, itemsize=2, backend="cpu"), "mulsum"),
    # inner/tail pair: the einsum wins
    (lambda: plan_tvc2(BIG, 1, itemsize=4, backend="cpu"), "native"),
    (lambda: plan_tvc2((64,) * 4, 2, itemsize=4, backend="cpu"), "native"),
    # chains pin the bitwise-batchable engine per backend
    (lambda: plan_batched(8, (16, 16, 16), 1, itemsize=4, backend="cpu"),
     "mulsum"),
    (lambda: plan_batched(8, (16, 16, 16), 1, itemsize=4, backend="tpu"),
     "pallas"),
    (lambda: plan_dhopm3((8,) * 4, p=1, s=3, backend="cpu"), "mulsum"),
    (lambda: plan_dhopm3((8,) * 4, p=1, s=3, backend="tpu"), "pallas"),
    # grad_compress pins mulsum on EVERY backend (bitwise bucket guarantee)
    (lambda: plan_compress(4, (32, 8), backend="cpu"), "mulsum"),
    (lambda: plan_compress(4, (32, 8), backend="tpu"), "mulsum"),
]


@pytest.mark.parametrize("case", range(len(CHOICE_TABLE)))
def test_planner_choice_table(case):
    make, want = CHOICE_TABLE[case]
    assert make().impl == want


def test_single_mode_auto_never_mulsum():
    # mulsum's single-mode CPU behavior is bimodal (pathological on some
    # shapes) — the planner's contract is "never pathological"
    for shape in (SMALL, (64,) * 4, BIG, (24,) * 5):
        for k in range(len(shape)):
            p = plan_tvc(shape, k, itemsize=4, backend="cpu")
            assert p.impl in ("native", "looped", "unfolded"), (shape, k, p)


def test_dhopm3_plan_flags():
    # fusion strictly reduces launches at s = d-1 on an order-4 chain
    p = plan_dhopm3((8,) * 4, p=1, s=3, itemsize=4, backend="cpu")
    assert p.fused
    # no wire to hide at p = 1: auto stays synchronous
    assert p.overlap_chunks == 1
    # explicit flags always override the model
    q = plan_dhopm3((8,) * 4, p=1, s=3, fuse_pairs=False, overlap=4,
                    backend="cpu")
    assert not q.fused and q.overlap_chunks == 4
    # allreduce algorithm from dist.collectives at the dominant payload
    r = plan_dhopm3((64,) * 3, p=8, s=2, backend="cpu")
    assert r.algo in ("ring", "doubling")


def test_tvc2_choice_monotone_in_size():
    """Growing n never flips auto BACK to the dispatch-bound engine: once
    the bandwidth-bound winner (mulsum on leading pairs) takes over, it
    stays for every larger size."""
    sizes = (2, 4, 8, 16, 32, 64, 128, 256)
    seq = [plan_tvc2((n, n, n), 0, itemsize=4, backend="cpu").impl
           for n in sizes]
    assert seq[-1] == "mulsum"  # the measured large-shape winner
    first = seq.index("mulsum")
    assert all(e == "mulsum" for e in seq[first:]), seq


def test_batched_bucket_monotone_in_batch():
    got = [plan_batched(b, (16, 16, 16), 1, itemsize=4, backend="cpu").bucket
           for b in (1, 2, 8, 64, 512)]
    assert got[0] is False  # B = 1: nothing to amortize
    first = got.index(True)
    assert all(got[first:]), got


# ---- Plan object contract ----

def test_plan_hashable_and_cached():
    a = plan_tvc2(BIG, 0, itemsize=4, backend="cpu")
    b = plan_tvc2(BIG, 0, itemsize=4, backend="cpu")
    assert a is b  # lru-cached: same static inputs, same object
    assert hash(a) == hash(b)
    d = a.as_cell_dict()
    assert set(d) == {"engine", "fused", "overlap_chunks", "algo"}


def test_auto_matches_explicit_bitwise():
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(size=(8, 12, 6)).astype(np.float32))
    x1 = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))
    x2 = jnp.asarray(rng.normal(size=(12,)).astype(np.float32))
    impl = plan_tvc2((8, 12, 6), 0, itemsize=4).impl
    got = tvc2(A, x1, 0, x2, 1, impl="auto")
    want = tvc2(A, x1, 0, x2, 1, impl=impl)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_disable_plan_env(monkeypatch):
    monkeypatch.setenv("REPRO_TVC_DISABLE_PLAN", "1")
    p = plan_tvc2(BIG, 0, itemsize=4, backend="cpu")
    assert p.reason == "plan-disabled"
    assert p.impl == "native"  # the pre-planner static default


# ---- fallback counter (bugfix regression) ----

def test_tvc2_traced_ab_two_launch_counted():
    """The former SILENT de-optimization: a traced alpha forces the pallas
    pair kernel's fused epilogue out into a second launch.  It must now be
    counted in plan_report()."""
    reset_plan_report()
    rng = np.random.default_rng(1)
    A = jnp.asarray(rng.normal(size=(4, 5, 6)).astype(np.float32))
    x1 = jnp.asarray(rng.normal(size=(4,)).astype(np.float32))
    x2 = jnp.asarray(rng.normal(size=(5,)).astype(np.float32))

    @jax.jit
    def f(A, x1, x2, alpha):  # alpha is a tracer inside jit
        return tvc2(A, x1, 0, x2, 1, alpha=alpha, impl="pallas")

    out = f(A, x1, x2, jnp.float32(2.0))
    want = 2.0 * np.einsum("abv,a,b->v", np.asarray(A), np.asarray(x1),
                           np.asarray(x2))
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5, atol=1e-5)
    counters = plan_report()["counters"]
    assert counters.get("tvc2.two_launch_fallback", 0) >= 1, counters


def test_tvc2_static_ab_no_fallback_counter():
    reset_plan_report()
    rng = np.random.default_rng(2)
    A = jnp.asarray(rng.normal(size=(4, 5, 6)).astype(np.float32))
    x1 = jnp.asarray(rng.normal(size=(4,)).astype(np.float32))
    x2 = jnp.asarray(rng.normal(size=(5,)).astype(np.float32))
    tvc2(A, x1, 0, x2, 1, alpha=2.0, impl="pallas")
    counters = plan_report()["counters"]
    assert counters.get("tvc2.two_launch_fallback", 0) == 0, counters


# ---- AOT warm-start ----

def test_warmup_in_process_cache_hit():
    aot.reset()

    def step(x):
        return x * 2.0 + 1.0

    fn = jax.jit(step)
    x = jnp.ones((8,), jnp.float32)
    r1 = aot.warmup(fn, x, name="test_plan_step")
    assert r1["cache"] in ("cold", "persistent")
    assert r1["compile_us"] > 0
    r2 = aot.warmup(fn, x, name="test_plan_step")
    assert r2["cache"] == "in_process"
    # a different shape signature is a new entry, not a hit
    r3 = aot.warmup(fn, jnp.ones((4,), jnp.float32), name="test_plan_step")
    assert r3["cache"] != "in_process"
    stats = plan_report()["aot"]
    assert stats["entries"] >= 2
    assert stats["in_process_hits"] >= 1


def test_warmup_executable_runs():
    aot.reset()
    fn = jax.jit(lambda x: x + 1.0)
    x = jnp.zeros((4,), jnp.float32)
    rep = aot.warmup(fn, x, name="test_plan_exec")
    out = rep["executable"](x)
    np.testing.assert_array_equal(np.asarray(out), np.ones((4,)))


def test_persistent_cache_roundtrip(tmp_path, monkeypatch):
    """Second warmup of the SAME computation under a fresh warmup registry
    (a new process, as far as the in-process dict is concerned) must hit
    the persistent compilation cache, not recompile."""
    aot.enable_persistent_cache(str(tmp_path / "xla_cache"))
    aot.reset()
    fn = jax.jit(lambda x: jnp.tanh(x) * 3.0)
    x = jnp.ones((16,), jnp.float32)
    r1 = aot.warmup(fn, x, name="test_plan_persist")
    aot.reset()  # wipe the in-process registry; persistent cache survives
    fn2 = jax.jit(lambda x: jnp.tanh(x) * 3.0)
    r2 = aot.warmup(fn2, x, name="test_plan_persist")
    assert r2["cache"] == "persistent", (r1, r2)
