"""Continuous-batching serve engine: admission, slot recycling, ragged
prompts, grouped KV compression, and the determinism guarantees."""
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import memory_model as mm
from repro.core.dhopm import hopm3, hopm3_batched, hopm_init_factors
from repro.models import registry
from repro.serve import DecodeEngine, GenerationResult, Request, RequestQueue
from repro.serve.engine import _compress_group
from repro.verify.walker import count_primitive

EOS = 7


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-1.5b", smoke=True)
    params = registry.get(cfg.family).init(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def engine4(setup):
    cfg, params = setup
    return DecodeEngine(cfg, params, batch_size=4, max_seq=64, eos_id=EOS)


def _reqs(n, max_new=4, base_len=3):
    # ragged on purpose: lengths cycle base_len .. base_len+3
    return [Request(rid=i,
                    tokens=np.arange(base_len + i % 4, dtype=np.int32) + 1,
                    max_new_tokens=max_new)
            for i in range(n)]


# ---- GenerationResult.lengths default (bugfix) ----------------------------

def test_generation_result_lengths_default():
    # lengths was a mutable-default-adjacent `= None` with no construction:
    # callers that skipped it got None and crashed on arithmetic downstream
    r = GenerationResult(np.zeros((3, 5), np.int32), steps=5,
                         prefill_tokens=12)
    assert r.lengths is not None and r.lengths.shape == (3,)
    assert (r.lengths == 5).all()
    explicit = GenerationResult(np.zeros((2, 4), np.int32), steps=4,
                                prefill_tokens=8,
                                lengths=np.array([2, 4]))
    assert (explicit.lengths == [2, 4]).all()


# ---- slot lifecycle edge cases --------------------------------------------

def test_all_slots_retire_at_step_zero(engine4):
    """Every request's budget is one token — all slots retire on their
    prefill sample, before a single engine step runs."""
    res, stats = engine4.serve(RequestQueue(_reqs(4, max_new=1)),
                               compress=False)
    assert stats.completed == 4
    assert stats.steps == 0
    assert all(r.length == 1 for r in res)


def test_queue_drains_mid_step(engine4):
    """More requests than slots: the tail of the queue must be admitted
    into recycled slots mid-generation and still complete."""
    res, stats = engine4.serve(RequestQueue(_reqs(11, max_new=3)),
                               compress=False)
    assert stats.completed == 11
    assert stats.recycled >= 7          # 11 requests through 4 slots
    assert sorted(r.rid for r in res) == list(range(11))
    assert all(1 <= r.length <= 3 for r in res)


def test_b1_engine(setup):
    cfg, params = setup
    eng = DecodeEngine(cfg, params, batch_size=1, max_seq=64, eos_id=EOS)
    res, stats = eng.serve(RequestQueue(_reqs(3, max_new=3)), compress=True,
                           comp_impl="mulsum")
    assert stats.completed == 3
    assert all(r.compressed for r in res)


def test_ragged_prompts_cohort_independent(setup, engine4):
    """Ragged prompts served together in one slot batch produce exactly the
    tokens each request gets when served alone — a slot's stream depends
    only on its own request (fresh batch-1 prefill + request-keyed
    sampling), never on cohort or admission order."""
    cfg, params = setup
    reqs = _reqs(4, max_new=4)
    together, _ = engine4.serve(
        RequestQueue(Request(rid=r.rid, tokens=r.tokens,
                             max_new_tokens=r.max_new_tokens)
                     for r in reqs), compress=False)
    eng1 = DecodeEngine(cfg, params, batch_size=1, max_seq=64, eos_id=EOS)
    by_rid = {r.rid: r for r in together}
    for req in reqs:
        alone, _ = eng1.serve(
            RequestQueue([Request(rid=req.rid, tokens=req.tokens,
                                  max_new_tokens=req.max_new_tokens)]),
            compress=False)
        assert np.array_equal(alone[0].tokens, by_rid[req.rid].tokens), \
            req.rid


# ---- grouped compression ---------------------------------------------------

def test_serve_compression_accounting(engine4):
    res, stats = engine4.serve(RequestQueue(_reqs(8, max_new=3)),
                               compress=True, comp_sweeps=2,
                               comp_impl="mulsum")
    assert stats.completed == 8
    assert stats.comp_events
    # launch accounting: per group event, sweeps x the walker's launch
    # schedule for the view ORDER — group size never enters
    want = sum(2 * mm.dhopm_launches_per_sweep(len(v))
               for _b, v in stats.comp_events)
    assert stats.comp_launches == want
    assert stats.comp_dense_bytes > stats.comp_factor_bytes
    assert stats.compression_ratio > 1.0
    for r in res:
        assert set(r.compressed) == {"k", "v"}
        for c in r.compressed.values():
            assert len(c.xs) == len(c.view)
            assert c.ctx == r.prompt_len + r.length
            assert c.factor_bytes == mm.rank1_factor_elems(c.view) * 4


def test_compress_group_bitwise_vs_per_slot():
    """The engine's grouped rank-1 chain must match per-slot hopm3 BITWISE
    under the order-explicit mulsum engine (same guarantee grad_compress's
    buckets carry)."""
    rng = np.random.default_rng(5)
    view = (2, 2, 16, 8)
    B = 3
    A_b = jnp.asarray(rng.standard_normal((B,) + view), jnp.float32)
    xs0 = [hopm_init_factors(jax.random.PRNGKey(i), view)[0]
           for i in range(B)]
    xs_b = tuple(jnp.stack([x[m] for x in xs0]) for m in range(len(view)))
    xs, lam = _compress_group(A_b, xs_b, sweeps=2, impl="mulsum")
    for b in range(B):
        x1, l1 = hopm3(A_b[b], list(xs0[b]), sweeps=2, impl="mulsum")
        assert np.array_equal(np.asarray(lam[b]), np.asarray(l1))
        for m in range(len(view)):
            assert np.array_equal(np.asarray(xs[m][b]), np.asarray(x1[m]))


def _count_pallas(jaxpr):
    # the shared walker also descends into list/tuple params (cond
    # branches), which this file's old private copy silently skipped
    return count_primitive(jaxpr, "pallas_call")


def test_compress_group_one_launch_chain_any_group_size():
    """Acceptance: ONE batched contraction launch chain per compression
    group per step — the pallas launch count in the traced chain equals
    sweeps x dhopm_launches_per_sweep(d) and is independent of the group
    size (a per-slot loop would scale linearly with B)."""
    view = (2, 2, 16, 8)
    sweeps = 2
    want = sweeps * mm.dhopm_launches_per_sweep(len(view))
    counts = set()
    for B in (2, 16):
        A = jnp.zeros((B,) + view, jnp.float32)
        xb = tuple(jnp.zeros((B, n), jnp.float32) for n in view)
        jx = jax.make_jaxpr(
            lambda a, x: hopm3_batched(a, list(x), sweeps=sweeps,
                                       impl="pallas"))(A, xb)
        counts.add(_count_pallas(jx.jaxpr))
    assert counts == {want}, (counts, want)


# ---- recycled-slot determinism across hash salts ---------------------------

_SERVE_DIGEST = r"""
import zlib
import numpy as np
import jax
from repro.configs import get_config
from repro.models import registry
from repro.serve import DecodeEngine, Request, RequestQueue

cfg = get_config("qwen2-1.5b", smoke=True)
params = registry.get(cfg.family).init(cfg, jax.random.PRNGKey(0))
eng = DecodeEngine(cfg, params, batch_size=2, max_seq=64, eos_id=7)
q = RequestQueue(Request(rid=f"req-{i}",
                         tokens=np.arange(3 + i % 3, dtype=np.int32) + 1,
                         max_new_tokens=3)
                 for i in range(6))
res, stats = eng.serve(q, temperature=0.8, seed=0, compress=True,
                       comp_sweeps=1, comp_impl="mulsum")
assert stats.recycled > 0
buf = b"".join(
    np.asarray(r.tokens).tobytes()
    + b"".join(np.asarray(x).tobytes()
               for c in sorted(r.compressed) for x in r.compressed[c].xs)
    for r in sorted(res, key=lambda r: r.rid))
print(zlib.crc32(buf))
"""


def test_recycled_slot_determinism_across_hash_seeds():
    """Per-request sampling keys and per-leaf factor seeds come from crc32
    of stable identities, never salted hash(): two processes with different
    PYTHONHASHSEED salts must serve the same stream — recycled slots
    included — to identical tokens AND identical compressed factors."""
    root = pathlib.Path(__file__).resolve().parent.parent
    digests = []
    for salt in ("0", "424242"):
        env = dict(os.environ)
        env["PYTHONHASHSEED"] = salt
        env["PYTHONPATH"] = str(root / "src")
        proc = subprocess.run(
            [sys.executable, "-c", _SERVE_DIGEST],
            capture_output=True, text=True, env=env, timeout=600)
        assert proc.returncode == 0, proc.stderr[-2000:]
        digests.append(proc.stdout.strip())
    assert digests[0] == digests[1], digests
