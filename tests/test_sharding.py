"""Sharding rule tables + abstract-spec plumbing (1-device mesh: the rules
are pure functions of mesh *shape*, so a (1,1) mesh exercises the divisibility
logic with axis sizes patched in directly)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.dist.sharding import (
    AxisEnv, cache_specs, param_specs, spec_for_leaf, _roles_for,
)
from repro.models import registry


def env(data=16, model=16, pod=None, fsdp=True):
    shape = {"data": data, "model": model}
    if pod:
        shape = {"pod": pod, **shape}
    return AxisEnv(mesh_shape=shape,
                   fsdp_axes=tuple(a for a in ("pod", "data") if a in shape)
                   if fsdp else (),
                   fsdp_min_size=(1 << 22) if fsdp else (1 << 62))


class FakeLeaf:
    def __init__(self, shape):
        self.shape = shape


class Key:
    def __init__(self, k):
        self.key = k


def spec(names, shape, cfg=None, ax=None):
    path = tuple(Key(n) for n in names)
    return spec_for_leaf(path, FakeLeaf(shape), cfg, ax or env())


def test_attention_weights_tp_and_fsdp():
    # llama wq: (L, D, H*hd) = (126, 16384, 16384): fsdp on D, tp on out
    assert spec(["layers", "attn", "wq"], (126, 16384, 16384)) == \
        P(None, "data", "model")
    # wo transposed roles
    assert spec(["layers", "attn", "wo"], (126, 16384, 16384)) == \
        P(None, "model", "data")


def test_small_tensor_never_fsdp():
    # qwen wq (28, 1536, 1536): big enough? 28*1536*1536 = 66M > 2^22 but the
    # sharded dim itself must divide: 1536 % 16 == 0 -> fsdp applies
    assert spec(["layers", "attn", "wq"], (28, 1536, 1536)) == \
        P(None, "data", "model")
    # tiny norm scale: replicated
    assert spec(["layers", "ln1", "scale"], (28, 1536)) == P(None, None)


def test_nondivisible_dims_stay_replicated():
    # rwkv maa LoRA: explicitly unsharded
    assert spec(["layers", "att", "maa_w1"], (32, 2560, 160)) == \
        P(None, None, None)
    # vocab not multiple of 16 stays unsharded on tp (fsdp on D still applies)
    assert spec(["embed", "tok"], (51865, 384)) == P(None, "data")
    # padded vocab shards
    s = spec(["embed", "tok"], (51968, 4096))
    assert s[0] == "model"


def test_moe_expert_sharding():
    cfg = get_config("kimi-k2-1t-a32b")
    s = spec(["layers", "ffn", "w_gate"], (61, 384, 7168, 2048), cfg=cfg)
    assert s == P(None, "model", "data", None)
    s = spec(["layers", "ffn", "w_down"], (61, 384, 2048, 7168), cfg=cfg)
    assert s == P(None, "model", None, "data")


def test_rwkv_ffn_qualified_rules():
    # channel-mix out-proj (F, D) is ("tp", "fsdp")
    assert _roles_for(["layers", "ffn", "wv"], (8960, 2560), None) == \
        ("tp", "fsdp")
    # attention wv is the generic in-proj rule
    assert _roles_for(["layers", "att", "wv"], (2560, 2560), None) == \
        ("fsdp", "tp")


def test_serving_env_disables_fsdp():
    ax = env(fsdp=False)
    assert spec(["layers", "attn", "wq"], (126, 16384, 16384), ax=ax) == \
        P(None, None, "model")


def test_param_specs_cover_every_leaf():
    """Every arch: spec tree aligns with the param tree, and every sharded
    axis divides its dim."""
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    for arch in ("qwen2-1.5b", "kimi-k2-1t-a32b", "rwkv6-3b",
                 "recurrentgemma-9b", "whisper-tiny", "internvl2-26b"):
        cfg = get_config(arch, smoke=True)
        mod = registry.get(cfg.family)
        shapes = jax.eval_shape(lambda m=mod, c=cfg: m.init(c, jax.random.PRNGKey(0)))
        specs = param_specs(cfg, shapes, mesh)
        n_leaves = len(jax.tree.leaves(shapes))
        n_specs = len(jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P)))
        assert n_leaves == n_specs, arch


def test_cache_specs_shard_seq_over_model():
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = get_config("granite-8b", smoke=True)
    mod = registry.get(cfg.family)
    cache = jax.eval_shape(lambda: mod.init_cache(cfg, 8, 64))
    specs = cache_specs(cfg, cache, mesh)
    # (L, B, KV, S, hd): seq dim is second-to-last
    assert specs["k"][3] == "model"
    assert specs["pos"] == P()


def test_constrain_noop_outside_context():
    from repro.dist.sharding import constrain
    x = jnp.ones((4, 4))
    assert constrain(x, "dp", None) is x
