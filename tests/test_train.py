"""Training substrate tests: optimizer, schedule, checkpointing, data
pipeline determinism, end-to-end loss descent, serve engine."""
import os
import pathlib
import subprocess
import sys
import tempfile

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLMData
from repro.serve import DecodeEngine
from repro.train import checkpoint as ck
from repro.train import optimizer as opt_mod
from repro.train.train_loop import TrainConfig, train


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


# ---- optimizer ----

def test_adamw_minimizes_quadratic():
    cfg = opt_mod.OptConfig(kind="adamw", lr=0.1, warmup_steps=1,
                            total_steps=200, weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt_mod.init(cfg, params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, m = opt_mod.update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adafactor_factored_state_shapes():
    cfg = opt_mod.OptConfig(kind="adafactor", factored_min_dim=4)
    params = {"big": jnp.zeros((8, 16)), "vec": jnp.zeros((8,))}
    state = opt_mod.init(cfg, params)
    assert state["leaves"]["big"]["vr"].shape == (8,)
    assert state["leaves"]["big"]["vc"].shape == (16,)
    assert state["leaves"]["vec"]["v"].shape == (8,)
    # factored memory << full second moment
    grads = {"big": jnp.ones((8, 16)), "vec": jnp.ones((8,))}
    p2, s2, _ = opt_mod.update(cfg, params, grads, state)
    assert jnp.isfinite(p2["big"]).all()


def test_schedule_warmup_and_decay():
    cfg = opt_mod.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    lrs = [float(opt_mod.schedule(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0 + 1e-6          # warmup
    assert lrs[99] < lrs[50] < lrs[11]            # decay
    assert lrs[99] >= 0.1 * 1.0 - 1e-6            # floor


# ---- checkpoint ----

def test_checkpoint_roundtrip_bf16_and_retention():
    tree = {
        "a": jnp.arange(12.0, dtype=jnp.bfloat16).reshape(3, 4),
        "nested": {"b": jnp.asarray([1, 2, 3], jnp.int32)},
    }
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            ck.save(d, s, tree, keep_last=2)
        assert ck.latest_step(d) == 5
        steps = sorted(int(p.name.split("-")[1])
                       for p in __import__("pathlib").Path(d).glob("step-*"))
        assert steps == [4, 5]                    # retention
        restored, manifest = ck.restore(d, tree)
        assert manifest["step"] == 5
        assert restored["a"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(
            np.asarray(restored["a"], np.float32),
            np.asarray(tree["a"], np.float32))
        np.testing.assert_array_equal(np.asarray(restored["nested"]["b"]),
                                      [1, 2, 3])


def test_checkpoint_resume_training():
    mesh = _mesh11()
    cfg = get_config("stablelm-1.6b", smoke=True)
    dcfg = DataConfig(cfg.vocab_size, 32, 4, seed=5)
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainConfig(opt=opt_mod.OptConfig(lr=1e-3, warmup_steps=2,
                                                 total_steps=40),
                           ckpt_dir=d, ckpt_every=5)
        data = SyntheticLMData(dcfg, mesh)
        train(cfg, mesh, tcfg, data.iterate(0), 6, log_every=100, log=lambda *a: None)
        assert ck.latest_step(d) is not None
        # resume continues from the checkpoint (restore path exercised)
        p2, o2, hist = train(cfg, mesh, tcfg, data.iterate(6), 10,
                             log_every=100, log=lambda *a: None)
        assert int(o2["step"]) == 10


# ---- data pipeline ----

def test_data_determinism_and_resume():
    dcfg = DataConfig(vocab_size=97, seq_len=16, global_batch=4, seed=9)
    d1 = SyntheticLMData(dcfg)
    d2 = SyntheticLMData(dcfg)
    b1 = d1.batch_at(42)
    b2 = d2.batch_at(42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 16)
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 97
    it = d1.iterate(42)
    first = next(it)
    np.testing.assert_array_equal(np.asarray(first["tokens"]), b1["tokens"])


def test_data_extra_inputs():
    dcfg = DataConfig(vocab_size=97, seq_len=8, global_batch=2, seed=1,
                      extra_key="audio_embeds", extra_shape=(16, 64))
    b = SyntheticLMData(dcfg).batch_at(0)
    assert b["audio_embeds"].shape == (2, 16, 64)


# ---- end-to-end descent + serve ----

@pytest.mark.parametrize("arch", ["qwen2-1.5b", "rwkv6-3b", "recurrentgemma-9b"])
def test_loss_descends(arch):
    mesh = _mesh11()
    cfg = get_config(arch, smoke=True)
    tcfg = TrainConfig(opt=opt_mod.OptConfig(lr=2e-3, warmup_steps=5,
                                             total_steps=60))
    data = SyntheticLMData(DataConfig(cfg.vocab_size, 32, 8, seed=2), mesh)
    _, _, hist = train(cfg, mesh, tcfg, data.iterate(0), 25,
                       log_every=100, log=lambda *a: None)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_staged_wire_matches_monolithic_grad_sync():
    """TrainConfig.staged_wire routes the §5.5 gradient sync through the
    resumable staged collective; at p = 1 (and in general, leaf-for-leaf)
    it must reproduce the monolithic mp_allreduce path exactly."""
    from repro.train.train_loop import make_train_step, setup
    mesh = _mesh11()
    cfg = get_config("qwen2-1.5b", smoke=True)
    ocfg = opt_mod.OptConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    data = SyntheticLMData(DataConfig(cfg.vocab_size, 32, 8, seed=5), mesh)
    batch = data.device_put(data.batch_at(0))

    outs = {}
    for staged in (False, True):
        tcfg = TrainConfig(opt=ocfg, mode="dp_explicit", mp_wire="bf16",
                           staged_wire=staged)
        params, opt_state, comp_state, _ = setup(cfg, mesh, tcfg)
        step_fn, _ = make_train_step(cfg, mesh, tcfg)
        p2, _, _, m = step_fn(params, opt_state, comp_state, batch)
        outs[staged] = (float(m["loss"]), p2)
    assert outs[False][0] == outs[True][0]
    for a, b in zip(jax.tree.leaves(outs[False][1]),
                    jax.tree.leaves(outs[True][1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serve_engine_greedy_deterministic():
    cfg = get_config("qwen2-1.5b", smoke=True)
    from repro.models import registry
    params = registry.get(cfg.family).init(cfg, jax.random.PRNGKey(0))
    eng = DecodeEngine(cfg, params, max_seq=64, batch_size=2)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8))
    r1 = eng.generate(prompts, steps=6)
    r2 = eng.generate(prompts, steps=6)
    assert r1.tokens.shape == (2, 6)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    assert (r1.tokens < cfg.vocab_size).all()     # never samples vocab padding


def test_serve_engine_eos_retires():
    cfg = get_config("qwen2-1.5b", smoke=True)
    from repro.models import registry
    params = registry.get(cfg.family).init(cfg, jax.random.PRNGKey(0))
    eng = DecodeEngine(cfg, params, max_seq=64, batch_size=2, eos_id=None)
    prompts = np.zeros((2, 4), np.int32)
    r = eng.generate(prompts, steps=4, temperature=1.0, top_k=8, seed=3)
    assert r.tokens.shape[1] == 4
    np.testing.assert_array_equal(r.lengths, [4, 4])   # no EOS: full length


def test_serve_engine_eos_masks_retired_slots():
    """Bugfix regression: an EOS-retired slot's recorded tokens must be
    frozen at eos_id (the engine keeps stepping the static batch, but its
    post-EOS samples are garbage and must never be reported), and lengths
    must report the true per-sequence generated length."""
    cfg = get_config("qwen2-1.5b", smoke=True)
    from repro.models import registry
    params = registry.get(cfg.family).init(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8))
    steps = 8

    # pick an eos_id the greedy decode actually emits mid-stream: run once
    # without EOS and choose sequence 0's token at step 2
    free = DecodeEngine(cfg, params, max_seq=64, batch_size=2,
                        eos_id=None).generate(prompts, steps=steps)
    eos = int(free.tokens[0, 2])

    eng = DecodeEngine(cfg, params, max_seq=64, batch_size=2, eos_id=eos)
    r = eng.generate(prompts, steps=steps)
    assert r.tokens.shape == (2, r.steps)
    for i in range(2):
        row = r.tokens[i]
        hits = np.flatnonzero(row == eos)
        if hits.size:
            first = int(hits[0])
            # greedy decode is deterministic up to retirement
            np.testing.assert_array_equal(row[:first],
                                          free.tokens[i, :first])
            assert (row[first:] == eos).all(), row
            assert int(r.lengths[i]) == first + 1
        else:
            assert int(r.lengths[i]) == r.steps
    # sequence 0 retires by construction (its greedy stream emits eos at
    # step 2 at the latest), so the masking path genuinely ran
    assert int(r.lengths[0]) <= 3 < steps


# ---- determinism: warm-start factor seeding is PYTHONHASHSEED-proof ----

_INIT_STATE_DIGEST = r"""
import zlib
import numpy as np
import jax.numpy as jnp
from repro.train import grad_compress as gc

cfg = gc.CompressorCfg(rank=2, sweeps=1, min_size=16, prec="f32")
params = {"wq": jnp.zeros((8, 12)), "nested": {"wk": jnp.zeros((6, 5, 4))}}
st = gc.init_state(params, cfg, seed=3)
buf = b"".join(
    np.asarray(x).tobytes()
    for leaf in [st["wq"], st["nested"]["wk"]]
    for r in leaf["xs"] for x in r)
print(zlib.crc32(buf))
"""


def test_init_state_deterministic_across_hash_seeds():
    """Bugfix regression: warm-start factors were seeded with
    ``hash(str(path))``, which is salted per process via PYTHONHASHSEED —
    every host/restart drew different factors, silently breaking multi-host
    reproducibility.  Two subprocesses with different salts must now
    produce identical factors."""
    root = pathlib.Path(__file__).resolve().parent.parent
    digests = []
    for salt in ("0", "424242"):
        env = dict(os.environ)
        env["PYTHONHASHSEED"] = salt
        env["PYTHONPATH"] = str(root / "src")
        proc = subprocess.run(
            [sys.executable, "-c", _INIT_STATE_DIGEST],
            capture_output=True, text=True, env=env, timeout=300)
        assert proc.returncode == 0, proc.stderr[-2000:]
        digests.append(proc.stdout.strip())
    assert digests[0] == digests[1], digests
