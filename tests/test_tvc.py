"""Unit tests: single-device TVC (all impls), splitting, BLAS semantics."""
import math

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import tvc, tvc_bytes, tvc_chain, tvc_shape, mode_uv
from repro.core.splitting import (
    best_split_dim, optimal_division, plan_split, plan_split_for_mesh,
)
from repro.kernels import ref

RNG = np.random.default_rng(11)


def rand(shape, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(dtype))


SHAPES = [(7,), (5, 9), (4, 6, 5), (3, 4, 2, 5), (2, 3, 2, 3, 2)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("impl", ["native", "looped", "unfolded", "pallas"])
def test_tvc_matches_oracle_every_mode(shape, impl):
    A = rand(shape)
    for k in range(len(shape)):
        x = rand((shape[k],))
        got = tvc(A, x, k, impl=impl)
        want = ref.tvc_ref(A, x, k)
        assert got.shape == tvc_shape(shape, k)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_tvc_alpha_beta():
    A = rand((6, 5, 4))
    x = rand((5,))
    y = rand((6, 4))
    got = tvc(A, x, 1, alpha=3.0, beta=-2.0, y=y)
    want = 3.0 * ref.tvc_ref(A, x, 1) - 2.0 * y
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_tvc_beta_requires_y():
    with pytest.raises(ValueError):
        tvc(rand((3, 3)), rand((3,)), 0, beta=1.0)


def test_tvc_bad_mode_and_shape():
    with pytest.raises(ValueError):
        tvc(rand((3, 4)), rand((4,)), 2)
    with pytest.raises(ValueError):
        tvc(rand((3, 4)), rand((3,)), 1)


def test_mode_uv():
    assert mode_uv((2, 3, 4, 5), 0) == (1, 2, 60)
    assert mode_uv((2, 3, 4, 5), 2) == (6, 4, 5)
    assert mode_uv((2, 3, 4, 5), 3) == (24, 5, 1)


def test_tvc_chain_matches_composition():
    A = rand((3, 4, 5, 2))
    xs = [rand((n,)) for n in A.shape]
    got = tvc_chain(A, xs, [0, 2, 3])
    want = A
    # contract 0, then 2 (now local 1), then 3 (now local 1)
    want = ref.tvc_ref(want, xs[0], 0)
    want = ref.tvc_ref(want, xs[2], 1)
    want = ref.tvc_ref(want, xs[3], 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_tvc_bytes():
    # read A + read x + write Y (f32)
    assert tvc_bytes((10, 20), 0, 4) == (200 + 10 + 20) * 4
    assert tvc_bytes((10, 20), 0, 4, beta=1.0) == (200 + 10 + 40) * 4


def test_bf16_storage_f32_accum():
    A = rand((32, 16, 8)).astype(jnp.bfloat16)
    x = rand((16,)).astype(jnp.bfloat16)
    got = tvc(A, x, 1, prec="bf16")
    assert got.dtype == jnp.bfloat16
    want = ref.tvc_ref(A, x, 1, prec="bf16")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=3e-2, atol=3e-2)


# ---- splitting ------------------------------------------------------------

def test_optimal_division_promotes_vector_multiples():
    assert optimal_division(979, 8, quantum=8) == 128
    assert optimal_division(64, 8, quantum=8) == 8
    assert optimal_division(4, 3, quantum=2) == 2  # paper Fig. 1 s=2: p -> 2


def test_plan_split_lowers_p():
    plan = plan_split(4, 3, quantum=2)
    assert plan.p == 2 and plan.chunk == 2 and plan.pad == 0


def test_plan_split_bounds_cover_everything():
    plan = plan_split(979, 8)
    covered = []
    for r in range(plan.p):
        lo, hi = plan.bounds(r)
        covered.extend(range(lo, hi))
    assert covered == list(range(979))


def test_plan_split_for_mesh_uses_exactly_p():
    plan = plan_split_for_mesh(979, 16)
    assert plan.p == 16
    assert plan.p * plan.chunk >= 979
    assert plan.pad == plan.p * plan.chunk - 979


def test_best_split_dim_prefers_last_and_avoids_k():
    assert best_split_dim((8, 8, 8), 4) == 2
    assert best_split_dim((8, 8, 8), 4, avoid=2) == 1
    assert best_split_dim((8, 8, 2), 4) == 1  # last dim too small for p=4
