"""Fused-pair (tvc2) coverage: single-launch guarantee asserted on the
jaxpr (incl. through dHOPM_3's fused chains), prime/odd ragged sweeps across
orders 3-4 in f32 + bf16, the fused alpha/beta epilogue vs the two-launch
reference, the no-pad guarantee, the fused-pair streamed-bytes accounting,
and the sweep-table preference of the autotuner.  No optional deps."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import dhopm as dh
from repro.core import memory_model as mm
from repro.core.dtvc import ShardState, dtvc2_local
from repro.core.tvc import tvc as core_tvc, tvc2 as core_tvc2, tvc2_bytes
from repro.kernels import autotune, block_table, ops
from repro.verify.walker import count_primitive

RNG = np.random.default_rng(11)


def rand(shape, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(dtype))


def two_launch_ref(A, x1, k1, x2, alpha=1.0, beta=0.0, y=None):
    """The unfused reference: two single-mode TVCs + explicit update."""
    mid = core_tvc(A, x1, k1, impl="native")
    out = core_tvc(mid, x2, k1, impl="native")
    out = alpha * np.asarray(out, np.float32)
    if beta:
        out = out + beta * np.asarray(y, np.float32)
    return out


def _count_pallas(jaxpr) -> int:
    """pallas_call eqns in a jaxpr, recursing into sub-jaxprs (pjit bodies,
    shard_map bodies, kernel jaxprs)."""
    return count_primitive(jaxpr, "pallas_call")


# ---- correctness: ragged sweeps, both pair kernels, both dtypes -----------

PAIR_SHAPES = [
    # (shape, k1): order 3-4, prime/odd extents, every pair position --
    # v == 1 cases take the dedicated chain-tail kernel
    ((7, 13, 129), 0),       # order-3 leading pair, v = 129
    ((7, 13, 129), 1),       # order-3 tail pair, v = 1
    ((3, 5, 7, 2), 0),       # order-4 leading, v = 14
    ((3, 5, 7, 2), 1),       # order-4 middle, v = 2
    ((3, 5, 7, 2), 2),       # order-4 tail, v = 1
    ((1, 17, 257, 1), 1),    # u = 1 ragged pair ending in v = 1
    ((37, 2, 3, 1), 1),      # singleton trailing dim, tail kernel
]


@pytest.mark.parametrize("shape,k1", PAIR_SHAPES)
@pytest.mark.parametrize("polname", ["f32", "bf16"])
def test_tvc2_ragged_sweep(shape, k1, polname):
    A = rand(shape)
    x1, x2 = rand((shape[k1],)), rand((shape[k1 + 1],))
    if polname == "bf16":
        A, x1, x2 = (t.astype(jnp.bfloat16) for t in (A, x1, x2))
    got = core_tvc2(A, x1, k1, x2, k1 + 1, impl="pallas", prec=polname)
    want = core_tvc2(A, x1, k1, x2, k1 + 1, impl="native", prec=polname)
    assert got.shape == want.shape and got.dtype == want.dtype
    tol = 1e-4 if polname == "f32" else 6e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("shape,k1", [((7, 13, 129), 1), ((3, 5, 7, 2), 0),
                                      ((3, 5, 7, 2), 2)])
@pytest.mark.parametrize("polname", ["f32", "bf16"])
def test_tvc2_epilogue_vs_two_launch(shape, k1, polname):
    """Fused alpha/beta epilogue == two launches + explicit axpby."""
    A = rand(shape)
    x1, x2 = rand((shape[k1],)), rand((shape[k1 + 1],))
    y_shape = tuple(s for i, s in enumerate(shape) if i not in (k1, k1 + 1))
    y = rand(y_shape)
    if polname == "bf16":
        A, x1, x2, y = (t.astype(jnp.bfloat16) for t in (A, x1, x2, y))
    got = core_tvc2(A, x1, k1, x2, k1 + 1, alpha=2.5, beta=-0.5, y=y,
                    impl="pallas", prec=polname)
    want = two_launch_ref(A.astype(jnp.float32), np.asarray(x1, np.float32),
                          k1, np.asarray(x2, np.float32), alpha=2.5,
                          beta=-0.5, y=np.asarray(y, np.float32))
    tol = 1e-4 if polname == "f32" else 8e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               rtol=tol, atol=tol)


def test_tvc2_traced_alpha_beta_under_jit():
    """Runtime-computed alpha/beta must trace cleanly (no Python bool on a
    tracer) and match the static-scalar result."""
    A, x1, x2 = rand((3, 5, 7, 2)), rand((5,)), rand((7,))
    y = rand((3, 2))

    @jax.jit
    def f(A, x1, x2, y, a, b):
        return core_tvc2(A, x1, 1, x2, 2, alpha=a, beta=b, y=y,
                         impl="pallas")

    got = f(A, x1, x2, y, jnp.float32(2.5), jnp.float32(-0.5))
    want = core_tvc2(A, x1, 1, x2, 2, alpha=2.5, beta=-0.5, y=y,
                     impl="native")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)

    y1 = rand((3, 7, 2))

    @jax.jit
    def g(A, x, y, a, b):
        return core_tvc(A, x, 1, alpha=a, beta=b, y=y, impl="native")

    got = g(A, x1, y1, jnp.float32(3.0), jnp.float32(0.5))
    want = core_tvc(A, x1, 1, alpha=3.0, beta=0.5, y=y1, impl="native")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_tvc2_beta_requires_y():
    A = rand((3, 4, 5))
    with pytest.raises(ValueError):
        core_tvc2(A, rand((3,)), 0, rand((4,)), 1, beta=1.0, impl="pallas")
    with pytest.raises(ValueError):
        ops.tvc2_pallas(rand((2, 3, 4, 5)), rand((3,)), rand((4,)), beta=1.0)


# ---- single-launch guarantee (jaxpr) --------------------------------------

@pytest.mark.parametrize("shape", [(4, 5, 7, 3), (4, 5, 7, 1)])
def test_tvc2_is_one_launch(shape):
    """One fused pair == exactly ONE pallas_call, for both the generic and
    the chain-tail (v == 1) kernels, with and without the epilogue."""
    a, x1, x2 = rand(shape), rand((5,)), rand((7,))
    jaxpr = jax.make_jaxpr(
        lambda a, x1, x2: ops.tvc2_pallas(a, x1, x2))(a, x1, x2)
    assert _count_pallas(jaxpr.jaxpr) == 1
    y = rand((shape[0], shape[3]))
    jaxpr = jax.make_jaxpr(
        lambda a, x1, x2, y: ops.tvc2_pallas(a, x1, x2, y, alpha=2.0,
                                             beta=-1.0))(a, x1, x2, y)
    assert _count_pallas(jaxpr.jaxpr) == 1


def _hopm3_launches(shape, fuse_pairs, **kw):
    A = rand(shape)
    xs = [rand((n,)) for n in shape]
    jaxpr = jax.make_jaxpr(lambda A, *xs: dh.hopm3(
        A, list(xs), sweeps=1, impl="pallas", fuse_pairs=fuse_pairs, **kw
    )[0])(A, *xs)
    return _count_pallas(jaxpr.jaxpr)


def test_hopm3_fused_chain_is_one_launch_per_pair():
    """d = 4 sweep: the fused schedule forms 2 adjacent pairs (one of them
    the chain tail) out of 9 single contractions — so exactly 2 launches
    disappear from the jaxpr."""
    unfused = _hopm3_launches((5, 4, 6, 3), fuse_pairs=False)
    fused = _hopm3_launches((5, 4, 6, 3), fuse_pairs=True)
    assert unfused == 9, unfused
    assert fused == unfused - 2, (fused, unfused)


def test_dhopm3_fused_chain_is_one_launch_per_pair():
    """Same assertion through the real dhopm3 entry point (shard_map body,
    p = 1 mesh, s = 0 so both pairs of the d = 4 schedule fuse)."""
    mesh = jax.make_mesh((1,), ("x",))
    shape = (5, 4, 6, 3)
    A = rand(shape)
    xs = [rand((n,)) for n in shape]

    def counts(fuse):
        jaxpr = jax.make_jaxpr(lambda A, *xs: dh.dhopm3(
            A, list(xs), mesh, "x", s=0, sweeps=1, impl="pallas",
            fuse_pairs=fuse)[0])(A, *xs)
        return _count_pallas(jaxpr.jaxpr)

    unfused, fused = counts(False), counts(True)
    assert unfused == 9 and fused == 7, (unfused, fused)


def test_no_pad_in_pair_jaxprs():
    """Zero-copy guarantee extends to both pair kernels + fused epilogue."""
    def prims(fn, *args):
        jaxpr = jax.make_jaxpr(fn)(*args)
        acc = set()

        def walk(j):
            for eqn in j.eqns:
                acc.add(eqn.primitive.name)
                for v in eqn.params.values():
                    for item in (v if isinstance(v, (list, tuple)) else [v]):
                        inner = getattr(item, "jaxpr", item)
                        if hasattr(inner, "eqns"):
                            walk(inner)
        walk(jaxpr.jaxpr)
        return acc

    a, x1, x2 = rand((4, 5, 7, 3)), rand((5,)), rand((7,))
    y = rand((4, 3))
    p = prims(lambda a, x1, x2, y: ops.tvc2_pallas(a, x1, x2, y, alpha=2.0,
                                                   beta=-0.5), a, x1, x2, y)
    assert "pallas_call" in p and "pad" not in p, sorted(p)
    a_t, y_t = rand((4, 5, 7, 1)), rand((4, 1))
    p = prims(lambda a, x1, x2, y: ops.tvc2_pallas(a, x1, x2, y, alpha=2.0,
                                                   beta=-0.5), a_t, x1, x2, y_t)
    assert "pallas_call" in p and "pad" not in p, sorted(p)


# ---- dtvc2_local: shard-level fused pair ----------------------------------

def test_dtvc2_local_tracks_split_and_updates():
    A = rand((6, 5, 7, 3))
    x1, x2 = rand((5,)), rand((7,))
    y = rand((6, 3))
    out, st = dtvc2_local(A, x1, 1, x2, ShardState(split=3), impl="pallas",
                          alpha=2.0, beta=-0.5, y=y)
    want = two_launch_ref(A, x1, 1, x2, alpha=2.0, beta=-0.5, y=y)
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-4, atol=1e-4)
    assert st == ShardState(split=1)        # split above the pair drops by 2


def test_dtvc2_local_rejects_split_in_pair():
    A = rand((6, 5, 7, 3))
    for s in (1, 2):
        with pytest.raises(ValueError):
            dtvc2_local(A, rand((5,)), 1, rand((7,)), ShardState(split=s))


# ---- memory model: fused-pair streamed accounting -------------------------

def test_fused_pair_predicts_strictly_fewer_bytes():
    """Acceptance: memory_model predicts strictly fewer streamed bytes for
    the fused pair than the two-launch (2x dTVC) reference, everywhere."""
    for (u, n1, n2, v) in [(1, 8, 8, 8), (7, 13, 129, 3), (322, 322, 322, 1),
                           (1, 2, 2, 2)]:
        fused = mm.tvc2_streamed_elems(u, n1, n2, v)
        unfused = mm.tvc2_unfused_streamed_elems(u, n1, n2, v)
        assert fused < unfused, (u, n1, n2, v)
        # the gap is exactly the intermediate's write + read-back
        assert unfused - fused == 2 * u * n2 * v
        assert mm.fused_pair_saving(u, n1, n2, v) > 1.0


def test_tvc2_bytes_matches_streamed_elems():
    shape, k1 = (7, 13, 129), 1
    u, n1, n2, v = 7, 13, 129, 1
    assert tvc2_bytes(shape, k1, k1 + 1, 4) == \
        mm.tvc2_streamed_elems(u, n1, n2, v) * 4
    assert tvc2_bytes(shape, k1, k1 + 1, 4, beta=1.0) == \
        mm.tvc2_streamed_elems(u, n1, n2, v, beta=1.0) * 4


def test_simulated_fused_sweep_beats_hopm3():
    for (n, d, p, s) in [(30, 3, 4, 0), (20, 4, 8, 3), (12, 5, 2, 0)]:
        fused = mm.simulate_sweep(n, d, p, s, "hopm3_fused")
        plain = mm.simulate_sweep(n, d, p, s, "hopm3")
        assert fused < plain, (n, d, p, s)
    # d = 3 with s = 2: every candidate pair either crosses the W boundary
    # or contains the split mode -- nothing fuses, the model agrees exactly
    assert mm.simulate_sweep(30, 3, 4, 2, "hopm3_fused") == \
        mm.simulate_sweep(30, 3, 4, 2, "hopm3")


# ---- autotuner: pair blocks + sweep-table preference ----------------------

@pytest.mark.parametrize("storage", [jnp.float32, jnp.bfloat16])
def test_tvc2_pair_blocks_quanta_and_budget(storage):
    q = autotune.sublane_quantum(storage)
    for (u, n1, n2) in [(7, 13, 129), (4096, 4096, 4096), (1, 1, 1)]:
        bu, b1, b2 = autotune.pick_tvc2_pair_blocks(u, n1, n2,
                                                    storage=storage)
        assert bu % q == 0 and b1 % q == 0 and b2 % autotune.LANE == 0
        ssz = jnp.dtype(storage).itemsize
        assert 2 * bu * b1 * b2 * ssz <= autotune.vmem_budget()


@pytest.fixture
def clean_table():
    block_table.clear()
    yield
    block_table.clear()


def test_autotune_prefers_pinned_table_entry(clean_table):
    """Acceptance: a sweep-table entry wins over the heuristic when one
    exists for the (kind, dtype, backend, size-bucket) cell."""
    dims = (40, 96, 640)
    heur = autotune.pick_tvc3_blocks(*dims, table=False)
    pinned = (16, 32, 256)
    assert pinned != heur
    block_table.pin(block_table.entry("tvc3", dims, pinned, jnp.float32,
                                      gbs=99.0))
    assert autotune.pick_tvc3_blocks(*dims) == pinned
    # same size bucket, different exact (ragged) extents: still a hit,
    # sanitized to the new view
    assert autotune.pick_tvc3_blocks(33, 65, 513) == pinned
    # different bucket: miss, heuristic
    assert autotune.pick_tvc3_blocks(7, 13, 129) == \
        autotune.pick_tvc3_blocks(7, 13, 129, table=False)
    # higher-gbs entry for the same cell wins
    block_table.pin(block_table.entry("tvc3", dims, (8, 96, 640),
                                      jnp.float32, gbs=500.0))
    assert autotune.pick_tvc3_blocks(*dims) == (8, 96, 640)


def test_table_entry_is_sanitized_and_budget_checked(clean_table):
    dims = (40, 96, 640)
    # off-quantum junk blocks: rounded to quanta and clamped to the view
    block_table.pin(block_table.entry("tvc3", dims, (3, 50, 1000),
                                      jnp.float32, gbs=9.0))
    bu, bk, bv = autotune.pick_tvc3_blocks(*dims)
    assert bu % 8 == 0 and bk % 8 == 0 and bv % autotune.LANE == 0
    assert bv <= 640 + autotune.LANE
    # an entry that busts a small budget is rejected -> heuristic
    got = autotune.pick_tvc3_blocks(*dims, budget=64 * 1024)
    assert got == autotune.pick_tvc3_blocks(*dims, budget=64 * 1024,
                                            table=False)


def test_table_disable_env_and_backend_filter(clean_table, monkeypatch):
    dims = (40, 96, 640)
    block_table.pin(block_table.entry("tvc3", dims, (16, 32, 256),
                                      jnp.float32, gbs=9.0))
    monkeypatch.setenv("REPRO_TVC_DISABLE_TABLE", "1")
    assert autotune.pick_tvc3_blocks(*dims) == \
        autotune.pick_tvc3_blocks(*dims, table=False)
    monkeypatch.delenv("REPRO_TVC_DISABLE_TABLE")
    # entries measured on another backend never steer this one
    block_table.clear()
    block_table.pin(block_table.entry("tvc3", dims, (16, 32, 256),
                                      jnp.float32, gbs=9.0, backend="tpu"))
    if jax.default_backend() != "tpu":
        assert autotune.pick_tvc3_blocks(*dims) == \
            autotune.pick_tvc3_blocks(*dims, table=False)


def test_pair_kernels_honour_table_blocks(clean_table):
    """A pinned pair-kernel entry flows through ops dispatch and still
    computes the right thing (blocks are a pure perf knob)."""
    block_table.pin(block_table.entry("tvc2_pair", (4, 5, 9), (8, 8, 128),
                                      jnp.float32, gbs=9.0))
    a, x1, x2 = rand((4, 5, 9, 1)), rand((5,)), rand((9,))
    got = ops.tvc2_pallas(a, x1, x2)
    want = np.einsum("uabv,a,b->uv", np.asarray(a), np.asarray(x1),
                     np.asarray(x2))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)
