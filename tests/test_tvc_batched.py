"""Batched TVC subsystem coverage: batched-vs-vmap-native allclose oracles
(orders 3-4, every mode class, f32 + bf16, prime/odd ragged shapes), the
one-launch-per-chain-step jaxpr guarantee of hopm3_batched (launch count
independent of B), the per-batch alpha/beta/y epilogue vs the per-leaf
oracle, batched autotuner/block-table plumbing, batched streamed-bytes
accounting + the launch-amortization predictor, and the grad_compress
regression proving bucketed compression is bitwise-equal to the per-leaf
loop.  No optional deps."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import dhopm as dh
from repro.core import memory_model as mm
from repro.core.tvc import tvc as core_tvc, tvc2_batched, tvc_batched
from repro.kernels import autotune, block_table, ops
from repro.train import grad_compress as gc
from repro.verify.walker import count_primitive

RNG = np.random.default_rng(23)


def rand(shape, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(dtype))


def _count_pallas(jaxpr) -> int:
    return count_primitive(jaxpr, "pallas_call")


# ---- correctness: batched pallas vs the vmap'd native oracle --------------

BATCHED_CASES = [
    # (B, shape, k): orders 3-4, prime/odd ragged extents, every mode class
    # (leading, inner, matvec tail) -- odd B exercises partial batch blocks
    (3, (5, 7, 129), 0),
    (3, (5, 7, 129), 1),
    (3, (5, 7, 129), 2),       # tail: batched matvec kernel
    (5, (3, 5, 7, 2), 0),
    (5, (3, 5, 7, 2), 2),
    (5, (3, 5, 7, 2), 3),      # tail
    (2, (1, 17, 257), 1),      # u = 1 ragged
    (7, (37, 2, 3), 2),        # singleton-ish dims, tail
]


@pytest.mark.parametrize("B,shape,k", BATCHED_CASES)
@pytest.mark.parametrize("polname", ["f32", "bf16"])
def test_tvc_batched_vs_vmap_native(B, shape, k, polname):
    A = rand((B,) + shape)
    x = rand((B, shape[k]))
    if polname == "bf16":
        A, x = A.astype(jnp.bfloat16), x.astype(jnp.bfloat16)
    got = tvc_batched(A, x, k, impl="pallas", prec=polname)
    want = tvc_batched(A, x, k, impl="native", prec=polname)
    assert got.shape == want.shape and got.dtype == want.dtype
    tol = 1e-4 if polname == "f32" else 6e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


PAIR_CASES = [
    (3, (5, 7, 129), 0),       # leading pair, v > 1 (batched tvc4 kernel)
    (3, (5, 7, 129), 1),       # tail pair, v == 1 (batched chain tail)
    (5, (3, 5, 7, 2), 0),
    (5, (3, 5, 7, 2), 2),      # order-4 tail
]


@pytest.mark.parametrize("B,shape,k1", PAIR_CASES)
@pytest.mark.parametrize("polname", ["f32", "bf16"])
def test_tvc2_batched_vs_vmap_native(B, shape, k1, polname):
    A = rand((B,) + shape)
    x1, x2 = rand((B, shape[k1])), rand((B, shape[k1 + 1]))
    if polname == "bf16":
        A, x1, x2 = (t.astype(jnp.bfloat16) for t in (A, x1, x2))
    got = tvc2_batched(A, x1, k1, x2, k1 + 1, impl="pallas", prec=polname)
    want = tvc2_batched(A, x1, k1, x2, k1 + 1, impl="native", prec=polname)
    assert got.shape == want.shape and got.dtype == want.dtype
    tol = 1e-4 if polname == "f32" else 8e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_mulsum_impl_matches_native():
    """The bitwise-batchable mulsum engine is the same math as native."""
    A, x = rand((5, 7, 9)), rand((7,))
    np.testing.assert_allclose(
        np.asarray(core_tvc(A, x, 1, impl="mulsum")),
        np.asarray(core_tvc(A, x, 1, impl="native")), rtol=1e-5, atol=1e-5)


# ---- per-batch alpha/beta/y epilogue vs the per-leaf oracle ---------------

@pytest.mark.parametrize("shape,k", [((5, 7, 9), 1), ((5, 7, 9), 2),
                                     ((3, 5, 7, 2), 1)])
def test_per_batch_epilogue_vs_per_leaf(shape, k):
    B = 4
    A = rand((B,) + shape)
    x = rand((B, shape[k]))
    yshape = tuple(s for i, s in enumerate(shape) if i != k)
    y = rand((B,) + yshape)
    al = rand((B,))
    be = rand((B,))
    got = tvc_batched(A, x, k, alpha=al, beta=be, y=y, impl="pallas")
    for i in range(B):
        want = core_tvc(A[i], x[i], k, alpha=float(al[i]), beta=float(be[i]),
                        y=y[i], impl="native")
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_batched_static_epilogue_and_beta_requires_y():
    B, shape = 3, (5, 7, 9)
    A, x, y = rand((B,) + shape), rand((B, 7)), rand((B, 5, 9))
    got = tvc_batched(A, x, 1, alpha=2.0, beta=-0.5, y=y, impl="pallas")
    want = tvc_batched(A, x, 1, alpha=2.0, beta=-0.5, y=y, impl="native")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    with pytest.raises(ValueError):
        ops.tvc_pallas_batched(A.reshape(B, 5, 7, 9), x, beta=1.0)
    with pytest.raises(ValueError):
        # per-batch beta cannot be proven zero -> y is required
        ops.tvc_pallas_batched(A.reshape(B, 5, 7, 9), x, beta=rand((B,)))


def test_axpby_batched_per_row():
    B, n = 5, 37            # ragged, larger than one lane run? keep small
    x, y = rand((B, n)), rand((B, n))
    al, be = rand((B,)), rand((B,))
    got = ops.axpby_pallas_batched(al, x, be, y)
    want = al[:, None] * x + be[:, None] * y
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # scalar broadcast path
    got = ops.axpby_pallas_batched(2.0, x, -0.5, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(2.0 * x - 0.5 * y),
                               rtol=1e-5, atol=1e-5)


# ---- one launch per chain step, independent of B --------------------------

def _hopm3_batched_launches(B, shape, fuse_pairs):
    A = rand((B,) + shape)
    xs = [rand((B, n)) for n in shape]
    jaxpr = jax.make_jaxpr(lambda A, *xs: dh.hopm3_batched(
        A, list(xs), sweeps=1, impl="pallas", fuse_pairs=fuse_pairs
    )[0])(A, *xs)
    return _count_pallas(jaxpr.jaxpr)


def test_hopm3_batched_one_launch_per_chain_step():
    """Acceptance: the launch count of a batched sweep equals the unbatched
    hopm3 schedule (9 for d = 4; 7 fused) and is INDEPENDENT of B."""
    shape = (5, 4, 6, 3)
    counts = {B: _hopm3_batched_launches(B, shape, False) for B in (1, 2, 5)}
    assert set(counts.values()) == {9}, counts
    fused = {B: _hopm3_batched_launches(B, shape, True) for B in (1, 2, 5)}
    assert set(fused.values()) == {7}, fused


def test_hopm3_batched_matches_vmap_hopm3():
    B, shape = 4, (5, 4, 6, 3)
    A = rand((B,) + shape)
    xs0 = [rand((B, n)) for n in shape]
    for fuse in (False, True):
        xsb, lamb = dh.hopm3_batched(A, xs0, sweeps=2, impl="pallas",
                                     fuse_pairs=fuse)

        def one(A_, *x_):
            xs_, lam_ = dh.hopm3(A_, list(x_), sweeps=2, impl="native",
                                 fuse_pairs=fuse)
            return tuple(xs_), lam_

        xsv, lamv = jax.vmap(one)(A, *xs0)
        np.testing.assert_allclose(np.asarray(lamb), np.asarray(lamv),
                                   rtol=1e-5, atol=1e-5)
        for a, b in zip(xsb, xsv):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-4)


# ---- autotuner: bb dimension + batched table kinds ------------------------

@pytest.mark.parametrize("storage", [jnp.float32, jnp.bfloat16])
def test_batched_blocks_quanta_and_budget(storage):
    q = autotune.sublane_quantum(storage)
    ssz = jnp.dtype(storage).itemsize
    for B, dims in [(8, (7, 13, 129)), (64, (16, 16, 16)), (3, (1, 1, 1))]:
        bb, bu, bk, bv = autotune.pick_tvc3_batched_blocks(
            B, *dims, storage=storage)
        assert 1 <= bb <= B
        assert bu % 8 == 0 and bk % q == 0 and bv % autotune.LANE == 0
        assert 2 * bb * bu * bk * bv * ssz <= autotune.vmem_budget()
        bb2, bu2, bk2 = autotune.pick_tvc2_batched_blocks(
            B, dims[0], dims[1], storage=storage)
        assert 1 <= bb2 <= B and bu2 % q == 0 and bk2 % autotune.LANE == 0


def test_batched_bb_grows_with_budget():
    """The whole VMEM budget is spent across bb tiles: a small cell gets a
    large batch block, and a tiny budget collapses bb back to 1."""
    bb, *_ = autotune.pick_tvc3_batched_blocks(64, 8, 8, 16)
    assert bb > 1
    bb_small, *rest = autotune.pick_tvc3_batched_blocks(
        64, 8, 8, 16, budget=16 * 1024)
    assert bb_small <= bb


@pytest.fixture
def clean_table():
    block_table.clear()
    yield
    block_table.clear()


def test_batched_table_kind_is_consulted(clean_table):
    dims = (8, 8, 8, 16)
    heur = autotune.pick_tvc3_batched_blocks(*dims, table=False)
    pinned = (2, 8, 8, 128)
    assert pinned != heur
    block_table.pin(block_table.entry("tvc3_batched", dims, pinned,
                                      jnp.float32, gbs=99.0))
    assert autotune.pick_tvc3_batched_blocks(*dims) == pinned
    # unbatched lookups never see batched entries
    assert autotune.pick_tvc3_blocks(8, 8, 16) == \
        autotune.pick_tvc3_blocks(8, 8, 16, table=False)


# ---- memory model: batched accounting + launch amortization ---------------

def test_batched_streamed_elems_scale_linearly():
    for (b, u, nk, v) in [(8, 16, 16, 16), (64, 5, 7, 1), (1, 3, 4, 5)]:
        assert mm.tvc_batched_streamed_elems(b, u, nk, v) == \
            b * mm.tvc_streamed_elems(u, nk, v)
        assert mm.tvc2_batched_streamed_elems(b, u, nk, v, 3) == \
            b * mm.tvc2_streamed_elems(u, nk, v, 3)


def test_launch_amortized_speedup_regimes():
    # dispatch-dominated small cell: speedup -> B
    tiny = mm.launch_amortized_speedup(64, 16 * 1024, 10.0, 200.0)
    assert tiny > 10.0
    # stream-dominated big cell: speedup -> 1
    big = mm.launch_amortized_speedup(64, 4 * 1024 ** 3, 10.0, 200.0)
    assert 1.0 < big < 1.05
    # monotone in B
    s8 = mm.launch_amortized_speedup(8, 1024 ** 2, 10.0, 200.0)
    s64 = mm.launch_amortized_speedup(64, 1024 ** 2, 10.0, 200.0)
    assert 1.0 < s8 < s64 < 64.0


# ---- grad_compress: bucketed == per-leaf, bitwise -------------------------

def _run_compress(cfg, grads, state, mesh):
    def body(g, s):
        ng, ns, _ = gc.compress_and_sync(g, s, cfg, "dp")
        return ng, ns

    fn = jax.shard_map(body, mesh=mesh, in_specs=(P(), P()),
                       out_specs=(P(), P()), check_vma=False)
    return jax.jit(fn)(grads, state)


def test_grad_compress_bucketed_is_bitwise_equal():
    """Acceptance: the shape-bucketed scheduler (one hopm3_batched chain per
    bucket) reproduces the per-leaf loop bit for bit — same seeds, same
    factors, same error-feedback state."""
    cfg = gc.CompressorCfg(rank=2, sweeps=2, min_size=16, prec="f32")
    rng = np.random.default_rng(7)

    def r(s):
        return jnp.asarray(rng.normal(size=s).astype(np.float32))

    params = {
        "wq": r((8, 12)), "wk": r((8, 12)), "wv": r((8, 12)),   # bucket of 3
        "mlp": r((6, 5, 4)),                                    # singleton
        "bias": r((3,)),                                        # exact path
    }
    grads = {k: r(v.shape) for k, v in params.items()}
    state = gc.init_state(params, cfg, seed=0)
    mesh = jax.make_mesh((1,), ("dp",))

    g1, s1 = _run_compress(cfg, grads, state, mesh)
    g0, s0 = _run_compress(dataclasses.replace(cfg, bucket=False),
                           grads, state, mesh)
    for a, b in zip(jax.tree.leaves((g1, s1)), jax.tree.leaves((g0, s0))):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_grad_compress_bucketed_compresses():
    """Bucketed compression still actually compresses: the rank-r
    reconstruction plus error feedback is exact (g_hat + e == resid)."""
    cfg = gc.CompressorCfg(rank=2, sweeps=2, min_size=16, prec="f32")
    rng = np.random.default_rng(9)

    def r(s):
        return jnp.asarray(rng.normal(size=s).astype(np.float32))

    params = {"a": r((8, 12)), "b": r((8, 12))}
    grads = {k: r(v.shape) for k, v in params.items()}
    state = gc.init_state(params, cfg, seed=1)
    mesh = jax.make_mesh((1,), ("dp",))
    g1, s1 = _run_compress(cfg, grads, state, mesh)
    for k in params:
        recon = np.asarray(g1[k]) + np.asarray(s1[k]["e"])
        np.testing.assert_allclose(recon, np.asarray(grads[k]),
                                   rtol=1e-5, atol=1e-5)
