"""The static verifier's own coverage: the shared walker (including the
cond-branch regression the old test_serving copy missed), each rule's
*negative* path — a seeded violation must produce exactly one finding with
the right rule id — plus waivers, the JSON report, and the CLI."""
import json

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import memory_model as mm
from repro.core.tvc import tvc
from repro.verify import walker
from repro.verify.__main__ import main as verify_main
from repro.verify.entrypoints import EntryPoint, get_entrypoints
from repro.verify.report import run_entrypoint, run_verify
from repro.verify.rules import (
    RULES, TraceCtx, donated_params, expected_collectives,
    expected_launches, hash_seed_sites, run_rules,
)

SHAPE = (8, 6, 16)


def _rand(shape):
    return jnp.asarray(np.zeros(shape, np.float32))


def _findings(name, params, rule_ids, jaxpr=None):
    return run_rules(TraceCtx(name, jaxpr, params), rule_ids)


# ---- walker ----------------------------------------------------------------

def test_walker_descends_into_cond_branches():
    """Regression: the old test_serving.py walker only recursed into params
    that had a .jaxpr attribute, so a pallas_call inside a lax.cond branch
    (branches is a *tuple* of ClosedJaxprs) was invisible to it."""
    A, x = _rand(SHAPE), _rand((6,))

    def f(pred, A, x):
        return lax.cond(pred,
                        lambda a: tvc(a, x, 1, impl="pallas"),
                        lambda a: jnp.zeros((8, 16), jnp.float32) + a[:, 0],
                        A)

    jx = jax.make_jaxpr(f)(jnp.asarray(True), A, x)
    assert walker.count_primitive(jx, "pallas_call") == 1

    # the old serving-file traversal (reproduced verbatim) misses it
    def old_count(jaxpr):
        n = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                n += 1
            for sub in eqn.params.values():
                if hasattr(sub, "jaxpr"):
                    n += old_count(sub.jaxpr)
        return n

    assert old_count(jx.jaxpr) == 0


def test_walker_kernel_scope_and_named_calls():
    A, x = _rand(SHAPE), _rand((6,))
    jx = jax.make_jaxpr(lambda a: tvc(a, x, 1, impl="pallas"))(A)
    counts = walker.primitive_counts(jx, kernel_only=True)
    assert counts["pallas_call"] == 0        # the call itself is host-side
    assert sum(counts.values()) > 0          # but the kernel body is seen
    roll = jax.make_jaxpr(lambda t: jnp.roll(t, 5))(x)
    assert walker.count_named_calls(roll, "roll") == 1
    assert walker.count_named_calls(jx, "roll") == 0
    assert len(walker.collect_eqns(jx)) == sum(
        walker.primitive_counts(jx).values())


# ---- seeded violations: exactly one finding, right rule id -----------------

def test_seeded_pad_fires_no_pad():
    A, x = _rand(SHAPE), _rand((6,))
    jx = jax.make_jaxpr(
        lambda a: tvc(jnp.pad(a, ((0, 0), (1, 1), (0, 0))),
                      jnp.pad(x, (1, 1)), 1, impl="pallas"))(A)
    out = _findings("seeded_pad", {}, ["no_pad"], jx)
    assert [f.rule for f in out] == ["no_pad"]


def test_seeded_stack_fires_no_stack():
    rows = [_rand((5, 7)) for _ in range(4)]
    jx = jax.make_jaxpr(lambda *rs: jnp.stack(rs))(*rows)
    out = _findings("seeded_stack", {}, ["no_stack"], jx)
    assert [f.rule for f in out] == ["no_stack"]


def test_seeded_extra_launch_fires_launch_count():
    A, x = _rand(SHAPE), _rand((6,))
    jx = jax.make_jaxpr(
        lambda a: tvc(a, x, 1, impl="pallas")
        + tvc(a, x, 1, impl="pallas"))(A)
    out = _findings("seeded_launch", {"launch": {"kind": "tvc"}},
                    ["launch_count"], jx)
    assert [f.rule for f in out] == ["launch_count"]
    assert "closed form says 1" in out[0].message


def test_seeded_undemoted_hop_fires_wire_demotion():
    mesh = jax.sharding.AbstractMesh((("x", 8),))
    fn = jax.shard_map(
        lambda t: lax.ppermute(t, "x", [(i, (i + 1) % 8) for i in range(8)]),
        mesh=mesh, in_specs=P(), out_specs=P(), check_vma=False)
    jx = jax.make_jaxpr(fn)(jnp.ones((16,), jnp.float32))
    # the hop rides the wire in f32 while the policy stores bf16
    out = _findings("seeded_hop", {"schedule": {"prec": "bf16"}},
                    ["wire_demotion"], jx)
    assert [f.rule for f in out] == ["wire_demotion"]
    # and is clean under the policy it actually honors
    assert _findings("ok_hop", {"schedule": {"prec": "f32"}},
                     ["wire_demotion"], jx) == []


def test_seeded_hash_seed_fires_no_hash_seed(tmp_path):
    bad = ("import jax\n"
           "def init_state(path):\n"
           "    return jax.random.PRNGKey(hash(str(path)) % 2**31)\n")
    assert len(hash_seed_sites(bad, "bad.py")) == 1
    (tmp_path / "seeded.py").write_text(bad)
    out = _findings("seeded_hash", {"source_root": str(tmp_path)},
                    ["no_hash_seed"])
    assert [f.rule for f in out] == ["no_hash_seed"]
    assert "seeded.py:3" in out[0].message


def test_seeded_reduce_sum_fires_mulsum_determinism():
    jx = jax.make_jaxpr(lambda a: jnp.sum(a, axis=1))(_rand(SHAPE))
    out = _findings("seeded_reduce", {}, ["mulsum_determinism"], jx)
    assert [f.rule for f in out] == ["mulsum_determinism"]


def test_seeded_undonated_buffer_fires_donation():
    def f(buf, r):
        return buf.at[0].set(r)

    # no donate_argnums: the compiled module aliases nothing
    text = jax.jit(f).lower(
        _rand((3, 5)), _rand((5,))).compile().as_text()
    out = _findings(
        "seeded_donation",
        {"donation": {"compiled_text": text, "donated": [0]}},
        ["donation"])
    assert [f.rule for f in out] == ["donation"]


# ---- closed-form expectations stay closed-form -----------------------------

def test_expected_launches_recomputed_from_memory_model():
    spec = {"kind": "chain", "d": 4, "s": 0, "fuse_pairs": "auto",
            "sweeps": 3}
    assert expected_launches(spec) \
        == 3 * mm.dhopm_launches_per_sweep(4, 0, "auto")


def test_expected_collectives_schedule():
    # (8, 6, 16) at p=8 is all-doubling: 2 reductions x log2(8) hops + the
    # split all-gather; bf16 changes nothing in the doubling regime
    for prec in ("f32", "bf16"):
        got = expected_collectives(
            {"shape": (8, 6, 16), "p": 8, "s": 0, "prec": prec})
        assert got == {"ppermute": 6, "psum": 0, "all_gather": 1}
    # ring regime: f32 rides the psum fast path, bf16 pays the staged hops
    ring_f32 = expected_collectives(
        {"shape": (80000, 8, 8), "p": 8, "s": 1, "prec": "f32"})
    assert ring_f32 == {"ppermute": 3, "psum": 1, "all_gather": 1}
    ring_bf16 = expected_collectives(
        {"shape": (80000, 8, 8), "p": 8, "s": 1, "prec": "bf16"})
    assert ring_bf16 == {"ppermute": 10, "psum": 0, "all_gather": 2}


def test_donated_params_parser():
    text = ("HloModule jit_f, is_scheduled=true, "
            "input_output_alias={ {}: (0, {}, may-alias) }, "
            "entry_computation_layout={(f32[3,5]{1,0})->f32[3,5]{1,0}}")
    assert donated_params(text) == {0}
    assert donated_params("HloModule jit_f") == set()


# ---- waivers, report, CLI --------------------------------------------------

def _seeded_stack_ep():
    rows = [_rand((5, 7)) for _ in range(4)]
    jx = jax.make_jaxpr(lambda *rs: jnp.stack(rs))(*rows)
    return EntryPoint("seeded", lambda: TraceCtx("seeded", jx, {}),
                      ("no_stack",))


def test_waived_finding_does_not_block():
    ep = _seeded_stack_ep()
    assert run_entrypoint(ep)["ok"] is False
    waived = run_entrypoint(ep, {("seeded", "no_stack"): "known cold path"})
    assert waived["ok"] is True
    assert waived["findings"][0]["waived"] is True


def test_run_verify_green_on_head_subset():
    report = run_verify(names=["tvc_pallas_m1", "arena_assemble_rows",
                               "source_no_hash_seed"])
    assert report["ok"] is True
    assert report["summary"]["entrypoints"] == 3
    assert report["summary"]["findings"] == 0


def test_every_registered_rule_is_exercised_by_an_entrypoint():
    used = {r for ep in get_entrypoints() for r in ep.rules}
    assert used == set(RULES), (used, set(RULES))


def test_cli_json_report(tmp_path):
    out = tmp_path / "report.json"
    rc = verify_main(["--entry", "arena_assemble_rows",
                      "--entry", "source_no_hash_seed",
                      "--json", str(out)])
    assert rc == 0
    report = json.loads(out.read_text())
    assert report["ok"] is True
    assert {r["entrypoint"] for r in report["entrypoints"]} \
        == {"arena_assemble_rows", "source_no_hash_seed"}


def test_cli_waiver_file(tmp_path):
    wf = tmp_path / "waivers.json"
    wf.write_text(json.dumps([{"entrypoint": "arena_assemble_rows",
                               "rule": "no_stack",
                               "reason": "example"}]))
    rc = verify_main(["--entry", "arena_assemble_rows",
                      "--waivers", str(wf)])
    assert rc == 0
